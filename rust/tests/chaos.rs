//! Chaos harness: the serving stack under a deterministic, seeded
//! fault schedule ([`dynamap::fault`]).
//!
//! Each test installs a [`FaultPlan`] through the RAII [`FaultGuard`]
//! and drives a live loopback server (or the in-process registry)
//! while specific sites misbehave: slow layers, panicking compute,
//! dead schedulers, dropped/stalled connections, corrupted reply
//! frames. The invariants under fire are always the same:
//!
//! * **exactly one typed reply per request** — every offered request
//!   is accounted as ok, shed, deadline-missed or errored; nothing is
//!   double-counted, nothing vanishes;
//! * **zero admission-permit leaks** — `assert_quiesced()` after every
//!   storm;
//! * **blast-radius one** — a poisoned request fails alone, its batch
//!   siblings return bitwise-correct results; a dead scheduler costs
//!   one re-host, not the model;
//! * **the server outlives the storm** — a post-storm ping and a
//!   bitwise-checked inference must succeed, and the drain is clean.
//!
//! The fault registry is process-global, so every test serializes on
//! [`chaos_lock`] and scopes its plan with [`FaultGuard`].

use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use dynamap::api::{Backend, Compiler, Device, DynamapError, Session};
use dynamap::fault::{FaultGuard, FaultPlan, Site, SiteConfig};
use dynamap::net::{Client, HedgeConfig, NetServer, RetryPolicy};
use dynamap::serve::loadgen::{open_loop, open_loop_input, OpenLoopConfig};
use dynamap::serve::{BatchConfig, ModelRegistry, RegistryConfig};
use dynamap::util::parallel::parallel_run;

/// Serializes the tests in this binary: the fault registry is global,
/// and a plan installed for one test must never leak into another.
fn chaos_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

/// Seed for the fault schedules; `DYNAMAP_FAULT_SEED` (pinned in the
/// CI chaos-smoke job) overrides so a failing schedule can be replayed.
fn fault_seed() -> u64 {
    std::env::var("DYNAMAP_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(99)
}

fn temp_root(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("dynamap_chaos_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn registry(
    root: &PathBuf,
    max_batch: usize,
    max_wait_ms: u64,
    max_inflight: usize,
) -> Arc<ModelRegistry> {
    Arc::new(ModelRegistry::new(RegistryConfig {
        artifacts_root: root.join("zoo"),
        plan_cache: Some(root.join("plans")),
        capacity: 0,
        synthesize_missing: true,
        seed: 0xA11CE,
        compiler: Compiler::new().device(Device::small_edge()),
        batch: BatchConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
        },
        max_inflight,
        profile: false,
        slos: Default::default(),
    }))
}

/// Sequential reference over the same synthesized artifacts and plan
/// cache — served replies must be bitwise-equal to this.
fn reference_session(root: &PathBuf) -> Session {
    let dir = root.join("zoo").join("mini-inception");
    Session::builder(dir.to_str().unwrap().to_string())
        .backend(Backend::Native)
        .compiler(Compiler::new().device(Device::small_edge()))
        .plan_cache(root.join("plans"))
        .build()
        .unwrap()
}

#[test]
fn requests_expiring_in_queue_are_shed_before_compute() {
    let _serial = chaos_lock();
    let root = temp_root("queue_deadline");
    // max_wait 120 ms ≫ the 10 ms deadline: a lone request must sit in
    // the queue past its deadline and be shed at flush time
    let reg = registry(&root, 8, 120, 0);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let input = open_loop_input(99, 0, dims);

    let e = reg
        .infer_with_deadline(
            "mini",
            &input,
            Some(std::time::Instant::now() + Duration::from_millis(10)),
        )
        .unwrap_err();
    match e {
        DynamapError::DeadlineExceeded { model, waited_ms } => {
            assert_eq!(model, "mini-inception");
            assert!(waited_ms >= 10, "expired only after its {waited_ms} ms queue wait");
        }
        other => panic!("expected DeadlineExceeded, got {other}"),
    }
    let snap = host.metrics().snapshot();
    assert_eq!(snap.deadline_miss, 1);
    assert_eq!(snap.batches, 0, "an expired request must never enter a batch");
    assert_eq!(snap.requests, 0, "sheds are not served requests");

    // an already-expired deadline is shed pre-admission: waited_ms == 0
    let e = reg
        .infer_with_deadline("mini", &input, Some(std::time::Instant::now()))
        .unwrap_err();
    assert!(
        matches!(e, DynamapError::DeadlineExceeded { waited_ms: 0, .. }),
        "pre-admission shed never waits: {e}"
    );

    reg.assert_quiesced();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn one_poisoned_request_fails_alone_while_batch_siblings_complete() {
    let _serial = chaos_lock();
    let root = temp_root("panic_isolation");
    let reg = registry(&root, 4, 30, 0);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.local_addr().to_string()).unwrap();

    // reference replies BEFORE the guard: the reference session shares
    // the WorkerPanic site and must not trip it
    let mut session = reference_session(&root);
    let expected: Vec<_> =
        (0..4).map(|i| session.infer(&open_loop_input(99, i, dims)).unwrap().0).collect();

    // exactly one request (rate 1.0, limit 1) panics mid-compute
    let guard = FaultGuard::install(FaultPlan::new(fault_seed()).with_config(
        Site::WorkerPanic,
        SiteConfig { rate: 1.0, limit: 1, delay: Duration::ZERO },
    ));
    let results = parallel_run(4, |i| client.infer("mini", &open_loop_input(99, i, dims)));
    assert_eq!(dynamap::fault::fired(Site::WorkerPanic), 1, "the site fired exactly once");
    drop(guard);

    let mut panicked = 0usize;
    for (i, r) in results.iter().enumerate() {
        match r {
            Ok((out, _)) => {
                assert_eq!(out, &expected[i], "sibling {i} corrupted by a panicking peer");
            }
            Err(DynamapError::Serve(msg)) => {
                assert!(msg.contains("panicked"), "typed panic reply carries the cause: {msg}");
                panicked += 1;
            }
            Err(other) => panic!("request {i}: expected Serve(panicked) or Ok, got {other}"),
        }
    }
    assert_eq!(panicked, 1, "blast radius is exactly one request");
    assert_eq!(host.metrics().snapshot().panics_recovered, 1);

    // the server took a panic and kept serving
    client.ping().unwrap();
    let (out, _) = client.infer("mini", &open_loop_input(99, 0, dims)).unwrap();
    assert_eq!(out, expected[0]);

    client.shutdown_server().unwrap();
    server.shutdown();
    reg.assert_quiesced(); // the panicked request released its permit too
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn dead_scheduler_wedges_one_host_and_the_registry_rehosts_it() {
    let _serial = chaos_lock();
    let root = temp_root("wedged");
    let reg = registry(&root, 4, 5, 0);
    let before = reg.host("mini").unwrap();
    let dims = before.input_dims();
    let input = open_loop_input(99, 0, dims);
    let mut session = reference_session(&root);
    let expected = session.infer(&input).unwrap().0;

    // the scheduler thread dies on the first request it dequeues
    let guard = FaultGuard::install(FaultPlan::new(fault_seed()).with_config(
        Site::SchedulerPanic,
        SiteConfig { rate: 1.0, limit: 1, delay: Duration::ZERO },
    ));
    // the caller still gets its reply: the registry detects the wedged
    // queue (open but dead), evicts the poisoned host, re-hosts from
    // the plan cache and retries — invisible to the caller
    let (out, _) = reg.infer("mini", &input).unwrap();
    assert_eq!(dynamap::fault::fired(Site::SchedulerPanic), 1);
    drop(guard);
    assert_eq!(out, expected, "reply after re-host != sequential Session::infer");

    let after = reg.host("mini").unwrap();
    assert!(
        !Arc::ptr_eq(&before, &after),
        "the wedged host must have been replaced, not resurrected"
    );
    assert!(!after.is_wedged());

    reg.assert_quiesced();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn seeded_storm_full_soak_accounts_every_request_and_drains_clean() {
    let _serial = chaos_lock();
    let root = temp_root("soak");
    let reg = registry(&root, 8, 5, 32);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();

    // a client with the full reliability kit: transport + shed retries
    // under backoff, hedging, a bounded budget — counters mirrored into
    // the server's per-model metrics
    let client = Client::connect_with(
        server.local_addr().to_string(),
        RetryPolicy {
            transport_attempts: 3,
            overloaded_attempts: 2,
            retry_budget: 128,
            seed: fault_seed(),
            hedge: Some(HedgeConfig::default()),
            ..RetryPolicy::default()
        },
    )
    .unwrap();
    client.bind_metrics(host.metrics().clone());

    let mut session = reference_session(&root);
    let expected0 = session.infer(&open_loop_input(99, 0, dims)).unwrap().0;

    // the storm: slow layers, panics, stalls, drops, corrupted replies
    // — all seeded, so a failure replays with DYNAMAP_FAULT_SEED
    let plan = FaultPlan::new(fault_seed())
        .with_config(
            Site::SlowLayer,
            SiteConfig { rate: 0.05, limit: 0, delay: Duration::from_millis(3) },
        )
        .with(Site::WorkerPanic, 0.02)
        .with_config(
            Site::ConnStall,
            SiteConfig { rate: 0.05, limit: 0, delay: Duration::from_millis(5) },
        )
        .with(Site::ConnDrop, 0.03)
        .with(Site::CorruptReply, 0.03);
    let guard = FaultGuard::install(plan);

    let cfg = OpenLoopConfig {
        model: "mini".into(),
        rate_qps: 800.0,
        requests: 150,
        seed: 99,
        workers: 16,
        deadline: Some(Duration::from_millis(250)),
        trace: false,
    };
    let report = open_loop(&client, &cfg).unwrap();
    drop(guard);

    // exactly one typed outcome per offered request — the storm may
    // shift requests between buckets, never lose or duplicate them
    assert_eq!(report.sent, 150);
    assert_eq!(
        report.ok + report.shed + report.deadline_miss + report.errors,
        150,
        "accounting hole under faults: {}",
        report.summary()
    );
    assert!(report.ok > 0, "the server kept serving through the storm: {}", report.summary());

    // client-side reliability spend is visible and bounded
    let stats = client.stats();
    assert!(
        stats.budget_remaining <= 128,
        "budget only decreases: {} left",
        stats.budget_remaining
    );
    let snap = host.metrics().snapshot();
    assert_eq!(snap.retries, stats.retries, "bound metrics mirror client retries");
    assert_eq!(snap.hedges_won, stats.hedges_won, "bound metrics mirror hedge wins");

    // post-storm: faults off, the server is intact — liveness, bitwise
    // correctness, clean drain, zero leaked permits
    client.ping().unwrap();
    let (out, _) = client.infer("mini", &open_loop_input(99, 0, dims)).unwrap();
    assert_eq!(out, expected0, "post-storm reply != sequential Session::infer");

    client.shutdown_server().unwrap();
    server.shutdown();
    reg.assert_quiesced();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn artifact_io_faults_surface_typed_and_do_not_poison_the_registry() {
    let _serial = chaos_lock();
    let root = temp_root("artifact_io");
    let reg = registry(&root, 4, 2, 0);

    // every artifact load fails while the fault is armed (limit 1: the
    // first host attempt eats it)
    let guard = FaultGuard::install(FaultPlan::new(fault_seed()).with_config(
        Site::ArtifactIo,
        SiteConfig { rate: 1.0, limit: 1, delay: Duration::ZERO },
    ));
    let err = reg.host("mini").unwrap_err();
    assert!(
        matches!(err, DynamapError::Io { .. }),
        "injected artifact I/O error must stay typed: {err}"
    );
    assert_eq!(dynamap::fault::fired(Site::ArtifactIo), 1);
    drop(guard);

    // the failed host left nothing behind: hosting works immediately
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    assert!(reg.infer("mini", &open_loop_input(99, 0, dims)).is_ok());

    reg.assert_quiesced();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}
