//! PJRT integration tests: every (layer, algorithm) artifact must
//! reproduce the Python oracle's per-layer golden outputs, and the
//! end-to-end session must reproduce the whole-network golden.
//!
//! These tests are skipped (with a note) when `make artifacts` has not
//! been run.

use dynamap::api::{Backend, Compiler, Policy, Session};
use dynamap::runtime::{Manifest, PjrtRuntime, TensorBuf};

fn artifacts_dir() -> Option<String> {
    let d = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(d).join("manifest.json").exists() {
        Some(d.to_string())
    } else {
        eprintln!("skipping PJRT test: run `make artifacts` first");
        None
    }
}

fn safe(name: &str) -> String {
    name.replace('/', "_")
}

#[test]
fn every_layer_algo_artifact_matches_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let mut rt = PjrtRuntime::cpu().unwrap();
    let mut checked = 0;
    for layer in &m.layers {
        let gi = m.load_f32(&format!("golden_in__{}.bin", safe(&layer.name))).unwrap();
        let go = m.load_f32(&format!("golden_out__{}.bin", safe(&layer.name))).unwrap();
        let x = TensorBuf::new(vec![layer.c_in, layer.h1, layer.h2], gi);
        let w = TensorBuf::new(
            vec![layer.c_out, layer.c_in, layer.k1, layer.k2],
            m.weights(layer).unwrap(),
        );
        for (algo, file) in &layer.algos {
            let out = rt
                .execute(&m.dir.join(file), &[&x, &w], vec![layer.c_out, layer.o1, layer.o2])
                .unwrap();
            let max_err = out
                .data
                .iter()
                .zip(&go)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_err < 1e-3,
                "{} [{algo}]: max |Δ| = {max_err} vs oracle",
                layer.name
            );
            checked += 1;
        }
    }
    assert!(checked >= 16, "expected ≥16 (layer, algo) pairs, checked {checked}");
}

#[test]
fn session_reproduces_golden_for_every_policy() {
    let Some(dir) = artifacts_dir() else { return };
    for policy in [
        None,
        Some(Policy::Im2colOnly),
        Some(Policy::Kn2rowApplied),
        Some(Policy::WinoApplied),
        Some(Policy::Greedy),
    ] {
        let mut builder = Session::builder(dir.as_str());
        if let Some(p) = policy {
            builder = builder.policy(p);
        }
        let mut session = builder.build().unwrap();
        assert_eq!(session.model(), "mini-inception");
        let err = session.validate_golden().unwrap();
        assert!(err < 1e-3, "{policy:?}: golden max |Δ| = {err}");
    }
}

#[test]
fn session_infer_batch_matches_sequential() {
    let Some(dir) = artifacts_dir() else { return };
    let mut session = Session::builder(dir.as_str()).build().unwrap();
    let (gi, _) = session.manifest().golden().unwrap();
    let (c, h1, h2) = session.manifest().input;
    let golden = TensorBuf::new(vec![c, h1, h2], gi);

    let n = 3;
    let batch: Vec<TensorBuf> = vec![golden.clone(); n];
    let (outputs, metrics) = session.infer_batch(&batch).unwrap();
    assert_eq!(outputs.len(), n);
    assert_eq!(metrics.per_request.len(), n);
    assert_eq!(metrics.stats.count(), n, "aggregate stats must count N requests");
    assert_eq!(session.stats().count(), n, "session-wide stats must count N requests");

    // batched outputs are bit-identical to N sequential infer calls
    for (i, batched) in outputs.iter().enumerate() {
        let (seq, _) = session.infer(&golden).unwrap();
        assert_eq!(batched, &seq, "request {i}: batched != sequential");
    }
    assert_eq!(session.stats().count(), 2 * n);
}

#[test]
fn native_backend_reproduces_goldens_and_parallel_batch() {
    // the kernel-layer backend must agree with the Python oracle on the
    // same manifest the PJRT backend serves, and its parallel batch
    // path must be bit-identical to sequential inference
    let Some(dir) = artifacts_dir() else { return };
    let mut native =
        Session::builder(dir.as_str()).backend(Backend::Native).build().unwrap();
    assert_eq!(native.loaded_executables(), 0);
    let err = native.validate_golden().unwrap();
    assert!(err < 1e-3, "native kernel backend golden max |Δ| = {err}");

    let (gi, _) = native.manifest().golden().unwrap();
    let (c, h1, h2) = native.manifest().input;
    let golden = TensorBuf::new(vec![c, h1, h2], gi);
    let batch = vec![golden.clone(); 4];
    let (outs, metrics) = native.infer_batch(&batch).unwrap();
    assert_eq!(metrics.stats.count(), 4);
    for (i, batched) in outs.iter().enumerate() {
        let (seq, _) = native.infer(&golden).unwrap();
        assert_eq!(batched, &seq, "request {i}: parallel batched != sequential");
    }
}

#[test]
fn session_loads_cached_plan_without_rerunning_dse() {
    let Some(dir) = artifacts_dir() else { return };
    let cache_dir = std::env::temp_dir()
        .join(format!("dynamap_session_cache_{}", std::process::id()));
    std::fs::create_dir_all(&cache_dir).ok();

    // first session: compiles the plan and persists it
    let c1 = Compiler::new();
    std::fs::remove_file(cache_dir.join(c1.cache_file_name("mini-inception"))).ok();
    let s1 = Session::builder(dir.as_str())
        .compiler(c1.clone())
        .plan_cache(&cache_dir)
        .build()
        .unwrap();
    assert!(!s1.plan_from_cache());
    assert_eq!(c1.compile_count(), 1);

    // fresh session with an equivalent compiler: plan comes from disk,
    // the DSE (and CostGraph::build) never runs
    let c2 = Compiler::new();
    let mut s2 = Session::builder(dir.as_str())
        .compiler(c2.clone())
        .plan_cache(&cache_dir)
        .build()
        .unwrap();
    assert!(s2.plan_from_cache());
    assert_eq!(c2.compile_count(), 0, "cached session must not re-run the DSE");
    assert_eq!(
        s2.plan().unwrap().plan.mapping.assignment,
        s1.plan().unwrap().plan.mapping.assignment
    );
    // and it still serves correctly
    let err = s2.validate_golden().unwrap();
    assert!(err < 1e-3, "cached-plan session golden max |Δ| = {err}");
    std::fs::remove_dir_all(&cache_dir).ok();
}

#[test]
fn session_serves_explicit_plan_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let artifact = Compiler::new()
        .compile(&dynamap::graph::zoo::mini_inception())
        .unwrap();
    let mut session =
        Session::builder(dir.as_str()).plan(artifact).build().unwrap();
    assert!(session.plan_from_cache());
    let err = session.validate_golden().unwrap();
    assert!(err < 1e-3);
}

#[test]
fn fused_artifact_matches_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let Some(fused) = m.fused.clone() else { return };
    let (gi, go) = m.golden().unwrap();
    let (c, h1, h2) = m.input;
    let x = TensorBuf::new(vec![c, h1, h2], gi);
    let mut rt = PjrtRuntime::cpu().unwrap();
    let shape: Vec<usize> = m.golden_output_shape.clone();
    let out = rt.execute(&m.dir.join(&fused), &[&x], shape).unwrap();
    let max_err = out
        .data
        .iter()
        .zip(&go)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "fused: max |Δ| = {max_err}");
}

#[test]
fn default_session_build_serves_golden() {
    // the plain front door: a default (optimal-mapping) `Session::builder`
    // build over AOT artifacts, no policy or custom map
    let Some(dir) = artifacts_dir() else { return };
    let mut session = Session::builder(dir.as_str()).build().unwrap();
    let err = session.validate_golden().unwrap();
    assert!(err < 1e-3, "default session golden max |Δ| = {err}");
    assert!(session.loaded_executables() > 0);
}
