//! PJRT integration tests: every (layer, algorithm) artifact must
//! reproduce the Python oracle's per-layer golden outputs, and the
//! end-to-end engine must reproduce the whole-network golden.
//!
//! These tests are skipped (with a note) when `make artifacts` has not
//! been run.

use dynamap::coordinator::{EnginePolicy, InferenceEngine};
use dynamap::cost::graph_build::Policy;
use dynamap::runtime::{Manifest, PjrtRuntime, TensorBuf};

fn artifacts_dir() -> Option<String> {
    let d = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(d).join("manifest.json").exists() {
        Some(d.to_string())
    } else {
        eprintln!("skipping PJRT test: run `make artifacts` first");
        None
    }
}

fn safe(name: &str) -> String {
    name.replace('/', "_")
}

#[test]
fn every_layer_algo_artifact_matches_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let mut rt = PjrtRuntime::cpu().unwrap();
    let mut checked = 0;
    for layer in &m.layers {
        let gi = m.load_f32(&format!("golden_in__{}.bin", safe(&layer.name))).unwrap();
        let go = m.load_f32(&format!("golden_out__{}.bin", safe(&layer.name))).unwrap();
        let x = TensorBuf::new(vec![layer.c_in, layer.h1, layer.h2], gi);
        let w = TensorBuf::new(
            vec![layer.c_out, layer.c_in, layer.k1, layer.k2],
            m.weights(layer).unwrap(),
        );
        for (algo, file) in &layer.algos {
            let out = rt
                .execute(&m.dir.join(file), &[&x, &w], vec![layer.c_out, layer.o1, layer.o2])
                .unwrap();
            let max_err = out
                .data
                .iter()
                .zip(&go)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_err < 1e-3,
                "{} [{algo}]: max |Δ| = {max_err} vs oracle",
                layer.name
            );
            checked += 1;
        }
    }
    assert!(checked >= 16, "expected ≥16 (layer, algo) pairs, checked {checked}");
}

#[test]
fn engine_reproduces_golden_for_every_policy() {
    let Some(dir) = artifacts_dir() else { return };
    for policy in [
        EnginePolicy::Optimal,
        EnginePolicy::Baseline(Policy::Im2colOnly),
        EnginePolicy::Baseline(Policy::Kn2rowApplied),
        EnginePolicy::Baseline(Policy::WinoApplied),
        EnginePolicy::Baseline(Policy::Greedy),
    ] {
        let label = format!("{policy:?}");
        let mut engine = InferenceEngine::new(&dir, policy).unwrap();
        let err = engine.validate_golden().unwrap();
        assert!(err < 1e-3, "{label}: golden max |Δ| = {err}");
    }
}

#[test]
fn fused_artifact_matches_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let Some(fused) = m.fused.clone() else { return };
    let (gi, go) = m.golden().unwrap();
    let (c, h1, h2) = m.input;
    let x = TensorBuf::new(vec![c, h1, h2], gi);
    let mut rt = PjrtRuntime::cpu().unwrap();
    let shape: Vec<usize> = m.golden_output_shape.clone();
    let out = rt.execute(&m.dir.join(&fused), &[&x], shape).unwrap();
    let max_err = out
        .data
        .iter()
        .zip(&go)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "fused: max |Δ| = {max_err}");
}
