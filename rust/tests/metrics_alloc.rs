//! Regression for the `ServerMetrics::report` hot-path cost: with the
//! log-bucketed histogram behind `ModelMetrics`, producing a stats
//! report must never re-sort (or even copy) the latency samples — at
//! 64Ki recorded requests a sort-based percentile path would allocate
//! ≥ 512 KiB per report, which this binary's counting allocator would
//! see. The same run cross-checks the histogram percentiles against an
//! exact sorted-sample computation on seed-99 data.
//!
//! One `#[test]` on purpose: the allocation counter is process-global,
//! and a sibling test allocating concurrently would pollute the byte
//! delta measured around `report()`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use dynamap::obs::LogHistogram;
use dynamap::serve::ServerMetrics;
use dynamap::util::rng::Rng;

/// System allocator wrapper that counts bytes handed out.
struct CountingAlloc;

static BYTES: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        BYTES.fetch_add(new_size.saturating_sub(layout.size()), Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// A sorted copy of the 64Ki samples is ≥ 512 KiB in a single
/// allocation; a report that stays an order of magnitude under that
/// cannot be sorting. The slack covers the rendered ASCII table and
/// its per-cell strings.
const REPORT_ALLOC_BUDGET: usize = 64 * 1024;

#[test]
fn report_never_sorts_samples_and_histogram_tracks_exact_quantiles() {
    const N: usize = 64 * 1024;
    let metrics = ServerMetrics::new();
    let model = metrics.model("mini-inception");

    // seed-99 log-uniform latencies spanning ~5 decades — the shape
    // that stresses geometric bucketing hardest
    let mut rng = Rng::new(99);
    let mut samples = Vec::with_capacity(N);
    for _ in 0..N {
        let us = 10f64.powf(rng.f64() * 5.0); // 1 µs .. 100 ms
        samples.push(us);
        model.record_request(us);
    }

    // agreement: snapshot percentiles within the documented bucket
    // error of the exact sorted-sample quantiles
    let snap = model.snapshot();
    assert_eq!(snap.requests, N as u64);
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let exact = |p: f64| samples[((p / 100.0) * (N - 1) as f64).round() as usize];
    for (p, got) in [
        (50.0, snap.p50_us),
        (95.0, snap.p95_us),
        (99.0, snap.p99_us),
        (99.9, snap.p999_us),
    ] {
        let want = exact(p);
        let rel = (got - want).abs() / want;
        assert!(
            rel <= LogHistogram::MAX_RELATIVE_ERROR,
            "p{p}: snapshot {got:.1}µs vs exact {want:.1}µs — relative error \
             {rel:.4} exceeds the documented bound"
        );
    }
    let mean_exact = samples.iter().sum::<f64>() / N as f64;
    assert!(
        (snap.mean_us - mean_exact).abs() / mean_exact < 1e-9,
        "the mean is tracked exactly, outside the buckets"
    );

    // regression: a full report over the 64Ki-sample model allocates
    // far less than one sample-window copy would
    let before = BYTES.load(Ordering::Relaxed);
    let report = metrics.report();
    let delta = BYTES.load(Ordering::Relaxed) - before;
    assert!(report.contains("mini-inception"), "the table names the model");
    assert!(
        delta < REPORT_ALLOC_BUDGET,
        "report() allocated {delta} bytes — a sample sort/copy has crept back \
         into the stats path (budget {REPORT_ALLOC_BUDGET})"
    );
}
