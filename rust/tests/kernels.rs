//! Microkernel-tier property suite (ISSUE 8).
//!
//! Three claims are proven here, end to end through the public API:
//!
//! 1. **Bit-exactness** — `kernels::simd::gemm` (every selectable
//!    kernel, including the forced scalar fallback) is bit-identical to
//!    `Mat::matmul` and to the packed scalar kernel `kernels::gemm`,
//!    over a seeded sweep of ragged and degenerate shapes (zero dims,
//!    ones, primes, non-multiples of every lane width, remainder rows
//!    and columns, multi-group double-buffered packing).
//! 2. **Selector determinism + coverage** — same capabilities + same
//!    shape ⇒ same kernel choice, across selectors and runs; the sweep
//!    executes every (kind × mr) kernel the host can select; the
//!    `DYNAMAP_SIMD=off` hook forces the scalar path (driven through
//!    `CpuCaps::from_env_value` so tests never mutate process env).
//! 3. **Cost fold** — a measured `KernelThroughput` table changes DSE
//!    algorithm assignments on mini-inception vs the analytic default,
//!    keys a distinct plan fingerprint, and round-trips through
//!    `PlanArtifact` and `PlanCache` (miss, then hit).

use dynamap::algos::tensor::Mat;
use dynamap::api::{Compiler, PlanArtifact, PlanCache};
use dynamap::cost::{Device, KernelThroughput};
use dynamap::graph::zoo;
use dynamap::kernels::{self, simd, CpuCaps, KernelChoice, KernelKind, KernelSelector, PackedWt};
use dynamap::util::rng::Rng;

/// Ragged/degenerate GEMM dims: zero, one, primes, and
/// non-multiples of both lane widths (8 and 16) and of the mr=4 row
/// block.
const DIMS: [usize; 12] = [0, 1, 2, 3, 5, 7, 13, 17, 31, 33, 64, 100];

fn random_mat(r: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| r.f32_range(-2.0, 2.0))
}

/// Every kernel the host can select: each available kind at both
/// register-tile heights, plus small `nc` overrides so the shape spans
/// several double-buffered panel groups.
fn all_choices(b: usize) -> Vec<KernelChoice> {
    let mut out = Vec::new();
    for kind in KernelSelector::probed().kinds() {
        for mr in [1, 4] {
            let natural = KernelChoice::of(kind, mr, b);
            let mut tight = natural;
            tight.nc = tight.nr; // one panel per group → many groups
            out.push(natural);
            out.push(tight);
        }
    }
    out
}

#[test]
fn simd_bit_identical_to_matmul_and_packed_on_seeded_ragged_sweep() {
    let mut rng = Rng::new(99);
    for case in 0..120 {
        let a = *rng.choose(&DIMS);
        let b = *rng.choose(&DIMS);
        let c = *rng.choose(&DIMS);
        let x = random_mat(&mut rng, a, b);
        let w = random_mat(&mut rng, b, c);
        let packed = PackedWt::pack(&w);
        let reference = x.matmul(&w);
        let packed_out = kernels::gemm(&x, &packed);
        assert_eq!(
            packed_out.data, reference.data,
            "case {case}: packed kernel vs matmul ({a},{b},{c})"
        );
        let probed = simd::gemm(&x, &packed);
        assert_eq!(probed.data, reference.data, "case {case}: probed simd ({a},{b},{c})");
        for choice in all_choices(b) {
            let out = simd::gemm_with(&x, &packed, &choice);
            assert_eq!(
                out.data,
                reference.data,
                "case {case}: kernel {} nc={} on ({a},{b},{c})",
                choice.name(),
                choice.nc
            );
        }
    }
}

#[test]
fn zero_depth_gemm_is_the_zero_matrix_for_every_kernel() {
    // b = 0: no accumulation step runs; every kernel must still produce
    // the exact zero matrix `Mat::matmul` produces
    let x = Mat::zeros(5, 0);
    let w = Mat::zeros(0, 19);
    let packed = PackedWt::pack(&w);
    let reference = x.matmul(&w);
    assert!(reference.data.iter().all(|&v| v == 0.0));
    for choice in all_choices(0) {
        let out = simd::gemm_with(&x, &packed, &choice);
        assert_eq!(out.data, reference.data, "kernel {}", choice.name());
    }
    assert_eq!(simd::gemm(&x, &packed).data, reference.data);
}

#[test]
fn remainder_columns_ignore_zero_padded_tail_lanes() {
    // c = 17: one full 16-lane panel + a 1-column tail on AVX2, and
    // 2×8 + 1 on the 8-lane kernels; the dead lanes must never leak
    let mut rng = Rng::new(99);
    let x = random_mat(&mut rng, 9, 21);
    let w = random_mat(&mut rng, 21, 17);
    let packed = PackedWt::pack(&w);
    let reference = x.matmul(&w);
    for choice in all_choices(21) {
        assert_eq!(
            simd::gemm_with(&x, &packed, &choice).data,
            reference.data,
            "kernel {}",
            choice.name()
        );
    }
}

#[test]
fn selector_is_deterministic_for_fixed_caps_and_shape() {
    let shapes = [(1, 1, 1), (3, 9, 17), (128, 96, 128), (7, 64, 8), (512, 32, 300)];
    for caps in [KernelSelector::probed().caps(), CpuCaps::scalar()] {
        for (a, b, c) in shapes {
            let first = KernelSelector::new(caps).choose(a, b, c);
            for _ in 0..3 {
                assert_eq!(
                    KernelSelector::new(caps).choose(a, b, c),
                    first,
                    "choice must be a pure function of (caps, shape)"
                );
            }
        }
    }
    // the probed singleton agrees with a fresh selector over its caps
    let probed = KernelSelector::probed();
    for (a, b, c) in shapes {
        assert_eq!(probed.choose(a, b, c), KernelSelector::new(probed.caps()).choose(a, b, c));
    }
}

#[test]
fn shape_sweep_exercises_every_selectable_kernel() {
    // every (kind × mr) kernel the host can run executes at least once
    // in this suite — run them here explicitly and verify against the
    // reference so "exercised" means "computed correctly", not just
    // "constructed"
    let mut rng = Rng::new(99);
    let x = random_mat(&mut rng, 6, 11);
    let w = random_mat(&mut rng, 11, 23);
    let packed = PackedWt::pack(&w);
    let reference = x.matmul(&w);
    let mut exercised = std::collections::BTreeSet::new();
    for choice in all_choices(11) {
        assert_eq!(simd::gemm_with(&x, &packed, &choice).data, reference.data);
        exercised.insert(choice.name());
    }
    for kind in KernelSelector::probed().kinds() {
        for mr in [1, 4] {
            let name = KernelChoice::of(kind, mr, 11).name();
            assert!(exercised.contains(&name), "kernel {name} never exercised");
        }
    }
    // and the selector itself reaches both register-tile heights
    let sel = KernelSelector::probed();
    assert_eq!(sel.choose(1, 8, 8).mr, 1);
    assert_eq!(sel.choose(64, 8, 8).mr, 4);
}

#[test]
fn env_hook_forces_the_scalar_fallback() {
    // DYNAMAP_SIMD=off, driven through the factored env hook (mutating
    // real process env would race the probe across test threads)
    let caps = CpuCaps::from_env_value(Some("off"));
    assert_eq!(caps, CpuCaps::scalar());
    let sel = KernelSelector::new(caps);
    assert_eq!(sel.kinds(), vec![KernelKind::Scalar]);
    let mut rng = Rng::new(99);
    for (a, b, c) in [(1, 1, 1), (5, 7, 19), (64, 33, 100)] {
        let choice = sel.choose(a, b, c);
        assert_eq!(choice.kind, KernelKind::Scalar);
        let x = random_mat(&mut rng, a, b);
        let w = random_mat(&mut rng, b, c);
        let packed = PackedWt::pack(&w);
        assert_eq!(
            simd::gemm_with(&x, &packed, &choice).data,
            x.matmul(&w).data,
            "scalar fallback must stay bit-identical at ({a},{b},{c})"
        );
    }
}

/// Per-layer algorithm assignment of a compiled plan, in layer order.
fn algo_map(a: &PlanArtifact) -> Vec<(String, String)> {
    a.plan.mapping.layers.iter().map(|l| (l.name.clone(), l.cost.algo.name())).collect()
}

#[test]
fn measured_throughput_changes_dse_assignment_and_fingerprint() {
    let cnn = zoo::mini_inception();
    let base = Compiler::new().device(Device::small_edge());
    let analytic = base.compile(&cnn).unwrap();

    // flops-dominated host: a slow kernel with zero call overhead makes
    // seconds ∝ multiplications — the three Winograd-applicable layers
    // (stem 3×3, inc/b2_3x3, inc/b3_5x5) must switch to Winograd's
    // reduced-multiplication transform space
    let slow = KernelThroughput::default().with("scalar-4x8", 0.05);
    let slow_plan = base.clone().microkernels(slow).compile(&cnn).unwrap();
    let wino = algo_map(&slow_plan)
        .iter()
        .filter(|(_, algo)| algo.starts_with("winograd"))
        .count();
    assert_eq!(wino, 3, "flops-dominated pricing must map the 3 applicable layers to winograd");

    // overhead-dominated host: 10 ms per GEMM call dwarfs compute, so
    // the single-call im2col strictly dominates on every wide-kernel
    // layer (kn2row pays K1K2 calls, Winograd (m+r−1)²·rounds; on the
    // 1×1 layers im2col and kn2row are the *same* GEMM, so we don't
    // assert a tie-break there)
    let overhead =
        KernelThroughput::default().with("avx2-4x16", 5.0).with_call_overhead(1e-2);
    let overhead_plan = base.clone().microkernels(overhead).compile(&cnn).unwrap();
    let wide = ["stem", "inc/b2_3x3", "inc/b3_5x5"];
    for (name, algo) in algo_map(&overhead_plan) {
        if wide.contains(&name.as_str()) {
            assert_eq!(algo, "im2col", "call-overhead pricing must pick im2col for {name}");
        }
        assert!(!algo.starts_with("winograd"), "{name} must not pay 48+ call overheads");
    }

    // the two host-priced plans disagree with each other, so at least
    // one changed an assignment vs the analytic default
    assert_ne!(algo_map(&slow_plan), algo_map(&overhead_plan));
    assert!(
        algo_map(&slow_plan) != algo_map(&analytic)
            || algo_map(&overhead_plan) != algo_map(&analytic)
    );

    // each table keys its own plan-cache entry
    assert_ne!(analytic.fingerprint, slow_plan.fingerprint);
    assert_ne!(analytic.fingerprint, overhead_plan.fingerprint);
    assert_ne!(slow_plan.fingerprint, overhead_plan.fingerprint);
}

#[test]
fn microkernel_priced_plan_round_trips_and_caches() {
    let cnn = zoo::mini_inception();
    let table = KernelThroughput::default().with("avx2-4x16", 5.0).with_call_overhead(1e-2);
    let compiler = Compiler::new().device(Device::small_edge()).microkernels(table);

    // artifact round-trip preserves the mapping and the fingerprint
    let artifact = compiler.compile(&cnn).unwrap();
    let dir = std::env::temp_dir()
        .join(format!("dynamap_kernels_artifact_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.json");
    artifact.save(&path).unwrap();
    let loaded = PlanArtifact::load(&path).unwrap();
    assert_eq!(loaded.fingerprint, artifact.fingerprint);
    assert_eq!(algo_map(&loaded), algo_map(&artifact));

    // cache: miss compiles once, hit compiles zero times
    let cache_dir = dir.join("cache");
    std::fs::remove_dir_all(&cache_dir).ok();
    let cache = PlanCache::new(&cache_dir);
    let before = compiler.compile_count();
    let (first, was_cached) = cache.load_or_compile(&compiler, &cnn).unwrap();
    assert!(!was_cached, "first lookup must miss");
    let (second, was_cached) = cache.load_or_compile(&compiler, &cnn).unwrap();
    assert!(was_cached, "second lookup must hit");
    assert_eq!(compiler.compile_count(), before + 1, "the hit must not re-run the DSE");
    assert_eq!(first.fingerprint, second.fingerprint);

    // a differently-measured table misses the same cache
    let other = Compiler::new()
        .device(Device::small_edge())
        .microkernels(KernelThroughput::default().with("scalar-4x8", 0.05));
    let (_, was_cached) = cache.load_or_compile(&other, &cnn).unwrap();
    assert!(!was_cached, "a different table must key a different entry");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn measured_table_from_the_live_selector_folds_end_to_end() {
    // the real producer→consumer path: measure this host, fold the
    // table, compile — the plan must be well-formed and keyed apart
    // from the analytic default
    let table = KernelSelector::probed().measure();
    assert!(!table.is_empty());
    assert!(table.gemm_sec(128, 96, 128).unwrap() > 0.0);
    let base = Compiler::new().device(Device::small_edge());
    let priced = base.clone().microkernels(table).compile(&zoo::mini_inception()).unwrap();
    assert!(priced.plan.total_latency_ms > 0.0);
    assert_eq!(priced.plan.mapping.layers.len(), 7);
    assert_ne!(priced.fingerprint, base.compile(&zoo::mini_inception()).unwrap().fingerprint);
}
