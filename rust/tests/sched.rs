//! Multi-tenant co-scheduling integration tests (ROADMAP open item 2):
//! the thread-budget partitioner's invariants under a seeded sweep, the
//! TCP soak (an interactive tenant keeps its SLO while a saturating
//! bulk tenant is shed, replies bitwise-equal to `Session::infer`), the
//! pressure → deferral chain end to end, per-partition plan re-solves
//! through the fingerprint-keyed plan cache, and the preemption blast
//! radius: exactly one reply per submitted request across concurrent
//! hot swaps and pressure raises. Everything runs on synthesized
//! artifacts and loopback ephemeral ports — no PJRT, no fixed ports.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dynamap::api::{Backend, Compiler, Device, Session};
use dynamap::cost::DeviceCalibration;
use dynamap::net::{Client, NetServer};
use dynamap::runtime::TensorBuf;
use dynamap::serve::loadgen::open_loop_input;
use dynamap::serve::{
    open_loop_mixed, partition_threads, tenant_seed, BatchConfig, MixedConfig, ModelRegistry,
    ModelSlo, RegistryConfig, SloTable, Tenant, TenantLoad,
};
use dynamap::util::parallel::{parallel_run, worker_count};
use dynamap::util::rng::Rng;

fn temp_root(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("dynamap_sched_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn slo_table(entries: &[(&str, ModelSlo)]) -> SloTable {
    entries.iter().map(|(m, s)| (m.to_string(), *s)).collect()
}

/// Registry over a temp root: small-edge device (fast DSE), shared plan
/// cache, synthetic artifacts, per-model SLOs + batching + admission.
fn registry(
    root: &PathBuf,
    slos: SloTable,
    max_batch: usize,
    max_wait_ms: u64,
    max_inflight: usize,
) -> Arc<ModelRegistry> {
    Arc::new(ModelRegistry::new(RegistryConfig {
        artifacts_root: root.join("zoo"),
        plan_cache: Some(root.join("plans")),
        capacity: 0,
        synthesize_missing: true,
        seed: 0xA11CE,
        compiler: Compiler::new().device(Device::small_edge()),
        batch: BatchConfig { max_batch, max_wait: Duration::from_millis(max_wait_ms) },
        max_inflight,
        profile: false,
        slos,
    }))
}

/// A sequential reference session over the same synthesized artifacts
/// and plan cache as the registry (same plan, same weights — replies
/// must be bitwise-equal).
fn reference_session(root: &PathBuf, model: &str) -> Session {
    let dir = root.join("zoo").join(model);
    Session::builder(dir.to_str().unwrap().to_string())
        .backend(Backend::Native)
        .compiler(Compiler::new().device(Device::small_edge()))
        .plan_cache(root.join("plans"))
        .build()
        .unwrap()
}

#[test]
fn partitioner_invariants_hold_across_a_seeded_sweep() {
    // shape invariants: ≥ 1 thread per tenant, budgets sum to
    // max(total, tenants), bit-for-bit replay — over 300 seeded shapes
    let mut rng = Rng::new(99);
    for _ in 0..300 {
        let n = 1 + (rng.next_u64() % 8) as usize;
        let tenants: Vec<Tenant> = (0..n)
            .map(|i| Tenant {
                model: format!("m{i}"),
                priority: 1 + (rng.next_u64() % 16) as u32,
                demand: rng.f64() * 500.0,
            })
            .collect();
        let total = (rng.next_u64() % 96) as usize;
        let budgets = partition_threads(total, &tenants);
        assert_eq!(budgets.len(), n);
        assert!(budgets.values().all(|&b| b >= 1), "{budgets:?}");
        assert_eq!(
            budgets.values().sum::<usize>(),
            total.max(n),
            "total={total} n={n}: {budgets:?}"
        );
        assert_eq!(budgets, partition_threads(total, &tenants), "must replay bit-for-bit");
    }
    // priority monotonicity at equal demand: the heavier tenant never
    // receives fewer threads
    let mut rng = Rng::new(99);
    for _ in 0..300 {
        let demand = 1.0 + rng.f64() * 100.0;
        let lo = 1 + (rng.next_u64() % 8) as u32;
        let hi = lo + 1 + (rng.next_u64() % 8) as u32;
        let total = 2 + (rng.next_u64() % 62) as usize;
        let tenants = vec![
            Tenant { model: "high".into(), priority: hi, demand },
            Tenant { model: "low".into(), priority: lo, demand },
        ];
        let budgets = partition_threads(total, &tenants);
        assert!(
            budgets["high"] >= budgets["low"],
            "total={total} hi={hi} lo={lo} demand={demand}: {budgets:?}"
        );
    }
}

#[test]
fn tcp_soak_high_priority_keeps_slo_while_bulk_saturates() {
    let root = temp_root("soak");
    let slos = slo_table(&[
        ("mini", ModelSlo::interactive_ms(400.0)),
        ("mini-vgg", ModelSlo::bulk()),
    ]);
    // admission budget 16 per host: the bulk tenant's 4000 qps burst
    // must shed against it instead of crowding the interactive tenant
    let reg = registry(&root, slos, 4, 2, 16);
    let hi = reg.host("mini").unwrap();
    let bulk = reg.host("mini-vgg").unwrap();
    assert!(hi.slo().is_interactive());
    assert!(bulk.slo().best_effort);

    // hosting with a non-empty SLO table partitioned the thread pool
    let budgets = reg.repartition();
    assert!(budgets.values().all(|&b| b >= 1), "{budgets:?}");
    assert_eq!(budgets.values().sum::<usize>(), worker_count(usize::MAX).max(2));
    assert!(hi.thread_budget() >= 1 && bulk.thread_budget() >= 1);

    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.local_addr().to_string()).unwrap();
    let cfg = MixedConfig {
        tenants: vec![
            TenantLoad {
                model: "mini".into(),
                rate_qps: 250.0,
                requests: 80,
                slo: Some(Duration::from_millis(400)),
                deadline: None,
            },
            TenantLoad {
                model: "mini-vgg".into(),
                rate_qps: 4000.0,
                requests: 240,
                slo: None,
                deadline: None,
            },
        ],
        seed: 99,
        workers: 64,
    };
    let report = open_loop_mixed(&client, &cfg).unwrap();

    // every request of every tenant accounted, all sheds typed
    for t in &report.tenants {
        let r = &t.report;
        assert_eq!(
            r.ok + r.shed + r.deadline_miss + r.errors,
            r.sent,
            "{}: every request accounted",
            t.model
        );
        assert_eq!(r.errors, 0, "{}: sheds must be typed, not generic", t.model);
    }
    let hi_rep = &report.tenants[0];
    let bulk_rep = &report.tenants[1];
    // interactive tenant: tail inside its (CI-generous) target, and the
    // bulk storm never starved it outright
    assert!(
        hi_rep.report.ok * 2 >= hi_rep.report.sent,
        "interactive tenant starved: {}",
        hi_rep.report.summary()
    );
    let p99 = hi_rep.report.latency.percentiles(&[99.0])[0];
    assert!(p99 <= 400_000.0, "high-priority p99 {p99:.0}µs blew the 400ms SLO");
    // bulk tenant: overload observed as typed shedding
    assert!(bulk_rep.report.shed >= 1, "bulk must shed: {}", bulk_rep.report.summary());
    assert!(
        report.summary().contains("slo attainment: high="),
        "{}",
        report.summary()
    );

    // SLO attainment threads into per-model metrics, the stats table
    // and the wire Stats frame
    let snap = hi.metrics().snapshot();
    assert_eq!(snap.slo_target_us, 400_000);
    assert!(snap.slo_attainment_pct().is_some());
    let table = reg.metrics().report();
    assert!(table.contains("slo ms") && table.contains("miss %"), "{table}");
    let stats_json = client.server_stats().unwrap();
    assert!(stats_json.contains("slo_target_us"), "stats frame must carry SLO fields");

    // replies are bitwise-equal to sequential Session::infer over the
    // same plans, for both tenants' exact request streams
    let hi_dims = hi.input_dims();
    let mut hi_ref = reference_session(&root, "mini-inception");
    for i in 0..4 {
        let input = open_loop_input(tenant_seed(99, 0), i, hi_dims);
        let expected = hi_ref.infer(&input).unwrap().0;
        let (got, _) = client.infer("mini", &input).unwrap();
        assert_eq!(got, expected, "hi request {i}: reply != sequential Session::infer");
    }
    let bulk_dims = bulk.input_dims();
    let mut bulk_ref = reference_session(&root, "mini-vgg");
    for i in 0..4 {
        let input = open_loop_input(tenant_seed(99, 1), i, bulk_dims);
        let expected = bulk_ref.infer(&input).unwrap().0;
        let (got, _) = client.infer("mini-vgg", &input).unwrap();
        assert_eq!(got, expected, "bulk request {i}: reply != sequential Session::infer");
    }

    client.shutdown_server().unwrap();
    server.shutdown();
    reg.assert_quiesced(); // sheds and deferrals must not leak permits
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn late_interactive_flush_raises_pressure() {
    let root = temp_root("raise");
    // a 4 ms target under a 200 ms batch window: the one queued request
    // has waited ≥ ¼ of the target by flush time, so the scheduler must
    // raise pressure, and the hold (max(target/2, max_wait) = 200 ms)
    // outlives the flush by a wide margin
    let slos = slo_table(&[("mini", ModelSlo::interactive_ms(4.0))]);
    let reg = registry(&root, slos, 8, 200, 0);
    let hi = reg.host("mini").unwrap();
    let dims = hi.input_dims();

    assert_eq!(reg.coordinator().raises(), 0);
    assert!(!reg.coordinator().pressured());
    reg.infer("mini", &open_loop_input(99, 0, dims)).unwrap();
    assert!(
        reg.coordinator().raises() >= 1,
        "a flush whose oldest request threatened the SLO must raise pressure"
    );
    assert!(reg.coordinator().pressured(), "the pressure hold outlives the flush");

    reg.assert_quiesced();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn bulk_flush_defers_bounded_under_pressure_and_never_drops() {
    let root = temp_root("defer");
    let slos = slo_table(&[
        ("mini", ModelSlo::interactive_ms(50.0)),
        ("mini-vgg", ModelSlo::bulk()),
    ]);
    // max_wait 10 ms → deferral bound (8 × max_wait) = 80 ms: a long
    // pressure window cannot park bulk longer than that
    let reg = registry(&root, slos, 8, 10, 0);
    let bulk = reg.host("mini-vgg").unwrap();
    let dims = bulk.input_dims();
    let input = open_loop_input(7, 0, dims);
    let expected = reference_session(&root, "mini-vgg").infer(&input).unwrap().0;

    // pressure held far past the deferral bound: the bulk flush must
    // park (counted once) and then flush anyway — bounded deferral,
    // never starvation
    reg.coordinator().raise(Duration::from_secs(5));
    let t0 = Instant::now();
    let (out, _) = reg.infer("mini-vgg", &input).unwrap();
    let waited = t0.elapsed();
    assert_eq!(out, expected, "deferred reply != sequential Session::infer");
    assert!(
        waited >= Duration::from_millis(70),
        "the flush should have parked near the deferral bound, waited {waited:?}"
    );
    assert!(
        waited < Duration::from_secs(4),
        "deferral must be bounded well below the pressure window, waited {waited:?}"
    );
    let snap = bulk.metrics().snapshot();
    assert!(snap.deferrals >= 1, "the deferral must be accounted");
    assert_eq!(snap.requests, 1, "the deferred request was served, not dropped");

    reg.assert_quiesced();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn partition_replans_key_the_cache_and_stay_bitwise_correct() {
    let root = temp_root("replan");
    let slos = slo_table(&[
        ("mini", ModelSlo::interactive_ms(100.0)),
        ("mini-vgg", ModelSlo::bulk()),
    ]);
    let reg = registry(&root, slos, 4, 2, 0);
    let hi = reg.host("mini").unwrap();
    let _bulk = reg.host("mini-vgg").unwrap();
    let total = worker_count(usize::MAX);
    if total < 2 {
        // a single-thread host: every tenant owns the full pool, so no
        // re-solve is needed (or possible)
        assert_eq!(reg.resolve_partition_plans().unwrap(), 0);
        reg.shutdown();
        std::fs::remove_dir_all(&root).ok();
        return;
    }
    let budgets = reg.repartition();
    let epoch_before = hi.epoch();
    // two tenants on ≥ 2 threads: both budgets are strict partitions,
    // so both plans re-solve and publish through the hot-swap path
    assert_eq!(reg.resolve_partition_plans().unwrap(), 2, "{budgets:?}");
    assert!(hi.epoch() > epoch_before, "re-solve must publish via swap_state");

    // the re-solved plan equals what a sequential session under the
    // same scaled calibration compiles: same fingerprint → same cached
    // plan → bitwise-identical replies
    let dims = hi.input_dims();
    let factor = total as f64 / hi.thread_budget() as f64;
    let scaled_compiler = Compiler::new()
        .device(Device::small_edge())
        .calibration(DeviceCalibration::identity().scaled(factor));
    let dir = root.join("zoo").join("mini-inception");
    let mut reference = Session::builder(dir.to_str().unwrap().to_string())
        .backend(Backend::Native)
        .compiler(scaled_compiler)
        .plan_cache(root.join("plans"))
        .build()
        .unwrap();
    for i in 0..4 {
        let input = open_loop_input(99, i, dims);
        let expected = reference.infer(&input).unwrap().0;
        let (got, _) = reg.infer("mini", &input).unwrap();
        assert_eq!(got, expected, "request {i}: partitioned plan reply != reference");
    }
    // idempotent: a repeat resolve re-publishes from the cache without
    // failing (the partition keys already exist)
    assert_eq!(reg.resolve_partition_plans().unwrap(), 2);

    reg.assert_quiesced();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn preemption_blast_radius_is_zero_across_swaps_and_pressure() {
    let root = temp_root("blast");
    let slos = slo_table(&[
        ("mini", ModelSlo::interactive_ms(100.0)),
        ("mini-vgg", ModelSlo::bulk()),
    ]);
    let reg = registry(&root, slos, 4, 2, 0);
    let hi = reg.host("mini").unwrap();
    let bulk = reg.host("mini-vgg").unwrap();
    let hi_dims = hi.input_dims();
    let bulk_dims = bulk.input_dims();

    // sequential expectations over the same plans, computed up front
    let mut hi_ref = reference_session(&root, "mini-inception");
    let mut bulk_ref = reference_session(&root, "mini-vgg");
    let hi_expected: Vec<TensorBuf> = (0..12)
        .map(|i| hi_ref.infer(&open_loop_input(99, i, hi_dims)).unwrap().0)
        .collect();
    let bulk_expected: Vec<TensorBuf> = (0..12)
        .map(|i| bulk_ref.infer(&open_loop_input(7, i, bulk_dims)).unwrap().0)
        .collect();

    let epoch_before = hi.epoch();
    let results = std::thread::scope(|s| {
        // chaos thread: hot-swap both tenants (same compiler + plan
        // cache, so the very same plan) and raise pressure while the
        // submitters are mid-flight — deferral must park batches whole,
        // never mix plan epochs or drop a reply
        let chaos = s.spawn(|| {
            for _ in 0..4 {
                for model in ["mini-inception", "mini-vgg"] {
                    let dir = root.join("zoo").join(model);
                    let session = Session::builder(dir.to_str().unwrap().to_string())
                        .backend(Backend::Native)
                        .compiler(Compiler::new().device(Device::small_edge()))
                        .plan_cache(root.join("plans"))
                        .build()
                        .unwrap();
                    let plan_shape = session.plan().map(|a| (a.plan.p1, a.plan.p2));
                    let state = session.native_state().expect("native state");
                    reg.swap_state(model, state, plan_shape).unwrap();
                }
                reg.coordinator().raise(Duration::from_millis(2));
                std::thread::sleep(Duration::from_millis(3));
            }
        });
        let results = parallel_run(8, |t| {
            let mut replies = Vec::new();
            for i in 0..12 {
                if t % 2 == 0 {
                    replies.push(("hi", i, reg.infer("mini", &open_loop_input(99, i, hi_dims))));
                } else {
                    replies.push((
                        "bulk",
                        i,
                        reg.infer("mini-vgg", &open_loop_input(7, i, bulk_dims)),
                    ));
                }
            }
            replies
        });
        chaos.join().unwrap();
        results
    });

    // exactly one reply per submitted request, every one bitwise-equal
    // to the sequential reference — across 8 hot-swaps per model and
    // repeated pressure raises
    let mut replies = 0;
    for thread_replies in &results {
        for (kind, i, r) in thread_replies {
            let (out, _) =
                r.as_ref().unwrap_or_else(|e| panic!("{kind} request {i} failed: {e}"));
            let expected =
                if *kind == "hi" { &hi_expected[*i] } else { &bulk_expected[*i] };
            assert_eq!(out, expected, "{kind} request {i}: reply corrupted across swaps");
            replies += 1;
        }
    }
    assert_eq!(replies, 8 * 12, "exactly one reply per submit");
    assert!(hi.epoch() >= epoch_before + 4, "the swaps actually ran during the soak");
    assert!(bulk.metrics().snapshot().requests + hi.metrics().snapshot().requests >= 96);

    reg.assert_quiesced();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}
