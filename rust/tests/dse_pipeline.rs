//! Integration tests across the whole DSE pipeline: zoo × devices ×
//! policies, cost-graph structural invariants, determinism,
//! failure-injection on user-supplied inputs, and the staged
//! `Compiler → PlanArtifact` API with its plan cache.

use dynamap::api::{Compiler, DynamapError, PlanArtifact, PlanCache};
use dynamap::cost::graph_build::{BuildOpts, CostGraph, Policy};
use dynamap::cost::Device;
use dynamap::dse::DseConfig;
use dynamap::graph::{config, zoo};
use dynamap::pbqp::brute::search_space;
use dynamap::sp;

#[test]
fn every_zoo_model_maps_on_every_device() {
    for model in zoo::names() {
        let cnn = zoo::by_name(model).unwrap();
        for device in [Device::alveo_u200(), Device::small_edge()] {
            // keep the sweep small for the big nets
            let compiler = Compiler::new()
                .device(device.clone())
                .p1_bounds(8, 256.min(device.dsp_cap));
            let plan = compiler
                .compile(&cnn)
                .unwrap_or_else(|e| panic!("{model} on {}: {e}", device.name))
                .into_plan();
            assert!(plan.p1 * plan.p2 <= device.dsp_cap, "{model}: over budget");
            assert!(plan.total_latency_ms > 0.0);
            assert_eq!(plan.mapping.layers.len(), cnn.conv_count());
            // every layer utilization in (0, 1]
            for l in &plan.mapping.layers {
                assert!(
                    l.cost.utilization > 0.0 && l.cost.utilization <= 1.0,
                    "{model}/{}: μ = {}",
                    l.name,
                    l.cost.utilization
                );
            }
        }
    }
}

#[test]
fn optimality_ordering_holds_everywhere() {
    // OPT ≤ greedy ≤ ... is not guaranteed for greedy, but OPT ≤ every
    // policy must hold on every model (Theorem 4.1 optimality).
    for model in zoo::names() {
        let cnn = zoo::by_name(model).unwrap();
        let compiler = Compiler::new().p1_bounds(32, 128);
        let opt = compiler.compile(&cnn).unwrap().plan.total_latency_ms;
        for p in
            [Policy::Im2colOnly, Policy::Kn2rowApplied, Policy::WinoApplied, Policy::Greedy]
        {
            let bl =
                compiler.clone().policy(p).compile(&cnn).unwrap().plan.total_latency_ms;
            assert!(opt <= bl + 1e-9, "{model}: OPT {opt} > {p:?} {bl}");
        }
    }
}

#[test]
fn cost_graphs_remain_series_parallel() {
    // the V_s insertion of §5.1 must preserve the SP property the
    // solver relies on (subdivision argument in graph_build docs)
    for model in zoo::names() {
        let cnn = zoo::by_name(model).unwrap();
        assert!(sp::cnn_is_series_parallel(&cnn), "{model} CNN graph not SP");
        let cfg = DseConfig::alveo_u200();
        let g = CostGraph::build(
            &cnn,
            &cfg.cost_model(),
            &cfg.transition_model(),
            64,
            64,
            BuildOpts::default(),
        );
        let edges: Vec<(usize, usize)> =
            g.problem.edges.iter().map(|e| (e.u, e.v)).collect();
        assert!(
            sp::is_series_parallel(g.problem.n(), &edges, g.source, g.sink),
            "{model} cost graph not SP"
        );
    }
}

#[test]
fn dse_is_deterministic() {
    let cnn = zoo::googlenet();
    let compiler = Compiler::new();
    let a = compiler.compile(&cnn).unwrap().into_plan();
    let b = compiler.compile(&cnn).unwrap().into_plan();
    assert_eq!(a.p1, b.p1);
    assert_eq!(a.p2, b.p2);
    assert_eq!(a.mapping.assignment, b.mapping.assignment);
    assert_eq!(a.total_latency_ms, b.total_latency_ms);
}

#[test]
fn sp_solver_matches_brute_on_real_cost_graph() {
    // mini-inception cost graph is small enough to brute force
    let cnn = zoo::mini_inception();
    let cfg = DseConfig::with_device(Device::small_edge());
    let g = CostGraph::build(
        &cnn,
        &cfg.cost_model(),
        &cfg.transition_model(),
        16,
        16,
        BuildOpts::default(),
    );
    assert!(search_space(&g.problem) < (1 << 24));
    let opt = g.solve(&cnn);
    let brute = dynamap::pbqp::solve_brute(&g.problem);
    assert!((opt.total_sec - brute.cost).abs() < 1e-12);
}

#[test]
fn fusion_and_weight_overlap_only_help() {
    let cnn = zoo::googlenet();
    let on = Compiler::new().p1_bounds(64, 128);
    let off = on.clone().sram_fuse(false).overlap_weight_load(false);
    let l_on = on.compile(&cnn).unwrap().plan.total_latency_ms;
    let l_off = off.compile(&cnn).unwrap().plan.total_latency_ms;
    assert!(l_on <= l_off + 1e-9, "optimizations should not hurt: {l_on} vs {l_off}");
}

#[test]
fn json_roundtrip_preserves_dse_result() {
    let cnn = zoo::mini_inception();
    let tmp = std::env::temp_dir().join("dynamap_mini.json");
    config::save(&cnn, tmp.to_str().unwrap()).unwrap();
    let loaded = config::load(tmp.to_str().unwrap()).unwrap();
    let compiler = Compiler::new().device(Device::small_edge());
    let a = compiler.compile(&cnn).unwrap().into_plan();
    let b = compiler.compile(&loaded).unwrap().into_plan();
    assert_eq!(a.total_latency_ms, b.total_latency_ms);
    assert_eq!(a.mapping.assignment, b.mapping.assignment);
}

#[test]
fn plan_artifact_roundtrip_and_cache() {
    let cnn = zoo::mini_inception();
    let compiler = Compiler::new().device(Device::small_edge());
    let artifact = compiler.compile(&cnn).unwrap();

    // full value round-trip through disk
    let path = std::env::temp_dir()
        .join(format!("dynamap_pipeline_artifact_{}.json", std::process::id()));
    artifact.save(&path).unwrap();
    let back = PlanArtifact::load(&path).unwrap();
    assert_eq!(back.model, "mini-inception");
    assert_eq!(back.fingerprint, compiler.fingerprint());
    assert_eq!(back.plan.mapping.assignment, artifact.plan.mapping.assignment);
    assert_eq!(back.plan.total_latency_ms, artifact.plan.total_latency_ms);
    std::fs::remove_file(&path).ok();

    // cache: second resolution must not re-run the DSE
    let dir = std::env::temp_dir()
        .join(format!("dynamap_pipeline_cache_{}", std::process::id()));
    let probe = Compiler::new().device(Device::small_edge());
    let cache = PlanCache::new(&dir);
    std::fs::remove_file(cache.path_for(&probe, &cnn.name)).ok();
    let (_, cached) = cache.load_or_compile(&probe, &cnn).unwrap();
    assert!(!cached);
    let (hit, cached) = cache.load_or_compile(&probe, &cnn).unwrap();
    assert!(cached, "second resolution should come from the cache");
    assert_eq!(probe.compile_count(), 1, "cached path must not re-run the DSE");
    assert_eq!(hit.plan.mapping.assignment, artifact.plan.mapping.assignment);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn failure_injection_bad_inputs() {
    // malformed JSON
    let tmp = std::env::temp_dir().join("dynamap_bad.json");
    std::fs::write(&tmp, "{not json").unwrap();
    assert!(config::load(tmp.to_str().unwrap()).is_err());
    // structurally invalid CNN (dangling edge)
    std::fs::write(
        &tmp,
        r#"{"name":"bad","nodes":[{"name":"in","kind":"input","c":1,"h1":4,"h2":4}],"edges":[[0,5]]}"#,
    )
    .unwrap();
    assert!(config::load(tmp.to_str().unwrap()).is_err());
    // missing artifact dir surfaces a typed Io error
    let e = dynamap::runtime::Manifest::load("/no/such/dir").unwrap_err();
    assert!(matches!(e, DynamapError::Io { .. }), "{e}");
    // degenerate sweep bounds are typed Dse errors, not panics
    let e = Compiler::new()
        .device(Device::small_edge())
        .p1_bounds(8, 2)
        .compile(&zoo::mini_inception())
        .unwrap_err();
    assert!(matches!(e, DynamapError::Dse(_)), "{e}");
    // one-PE device cannot panic the sweep
    let mut device = Device::small_edge();
    device.dsp_cap = 1;
    let plan = Compiler::new()
        .device(device)
        .p1_bounds(1, 1)
        .compile(&zoo::mini_inception())
        .unwrap()
        .into_plan();
    assert_eq!((plan.p1, plan.p2), (1, 1));
}

#[test]
fn emit_produces_consistent_package() {
    let cnn = zoo::mini_inception();
    let compiler = Compiler::new().device(Device::small_edge());
    let plan = compiler.compile(&cnn).unwrap().into_plan();
    let v = dynamap::emit::verilog::overlay_top(&plan);
    assert!(v.contains(&format!("P_SA1 = {}", plan.p1)));
    let c = dynamap::emit::control::control_stream(&cnn, &plan);
    let words = c.get("layers").as_arr().unwrap();
    assert_eq!(words.len(), plan.mapping.layers.len());
    // control words' cycle estimates sum to the plan's compute portion
    let sum: f64 = words.iter().map(|w| w.get("est_cycles").as_f64().unwrap()).sum();
    assert!(sum > 0.0);
}

#[test]
fn compiler_covers_legacy_call_shapes() {
    // the 0.1 `Dse` driver is gone; its call shapes (`run`,
    // `run_policy`, `run_fixed_shape`) map 1:1 onto the staged Compiler
    let cnn = zoo::mini_inception();
    let cfg = DseConfig::with_device(Device::small_edge());
    let compiler = Compiler::from_config(cfg);
    let plan = compiler.compile(&cnn).unwrap().into_plan();
    let again = compiler.compile(&cnn).unwrap().into_plan();
    assert_eq!(plan.mapping.assignment, again.mapping.assignment);
    assert_eq!(plan.total_latency_ms, again.total_latency_ms);
    let bl = compiler.clone().policy(Policy::Im2colOnly).compile(&cnn).unwrap().into_plan();
    assert!(plan.total_latency_ms <= bl.total_latency_ms + 1e-9);
    let fixed =
        compiler.clone().fixed_shape(16, 16).compile(&cnn).unwrap().into_plan();
    assert_eq!((fixed.p1, fixed.p2), (16, 16));
}
