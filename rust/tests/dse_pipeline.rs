//! Integration tests across the whole DSE pipeline: zoo × devices ×
//! policies, cost-graph structural invariants, determinism, and
//! failure-injection on user-supplied inputs.

use dynamap::cost::graph_build::{BuildOpts, CostGraph, Policy};
use dynamap::cost::transition::TransitionModel;
use dynamap::cost::Device;
use dynamap::dse::{Dse, DseConfig};
use dynamap::graph::{config, zoo};
use dynamap::pbqp::brute::search_space;
use dynamap::sp;

#[test]
fn every_zoo_model_maps_on_every_device() {
    for model in zoo::names() {
        let cnn = zoo::by_name(model).unwrap();
        for device in [Device::alveo_u200(), Device::small_edge()] {
            let mut cfg = DseConfig::with_device(device.clone());
            // keep the sweep small for the big nets
            cfg.p1_lo = 8;
            cfg.p1_hi = 256.min(device.dsp_cap);
            let plan = Dse::new(cfg).run(&cnn).unwrap_or_else(|e| {
                panic!("{model} on {}: {e}", device.name)
            });
            assert!(plan.p1 * plan.p2 <= device.dsp_cap, "{model}: over budget");
            assert!(plan.total_latency_ms > 0.0);
            assert_eq!(plan.mapping.layers.len(), cnn.conv_count());
            // every layer utilization in (0, 1]
            for l in &plan.mapping.layers {
                assert!(
                    l.cost.utilization > 0.0 && l.cost.utilization <= 1.0,
                    "{model}/{}: μ = {}",
                    l.name,
                    l.cost.utilization
                );
            }
        }
    }
}

#[test]
fn optimality_ordering_holds_everywhere() {
    // OPT ≤ greedy ≤ ... is not guaranteed for greedy, but OPT ≤ every
    // policy must hold on every model (Theorem 4.1 optimality).
    for model in zoo::names() {
        let cnn = zoo::by_name(model).unwrap();
        let mut cfg = DseConfig::alveo_u200();
        cfg.p1_lo = 32;
        cfg.p1_hi = 128;
        let dse = Dse::new(cfg);
        let opt = dse.run(&cnn).unwrap().total_latency_ms;
        for p in
            [Policy::Im2colOnly, Policy::Kn2rowApplied, Policy::WinoApplied, Policy::Greedy]
        {
            let bl = dse.run_policy(&cnn, p).unwrap().total_latency_ms;
            assert!(
                opt <= bl + 1e-9,
                "{model}: OPT {opt} > {p:?} {bl}"
            );
        }
    }
}

#[test]
fn cost_graphs_remain_series_parallel() {
    // the V_s insertion of §5.1 must preserve the SP property the
    // solver relies on (subdivision argument in graph_build docs)
    for model in zoo::names() {
        let cnn = zoo::by_name(model).unwrap();
        assert!(sp::cnn_is_series_parallel(&cnn), "{model} CNN graph not SP");
        let cfg = DseConfig::alveo_u200();
        let g = CostGraph::build(
            &cnn,
            &cfg.cost_model(),
            &cfg.transition_model(),
            64,
            64,
            BuildOpts::default(),
        );
        let edges: Vec<(usize, usize)> =
            g.problem.edges.iter().map(|e| (e.u, e.v)).collect();
        assert!(
            sp::is_series_parallel(g.problem.n(), &edges, g.source, g.sink),
            "{model} cost graph not SP"
        );
    }
}

#[test]
fn dse_is_deterministic() {
    let cnn = zoo::googlenet();
    let dse = Dse::new(DseConfig::alveo_u200());
    let a = dse.run(&cnn).unwrap();
    let b = dse.run(&cnn).unwrap();
    assert_eq!(a.p1, b.p1);
    assert_eq!(a.p2, b.p2);
    assert_eq!(a.mapping.assignment, b.mapping.assignment);
    assert_eq!(a.total_latency_ms, b.total_latency_ms);
}

#[test]
fn sp_solver_matches_brute_on_real_cost_graph() {
    // mini-inception cost graph is small enough to brute force
    let cnn = zoo::mini_inception();
    let cfg = DseConfig::with_device(Device::small_edge());
    let g = CostGraph::build(
        &cnn,
        &cfg.cost_model(),
        &cfg.transition_model(),
        16,
        16,
        BuildOpts::default(),
    );
    assert!(search_space(&g.problem) < (1 << 24));
    let opt = g.solve(&cnn);
    let brute = dynamap::pbqp::solve_brute(&g.problem);
    assert!((opt.total_sec - brute.cost).abs() < 1e-12);
}

#[test]
fn fusion_and_weight_overlap_only_help() {
    let cnn = zoo::googlenet();
    let mut on = DseConfig::alveo_u200();
    on.p1_lo = 64;
    on.p1_hi = 128;
    let mut off = on.clone();
    off.opts.sram_fuse = false;
    off.opts.overlap_weight_load = false;
    let l_on = Dse::new(on).run(&cnn).unwrap().total_latency_ms;
    let l_off = Dse::new(off).run(&cnn).unwrap().total_latency_ms;
    assert!(l_on <= l_off + 1e-9, "optimizations should not hurt: {l_on} vs {l_off}");
}

#[test]
fn json_roundtrip_preserves_dse_result() {
    let cnn = zoo::mini_inception();
    let tmp = std::env::temp_dir().join("dynamap_mini.json");
    config::save(&cnn, tmp.to_str().unwrap()).unwrap();
    let loaded = config::load(tmp.to_str().unwrap()).unwrap();
    let dse = Dse::new(DseConfig::with_device(Device::small_edge()));
    let a = dse.run(&cnn).unwrap();
    let b = dse.run(&loaded).unwrap();
    assert_eq!(a.total_latency_ms, b.total_latency_ms);
    assert_eq!(a.mapping.assignment, b.mapping.assignment);
}

#[test]
fn failure_injection_bad_inputs() {
    // malformed JSON
    let tmp = std::env::temp_dir().join("dynamap_bad.json");
    std::fs::write(&tmp, "{not json").unwrap();
    assert!(config::load(tmp.to_str().unwrap()).is_err());
    // structurally invalid CNN (dangling edge)
    std::fs::write(
        &tmp,
        r#"{"name":"bad","nodes":[{"name":"in","kind":"input","c":1,"h1":4,"h2":4}],"edges":[[0,5]]}"#,
    )
    .unwrap();
    assert!(config::load(tmp.to_str().unwrap()).is_err());
    // missing artifact dir
    assert!(dynamap::runtime::Manifest::load("/no/such/dir").is_err());
    // zero-DSP device cannot panic the sweep
    let mut cfg = DseConfig::with_device(Device::small_edge());
    cfg.device.dsp_cap = 1;
    cfg.p1_lo = 1;
    cfg.p1_hi = 1;
    let plan = Dse::new(cfg).run(&zoo::mini_inception()).unwrap();
    assert_eq!((plan.p1, plan.p2), (1, 1));
}

#[test]
fn emit_produces_consistent_package() {
    let cnn = zoo::mini_inception();
    let dse = Dse::new(DseConfig::with_device(Device::small_edge()));
    let plan = dse.run(&cnn).unwrap();
    let v = dynamap::emit::verilog::overlay_top(&plan);
    assert!(v.contains(&format!("P_SA1 = {}", plan.p1)));
    let c = dynamap::emit::control::control_stream(&cnn, &plan);
    let words = c.get("layers").as_arr().unwrap();
    assert_eq!(words.len(), plan.mapping.layers.len());
    // control words' cycle estimates sum to the plan's compute portion
    let sum: f64 = words.iter().map(|w| w.get("est_cycles").as_f64().unwrap()).sum();
    assert!(sum > 0.0);
}
