//! Multi-model serving engine integration tests: the soak test (N
//! client threads × M requests through the batch queue produce outputs
//! bitwise-equal to sequential `Session::infer`), registry LRU eviction
//! with lazy recompilation through the shared plan cache, and
//! concurrent two-model serving. Everything runs on synthesized
//! artifacts — no PJRT, no `make artifacts`.

use std::path::PathBuf;

use dynamap::api::{Backend, Compiler, Device, DynamapError, Session};
use dynamap::runtime::TensorBuf;
use dynamap::serve::{BatchConfig, ModelRegistry, RegistryConfig};
use dynamap::util::parallel::parallel_run;
use dynamap::util::rng::Rng;

fn temp_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dynamap_serving_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Registry over a temp root: small-edge device (fast DSE), shared plan
/// cache under the same root, synthetic artifacts.
fn registry(root: &PathBuf, capacity: usize, max_batch: usize, max_wait_ms: u64) -> ModelRegistry {
    ModelRegistry::new(RegistryConfig {
        artifacts_root: root.join("zoo"),
        plan_cache: Some(root.join("plans")),
        capacity,
        synthesize_missing: true,
        seed: 0xA11CE,
        compiler: Compiler::new().device(Device::small_edge()),
        batch: BatchConfig {
            max_batch,
            max_wait: std::time::Duration::from_millis(max_wait_ms),
        },
        max_inflight: 0,
        profile: false,
        slos: Default::default(),
    })
}

fn input_for(dims: (usize, usize, usize), client: usize, req: usize) -> TensorBuf {
    let (c, h1, h2) = dims;
    let mut rng = Rng::new(0xBA5E ^ ((client * 1000 + req) as u64));
    TensorBuf::new(
        vec![c, h1, h2],
        (0..c * h1 * h2).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
    )
}

/// The soak test of the PR: concurrent closed-loop clients through the
/// dynamic batching queue must be indistinguishable (bitwise) from a
/// sequential `Session::infer` loop over the same inputs.
#[test]
fn soak_batched_outputs_bitwise_equal_sequential() {
    let root = temp_root("soak");
    let reg = registry(&root, 0, 5, 25);
    let host = reg.host("mini").unwrap();
    assert_eq!(host.model(), "mini-inception");
    assert!(!host.plan_from_cache(), "first host compiles the plan");
    let dims = host.input_dims();

    // sequential reference: a plain Session over the very same
    // synthesized artifact dir (and plan cache, so the same algo map)
    let dir = root.join("zoo").join("mini-inception");
    let mut session = Session::builder(dir.to_str().unwrap().to_string())
        .backend(Backend::Native)
        .compiler(Compiler::new().device(Device::small_edge()))
        .plan_cache(root.join("plans"))
        .build()
        .unwrap();
    assert!(session.plan_from_cache(), "registry already populated the shared plan cache");

    let clients = 4usize;
    let per_client = 10usize;
    let expected: Vec<Vec<TensorBuf>> = (0..clients)
        .map(|ci| {
            (0..per_client)
                .map(|j| session.infer(&input_for(dims, ci, j)).unwrap().0)
                .collect()
        })
        .collect();

    // the soak: concurrent closed-loop clients through the batch queue
    let results: Vec<Vec<TensorBuf>> = parallel_run(clients, |ci| {
        (0..per_client)
            .map(|j| reg.infer("mini", &input_for(dims, ci, j)).unwrap().0)
            .collect()
    });
    for (ci, (exp, got)) in expected.iter().zip(&results).enumerate() {
        for (j, (e, g)) in exp.iter().zip(got).enumerate() {
            assert_eq!(e, g, "client {ci} request {j}: batched != sequential");
        }
    }

    // telemetry must account for exactly the queued traffic
    let snap = host.metrics().snapshot();
    let total = (clients * per_client) as u64;
    assert_eq!(snap.requests, total);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.queue_depth, 0, "queue drained");
    let hist_total: u64 = snap.batch_hist.iter().map(|(size, n)| *size as u64 * n).sum();
    assert_eq!(hist_total, total, "batch histogram covers every request");
    assert!(snap.batches >= total / 5, "no batch may exceed max_batch=5");
    assert!(snap.batch_hist.keys().all(|&s| (1..=5).contains(&s)));
    assert!(snap.p50_us > 0.0 && snap.p99_us >= snap.p50_us);

    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// A malformed request is rejected at submit time with a typed Shape
/// error and never enters a batch — so it cannot fail co-batched
/// requests from other callers or distort the serving counters.
#[test]
fn wrong_shape_is_rejected_before_batching() {
    let root = temp_root("shape");
    let reg = registry(&root, 0, 4, 2);
    let host = reg.host("mini").unwrap();
    let err = reg.infer("mini", &TensorBuf::zeros(vec![1, 1, 1])).unwrap_err();
    assert!(matches!(err, DynamapError::Shape { .. }), "{err}");
    // the queue saw nothing: no request, no error, no batch
    let snap = host.metrics().snapshot();
    assert_eq!((snap.requests, snap.errors, snap.batches), (0, 0, 0));
    // and valid traffic is unaffected
    let (out, _) = reg.infer("mini", &input_for(host.input_dims(), 0, 0)).unwrap();
    assert_eq!(out.shape, vec![16, 8, 8]);
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// Registry behavior end to end, sharing one artifact root + plan cache
/// across three registry configurations (synthesis and each model's DSE
/// run exactly once): LRU eviction under capacity pressure, lazy
/// re-hosting from the shared plan cache, recency refresh on touch, and
/// two models serving concurrently — mini-vgg's trailing FC runs
/// natively as a 1×1 conv. (Big-model hosting — googlenet and friends —
/// goes through the identical code path via `dynamap serve`/`loadgen`;
/// tier-1 sticks to the debug-build-fast mini pair.)
#[test]
fn registry_lru_eviction_and_multi_model_serving() {
    let root = temp_root("registry");

    // -- capacity 1: hosting a second model evicts the first ------------
    let reg = registry(&root, 1, 4, 2);
    let first = reg.host("mini").unwrap();
    assert_eq!(reg.loads(), 1);
    assert_eq!(reg.resident(), vec!["mini-inception".to_string()]);
    assert!(!first.plan_from_cache(), "first host compiles the plan");
    let mini_dims = first.input_dims();

    let vgg = reg.host("mini-vgg").unwrap();
    assert_eq!(reg.loads(), 2);
    assert_eq!(reg.resident(), vec!["mini-vgg".to_string()]);
    let vgg_dims = vgg.input_dims();
    assert_eq!(vgg_dims, (3, 16, 16), "per-model input shapes");

    // the evicted host's queue is shut down: stale handles fail typed…
    let stale = first.infer(input_for(mini_dims, 0, 0));
    assert!(
        matches!(stale, Err(DynamapError::QueueClosed { .. })),
        "evicted host must refuse new requests with the retry-safe error"
    );

    // …but the registry transparently re-hosts: this evicts mini-vgg,
    // rebuilds mini from the shared plan cache (no DSE) and serves
    let (out, _) = reg.infer("mini", &input_for(mini_dims, 0, 0)).unwrap();
    assert_eq!(out.shape, vec![16, 8, 8]);
    assert_eq!(reg.loads(), 3, "eviction + re-request = one more session build");
    let back = reg.host("mini").unwrap();
    assert!(back.plan_from_cache(), "rebuild must hit the shared plan cache");
    assert_eq!(reg.loads(), 3, "resident hit does not rebuild");
    reg.shutdown();
    assert!(reg.resident().is_empty());

    // -- capacity 2: touches refresh recency, eviction is explicit ------
    let reg2 = registry(&root, 2, 4, 2);
    let a = reg2.host("mini").unwrap();
    let b = reg2.host("mini-vgg").unwrap();
    assert!(a.plan_from_cache() && b.plan_from_cache(), "all plans cached by now");
    assert_eq!(
        reg2.resident(),
        vec!["mini-inception".to_string(), "mini-vgg".to_string()]
    );
    reg2.host("mini").unwrap(); // touch → MRU end
    assert_eq!(
        reg2.resident(),
        vec!["mini-vgg".to_string(), "mini-inception".to_string()]
    );
    assert_eq!(reg2.loads(), 2, "touches never rebuild resident hosts");
    assert!(reg2.evict("mini-vgg"));
    assert_eq!(reg2.resident(), vec!["mini-inception".to_string()]);
    assert!(!reg2.evict("mini-vgg"), "double eviction is a no-op");
    reg2.shutdown();

    // -- capacity 4: both models serve concurrently ---------------------
    let reg3 = registry(&root, 4, 4, 2);
    let outputs = parallel_run(4, |ci| {
        if ci % 2 == 0 {
            reg3.infer("mini", &input_for(mini_dims, 0, 0)).unwrap().0
        } else {
            reg3.infer("mini-vgg", &input_for(vgg_dims, 1, 0)).unwrap().0
        }
    });
    assert_eq!(outputs[0].shape, vec![16, 8, 8]);
    // mini-vgg ends in a 10-way FC served natively as a 1×1 conv
    assert_eq!(outputs[1].shape, vec![10, 1, 1]);
    assert!(outputs[1].data.iter().all(|v| v.is_finite()));
    assert_eq!(outputs[0], outputs[2], "same input, same model → same output");
    assert_eq!(outputs[1], outputs[3], "same input, same model → same output");
    assert_eq!(
        reg3.resident(),
        vec!["mini-inception".to_string(), "mini-vgg".to_string()]
    );
    reg3.shutdown();
    std::fs::remove_dir_all(&root).ok();
}
