//! Quantized serving-path tests: the mixed-precision accuracy harness
//! (int8 layers within a documented tolerance of the f32 golden path),
//! the precision-aware DSE on mini-inception, and the mixed-precision
//! plan-artifact round trip.
//!
//! Documented accuracy tolerance: with per-output-channel weight scales
//! and per-tensor activation scales, every output element of a
//! mixed-precision mini-inception inference stays within **5% of the
//! f32 output's maximum magnitude** (measured headroom is ~3×; see the
//! "Precision in the mapping space" section of ARCHITECTURE.md).

use std::collections::BTreeMap;
use std::path::PathBuf;

use dynamap::api::{Backend, Compiler, PlanArtifact, Session};
use dynamap::cost::gemm::Dataflow;
use dynamap::graph::layer::Op;
use dynamap::graph::zoo;
use dynamap::quant::{self, Precision};
use dynamap::runtime::TensorBuf;
use dynamap::util::rng::Rng;

/// Relative-to-range L∞ tolerance for mixed-precision inference.
const QUANT_TOLERANCE: f32 = 0.05;

fn write_f32(path: &std::path::Path, data: &[f32]) {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).unwrap();
}

/// Minimal artifact manifest for mini-inception with random weights and
/// no HLO artifacts (same shape as the native-session test suite).
fn synth_manifest_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dynamap_quant_manifest_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cnn = zoo::mini_inception();
    let mut rng = Rng::new(0x0_11_7);
    let mut layers = Vec::new();
    for node in &cnn.nodes {
        let Op::Conv(spec) = &node.op else { continue };
        let safe = node.name.replace('/', "_");
        let wfile = format!("w__{safe}.bin");
        let n = spec.weight_count();
        let w: Vec<f32> = (0..n).map(|_| rng.f32_range(-0.5, 0.5)).collect();
        write_f32(&dir.join(&wfile), &w);
        layers.push(format!(
            r#"{{"name":"{}","c_in":{},"c_out":{},"h1":{},"h2":{},"k1":{},"k2":{},"s":{},"p1":{},"p2":{},"o1":{},"o2":{},"algos":{{}},"weights":"{}","weight_count":{}}}"#,
            node.name,
            spec.c_in,
            spec.c_out,
            spec.h1,
            spec.h2,
            spec.k1,
            spec.k2,
            spec.s,
            spec.p1,
            spec.p2,
            spec.o1(),
            spec.o2(),
            wfile,
            n
        ));
    }
    let manifest = format!(
        r#"{{"model":"mini-inception","input":{{"c":4,"h1":16,"h2":16}},"layers":[{}],"golden_input":"","golden_output":""}}"#,
        layers.join(",")
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    dir
}

fn random_inputs(n: usize, seed: u64) -> Vec<TensorBuf> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            TensorBuf::new(
                vec![4, 16, 16],
                (0..4 * 16 * 16).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            )
        })
        .collect()
}

/// `layer → family` maps for the accuracy harness: the f32 golden map
/// and the mixed map that serves every im2col/kn2row layer int8 while
/// the 3×3 layers stay winograd/f32 — the shape of plan the
/// precision-aware DSE produces.
fn golden_and_mixed_maps() -> (BTreeMap<String, String>, BTreeMap<String, String>) {
    let cnn = zoo::mini_inception();
    let mut golden = BTreeMap::new();
    let mut mixed = BTreeMap::new();
    for node in &cnn.nodes {
        let Op::Conv(spec) = &node.op else { continue };
        let (f32_name, mixed_name) = match spec.k1 {
            3 => ("winograd", "winograd".to_string()),
            5 => ("kn2row", quant::mapped_name("kn2row", Precision::Int8)),
            _ => ("im2col", quant::mapped_name("im2col", Precision::Int8)),
        };
        golden.insert(node.name.clone(), f32_name.to_string());
        mixed.insert(node.name.clone(), mixed_name);
    }
    (golden, mixed)
}

fn assert_within_tolerance(q: &TensorBuf, golden: &TensorBuf, what: &str) {
    assert_eq!(q.shape, golden.shape, "{what}: shape mismatch");
    let range = golden.data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    for (i, (a, b)) in q.data.iter().zip(&golden.data).enumerate() {
        assert!(
            (a - b).abs() <= QUANT_TOLERANCE * range,
            "{what}: elem {i}: |{a} - {b}| exceeds {QUANT_TOLERANCE} of range {range}"
        );
    }
}

#[test]
fn mixed_precision_accuracy_within_documented_tolerance() {
    let dir = synth_manifest_dir("accuracy");
    let (golden_map, mixed_map) = golden_and_mixed_maps();
    let mut golden = Session::builder(dir.to_str().unwrap())
        .backend(Backend::Native)
        .algo_map(golden_map)
        .build()
        .unwrap();
    let mut mixed = Session::builder(dir.to_str().unwrap())
        .backend(Backend::Native)
        .algo_map(mixed_map.clone())
        .build()
        .unwrap();
    // the session reports the precisions it actually serves
    assert_eq!(mixed.algo_map(), &mixed_map, "no clamping expected for this map");
    let state = mixed.native_state().unwrap();
    assert!(state.int8_count() >= 3, "1×1 and 5×5 layers must serve int8");
    assert_eq!(state.precision("inc/b2_3x3"), Some(Precision::F32), "winograd stays f32");

    for (i, input) in random_inputs(4, 40).iter().enumerate() {
        let (g, _) = golden.infer(input).unwrap();
        let (q, _) = mixed.infer(input).unwrap();
        assert_within_tolerance(&q, &g, &format!("dynamic-scale request {i}"));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn calibrated_activation_scales_hold_the_same_tolerance() {
    let dir = synth_manifest_dir("calibrated");
    let (golden_map, mixed_map) = golden_and_mixed_maps();
    let mut golden = Session::builder(dir.to_str().unwrap())
        .backend(Backend::Native)
        .algo_map(golden_map)
        .build()
        .unwrap();
    // calibrate per-tensor activation scales from a handful of profiled
    // batches on the f32 path...
    let scales = golden
        .native_state()
        .unwrap()
        .calibrate_activations(&random_inputs(8, 41))
        .unwrap();
    assert_eq!(scales.len(), 7, "one scale per conv layer");
    // ...then serve quantized with the calibrated (static) scales
    let mut mixed = Session::builder(dir.to_str().unwrap())
        .backend(Backend::Native)
        .algo_map(mixed_map)
        .act_scales(scales.clone())
        .build()
        .unwrap();
    // calibration survives a JSON round trip unchanged
    let path = dir.join("act_scales.json");
    scales.save(&path).unwrap();
    assert_eq!(dynamap::quant::ActScales::load(&path).unwrap(), scales);

    for (i, input) in random_inputs(4, 42).iter().enumerate() {
        let (g, _) = golden.infer(input).unwrap();
        let (q, _) = mixed.infer(input).unwrap();
        assert_within_tolerance(&q, &g, &format!("static-scale request {i}"));
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The precision-aware compiler used by the DSE-selection and
/// round-trip tests. The NS-only 8×8 operating point is where the
/// precision trade-off is legible on mini-inception's tiny layers:
/// Winograd/f32 wins the 3×3/5×5 layers outright (its 2.25× multiply
/// reduction beats the 2× DSP packing once `I_SA` is small), while the
/// head's `C_out = 16 > P_SA2` column tiling halves under int8 packing.
/// Under free dataflow choice the IS dataflow lets packed im2col win
/// everything, which is a valid plan but not the mix this test pins.
fn mixed_compiler() -> Compiler {
    Compiler::new()
        .fixed_shape(8, 8)
        .force_dataflow(Dataflow::NS)
        .precision_search(true)
}

#[test]
fn dse_selects_int8_and_winograd_f32_on_mini_inception() {
    let artifact = mixed_compiler().compile(&zoo::mini_inception()).unwrap();
    let layers = &artifact.plan.mapping.layers;
    assert_eq!(layers.len(), 7);
    let int8 = layers.iter().filter(|l| l.cost.precision == Precision::Int8).count();
    let wino_f32 = layers
        .iter()
        .filter(|l| {
            matches!(l.cost.algo, dynamap::cost::Algo::Winograd { .. })
                && l.cost.precision == Precision::F32
        })
        .count();
    assert!(int8 >= 1, "DSE must quantize at least one layer: {:?}", algo_summary(layers));
    assert!(
        wino_f32 >= 1,
        "DSE must keep at least one winograd/f32 layer: {:?}",
        algo_summary(layers)
    );
    // the winograd-stays-f32 constraint holds for every selected layer
    assert!(layers
        .iter()
        .filter(|l| matches!(
            l.cost.algo,
            dynamap::cost::Algo::Winograd { .. } | dynamap::cost::Algo::WinogradStrided { .. }
        ))
        .all(|l| l.cost.precision == Precision::F32));
    // the head's wide output tiling is exactly what DSP packing halves
    let head = layers.iter().find(|l| l.name == "head").unwrap();
    assert_eq!(head.cost.precision, Precision::Int8, "{:?}", algo_summary(layers));
}

fn algo_summary(
    layers: &[dynamap::cost::graph_build::LayerAssignment],
) -> Vec<(String, String)> {
    layers
        .iter()
        .map(|l| (l.name.clone(), quant::mapped_name(&l.cost.algo.name(), l.cost.precision)))
        .collect()
}

#[test]
fn mixed_precision_plan_round_trips_with_identical_map_and_fingerprint() {
    let compiler = mixed_compiler();
    let cnn = zoo::mini_inception();
    let a = compiler.compile(&cnn).unwrap();
    let dir =
        std::env::temp_dir().join(format!("dynamap_quant_plan_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("mini.json");
    a.save(&path).unwrap();
    let b = PlanArtifact::load(&path).unwrap();

    // identical per-layer (algorithm, precision) map
    let map = |art: &PlanArtifact| -> Vec<(String, String)> {
        art.plan
            .mapping
            .layers
            .iter()
            .map(|l| {
                (l.name.clone(), quant::mapped_name(l.cost.algo.family(), l.cost.precision))
            })
            .collect()
    };
    assert_eq!(map(&a), map(&b));
    assert!(
        map(&a).iter().any(|(_, m)| m.ends_with("-int8")),
        "round trip must exercise a genuinely mixed plan: {:?}",
        map(&a)
    );
    // identical cache fingerprint, and the cache serves it back without
    // re-running the DSE
    assert_eq!(a.fingerprint, b.fingerprint);
    assert_eq!(a.fingerprint, compiler.fingerprint());
    let cache = dynamap::api::PlanCache::new(&dir);
    let (c, cached) = {
        // seed the cache with the artifact under its canonical name
        a.save(cache.path_for(&compiler, &cnn.name)).unwrap();
        cache.load_or_compile(&compiler, &cnn).unwrap()
    };
    assert!(cached, "fingerprint-matched mixed plan must come from the cache");
    assert_eq!(map(&a), map(&c));
    assert_eq!(compiler.compile_count(), 1, "only the original compile ran the DSE");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn native_session_serves_a_mixed_precision_plan() {
    let dir = synth_manifest_dir("plan_serving");
    let artifact = mixed_compiler().compile(&zoo::mini_inception()).unwrap();
    let expected: BTreeMap<String, Precision> = artifact
        .plan
        .mapping
        .layers
        .iter()
        .map(|l| (l.name.clone(), l.cost.precision))
        .collect();
    let mut session = Session::builder(dir.to_str().unwrap())
        .backend(Backend::Native)
        .plan(artifact)
        .build()
        .unwrap();
    let state = session.native_state().unwrap();
    for (layer, precision) in &expected {
        assert_eq!(
            state.precision(layer),
            Some(*precision),
            "layer {layer} must serve at the plan's precision"
        );
    }
    assert!(state.int8_count() >= 1);
    // and it still infers sane outputs
    let (out, metrics) = session.infer(&random_inputs(1, 43)[0]).unwrap();
    assert_eq!(out.shape, vec![16, 8, 8]);
    assert!(out.data.iter().all(|v| v.is_finite()));
    assert_eq!(metrics.per_layer_us.len(), 7);
    std::fs::remove_dir_all(&dir).ok();
}
