//! Native-backend serving tests: a [`Session`] over the in-process
//! kernel layer needs only the manifest and weight files — no PJRT
//! client, no AOT-compiled HLO — so these tests synthesize a manifest
//! for mini-inception and run under plain `cargo test`, covering the
//! parallel `infer_batch` ≡ sequential `infer` golden equality that the
//! PJRT-gated tests can only check when artifacts are built.

use std::collections::BTreeMap;
use std::path::PathBuf;

use dynamap::api::{Backend, Session};
use dynamap::graph::layer::Op;
use dynamap::graph::zoo;
use dynamap::runtime::TensorBuf;
use dynamap::util::rng::Rng;

fn write_f32(path: &std::path::Path, data: &[f32]) {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).unwrap();
}

/// Write a minimal artifact manifest for mini-inception with random
/// weights and no HLO artifacts (`algos: {}`) into a fresh temp dir.
fn synth_manifest_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dynamap_native_manifest_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cnn = zoo::mini_inception();
    let mut rng = Rng::new(0x5EED);
    let mut layers = Vec::new();
    for node in &cnn.nodes {
        let Op::Conv(spec) = &node.op else { continue };
        let safe = node.name.replace('/', "_");
        let wfile = format!("w__{safe}.bin");
        let n = spec.weight_count();
        let w: Vec<f32> = (0..n).map(|_| rng.f32_range(-0.5, 0.5)).collect();
        write_f32(&dir.join(&wfile), &w);
        layers.push(format!(
            r#"{{"name":"{}","c_in":{},"c_out":{},"h1":{},"h2":{},"k1":{},"k2":{},"s":{},"p1":{},"p2":{},"o1":{},"o2":{},"algos":{{}},"weights":"{}","weight_count":{}}}"#,
            node.name,
            spec.c_in,
            spec.c_out,
            spec.h1,
            spec.h2,
            spec.k1,
            spec.k2,
            spec.s,
            spec.p1,
            spec.p2,
            spec.o1(),
            spec.o2(),
            wfile,
            n
        ));
    }
    let manifest = format!(
        r#"{{"model":"mini-inception","input":{{"c":4,"h1":16,"h2":16}},"layers":[{}],"golden_input":"","golden_output":""}}"#,
        layers.join(",")
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    dir
}

fn random_inputs(n: usize, seed: u64) -> Vec<TensorBuf> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            TensorBuf::new(
                vec![4, 16, 16],
                (0..4 * 16 * 16).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            )
        })
        .collect()
}

#[test]
fn native_session_serves_without_pjrt_artifacts() {
    let dir = synth_manifest_dir("serve");
    let mut session = Session::builder(dir.to_str().unwrap())
        .backend(Backend::Native)
        .build()
        .unwrap();
    assert_eq!(session.backend(), Backend::Native);
    assert_eq!(session.model(), "mini-inception");
    assert_eq!(session.loaded_executables(), 0, "native backend compiles no HLO");
    assert_eq!(session.prepared_count(), 7, "weights lowered once per conv layer");
    assert!(session.plan().is_some(), "DSE plan resolved at build time");

    let inputs = random_inputs(1, 11);
    let (out, metrics) = session.infer(&inputs[0]).unwrap();
    assert_eq!(out.shape, vec![16, 8, 8]);
    assert!(out.data.iter().all(|v| v.is_finite()));
    assert_eq!(metrics.per_layer_us.len(), 7, "one metric entry per conv layer");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn parallel_infer_batch_matches_sequential_bitwise() {
    let dir = synth_manifest_dir("batch");
    let mut session = Session::builder(dir.to_str().unwrap())
        .backend(Backend::Native)
        .build()
        .unwrap();
    let n = 6;
    let inputs = random_inputs(n, 22);
    let (batched, metrics) = session.infer_batch(&inputs).unwrap();
    assert_eq!(batched.len(), n);
    assert_eq!(metrics.per_request.len(), n);
    assert_eq!(metrics.stats.count(), n, "aggregate stats must count N requests");
    assert_eq!(session.stats().count(), n, "session-wide stats must count N requests");

    // the parallel fan-out must be invisible: outputs bit-identical to
    // sequential infer calls, in input order
    for (i, (input, batched_out)) in inputs.iter().zip(&batched).enumerate() {
        let (seq, _) = session.infer(input).unwrap();
        assert_eq!(batched_out, &seq, "request {i}: parallel != sequential");
    }
    assert_eq!(session.stats().count(), 2 * n);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn native_session_honours_explicit_algo_map() {
    let dir = synth_manifest_dir("algomap");
    let cnn = zoo::mini_inception();
    // force a distinct algorithm family per kernel size
    let mut map = BTreeMap::new();
    for node in &cnn.nodes {
        let Op::Conv(spec) = &node.op else { continue };
        let algo = match spec.k1 {
            1 => "im2col",
            3 => "winograd",
            _ => "kn2row",
        };
        map.insert(node.name.clone(), algo.to_string());
    }
    let mut session = Session::builder(dir.to_str().unwrap())
        .backend(Backend::Native)
        .algo_map(map.clone())
        .build()
        .unwrap();
    assert_eq!(session.algo_map(), &map, "native backend must not clamp supported algos");

    // all three families execute and agree with an all-im2col session
    let all_im2col: BTreeMap<String, String> =
        map.keys().map(|k| (k.clone(), "im2col".to_string())).collect();
    let mut reference = Session::builder(dir.to_str().unwrap())
        .backend(Backend::Native)
        .algo_map(all_im2col)
        .build()
        .unwrap();
    for input in &random_inputs(2, 33) {
        let (a, _) = session.infer(input).unwrap();
        let (b, _) = reference.infer(input).unwrap();
        assert_eq!(a.shape, b.shape);
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert!(
                (x - y).abs() < 1e-3,
                "algorithm families disagree at {i}: {x} vs {y}"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
