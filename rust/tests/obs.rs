//! Observability integration tests: trace ids over live TCP (the
//! protocol-v3 trailer), span well-formedness against the *live* plan
//! across a hot swap, v2-client compatibility (no trace, no error),
//! the `Stats`/`TraceDump` wire frames, and bounded ring behaviour
//! under real load. Everything runs on loopback ephemeral ports with
//! synthesized artifacts — no PJRT, no fixed port numbers.
//!
//! The span recorder is process-global (like the fault registry), so
//! every test that installs one serializes on [`obs_lock`].

use std::collections::BTreeMap;
use std::io::Write;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use dynamap::api::{Backend, Compiler, Device, Session};
use dynamap::net::{protocol, Client, Frame, NetServer};
use dynamap::obs::{ObsGuard, Stage, TraceId};
use dynamap::serve::loadgen::{open_loop, open_loop_input, OpenLoopConfig};
use dynamap::serve::{BatchConfig, ModelRegistry, RegistryConfig};
use dynamap::util::json::Json;

fn obs_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn temp_root(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("dynamap_obs_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Registry over a temp root: small-edge device (fast DSE), shared plan
/// cache, synthetic artifacts.
fn registry(root: &PathBuf, max_batch: usize, max_wait_ms: u64) -> Arc<ModelRegistry> {
    Arc::new(ModelRegistry::new(RegistryConfig {
        artifacts_root: root.join("zoo"),
        plan_cache: Some(root.join("plans")),
        capacity: 0,
        synthesize_missing: true,
        seed: 0xA11CE,
        compiler: Compiler::new().device(Device::small_edge()),
        batch: BatchConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
        },
        max_inflight: 0,
        profile: false,
        slos: Default::default(),
    }))
}

/// Events of `doc` whose `args.trace` equals `id`'s hex form.
fn events_of<'a>(events: &'a [Json], id: TraceId) -> Vec<&'a Json> {
    let hex = id.to_string();
    events
        .iter()
        .filter(|e| e.get("args").get("trace").as_str() == Some(hex.as_str()))
        .collect()
}

fn cats<'a>(events: &[&'a Json]) -> Vec<&'a str> {
    events.iter().filter_map(|e| e.get("cat").as_str()).collect()
}

#[test]
fn traced_requests_over_tcp_export_complete_perfetto_spans() {
    let _serial = obs_lock();
    let root = temp_root("tcp");
    let reg = registry(&root, 4, 2);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let served_map = host.state().algo_map().clone();
    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.local_addr().to_string()).unwrap();
    let guard = ObsGuard::install(dynamap::obs::DEFAULT_CAPACITY);

    // six traced requests with deterministic seeded ids — the id rides
    // the protocol-v3 trailer; spans are recorded server-side
    let ids: Vec<TraceId> = (0..6).map(|i| TraceId::derive(99, i)).collect();
    for (i, id) in ids.iter().enumerate() {
        client
            .infer_traced("mini", &open_loop_input(99, i, dims), None, Some(*id))
            .unwrap();
    }

    // fetch the spans back over the wire and validate the export shape
    let json = client.dump_trace().unwrap();
    let doc = Json::parse(&json).expect("TraceDumpOk payload parses as JSON");
    let events = doc.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!events.is_empty(), "traced requests must leave spans");

    // well-formedness: every event is a complete-interval event with a
    // known category and a non-empty name
    for e in events {
        assert_eq!(e.get("ph").as_str(), Some("X"), "complete events only");
        assert!(e.get("ts").as_u64().is_some(), "ts is numeric µs");
        assert!(e.get("dur").as_u64().is_some(), "dur is numeric µs");
        let cat = e.get("cat").as_str().expect("category present");
        assert!(
            ["admission", "queue", "flush", "layer", "measure"].contains(&cat),
            "unknown category {cat}"
        );
        assert!(!e.get("name").as_str().unwrap_or("").is_empty());
    }

    // per request: the full admission → queue → layer path, every layer
    // span tagged with the live plan's (algo, precision, kernel)
    let n_layers = served_map.len();
    for (i, id) in ids.iter().enumerate() {
        let mine = events_of(events, *id);
        let c = cats(&mine);
        assert!(c.contains(&"admission"), "request {i}: no admission span");
        assert!(c.contains(&"queue"), "request {i}: no queue span");
        let layers: Vec<_> =
            mine.iter().filter(|e| e.get("cat").as_str() == Some("layer")).collect();
        assert_eq!(
            layers.len(),
            n_layers,
            "request {i}: one layer span per planned layer"
        );
        for l in &layers {
            let name = l.get("name").as_str().expect("layer span names the layer");
            let algo = l.get("args").get("algo").as_str().expect("algo tag");
            assert_eq!(
                Some(&algo.to_string()),
                served_map.get(name),
                "request {i}: span algo for '{name}' must match the live plan"
            );
            let precision = l.get("args").get("precision").as_str().expect("precision tag");
            assert!(["f32", "int8"].contains(&precision), "{precision}");
            assert!(
                !l.get("args").get("kernel").as_str().unwrap_or("").is_empty(),
                "kernel tag present"
            );
        }
    }

    // batch flushes show up (untraced, on track 0, tagged with size)
    let flushes: Vec<_> =
        events.iter().filter(|e| e.get("cat").as_str() == Some("flush")).collect();
    assert!(!flushes.is_empty(), "at least one batch flush span");
    for f in &flushes {
        assert_eq!(f.get("tid").as_u64(), Some(0), "flush spans are untraced");
        assert!(f.get("args").get("batch").as_str().is_some(), "batch-size tag");
    }

    // TraceDump drains: a second dump sees only spans recorded since
    let json2 = client.dump_trace().unwrap();
    let doc2 = Json::parse(&json2).unwrap();
    assert_eq!(
        doc2.get("traceEvents").as_arr().map(<[_]>::len),
        Some(0),
        "dump is collect-then-fetch — the ring is left empty"
    );

    // the Stats frame returns the full metrics + histogram snapshot
    let stats = client.server_stats().unwrap();
    let sdoc = Json::parse(&stats).expect("StatsOk payload parses as JSON");
    let models = sdoc.get("models").as_arr().expect("models array");
    let mine = models
        .iter()
        .find(|m| m.get("model").as_str() == Some("mini-inception"))
        .expect("served model present in the scrape");
    assert_eq!(mine.get("requests").as_u64(), Some(6));
    assert!(
        !mine.get("latency_hist").get("buckets").as_arr().unwrap_or(&[]).is_empty(),
        "histogram buckets ride the Stats frame"
    );

    drop(guard);
    client.shutdown_server().unwrap();
    server.shutdown();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn v2_clients_get_replies_and_untraced_spans() {
    let _serial = obs_lock();
    let root = temp_root("v2");
    let reg = registry(&root, 4, 2);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let guard = ObsGuard::install(4096);

    // a trailer-less Infer body is valid in every protocol version;
    // re-stamp the header's version byte to 2 to impersonate an old
    // client that has never heard of trace ids
    let mut bytes = protocol::encode_frame(&Frame::Infer {
        model: "mini".into(),
        input: open_loop_input(99, 0, dims),
        deadline_ms: None,
        trace: None,
    });
    bytes[2] = 2;
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.write_all(&bytes).unwrap();
    let reply = protocol::read_frame(&mut raw).unwrap().expect("a reply frame");
    assert!(
        matches!(reply, Frame::InferOk { .. }),
        "v2 infer must succeed untraced, got {reply:?}"
    );
    drop(raw);

    // the request still produced its spans — all uncorrelated
    let spans = guard.recorder().snapshot();
    assert!(
        spans.iter().any(|s| s.stage == Stage::Layer),
        "v2 requests are observable too"
    );
    for s in &spans {
        assert_eq!(s.trace, None, "no trailer ⇒ no trace id on any span");
    }

    drop(guard);
    let client = Client::connect(addr).unwrap();
    client.shutdown_server().unwrap();
    server.shutdown();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn layer_spans_follow_the_live_plan_across_a_hot_swap() {
    let _serial = obs_lock();
    let root = temp_root("swap");
    let reg = registry(&root, 4, 2);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let old_map = host.state().algo_map().clone();
    let guard = ObsGuard::install(4096);

    let before = TraceId::derive(7, 0);
    reg.infer_traced("mini", &open_loop_input(7, 0, dims), None, Some(before)).unwrap();

    // hot-swap to a plan that flips every general conv between the two
    // always-valid families, exactly like `tune::remap` does: rebuild
    // the session over the same artifacts with an explicit algo map
    let new_map: BTreeMap<String, String> = old_map
        .iter()
        .map(|(layer, algo)| {
            let flipped = if algo == "im2col" { "kn2row" } else { "im2col" };
            (layer.clone(), flipped.to_string())
        })
        .collect();
    let dir = root.join("zoo").join("mini-inception");
    let session = Session::builder(dir.to_string_lossy().into_owned())
        .backend(Backend::Native)
        .algo_map(new_map)
        .build()
        .unwrap();
    let new_state = session.native_state().expect("native backend shares state");
    let served_after = new_state.algo_map().clone();
    assert_ne!(old_map, served_after, "the swap must actually change the plan");
    reg.swap_state("mini", new_state, None).unwrap();

    let after = TraceId::derive(7, 1);
    reg.infer_traced("mini", &open_loop_input(7, 1, dims), None, Some(after)).unwrap();

    // each request's layer spans carry the algo of the plan that was
    // live *when it ran* — a swap never rewrites history
    let spans = guard.recorder().snapshot();
    let layer_algos = |id: TraceId| -> BTreeMap<String, String> {
        spans
            .iter()
            .filter(|s| s.trace == Some(id) && s.stage == Stage::Layer)
            .map(|s| {
                let algo = s
                    .tags
                    .iter()
                    .find(|(k, _)| *k == "algo")
                    .map(|(_, v)| v.clone())
                    .expect("layer spans carry an algo tag");
                (s.name.clone(), algo)
            })
            .collect()
    };
    assert_eq!(layer_algos(before), old_map, "pre-swap spans match the old plan");
    assert_eq!(layer_algos(after), served_after, "post-swap spans match the new plan");

    drop(guard);
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn ring_overflow_under_live_load_stays_bounded_and_never_blocks() {
    let _serial = obs_lock();
    let root = temp_root("ring");
    let reg = registry(&root, 4, 1);
    reg.host("mini").unwrap();
    // a ring far smaller than the span volume of the run: a 6-layer
    // model × 48 requests produces hundreds of spans
    let guard = ObsGuard::install(16);

    let cfg = OpenLoopConfig {
        model: "mini".into(),
        rate_qps: 2000.0,
        requests: 48,
        seed: 99,
        workers: 8,
        deadline: None,
        trace: true,
    };
    let report = open_loop(reg.as_ref(), &cfg).unwrap();
    assert_eq!(report.sent, 48);
    assert_eq!(report.errors, 0, "overflow must never surface as request errors");

    let rec = guard.recorder();
    assert!(rec.len() <= 16, "ring never exceeds its capacity");
    assert!(rec.dropped() > 0, "the run must actually have overflowed");
    // what remains is the newest window, still well-formed
    for s in rec.snapshot() {
        assert!(!s.name.is_empty());
    }

    drop(guard);
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}
