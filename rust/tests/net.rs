//! TCP front-end integration tests: wire round trips bitwise-equal to
//! `Session::infer`, typed errors over the wire, admission-control
//! shedding with the retry hint, graceful drain (every accepted request
//! replied, late connects refused), and malformed-byte robustness.
//! Everything runs on loopback ephemeral ports with synthesized
//! artifacts — no PJRT, no fixed port numbers.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use dynamap::api::{Backend, Compiler, Device, DynamapError, Session};
use dynamap::net::{Client, HedgeConfig, NetServer, RetryPolicy};
use dynamap::runtime::TensorBuf;
use dynamap::serve::loadgen::{open_loop, open_loop_input, OpenLoopConfig};
use dynamap::serve::{BatchConfig, ModelRegistry, RegistryConfig};
use dynamap::util::parallel::parallel_run;

fn temp_root(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("dynamap_net_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Registry over a temp root: small-edge device (fast DSE), shared plan
/// cache, synthetic artifacts, configurable batching + admission.
fn registry(
    root: &PathBuf,
    max_batch: usize,
    max_wait_ms: u64,
    max_inflight: usize,
) -> Arc<ModelRegistry> {
    Arc::new(ModelRegistry::new(RegistryConfig {
        artifacts_root: root.join("zoo"),
        plan_cache: Some(root.join("plans")),
        capacity: 0,
        synthesize_missing: true,
        seed: 0xA11CE,
        compiler: Compiler::new().device(Device::small_edge()),
        batch: BatchConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
        },
        max_inflight,
        profile: false,
        slos: Default::default(),
    }))
}

/// A sequential reference session over the same synthesized artifacts
/// and plan cache as the registry (so: the same plan, the same
/// weights — replies must be bitwise-equal).
fn reference_session(root: &PathBuf) -> Session {
    let dir = root.join("zoo").join("mini-inception");
    Session::builder(dir.to_str().unwrap().to_string())
        .backend(Backend::Native)
        .compiler(Compiler::new().device(Device::small_edge()))
        .plan_cache(root.join("plans"))
        .build()
        .unwrap()
}

#[test]
fn infer_over_tcp_is_bitwise_equal_to_session_and_errors_are_typed() {
    let root = temp_root("roundtrip");
    let reg = registry(&root, 4, 2, 0);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let client = Client::connect(addr).unwrap();

    // liveness first
    let rtt = client.ping().unwrap();
    assert!(rtt < Duration::from_secs(5));

    // replies bitwise-equal to a sequential Session over the same
    // artifacts + plan cache, concurrently from several connections
    let mut session = reference_session(&root);
    let expected: Vec<TensorBuf> = (0..8)
        .map(|i| session.infer(&open_loop_input(99, i, dims)).unwrap().0)
        .collect();
    let got: Vec<(TensorBuf, f64)> = parallel_run(8, |i| {
        client.infer("mini", &open_loop_input(99, i, dims)).unwrap()
    });
    for (i, ((out, server_us), exp)) in got.iter().zip(&expected).enumerate() {
        assert_eq!(out, exp, "request {i}: TCP reply != sequential Session::infer");
        assert!(*server_us > 0.0, "server-side latency must be reported");
    }

    // typed errors survive the wire
    let e = client.infer("no-such-model", &open_loop_input(99, 0, dims)).unwrap_err();
    assert!(matches!(e, DynamapError::UnknownModel(_)), "{e}");
    let e = client.infer("mini", &TensorBuf::zeros(vec![1, 1, 1])).unwrap_err();
    assert!(matches!(e, DynamapError::Shape { .. }), "{e}");

    // the same client still works after server-side errors (the
    // connection stayed on a frame boundary)
    let (out, _) = client.infer("mini", &open_loop_input(99, 0, dims)).unwrap();
    assert_eq!(out, expected[0]);

    client.shutdown_server().unwrap();
    server.shutdown();
    reg.assert_quiesced(); // every admission permit returned
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn admission_budget_sheds_over_tcp_with_retry_hint() {
    let root = temp_root("admission");
    // budget 1, slow flush (one request waits out the full 200 ms
    // max_wait) — a second concurrent request must be shed, not queued
    let reg = registry(&root, 8, 200, 1);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.local_addr().to_string()).unwrap();

    let results = parallel_run(2, |i| {
        if i == 1 {
            // let request 0 occupy the only in-flight slot first
            std::thread::sleep(Duration::from_millis(60));
        }
        client.infer("mini", &open_loop_input(99, i, dims))
    });
    let ok: Vec<_> = results.iter().filter(|r| r.is_ok()).collect();
    let shed: Vec<_> = results.iter().filter_map(|r| r.as_ref().err()).collect();
    assert_eq!((ok.len(), shed.len()), (1, 1), "one served, one shed");
    match shed[0] {
        DynamapError::Overloaded { model, retry_after_ms } => {
            assert_eq!(model, "mini-inception");
            assert!(*retry_after_ms >= 1, "hint must be a usable backoff");
        }
        other => panic!("expected Overloaded over the wire, got {other}"),
    }

    // the shed is accounted per model and surfaced in the stats table
    let snap = host.metrics().snapshot();
    assert_eq!(snap.shed, 1);
    assert_eq!(snap.requests, 1);
    let report = reg.metrics().report();
    assert!(report.contains("shed"), "stats table carries the shed column:\n{report}");

    // budget released after the reply: the next request is admitted
    assert!(client.infer("mini", &open_loop_input(99, 5, dims)).is_ok());
    client.shutdown_server().unwrap();
    server.shutdown();
    reg.assert_quiesced(); // sheds must not leak permits either
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn graceful_drain_replies_to_inflight_and_refuses_late_connects() {
    let root = temp_root("drain");
    // 40 ms max_wait: requests sit mid-batch when the drain starts
    let reg = registry(&root, 8, 40, 0);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let mut expected_session = reference_session(&root);
    let expected: Vec<TensorBuf> = (0..3)
        .map(|i| expected_session.infer(&open_loop_input(7, i, dims)).unwrap().0)
        .collect();

    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let client = Client::connect(addr.clone()).unwrap();

    let results = std::thread::scope(|s| {
        let handles: Vec<_> = (0..3)
            .map(|i| {
                let client = &client;
                s.spawn(move || client.infer("mini", &open_loop_input(7, i, dims)))
            })
            .collect();
        // shutdown mid-batch: the requests are in flight (queued,
        // waiting out max_wait=40ms) when the drain begins
        std::thread::sleep(Duration::from_millis(15));
        server.shutdown();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
    });

    // every accepted request got its reply, bitwise-equal to Session
    for (i, (r, exp)) in results.iter().zip(&expected).enumerate() {
        let (out, _) = r.as_ref().unwrap_or_else(|e| panic!("request {i} dropped: {e}"));
        assert_eq!(out, exp, "request {i}: drained reply != sequential Session::infer");
    }
    assert_eq!(host.metrics().snapshot().requests, 3);

    // late connects are refused cleanly — the listener is gone
    assert!(
        TcpStream::connect(&addr).is_err(),
        "post-drain connect must be refused"
    );
    assert!(Client::connect(addr).is_err(), "pooled client sees the refusal typed");

    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn malformed_bytes_get_typed_reply_and_never_kill_the_server() {
    let root = temp_root("malformed");
    let reg = registry(&root, 4, 2, 0);
    reg.host("mini").unwrap();
    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    // (a) garbage that is not even a header: typed Protocol error
    // frame back (best effort), then the server closes the connection.
    // Exactly one header's worth of bytes, so the server has nothing
    // unread at close time (an unread backlog would RST the reply away).
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.write_all(b"GARBAGE!").unwrap();
    let mut reply = Vec::new();
    raw.read_to_end(&mut reply).unwrap(); // returns once server closes
    assert!(!reply.is_empty(), "server should reply before closing");
    // the reply must itself be a well-formed Error(Protocol) frame
    let frame = dynamap::net::protocol::read_frame(&mut &reply[..]).unwrap().unwrap();
    assert!(
        matches!(frame, dynamap::net::Frame::Error(dynamap::net::WireError::Protocol(_))),
        "{frame:?}"
    );

    // (b) a valid header announcing an oversized payload: rejected
    // before allocation, connection closed
    let mut raw = TcpStream::connect(&addr).unwrap();
    let mut header = Vec::new();
    header.extend_from_slice(&dynamap::net::protocol::MAGIC.to_le_bytes());
    header.push(dynamap::net::protocol::VERSION);
    header.push(1); // Infer
    header.extend_from_slice(&u32::MAX.to_le_bytes());
    raw.write_all(&header).unwrap();
    let mut reply = Vec::new();
    raw.read_to_end(&mut reply).unwrap();
    let frame = dynamap::net::protocol::read_frame(&mut &reply[..]).unwrap().unwrap();
    assert!(matches!(frame, dynamap::net::Frame::Error(_)), "{frame:?}");

    // (c) a truncated frame (header promises more than arrives): the
    // server must not hang on it forever once the peer closes
    let mut raw = TcpStream::connect(&addr).unwrap();
    let bytes =
        dynamap::net::protocol::encode_frame(&dynamap::net::Frame::Ping);
    raw.write_all(&bytes[..bytes.len() - 2]).unwrap();
    drop(raw); // half a header, then hang up

    // after all of that, the server still serves normal traffic
    let client = Client::connect(addr).unwrap();
    client.ping().unwrap();
    let dims = reg.host("mini").unwrap().input_dims();
    assert!(client.infer("mini", &open_loop_input(99, 0, dims)).is_ok());

    client.shutdown_server().unwrap();
    server.shutdown();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn open_loop_over_tcp_sheds_under_overload_and_server_stays_live() {
    let root = temp_root("openloop");
    // deliberately tiny budget + slow flush: offered load far beyond
    // capacity, so the open loop must observe typed shedding
    let reg = registry(&root, 2, 25, 1);
    reg.host("mini").unwrap();
    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.local_addr().to_string()).unwrap();

    let cfg = OpenLoopConfig {
        model: "mini".into(),
        rate_qps: 2000.0,
        requests: 80,
        seed: 99,
        workers: 16,
        deadline: None,
        trace: false,
    };
    let report = open_loop(&client, &cfg).unwrap();
    assert_eq!(report.sent, 80);
    assert_eq!(
        report.ok + report.shed + report.deadline_miss + report.errors,
        80,
        "every request accounted"
    );
    assert!(report.ok >= 1, "the server kept serving under overload");
    assert!(report.shed >= 1, "overload must be shed, not absorbed: {}", report.summary());
    assert_eq!(report.errors, 0, "sheds are typed, not generic failures");
    // shed replies are prompt (admission rejects before the queue, so
    // a shed never waits out a batch window); generous CI bound
    assert!(
        report.shed_latency.max() < 1_000_000.0,
        "shed reply took {}µs",
        report.shed_latency.max()
    );
    // deterministic workload: summary parses for the CI smoke job
    assert!(report.summary().contains("shed="), "{}", report.summary());

    // server is still alive and draining works
    client.ping().unwrap();
    client.shutdown_server().unwrap();
    server.shutdown();
    reg.assert_quiesced();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn deadlines_ride_the_wire_and_expired_requests_come_back_typed() {
    let root = temp_root("deadline");
    let reg = registry(&root, 4, 2, 0);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    let client = Client::connect(server.local_addr().to_string()).unwrap();

    // a generous deadline changes nothing: same bitwise reply
    let mut session = reference_session(&root);
    let expected = session.infer(&open_loop_input(99, 0, dims)).unwrap().0;
    let (out, _) = client
        .infer_with_deadline("mini", &open_loop_input(99, 0, dims), Some(Duration::from_secs(30)))
        .unwrap();
    assert_eq!(out, expected, "deadline-carrying reply != sequential Session::infer");

    // a zero deadline is expired the moment the server decodes it:
    // shed pre-admission with the typed error, never batched
    let batches_before = host.metrics().snapshot().batches;
    let e = client
        .infer_with_deadline("mini", &open_loop_input(99, 1, dims), Some(Duration::ZERO))
        .unwrap_err();
    match e {
        DynamapError::DeadlineExceeded { model, waited_ms } => {
            assert_eq!(model, "mini-inception");
            assert_eq!(waited_ms, 0, "pre-admission shed never waited in queue");
        }
        other => panic!("expected DeadlineExceeded over the wire, got {other}"),
    }
    let snap = host.metrics().snapshot();
    assert_eq!(snap.batches, batches_before, "an expired request must not enter a batch");
    assert_eq!(snap.deadline_miss, 1, "the miss is counted per model");

    // the connection stayed on a frame boundary; plain traffic resumes
    assert!(client.infer("mini", &open_loop_input(99, 2, dims)).is_ok());
    client.shutdown_server().unwrap();
    server.shutdown();
    reg.assert_quiesced(); // a deadline shed must not leak its permit
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn client_retries_sheds_under_backoff_when_the_policy_allows() {
    let root = temp_root("retry");
    // budget 1 + slow flush: the second concurrent request is shed —
    // but with overloaded_attempts granted it retries past the storm
    let reg = registry(&root, 8, 150, 1);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    let client = Client::connect_with(
        server.local_addr().to_string(),
        RetryPolicy {
            overloaded_attempts: 20,
            max_backoff: Duration::from_millis(50),
            ..RetryPolicy::default()
        },
    )
    .unwrap();

    let results = parallel_run(2, |i| {
        if i == 1 {
            std::thread::sleep(Duration::from_millis(40));
        }
        client.infer("mini", &open_loop_input(99, i, dims))
    });
    for (i, r) in results.iter().enumerate() {
        assert!(r.is_ok(), "request {i} should succeed after retries: {:?}", r.as_ref().err());
    }
    let stats = client.stats();
    assert!(stats.retries >= 1, "the shed request must have retried");
    assert!(
        stats.budget_remaining < RetryPolicy::default().retry_budget,
        "retries draw from the budget"
    );
    // the shed itself still shows in the server's accounting
    assert!(host.metrics().snapshot().shed >= 1);

    client.shutdown_server().unwrap();
    server.shutdown();
    reg.assert_quiesced();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn retry_budget_bounds_transport_retries() {
    // a stub listener that accepts and immediately hangs up: every
    // attempt is a transport failure (detached thread; it dies with
    // the test process)
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(conn) = conn else { break };
            drop(conn);
        }
    });

    let input = TensorBuf::zeros(vec![4, 16, 16]);
    // attempts allowed but budget dry: the first failure surfaces raw
    let broke = Client::connect_with(
        addr.clone(),
        RetryPolicy { transport_attempts: 5, retry_budget: 0, ..RetryPolicy::default() },
    )
    .unwrap();
    assert!(matches!(broke.infer("mini", &input), Err(DynamapError::Net(_))));
    assert_eq!(broke.stats().retries, 0, "no budget, no retries");

    // budget available: exactly transport_attempts total tries
    let client = Client::connect_with(
        addr,
        RetryPolicy {
            transport_attempts: 3,
            retry_budget: 10,
            base_backoff: Duration::from_micros(100),
            ..RetryPolicy::default()
        },
    )
    .unwrap();
    assert!(matches!(client.infer("mini", &input), Err(DynamapError::Net(_))));
    let stats = client.stats();
    assert_eq!(stats.retries, 2, "3 attempts = 1 try + 2 retries");
    assert_eq!(stats.budget_remaining, 8);
}

#[test]
fn hedged_requests_return_bitwise_correct_replies() {
    let root = temp_root("hedge");
    let reg = registry(&root, 4, 2, 0);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").unwrap();
    // an aggressive hedge delay (1 ms cold) so the race actually runs:
    // most requests will have a hedge in flight alongside the primary
    let client = Client::connect_with(
        server.local_addr().to_string(),
        RetryPolicy {
            hedge: Some(HedgeConfig {
                ewma_mult: 1.0,
                min_delay: Duration::from_micros(200),
                max_delay: Duration::from_millis(1),
            }),
            ..RetryPolicy::default()
        },
    )
    .unwrap();

    let mut session = reference_session(&root);
    for i in 0..12 {
        let input = open_loop_input(99, i, dims);
        let expected = session.infer(&input).unwrap().0;
        let (out, _) = client.infer("mini", &input).unwrap();
        // whichever attempt won, the reply is the same tensor — hedging
        // may duplicate compute, never results
        assert_eq!(out, expected, "request {i}: hedged reply != sequential Session::infer");
    }

    client.ping().unwrap();
    client.shutdown_server().unwrap();
    server.shutdown();
    reg.assert_quiesced(); // losing hedges must release their permits too
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}
