//! Seeded property test at the crate surface: on randomized small
//! series-parallel PBQP instances the polynomial-time SP solver must
//! return solutions with exactly the brute-force-optimal cost, and the
//! cost it reports must equal the cost of the assignment it returns.
//!
//! `tune::remap` re-runs this solver *in production* whenever the
//! calibrated cost model justifies a plan hot-swap, so an optimality
//! regression here would silently ship worse mappings to live serving
//! — this test catches it before that. Graphs are built by the paper's
//! inductive SP construction (Definition 1: start from K₂, repeatedly
//! subdivide an edge or duplicate an edge in parallel), with dyadic
//! fractional costs so float comparisons stay exact.

use dynamap::pbqp::brute::search_space;
use dynamap::pbqp::{solve_brute, solve_sp, Matrix, Problem};
use dynamap::util::proptest;
use dynamap::util::rng::Rng;

/// Random series-parallel PBQP instance with source 0 and sink 1.
/// Domains of size 1–4 (the real cost graphs have ≤4 algorithm
/// choices), up to 12 series/parallel growth steps, dyadic costs in
/// [0, 32).
fn random_sp_problem(rng: &mut Rng) -> Problem {
    let mut p = Problem::default();
    let dom = |rng: &mut Rng| rng.range(1, 4);
    let labels = |n: usize| (0..n).map(|i| format!("o{i}")).collect::<Vec<_>>();
    let costs =
        |rng: &mut Rng, n: usize| (0..n).map(|_| rng.below(256) as f64 / 8.0).collect();
    let matrix = |rng: &mut Rng, a: usize, b: usize| {
        Matrix::from_fn(a, b, |_, _| rng.below(256) as f64 / 8.0)
    };
    let ds = dom(rng);
    let dt = dom(rng);
    let costs_s = costs(rng, ds);
    let costs_t = costs(rng, dt);
    let s = p.add_vertex("s", costs_s, labels(ds));
    let t = p.add_vertex("t", costs_t, labels(dt));
    let m0 = matrix(rng, p.costs[s].len(), p.costs[t].len());
    p.add_edge(s, t, m0);
    for _ in 0..rng.range(1, 12) {
        let eid = rng.below(p.edges.len() as u64) as usize;
        let (u, v) = (p.edges[eid].u, p.edges[eid].v);
        if rng.bool() {
            // series: subdivide (u, v) with a fresh vertex
            let dk = dom(rng);
            let name = format!("v{}", p.n());
            let ck = costs(rng, dk);
            let k = p.add_vertex(&name, ck, labels(dk));
            let m1 = matrix(rng, p.costs[u].len(), dk);
            let m2 = matrix(rng, dk, p.costs[v].len());
            p.edges.remove(eid);
            p.add_edge(u, k, m1);
            p.add_edge(k, v, m2);
        } else {
            // parallel: duplicate (u, v) with fresh transition costs
            let m = matrix(rng, p.costs[u].len(), p.costs[v].len());
            p.add_edge(u, v, m);
        }
    }
    p
}

#[test]
fn sp_solver_is_cost_optimal_on_random_sp_graphs() {
    proptest::check("sp_solver_vs_brute_crate_surface", 128, |rng: &mut Rng| {
        let p = random_sp_problem(rng);
        if search_space(&p) >= (1 << 22) {
            return Ok(()); // keep the brute-force oracle fast
        }
        let sol = solve_sp(&p, 0, 1)
            .ok_or("inductively constructed SP graph judged non-series-parallel")?;
        let brute = solve_brute(&p);
        if (sol.cost - brute.cost).abs() > 1e-9 {
            return Err(format!(
                "sp solver cost {} != brute-force optimum {} on {} vertices",
                sol.cost,
                brute.cost,
                p.n()
            ));
        }
        let evaluated = p.evaluate(&sol.assignment);
        if (evaluated - sol.cost).abs() > 1e-9 {
            return Err(format!(
                "reported cost {} != evaluated assignment cost {}",
                sol.cost, evaluated
            ));
        }
        Ok(())
    });
}

#[test]
fn sp_solver_matches_brute_on_pure_chains_and_fans() {
    // degenerate shapes the generator rarely hits in quantity: long
    // chains (every vertex degree ≤ 2) and wide parallel fans
    proptest::check("sp_solver_chains_and_fans", 32, |rng: &mut Rng| {
        let mut p = Problem::default();
        let labels = vec!["a".to_string(), "b".to_string()];
        let costs = |rng: &mut Rng| vec![rng.below(64) as f64 / 4.0, rng.below(64) as f64 / 4.0];
        let c0 = costs(rng);
        let c1 = costs(rng);
        let s = p.add_vertex("s", c0, labels.clone());
        let t = p.add_vertex("t", c1, labels.clone());
        if rng.bool() {
            // chain s - v1 - … - vk - t
            let mut prev = s;
            for i in 0..rng.range(1, 8) {
                let ci = costs(rng);
                let v = p.add_vertex(&format!("v{i}"), ci, labels.clone());
                let m = Matrix::from_fn(2, 2, |_, _| rng.below(64) as f64 / 4.0);
                p.add_edge(prev, v, m);
                prev = v;
            }
            let m = Matrix::from_fn(2, 2, |_, _| rng.below(64) as f64 / 4.0);
            p.add_edge(prev, t, m);
        } else {
            // fan: many parallel s→t edges
            for _ in 0..rng.range(2, 9) {
                let m = Matrix::from_fn(2, 2, |_, _| rng.below(64) as f64 / 4.0);
                p.add_edge(s, t, m);
            }
        }
        let sol = solve_sp(&p, s, t).ok_or("chain/fan judged non-SP")?;
        let brute = solve_brute(&p);
        if (sol.cost - brute.cost).abs() > 1e-9 {
            return Err(format!("sp {} != brute {}", sol.cost, brute.cost));
        }
        Ok(())
    });
}
