//! Online-adaptation integration tests: a deliberately mis-calibrated
//! device whose profile-driven calibration + re-map changes the served
//! algorithm assignment (deterministically, via synthetic
//! observations), the hot-swap soak test (concurrent clients across
//! forced swaps, every reply bitwise-identical to a sequential
//! `Session::infer` under the plan that served it), and a tune
//! controller smoke test over real profiled traffic. Everything runs
//! on synthesized artifacts — no PJRT, no `make artifacts`.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Barrier};

use dynamap::api::{Backend, Compiler, Device, NativeState, Session};
use dynamap::cost::{Algo, DeviceCalibration};
use dynamap::runtime::TensorBuf;
use dynamap::serve::{BatchConfig, ModelRegistry, RegistryConfig};
use dynamap::tune::{calibrate, remap, RemapConfig, TuneConfig, TuneController};
use dynamap::util::parallel::parallel_run;
use dynamap::util::rng::Rng;

fn temp_root(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("dynamap_tune_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn registry(root: &PathBuf, skew: DeviceCalibration, profile: bool) -> ModelRegistry {
    ModelRegistry::new(RegistryConfig {
        artifacts_root: root.join("zoo"),
        plan_cache: Some(root.join("plans")),
        capacity: 0,
        synthesize_missing: true,
        seed: 0x7EA1,
        compiler: Compiler::new().device(Device::small_edge()).calibration(skew),
        batch: BatchConfig {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(2),
        },
        max_inflight: 0,
        profile,
        slos: Default::default(),
    })
}

fn input_for(dims: (usize, usize, usize), idx: usize) -> TensorBuf {
    let (c, h1, h2) = dims;
    let mut rng = Rng::new(0x717E ^ (idx as u64));
    TensorBuf::new(
        vec![c, h1, h2],
        (0..c * h1 * h2).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
    )
}

/// Sequential reference session over the registry's synthesized
/// artifacts, serving an explicit algorithm map.
fn reference_session(root: &PathBuf, map: BTreeMap<String, String>) -> Session {
    let dir = root.join("zoo").join("mini-inception");
    Session::builder(dir.to_str().unwrap().to_string())
        .backend(Backend::Native)
        .algo_map(map)
        .build()
        .unwrap()
}

/// The acceptance-criterion test: start from a deliberately
/// mis-calibrated device (kn2row priced ~10000× too cheap, so the DSE
/// maps every conv layer to kn2row), feed the profiler observations in
/// which kn2row is really 50× *slower* than the analytic model says,
/// then calibrate + remap. The algorithm assignment must change, the
/// swap must bump the epoch and the swap counter, and post-swap
/// serving must stay bitwise-identical to a sequential
/// `Session::infer` under the new map.
#[test]
fn calibrated_remap_changes_assignment_on_mini_inception() {
    let root = temp_root("remap");
    let skew = DeviceCalibration::default().with("kn2row", 1e-4, 0.0);
    let reg = registry(&root, skew, true);
    let host = reg.host("mini").unwrap();
    let old_map = host.state().algo_map().clone();
    assert!(
        old_map.values().any(|a| a == "kn2row"),
        "the mis-calibrated device must bait the DSE into kn2row, got {old_map:?}"
    );
    let (p1, p2) = host.plan_shape().expect("registry hosts carry the plan shape");

    // deterministic observations: every available (layer, family) pair
    // observed at exactly its base analytic latency — except kn2row,
    // observed 50× slower (reality disagreeing with the skewed model)
    let mut base_cm = reg.config().compiler.config().cost_model();
    base_cm.calibration = DeviceCalibration::identity();
    let state = host.state();
    let profile = host.profile().expect("profiling is on").clone();
    let mut samples = Vec::new();
    for node in &state.cnn().nodes {
        let Some(spec) = node.op.conv() else { continue };
        for algo in Algo::available(spec, 2, 3, false) {
            let factor = if algo.family() == "kn2row" { 50.0 } else { 1.0 };
            let us = base_cm.best_conv_cost(spec, algo, p1, p2).seconds * 1e6 * factor;
            samples.push((node.name.clone(), algo.family().to_string(), us));
        }
    }
    for _ in 0..4 {
        profile.record(&samples);
    }

    let cal = calibrate(
        state.cnn(),
        &reg.config().compiler,
        p1,
        p2,
        &profile.snapshot(),
    )
    .unwrap();
    let kn_scale = cal.calibration.fit("kn2row").apply(1.0);
    assert!(
        (45.0..55.0).contains(&kn_scale),
        "kn2row fit should recover the 50× skew, got {kn_scale}"
    );

    let outcome = remap(&reg, "mini", &cal, &RemapConfig::default()).unwrap();
    assert!(outcome.swapped, "calibrated re-solve must beat the baited plan: {outcome:?}");
    assert!(
        !outcome.changed.is_empty(),
        "at least one layer's algorithm assignment must change"
    );
    assert!(outcome.predicted_speedup > 1.0, "{outcome:?}");
    assert_eq!(outcome.epoch, Some(1));
    assert_eq!(host.epoch(), 1);
    assert_eq!(host.metrics().snapshot().swaps, 1);

    let new_map = host.state().algo_map().clone();
    assert_ne!(new_map, old_map);
    assert!(
        outcome
            .changed
            .iter()
            .all(|c| old_map.get(&c.layer) == Some(&c.from)
                && new_map.get(&c.layer) == Some(&c.to)),
        "the reported diff must describe the actual swap: {:?}",
        outcome.changed
    );

    // post-swap serving is bitwise-identical to a sequential session
    // over the same artifacts with the new map
    let mut reference = reference_session(&root, new_map);
    let dims = host.input_dims();
    for idx in 0..4 {
        let input = input_for(dims, idx);
        let (expect, _) = reference.infer(&input).unwrap();
        let (got, _) = reg.infer("mini", &input).unwrap();
        assert_eq!(expect, got, "request {idx} after the hot swap");
    }

    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// The hot-swap soak test: ≥4 concurrent closed-loop clients across
/// forced swaps. Phase 1 brackets one swap with barriers, so every
/// pre-swap reply must be bitwise-identical to sequential
/// `Session::infer` under plan A and every post-swap reply under plan
/// B. Phase 2 races three swaps against in-flight traffic: each reply
/// must match exactly one of the two sequential references — a batch
/// is never served by a mix of plans, and no reply is lost,
/// duplicated or corrupted.
#[test]
fn hot_swap_soak_stays_bitwise_identical_to_sequential() {
    let root = temp_root("soak");
    let reg = registry(&root, DeviceCalibration::identity(), false);
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    let map_a = host.state().algo_map().clone();
    let map_b: BTreeMap<String, String> =
        map_a.keys().map(|k| (k.clone(), "im2col".to_string())).collect();
    assert_ne!(map_a, map_b, "the swap must actually change algorithms");

    let session_b = reference_session(&root, map_b.clone());
    let state_b: Arc<NativeState> = session_b.native_state().unwrap();
    let session_a2 = reference_session(&root, map_a.clone());
    let state_a: Arc<NativeState> = session_a2.native_state().unwrap();

    // sequential references for a fixed input set under both plans
    let k_inputs = 6usize;
    let mut ref_session_a = reference_session(&root, map_a);
    let mut ref_session_b = reference_session(&root, map_b);
    let refs_a: Vec<TensorBuf> = (0..k_inputs)
        .map(|i| ref_session_a.infer(&input_for(dims, i)).unwrap().0)
        .collect();
    let refs_b: Vec<TensorBuf> = (0..k_inputs)
        .map(|i| ref_session_b.infer(&input_for(dims, i)).unwrap().0)
        .collect();

    // -- phase 1: barrier-bracketed swap ---------------------------------
    let clients = 4usize;
    let half = 8usize;
    let before_swap = Barrier::new(clients + 1);
    let after_swap = Barrier::new(clients + 1);
    parallel_run(clients + 1, |i| {
        if i == clients {
            before_swap.wait();
            reg.swap_state("mini", state_b.clone(), None).unwrap();
            after_swap.wait();
            return;
        }
        for j in 0..half {
            let idx = (i * 31 + j) % k_inputs;
            let (out, _) = reg.infer("mini", &input_for(dims, idx)).unwrap();
            assert_eq!(out, refs_a[idx], "client {i} pre-swap request {j}");
        }
        before_swap.wait();
        after_swap.wait();
        for j in 0..half {
            let idx = (i * 17 + j) % k_inputs;
            let (out, _) = reg.infer("mini", &input_for(dims, idx)).unwrap();
            assert_eq!(out, refs_b[idx], "client {i} post-swap request {j}");
        }
    });
    assert_eq!(host.epoch(), 1);

    // -- phase 2: swaps racing in-flight traffic -------------------------
    let per_client = 30usize;
    let results = parallel_run(clients + 1, |i| {
        if i == clients {
            for swap in 0..3 {
                std::thread::sleep(std::time::Duration::from_millis(3));
                let state =
                    if swap % 2 == 0 { state_a.clone() } else { state_b.clone() };
                reg.swap_state("mini", state, None).unwrap();
            }
            return Vec::new();
        }
        (0..per_client)
            .map(|j| {
                let idx = (i * 13 + j) % k_inputs;
                (idx, reg.infer("mini", &input_for(dims, idx)).unwrap().0)
            })
            .collect()
    });
    let mut replies = 0usize;
    for (idx, out) in results.into_iter().flatten() {
        assert!(
            out == refs_a[idx] || out == refs_b[idx],
            "reply for input {idx} matches neither plan's sequential output"
        );
        replies += 1;
    }
    assert_eq!(replies, clients * per_client, "every request got exactly one reply");
    assert_eq!(host.epoch(), 4, "1 bracketed + 3 racing swaps");

    let snap = host.metrics().snapshot();
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.swaps, 4);
    assert_eq!(
        snap.requests,
        (clients * (2 * half + per_client)) as u64,
        "metrics account every soak request exactly once"
    );

    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// Controller smoke test over *real* profiled traffic: the cadence
/// thread runs passes without disturbing serving, and shuts down
/// cleanly.
#[test]
fn tune_controller_runs_passes_over_live_traffic() {
    let root = temp_root("controller");
    let reg = Arc::new(registry(&root, DeviceCalibration::identity(), true));
    let host = reg.host("mini").unwrap();
    let dims = host.input_dims();
    for idx in 0..24 {
        reg.infer("mini", &input_for(dims, idx)).unwrap();
    }
    assert!(host.profile().unwrap().requests() >= 24);

    let controller = TuneController::spawn(
        reg.clone(),
        TuneConfig {
            interval: std::time::Duration::from_millis(25),
            min_new_requests: 1,
            hysteresis: 0.05,
            verbose: false,
        },
    );
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while controller.passes() == 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(controller.passes() >= 1, "controller never ticked");
    controller.shutdown();
    controller.shutdown(); // idempotent

    // serving still healthy after (and regardless of) any remap
    let (out, _) = reg.infer("mini", &input_for(dims, 0)).unwrap();
    assert_eq!(out.shape, vec![16, 8, 8]);
    assert_eq!(host.metrics().snapshot().errors, 0);

    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}
