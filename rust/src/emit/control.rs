//! Control-signal sequence generation (DSE step ⑥): one control word
//! per layer encoding the algorithm, dataflow, GEMM tiling, DLT
//! configuration and module enables — what the overlay's sequencer
//! consumes at run time.

use crate::cost::conv::Algo;
use crate::cost::transition::input_format;
use crate::dse::Plan;
use crate::graph::layer::Op;
use crate::graph::Cnn;
use crate::util::json::Json;

/// Control word for one conv layer.
pub fn layer_word(cnn: &Cnn, plan: &Plan, idx: usize) -> Json {
    let l = &plan.mapping.layers[idx];
    let Op::Conv(spec) = &cnn.node(l.node).op else { unreachable!() };
    let (a, b, c, calls) = (l.cost.gemm.0, l.cost.gemm.1, l.cost.gemm.2, l.cost.gemm.3);
    let algo_code = match l.cost.algo {
        Algo::Im2col => 0,
        Algo::Kn2row => 1,
        Algo::Winograd { .. } => 2,
        Algo::WinogradStrided { .. } => 3,
    };
    let df_code = match l.cost.dataflow.name() {
        "NS" => 0,
        "WS" => 1,
        _ => 2,
    };
    Json::obj(vec![
        ("layer", Json::str(l.name.clone())),
        ("algo", Json::num(algo_code as f64)),
        ("algo_name", Json::str(l.cost.algo.name())),
        ("dataflow", Json::num(df_code as f64)),
        ("gemm_a", Json::num(a as f64)),
        ("gemm_b", Json::num(b as f64)),
        ("gemm_c", Json::num(c as f64)),
        ("gemm_calls", Json::num(calls as f64)),
        ("dlt_in_format", Json::str(input_format(l.cost.algo).name())),
        ("pad_accum_en", Json::Bool(matches!(l.cost.algo, Algo::Kn2row))),
        ("lt_en", Json::Bool(matches!(l.cost.algo, Algo::Winograd { .. } | Algo::WinogradStrided { .. }))),
        ("k1", Json::num(spec.k1 as f64)),
        ("k2", Json::num(spec.k2 as f64)),
        ("stride", Json::num(spec.s as f64)),
        ("est_cycles", Json::num(l.cost.cycles as f64)),
    ])
}

/// The full per-network control stream.
pub fn control_stream(cnn: &Cnn, plan: &Plan) -> Json {
    let words: Vec<Json> =
        (0..plan.mapping.layers.len()).map(|i| layer_word(cnn, plan, i)).collect();
    Json::obj(vec![
        ("network", Json::str(plan.cnn_name.clone())),
        ("p_sa1", Json::num(plan.p1 as f64)),
        ("p_sa2", Json::num(plan.p2 as f64)),
        ("layers", Json::Arr(words)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Compiler;
    use crate::dse::DseConfig;
    use crate::graph::zoo;
    use crate::util::json::Json as J;

    #[test]
    fn stream_covers_all_conv_layers() {
        let cnn = zoo::mini_inception();
        let compiler =
            Compiler::from_config(DseConfig::with_device(crate::cost::Device::small_edge()));
        let plan = compiler.compile(&cnn).unwrap().into_plan();
        let s = control_stream(&cnn, &plan);
        assert_eq!(s.get("layers").as_arr().unwrap().len(), 7);
        // round-trips through the JSON parser
        let back = J::parse(&s.pretty()).unwrap();
        assert_eq!(back.get("p_sa1").as_usize(), Some(plan.p1));
        // every word has consistent enables
        for w in back.get("layers").as_arr().unwrap() {
            let algo = w.get("algo").as_usize().unwrap();
            assert_eq!(w.get("pad_accum_en").as_bool(), Some(algo == 1));
            assert_eq!(w.get("lt_en").as_bool(), Some(algo >= 2));
        }
    }
}
