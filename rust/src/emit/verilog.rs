//! Verilog generation: the parameterized overlay top-level and the
//! dataflow-switchable stall-free PE of §3.2 (Fig. 3).
//!
//! The RTL is *structurally* faithful — MAC datapath with the NS shift
//! paths, WS/IS ping-pong preload registers, the drain mux, and the
//! generate-loop systolic grid — but is emitted as a deliverable
//! artifact, not synthesized in this environment.

use crate::dse::Plan;

/// The dataflow-switchable PE (Fig. 3): black/red NS datapath, blue
/// ping-pong weight registers, grey drain mux.
pub fn pe_module() -> String {
    r#"// -----------------------------------------------------------------
// dynamap_pe — dataflow-switchable stall-free processing element (§3.2)
//   MODE 00: NS  (non-stationary: operands stream, result stays)
//   MODE 01: WS  (weight-stationary: ping-pong pre-loaded weight)
//   MODE 10: IS  (input-stationary: mirror of WS)
// -----------------------------------------------------------------
module dynamap_pe #(
    parameter DW = 8,     // INT8 operands
    parameter AW = 32     // accumulator width
) (
    input  wire              clk,
    input  wire              rst,
    input  wire [1:0]        mode,        // dataflow select
    input  wire              preload_en,  // ping-pong bank load strobe
    input  wire              bank_sel,    // active ping-pong bank
    input  wire              drain_sel,   // grey mux: own acc vs pass-through
    input  wire [DW-1:0]     a_in,        // activation from west
    input  wire [DW-1:0]     w_in,        // weight from north
    input  wire [AW-1:0]     acc_in,      // partial/drain chain from north
    output reg  [DW-1:0]     a_out,       // to east
    output reg  [DW-1:0]     w_out,       // to south
    output reg  [AW-1:0]     acc_out      // to south (result or pass)
);
    // ping-pong stationary registers (blue in Fig. 3): the next pass's
    // block is pre-fetched while the current pass computes
    reg [DW-1:0] station [0:1];
    reg [AW-1:0] acc;

    wire [DW-1:0] mul_a = (mode == 2'b10) ? station[bank_sel] : a_in;
    wire [DW-1:0] mul_w = (mode == 2'b01) ? station[bank_sel] : w_in;
    wire signed [2*DW-1:0] prod = $signed(mul_a) * $signed(mul_w);

    always @(posedge clk) begin
        if (rst) begin
            acc     <= {AW{1'b0}};
            a_out   <= {DW{1'b0}};
            w_out   <= {DW{1'b0}};
            acc_out <= {AW{1'b0}};
        end else begin
            if (preload_en)
                station[~bank_sel] <= (mode == 2'b01) ? w_in : a_in;
            // MAC + systolic shifts
            acc   <= (mode == 2'b00 ? acc : acc_in) + {{(AW-2*DW){prod[2*DW-1]}}, prod};
            a_out <= a_in;
            w_out <= w_in;
            // grey drain mux: shift own result out while neighbours'
            // results pass through — overlaps I_SA with the next pass
            acc_out <= drain_sel ? acc : acc_in;
        end
    end
endmodule
"#
    .to_string()
}

/// The overlay top: P_SA1 × P_SA2 PE grid + module ports for the DLT,
/// Linear Transform, Pad-and-Accumulate and Pooling engines.
pub fn overlay_top(plan: &Plan) -> String {
    let (p1, p2) = (plan.p1, plan.p2);
    let mut v = String::new();
    v.push_str(&format!(
        "// ==================================================================\n\
         // DYNAMAP overlay — generated for {} (P_SA = {p1} x {p2})\n\
         // latency model: {:.3} ms end-to-end @ {:.0} GOP/s\n\
         // ==================================================================\n\n",
        plan.cnn_name, plan.total_latency_ms, plan.throughput_gops
    ));
    v.push_str(&pe_module());
    v.push_str(&format!(
        r#"
// -----------------------------------------------------------------
// dynamap_overlay_top — unified computing unit (§3.1, Fig. 2)
// -----------------------------------------------------------------
module dynamap_overlay_top #(
    parameter P_SA1 = {p1},
    parameter P_SA2 = {p2},
    parameter DW    = 8,
    parameter AW    = 32
) (
    input  wire                    clk,
    input  wire                    rst,
    input  wire [1:0]              mode,        // NS / WS / IS
    input  wire [2:0]              algo,        // im2col / kn2row / winograd
    input  wire                    preload_en,
    input  wire                    bank_sel,
    input  wire [P_SA1*DW-1:0]     act_in,      // from Input Buffer banks
    input  wire [P_SA2*DW-1:0]     wgt_in,      // from Kernel Buffer banks
    output wire [P_SA2*AW-1:0]     result_out   // to Output Buffer banks
);
    // activation / weight / accumulator meshes
    wire [DW-1:0] a_mesh [0:P_SA1][0:P_SA2];
    wire [DW-1:0] w_mesh [0:P_SA1][0:P_SA2];
    wire [AW-1:0] c_mesh [0:P_SA1][0:P_SA2];

    genvar r, c;
    generate
        for (r = 0; r < P_SA1; r = r + 1) begin : row
            assign a_mesh[r][0] = act_in[r*DW +: DW];
            for (c = 0; c < P_SA2; c = c + 1) begin : col
                if (r == 0) begin
                    assign w_mesh[0][c] = wgt_in[c*DW +: DW];
                    assign c_mesh[0][c] = {{AW{{1'b0}}}};
                end
                dynamap_pe #(.DW(DW), .AW(AW)) pe (
                    .clk(clk), .rst(rst), .mode(mode),
                    .preload_en(preload_en), .bank_sel(bank_sel),
                    .drain_sel(1'b1),
                    .a_in(a_mesh[r][c]),   .w_in(w_mesh[r][c]),
                    .acc_in(c_mesh[r][c]),
                    .a_out(a_mesh[r][c+1]), .w_out(w_mesh[r+1][c]),
                    .acc_out(c_mesh[r+1][c])
                );
            end
        end
        for (c = 0; c < P_SA2; c = c + 1) begin : drain
            assign result_out[c*AW +: AW] = c_mesh[P_SA1][c];
        end
    endgenerate

    // auxiliary engines (separate modules; algo selects the active path)
    //   algo = 0: im2col  — DLT streams Toeplitz into the Input Buffer
    //   algo = 1: kn2row  — Pad-and-Accumulate engages on result_out
    //   algo = 2: winograd — Linear Transform wraps act/wgt/result
endmodule
"#
    ));
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Compiler;
    use crate::dse::DseConfig;
    use crate::graph::zoo;

    #[test]
    fn emits_parameterized_top() {
        let compiler =
            Compiler::from_config(DseConfig::with_device(crate::cost::Device::small_edge()));
        let plan = compiler.compile(&zoo::mini_inception()).unwrap().into_plan();
        let v = overlay_top(&plan);
        assert!(v.contains("module dynamap_pe"));
        assert!(v.contains("module dynamap_overlay_top"));
        assert!(v.contains(&format!("parameter P_SA1 = {}", plan.p1)));
        assert!(v.contains(&format!("parameter P_SA2 = {}", plan.p2)));
        // balanced generate blocks
        assert_eq!(v.matches("endmodule").count(), 2);
    }
}
