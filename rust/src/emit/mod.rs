//! DSE steps ④–⑥: overlay customization output.
//!
//! The paper's DYNAMAP emits synthesizable Verilog parameterized by
//! `(P_SA1, P_SA2)` plus the control-signal sequences that drive the
//! DLT / Linear-Transform / Pad-and-Accumulate modules per layer. We
//! have no synthesis flow (DESIGN.md §Hardware-Adaptation), so this
//! module reproduces the *artifact shape*: a parameterized Verilog
//! top-level + PE ([`verilog`]) and the per-layer control-word stream
//! ([`control`]) the simulated overlay interprets; timing claims come
//! from the simulator, not from synthesis.

pub mod verilog;
pub mod control;

use std::path::{Path, PathBuf};

use crate::api::{Compiler, DynamapError};
use crate::dse::Plan;
use crate::graph::{zoo, Cnn};
use crate::util::cli::Args;

/// Write the overlay package (Verilog top-level + control stream) for a
/// compiled plan into `out_dir`; returns the two written paths.
pub fn emit_package(
    cnn: &Cnn,
    plan: &Plan,
    out_dir: &str,
) -> Result<(PathBuf, PathBuf), DynamapError> {
    std::fs::create_dir_all(out_dir).map_err(|e| DynamapError::io(out_dir, e))?;
    let v = verilog::overlay_top(plan);
    let c = control::control_stream(cnn, plan);
    let stem = crate::api::compiler::sanitize(&cnn.name);
    let vp = Path::new(out_dir).join(format!("dynamap_overlay_{stem}.v"));
    let cp = Path::new(out_dir).join(format!("control_{stem}.json"));
    std::fs::write(&vp, v).map_err(|e| DynamapError::io(&vp, e))?;
    std::fs::write(&cp, c.pretty()).map_err(|e| DynamapError::io(&cp, e))?;
    Ok((vp, cp))
}

/// `dynamap emit --model googlenet --out build/` — run DSE and write
/// the overlay package.
pub fn cli(args: &Args) -> i32 {
    let model = args.get_or("model", "googlenet");
    let out = args.get_or("out", "build");
    let Some(cnn) = zoo::by_name(model) else {
        eprintln!("unknown model '{model}'");
        return 1;
    };
    let artifact = match Compiler::new().compile(&cnn) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("emit: {e}");
            return 1;
        }
    };
    match emit_package(&cnn, &artifact.plan, out) {
        Ok((vp, cp)) => {
            println!(
                "wrote {} and {} (P_SA = {}×{})",
                vp.display(),
                cp.display(),
                artifact.plan.p1,
                artifact.plan.p2
            );
            0
        }
        Err(e) => {
            eprintln!("emit: {e}");
            1
        }
    }
}
