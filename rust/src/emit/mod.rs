//! DSE steps ④–⑥: overlay customization output.
//!
//! The paper's DYNAMAP emits synthesizable Verilog parameterized by
//! `(P_SA1, P_SA2)` plus the control-signal sequences that drive the
//! DLT / Linear-Transform / Pad-and-Accumulate modules per layer. We
//! have no synthesis flow (DESIGN.md §Hardware-Adaptation), so this
//! module reproduces the *artifact shape*: a parameterized Verilog
//! top-level + PE ([`verilog`]) and the per-layer control-word stream
//! ([`control`]) the simulated overlay interprets; timing claims come
//! from the simulator, not from synthesis.

pub mod verilog;
pub mod control;

use crate::dse::{Dse, DseConfig};
use crate::graph::zoo;
use crate::util::cli::Args;

/// `dynamap emit --model googlenet --out build/` — run DSE and write
/// the overlay package.
pub fn cli(args: &Args) -> i32 {
    let model = args.get_or("model", "googlenet");
    let out = args.get_or("out", "build");
    let Some(cnn) = zoo::by_name(model) else {
        eprintln!("unknown model '{model}'");
        return 1;
    };
    let dse = Dse::new(DseConfig::alveo_u200());
    let plan = dse.run(&cnn).unwrap();
    std::fs::create_dir_all(out).ok();
    let v = verilog::overlay_top(&plan);
    let c = control::control_stream(&cnn, &plan);
    let vp = format!("{out}/dynamap_overlay_{model}.v");
    let cp = format!("{out}/control_{model}.json");
    std::fs::write(&vp, v).expect("write verilog");
    std::fs::write(&cp, c.pretty()).expect("write control stream");
    println!("wrote {vp} and {cp} (P_SA = {}×{})", plan.p1, plan.p2);
    0
}
