//! The CNN DAG and its builder.

use super::layer::Op;
use std::collections::BTreeMap;

pub type NodeId = usize;

/// One node of the network graph.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: Op,
}

/// A CNN as a DAG of layers. Edges are `(src, dst)` pairs; the graph is
/// validated to be acyclic, single-input/single-output and
/// shape-consistent at build time.
#[derive(Debug, Clone)]
pub struct Cnn {
    pub name: String,
    pub nodes: Vec<Node>,
    pub edges: Vec<(NodeId, NodeId)>,
}

impl Cnn {
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn successors(&self, id: NodeId) -> Vec<NodeId> {
        self.edges.iter().filter(|(s, _)| *s == id).map(|(_, d)| *d).collect()
    }

    pub fn predecessors(&self, id: NodeId) -> Vec<NodeId> {
        self.edges.iter().filter(|(_, d)| *d == id).map(|(s, _)| *s).collect()
    }

    pub fn out_degree(&self, id: NodeId) -> usize {
        self.edges.iter().filter(|(s, _)| *s == id).count()
    }

    pub fn in_degree(&self, id: NodeId) -> usize {
        self.edges.iter().filter(|(_, d)| *d == id).count()
    }

    /// All convolution layers in topological order.
    pub fn conv_nodes(&self) -> Vec<NodeId> {
        self.topo_order()
            .into_iter()
            .filter(|&id| self.nodes[id].op.is_conv())
            .collect()
    }

    /// The unique input node.
    pub fn input(&self) -> NodeId {
        self.nodes
            .iter()
            .find(|n| matches!(n.op, Op::Input { .. }))
            .expect("graph has no input node")
            .id
    }

    /// The unique output node.
    pub fn output(&self) -> NodeId {
        self.nodes
            .iter()
            .find(|n| matches!(n.op, Op::Output))
            .expect("graph has no output node")
            .id
    }

    /// Kahn topological order; panics on cycles (graphs are validated at
    /// build time so this is an internal invariant).
    pub fn topo_order(&self) -> Vec<NodeId> {
        let mut indeg = vec![0usize; self.nodes.len()];
        for &(_, d) in &self.edges {
            indeg[d] += 1;
        }
        let mut queue: Vec<NodeId> =
            (0..self.nodes.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(id) = queue.pop() {
            order.push(id);
            for s in self.successors(id) {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push(s);
                }
            }
        }
        assert_eq!(order.len(), self.nodes.len(), "cycle in CNN graph '{}'", self.name);
        order
    }

    /// Total MACs over all conv layers (direct convolution accounting).
    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().filter_map(|n| n.op.conv()).map(|c| c.macs()).sum()
    }

    /// Total GOPs (2 × MACs / 1e9) — the paper quotes ~3 GOPs for
    /// GoogLeNet and ~9 GOPs for Inception-v4.
    pub fn total_gops(&self) -> f64 {
        self.total_macs() as f64 * 2.0 / 1e9
    }

    pub fn conv_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.op.is_conv()).count()
    }

    /// Validate shape consistency along every edge and basic structure.
    pub fn validate(&self) -> Result<(), String> {
        // structural checks
        let n_in = self.nodes.iter().filter(|n| matches!(n.op, Op::Input { .. })).count();
        let n_out = self.nodes.iter().filter(|n| matches!(n.op, Op::Output)).count();
        if n_in != 1 {
            return Err(format!("expected 1 input node, found {}", n_in));
        }
        if n_out != 1 {
            return Err(format!("expected 1 output node, found {}", n_out));
        }
        for &(s, d) in &self.edges {
            if s >= self.nodes.len() || d >= self.nodes.len() {
                return Err(format!("edge ({s},{d}) out of bounds"));
            }
        }
        // acyclicity (topo_order panics internally; replicate as check)
        let mut indeg = vec![0usize; self.nodes.len()];
        for &(_, d) in &self.edges {
            indeg[d] += 1;
        }
        let mut queue: Vec<NodeId> =
            (0..self.nodes.len()).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(id) = queue.pop() {
            seen += 1;
            for s in self.successors(id) {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push(s);
                }
            }
        }
        if seen != self.nodes.len() {
            return Err("cycle detected".into());
        }
        // per-edge shape consistency
        for &(s, d) in &self.edges {
            let (cs, h1s, h2s) = self.nodes[s].op.out_shape();
            let dst = &self.nodes[d].op;
            let err = |what: &str| {
                Err(format!(
                    "edge {} -> {}: {}",
                    self.nodes[s].name, self.nodes[d].name, what
                ))
            };
            match dst {
                Op::Conv(c) => {
                    if (c.c_in, c.h1, c.h2) != (cs, h1s, h2s) {
                        return err(&format!(
                            "conv expects ({},{},{}), got ({},{},{})",
                            c.c_in, c.h1, c.h2, cs, h1s, h2s
                        ));
                    }
                }
                Op::Pool(p) => {
                    if (p.c, p.h1, p.h2) != (cs, h1s, h2s) {
                        return err(&format!(
                            "pool expects ({},{},{}), got ({},{},{})",
                            p.c, p.h1, p.h2, cs, h1s, h2s
                        ));
                    }
                }
                Op::Concat { h1, h2, .. } => {
                    if (*h1, *h2) != (h1s, h2s) {
                        return err("concat spatial dims mismatch");
                    }
                }
                Op::Add { c, h1, h2 } => {
                    if (*c, *h1, *h2) != (cs, h1s, h2s) {
                        return err("add shape mismatch");
                    }
                }
                Op::Fc { c_in, .. } => {
                    if *c_in != cs * h1s * h2s && *c_in != cs {
                        return err(&format!(
                            "fc expects c_in {} but got {}x{}x{}",
                            c_in, cs, h1s, h2s
                        ));
                    }
                }
                Op::Output | Op::Input { .. } => {}
            }
        }
        // concat channel sums
        for n in &self.nodes {
            if let Op::Concat { c_out, .. } = n.op {
                let sum: usize = self
                    .predecessors(n.id)
                    .iter()
                    .map(|&p| self.nodes[p].op.out_shape().0)
                    .sum();
                if sum != c_out {
                    return Err(format!(
                        "concat '{}' expects {} channels, inputs sum to {}",
                        n.name, c_out, sum
                    ));
                }
            }
        }
        Ok(())
    }

    /// A compact multi-line summary (used by the `zoo` CLI subcommand).
    pub fn summary(&self) -> String {
        let mut by_kind: BTreeMap<&str, usize> = BTreeMap::new();
        for n in &self.nodes {
            *by_kind.entry(n.op.kind()).or_insert(0) += 1;
        }
        let kinds = by_kind
            .iter()
            .map(|(k, v)| format!("{k}:{v}"))
            .collect::<Vec<_>>()
            .join(" ");
        format!(
            "{}: {} nodes, {} edges, {} conv layers, {:.2} GOPs [{}]",
            self.name,
            self.nodes.len(),
            self.edges.len(),
            self.conv_count(),
            self.total_gops(),
            kinds
        )
    }
}

/// Incremental builder used by the model zoo. Tracks the running
/// `(channels, h1, h2)` shape so layers can be chained without repeating
/// dimensions, and validates the finished graph.
pub struct CnnBuilder {
    name: String,
    nodes: Vec<Node>,
    edges: Vec<(NodeId, NodeId)>,
}

impl CnnBuilder {
    pub fn new(name: &str) -> CnnBuilder {
        CnnBuilder { name: name.to_string(), nodes: Vec::new(), edges: Vec::new() }
    }

    /// Add a node with explicit predecessor list; returns its id.
    pub fn add(&mut self, name: &str, op: Op, preds: &[NodeId]) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { id, name: name.to_string(), op });
        for &p in preds {
            self.edges.push((p, id));
        }
        id
    }

    /// Shape of a node's output — used by chaining helpers.
    pub fn shape(&self, id: NodeId) -> (usize, usize, usize) {
        self.nodes[id].op.out_shape()
    }

    /// Chain a conv after `prev`, inferring `c_in/h1/h2` from `prev`.
    /// `pad` is `(p1, p2)`.
    pub fn conv(
        &mut self,
        name: &str,
        prev: NodeId,
        c_out: usize,
        k: (usize, usize),
        s: usize,
        pad: (usize, usize),
    ) -> NodeId {
        let (c, h1, h2) = self.shape(prev);
        let spec = super::layer::ConvSpec::new(c, c_out, h1, h2, k.0, k.1, s, pad.0, pad.1);
        self.add(name, Op::Conv(spec), &[prev])
    }

    /// Same-padded conv (odd kernels, stride 1).
    pub fn conv_same(
        &mut self,
        name: &str,
        prev: NodeId,
        c_out: usize,
        k: (usize, usize),
    ) -> NodeId {
        self.conv(name, prev, c_out, k, 1, (k.0 / 2, k.1 / 2))
    }

    pub fn pool(
        &mut self,
        name: &str,
        prev: NodeId,
        kind: super::layer::PoolKind,
        k: usize,
        s: usize,
        p: usize,
    ) -> NodeId {
        let (c, h1, h2) = self.shape(prev);
        self.add(
            name,
            Op::Pool(super::layer::PoolSpec { kind, c, h1, h2, k, s, p }),
            &[prev],
        )
    }

    pub fn concat(&mut self, name: &str, preds: &[NodeId]) -> NodeId {
        let (_, h1, h2) = self.shape(preds[0]);
        let c_out = preds.iter().map(|&p| self.shape(p).0).sum();
        self.add(name, Op::Concat { c_out, h1, h2 }, preds)
    }

    pub fn finish(mut self, input_c: usize, input_h: usize) -> Cnn {
        // if the caller forgot input/output nodes the zoo builders add
        // them; finish() only validates.
        let _ = (input_c, input_h);
        // append terminal Output node connected to all sinks (nodes with
        // no successors), unless one exists already.
        let has_output = self.nodes.iter().any(|n| matches!(n.op, Op::Output));
        if !has_output {
            let sinks: Vec<NodeId> = (0..self.nodes.len())
                .filter(|&i| !self.edges.iter().any(|(s, _)| *s == i))
                .collect();
            let id = self.nodes.len();
            self.nodes.push(Node { id, name: "output".into(), op: Op::Output });
            for s in sinks {
                self.edges.push((s, id));
            }
        }
        let cnn = Cnn { name: self.name, nodes: self.nodes, edges: self.edges };
        if let Err(e) = cnn.validate() {
            panic!("invalid CNN '{}': {}", cnn.name, e);
        }
        cnn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::{ConvSpec, PoolKind};

    fn tiny() -> Cnn {
        let mut b = CnnBuilder::new("tiny");
        let inp = b.add("in", Op::Input { c: 3, h1: 8, h2: 8 }, &[]);
        let c1 = b.conv_same("c1", inp, 8, (3, 3));
        let p = b.pool("p", c1, PoolKind::Max, 2, 2, 0);
        let c2 = b.conv_same("c2", p, 16, (1, 1));
        let _ = c2;
        b.finish(3, 8)
    }

    #[test]
    fn builder_chains_shapes() {
        let net = tiny();
        assert_eq!(net.conv_count(), 2);
        let convs = net.conv_nodes();
        let c2 = net.node(convs[1]).op.conv().unwrap();
        assert_eq!((c2.c_in, c2.h1, c2.h2), (8, 4, 4));
        net.validate().unwrap();
    }

    #[test]
    fn topo_order_is_valid() {
        let net = tiny();
        let order = net.topo_order();
        let pos: std::collections::HashMap<_, _> =
            order.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        for &(s, d) in &net.edges {
            assert!(pos[&s] < pos[&d], "edge {s}->{d} violates topo order");
        }
    }

    #[test]
    fn validate_catches_shape_mismatch() {
        let mut b = CnnBuilder::new("bad");
        let inp = b.add("in", Op::Input { c: 3, h1: 8, h2: 8 }, &[]);
        // conv expecting 4 channels after a 3-channel input
        let spec = ConvSpec::new(4, 8, 8, 8, 3, 3, 1, 1, 1);
        b.add("bad", Op::Conv(spec), &[inp]);
        let nodes = b.nodes;
        let edges = b.edges;
        let cnn = Cnn { name: "bad".into(), nodes, edges };
        assert!(cnn.validate().is_err());
    }

    #[test]
    fn concat_sums_channels() {
        let mut b = CnnBuilder::new("cat");
        let inp = b.add("in", Op::Input { c: 8, h1: 4, h2: 4 }, &[]);
        let a = b.conv_same("a", inp, 4, (1, 1));
        let c = b.conv_same("c", inp, 12, (3, 3));
        let cat = b.concat("cat", &[a, c]);
        assert_eq!(b.shape(cat).0, 16);
        let net = b.finish(8, 4);
        net.validate().unwrap();
    }

    #[test]
    fn gops_accounting() {
        let net = tiny();
        let manual: u64 = net
            .nodes
            .iter()
            .filter_map(|n| n.op.conv())
            .map(|c| c.macs())
            .sum();
        assert_eq!(net.total_macs(), manual);
    }
}
