//! Layer descriptors: convolution, pooling, concat, element-wise add, FC.

/// Meta data of one convolution layer (paper §2.1).
///
/// Input feature maps are `c_in` channels of `h1 × h2`; weights are
/// `c_in × c_out` kernels of `k1 × k2`; `s` is the stride and `(p1, p2)`
/// the symmetric zero padding applied along each spatial dimension.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvSpec {
    pub c_in: usize,
    pub c_out: usize,
    pub h1: usize,
    pub h2: usize,
    pub k1: usize,
    pub k2: usize,
    pub s: usize,
    pub p1: usize,
    pub p2: usize,
}

impl ConvSpec {
    /// Convenience constructor with "same" padding for stride-1 layers
    /// (odd kernels) and "valid" otherwise controllable via `p`.
    pub fn new(
        c_in: usize,
        c_out: usize,
        h1: usize,
        h2: usize,
        k1: usize,
        k2: usize,
        s: usize,
        p1: usize,
        p2: usize,
    ) -> ConvSpec {
        ConvSpec { c_in, c_out, h1, h2, k1, k2, s, p1, p2 }
    }

    /// Output height `O1 = ⌊(H1 + 2·p1 − K1)/s⌋ + 1`.
    pub fn o1(&self) -> usize {
        (self.h1 + 2 * self.p1 - self.k1) / self.s + 1
    }

    /// Output width `O2`.
    pub fn o2(&self) -> usize {
        (self.h2 + 2 * self.p2 - self.k2) / self.s + 1
    }

    /// Total multiply-accumulate operations of direct convolution —
    /// `Y_CONV` in Eq. 14 of the paper.
    pub fn macs(&self) -> u64 {
        self.o1() as u64
            * self.o2() as u64
            * self.k1 as u64
            * self.k2 as u64
            * self.c_in as u64
            * self.c_out as u64
    }

    /// 2 × MACs, the usual GOP accounting.
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Whether the Winograd family is applicable: square kernel of at
    /// least `r × r` and unit stride (paper §6.1.2: "layers with
    /// square-shaped kernels"; strided Winograd is listed as future work
    /// and implemented separately as an extension).
    pub fn winograd_applicable(&self, r: usize) -> bool {
        self.k1 == self.k2 && self.k1 >= r && self.s == 1
    }

    /// Number of weights.
    pub fn weight_count(&self) -> usize {
        self.c_in * self.c_out * self.k1 * self.k2
    }

    /// Number of input activations (unpadded).
    pub fn input_count(&self) -> usize {
        self.c_in * self.h1 * self.h2
    }

    /// Number of output activations.
    pub fn output_count(&self) -> usize {
        self.c_out * self.o1() * self.o2()
    }
}

/// Pooling flavor. AvgPool can be lowered to a convolution with a
/// constant `1/(K1·K2)` kernel (paper §3.4); MaxPool uses the dedicated
/// HPU/VPU pooling module.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// Pooling layer meta data.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolSpec {
    pub kind: PoolKind,
    pub c: usize,
    pub h1: usize,
    pub h2: usize,
    pub k: usize,
    pub s: usize,
    pub p: usize,
}

impl PoolSpec {
    pub fn o1(&self) -> usize {
        (self.h1 + 2 * self.p - self.k) / self.s + 1
    }
    pub fn o2(&self) -> usize {
        (self.h2 + 2 * self.p - self.k) / self.s + 1
    }
    /// AvgPool expressed as an equivalent depth-preserving conv (§3.4).
    pub fn as_conv(&self) -> ConvSpec {
        ConvSpec::new(self.c, self.c, self.h1, self.h2, self.k, self.k, self.s, self.p, self.p)
    }
}

/// A node in the CNN graph.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Network input: `c` channels of `h1 × h2`.
    Input { c: usize, h1: usize, h2: usize },
    Conv(ConvSpec),
    Pool(PoolSpec),
    /// Channel-wise filter concatenation (inception join).
    Concat { c_out: usize, h1: usize, h2: usize },
    /// Element-wise residual addition (ResNet join).
    Add { c: usize, h1: usize, h2: usize },
    /// Fully-connected layer, executed as a `1 × c_in → c_out` GEMM.
    Fc { c_in: usize, c_out: usize },
    Output,
}

impl Op {
    /// Output tensor shape `(channels, h1, h2)`; FC/Output flatten to
    /// `(c, 1, 1)`.
    pub fn out_shape(&self) -> (usize, usize, usize) {
        match self {
            Op::Input { c, h1, h2 } => (*c, *h1, *h2),
            Op::Conv(c) => (c.c_out, c.o1(), c.o2()),
            Op::Pool(p) => (p.c, p.o1(), p.o2()),
            Op::Concat { c_out, h1, h2 } => (*c_out, *h1, *h2),
            Op::Add { c, h1, h2 } => (*c, *h1, *h2),
            Op::Fc { c_out, .. } => (*c_out, 1, 1),
            Op::Output => (0, 0, 0),
        }
    }

    pub fn is_conv(&self) -> bool {
        matches!(self, Op::Conv(_))
    }

    pub fn conv(&self) -> Option<&ConvSpec> {
        match self {
            Op::Conv(c) => Some(c),
            _ => None,
        }
    }

    /// Human-readable op kind.
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input { .. } => "input",
            Op::Conv(_) => "conv",
            Op::Pool(p) => {
                if p.kind == PoolKind::Max {
                    "maxpool"
                } else {
                    "avgpool"
                }
            }
            Op::Concat { .. } => "concat",
            Op::Add { .. } => "add",
            Op::Fc { .. } => "fc",
            Op::Output => "output",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_dims() {
        // 224×224, 7×7 stride 2 pad 3 → 112×112 (GoogLeNet conv1)
        let c = ConvSpec::new(3, 64, 224, 224, 7, 7, 2, 3, 3);
        assert_eq!((c.o1(), c.o2()), (112, 112));
        // same-padded 3×3 stride 1 keeps dims
        let c = ConvSpec::new(16, 32, 28, 28, 3, 3, 1, 1, 1);
        assert_eq!((c.o1(), c.o2()), (28, 28));
        // valid 3×3 stride 2 on 299 → 149 (Inception-v4 stem)
        let c = ConvSpec::new(3, 32, 299, 299, 3, 3, 2, 0, 0);
        assert_eq!((c.o1(), c.o2()), (149, 149));
    }

    #[test]
    fn macs_counts() {
        let c = ConvSpec::new(2, 4, 8, 8, 3, 3, 1, 1, 1);
        assert_eq!(c.macs(), 8 * 8 * 3 * 3 * 2 * 4);
        assert_eq!(c.ops(), 2 * c.macs());
    }

    #[test]
    fn winograd_applicability() {
        assert!(ConvSpec::new(1, 1, 8, 8, 3, 3, 1, 1, 1).winograd_applicable(3));
        assert!(ConvSpec::new(1, 1, 8, 8, 5, 5, 1, 2, 2).winograd_applicable(3));
        assert!(!ConvSpec::new(1, 1, 8, 8, 1, 1, 1, 0, 0).winograd_applicable(3));
        assert!(!ConvSpec::new(1, 1, 8, 8, 7, 1, 1, 3, 0).winograd_applicable(3));
        assert!(!ConvSpec::new(1, 1, 8, 8, 3, 3, 2, 1, 1).winograd_applicable(3));
    }

    #[test]
    fn avgpool_as_conv_preserves_dims() {
        let p = PoolSpec { kind: PoolKind::Avg, c: 32, h1: 8, h2: 8, k: 3, s: 1, p: 1 };
        let c = p.as_conv();
        assert_eq!((c.o1(), c.o2()), (p.o1(), p.o2()));
        assert_eq!(c.c_in, c.c_out);
    }
}
