//! Classic chain / skip-connection CNNs used by Lemma 4.3 (VGG, AlexNet,
//! ResNet are series-parallel) and as additional DSE workloads.

use crate::graph::layer::{Op, PoolKind};
use crate::graph::{Cnn, CnnBuilder, NodeId};

/// VGG-16 (configuration D) for 224×224×3 input. A pure chain.
pub fn vgg16() -> Cnn {
    let mut b = CnnBuilder::new("vgg16");
    let inp = b.add("input", Op::Input { c: 3, h1: 224, h2: 224 }, &[]);
    let blocks: &[(usize, usize)] = &[(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)];
    let mut cur = inp;
    for (bi, &(n, c)) in blocks.iter().enumerate() {
        for li in 0..n {
            cur = b.conv_same(&format!("conv{}_{}", bi + 1, li + 1), cur, c, (3, 3));
        }
        cur = b.pool(&format!("pool{}", bi + 1), cur, PoolKind::Max, 2, 2, 0);
    }
    let (c, h1, h2) = b.shape(cur);
    let f1 = b.add("fc6", Op::Fc { c_in: c * h1 * h2, c_out: 4096 }, &[cur]);
    let f2 = b.add("fc7", Op::Fc { c_in: 4096, c_out: 4096 }, &[f1]);
    b.add("fc8", Op::Fc { c_in: 4096, c_out: 1000 }, &[f2]);
    b.finish(3, 224)
}

/// AlexNet (single-tower variant) for 227×227×3 input. A pure chain.
pub fn alexnet() -> Cnn {
    let mut b = CnnBuilder::new("alexnet");
    let inp = b.add("input", Op::Input { c: 3, h1: 227, h2: 227 }, &[]);
    let c1 = b.conv("conv1", inp, 96, (11, 11), 4, (0, 0));
    let p1 = b.pool("pool1", c1, PoolKind::Max, 3, 2, 0);
    let c2 = b.conv_same("conv2", p1, 256, (5, 5));
    let p2 = b.pool("pool2", c2, PoolKind::Max, 3, 2, 0);
    let c3 = b.conv_same("conv3", p2, 384, (3, 3));
    let c4 = b.conv_same("conv4", c3, 384, (3, 3));
    let c5 = b.conv_same("conv5", c4, 256, (3, 3));
    let p5 = b.pool("pool5", c5, PoolKind::Max, 3, 2, 0);
    let (c, h1, h2) = b.shape(p5);
    let f1 = b.add("fc6", Op::Fc { c_in: c * h1 * h2, c_out: 4096 }, &[p5]);
    let f2 = b.add("fc7", Op::Fc { c_in: 4096, c_out: 4096 }, &[f1]);
    b.add("fc8", Op::Fc { c_in: 4096, c_out: 1000 }, &[f2]);
    b.finish(3, 227)
}

/// One basic residual block (two 3×3 convs + skip). When `down` is set,
/// the first conv has stride 2 and the skip is a 1×1/2 projection.
fn basic_block(
    b: &mut CnnBuilder,
    prev: NodeId,
    name: &str,
    c_out: usize,
    down: bool,
) -> NodeId {
    let s = if down { 2 } else { 1 };
    let c1 = b.conv(&format!("{name}/conv1"), prev, c_out, (3, 3), s, (1, 1));
    let c2 = b.conv_same(&format!("{name}/conv2"), c1, c_out, (3, 3));
    let skip = if down || b.shape(prev).0 != c_out {
        b.conv(&format!("{name}/proj"), prev, c_out, (1, 1), s, (0, 0))
    } else {
        prev
    };
    let (c, h1, h2) = b.shape(c2);
    b.add(&format!("{name}/add"), Op::Add { c, h1, h2 }, &[c2, skip])
}

/// ResNet-18 for 224×224×3 input. Skip connections make this the
/// parallel-edge case of the series-parallel reduction (Lemma 4.3).
pub fn resnet18() -> Cnn {
    let mut b = CnnBuilder::new("resnet18");
    let inp = b.add("input", Op::Input { c: 3, h1: 224, h2: 224 }, &[]);
    let c1 = b.conv("conv1", inp, 64, (7, 7), 2, (3, 3));
    let mut cur = b.pool("pool1", c1, PoolKind::Max, 3, 2, 1);
    let stages: &[(usize, usize, bool)] =
        &[(64, 2, false), (128, 2, true), (256, 2, true), (512, 2, true)];
    for (si, &(c, n, down_first)) in stages.iter().enumerate() {
        for bi in 0..n {
            let down = down_first && bi == 0;
            cur = basic_block(&mut b, cur, &format!("layer{}_{}", si + 1, bi + 1), c, down);
        }
    }
    let gap = b.pool("avgpool", cur, PoolKind::Avg, 7, 1, 0);
    let (c, h1, h2) = b.shape(gap);
    b.add("fc", Op::Fc { c_in: c * h1 * h2, c_out: 1000 }, &[gap]);
    b.finish(3, 224)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_shape_chain() {
        let g = vgg16();
        g.validate().unwrap();
        assert_eq!(g.conv_count(), 13);
        // every conv node in a chain has in/out degree 1
        for id in g.conv_nodes() {
            assert_eq!(g.in_degree(id), 1);
            assert_eq!(g.out_degree(id), 1);
        }
    }

    #[test]
    fn alexnet_dims() {
        let g = alexnet();
        g.validate().unwrap();
        assert_eq!(g.conv_count(), 5);
        let c1 = g.nodes.iter().find(|n| n.name == "conv1").unwrap();
        assert_eq!(c1.op.out_shape(), (96, 55, 55));
    }

    #[test]
    fn resnet18_has_skips() {
        let g = resnet18();
        g.validate().unwrap();
        // 1 stem + 8 blocks × 2 convs + 3 projections = 20 convs
        assert_eq!(g.conv_count(), 20);
        let adds = g.nodes.iter().filter(|n| matches!(n.op, Op::Add { .. })).count();
        assert_eq!(adds, 8);
    }
}
