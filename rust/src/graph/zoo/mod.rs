//! Model zoo: the networks the paper evaluates (GoogLeNet, Inception-v4),
//! the series-parallel lemma examples (VGG-16, AlexNet, ResNet-18 —
//! Lemma 4.3/4.4), `mini_inception`, the small network used for
//! functional end-to-end validation through the PJRT runtime, and
//! `mini_vgg`, its sequential sibling for multi-model serving tests.

mod googlenet;
mod inception_v4;
mod classic;
mod mini;

pub use classic::{alexnet, resnet18, vgg16};
pub use googlenet::googlenet;
pub use inception_v4::inception_v4;
pub use mini::{mini_inception, mini_vgg, MINI_INPUT_C, MINI_INPUT_H};

use super::Cnn;

/// Canonical zoo name for any accepted alias, without building the
/// model — cheap enough for per-request paths (the serving registry
/// canonicalizes every lookup through this).
pub fn canonical_name(name: &str) -> Option<&'static str> {
    match name {
        "googlenet" => Some("googlenet"),
        "inception-v4" | "inception_v4" | "inceptionv4" => Some("inception-v4"),
        "vgg16" | "vgg-16" => Some("vgg16"),
        "alexnet" => Some("alexnet"),
        "resnet18" | "resnet-18" => Some("resnet18"),
        "mini" | "mini-inception" | "mini_inception" => Some("mini-inception"),
        "mini-vgg" | "mini_vgg" | "minivgg" => Some("mini-vgg"),
        _ => None,
    }
}

/// Look up a zoo model by name (any alias [`canonical_name`] accepts).
pub fn by_name(name: &str) -> Option<Cnn> {
    match canonical_name(name)? {
        "googlenet" => Some(googlenet()),
        "inception-v4" => Some(inception_v4()),
        "vgg16" => Some(vgg16()),
        "alexnet" => Some(alexnet()),
        "resnet18" => Some(resnet18()),
        "mini-inception" => Some(mini_inception()),
        "mini-vgg" => Some(mini_vgg()),
        _ => None,
    }
}

/// All zoo model names.
pub fn names() -> &'static [&'static str] {
    &["googlenet", "inception-v4", "vgg16", "alexnet", "resnet18", "mini-inception", "mini-vgg"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_validate() {
        for name in names() {
            let net = by_name(name).unwrap();
            net.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn googlenet_stats() {
        let g = googlenet();
        // 57 conv layers (2 stem + 1 reduce + 9 modules × 6 convs)
        assert_eq!(g.conv_count(), 57);
        // ~3 GOPs (paper §6.2 quotes ~3 GOPs)
        let gops = g.total_gops();
        assert!((2.0..4.5).contains(&gops), "googlenet gops = {gops}");
    }

    #[test]
    fn inception_v4_stats() {
        let g = inception_v4();
        // paper quotes 141 CONV layers; canonical per-conv counting of the
        // published architecture gives 149 (see inception_v4.rs test).
        let n = g.conv_count();
        assert!((140..=150).contains(&n), "inception-v4 conv count = {n}");
        let gops = g.total_gops();
        // paper §6.2 loosely quotes "~9 GOPS"; the canonical architecture
        // is 12.3 GMACs = 24.6 GOPs (2 ops/MAC) — we assert the canonical
        // number and use the paper's constants verbatim only inside the
        // FlexCNN projection bench.
        assert!((20.0..28.0).contains(&gops), "inception-v4 gops = {gops}");
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("nope").is_none());
        assert!(canonical_name("nope").is_none());
    }

    #[test]
    fn canonical_name_agrees_with_built_model() {
        for alias in ["mini", "mini_inception", "inception_v4", "vgg-16", "minivgg"] {
            let canonical = canonical_name(alias).unwrap();
            assert_eq!(by_name(alias).unwrap().name, canonical, "{alias}");
        }
        for name in names() {
            assert_eq!(canonical_name(name), Some(*name), "canonical names are fixed points");
        }
    }
}
