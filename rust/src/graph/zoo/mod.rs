//! Model zoo: the networks the paper evaluates (GoogLeNet, Inception-v4),
//! the series-parallel lemma examples (VGG-16, AlexNet, ResNet-18 —
//! Lemma 4.3/4.4) and `mini_inception`, the small network used for
//! functional end-to-end validation through the PJRT runtime.

mod googlenet;
mod inception_v4;
mod classic;
mod mini;

pub use classic::{alexnet, resnet18, vgg16};
pub use googlenet::googlenet;
pub use inception_v4::inception_v4;
pub use mini::{mini_inception, MINI_INPUT_C, MINI_INPUT_H};

use super::Cnn;

/// Look up a zoo model by name.
pub fn by_name(name: &str) -> Option<Cnn> {
    match name {
        "googlenet" => Some(googlenet()),
        "inception-v4" | "inception_v4" | "inceptionv4" => Some(inception_v4()),
        "vgg16" | "vgg-16" => Some(vgg16()),
        "alexnet" => Some(alexnet()),
        "resnet18" | "resnet-18" => Some(resnet18()),
        "mini" | "mini-inception" | "mini_inception" => Some(mini_inception()),
        _ => None,
    }
}

/// All zoo model names.
pub fn names() -> &'static [&'static str] {
    &["googlenet", "inception-v4", "vgg16", "alexnet", "resnet18", "mini-inception"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_validate() {
        for name in names() {
            let net = by_name(name).unwrap();
            net.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn googlenet_stats() {
        let g = googlenet();
        // 57 conv layers (2 stem + 1 reduce + 9 modules × 6 convs)
        assert_eq!(g.conv_count(), 57);
        // ~3 GOPs (paper §6.2 quotes ~3 GOPs)
        let gops = g.total_gops();
        assert!((2.0..4.5).contains(&gops), "googlenet gops = {gops}");
    }

    #[test]
    fn inception_v4_stats() {
        let g = inception_v4();
        // paper quotes 141 CONV layers; canonical per-conv counting of the
        // published architecture gives 149 (see inception_v4.rs test).
        let n = g.conv_count();
        assert!((140..=150).contains(&n), "inception-v4 conv count = {n}");
        let gops = g.total_gops();
        // paper §6.2 loosely quotes "~9 GOPS"; the canonical architecture
        // is 12.3 GMACs = 24.6 GOPs (2 ops/MAC) — we assert the canonical
        // number and use the paper's constants verbatim only inside the
        // FlexCNN projection bench.
        assert!((20.0..28.0).contains(&gops), "inception-v4 gops = {gops}");
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("nope").is_none());
    }
}
