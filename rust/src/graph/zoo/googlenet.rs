//! GoogLeNet (Inception-v1, Szegedy et al. 2015) for 224×224×3 input.
//!
//! 9 inception modules of 6 conv layers each, plus the 3 stem convs —
//! 57 convolution layers total. Channel counts follow Table 1 of the
//! GoogLeNet paper.

use crate::graph::layer::{Op, PoolKind};
use crate::graph::{Cnn, CnnBuilder, NodeId};

/// One inception module: `(#1×1, #3×3 reduce, #3×3, #5×5 reduce, #5×5,
/// pool proj)`.
struct Inception {
    name: &'static str,
    b1: usize,
    b2r: usize,
    b2: usize,
    b3r: usize,
    b3: usize,
    b4: usize,
}

fn inception(b: &mut CnnBuilder, prev: NodeId, m: &Inception) -> NodeId {
    let n = m.name;
    let b1 = b.conv_same(&format!("{n}/1x1"), prev, m.b1, (1, 1));
    let b2r = b.conv_same(&format!("{n}/3x3_reduce"), prev, m.b2r, (1, 1));
    let b2 = b.conv_same(&format!("{n}/3x3"), b2r, m.b2, (3, 3));
    let b3r = b.conv_same(&format!("{n}/5x5_reduce"), prev, m.b3r, (1, 1));
    let b3 = b.conv_same(&format!("{n}/5x5"), b3r, m.b3, (5, 5));
    let p = b.pool(&format!("{n}/pool"), prev, PoolKind::Max, 3, 1, 1);
    let b4 = b.conv_same(&format!("{n}/pool_proj"), p, m.b4, (1, 1));
    b.concat(&format!("{n}/concat"), &[b1, b2, b3, b4])
}

/// Build the full GoogLeNet graph.
pub fn googlenet() -> Cnn {
    let mut b = CnnBuilder::new("googlenet");
    let inp = b.add("input", Op::Input { c: 3, h1: 224, h2: 224 }, &[]);

    // stem
    let c1 = b.conv("conv1/7x7_s2", inp, 64, (7, 7), 2, (3, 3));
    let p1 = b.pool("pool1/3x3_s2", c1, PoolKind::Max, 3, 2, 1);
    let c2r = b.conv_same("conv2/3x3_reduce", p1, 64, (1, 1));
    let c2 = b.conv_same("conv2/3x3", c2r, 192, (3, 3));
    let p2 = b.pool("pool2/3x3_s2", c2, PoolKind::Max, 3, 2, 1);

    const MODS_3: [Inception; 2] = [
        Inception { name: "inception_3a", b1: 64, b2r: 96, b2: 128, b3r: 16, b3: 32, b4: 32 },
        Inception { name: "inception_3b", b1: 128, b2r: 128, b2: 192, b3r: 32, b3: 96, b4: 64 },
    ];
    const MODS_4: [Inception; 5] = [
        Inception { name: "inception_4a", b1: 192, b2r: 96, b2: 208, b3r: 16, b3: 48, b4: 64 },
        Inception { name: "inception_4b", b1: 160, b2r: 112, b2: 224, b3r: 24, b3: 64, b4: 64 },
        Inception { name: "inception_4c", b1: 128, b2r: 128, b2: 256, b3r: 24, b3: 64, b4: 64 },
        Inception { name: "inception_4d", b1: 112, b2r: 144, b2: 288, b3r: 32, b3: 64, b4: 64 },
        Inception { name: "inception_4e", b1: 256, b2r: 160, b2: 320, b3r: 32, b3: 128, b4: 128 },
    ];
    const MODS_5: [Inception; 2] = [
        Inception { name: "inception_5a", b1: 256, b2r: 160, b2: 320, b3r: 32, b3: 128, b4: 128 },
        Inception { name: "inception_5b", b1: 384, b2r: 192, b2: 384, b3r: 48, b3: 128, b4: 128 },
    ];

    let mut cur = p2;
    for m in &MODS_3 {
        cur = inception(&mut b, cur, m);
    }
    cur = b.pool("pool3/3x3_s2", cur, PoolKind::Max, 3, 2, 1);
    for m in &MODS_4 {
        cur = inception(&mut b, cur, m);
    }
    cur = b.pool("pool4/3x3_s2", cur, PoolKind::Max, 3, 2, 1);
    for m in &MODS_5 {
        cur = inception(&mut b, cur, m);
    }
    let gap = b.pool("pool5/7x7_s1", cur, PoolKind::Avg, 7, 1, 0);
    let (c, h1, h2) = b.shape(gap);
    b.add("loss3/classifier", Op::Fc { c_in: c * h1 * h2, c_out: 1000 }, &[gap]);
    b.finish(3, 224)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let g = googlenet();
        g.validate().unwrap();
        assert_eq!(g.conv_count(), 57);
        // final concat produces 1024 channels at 7×7
        let fc = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Op::Fc { .. }))
            .unwrap();
        if let Op::Fc { c_in, c_out } = fc.op {
            assert_eq!(c_in, 1024);
            assert_eq!(c_out, 1000);
        }
    }

    #[test]
    fn module_channel_sums() {
        // inception_3a output = 64+128+32+32 = 256
        let g = googlenet();
        let cat = g
            .nodes
            .iter()
            .find(|n| n.name == "inception_3a/concat")
            .unwrap();
        assert_eq!(cat.op.out_shape().0, 256);
        // 3a operates at 28×28
        assert_eq!(cat.op.out_shape().1, 28);
    }

    #[test]
    fn spatial_pyramid() {
        let g = googlenet();
        let at = |name: &str| {
            g.nodes.iter().find(|n| n.name == name).unwrap().op.out_shape()
        };
        assert_eq!(at("conv1/7x7_s2"), (64, 112, 112));
        assert_eq!(at("pool2/3x3_s2").1, 28);
        assert_eq!(at("pool3/3x3_s2").1, 14);
        assert_eq!(at("pool4/3x3_s2").1, 7);
    }
}
