//! MiniInception — the small inception-style network used for functional
//! end-to-end validation through the PJRT runtime — and MiniVgg, its
//! sequential sibling for multi-model serving tests.
//!
//! Shapes are deliberately tiny (16×16 input, ≤32 channels) so the
//! interpret-mode Pallas kernels lower and execute quickly on the CPU
//! PJRT client, while still exercising every structural feature the
//! DYNAMAP flow must handle: a stem conv, an inception-style branch/concat
//! module with 1×1 / 3×3 / 5×5 kernels (so all three algorithm families
//! are applicable somewhere), max pooling and a 1×1 head.
//!
//! The layer shapes here must stay in sync with
//! `python/compile/model.py::MINI_LAYERS` — the AOT artifact manifest is
//! keyed by the conv names below.

use crate::graph::layer::{Op, PoolKind};
use crate::graph::Cnn;
use crate::graph::CnnBuilder;

pub const MINI_INPUT_C: usize = 4;
pub const MINI_INPUT_H: usize = 16;

/// Build MiniInception. Conv names are the artifact-manifest keys.
pub fn mini_inception() -> Cnn {
    let mut b = CnnBuilder::new("mini-inception");
    let inp = b.add(
        "input",
        Op::Input { c: MINI_INPUT_C, h1: MINI_INPUT_H, h2: MINI_INPUT_H },
        &[],
    );
    // stem: 3×3 same conv, 4→8 channels @16×16
    let stem = b.conv_same("stem", inp, 8, (3, 3));
    // inception module @16×16, in 8
    let b1 = b.conv_same("inc/b1_1x1", stem, 8, (1, 1));
    let b2r = b.conv_same("inc/b2_reduce", stem, 4, (1, 1));
    let b2 = b.conv_same("inc/b2_3x3", b2r, 8, (3, 3));
    let b3r = b.conv_same("inc/b3_reduce", stem, 4, (1, 1));
    let b3 = b.conv_same("inc/b3_5x5", b3r, 8, (5, 5));
    let cat = b.concat("inc/concat", &[b1, b2, b3]);
    // reduce: maxpool /2 → 8×8
    let pool = b.pool("pool", cat, PoolKind::Max, 2, 2, 0);
    // head: 1×1 conv 24→16 @8×8
    let head = b.conv_same("head", pool, 16, (1, 1));
    let _ = head;
    b.finish(MINI_INPUT_C, MINI_INPUT_H)
}

/// Build MiniVgg — a tiny sequential conv→pool tower with a 10-way FC
/// head. The cheap *second* model for multi-model serving tests and
/// demos: distinct input shape from mini-inception (3 vs 4 channels),
/// a global-average-pool + FC tail (so the native FC-as-1×1-conv path
/// is exercised without a full-size network), and a few thousand MACs
/// end to end, fast even in debug builds.
pub fn mini_vgg() -> Cnn {
    let mut b = CnnBuilder::new("mini-vgg");
    let inp = b.add("input", Op::Input { c: 3, h1: 16, h2: 16 }, &[]);
    let c1 = b.conv_same("conv1", inp, 8, (3, 3));
    let p1 = b.pool("pool1", c1, PoolKind::Max, 2, 2, 0); // → 8×8
    let c2 = b.conv_same("conv2", p1, 16, (3, 3));
    let p2 = b.pool("pool2", c2, PoolKind::Max, 2, 2, 0); // → 4×4
    let c3 = b.conv_same("conv3", p2, 16, (1, 1));
    let gap = b.pool("gap", c3, PoolKind::Avg, 4, 1, 0); // → 1×1
    let (c, h1, h2) = b.shape(gap);
    b.add("fc", Op::Fc { c_in: c * h1 * h2, c_out: 10 }, &[gap]);
    b.finish(3, 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let g = mini_inception();
        g.validate().unwrap();
        assert_eq!(g.conv_count(), 7);
        let cat = g.nodes.iter().find(|n| n.name == "inc/concat").unwrap();
        assert_eq!(cat.op.out_shape(), (24, 16, 16));
        let head = g.nodes.iter().find(|n| n.name == "head").unwrap();
        assert_eq!(head.op.out_shape(), (16, 8, 8));
    }

    #[test]
    fn mini_vgg_structure() {
        let g = mini_vgg();
        g.validate().unwrap();
        assert_eq!(g.conv_count(), 3);
        let gap = g.nodes.iter().find(|n| n.name == "gap").unwrap();
        assert_eq!(gap.op.out_shape(), (16, 1, 1));
        let fc = g.nodes.iter().find(|n| n.name == "fc").unwrap();
        assert!(matches!(fc.op, Op::Fc { c_in: 16, c_out: 10 }));
    }

    #[test]
    fn all_algorithms_applicable_somewhere() {
        let g = mini_inception();
        // at least one layer where winograd applies (3×3, stride 1)
        assert!(g
            .nodes
            .iter()
            .filter_map(|n| n.op.conv())
            .any(|c| c.winograd_applicable(3)));
    }
}
