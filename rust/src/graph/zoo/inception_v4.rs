//! Inception-v4 (Szegedy et al. 2016, "Inception-v4, Inception-ResNet and
//! the Impact of Residual Connections on Learning") for 299×299×3 input.
//!
//! Stem + 4×Inception-A + Reduction-A + 7×Inception-B + Reduction-B +
//! 3×Inception-C — 140 convolution layers. Kernel shapes include the
//! 1×7/7×1 and 1×3/3×1 factorized convolutions the paper highlights as
//! memory-bound (§6.1.2: "a large portion of the kernels are shaped
//! 7(3)×1, making such layers more memory-bound").

use crate::graph::layer::{Op, PoolKind};
use crate::graph::{Cnn, CnnBuilder, NodeId};

fn stem(b: &mut CnnBuilder, inp: NodeId) -> NodeId {
    // 299×299×3 → 149×149×32 → 147×147×32 → 147×147×64
    let c1 = b.conv("stem/conv1_3x3_s2v", inp, 32, (3, 3), 2, (0, 0));
    let c2 = b.conv("stem/conv2_3x3_v", c1, 32, (3, 3), 1, (0, 0));
    let c3 = b.conv_same("stem/conv3_3x3", c2, 64, (3, 3));
    // split 1: maxpool ‖ conv 3×3/2 v (96) → concat 160 @73
    let p1 = b.pool("stem/pool1_3x3_s2v", c3, PoolKind::Max, 3, 2, 0);
    let c4 = b.conv("stem/conv4_3x3_s2v", c3, 96, (3, 3), 2, (0, 0));
    let cat1 = b.concat("stem/concat1", &[p1, c4]);
    // split 2: (1×1 64 → 3×3 v 96) ‖ (1×1 64 → 7×1 64 → 1×7 64 → 3×3 v 96)
    let a1 = b.conv_same("stem/brA_1x1", cat1, 64, (1, 1));
    let a2 = b.conv("stem/brA_3x3_v", a1, 96, (3, 3), 1, (0, 0));
    let b1 = b.conv_same("stem/brB_1x1", cat1, 64, (1, 1));
    let b2 = b.conv_same("stem/brB_7x1", b1, 64, (7, 1));
    let b3 = b.conv_same("stem/brB_1x7", b2, 64, (1, 7));
    let b4 = b.conv("stem/brB_3x3_v", b3, 96, (3, 3), 1, (0, 0));
    let cat2 = b.concat("stem/concat2", &[a2, b4]);
    // split 3: conv 3×3/2 v (192) ‖ maxpool → concat 384 @35
    let c5 = b.conv("stem/conv5_3x3_s2v", cat2, 192, (3, 3), 2, (0, 0));
    let p2 = b.pool("stem/pool2_3x3_s2v", cat2, PoolKind::Max, 3, 2, 0);
    b.concat("stem/concat3", &[c5, p2])
}

fn inception_a(b: &mut CnnBuilder, prev: NodeId, idx: usize) -> NodeId {
    let n = format!("inception_a{idx}");
    let p = b.pool(&format!("{n}/avgpool"), prev, PoolKind::Avg, 3, 1, 1);
    let br1 = b.conv_same(&format!("{n}/b1_1x1"), p, 96, (1, 1));
    let br2 = b.conv_same(&format!("{n}/b2_1x1"), prev, 96, (1, 1));
    let br3a = b.conv_same(&format!("{n}/b3_1x1"), prev, 64, (1, 1));
    let br3 = b.conv_same(&format!("{n}/b3_3x3"), br3a, 96, (3, 3));
    let br4a = b.conv_same(&format!("{n}/b4_1x1"), prev, 64, (1, 1));
    let br4b = b.conv_same(&format!("{n}/b4_3x3a"), br4a, 96, (3, 3));
    let br4 = b.conv_same(&format!("{n}/b4_3x3b"), br4b, 96, (3, 3));
    b.concat(&format!("{n}/concat"), &[br1, br2, br3, br4])
}

fn reduction_a(b: &mut CnnBuilder, prev: NodeId) -> NodeId {
    // 35×35×384 → 17×17×1024
    let p = b.pool("reduction_a/pool", prev, PoolKind::Max, 3, 2, 0);
    let br2 = b.conv("reduction_a/b2_3x3_s2v", prev, 384, (3, 3), 2, (0, 0));
    let br3a = b.conv_same("reduction_a/b3_1x1", prev, 192, (1, 1));
    let br3b = b.conv_same("reduction_a/b3_3x3", br3a, 224, (3, 3));
    let br3 = b.conv("reduction_a/b3_3x3_s2v", br3b, 256, (3, 3), 2, (0, 0));
    b.concat("reduction_a/concat", &[p, br2, br3])
}

fn inception_b(b: &mut CnnBuilder, prev: NodeId, idx: usize) -> NodeId {
    let n = format!("inception_b{idx}");
    let p = b.pool(&format!("{n}/avgpool"), prev, PoolKind::Avg, 3, 1, 1);
    let br1 = b.conv_same(&format!("{n}/b1_1x1"), p, 128, (1, 1));
    let br2 = b.conv_same(&format!("{n}/b2_1x1"), prev, 384, (1, 1));
    let br3a = b.conv_same(&format!("{n}/b3_1x1"), prev, 192, (1, 1));
    let br3b = b.conv_same(&format!("{n}/b3_1x7"), br3a, 224, (1, 7));
    let br3 = b.conv_same(&format!("{n}/b3_7x1"), br3b, 256, (7, 1));
    let br4a = b.conv_same(&format!("{n}/b4_1x1"), prev, 192, (1, 1));
    let br4b = b.conv_same(&format!("{n}/b4_1x7a"), br4a, 192, (1, 7));
    let br4c = b.conv_same(&format!("{n}/b4_7x1a"), br4b, 224, (7, 1));
    let br4d = b.conv_same(&format!("{n}/b4_1x7b"), br4c, 224, (1, 7));
    let br4 = b.conv_same(&format!("{n}/b4_7x1b"), br4d, 256, (7, 1));
    b.concat(&format!("{n}/concat"), &[br1, br2, br3, br4])
}

fn reduction_b(b: &mut CnnBuilder, prev: NodeId) -> NodeId {
    // 17×17×1024 → 8×8×1536
    let p = b.pool("reduction_b/pool", prev, PoolKind::Max, 3, 2, 0);
    let br2a = b.conv_same("reduction_b/b2_1x1", prev, 192, (1, 1));
    let br2 = b.conv("reduction_b/b2_3x3_s2v", br2a, 192, (3, 3), 2, (0, 0));
    let br3a = b.conv_same("reduction_b/b3_1x1", prev, 256, (1, 1));
    let br3b = b.conv_same("reduction_b/b3_1x7", br3a, 256, (1, 7));
    let br3c = b.conv_same("reduction_b/b3_7x1", br3b, 320, (7, 1));
    let br3 = b.conv("reduction_b/b3_3x3_s2v", br3c, 320, (3, 3), 2, (0, 0));
    b.concat("reduction_b/concat", &[p, br2, br3])
}

fn inception_c(b: &mut CnnBuilder, prev: NodeId, idx: usize) -> NodeId {
    let n = format!("inception_c{idx}");
    let p = b.pool(&format!("{n}/avgpool"), prev, PoolKind::Avg, 3, 1, 1);
    let br1 = b.conv_same(&format!("{n}/b1_1x1"), p, 256, (1, 1));
    let br2 = b.conv_same(&format!("{n}/b2_1x1"), prev, 256, (1, 1));
    // branch 3: 1×1 384 → {1×3 256 ‖ 3×1 256}
    let br3a = b.conv_same(&format!("{n}/b3_1x1"), prev, 384, (1, 1));
    let br3l = b.conv_same(&format!("{n}/b3_1x3"), br3a, 256, (1, 3));
    let br3r = b.conv_same(&format!("{n}/b3_3x1"), br3a, 256, (3, 1));
    // branch 4: 1×1 384 → 1×3 448 → 3×1 512 → {3×1 256 ‖ 1×3 256}
    let br4a = b.conv_same(&format!("{n}/b4_1x1"), prev, 384, (1, 1));
    let br4b = b.conv_same(&format!("{n}/b4_1x3"), br4a, 448, (1, 3));
    let br4c = b.conv_same(&format!("{n}/b4_3x1"), br4b, 512, (3, 1));
    let br4l = b.conv_same(&format!("{n}/b4_3x1b"), br4c, 256, (3, 1));
    let br4r = b.conv_same(&format!("{n}/b4_1x3b"), br4c, 256, (1, 3));
    b.concat(&format!("{n}/concat"), &[br1, br2, br3l, br3r, br4l, br4r])
}

/// Build the full Inception-v4 graph.
pub fn inception_v4() -> Cnn {
    let mut b = CnnBuilder::new("inception-v4");
    let inp = b.add("input", Op::Input { c: 3, h1: 299, h2: 299 }, &[]);
    let mut cur = stem(&mut b, inp);
    for i in 0..4 {
        cur = inception_a(&mut b, cur, i + 1);
    }
    cur = reduction_a(&mut b, cur);
    for i in 0..7 {
        cur = inception_b(&mut b, cur, i + 1);
    }
    cur = reduction_b(&mut b, cur);
    for i in 0..3 {
        cur = inception_c(&mut b, cur, i + 1);
    }
    let gap = b.pool("avgpool_8x8", cur, PoolKind::Avg, 8, 1, 0);
    let (c, h1, h2) = b.shape(gap);
    b.add("classifier", Op::Fc { c_in: c * h1 * h2, c_out: 1000 }, &[gap]);
    b.finish(3, 299)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let g = inception_v4();
        g.validate().unwrap();
        let at = |name: &str| {
            g.nodes.iter().find(|n| n.name == name).unwrap().op.out_shape()
        };
        assert_eq!(at("stem/concat1"), (160, 73, 73));
        assert_eq!(at("stem/concat2"), (192, 71, 71));
        assert_eq!(at("stem/concat3"), (384, 35, 35));
        assert_eq!(at("inception_a1/concat"), (384, 35, 35));
        assert_eq!(at("reduction_a/concat"), (1024, 17, 17));
        assert_eq!(at("inception_b1/concat"), (1024, 17, 17));
        assert_eq!(at("reduction_b/concat"), (1536, 8, 8));
        assert_eq!(at("inception_c1/concat"), (1536, 8, 8));
    }

    #[test]
    fn conv_count_close_to_paper() {
        // The paper quotes 141 CONV layers; the canonical architecture as
        // published (Szegedy 2016, Fig. 3-9) counts 149 when every
        // factorized conv is counted individually. The discrepancy is in
        // counting convention, not structure — module shapes are asserted
        // exactly in `structure()`.
        let g = inception_v4();
        assert_eq!(g.conv_count(), 149);
    }

    #[test]
    fn has_factorized_kernels() {
        let g = inception_v4();
        let n7x1 = g
            .nodes
            .iter()
            .filter_map(|n| n.op.conv())
            .filter(|c| (c.k1 == 7 && c.k2 == 1) || (c.k1 == 1 && c.k2 == 7))
            .count();
        assert!(n7x1 >= 20, "expected many 7x1/1x7 layers, got {n7x1}");
    }
}
