//! CNN graph intermediate representation.
//!
//! A [`Cnn`] is a DAG of layers ([`Op`]). Convolution layers carry the full
//! meta data the paper's cost model needs (§2.1): input feature map
//! `H1×H2`, kernels `K1×K2`, stride, padding and channel counts. The model
//! zoo ([`zoo`]) provides the networks the paper evaluates (GoogLeNet,
//! Inception-v4) plus the series-parallel lemma examples (VGG, AlexNet,
//! ResNet) and the small `MiniInception` used for end-to-end functional
//! validation through the PJRT runtime.

pub mod layer;
pub mod cnn;
pub mod config;
pub mod zoo;

pub use cnn::{Cnn, CnnBuilder, NodeId};
pub use layer::{ConvSpec, Op, PoolKind, PoolSpec};
