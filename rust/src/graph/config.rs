//! JSON (de)serialization of CNN graphs — lets users bring their own
//! network description (`examples/custom_cnn.rs`) instead of the zoo.

use super::layer::{ConvSpec, Op, PoolKind, PoolSpec};
use super::cnn::{Cnn, Node};
use crate::util::json::Json;

/// Serialize a CNN to JSON.
pub fn to_json(cnn: &Cnn) -> Json {
    let nodes = cnn
        .nodes
        .iter()
        .map(|n| {
            let mut fields = vec![
                ("name", Json::str(n.name.clone())),
                ("kind", Json::str(n.op.kind())),
            ];
            match &n.op {
                Op::Input { c, h1, h2 } => {
                    fields.push(("c", Json::num(*c as f64)));
                    fields.push(("h1", Json::num(*h1 as f64)));
                    fields.push(("h2", Json::num(*h2 as f64)));
                }
                Op::Conv(c) => {
                    for (k, v) in [
                        ("c_in", c.c_in),
                        ("c_out", c.c_out),
                        ("h1", c.h1),
                        ("h2", c.h2),
                        ("k1", c.k1),
                        ("k2", c.k2),
                        ("s", c.s),
                        ("p1", c.p1),
                        ("p2", c.p2),
                    ] {
                        fields.push((k, Json::num(v as f64)));
                    }
                }
                Op::Pool(p) => {
                    for (k, v) in
                        [("c", p.c), ("h1", p.h1), ("h2", p.h2), ("k", p.k), ("s", p.s), ("p", p.p)]
                    {
                        fields.push((k, Json::num(v as f64)));
                    }
                }
                Op::Concat { c_out, h1, h2 } => {
                    fields.push(("c_out", Json::num(*c_out as f64)));
                    fields.push(("h1", Json::num(*h1 as f64)));
                    fields.push(("h2", Json::num(*h2 as f64)));
                }
                Op::Add { c, h1, h2 } => {
                    fields.push(("c", Json::num(*c as f64)));
                    fields.push(("h1", Json::num(*h1 as f64)));
                    fields.push(("h2", Json::num(*h2 as f64)));
                }
                Op::Fc { c_in, c_out } => {
                    fields.push(("c_in", Json::num(*c_in as f64)));
                    fields.push(("c_out", Json::num(*c_out as f64)));
                }
                Op::Output => {}
            }
            Json::obj(fields)
        })
        .collect::<Vec<_>>();
    let edges = cnn
        .edges
        .iter()
        .map(|&(s, d)| Json::arr(vec![Json::num(s as f64), Json::num(d as f64)]))
        .collect::<Vec<_>>();
    Json::obj(vec![
        ("name", Json::str(cnn.name.clone())),
        ("nodes", Json::Arr(nodes)),
        ("edges", Json::Arr(edges)),
    ])
}

fn req(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key).as_usize().ok_or_else(|| format!("missing/invalid field '{key}' in {j}"))
}

/// Deserialize a CNN from JSON (inverse of [`to_json`]); validates.
pub fn from_json(j: &Json) -> Result<Cnn, String> {
    let name = j.get("name").as_str().unwrap_or("custom").to_string();
    let mut nodes = Vec::new();
    for (id, nj) in j.get("nodes").as_arr().ok_or("missing 'nodes'")?.iter().enumerate() {
        let nname = nj.get("name").as_str().unwrap_or("").to_string();
        let kind = nj.get("kind").as_str().ok_or("node missing 'kind'")?;
        let op = match kind {
            "input" => Op::Input { c: req(nj, "c")?, h1: req(nj, "h1")?, h2: req(nj, "h2")? },
            "conv" => Op::Conv(ConvSpec::new(
                req(nj, "c_in")?,
                req(nj, "c_out")?,
                req(nj, "h1")?,
                req(nj, "h2")?,
                req(nj, "k1")?,
                req(nj, "k2")?,
                req(nj, "s")?,
                req(nj, "p1")?,
                req(nj, "p2")?,
            )),
            "maxpool" | "avgpool" => Op::Pool(PoolSpec {
                kind: if kind == "maxpool" { PoolKind::Max } else { PoolKind::Avg },
                c: req(nj, "c")?,
                h1: req(nj, "h1")?,
                h2: req(nj, "h2")?,
                k: req(nj, "k")?,
                s: req(nj, "s")?,
                p: req(nj, "p")?,
            }),
            "concat" => Op::Concat { c_out: req(nj, "c_out")?, h1: req(nj, "h1")?, h2: req(nj, "h2")? },
            "add" => Op::Add { c: req(nj, "c")?, h1: req(nj, "h1")?, h2: req(nj, "h2")? },
            "fc" => Op::Fc { c_in: req(nj, "c_in")?, c_out: req(nj, "c_out")? },
            "output" => Op::Output,
            other => return Err(format!("unknown node kind '{other}'")),
        };
        nodes.push(Node { id, name: nname, op });
    }
    let mut edges = Vec::new();
    for ej in j.get("edges").as_arr().ok_or("missing 'edges'")? {
        let s = ej.at(0).as_usize().ok_or("bad edge src")?;
        let d = ej.at(1).as_usize().ok_or("bad edge dst")?;
        edges.push((s, d));
    }
    let cnn = Cnn { name, nodes, edges };
    cnn.validate()?;
    Ok(cnn)
}

/// Load a CNN from a JSON file on disk.
pub fn load(path: &str) -> Result<Cnn, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let j = Json::parse(&text).map_err(|e| e.to_string())?;
    from_json(&j)
}

/// Save a CNN as pretty JSON.
pub fn save(cnn: &Cnn, path: &str) -> Result<(), String> {
    std::fs::write(path, to_json(cnn).pretty()).map_err(|e| format!("write {path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    #[test]
    fn roundtrip_all_zoo_models() {
        for name in zoo::names() {
            let net = zoo::by_name(name).unwrap();
            let j = to_json(&net);
            let back = from_json(&j).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(back.nodes.len(), net.nodes.len());
            assert_eq!(back.edges, net.edges);
            assert_eq!(back.total_macs(), net.total_macs());
        }
    }

    #[test]
    fn rejects_invalid() {
        assert!(from_json(&Json::parse(r#"{"nodes": [], "edges": []}"#).unwrap()).is_err());
        let bad = r#"{"name":"x","nodes":[{"name":"in","kind":"wat"}],"edges":[]}"#;
        assert!(from_json(&Json::parse(bad).unwrap()).is_err());
    }
}
