//! Benchmark harness + paper figure/table regeneration.
//!
//! [`harness`] is a minimal criterion substitute (criterion is not
//! available in the offline build); [`figures`] regenerates every table
//! and figure of the paper's evaluation section (§6) — each is also
//! exposed as a `cargo bench` target under `rust/benches/`.

pub mod harness;
pub mod figures;
