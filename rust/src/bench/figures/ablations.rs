//! Ablation benches for the design choices DESIGN.md calls out:
//! stall-free PEs, dataflow switching, rectangular arrays, PBQP vs
//! greedy, transition-aware mapping, Winograd tile size, SRAM fusion.

use crate::api::Compiler;
use crate::cost::gemm::Dataflow;
use crate::cost::graph_build::Policy;
use crate::dse::DseConfig;
use crate::graph::zoo;
use crate::util::table::{fnum, Table};

fn latency(cfg: DseConfig, model: &str) -> f64 {
    let cnn = zoo::by_name(model).unwrap();
    Compiler::from_config(cfg).compile(&cnn).unwrap().plan.total_latency_ms
}

pub fn run() -> Vec<Table> {
    let mut t = Table::new(
        "Ablations — end-to-end latency (ms) when disabling one optimization",
        &["variant", "googlenet", "inception-v4"],
    );
    let base = DseConfig::alveo_u200();

    fn row(t: &mut Table, label: &str, cfg: DseConfig) {
        t.row(vec![
            label.to_string(),
            fnum(latency(cfg.clone(), "googlenet"), 3),
            fnum(latency(cfg, "inception-v4"), 3),
        ]);
    }

    row(&mut t, "full DYNAMAP (baseline)", base.clone());
    // stall-free needs a direct CostModel toggle (not in DseConfig)
    {
        let cnn_g = zoo::googlenet();
        let cnn_i = zoo::inception_v4();
        let compiler = Compiler::from_config(base.clone());
        let arch_g = compiler.identify(&cnn_g).unwrap();
        let arch_i = compiler.identify(&cnn_i).unwrap();
        let mut cm = base.cost_model();
        cm.stall_free = false;
        let tm = base.transition_model();
        let g_g =
            crate::cost::graph_build::CostGraph::build(&cnn_g, &cm, &tm, arch_g.p1, arch_g.p2, base.opts);
        let g_i =
            crate::cost::graph_build::CostGraph::build(&cnn_i, &cm, &tm, arch_i.p1, arch_i.p2, base.opts);
        t.row(vec![
            "no stall-free PEs (naive I_SA)".into(),
            fnum(g_g.solve(&cnn_g).total_sec * 1e3, 3),
            fnum(g_i.solve(&cnn_i).total_sec * 1e3, 3),
        ]);
    }
    row(&mut t, "NS dataflow only", {
        let mut c = base.clone();
        c.force_dataflow = Some(Dataflow::NS);
        c
    });
    row(&mut t, "no SRAM fusion (always round-trip DRAM)", {
        let mut c = base.clone();
        c.opts.sram_fuse = false;
        c
    });
    row(&mut t, "weight load not overlapped", {
        let mut c = base.clone();
        c.opts.overlap_weight_load = false;
        c
    });
    row(&mut t, "Winograd F(4×4, 3×3) tiles", {
        let mut c = base.clone();
        c.wino_m = 4;
        c
    });
    row(&mut t, "strided-Winograd extension (§7)", {
        let mut c = base.clone();
        c.strided_winograd = true;
        c
    });

    // greedy vs optimal mapping
    {
        let greedy = Compiler::from_config(base.clone()).policy(Policy::Greedy);
        let g = greedy.compile(&zoo::googlenet()).unwrap().plan;
        let i = greedy.compile(&zoo::inception_v4()).unwrap().plan;
        t.row(vec![
            "greedy node-cost mapping (no PBQP)".into(),
            fnum(g.total_latency_ms, 3),
            fnum(i.total_latency_ms, 3),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_optimizations_cost_latency() {
        let base = DseConfig::alveo_u200();
        let l_base = latency(base.clone(), "googlenet");
        let mut ns = base.clone();
        ns.force_dataflow = Some(Dataflow::NS);
        let l_ns = latency(ns, "googlenet");
        assert!(l_ns >= l_base - 1e-9, "NS-only {l_ns} vs full {l_base}");
        let mut nf = base.clone();
        nf.opts.sram_fuse = false;
        let l_nf = latency(nf, "googlenet");
        assert!(l_nf >= l_base - 1e-9, "no-fuse {l_nf} vs full {l_base}");
    }

    #[test]
    fn strided_winograd_helps_or_ties_stem_heavy_nets() {
        // the extension adds an option; the optimal mapping can only
        // improve or stay equal
        let base = DseConfig::alveo_u200();
        let mut ext = base.clone();
        ext.strided_winograd = true;
        let l_base = latency(base, "inception-v4");
        let l_ext = latency(ext, "inception-v4");
        assert!(l_ext <= l_base + 1e-9, "extension {l_ext} vs base {l_base}");
    }
}
