//! Figures 11/12 — per-module execution time (computation +
//! communication) under the four mappings of §6.1.2:
//!
//! * `bl3` im2col-only, `bl4` kn2row-applied, `bl5` wino-applied,
//! * `OPT` — the PBQP mapping returned by DYNAMAP.
//!
//! Layers are grouped into their Inception/Reduction modules by name
//! prefix, matching the x-axis of the paper's plots.

use crate::api::Compiler;
use crate::cost::graph_build::{MappingResult, Policy};
use crate::dse::Plan;
use crate::graph::Cnn;
use crate::graph::zoo;
use crate::util::table::{fnum, Table};
use std::collections::BTreeMap;

/// Module key of a layer name ("inception_3a/5x5" → "inception_3a").
fn module_of(name: &str) -> String {
    name.split('/').next().unwrap_or(name).to_string()
}

/// Sum per-module (compute + inbound transition) seconds for a mapping.
pub fn module_times(cnn: &Cnn, plan: &Plan) -> BTreeMap<String, f64> {
    let mapping: &MappingResult = &plan.mapping;
    let mut by_module: BTreeMap<String, f64> = BTreeMap::new();
    for l in &mapping.layers {
        *by_module.entry(module_of(&l.name)).or_insert(0.0) += l.cost.seconds;
    }
    // distribute transition time proportionally to module compute time
    // (transitions belong to edges; the paper's module bars include the
    // communication latency of the module's layers)
    let total_compute: f64 = by_module.values().sum();
    if total_compute > 0.0 {
        let scale = mapping.transition_sec / total_compute;
        for v in by_module.values_mut() {
            *v += *v * scale;
        }
    }
    let _ = cnn;
    by_module
}

pub struct ModuleFig {
    pub table: Table,
    /// per-policy end-to-end latency ms: (bl3, bl4, bl5, opt)
    pub e2e_ms: (f64, f64, f64, f64),
}

pub fn compute(model: &str) -> ModuleFig {
    let cnn = zoo::by_name(model).expect("unknown model");
    let compiler = Compiler::new();
    let run = |c: Compiler| c.compile(&cnn).unwrap().into_plan();
    let opt = run(compiler.clone());
    let bl3 = run(compiler.clone().policy(Policy::Im2colOnly));
    let bl4 = run(compiler.clone().policy(Policy::Kn2rowApplied));
    let bl5 = run(compiler.clone().policy(Policy::WinoApplied));

    let m3 = module_times(&cnn, &bl3);
    let m4 = module_times(&cnn, &bl4);
    let m5 = module_times(&cnn, &bl5);
    let mo = module_times(&cnn, &opt);

    let mut t = Table::new(
        &format!(
            "Fig. {} — module execution times (ms): {model}",
            if model.starts_with("incep") { 11 } else { 12 }
        ),
        &["module", "bl3 im2col", "bl4 kn2row", "bl5 wino", "OPT"],
    );
    for module in mo.keys() {
        t.row(vec![
            module.clone(),
            fnum(m3.get(module).copied().unwrap_or(0.0) * 1e3, 4),
            fnum(m4.get(module).copied().unwrap_or(0.0) * 1e3, 4),
            fnum(m5.get(module).copied().unwrap_or(0.0) * 1e3, 4),
            fnum(mo[module] * 1e3, 4),
        ]);
    }
    ModuleFig {
        table: t,
        e2e_ms: (
            bl3.total_latency_ms,
            bl4.total_latency_ms,
            bl5.total_latency_ms,
            opt.total_latency_ms,
        ),
    }
}

pub fn run(model: &str) -> Vec<Table> {
    let f = compute(model);
    let mut sum = Table::new("end-to-end", &["mapping", "latency ms"]);
    for (l, v) in [
        ("bl3 im2col-only", f.e2e_ms.0),
        ("bl4 kn2row-applied", f.e2e_ms.1),
        ("bl5 wino-applied", f.e2e_ms.2),
        ("OPT (DYNAMAP)", f.e2e_ms.3),
    ] {
        sum.row(vec![l.to_string(), fnum(v, 3)]);
    }
    vec![f.table, sum]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opt_never_worse_per_network() {
        for model in ["googlenet", "inception-v4"] {
            let f = compute(model);
            let (bl3, bl4, bl5, opt) = f.e2e_ms;
            assert!(opt <= bl3 + 1e-9, "{model}: OPT {opt} vs bl3 {bl3}");
            assert!(opt <= bl4 + 1e-9, "{model}: OPT {opt} vs bl4 {bl4}");
            assert!(opt <= bl5 + 1e-9, "{model}: OPT {opt} vs bl5 {bl5}");
        }
    }

    #[test]
    fn kn2row_shines_on_inception_not_googlenet() {
        // §6.1.2: "kn2row almost always out-performs im2col" on
        // Inception-v4; on GoogLeNet it is "less advantageous".
        let inc = compute("inception-v4");
        assert!(
            inc.e2e_ms.1 < inc.e2e_ms.0,
            "inception: kn2row {} should beat im2col {}",
            inc.e2e_ms.1,
            inc.e2e_ms.0
        );
        let goo = compute("googlenet");
        let kn_gain_goo = goo.e2e_ms.0 / goo.e2e_ms.1;
        let kn_gain_inc = inc.e2e_ms.0 / inc.e2e_ms.1;
        assert!(
            kn_gain_inc > kn_gain_goo,
            "kn2row advantage should be larger on inception ({kn_gain_inc:.3} vs {kn_gain_goo:.3})"
        );
    }

    #[test]
    fn module_grouping() {
        assert_eq!(module_of("inception_3a/5x5"), "inception_3a");
        assert_eq!(module_of("conv1/7x7_s2"), "conv1");
        assert_eq!(module_of("stem"), "stem");
    }
}
