//! Table 4 — "End-to-end Latency Improvement due to Dynamic Algorithm
//! Mapping": percentage latency decrease of the OPT mapping vs the
//! bl3/bl4/bl5 single-algorithm baselines, for both networks, plus the
//! paper's reported numbers for comparison.

use crate::api::Compiler;
use crate::cost::graph_build::Policy;
use crate::graph::zoo;
use crate::util::table::{fnum, Table};

/// Paper-reported Table 4 values (% decrease vs bl3/bl4/bl5).
pub fn paper_values(model: &str) -> (f64, f64, f64) {
    match model {
        "googlenet" => (67.5, 78.0, 22.0),
        _ => (86.0, 61.0, 17.0),
    }
}

/// Our measured improvement (%) of OPT vs the three baselines.
pub fn compute(model: &str) -> (f64, f64, f64) {
    let cnn = zoo::by_name(model).unwrap();
    let compiler = Compiler::new();
    let opt = compiler.compile(&cnn).unwrap().plan.total_latency_ms;
    let pct = |p: Policy| {
        let b = compiler.clone().policy(p).compile(&cnn).unwrap().plan.total_latency_ms;
        (1.0 - opt / b) * 100.0
    };
    (pct(Policy::Im2colOnly), pct(Policy::Kn2rowApplied), pct(Policy::WinoApplied))
}

pub fn run() -> Vec<Table> {
    let mut t = Table::new(
        "Table 4 — end-to-end latency improvement of OPT (% decrease)",
        &["network", "vs bl3 (ours)", "vs bl4 (ours)", "vs bl5 (ours)", "paper bl3/bl4/bl5"],
    );
    for model in ["googlenet", "inception-v4"] {
        let (b3, b4, b5) = compute(model);
        let (p3, p4, p5) = paper_values(model);
        t.row(vec![
            model.into(),
            fnum(b3, 1),
            fnum(b4, 1),
            fnum(b5, 1),
            format!("{p3}/{p4}/{p5}"),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn improvements_are_nonnegative() {
        for model in ["googlenet", "inception-v4"] {
            let (b3, b4, b5) = compute(model);
            assert!(b3 >= -1e-6, "{model} bl3 {b3}");
            assert!(b4 >= -1e-6, "{model} bl4 {b4}");
            assert!(b5 >= -1e-6, "{model} bl5 {b5}");
            // at least one baseline is materially beaten
            assert!(b3.max(b4).max(b5) > 2.0, "{model}: {b3}/{b4}/{b5}");
        }
    }

    #[test]
    fn wino_applied_is_closest_baseline_on_googlenet() {
        // paper: bl5 (22%) is the closest baseline on GoogLeNet — the
        // winograd-heavy mapping leaves the least on the table.
        let (b3, _b4, b5) = compute("googlenet");
        assert!(b5 < b3, "bl5 gap {b5} should be smaller than bl3 {b3}");
    }
}
