//! Figures 9/10 — per-layer effective PE utilization μ under three
//! hardware configurations:
//!
//! * `bl1` "square-NS": largest square array within the DSP budget
//!   (78×78 for 6084), NS dataflow only;
//! * `bl2` "algo1-NS": Algorithm-1 rectangular array, NS only;
//! * `OPT` "algo1-optimized": Algorithm-1 array + per-layer best
//!   dataflow.
//!
//! All three use the framework's returned algorithm mapping, exactly as
//! §6.1.1 describes. The summary row reproduces the paper's headline:
//! "32% and 35% lower end-to-end latency for GoogLeNet and
//! Inception-v4" vs bl1.

use crate::api::Compiler;
use crate::cost::gemm::Dataflow;
use crate::graph::layer::Op;
use crate::graph::zoo;
use crate::util::table::{fnum, Table};

/// Largest square P_SA within the DSP budget (78 for 6084).
pub fn square_side(cap: usize) -> usize {
    let mut s = 1;
    while (s + 1) * (s + 1) <= cap {
        s += 1;
    }
    s
}

pub struct UtilFig {
    pub layer_table: Table,
    pub summary: Table,
    /// (bl1, bl2, opt) end-to-end latency in ms.
    pub latency_ms: (f64, f64, f64),
    /// mean μ per configuration.
    pub mean_mu: (f64, f64, f64),
}

pub fn compute(model: &str) -> UtilFig {
    let cnn = zoo::by_name(model).expect("unknown model");
    let cap = 6084;
    let sq = square_side(cap);

    // OPT: full framework
    let compiler = Compiler::new();
    let opt = compiler.compile(&cnn).unwrap().into_plan();

    // NS-only config used by both baselines
    let ns = Compiler::new().force_dataflow(Dataflow::NS);
    let bl1 = ns.clone().fixed_shape(sq, sq).compile(&cnn).unwrap().into_plan();
    let bl2 = ns.clone().fixed_shape(opt.p1, opt.p2).compile(&cnn).unwrap().into_plan();

    let cm = compiler.config().cost_model();
    let mut ns_cm = cm.clone();
    ns_cm.force_dataflow = Some(Dataflow::NS);

    let mut t = Table::new(
        &format!(
            "Fig. {} — effective PE utilization μ per layer: {model}",
            if model.starts_with("incep") { 9 } else { 10 }
        ),
        &["layer", "algo (OPT)", "bl1 square-NS μ", "bl2 algo1-NS μ", "OPT μ"],
    );
    let (mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0);
    let mut n = 0.0;
    for l in &opt.mapping.layers {
        let Op::Conv(spec) = &cnn.node(l.node).op else { continue };
        let algo = l.cost.algo;
        let mu1 = ns_cm.best_conv_cost(spec, algo, sq, sq).utilization;
        let mu2 = ns_cm.best_conv_cost(spec, algo, opt.p1, opt.p2).utilization;
        let mu3 = l.cost.utilization;
        s1 += mu1;
        s2 += mu2;
        s3 += mu3;
        n += 1.0;
        t.row(vec![
            l.name.clone(),
            algo.name(),
            fnum(mu1, 3),
            fnum(mu2, 3),
            fnum(mu3, 3),
        ]);
    }

    let mut sum = Table::new(
        &format!("{model} — summary (paper: 32%/35% latency reduction vs bl1)"),
        &["config", "array", "mean μ", "e2e latency ms", "vs bl1"],
    );
    for (label, plan, mu) in [
        ("bl1 square-NS", &bl1, s1 / n),
        ("bl2 algo1-NS", &bl2, s2 / n),
        ("OPT (DYNAMAP)", &opt, s3 / n),
    ] {
        sum.row(vec![
            label.to_string(),
            format!("{}×{}", plan.p1, plan.p2),
            fnum(mu, 3),
            fnum(plan.total_latency_ms, 3),
            format!(
                "-{:.0}%",
                (1.0 - plan.total_latency_ms / bl1.total_latency_ms) * 100.0
            ),
        ]);
    }

    UtilFig {
        layer_table: t,
        summary: sum,
        latency_ms: (bl1.total_latency_ms, bl2.total_latency_ms, opt.total_latency_ms),
        mean_mu: (s1 / n, s2 / n, s3 / n),
    }
}

pub fn run(model: &str) -> Vec<Table> {
    let f = compute(model);
    vec![f.layer_table, f.summary]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_side_math() {
        assert_eq!(square_side(6084), 78);
        assert_eq!(square_side(1024), 32);
        assert_eq!(square_side(2), 1);
    }

    #[test]
    fn opt_improves_on_baselines_googlenet() {
        let f = compute("googlenet");
        let (bl1, bl2, opt) = f.latency_ms;
        assert!(opt <= bl2 + 1e-9, "OPT {opt} should beat bl2 {bl2}");
        assert!(opt < bl1, "OPT {opt} should beat bl1 {bl1}");
        // paper reports 32% vs bl1; assert a material improvement and
        // record the exact number in EXPERIMENTS.md
        let gain = 1.0 - opt / bl1;
        assert!(gain > 0.10, "latency gain vs square-NS = {gain:.2}");
        // OPT mean utilization should beat NS-only on the same array
        assert!(f.mean_mu.2 >= f.mean_mu.1 - 1e-9);
    }
}
