//! §6.1.2 runtime claim — "The algorithm mapping obtained in DYNAMAP
//! ... is obtained within 2 seconds on an AMD 3700X cpu" — plus the
//! O(N·d²) scaling of Theorem 4.1 on synthetic chains.

use crate::api::Compiler;
use crate::graph::zoo;
use crate::pbqp::{solve_sp, Matrix, Problem};
use crate::util::table::{fnum, Table};
use std::time::Instant;

/// Build a synthetic chain PBQP instance with `n` vertices, domain `d`.
pub fn chain_problem(n: usize, d: usize) -> Problem {
    let mut p = Problem::default();
    let labels: Vec<String> = (0..d).map(|i| format!("o{i}")).collect();
    for i in 0..n {
        let costs = (0..d).map(|k| ((i * 7 + k * 13) % 17) as f64).collect();
        p.add_vertex(&format!("v{i}"), costs, labels.clone());
    }
    for i in 0..n - 1 {
        let m = Matrix::from_fn(d, d, |a, b| ((a * 3 + b * 5 + i) % 11) as f64);
        p.add_edge(i, i + 1, m);
    }
    p
}

pub fn run() -> Vec<Table> {
    let mut t = Table::new(
        "DSE runtime (paper: < 2 s for the algorithm mapping)",
        &["stage", "model", "time"],
    );
    for model in ["googlenet", "inception-v4"] {
        let cnn = zoo::by_name(model).unwrap();
        let compiler = Compiler::new();
        let t0 = Instant::now();
        let arch = compiler.identify(&cnn).unwrap();
        let algo1_t = t0.elapsed();
        let t1 = Instant::now();
        let g = compiler.build_graph(&cnn, arch.p1, arch.p2);
        let build_t = t1.elapsed();
        let t2 = Instant::now();
        let _ = g.solve(&cnn);
        let solve_t = t2.elapsed();
        t.row(vec!["Algorithm 1".into(), model.into(), format!("{algo1_t:.2?}")]);
        t.row(vec!["cost graph".into(), model.into(), format!("{build_t:.2?}")]);
        t.row(vec!["PBQP solve".into(), model.into(), format!("{solve_t:.2?}")]);
    }

    let mut scale = Table::new(
        "PBQP solver scaling on synthetic chains (Theorem 4.1: O(N·d²))",
        &["N", "d", "solve time µs", "µs / (N·d²)"],
    );
    for &(n, d) in &[(100usize, 3usize), (1000, 3), (10000, 3), (1000, 6), (1000, 12)] {
        let p = chain_problem(n, d);
        let t0 = Instant::now();
        let sol = solve_sp(&p, 0, n - 1).expect("chain is SP");
        let dt = t0.elapsed().as_secs_f64() * 1e6;
        assert!(sol.cost.is_finite());
        scale.row(vec![
            n.to_string(),
            d.to_string(),
            fnum(dt, 1),
            fnum(dt / (n as f64 * (d * d) as f64), 4),
        ]);
    }
    vec![t, scale]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inception_mapping_under_2s() {
        let cnn = zoo::inception_v4();
        let compiler = Compiler::new();
        let arch = compiler.identify(&cnn).unwrap();
        let t0 = Instant::now();
        let g = compiler.build_graph(&cnn, arch.p1, arch.p2);
        let _ = g.solve(&cnn);
        let dt = t0.elapsed();
        assert!(
            dt.as_secs_f64() < 2.0,
            "PBQP mapping took {dt:.2?} (paper claims < 2 s)"
        );
    }

    #[test]
    fn chain_scaling_roughly_linear_in_n() {
        // time(10·N) should be ≲ 30× time(N) — crude but catches
        // accidental quadratic blowup in the reduction loop
        let t_for = |n: usize| {
            let p = chain_problem(n, 3);
            let t0 = Instant::now();
            solve_sp(&p, 0, n - 1).unwrap();
            t0.elapsed().as_secs_f64()
        };
        let t1k = t_for(1000).max(1e-6);
        let t4k = t_for(4000);
        assert!(
            t4k / t1k < 40.0,
            "scaling looks super-linear: {t1k:.6}s → {t4k:.6}s"
        );
    }
}
