//! Figure 1 — "Computation and Memory Loads of GEMM-CONV algorithms on
//! different layer configurations".
//!
//! Three representative layer configurations (an Inception-v4
//! factorized 1×7 layer, a mid-network 3×3 layer, a deep 5×5 GoogLeNet
//! layer) × three algorithms, reporting multiplication count and
//! activation memory traffic normalized to im2col — the trade-off
//! triangle that motivates dynamic algorithm mapping.

use crate::cost::conv::{Algo, CostModel};
use crate::cost::Device;
use crate::graph::layer::ConvSpec;
use crate::util::table::{fnum, Table};

/// The three layer configurations plotted in Fig. 1.
pub fn configs() -> Vec<(&'static str, ConvSpec)> {
    vec![
        // (a) memory-bound factorized kernel (Inception-B style)
        ("a: 17×17×1024, 1×7", ConvSpec::new(1024, 256, 17, 17, 1, 7, 1, 0, 3)),
        // (b) balanced mid-network square kernel
        ("b: 28×28×192, 3×3", ConvSpec::new(192, 256, 28, 28, 3, 3, 1, 1, 1)),
        // (c) compute-heavy large kernel on deep maps (GoogLeNet 5×5)
        ("c: 7×7×832, 5×5", ConvSpec::new(832, 128, 7, 7, 5, 5, 1, 2, 2)),
    ]
}

pub fn run() -> Vec<Table> {
    let cm = CostModel::new(Device::alveo_u200());
    let mut t = Table::new(
        "Fig. 1 — computation & memory loads (normalized to im2col)",
        &["layer config", "algorithm", "mults (G)", "mem (M elems)", "mults ×", "mem ×"],
    );
    for (label, spec) in configs() {
        let (base_mult, base_mem) = cm.loads(&spec, Algo::Im2col);
        for algo in Algo::available(&spec, 2, 3, false) {
            let (mults, mem) = cm.loads(&spec, algo);
            t.row(vec![
                label.to_string(),
                algo.name(),
                fnum(mults as f64 / 1e9, 3),
                fnum(mem as f64 / 1e6, 3),
                fnum(mults as f64 / base_mult as f64, 2),
                fnum(mem as f64 / base_mem as f64, 2),
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::conv::{Algo, CostModel};
    use crate::cost::Device;

    #[test]
    fn shape_of_fig1_tradeoffs() {
        let cm = CostModel::new(Device::alveo_u200());
        // (a) factorized 1×7: kn2row moves less memory than im2col
        let (_, spec_a) = &configs()[0];
        let (_, mem_im) = cm.loads(spec_a, Algo::Im2col);
        let (_, mem_kn) = cm.loads(spec_a, Algo::Kn2row);
        assert!(mem_kn < mem_im, "kn2row {mem_kn} should move less than im2col {mem_im}");
        // (b) 3×3: winograd multiplies less than both
        let (_, spec_b) = &configs()[1];
        let (m_im, _) = cm.loads(spec_b, Algo::Im2col);
        let (m_wi, _) = cm.loads(spec_b, Algo::Winograd { m: 2, r: 3 });
        assert!(m_wi < m_im);
        // table renders with 3 configs × ≥2 algos
        let tables = run();
        assert!(tables[0].rows.len() >= 7);
    }
}
