//! Regeneration of every table and figure in the paper's evaluation
//! (§6), plus the ablation studies DESIGN.md calls out.
//!
//! Each module returns [`crate::util::table::Table`]s that are printed
//! and optionally written as CSV into a reports directory; the
//! `cargo bench` targets under `rust/benches/` wrap these.

pub mod fig01;
pub mod util_figs;
pub mod module_figs;
pub mod table3;
pub mod table4;
pub mod dse_runtime;
pub mod ablations;

use crate::util::cli::Args;
use crate::util::table::Table;

/// Write tables to stdout and to `<dir>/<stem>.csv` when `dir` is set.
pub fn emit(tables: &[Table], dir: Option<&str>, stem: &str) {
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.render());
        if let Some(d) = dir {
            std::fs::create_dir_all(d).ok();
            let path = if tables.len() == 1 {
                format!("{d}/{stem}.csv")
            } else {
                format!("{d}/{stem}_{i}.csv")
            };
            if let Err(e) = std::fs::write(&path, t.to_csv()) {
                eprintln!("warn: write {path}: {e}");
            }
        }
    }
}

/// `dynamap figures [--out reports/] [--only NAME]` — run everything.
pub fn cli(args: &Args) -> i32 {
    let out = args.get("out");
    let only = args.get("only");
    let run = |name: &str| only.is_none() || only == Some(name);
    if run("fig01") {
        emit(&fig01::run(), out, "fig01_algo_loads");
    }
    if run("fig09") {
        emit(&util_figs::run("inception-v4"), out, "fig09_util_inception_v4");
    }
    if run("fig10") {
        emit(&util_figs::run("googlenet"), out, "fig10_util_googlenet");
    }
    if run("fig11") {
        emit(&module_figs::run("inception-v4"), out, "fig11_modules_inception_v4");
    }
    if run("fig12") {
        emit(&module_figs::run("googlenet"), out, "fig12_modules_googlenet");
    }
    if run("table3") {
        emit(&table3::run(), out, "table3_sota");
    }
    if run("table4") {
        emit(&table4::run(), out, "table4_improvement");
    }
    if run("dse") {
        emit(&dse_runtime::run(), out, "dse_runtime");
    }
    if run("ablations") {
        emit(&ablations::run(), out, "ablations");
    }
    0
}
