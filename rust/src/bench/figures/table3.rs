//! Table 3 — comparison with state-of-the-art implementations, plus the
//! §6.2 FlexCNN projection.
//!
//! Our rows come from the full DSE + cost model on the U200 meta data;
//! the competitor rows ([12] Ma'18, [27] Yu'19, [31]/[25]) are constants
//! quoted from the paper (their bitstreams cannot be re-run). The
//! comparison of interest is the *shape*: who wins and by what factor.

use crate::api::Compiler;
use crate::graph::zoo;
use crate::util::table::{fnum, Table};

/// Published competitor rows (from the paper's Table 3).
pub struct Published {
    pub name: &'static str,
    pub network: &'static str,
    pub device: &'static str,
    pub datatype: &'static str,
    pub freq_mhz: f64,
    pub throughput_gops: f64,
    pub latency_ms: f64,
}

pub fn published() -> Vec<Published> {
    vec![
        Published {
            name: "[12] Ma et al.",
            network: "googlenet",
            device: "Stratix 10 GX",
            datatype: "INT16",
            freq_mhz: 300.0,
            throughput_gops: 557.0,
            latency_ms: 5.7,
        },
        Published {
            name: "[27] Yu et al.",
            network: "googlenet",
            device: "KU115",
            datatype: "INT16",
            freq_mhz: 250.0,
            throughput_gops: 1630.0,
            latency_ms: 3.8,
        },
        Published {
            name: "[31] Zhang et al.",
            network: "inception-v4",
            device: "XCVU9P",
            datatype: "INT8",
            freq_mhz: 300.0,
            throughput_gops: 3448.0,
            latency_ms: 5.29,
        },
        Published {
            name: "[25] Wei et al.",
            network: "inception-v4",
            device: "XCVU9P",
            datatype: "INT8",
            freq_mhz: 180.0,
            throughput_gops: 1528.0,
            latency_ms: 6.03,
        },
    ]
}

/// Paper-reported DYNAMAP rows (for calibration of our simulated rows).
pub fn paper_dynamap() -> [(/*net*/ &'static str, /*lat ms*/ f64, /*gops*/ f64); 2] {
    [("googlenet", 1.34, 3568.0), ("inception-v4", 4.39, 3650.0)]
}

/// §6.2 FlexCNN projection: L = 24.7 ms × (8³·93%)/(P1·P2·100%) × GOPs/2.9.
pub fn flexcnn_projection(p1: usize, p2: usize, gops: f64) -> f64 {
    24.7 * (8.0 * 8.0 * 8.0 * 0.93) / (p1 as f64 * p2 as f64) * (gops / 2.9)
}

pub fn run() -> Vec<Table> {
    let compiler = Compiler::new();
    let mut t = Table::new(
        "Table 3 — comparison with state-of-the-art (our rows simulated on U200 meta)",
        &["impl", "network", "device", "dtype", "MHz", "GOP/s", "latency ms"],
    );
    let mut proj = Table::new(
        "§6.2 — FlexCNN best-case projection",
        &["network", "projected ms", "DYNAMAP (ours) ms", "paper DYNAMAP ms"],
    );
    for model in ["googlenet", "inception-v4"] {
        let cnn = zoo::by_name(model).unwrap();
        let plan = compiler.compile(&cnn).unwrap().into_plan();
        t.row(vec![
            "DYNAMAP (this repro)".into(),
            model.into(),
            "U200 (simulated)".into(),
            "INT8".into(),
            fnum(compiler.config().device.freq_mhz, 0),
            fnum(plan.throughput_gops, 0),
            fnum(plan.total_latency_ms, 2),
        ]);
        let (_, paper_lat, paper_gops) =
            paper_dynamap().iter().find(|(n, _, _)| *n == model).map(|&(n, l, g)| (n, l, g)).unwrap();
        t.row(vec![
            "DYNAMAP (paper)".into(),
            model.into(),
            "Alveo U200".into(),
            "INT8".into(),
            "286".into(),
            fnum(paper_gops, 0),
            fnum(paper_lat, 2),
        ]);
        // FlexCNN projection uses the paper's own GOPs accounting
        // (≈3 / ≈9 GOPs)
        let gops_paper = if model == "googlenet" { 3.0 } else { 9.0 };
        proj.row(vec![
            model.into(),
            fnum(flexcnn_projection(plan.p1, plan.p2, gops_paper), 2),
            fnum(plan.total_latency_ms, 2),
            fnum(paper_lat, 2),
        ]);
    }
    for p in published() {
        t.row(vec![
            p.name.into(),
            p.network.into(),
            p.device.into(),
            p.datatype.into(),
            fnum(p.freq_mhz, 0),
            fnum(p.throughput_gops, 0),
            fnum(p.latency_ms, 2),
        ]);
    }
    vec![t, proj]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flexcnn_formula_matches_paper_examples() {
        // paper: L_projected-GN = 2 ms with 92×66 PEs and 3 GOPs
        let gn = flexcnn_projection(92, 66, 3.0);
        assert!((1.8..2.2).contains(&gn), "GN projection {gn}");
        // L_projected-Incp4 = 6 ms with 95×64 PEs and 9 GOPs
        let incp = flexcnn_projection(95, 64, 9.0);
        assert!((5.5..6.5).contains(&incp), "Incp4 projection {incp}");
    }

    #[test]
    fn our_googlenet_beats_published_fpga_latencies() {
        // the shape claim: DYNAMAP (ours) < [12] 5.7ms and < [27] 3.8ms
        let plan = Compiler::new().compile(&zoo::googlenet()).unwrap().into_plan();
        for p in published().iter().filter(|p| p.network == "googlenet") {
            assert!(
                plan.total_latency_ms < p.latency_ms,
                "ours {} vs {} {}",
                plan.total_latency_ms,
                p.name,
                p.latency_ms
            );
        }
    }
}
