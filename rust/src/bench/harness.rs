//! Mini-criterion: wall-clock measurement with warm-up, adaptive
//! iteration counts and simple statistics. Used by the `cargo bench`
//! targets (all registered with `harness = false`).

use std::time::{Duration, Instant};

/// One measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.3?} /iter (median {:.3?}, min {:.3?}, {} iters)",
            self.name, self.mean, self.median, self.min, self.iters
        )
    }
}

/// The bench runner.
pub struct Bencher {
    /// Target total measurement time per benchmark.
    pub budget: Duration,
    pub warmup: Duration,
    pub results: Vec<Measurement>,
}

impl Bencher {
    pub fn new() -> Bencher {
        let fast = std::env::var("DYNAMAP_BENCH_FAST").is_ok();
        Bencher {
            budget: if fast { Duration::from_millis(200) } else { Duration::from_secs(2) },
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(300) },
            results: Vec::new(),
        }
    }

    /// Measure `f`, preventing it from being optimized away via its
    /// returned value.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // warm-up + calibration
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        let target_iters =
            ((self.budget.as_secs_f64() / per_iter.max(1e-9)) as u64).clamp(5, 10_000);

        let mut samples = Vec::with_capacity(target_iters as usize);
        for _ in 0..target_iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        let m = Measurement {
            name: name.to_string(),
            iters: target_iters,
            mean: total / target_iters as u32,
            median: samples[samples.len() / 2],
            min: samples[0],
            max: *samples.last().unwrap(),
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("DYNAMAP_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let m = b.bench("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(m.mean.as_nanos() > 0);
        assert!(m.min <= m.median && m.median <= m.max);
    }
}
