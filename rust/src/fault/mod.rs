//! Deterministic, seeded fault injection for the serving stack.
//!
//! Production serving fails in ways unit tests never provoke on their
//! own: a layer that suddenly runs 100× slow, a worker thread that
//! panics mid-batch, a connection that stalls or drops between request
//! and reply, a reply frame corrupted in flight, an artifact directory
//! that returns I/O errors. This module makes every one of those
//! failure modes *reachable on demand and reproducible by seed*, so the
//! chaos harness (`rust/tests/chaos.rs`) and the `chaos-smoke` CI job
//! can assert the reliability invariants — exactly one typed reply per
//! accepted request, zero leaked admission permits, clean drain — under
//! an adversarial schedule instead of a sunny-day one.
//!
//! Design constraints:
//!
//! - **Default-off and near-zero-cost when off.** Every injection point
//!   compiles down to one relaxed atomic load when no plan is
//!   installed. Production binaries pay nothing unless
//!   `DYNAMAP_FAULTS` is set.
//! - **Deterministic.** Whether draw *k* at site *s* fires is a pure
//!   function of `(seed, s, k)` via SplitMix64 — independent of thread
//!   interleaving, so a failing chaos run replays with the same seed.
//! - **Bounded.** Each site takes an optional `limit` so a test can ask
//!   for *exactly one* scheduler panic rather than a rate.
//!
//! The hooks ([`should_fire`], [`sleep_if`], [`panic_if`],
//! [`io_error_if`]) are sprinkled through `api::session` (slow layer,
//! worker panic), `serve::queue` (scheduler panic), `serve::registry`
//! (artifact I/O) and `net::server` (connection stall/drop, reply
//! corruption). Tests install a plan with [`install`] (or the
//! [`FaultGuard`] RAII wrapper) and read back per-site counts with
//! [`fired`].

#![warn(missing_docs)]
#![deny(clippy::correctness, clippy::suspicious)]

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// The injection sites wired through the stack. Each value doubles as a
/// stable index into the per-site counters, so adding a site at the end
/// never perturbs an existing seed's schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Site {
    /// Sleep inside a conv layer's compute (`api::session`), modelling
    /// interference / DVFS throttling on a shared device.
    SlowLayer,
    /// Panic inside per-request compute (`api::session`), modelling a
    /// poisoned request; the batch's siblings must still complete.
    WorkerPanic,
    /// Panic inside the `BatchQueue` scheduler thread itself
    /// (`serve::queue`), wedging the whole queue; the registry must
    /// re-host the model.
    SchedulerPanic,
    /// Drop the connection after serving a request but before writing
    /// the reply (`net::server`) — the client sees a transport error
    /// and must treat the request as retriable.
    ConnDrop,
    /// Stall the connection worker before serving (`net::server`),
    /// modelling a slow or half-dead peer path.
    ConnStall,
    /// Corrupt the reply frame's header kind byte (`net::server`) so
    /// the client's decoder rejects it as a protocol error.
    CorruptReply,
    /// Fail artifact/manifest I/O during model hosting
    /// (`serve::registry`).
    ArtifactIo,
}

/// All sites, in index order (parallel to the counter arrays).
pub const SITES: [Site; 7] = [
    Site::SlowLayer,
    Site::WorkerPanic,
    Site::SchedulerPanic,
    Site::ConnDrop,
    Site::ConnStall,
    Site::CorruptReply,
    Site::ArtifactIo,
];

impl Site {
    fn index(self) -> usize {
        SITES.iter().position(|s| *s == self).expect("site in SITES")
    }

    /// Parse the `DYNAMAP_FAULTS` spelling of a site (case-insensitive
    /// snake case).
    pub fn parse(name: &str) -> Option<Site> {
        match name.to_ascii_lowercase().as_str() {
            "slow_layer" => Some(Site::SlowLayer),
            "worker_panic" => Some(Site::WorkerPanic),
            "scheduler_panic" => Some(Site::SchedulerPanic),
            "conn_drop" => Some(Site::ConnDrop),
            "conn_stall" => Some(Site::ConnStall),
            "corrupt_reply" => Some(Site::CorruptReply),
            "artifact_io" => Some(Site::ArtifactIo),
            _ => None,
        }
    }
}

/// Per-site injection parameters.
#[derive(Clone, Copy, Debug)]
pub struct SiteConfig {
    /// Probability in `[0, 1]` that a given draw fires.
    pub rate: f64,
    /// Maximum number of firings (0 = unbounded). Lets a test request
    /// *exactly one* panic instead of a statistical rate.
    pub limit: u64,
    /// Delay applied by [`sleep_if`] sites (ignored elsewhere).
    pub delay: Duration,
}

impl Default for SiteConfig {
    fn default() -> Self {
        SiteConfig { rate: 0.0, limit: 0, delay: Duration::from_millis(0) }
    }
}

/// A complete fault schedule: a seed plus the set of armed sites.
///
/// Built programmatically by tests or parsed from the environment
/// (`DYNAMAP_FAULTS` / `DYNAMAP_FAULT_SEED`) by [`FaultPlan::from_env`].
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Seed for the deterministic per-draw decision hash.
    pub seed: u64,
    sites: BTreeMap<Site, SiteConfig>,
}

impl FaultPlan {
    /// Empty plan (no armed sites) with the given seed.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, sites: BTreeMap::new() }
    }

    /// Arm `site` at `rate` with no firing limit and no delay.
    pub fn with(mut self, site: Site, rate: f64) -> FaultPlan {
        self.sites.insert(site, SiteConfig { rate, ..SiteConfig::default() });
        self
    }

    /// Arm `site` with full per-site configuration.
    pub fn with_config(mut self, site: Site, cfg: SiteConfig) -> FaultPlan {
        self.sites.insert(site, cfg);
        self
    }

    /// Parse a plan from the environment. Returns `None` when
    /// `DYNAMAP_FAULTS` is unset or empty. The grammar is
    /// `site:rate[:delay_ms]` entries separated by commas, e.g.
    /// `DYNAMAP_FAULTS="slow_layer:0.05:3,worker_panic:0.01"`, with the
    /// seed taken from `DYNAMAP_FAULT_SEED` (default 99). Unknown sites
    /// and malformed entries are skipped with a note on stderr rather
    /// than aborting the server.
    pub fn from_env() -> Option<FaultPlan> {
        let spec = std::env::var("DYNAMAP_FAULTS").ok()?;
        if spec.trim().is_empty() {
            return None;
        }
        let seed = std::env::var("DYNAMAP_FAULT_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(99);
        let mut plan = FaultPlan::new(seed);
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let mut parts = entry.split(':');
            let site = parts.next().and_then(Site::parse);
            let rate = parts.next().and_then(|r| r.parse::<f64>().ok());
            let delay_ms = parts.next().and_then(|d| d.parse::<u64>().ok()).unwrap_or(0);
            match (site, rate) {
                (Some(site), Some(rate)) => {
                    plan = plan.with_config(
                        site,
                        SiteConfig {
                            rate,
                            limit: 0,
                            delay: Duration::from_millis(delay_ms),
                        },
                    );
                }
                _ => eprintln!("dynamap: ignoring malformed DYNAMAP_FAULTS entry {entry:?}"),
            }
        }
        Some(plan)
    }
}

/// The decision core, kept free of global state so it is unit-testable
/// without cross-contaminating parallel tests.
#[derive(Debug)]
pub struct Injector {
    seed: u64,
    /// One entry per [`SITES`] slot; `None` means the site is unarmed.
    sites: [Option<SiteConfig>; 7],
    /// Draw counters: how many times each site was *consulted*.
    draws: [AtomicU64; 7],
    /// Firing counters: how many times each site actually fired.
    hits: [AtomicU64; 7],
}

/// SplitMix64 finalizer — the same mixer `util::rng` seeds xoshiro
/// with; one application is enough to decorrelate (seed, site, draw)
/// triples.
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Injector {
    /// Build an injector from a plan.
    pub fn new(plan: &FaultPlan) -> Injector {
        let mut sites = [None; 7];
        for (site, cfg) in &plan.sites {
            sites[site.index()] = Some(*cfg);
        }
        Injector {
            seed: plan.seed,
            sites,
            draws: Default::default(),
            hits: Default::default(),
        }
    }

    /// Deterministically decide whether this draw at `site` fires.
    ///
    /// Lock-free: the draw index comes from a per-site atomic counter
    /// and the decision is `splitmix64(seed ^ site ^ draw)` mapped to
    /// `[0, 1)` and compared against the site's rate, so the schedule
    /// depends only on *how many* draws happened at the site, never on
    /// thread interleaving across sites. Respects the site's `limit`
    /// by rolling back an over-limit hit.
    pub fn should_fire(&self, site: Site) -> bool {
        let idx = site.index();
        let cfg = match self.sites[idx] {
            Some(cfg) if cfg.rate > 0.0 => cfg,
            _ => return false,
        };
        let draw = self.draws[idx].fetch_add(1, Ordering::Relaxed);
        let h = splitmix64(self.seed ^ ((idx as u64) << 56) ^ draw);
        // same u64 → f64 mapping as util::rng::Rng::f64
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u >= cfg.rate {
            return false;
        }
        if cfg.limit > 0 {
            let k = self.hits[idx].fetch_add(1, Ordering::SeqCst);
            if k >= cfg.limit {
                self.hits[idx].fetch_sub(1, Ordering::SeqCst);
                return false;
            }
            true
        } else {
            self.hits[idx].fetch_add(1, Ordering::Relaxed);
            true
        }
    }

    /// Configured delay for `site` (zero when unarmed).
    pub fn delay(&self, site: Site) -> Duration {
        self.sites[site.index()].map(|c| c.delay).unwrap_or(Duration::ZERO)
    }

    /// How many times `site` has fired so far.
    pub fn fired(&self, site: Site) -> u64 {
        self.hits[site.index()].load(Ordering::SeqCst)
    }
}

/// Fast path: is *any* plan installed? One relaxed load.
static ENABLED: AtomicBool = AtomicBool::new(false);
static ACTIVE: RwLock<Option<Arc<Injector>>> = RwLock::new(None);

/// Install a fault plan process-wide, replacing any previous one.
pub fn install(plan: FaultPlan) {
    let injector = Arc::new(Injector::new(&plan));
    *ACTIVE.write().expect("fault registry lock") = Some(injector);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Remove the installed plan; all hooks return to no-ops.
pub fn clear() {
    ENABLED.store(false, Ordering::SeqCst);
    *ACTIVE.write().expect("fault registry lock") = None;
}

/// Whether a plan is currently installed.
pub fn is_active() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn active() -> Option<Arc<Injector>> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    ACTIVE.read().expect("fault registry lock").clone()
}

/// Draw at `site`; true when the installed plan says this one fires.
pub fn should_fire(site: Site) -> bool {
    match active() {
        Some(inj) => inj.should_fire(site),
        None => false,
    }
}

/// Sleep for the site's configured delay when its draw fires.
/// Returns true when it slept.
pub fn sleep_if(site: Site) -> bool {
    if let Some(inj) = active() {
        if inj.should_fire(site) {
            std::thread::sleep(inj.delay(site));
            return true;
        }
    }
    false
}

/// Panic with an identifiable message when the site's draw fires.
pub fn panic_if(site: Site) {
    if should_fire(site) {
        panic!("injected fault: {site:?}");
    }
}

/// Return an injected I/O error for `path` when the site's draw fires.
pub fn io_error_if(site: Site, path: &str) -> std::io::Result<()> {
    if should_fire(site) {
        return Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("injected fault: {site:?} at {path}"),
        ));
    }
    Ok(())
}

/// How many times `site` has fired under the installed plan (0 when no
/// plan is installed).
pub fn fired(site: Site) -> u64 {
    match active() {
        Some(inj) => inj.fired(site),
        None => 0,
    }
}

/// RAII installer for tests: installs on construction, clears on drop —
/// including the unwind path, so a failing chaos test cannot leak its
/// schedule into the next one.
pub struct FaultGuard(());

impl FaultGuard {
    /// Install `plan` and return the guard holding it active.
    pub fn install(plan: FaultPlan) -> FaultGuard {
        install(plan);
        FaultGuard(())
    }
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_by_default() {
        assert!(!is_active());
        assert!(!should_fire(Site::WorkerPanic));
        assert_eq!(fired(Site::WorkerPanic), 0);
    }

    #[test]
    fn decisions_are_deterministic_and_rate_shaped() {
        let plan = FaultPlan::new(7).with(Site::SlowLayer, 0.25);
        let a = Injector::new(&plan);
        let b = Injector::new(&plan);
        let draws = 4000;
        let seq_a: Vec<bool> = (0..draws).map(|_| a.should_fire(Site::SlowLayer)).collect();
        let seq_b: Vec<bool> = (0..draws).map(|_| b.should_fire(Site::SlowLayer)).collect();
        assert_eq!(seq_a, seq_b, "same (seed, site, draw) must give same schedule");
        let hits = seq_a.iter().filter(|f| **f).count() as f64;
        let rate = hits / draws as f64;
        assert!(
            (rate - 0.25).abs() < 0.05,
            "empirical rate {rate} too far from configured 0.25"
        );
        // other sites stay silent
        assert!(!a.should_fire(Site::ConnDrop));
    }

    #[test]
    fn limit_bounds_firings() {
        let plan = FaultPlan::new(1).with_config(
            Site::WorkerPanic,
            SiteConfig { rate: 1.0, limit: 3, delay: Duration::ZERO },
        );
        let inj = Injector::new(&plan);
        let hits =
            (0..100).filter(|_| inj.should_fire(Site::WorkerPanic)).count();
        assert_eq!(hits, 3, "limit=3 must cap firings at exactly 3");
        assert_eq!(inj.fired(Site::WorkerPanic), 3);
    }

    #[test]
    fn seeds_change_the_schedule() {
        let a = Injector::new(&FaultPlan::new(1).with(Site::ConnStall, 0.5));
        let b = Injector::new(&FaultPlan::new(2).with(Site::ConnStall, 0.5));
        let seq_a: Vec<bool> = (0..256).map(|_| a.should_fire(Site::ConnStall)).collect();
        let seq_b: Vec<bool> = (0..256).map(|_| b.should_fire(Site::ConnStall)).collect();
        assert_ne!(seq_a, seq_b, "different seeds must give different schedules");
    }

    #[test]
    fn site_parse_round_trips() {
        for site in SITES {
            let name = format!("{site:?}");
            // Debug is CamelCase; the env grammar is snake_case
            let snake: String = name
                .chars()
                .enumerate()
                .flat_map(|(i, c)| {
                    if c.is_ascii_uppercase() && i > 0 {
                        vec!['_', c.to_ascii_lowercase()]
                    } else {
                        vec![c.to_ascii_lowercase()]
                    }
                })
                .collect();
            assert_eq!(Site::parse(&snake), Some(site), "parse {snake}");
        }
        assert_eq!(Site::parse("nope"), None);
    }

    #[test]
    fn guard_clears_on_drop() {
        {
            let _g = FaultGuard::install(FaultPlan::new(3).with(Site::ConnDrop, 1.0));
            assert!(is_active());
            assert!(should_fire(Site::ConnDrop));
        }
        assert!(!is_active());
        assert!(!should_fire(Site::ConnDrop));
    }
}
