//! Minimal JSON parser + writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`), CNN
//! config files and report emission. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII data).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after top-level value"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// Array element lookup; returns `Json::Null` out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(idx)).unwrap_or(&NULL)
    }

    // -- construction helpers --------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        let pad = |out: &mut String, d: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..d {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    v.write(out, depth + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, depth + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            map.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "roundtrip {s}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b").as_str(), Some("x\ny"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn pretty_is_reparseable() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr(vec![Json::str("a"), Json::Bool(true)])),
        ]);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(92.0).to_string(), "92");
        assert_eq!(Json::num(1.5).to_string(), "1.5");
    }
}
