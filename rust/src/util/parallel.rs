//! Scoped-thread data parallelism (rayon is unavailable in the offline
//! build; `std::thread::scope` provides the same fork-join guarantee
//! with zero dependencies).
//!
//! [`parallel_map`] fans a slice out over a dynamic work queue: workers
//! pull item indices from an atomic counter, so uneven per-item cost
//! (e.g. conv layers of very different sizes) still load-balances.
//! Results come back in input order, which keeps callers deterministic —
//! a parallel map over inference requests returns exactly what the
//! sequential loop would.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count for `n_items` parallel tasks: `available_parallelism`,
/// clamped to the item count and overridable via `DYNAMAP_THREADS`
/// (`DYNAMAP_THREADS=1` forces the sequential path, useful for
/// debugging and for apples-to-apples benchmarking).
pub fn worker_count(n_items: usize) -> usize {
    if n_items <= 1 {
        return 1;
    }
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let cap = std::env::var("DYNAMAP_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(hw);
    cap.min(n_items).max(1)
}

/// Apply `f` to every item of `items`, possibly in parallel, returning
/// the results in input order. `f` receives `(index, &item)`.
///
/// Work distribution is dynamic (atomic index queue). Worker panics are
/// re-raised on the caller thread, so a failing property test inside a
/// parallel section still reports its seed.
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    parallel_map_capped(items, 0, f)
}

/// [`parallel_map`] under an additional worker cap: at most `cap`
/// threads carry the fan-out (`0` = uncapped, identical to
/// `parallel_map`). This is how a multi-tenant batch flush honors its
/// model's thread-partition budget ([`crate::serve::sched`]) — the
/// global `DYNAMAP_THREADS` / `available_parallelism` ceiling still
/// applies on top, so a stale over-sized budget can never oversubscribe
/// the host.
pub fn parallel_map_capped<T, R, F>(items: &[T], cap: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let mut workers = worker_count(items.len());
    if cap > 0 {
        workers = workers.min(cap);
    }
    if workers <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(pairs) => {
                    for (i, r) in pairs {
                        out[i] = Some(r);
                    }
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    out.into_iter().map(|o| o.expect("parallel_map: missing result slot")).collect()
}

/// Run `f(0)`, `f(1)`, …, `f(n-1)` on `n` dedicated scoped threads and
/// collect the results in index order.
///
/// Unlike [`parallel_map`], this always spawns exactly `n` threads and
/// ignores `DYNAMAP_THREADS`: it models *concurrent callers* (blocking
/// closed-loop clients driving a serving queue, where each thread spends
/// its time waiting, not computing), not CPU-bound work items. Worker
/// panics are re-raised on the caller thread.
pub fn parallel_run<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if n <= 1 {
        return (0..n).map(&f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let f = &f;
                s.spawn(move || f(i))
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(r) => out[i] = Some(r),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    out.into_iter().map(|o| o.expect("parallel_run: missing result slot")).collect()
}

/// Produce/consume overlap for a pipeline of `n` sequential items:
/// while `consume(i, item_i)` runs on the caller thread, `produce(i+1)`
/// runs on one scoped helper thread, so item `i+1` is (usually) ready
/// the moment item `i` finishes. Consumption order is strictly
/// `0, 1, …, n-1` — this is double buffering, not a parallel map.
///
/// The kernel tier uses this to pack the next GEMM column-panel group
/// while the current one computes. Falls back to a sequential
/// pack-then-consume loop when `n <= 1` or the machine (or
/// `DYNAMAP_THREADS=1`) offers no second worker. Panics from either
/// side are re-raised on the caller thread.
pub fn double_buffered<T, P, C>(n: usize, produce: P, mut consume: C)
where
    T: Send,
    P: Fn(usize) -> T + Sync,
    C: FnMut(usize, T),
{
    if n <= 1 || worker_count(2) < 2 {
        for i in 0..n {
            consume(i, produce(i));
        }
        return;
    }
    std::thread::scope(|s| {
        let produce = &produce;
        let mut cur = Some(produce(0));
        for i in 0..n {
            let next = (i + 1 < n).then(|| s.spawn(move || produce(i + 1)));
            consume(i, cur.take().expect("double_buffered: missing item"));
            if let Some(h) = next {
                match h.join() {
                    Ok(v) => cur = Some(v),
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn handles_empty_and_singleton() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn matches_sequential_map() {
        let items: Vec<u64> = (0..1000).collect();
        let seq: Vec<u64> = items.iter().map(|&x| x.wrapping_mul(x) ^ 0xABCD).collect();
        let par = parallel_map(&items, |_, &x| x.wrapping_mul(x) ^ 0xABCD);
        assert_eq!(seq, par);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates() {
        let items: Vec<usize> = (0..64).collect();
        parallel_map(&items, |_, &x| {
            if x == 33 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn parallel_run_spawns_every_index() {
        assert!(parallel_run(0, |i| i).is_empty());
        assert_eq!(parallel_run(1, |i| i + 10), vec![10]);
        let out = parallel_run(17, |i| i * i);
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "client boom")]
    fn parallel_run_propagates_panics() {
        parallel_run(4, |i| {
            if i == 2 {
                panic!("client boom");
            }
            i
        });
    }

    #[test]
    fn double_buffered_consumes_in_order() {
        for n in [0usize, 1, 2, 7, 33] {
            let mut seen = Vec::new();
            double_buffered(n, |i| i * 3, |i, v| {
                assert_eq!(v, i * 3);
                seen.push(i);
            });
            assert_eq!(seen, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn double_buffered_matches_sequential() {
        let mut pipelined = Vec::new();
        double_buffered(100, |i| (i as u64).wrapping_mul(31) ^ 7, |_, v| pipelined.push(v));
        let sequential: Vec<u64> = (0..100).map(|i: u64| i.wrapping_mul(31) ^ 7).collect();
        assert_eq!(pipelined, sequential);
    }

    #[test]
    #[should_panic(expected = "producer boom")]
    fn double_buffered_propagates_producer_panics() {
        double_buffered(
            8,
            |i| {
                if i == 5 {
                    panic!("producer boom");
                }
                i
            },
            |_, _| {},
        );
    }

    #[test]
    #[should_panic(expected = "consumer boom")]
    fn double_buffered_propagates_consumer_panics() {
        double_buffered(8, |i| i, |i, _| {
            if i == 3 {
                panic!("consumer boom");
            }
        });
    }

    #[test]
    fn worker_count_bounds() {
        assert_eq!(worker_count(0), 1);
        assert_eq!(worker_count(1), 1);
        assert!(worker_count(4) <= 4);
        assert!(worker_count(1024) >= 1);
    }
}
