//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `subcommand --flag value --switch positional` style, with
//! `--key=value` and `--key value` both accepted.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, named options and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    ///
    /// `switch_names` lists flags that take no value; everything else
    /// starting with `--` consumes the following token as its value
    /// unless written as `--key=value`.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, switch_names: &[&str]) -> Args {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if switch_names.contains(&rest) {
                    args.switches.push(rest.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        args.switches.push(rest.to_string());
                    } else {
                        args.options.insert(rest.to_string(), it.next().unwrap());
                    }
                } else {
                    args.switches.push(rest.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn parse_env(switch_names: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), switch_names)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = Args::parse(sv(&["dse", "--model", "googlenet", "--dsp=6084", "extra"]), &[]);
        assert_eq!(a.subcommand.as_deref(), Some("dse"));
        assert_eq!(a.get("model"), Some("googlenet"));
        assert_eq!(a.get_usize("dsp", 0), 6084);
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn parses_switches() {
        let a = Args::parse(sv(&["run", "--verbose", "--out", "x.json"]), &["verbose"]);
        assert!(a.has("verbose"));
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn trailing_flag_is_switch() {
        let a = Args::parse(sv(&["run", "--json"]), &[]);
        assert!(a.has("json"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(sv(&[]), &[]);
        assert!(a.subcommand.is_none());
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("bw", 19.2), 19.2);
    }
}
