//! Minimal property-based testing harness (proptest is unavailable
//! offline). Runs a property over many seeded random cases and reports
//! the failing seed so cases are reproducible.

use super::rng::Rng;

/// Number of cases run per property (overridable via `DYNAMAP_PROPTEST_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("DYNAMAP_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

/// Run `prop` for `cases` seeds; panic with the seed on first failure.
///
/// The property receives a deterministic [`Rng`] it can draw its inputs
/// from and returns `Err(message)` to fail the case.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xD1A_0000 + case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{}' failed on case {} (seed {:#x}): {}",
                name, case, seed, msg
            );
        }
    }
}

/// Convenience: run with the default case count.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, default_cases(), prop);
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!(
                "mismatch at {}: {} vs {} (|Δ|={} > tol={})",
                i,
                x,
                y,
                (x - y).abs(),
                tol
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 16, |rng| {
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn check_reports_failures() {
        check("failing", 4, |_| Err("always".into()));
    }

    #[test]
    fn allclose() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-5, 1e-5).is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-5).is_err());
    }
}
