//! In-repo substrates replacing crates that are unavailable in the
//! offline build environment (serde, clap, proptest, criterion, prettytable).

pub mod json;
pub mod cli;
pub mod parallel;
pub mod rng;
pub mod proptest;
pub mod table;
