//! ASCII table rendering for reports, figures-as-text and benches.

/// A simple column-aligned table with a header row.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |w: &[usize]| {
            let mut s = String::from("+");
            for wi in w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s.push('\n');
            s
        };
        let fmt_row = |cells: &[String], w: &[usize]| {
            let mut s = String::from("|");
            for (c, wi) in cells.iter().zip(w) {
                s.push_str(&format!(" {:<width$} |", c, width = wi));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&w));
        out.push_str(&fmt_row(&self.header, &w));
        out.push_str(&line(&w));
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
        }
        out.push_str(&line(&w));
        out
    }

    /// Emit as CSV (for plotting outside).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals, trimming noise.
pub fn fnum(v: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, v)
}

/// Render a horizontal ASCII bar chart (one bar per label) — used to
/// visualize per-layer utilization figures in the terminal.
pub fn bar_chart(title: &str, items: &[(String, f64)], width: usize) -> String {
    let max = items.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-12);
    let lw = items.iter().map(|(l, _)| l.chars().count()).max().unwrap_or(0);
    let mut out = format!("== {} ==\n", title);
    for (label, v) in items {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "{:<lw$} | {:<width$} {:.4}\n",
            label,
            "#".repeat(n),
            v,
            lw = lw,
            width = width
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "22.5".into()]);
        let s = t.render();
        assert!(s.contains("| alpha | 1     |"));
        assert!(s.contains("| b     | 22.5  |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn bar_chart_renders() {
        let s = bar_chart("u", &[("l1".into(), 0.5), ("l2".into(), 1.0)], 10);
        assert!(s.contains("##########"));
    }
}
