//! Deterministic PRNG (xoshiro256**) — substrate for property tests and
//! synthetic workload generation; the `rand` crate is unavailable offline.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 seed gives a well-mixed state.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses rejection sampling to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in inclusive range `[lo, hi]`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Random i8 in `[-64, 63]` — a realistic INT8 activation/weight range.
    pub fn i8_small(&mut self) -> i8 {
        (self.below(128) as i64 - 64) as i8
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        // mean of U[0,1) over 10k samples should be close to 0.5
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
