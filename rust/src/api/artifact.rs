//! [`PlanArtifact`] — the cacheable boundary between offline DSE and
//! online serving.
//!
//! A plan artifact is a *versioned, fully round-trippable* serialization
//! of a [`Plan`]: `to_json ∘ from_json` preserves the architecture
//! parameters, the latency breakdown and the complete per-layer
//! algorithm/dataflow mapping, so DSE results are durable artifacts
//! keyed by `(model, device, config)` instead of values recomputed on
//! every process start. [`PlanCache`] implements that keying on disk.

use std::path::{Path, PathBuf};

use super::compiler::Compiler;
use super::error::DynamapError;
use crate::cost::conv::{Algo, ConvCost};
use crate::cost::gemm::Dataflow;
use crate::quant::Precision;
use crate::cost::graph_build::{LayerAssignment, MappingResult};
use crate::dse::Plan;
use crate::graph::Cnn;
use crate::util::json::Json;

/// A versioned, serializable DSE result.
#[derive(Debug, Clone)]
pub struct PlanArtifact {
    /// Schema version the artifact was written with.
    pub version: u64,
    /// Model name the plan was compiled for (must match the manifest's
    /// `model` field when handed to a session).
    pub model: String,
    /// Device name the plan targets.
    pub device: String,
    /// [`Compiler::fingerprint`] of the producing configuration.
    pub fingerprint: String,
    /// The full DSE output.
    pub plan: Plan,
}

impl PlanArtifact {
    /// Current schema version; [`PlanArtifact::from_json`] rejects
    /// artifacts written by a newer schema. Version history:
    /// 1 — initial staged-API schema; 2 — per-layer `precision` on
    /// every cost entry (older artifacts read back as all-f32).
    pub const SCHEMA_VERSION: u64 = 2;
    const SCHEMA_NAME: &'static str = "dynamap.plan-artifact";

    /// Wrap a freshly compiled [`Plan`] at the current schema version.
    pub fn new(model: String, device: String, fingerprint: String, plan: Plan) -> PlanArtifact {
        PlanArtifact { version: Self::SCHEMA_VERSION, model, device, fingerprint, plan }
    }

    /// Unwrap into the bare [`Plan`].
    pub fn into_plan(self) -> Plan {
        self.plan
    }

    // -- serialization ---------------------------------------------------

    /// Serialize to the versioned JSON schema (the exact form
    /// [`PlanArtifact::save`] writes to disk).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(Self::SCHEMA_NAME)),
            ("version", Json::num(self.version as f64)),
            ("model", Json::str(self.model.clone())),
            ("device", Json::str(self.device.clone())),
            ("fingerprint", Json::str(self.fingerprint.clone())),
            ("plan", plan_to_json(&self.plan)),
        ])
    }

    /// Parse an artifact from its JSON form, rejecting unknown schemas
    /// and versions newer than [`PlanArtifact::SCHEMA_VERSION`].
    pub fn from_json(j: &Json) -> Result<PlanArtifact, DynamapError> {
        let schema = j.get("schema").as_str().ok_or_else(|| bad("schema"))?;
        if schema != Self::SCHEMA_NAME {
            return Err(DynamapError::Artifact(format!(
                "unexpected schema '{schema}' (want '{}')",
                Self::SCHEMA_NAME
            )));
        }
        let version = j.get("version").as_u64().ok_or_else(|| bad("version"))?;
        if version > Self::SCHEMA_VERSION {
            return Err(DynamapError::Artifact(format!(
                "artifact schema version {version} is newer than supported version {}",
                Self::SCHEMA_VERSION
            )));
        }
        Ok(PlanArtifact {
            version,
            model: req_str(j, "model")?,
            device: req_str(j, "device")?,
            fingerprint: req_str(j, "fingerprint")?,
            plan: plan_from_json(j.get("plan"), version)?,
        })
    }

    /// Write the artifact (pretty JSON) to `path`, creating parent
    /// directories as needed.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), DynamapError> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| DynamapError::io(parent, e))?;
            }
        }
        std::fs::write(path, self.to_json().pretty()).map_err(|e| DynamapError::io(path, e))
    }

    /// Load an artifact previously written with [`PlanArtifact::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<PlanArtifact, DynamapError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| DynamapError::io(path, e))?;
        let j = Json::parse(&text).map_err(|e| DynamapError::json_in(path, e))?;
        PlanArtifact::from_json(&j)
    }
}

/// On-disk plan cache keyed by `(model, device, compiler fingerprint)`.
#[derive(Debug, Clone)]
pub struct PlanCache {
    /// Directory the cached plan artifacts live in.
    pub dir: PathBuf,
}

impl PlanCache {
    /// A cache rooted at `dir` (created lazily on first write).
    pub fn new(dir: impl Into<PathBuf>) -> PlanCache {
        PlanCache { dir: dir.into() }
    }

    /// Path a plan for `model` compiled by `compiler` lives at.
    pub fn path_for(&self, compiler: &Compiler, model: &str) -> PathBuf {
        self.dir.join(compiler.cache_file_name(model))
    }

    /// Load a cached plan if one exists *and* its fingerprint matches
    /// the compiler's current configuration.
    pub fn load(&self, compiler: &Compiler, model: &str) -> Option<PlanArtifact> {
        let a = PlanArtifact::load(self.path_for(compiler, model)).ok()?;
        (a.model == model && a.fingerprint == compiler.fingerprint()).then_some(a)
    }

    /// Return the cached plan when fresh, otherwise compile and persist
    /// it. The boolean is `true` when the plan came from the cache — on
    /// that path no DSE runs (observable via
    /// [`Compiler::compile_count`]).
    pub fn load_or_compile(
        &self,
        compiler: &Compiler,
        cnn: &Cnn,
    ) -> Result<(PlanArtifact, bool), DynamapError> {
        if let Some(a) = self.load(compiler, &cnn.name) {
            return Ok((a, true));
        }
        let a = compiler.compile(cnn)?;
        // the cache is an optimization: a compiled plan in hand must not
        // be discarded because the cache dir is unwritable — but the
        // caller asked for caching, so a failed write is worth a warning
        if let Err(e) = a.save(self.path_for(compiler, &cnn.name)) {
            eprintln!("warn: plan cache write failed: {e}");
        }
        Ok((a, false))
    }
}

// -- Plan (de)serialization ----------------------------------------------

fn bad(field: &str) -> DynamapError {
    DynamapError::Artifact(format!("missing or malformed field '{field}'"))
}

fn req_str(j: &Json, key: &str) -> Result<String, DynamapError> {
    Ok(j.get(key).as_str().ok_or_else(|| bad(key))?.to_string())
}

fn req_f64(j: &Json, key: &str) -> Result<f64, DynamapError> {
    j.get(key).as_f64().ok_or_else(|| bad(key))
}

fn req_usize(j: &Json, key: &str) -> Result<usize, DynamapError> {
    j.get(key).as_usize().ok_or_else(|| bad(key))
}

fn req_u64(j: &Json, key: &str) -> Result<u64, DynamapError> {
    j.get(key).as_u64().ok_or_else(|| bad(key))
}

fn algo_to_json(a: Algo) -> Json {
    match a {
        Algo::Im2col => Json::obj(vec![("kind", Json::str("im2col"))]),
        Algo::Kn2row => Json::obj(vec![("kind", Json::str("kn2row"))]),
        Algo::Winograd { m, r } => Json::obj(vec![
            ("kind", Json::str("winograd")),
            ("m", Json::num(m as f64)),
            ("r", Json::num(r as f64)),
        ]),
        Algo::WinogradStrided { m, r } => Json::obj(vec![
            ("kind", Json::str("winograd-strided")),
            ("m", Json::num(m as f64)),
            ("r", Json::num(r as f64)),
        ]),
    }
}

fn algo_from_json(j: &Json) -> Result<Algo, DynamapError> {
    let kind = j.get("kind").as_str().ok_or_else(|| bad("algo.kind"))?;
    match kind {
        "im2col" => Ok(Algo::Im2col),
        "kn2row" => Ok(Algo::Kn2row),
        "winograd" | "winograd-strided" => {
            let m = req_usize(j, "m")?;
            let r = req_usize(j, "r")?;
            Ok(if kind == "winograd" {
                Algo::Winograd { m, r }
            } else {
                Algo::WinogradStrided { m, r }
            })
        }
        other => Err(DynamapError::Artifact(format!("unknown algorithm kind '{other}'"))),
    }
}

fn dataflow_from_str(s: &str) -> Result<Dataflow, DynamapError> {
    match s {
        "NS" => Ok(Dataflow::NS),
        "WS" => Ok(Dataflow::WS),
        "IS" => Ok(Dataflow::IS),
        other => Err(DynamapError::Artifact(format!("unknown dataflow '{other}'"))),
    }
}

fn cost_to_json(c: &ConvCost) -> Json {
    let (a, b, cc, calls) = c.gemm;
    Json::obj(vec![
        ("algo", algo_to_json(c.algo)),
        ("precision", Json::str(c.precision.name())),
        ("dataflow", Json::str(c.dataflow.name())),
        ("cycles", Json::num(c.cycles as f64)),
        ("seconds", Json::num(c.seconds)),
        ("macs", Json::num(c.macs as f64)),
        ("utilization", Json::num(c.utilization)),
        (
            "gemm",
            Json::arr([
                Json::num(a as f64),
                Json::num(b as f64),
                Json::num(cc as f64),
                Json::num(calls as f64),
            ]),
        ),
    ])
}

fn precision_from_json(j: &Json, version: u64) -> Result<Precision, DynamapError> {
    match j.get("precision").as_str() {
        // only schema version 1 artifacts — which predate the precision
        // axis and are all-f32 by construction — may omit the key; a
        // v2 artifact without it is corrupt, not implicitly f32
        None if version < 2 => Ok(Precision::F32),
        None => Err(bad("precision")),
        Some("f32") => Ok(Precision::F32),
        Some("int8") => Ok(Precision::Int8),
        Some(other) => {
            Err(DynamapError::Artifact(format!("unknown precision '{other}'")))
        }
    }
}

fn cost_from_json(j: &Json, version: u64) -> Result<ConvCost, DynamapError> {
    let g = j.get("gemm");
    let gemm = (
        g.at(0).as_usize().ok_or_else(|| bad("gemm[0]"))?,
        g.at(1).as_usize().ok_or_else(|| bad("gemm[1]"))?,
        g.at(2).as_usize().ok_or_else(|| bad("gemm[2]"))?,
        g.at(3).as_usize().ok_or_else(|| bad("gemm[3]"))?,
    );
    Ok(ConvCost {
        algo: algo_from_json(j.get("algo"))?,
        precision: precision_from_json(j, version)?,
        dataflow: dataflow_from_str(
            j.get("dataflow").as_str().ok_or_else(|| bad("dataflow"))?,
        )?,
        cycles: req_u64(j, "cycles")?,
        seconds: req_f64(j, "seconds")?,
        macs: req_u64(j, "macs")?,
        utilization: req_f64(j, "utilization")?,
        gemm,
    })
}

fn mapping_to_json(m: &MappingResult) -> Json {
    let layers = m
        .layers
        .iter()
        .map(|l| {
            Json::obj(vec![
                ("node", Json::num(l.node as f64)),
                ("name", Json::str(l.name.clone())),
                ("cost", cost_to_json(&l.cost)),
            ])
        })
        .collect::<Vec<_>>();
    Json::obj(vec![
        (
            "assignment",
            Json::arr(m.assignment.iter().map(|&a| Json::num(a as f64))),
        ),
        ("total_sec", Json::num(m.total_sec)),
        ("compute_sec", Json::num(m.compute_sec)),
        ("transition_sec", Json::num(m.transition_sec)),
        ("layers", Json::Arr(layers)),
    ])
}

fn mapping_from_json(j: &Json, version: u64) -> Result<MappingResult, DynamapError> {
    let assignment = j
        .get("assignment")
        .as_arr()
        .ok_or_else(|| bad("assignment"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| bad("assignment[]")))
        .collect::<Result<Vec<_>, _>>()?;
    let mut layers = Vec::new();
    for lj in j.get("layers").as_arr().ok_or_else(|| bad("layers"))? {
        layers.push(LayerAssignment {
            node: req_usize(lj, "node")?,
            name: req_str(lj, "name")?,
            cost: cost_from_json(lj.get("cost"), version)?,
        });
    }
    Ok(MappingResult {
        assignment,
        total_sec: req_f64(j, "total_sec")?,
        compute_sec: req_f64(j, "compute_sec")?,
        transition_sec: req_f64(j, "transition_sec")?,
        layers,
    })
}

fn plan_to_json(p: &Plan) -> Json {
    Json::obj(vec![
        ("cnn", Json::str(p.cnn_name.clone())),
        ("p1", Json::num(p.p1 as f64)),
        ("p2", Json::num(p.p2 as f64)),
        ("tau_sec", Json::num(p.tau_sec)),
        ("latency_ms", Json::num(p.total_latency_ms)),
        ("throughput_gops", Json::num(p.throughput_gops)),
        ("mapping", mapping_to_json(&p.mapping)),
    ])
}

fn plan_from_json(j: &Json, version: u64) -> Result<Plan, DynamapError> {
    Ok(Plan {
        cnn_name: req_str(j, "cnn")?,
        p1: req_usize(j, "p1")?,
        p2: req_usize(j, "p2")?,
        tau_sec: req_f64(j, "tau_sec")?,
        total_latency_ms: req_f64(j, "latency_ms")?,
        throughput_gops: req_f64(j, "throughput_gops")?,
        mapping: mapping_from_json(j.get("mapping"), version)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::Device;
    use crate::graph::zoo;

    fn compile_mini() -> PlanArtifact {
        Compiler::new()
            .device(Device::small_edge())
            .compile(&zoo::mini_inception())
            .unwrap()
    }

    #[test]
    fn round_trip_preserves_everything() {
        let a = compile_mini();
        // through the string form, exactly as it hits disk
        let text = a.to_json().pretty();
        let b = PlanArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();

        assert_eq!(b.version, PlanArtifact::SCHEMA_VERSION);
        assert_eq!(b.model, a.model);
        assert_eq!(b.device, a.device);
        assert_eq!(b.fingerprint, a.fingerprint);
        // architecture + latency survive bit-exactly (f64 Display is
        // shortest-round-trip)
        assert_eq!((b.plan.p1, b.plan.p2), (a.plan.p1, a.plan.p2));
        assert_eq!(b.plan.tau_sec, a.plan.tau_sec);
        assert_eq!(b.plan.total_latency_ms, a.plan.total_latency_ms);
        assert_eq!(b.plan.throughput_gops, a.plan.throughput_gops);
        assert_eq!(b.plan.mapping.total_sec, a.plan.mapping.total_sec);
        assert_eq!(b.plan.mapping.compute_sec, a.plan.mapping.compute_sec);
        assert_eq!(b.plan.mapping.transition_sec, a.plan.mapping.transition_sec);
        assert_eq!(b.plan.mapping.assignment, a.plan.mapping.assignment);
        // the full per-layer algorithm/dataflow mapping
        assert_eq!(b.plan.mapping.layers.len(), a.plan.mapping.layers.len());
        for (x, y) in a.plan.mapping.layers.iter().zip(&b.plan.mapping.layers) {
            assert_eq!(x.node, y.node);
            assert_eq!(x.name, y.name);
            assert_eq!(x.cost, y.cost);
        }
    }

    // (on-disk save/load round-trip is covered at the crate surface in
    // rust/tests/dse_pipeline.rs::plan_artifact_roundtrip_and_cache)

    #[test]
    fn version1_artifacts_read_back_as_all_f32() {
        // schema v1 predates the precision axis: strip every
        // "precision" key and mark the artifact v1 — it must parse,
        // with every layer cost defaulting to f32
        let a = compile_mini();
        let mut j = a.to_json();
        fn strip(j: &mut Json) {
            match j {
                Json::Obj(m) => {
                    m.remove("precision");
                    for v in m.values_mut() {
                        strip(v);
                    }
                }
                Json::Arr(v) => {
                    for x in v.iter_mut() {
                        strip(x);
                    }
                }
                _ => {}
            }
        }
        strip(&mut j);
        // same stripped payload at version 2: corrupt, not implicitly f32
        let e = PlanArtifact::from_json(&j).unwrap_err();
        assert!(matches!(e, DynamapError::Artifact(_)), "{e}");
        if let Json::Obj(m) = &mut j {
            m.insert("version".into(), Json::num(1.0));
        }
        let b = PlanArtifact::from_json(&j).unwrap();
        assert_eq!(b.version, 1);
        assert!(!b.plan.mapping.layers.is_empty());
        assert!(b
            .plan
            .mapping
            .layers
            .iter()
            .all(|l| l.cost.precision == Precision::F32));
        // and an explicit unknown precision is a typed error
        let text = a.to_json().pretty().replace("\"f32\"", "\"int4\"");
        let e = PlanArtifact::from_json(&Json::parse(&text).unwrap()).unwrap_err();
        assert!(matches!(e, DynamapError::Artifact(_)), "{e}");
    }

    #[test]
    fn rejects_future_schema_and_garbage() {
        let a = compile_mini();
        if let Json::Obj(mut m) = a.to_json() {
            m.insert("version".into(), Json::num(999.0));
            let e = PlanArtifact::from_json(&Json::Obj(m)).unwrap_err();
            assert!(matches!(e, DynamapError::Artifact(_)), "{e}");
        } else {
            panic!("artifact json is not an object");
        }
        let e = PlanArtifact::from_json(&Json::parse("{}").unwrap()).unwrap_err();
        assert!(matches!(e, DynamapError::Artifact(_)), "{e}");
        assert!(PlanArtifact::load("/no/such/plan.json").is_err());
    }

    #[test]
    fn cache_hit_skips_dse() {
        let cnn = zoo::mini_inception();
        let compiler = Compiler::new().device(Device::small_edge());
        let dir = std::env::temp_dir().join(format!("dynamap_cache_{}", std::process::id()));
        let cache = PlanCache::new(&dir);
        std::fs::remove_file(cache.path_for(&compiler, &cnn.name)).ok();

        let (a, cached) = cache.load_or_compile(&compiler, &cnn).unwrap();
        assert!(!cached);
        assert_eq!(compiler.compile_count(), 1);

        // second resolution: served from disk, no CostGraph::build runs
        let (b, cached) = cache.load_or_compile(&compiler, &cnn).unwrap();
        assert!(cached);
        assert_eq!(compiler.compile_count(), 1, "cached path must not re-run the DSE");
        assert_eq!(b.plan.total_latency_ms, a.plan.total_latency_ms);
        assert_eq!(b.plan.mapping.assignment, a.plan.mapping.assignment);

        // a different configuration misses the cache
        let other = Compiler::new().device(Device::small_edge()).wino(4, 3);
        assert!(cache.load(&other, &cnn.name).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
