//! [`DynamapError`] — the crate-wide typed error.
//!
//! Every fallible operation on the public `Compiler → PlanArtifact →
//! Session` pipeline (and the lower-level `dse`, `runtime`, `coordinator`
//! and `emit` entry points it subsumes) returns `Result<_, DynamapError>`
//! instead of the stringly-typed `Result<_, String>` of the first
//! release. Variants are grouped by the subsystem that raised them so
//! callers can branch on failure class without parsing messages.

use crate::util::json::JsonError;
use std::fmt;
use std::path::PathBuf;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DynamapError>;

/// The typed error for every DYNAMAP pipeline stage.
#[derive(Debug)]
pub enum DynamapError {
    /// Filesystem failure, with the path that was being touched.
    Io { path: PathBuf, source: std::io::Error },
    /// JSON syntax error (manifest, CNN config or plan artifact).
    Json { path: Option<PathBuf>, source: JsonError },
    /// The AOT artifact manifest violates its contract (missing layer,
    /// bad field, weight-count mismatch, …).
    Manifest(String),
    /// PJRT runtime failure (client creation, HLO parse/compile,
    /// execution, result transfer).
    Runtime(String),
    /// CNN graph construction or validation failure.
    Graph(String),
    /// DSE configuration or search failure (empty `P_SA` sweep,
    /// degenerate bounds, …).
    Dse(String),
    /// Contradictory or invalid builder configuration.
    Config(String),
    /// Tensor shape mismatch on the serving path.
    Shape { context: String, expected: usize, got: usize },
    /// The artifact manifest names a model the zoo does not know.
    UnknownModel(String),
    /// A plan artifact violates the versioned schema.
    Artifact(String),
    /// Multi-model serving failure (batch flush failure, missing
    /// artifacts for a hosted model, …).
    Serve(String),
    /// A serving queue was already shut down when the request arrived —
    /// typically a registry LRU eviction racing a submit. Retrying
    /// against a freshly resolved host is safe and
    /// [`crate::serve::ModelRegistry::infer`] does so transparently.
    QueueClosed {
        /// Model whose queue was gone.
        model: String,
    },
    /// Admission control shed the request: the model's bounded in-flight
    /// budget was full, so the request was rejected *before* entering
    /// the batch queue instead of growing it unboundedly. Retriable —
    /// `retry_after_ms` is the server's backoff hint, derived from the
    /// model's recent batch latency.
    Overloaded {
        /// Model whose in-flight budget was exhausted.
        model: String,
        /// Suggested client backoff before retrying, milliseconds (≥ 1).
        retry_after_ms: u64,
    },
    /// The request's deadline expired before compute ran: either it
    /// arrived already expired, or it aged out while waiting in the
    /// batch queue. The request is shed *before* occupying a batch
    /// slot (or dropped at dequeue), so late work never wastes device
    /// time. Not retriable as-is — the caller must mint a new deadline.
    DeadlineExceeded {
        /// Model the expired request was addressed to.
        model: String,
        /// How long the request waited before being shed, milliseconds
        /// (0 when it arrived already expired).
        waited_ms: u64,
    },
    /// A wire-protocol violation on the network front-end: bad magic,
    /// unsupported version, truncated frame, oversized payload or a
    /// malformed frame body. The server replies with a typed protocol
    /// error frame (when the socket still permits) and closes the
    /// connection; the serving engine itself is unaffected.
    Protocol(String),
    /// Network transport failure (connect, read or write on the TCP
    /// front-end). Distinct from [`DynamapError::Protocol`]: the bytes
    /// never arrived, rather than arriving malformed. Inference requests
    /// are stateless, so retrying on a fresh connection is safe.
    Net(String),
}

impl DynamapError {
    /// Wrap an I/O error with the path it occurred on.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> DynamapError {
        DynamapError::Io { path: path.into(), source }
    }

    /// Wrap a JSON parse error with the file it came from.
    pub fn json_in(path: impl Into<PathBuf>, source: JsonError) -> DynamapError {
        DynamapError::Json { path: Some(path.into()), source }
    }
}

impl fmt::Display for DynamapError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DynamapError::Io { path, source } => {
                write!(f, "io error on {}: {}", path.display(), source)
            }
            DynamapError::Json { path: Some(p), source } => {
                write!(f, "{}: {}", p.display(), source)
            }
            DynamapError::Json { path: None, source } => write!(f, "{}", source),
            DynamapError::Manifest(m) => write!(f, "manifest error: {}", m),
            DynamapError::Runtime(m) => write!(f, "runtime error: {}", m),
            DynamapError::Graph(m) => write!(f, "graph error: {}", m),
            DynamapError::Dse(m) => write!(f, "dse error: {}", m),
            DynamapError::Config(m) => write!(f, "config error: {}", m),
            DynamapError::Shape { context, expected, got } => {
                write!(f, "shape error: {} expected {} elements, got {}", context, expected, got)
            }
            DynamapError::UnknownModel(m) => {
                write!(f, "unknown model '{}': not in the zoo registry", m)
            }
            DynamapError::Artifact(m) => write!(f, "plan artifact error: {}", m),
            DynamapError::Serve(m) => write!(f, "serving error: {}", m),
            DynamapError::QueueClosed { model } => {
                write!(f, "serving error: queue for model '{}' is shut down", model)
            }
            DynamapError::Overloaded { model, retry_after_ms } => {
                write!(
                    f,
                    "overloaded: model '{}' shed the request (retry after {} ms)",
                    model, retry_after_ms
                )
            }
            DynamapError::DeadlineExceeded { model, waited_ms } => {
                write!(
                    f,
                    "deadline exceeded: model '{}' shed the request after {} ms in queue",
                    model, waited_ms
                )
            }
            DynamapError::Protocol(m) => write!(f, "protocol error: {}", m),
            DynamapError::Net(m) => write!(f, "network error: {}", m),
        }
    }
}

impl std::error::Error for DynamapError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DynamapError::Io { source, .. } => Some(source),
            DynamapError::Json { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<JsonError> for DynamapError {
    fn from(e: JsonError) -> DynamapError {
        DynamapError::Json { path: None, source: e }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::error::Error;

    fn io_err(msg: &str) -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, msg)
    }

    #[test]
    fn display_carries_context() {
        let e = DynamapError::io("/tmp/x.json", io_err("denied"));
        let s = e.to_string();
        assert!(s.contains("/tmp/x.json"), "{s}");
        assert!(s.contains("denied"), "{s}");

        let e = DynamapError::Shape { context: "input".into(), expected: 1024, got: 7 };
        let s = e.to_string();
        assert!(s.contains("1024") && s.contains("7"), "{s}");

        let e = DynamapError::UnknownModel("resnet-99".into());
        assert!(e.to_string().contains("resnet-99"));

        let e = DynamapError::Overloaded { model: "mini".into(), retry_after_ms: 7 };
        let s = e.to_string();
        assert!(s.contains("mini") && s.contains("7 ms"), "{s}");

        let e = DynamapError::DeadlineExceeded { model: "mini".into(), waited_ms: 12 };
        let s = e.to_string();
        assert!(s.contains("mini") && s.contains("12 ms"), "{s}");

        let e = DynamapError::Protocol("bad magic 0xBEEF".into());
        assert!(e.to_string().contains("bad magic"), "{e}");

        let e = DynamapError::Net("connection refused".into());
        assert!(e.to_string().contains("connection refused"), "{e}");
    }

    #[test]
    fn io_and_json_expose_source() {
        let e = DynamapError::io("x", io_err("boom"));
        assert!(e.source().is_some());
        let e: DynamapError =
            crate::util::json::Json::parse("{bad").unwrap_err().into();
        assert!(e.source().is_some());
        assert!(DynamapError::Dse("x".into()).source().is_none());
    }
}
