//! [`Compiler`] — the offline stage of the staged pipeline.
//!
//! A fluent builder over the DYNAMAP DSE flow (Fig. 7): configure the
//! target device, Winograd tile, mapping policy and search bounds, then
//! [`Compiler::compile`] a CNN into a versioned [`PlanArtifact`]. The
//! expensive work (Algorithm 1 sweep + cost-graph construction + PBQP
//! solve) happens exactly once per `compile` call; the artifact is a
//! cacheable value keyed by `(model, device, config)` — see
//! [`crate::api::artifact::PlanCache`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::artifact::PlanArtifact;
use super::error::DynamapError;
use crate::cost::gemm::Dataflow;
use crate::cost::graph_build::{CostGraph, Policy};
use crate::cost::{Device, DeviceCalibration, KernelThroughput};
use crate::dse::algo1::{identify_parameters_bounded, Algo1Result};
use crate::dse::{DseConfig, Plan};
use crate::graph::Cnn;

/// The offline compiler: device + model hyper-parameters + mapping
/// policy, evaluated once into a [`PlanArtifact`].
///
/// The README's library quickstart, as a compiled example — run the
/// DSE once, persist the versioned plan, reuse it across processes:
///
/// ```no_run
/// use dynamap::api::Compiler;
/// use dynamap::graph::zoo;
///
/// // offline: run the DSE once (Algorithm 1 + cost graph + PBQP) …
/// let artifact = Compiler::new().compile(&zoo::googlenet())?;
/// println!(
///     "P_SA = {}×{}, latency = {:.3} ms",
///     artifact.plan.p1, artifact.plan.p2, artifact.plan.total_latency_ms
/// );
/// // … and persist the versioned artifact for later sessions
/// artifact.save("plans/googlenet.json")?;
///
/// // opt into the precision axis: the DSE may map layers to int8
/// let quantized = Compiler::new()
///     .precision_search(true)
///     .compile(&zoo::googlenet())?;
/// println!("{:?}", quantized.plan.algo_histogram());
/// # Ok::<(), dynamap::api::DynamapError>(())
/// ```
#[derive(Debug, Clone)]
pub struct Compiler {
    config: DseConfig,
    policy: Option<Policy>,
    fixed_shape: Option<(usize, usize)>,
    /// `true` once the caller has set explicit `P_SA1` bounds, so a
    /// later [`Compiler::device`] call does not clobber them.
    bounds_overridden: bool,
    /// Probe: how many times this compiler (and its clones) actually ran
    /// the DSE. Shared across clones so cache tests can assert that a
    /// cached path performed zero compilations.
    compiles: Arc<AtomicUsize>,
}

impl Default for Compiler {
    fn default() -> Compiler {
        Compiler::new()
    }
}

impl Compiler {
    /// A compiler targeting the paper's evaluation setup (Alveo U200,
    /// 6084-DSP cap, F(2×2, 3×3), optimal PBQP mapping).
    pub fn new() -> Compiler {
        Compiler::from_config(DseConfig::alveo_u200())
    }

    /// Wrap an explicit [`DseConfig`] (optimal mapping by default).
    pub fn from_config(config: DseConfig) -> Compiler {
        Compiler {
            config,
            policy: None,
            fixed_shape: None,
            bounds_overridden: false,
            compiles: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// The underlying configuration.
    pub fn config(&self) -> &DseConfig {
        &self.config
    }

    /// Retarget to a different device; resets the Algorithm-1 sweep
    /// bounds to `P_SA1 ∈ [2, dsp_cap]` unless [`Compiler::p1_bounds`]
    /// was already called on this builder.
    pub fn device(mut self, device: Device) -> Compiler {
        let cap = device.dsp_cap;
        self.config.device = device;
        if !self.bounds_overridden {
            self.config.p1_lo = 2;
            self.config.p1_hi = cap;
        }
        self
    }

    /// Winograd tile: `F(m×m, r×r)`.
    pub fn wino(mut self, m: usize, r: usize) -> Compiler {
        self.config.wino_m = m;
        self.config.wino_r = r;
        self
    }

    /// Enable the strided-Winograd future-work extension (§7).
    pub fn strided_winograd(mut self, on: bool) -> Compiler {
        self.config.strided_winograd = on;
        self
    }

    /// Search precision as a second mapping dimension: each conv
    /// vertex's PBQP domain widens from {algorithm × dataflow} to
    /// {algorithm × dataflow × precision}, with int8 choices priced at
    /// the device's DSP-packing throughput
    /// ([`Device::int8_macs_per_dsp`]), requantization costs on edges
    /// whose endpoints disagree, and Winograd constrained to f32 (see
    /// [`crate::quant`]). Off by default because quantization changes
    /// numerics; plans compiled either way never collide in a
    /// [`super::PlanCache`] — the flag is part of the fingerprint.
    pub fn precision_search(mut self, on: bool) -> Compiler {
        self.config.precision_search = on;
        self
    }

    /// Force a single dataflow (the NS-only baselines of Figs. 9/10).
    pub fn force_dataflow(mut self, df: Dataflow) -> Compiler {
        self.config.force_dataflow = Some(df);
        self
    }

    /// Apply a profile-fitted [`DeviceCalibration`] to the cost model:
    /// every plan this compiler produces prices each algorithm family
    /// at its observed (rather than purely analytic) latency. The
    /// calibration is part of [`Compiler::fingerprint`], so calibrated
    /// and uncalibrated plans never collide in a [`super::PlanCache`].
    pub fn calibration(mut self, calibration: DeviceCalibration) -> Compiler {
        self.config.calibration = calibration;
        self
    }

    /// Fold a measured host-microkernel throughput table
    /// ([`crate::kernels::KernelSelector::measure`]) into the cost
    /// model: f32 layer latencies are then priced from the host SIMD
    /// GEMM rate (per-shape tile occupancy + per-call overhead)
    /// instead of the analytic overlay cycles, so the mapping the DSE
    /// returns is optimal for what the native serving path actually
    /// runs. Part of [`Compiler::fingerprint`] — plans priced by
    /// different tables never collide in a [`super::PlanCache`].
    pub fn microkernels(mut self, table: KernelThroughput) -> Compiler {
        self.config.microkernels = table;
        self
    }

    /// Measure the host's microkernel tiers right now
    /// ([`crate::kernels::KernelSelector::measure`]) and fold the
    /// resulting table in, as [`Compiler::microkernels`] would. This is
    /// the `dynamap --measure` path: one ~10 ms calibration at startup
    /// buys a cost model priced from *this* machine's measured GEMM
    /// throughput. Each timed kernel emits a `measure` span when a
    /// recorder is installed ([`crate::obs`]).
    pub fn measure_microkernels(self) -> Compiler {
        let table = crate::kernels::KernelSelector::probed().measure();
        self.microkernels(table)
    }

    /// `P_SA1` sweep bounds for Algorithm 1. Survives a later
    /// [`Compiler::device`] call.
    pub fn p1_bounds(mut self, lo: usize, hi: usize) -> Compiler {
        self.config.p1_lo = lo;
        self.config.p1_hi = hi;
        self.bounds_overridden = true;
        self
    }

    /// Toggle DSE step 5's consecutive-layer on-chip hand-offs.
    pub fn sram_fuse(mut self, on: bool) -> Compiler {
        self.config.opts.sram_fuse = on;
        self
    }

    /// Toggle overlapping weight streaming with compute.
    pub fn overlap_weight_load(mut self, on: bool) -> Compiler {
        self.config.opts.overlap_weight_load = on;
        self
    }

    /// Map with a fixed baseline policy (bl3–bl5/greedy of §6.1.2)
    /// instead of the optimal PBQP solve.
    pub fn policy(mut self, policy: Policy) -> Compiler {
        self.policy = Some(policy);
        self
    }

    /// Restore the default optimal PBQP mapping.
    pub fn optimal(mut self) -> Compiler {
        self.policy = None;
        self
    }

    /// Skip Algorithm 1 and use a fixed systolic-array shape (the
    /// square-NS baseline bl1 of Figs. 9/10).
    pub fn fixed_shape(mut self, p1: usize, p2: usize) -> Compiler {
        self.fixed_shape = Some((p1, p2));
        self
    }

    /// How many times this compiler (including clones handed to a
    /// session builder) ran the full DSE. Plan-cache tests use this to
    /// assert the cached path never rebuilds the cost graph.
    pub fn compile_count(&self) -> usize {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Stable fingerprint of everything that influences the produced
    /// plan: device meta data, hyper-parameters, search bounds, policy
    /// and fixed shape. Two compilers with equal fingerprints produce
    /// identical plans for the same model, so the fingerprint keys the
    /// on-disk plan cache.
    pub fn fingerprint(&self) -> String {
        let c = &self.config;
        let d = &c.device;
        let policy = match self.policy {
            None => "optimal",
            Some(Policy::Im2colOnly) => "im2col-only",
            Some(Policy::Kn2rowApplied) => "kn2row-applied",
            Some(Policy::WinoApplied) => "wino-applied",
            Some(Policy::Greedy) => "greedy",
        };
        let df = match c.force_dataflow {
            None => "-".to_string(),
            Some(df) => df.name().to_string(),
        };
        let shape = match self.fixed_shape {
            None => "-".to_string(),
            Some((p1, p2)) => format!("{p1}x{p2}"),
        };
        let desc = format!(
            "{}|{}|{}|{}|{}|{}|{}|pack{}|{}|wino{}x{}|strided{}|prec{}|df{}|owl{}|fuse{}|p1[{},{}]|{}|cal{}|mk{}|{}",
            d.name,
            d.dsp_cap,
            d.freq_mhz,
            d.ddr_gbps,
            d.burst_len,
            d.sram_bytes,
            d.pool_units,
            d.int8_macs_per_dsp,
            policy,
            c.wino_m,
            c.wino_r,
            c.strided_winograd,
            c.precision_search,
            df,
            c.opts.overlap_weight_load,
            c.opts.sram_fuse,
            c.p1_lo,
            c.p1_hi,
            shape,
            c.calibration.describe(),
            c.microkernels.describe(),
            PlanArtifact::SCHEMA_VERSION,
        );
        format!("{:016x}", fnv1a(&desc))
    }

    /// File name a cached plan for `model` is stored under.
    pub fn cache_file_name(&self, model: &str) -> String {
        format!(
            "plan__{}__{}__{}.json",
            sanitize(model),
            sanitize(&self.config.device.name),
            self.fingerprint()
        )
    }

    /// Algorithm 1 only (Fig. 7 step ①).
    pub fn identify(&self, cnn: &Cnn) -> Result<Algo1Result, DynamapError> {
        self.check_bounds()?;
        Ok(identify_parameters_bounded(
            cnn,
            &self.config.cost_model(),
            self.config.device.dsp_cap,
            self.config.p1_lo,
            self.config.p1_hi,
        ))
    }

    /// Cost-graph construction for a fixed array shape (Fig. 7 step ②).
    pub fn build_graph(&self, cnn: &Cnn, p1: usize, p2: usize) -> CostGraph {
        CostGraph::build(
            cnn,
            &self.config.cost_model(),
            &self.config.transition_model(),
            p1,
            p2,
            self.config.opts,
        )
    }

    /// Run the staged DSE (Fig. 7 steps ①–③) and package the result as
    /// a versioned, cacheable [`PlanArtifact`].
    pub fn compile(&self, cnn: &Cnn) -> Result<PlanArtifact, DynamapError> {
        cnn.validate().map_err(DynamapError::Graph)?;
        let arch = match self.fixed_shape {
            Some((p1, p2)) => {
                if p1 == 0 || p2 == 0 {
                    return Err(DynamapError::Dse(format!(
                        "fixed shape {p1}x{p2} has a zero dimension"
                    )));
                }
                Algo1Result { p1, p2, tau_sec: 0.0, dataflow: Default::default() }
            }
            None => self.identify(cnn)?,
        };
        let graph = self.build_graph(cnn, arch.p1, arch.p2);
        let mapping = match self.policy {
            None => graph.solve(cnn),
            Some(p) => graph.solve_policy(cnn, p),
        };
        self.compiles.fetch_add(1, Ordering::Relaxed);

        let total_latency_ms = mapping.total_sec * 1e3;
        let throughput_gops = cnn.total_gops() / mapping.total_sec;
        let plan = Plan {
            cnn_name: cnn.name.clone(),
            p1: arch.p1,
            p2: arch.p2,
            tau_sec: arch.tau_sec,
            mapping,
            total_latency_ms,
            throughput_gops,
        };
        Ok(PlanArtifact::new(
            cnn.name.clone(),
            self.config.device.name.clone(),
            self.fingerprint(),
            plan,
        ))
    }

    fn check_bounds(&self) -> Result<(), DynamapError> {
        let c = &self.config;
        if c.device.dsp_cap == 0 {
            return Err(DynamapError::Dse("device has a zero DSP budget".into()));
        }
        if c.p1_lo == 0 {
            return Err(DynamapError::Dse("P_SA1 lower bound must be >= 1".into()));
        }
        if c.p1_lo > c.p1_hi.min(c.device.dsp_cap) {
            return Err(DynamapError::Dse(format!(
                "empty P_SA sweep: lo {} > min(hi {}, dsp_cap {})",
                c.p1_lo, c.p1_hi, c.device.dsp_cap
            )));
        }
        Ok(())
    }
}

/// Make a model/device name safe for use in a file name (shared with
/// the emit package writer).
pub(crate) fn sanitize(s: &str) -> String {
    s.chars().map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' }).collect()
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    fn small() -> Compiler {
        Compiler::new().device(Device::small_edge())
    }

    #[test]
    fn compiles_mini_end_to_end() {
        let c = small();
        let a = c.compile(&zoo::mini_inception()).unwrap();
        assert!(a.plan.total_latency_ms > 0.0);
        assert!(a.plan.throughput_gops > 0.0);
        assert_eq!(a.plan.mapping.layers.len(), 7);
        assert_eq!(a.model, "mini-inception");
        assert_eq!(a.device, "small-edge");
        assert_eq!(c.compile_count(), 1);
    }

    #[test]
    fn optimal_beats_every_policy() {
        let cnn = zoo::mini_inception();
        let opt = small().compile(&cnn).unwrap().plan.total_latency_ms;
        for p in
            [Policy::Im2colOnly, Policy::Kn2rowApplied, Policy::WinoApplied, Policy::Greedy]
        {
            let bl = small().policy(p).compile(&cnn).unwrap().plan.total_latency_ms;
            assert!(opt <= bl + 1e-9, "OPT {opt} > {p:?} {bl}");
        }
    }

    #[test]
    fn fixed_shape_skips_algorithm1() {
        let a = small().fixed_shape(16, 16).compile(&zoo::mini_inception()).unwrap();
        assert_eq!((a.plan.p1, a.plan.p2), (16, 16));
        assert_eq!(a.plan.tau_sec, 0.0);
    }

    #[test]
    fn fingerprint_tracks_config() {
        let base = Compiler::new();
        assert_eq!(base.fingerprint(), Compiler::new().fingerprint());
        assert_ne!(base.fingerprint(), Compiler::new().wino(4, 3).fingerprint());
        assert_ne!(
            base.fingerprint(),
            Compiler::new().policy(Policy::Greedy).fingerprint()
        );
        assert_ne!(base.fingerprint(), Compiler::new().fixed_shape(78, 78).fingerprint());
        assert_ne!(
            base.fingerprint(),
            Compiler::new().device(Device::small_edge()).fingerprint()
        );
        // a non-identity calibration keys a distinct plan-cache entry
        assert_ne!(
            base.fingerprint(),
            Compiler::new()
                .calibration(DeviceCalibration::default().with("kn2row", 2.0, 0.0))
                .fingerprint()
        );
        // precision search keys a distinct plan-cache entry too
        assert_ne!(base.fingerprint(), Compiler::new().precision_search(true).fingerprint());
        // a measured microkernel table keys a distinct plan-cache entry
        assert_ne!(
            base.fingerprint(),
            Compiler::new()
                .microkernels(KernelThroughput::default().with("avx2-4x16", 8.0))
                .fingerprint()
        );
        assert_eq!(
            base.fingerprint(),
            Compiler::new().microkernels(KernelThroughput::default()).fingerprint(),
            "empty microkernel table is the default"
        );
        assert_eq!(
            base.fingerprint(),
            Compiler::new().calibration(DeviceCalibration::identity()).fingerprint(),
            "identity calibration is the default"
        );
    }

    #[test]
    fn explicit_bounds_survive_device_in_any_order() {
        let a = Compiler::new().p1_bounds(32, 128).device(Device::small_edge());
        let b = Compiler::new().device(Device::small_edge()).p1_bounds(32, 128);
        assert_eq!((a.config().p1_lo, a.config().p1_hi), (32, 128));
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn cache_file_name_is_path_safe() {
        let name = Compiler::new().cache_file_name("my/model v2");
        assert!(!name.contains('/') && !name.contains(' '), "{name}");
        assert!(name.ends_with(".json"));
    }

    #[test]
    fn degenerate_bounds_are_typed_errors() {
        let cnn = zoo::mini_inception();
        let e = small().p1_bounds(0, 8).compile(&cnn).unwrap_err();
        assert!(matches!(e, DynamapError::Dse(_)), "{e}");
        let e = small().p1_bounds(64, 8).compile(&cnn).unwrap_err();
        assert!(matches!(e, DynamapError::Dse(_)), "{e}");
        let e = small().fixed_shape(0, 8).compile(&cnn).unwrap_err();
        assert!(matches!(e, DynamapError::Dse(_)), "{e}");
    }

    #[test]
    fn mapping_mixes_algorithms_on_googlenet() {
        // the paper's whole point: a single algorithm is not optimal
        let a = Compiler::new().compile(&zoo::googlenet()).unwrap();
        assert!(a.plan.algo_histogram().len() >= 2);
    }
}
