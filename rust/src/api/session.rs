//! [`Session`] — the online serving stage of the staged pipeline.
//!
//! A session owns everything the request path needs: the parsed AOT
//! manifest, the CNN resolved from the manifest's `model` field via the
//! zoo registry, a [`PlanArtifact`] (explicitly provided, loaded from a
//! [`PlanCache`], or compiled on first construction), the execution
//! backend, and that backend's weight form — on [`Backend::Native`],
//! per-layer [`PreparedWeights`] (im2col weight matrix, kn2row per-tap
//! unit matrices, Winograd-transformed kernels) lowered once at build
//! time. Inference never re-runs the DSE and never re-derives a weight
//! transform: everything request-invariant is resolved at build time,
//! mirroring the paper's split between the offline mapping flow and the
//! reused overlay.
//!
//! Two backends serve the conv layers:
//!
//! * [`Backend::Pjrt`] (default) executes the AOT-compiled HLO
//!   artifacts through the PJRT runtime — the end-to-end path validated
//!   against the Python oracle goldens.
//! * [`Backend::Native`] executes through the in-process kernel layer
//!   ([`crate::kernels`]) — no XLA executables needed, and because its
//!   request state is plain `Send + Sync` data, [`Session::infer_batch`]
//!   fans requests out across threads. (The PJRT client wraps foreign
//!   handles that are not thread-safe, so the PJRT backend serves
//!   batches sequentially.)
//!
//! On the native backend everything the request path reads is split
//! into [`NativeState`]: an `Arc`-shared, `Send + Sync` bundle of
//! graph + algorithm map + prepared weights. [`Session::native_state`]
//! hands that bundle to the multi-model serving engine
//! ([`crate::serve`]), whose batch-queue workers serve requests
//! without locking (or even retaining) the session that built it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use super::artifact::{PlanArtifact, PlanCache};
use super::compiler::Compiler;
use super::error::DynamapError;
use crate::algos::tensor::{Tensor, Weights};
use crate::coordinator::metrics::LatencyStats;
use crate::cost::conv::Algo;
use crate::cost::graph_build::Policy;
use crate::graph::layer::{ConvSpec, Op};
use crate::graph::{zoo, Cnn};
use crate::kernels::PreparedWeights;
use crate::overlay::pooling;
use crate::quant::{self, ActScales, Precision};
use crate::runtime::{Manifest, PjrtRuntime, TensorBuf};
use crate::tune::profiler::LayerProfile;
use crate::util::parallel::parallel_map;

/// How conv layers execute on the request path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// AOT-compiled HLO artifacts through the PJRT runtime.
    #[default]
    Pjrt,
    /// In-process kernel layer over the session's [`PreparedWeights`];
    /// enables parallel batch serving.
    Native,
}

/// Per-inference metrics.
#[derive(Debug, Clone)]
pub struct InferMetrics {
    /// End-to-end wall-clock compute time for the request, microseconds.
    pub total_us: f64,
    /// (layer name, algorithm, microseconds) per conv layer.
    pub per_layer_us: Vec<(String, String, f64)>,
}

/// Metrics for one [`Session::infer_batch`] call.
#[derive(Debug, Clone)]
pub struct BatchMetrics {
    /// Per-request metrics, in input order.
    pub per_request: Vec<InferMetrics>,
    /// Aggregate latency statistics over the batch.
    pub stats: LatencyStats,
}

/// Builder for [`Session`].
pub struct SessionBuilder {
    artifacts_dir: String,
    compiler: Compiler,
    custom_map: Option<BTreeMap<String, String>>,
    plan: Option<PlanArtifact>,
    cache_dir: Option<PathBuf>,
    backend: Backend,
    profiler: Option<Arc<LayerProfile>>,
    act_scales: Option<ActScales>,
}

impl SessionBuilder {
    /// Use a pre-configured compiler for the (non-cached) compile path.
    pub fn compiler(mut self, compiler: Compiler) -> SessionBuilder {
        self.compiler = compiler;
        self
    }

    /// Map with a fixed baseline policy instead of the optimal PBQP
    /// solve (shorthand for configuring the compiler).
    pub fn policy(mut self, policy: Policy) -> SessionBuilder {
        self.compiler = self.compiler.policy(policy);
        self
    }

    /// Skip the DSE entirely and use an explicit per-layer
    /// `layer name → algorithm name` map. Values are family names
    /// ("im2col", "kn2row", "winograd"), optionally precision-suffixed
    /// ("im2col-int8") to serve that layer quantized on the native
    /// backend (see [`crate::quant::mapped_name`]).
    pub fn algo_map(mut self, map: BTreeMap<String, String>) -> SessionBuilder {
        self.custom_map = Some(map);
        self
    }

    /// Calibrated per-tensor activation scales for quantized layers
    /// ([`crate::quant::ActScales`], produced by
    /// [`NativeState::calibrate_activations`]). Layers without a
    /// calibrated scale quantize dynamically from each request's own
    /// magnitude; f32 layers ignore the scales entirely.
    pub fn act_scales(mut self, scales: ActScales) -> SessionBuilder {
        self.act_scales = Some(scales);
        self
    }

    /// Serve from an explicit, previously saved plan artifact.
    pub fn plan(mut self, artifact: PlanArtifact) -> SessionBuilder {
        self.plan = Some(artifact);
        self
    }

    /// Cache compiled plans under `dir`, keyed by
    /// `(model, device, compiler fingerprint)`; later sessions with the
    /// same key skip the DSE.
    pub fn plan_cache(mut self, dir: impl AsRef<Path>) -> SessionBuilder {
        self.cache_dir = Some(dir.as_ref().to_path_buf());
        self
    }

    /// Choose the conv execution backend (default: [`Backend::Pjrt`]).
    /// [`Backend::Native`] serves from the in-process kernel layer: no
    /// HLO artifacts or PJRT client are required — only the manifest and
    /// weight files — and `infer_batch` parallelizes across requests.
    pub fn backend(mut self, backend: Backend) -> SessionBuilder {
        self.backend = backend;
        self
    }

    /// Attach a per-layer latency profiler to the native serving state
    /// at construction (so no post-build copy of the prepared weights
    /// is ever needed). Ignored on [`Backend::Pjrt`], which has no
    /// profiled native path.
    pub fn profiler(mut self, profiler: Arc<LayerProfile>) -> SessionBuilder {
        self.profiler = Some(profiler);
        self
    }

    /// Resolve the plan, pre-compile every chosen executable (PJRT
    /// backend), pre-load weights and lower them into per-layer
    /// [`PreparedWeights`].
    pub fn build(self) -> Result<Session, DynamapError> {
        let SessionBuilder {
            artifacts_dir,
            compiler,
            custom_map,
            plan,
            cache_dir,
            backend,
            profiler,
            act_scales,
        } = self;
        if custom_map.is_some() && (plan.is_some() || cache_dir.is_some()) {
            return Err(DynamapError::Config(
                "SessionBuilder: .algo_map bypasses the DSE and cannot be combined with \
                 .plan or .plan_cache"
                    .into(),
            ));
        }
        let manifest = Manifest::load(&artifacts_dir)?;
        let cnn = zoo::by_name(&manifest.model)
            .ok_or_else(|| DynamapError::UnknownModel(manifest.model.clone()))?;

        // resolve the plan: explicit artifact > custom map > cache > compile
        let (artifact, from_cache) = match (plan, &custom_map) {
            (Some(a), _) => {
                if a.model != cnn.name {
                    return Err(DynamapError::Artifact(format!(
                        "plan artifact targets model '{}' but the manifest serves '{}'",
                        a.model, cnn.name
                    )));
                }
                (Some(a), true)
            }
            (None, Some(_)) => (None, false),
            (None, None) => match &cache_dir {
                Some(dir) => {
                    let (a, cached) =
                        PlanCache::new(dir.clone()).load_or_compile(&compiler, &cnn)?;
                    (Some(a), cached)
                }
                None => (Some(compiler.compile(&cnn)?), false),
            },
        };

        let algo_map: BTreeMap<String, String> = match (&artifact, custom_map) {
            (_, Some(m)) => m,
            (Some(a), None) => a
                .plan
                .mapping
                .layers
                .iter()
                .map(|l| {
                    // plan entries carry (family, precision); the map
                    // spells the pair the serving-layer way, e.g.
                    // "im2col-int8" (see crate::quant::mapped_name)
                    (
                        l.name.clone(),
                        quant::mapped_name(l.cost.algo.family(), l.cost.precision),
                    )
                })
                .collect(),
            (None, None) => unreachable!("plan or custom map is always resolved"),
        };

        // clamp to executable algorithms, pre-compile executables (PJRT)
        // and lower weights once into the kernel layer's prepared form
        let mut runtime = match backend {
            Backend::Pjrt => Some(PjrtRuntime::cpu()?),
            Backend::Native => None,
        };
        let mut clamped = BTreeMap::new();
        let mut weights = BTreeMap::new();
        let mut prepared = BTreeMap::new();
        for layer in &manifest.layers {
            let want = algo_map.get(&layer.name).map(|s| s.as_str()).unwrap_or("im2col");
            let (want_family, want_precision) = quant::parse_mapped(want);
            let spec = ConvSpec::new(
                layer.c_in, layer.c_out, layer.h1, layer.h2, layer.k1, layer.k2, layer.s,
                layer.p1, layer.p2,
            );
            let (family, precision) = match &mut runtime {
                Some(rt) => {
                    // PJRT: clamp to the algorithms that were AOT'd —
                    // the executables are f32, so any requested int8
                    // clamps back to full precision
                    let family = if layer.algos.contains_key(want_family) {
                        want_family
                    } else {
                        "im2col"
                    };
                    let art = layer.algos.get(family).ok_or_else(|| {
                        DynamapError::Manifest(format!(
                            "{}: no artifact for {family}",
                            layer.name
                        ))
                    })?;
                    rt.load(&manifest.dir.join(art))?;
                    (family, Precision::F32)
                }
                None => {
                    // native: every kernel-layer algorithm is
                    // available; int8 applies to im2col/kn2row only
                    // (winograd clamps to f32, mirroring the DSE's
                    // constraint and PreparedWeights::with_precision)
                    let family = if ["im2col", "kn2row", "winograd"].contains(&want_family)
                    {
                        want_family
                    } else {
                        "im2col"
                    };
                    let algo = resolve_algo(family, &spec);
                    let precision = match (want_precision, algo) {
                        (Precision::Int8, Algo::Im2col | Algo::Kn2row) => Precision::Int8,
                        _ => Precision::F32,
                    };
                    (family, precision)
                }
            };
            clamped.insert(layer.name.clone(), quant::mapped_name(family, precision));
            let wts = Weights {
                c_out: layer.c_out,
                c_in: layer.c_in,
                k1: layer.k1,
                k2: layer.k2,
                data: manifest.weights(layer)?,
            };
            // each backend keeps exactly the weight form its request
            // path reads: native serves from the pre-lowered kernels,
            // PJRT feeds raw tensors to its executables
            match backend {
                Backend::Native => {
                    let scale = act_scales
                        .as_ref()
                        .and_then(|s| s.scale_for(&layer.name));
                    prepared.insert(
                        layer.name.clone(),
                        PreparedWeights::with_precision(
                            &wts,
                            &spec,
                            resolve_algo(family, &spec),
                            precision,
                            scale,
                        ),
                    );
                }
                Backend::Pjrt => {
                    weights.insert(
                        layer.name.clone(),
                        TensorBuf::new(
                            vec![layer.c_out, layer.c_in, layer.k1, layer.k2],
                            wts.data,
                        ),
                    );
                }
            }
        }
        // every conv layer of the resolved model must be backed by the
        // manifest, otherwise the serving loop would hit a missing
        // weights/executable entry mid-inference
        for id in cnn.conv_nodes() {
            let name = &cnn.node(id).name;
            if !clamped.contains_key(name) {
                return Err(DynamapError::Manifest(format!(
                    "manifest for model '{}' is missing conv layer '{}'",
                    cnn.name, name
                )));
            }
        }
        // native backend: split the request-invariant read state into a
        // shareable bundle (see `NativeState`) so batch workers and the
        // serving engine can run requests without holding the session
        let native = match backend {
            Backend::Native => Some(Arc::new(NativeState {
                cnn: cnn.clone(),
                algo_map: clamped.clone(),
                prepared,
                input: manifest.input,
                profiler,
            })),
            Backend::Pjrt => None,
        };
        Ok(Session {
            manifest,
            cnn,
            artifact,
            from_cache,
            algo_map: clamped,
            backend,
            runtime,
            weights,
            native,
            aggregate: LatencyStats::new(),
        })
    }
}

/// Kernel-layer algorithm for a clamped algorithm name, honouring the
/// same applicability rules as the cost model (non-applicable Winograd
/// falls back to the strided extension or im2col).
///
/// Deliberately re-derived from the name + spec rather than carried
/// through from the plan's typed [`Algo`]: custom `.algo_map` sessions
/// have no typed plan at all, and a plan compiled with non-default
/// Winograd hyper-parameters (e.g. `F(4×4, 3×3)`) must *clamp* to the
/// `F(2×2, 3×3)` core the kernel layer implements instead of panicking
/// at session build. Shared with `tune::calibrate`, which must price
/// observed family names exactly as the serving layer executes them.
pub(crate) fn resolve_algo(name: &str, spec: &ConvSpec) -> Algo {
    match name {
        "kn2row" => Algo::Kn2row,
        "winograd" => {
            if spec.winograd_applicable(3) {
                Algo::Winograd { m: 2, r: 3 }
            } else if spec.s == 2 && spec.k1 == spec.k2 && spec.k1 >= 3 {
                Algo::WinogradStrided { m: 2, r: 3 }
            } else {
                Algo::Im2col
            }
        }
        _ => Algo::Im2col,
    }
}

/// Request-invariant serving state of a native-backend session: the CNN
/// graph, the clamped algorithm map and every layer's pre-lowered
/// [`PreparedWeights`].
///
/// All fields are plain owned data, so the state is `Send + Sync` and a
/// single `Arc<NativeState>` can serve requests from any number of
/// threads concurrently — the multi-model engine in [`crate::serve`]
/// hands one to each batch-queue worker. The state is built once by
/// [`SessionBuilder::build`] and never mutated afterwards; per-session
/// aggregate statistics stay on the [`Session`] that created it.
#[derive(Debug, Clone)]
pub struct NativeState {
    cnn: Cnn,
    algo_map: BTreeMap<String, String>,
    prepared: BTreeMap<String, PreparedWeights>,
    input: (usize, usize, usize),
    /// Optional per-layer latency sink ([`crate::tune`]): when present,
    /// every request records its per-layer wall-clock samples here.
    /// Purely observational — attaching a profiler never changes a
    /// single output bit.
    profiler: Option<Arc<LayerProfile>>,
}

impl NativeState {
    /// Name of the model this state serves.
    pub fn model(&self) -> &str {
        &self.cnn.name
    }

    /// The CNN graph being served.
    pub fn cnn(&self) -> &Cnn {
        &self.cnn
    }

    /// Clamped `layer → algorithm` map actually being served.
    pub fn algo_map(&self) -> &BTreeMap<String, String> {
        &self.algo_map
    }

    /// Pre-lowered weights for one layer, if the manifest carried it.
    pub fn prepared(&self, layer: &str) -> Option<&PreparedWeights> {
        self.prepared.get(layer)
    }

    /// How many layers have pre-lowered weights.
    pub fn prepared_count(&self) -> usize {
        self.prepared.len()
    }

    /// Input dimensions `(C, H1, H2)` from the manifest.
    pub fn input_dims(&self) -> (usize, usize, usize) {
        self.input
    }

    /// Expected input element count `(C · H1 · H2)`.
    pub fn input_len(&self) -> usize {
        let (c, h1, h2) = self.input;
        c * h1 * h2
    }

    /// A copy of this state with `profiler` attached: every request
    /// served from the copy records its per-layer wall-clock samples
    /// into the shared [`LayerProfile`]. Note this clones the prepared
    /// weights; when building a fresh session, prefer
    /// [`SessionBuilder::profiler`], which attaches the profiler at
    /// construction with no copy.
    pub fn profiled(&self, profiler: Arc<LayerProfile>) -> NativeState {
        let mut state = self.clone();
        state.profiler = Some(profiler);
        state
    }

    /// The attached per-layer latency profile, if any.
    pub fn profiler(&self) -> Option<&Arc<LayerProfile>> {
        self.profiler.as_ref()
    }

    /// The precision each conv/FC layer actually executes with (after
    /// any clamping at build time).
    pub fn precision(&self, layer: &str) -> Option<Precision> {
        self.prepared.get(layer).map(|pw| pw.precision())
    }

    /// How many layers execute quantized.
    pub fn int8_count(&self) -> usize {
        self.prepared.values().filter(|pw| pw.precision() == Precision::Int8).count()
    }

    /// Calibrate per-tensor activation scales from a handful of
    /// representative batches: run each input through this state,
    /// recording every conv/FC layer's input-magnitude high-water mark.
    /// Feed the result to [`SessionBuilder::act_scales`] (or persist it
    /// with [`ActScales::save`]) so quantized layers use deterministic
    /// calibrated scales instead of per-request dynamic ones.
    ///
    /// Calibration observes the f32 activations *entering* each layer,
    /// so it works on an f32 state (the usual flow: calibrate first,
    /// then build the quantized session) as well as on a mixed one.
    pub fn calibrate_activations(
        &self,
        batches: &[TensorBuf],
    ) -> Result<ActScales, DynamapError> {
        let mut scales = ActScales::new();
        for input in batches {
            let mut observe = |layer: &str, data: &[f32]| {
                scales.observe(layer, quant::max_abs(data));
            };
            self.infer_observed(input, Some(&mut observe), None)?;
        }
        Ok(scales)
    }

    /// One request through the CNN graph with conv (and FC) layers
    /// executed by the kernel layer. Takes `&self` over immutable data,
    /// so a parallel batch can fan it out across threads.
    pub fn infer(&self, input: &TensorBuf) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        self.infer_observed(input, None, None)
    }

    /// [`NativeState::infer`] carrying the request's trace identity:
    /// when a recorder is installed ([`crate::obs::install`]), every
    /// conv/FC layer emits one [`crate::obs::Stage::Layer`] span tagged
    /// with the layer name plus the live plan's `algo`, executed
    /// `precision` and host microkernel `kernel` tier. With tracing off
    /// this is exactly [`NativeState::infer`]: the only added work is
    /// one relaxed atomic load per request.
    pub fn infer_traced(
        &self,
        input: &TensorBuf,
        trace: Option<crate::obs::TraceId>,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        self.infer_observed(input, None, trace)
    }

    /// [`NativeState::infer`] with an optional observer called with
    /// each conv/FC layer's name and input activation before the layer
    /// executes (the calibration hook; `None` on the serving hot path)
    /// and the request's optional span-correlation id.
    fn infer_observed(
        &self,
        input: &TensorBuf,
        mut observe: Option<&mut dyn FnMut(&str, &[f32])>,
        trace: Option<crate::obs::TraceId>,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        let cnn = &self.cnn;
        // chaos hook: one poisoned request panics mid-compute; the batch
        // queue's per-request catch_unwind must convert it into a typed
        // error while batch siblings complete untouched
        crate::fault::panic_if(crate::fault::Site::WorkerPanic);
        // resolve the span recorder once per request (one relaxed load
        // when tracing is off); the kernel tag is the best microkernel
        // tier executable on this host — the same ranking `gemm` uses
        let recorder = crate::obs::active();
        let kernel: &'static str = if recorder.is_some() {
            crate::kernels::KernelSelector::probed()
                .kinds()
                .first()
                .map(|k| k.name())
                .unwrap_or("scalar")
        } else {
            "scalar"
        };
        let t_total = Instant::now();
        let mut per_layer = Vec::new();
        // activations stay `Tensor` end to end — the only buffer copies
        // are the request boundary conversions, never per layer
        let mut values: BTreeMap<usize, Tensor> = BTreeMap::new();
        let mut final_out = None;
        for id in cnn.topo_order() {
            let node = cnn.node(id);
            let preds = cnn.predecessors(id);
            let out = match &node.op {
                Op::Input { c, h1, h2 } => {
                    if input.len() != c * h1 * h2 {
                        return Err(DynamapError::Shape {
                            context: "input".into(),
                            expected: c * h1 * h2,
                            got: input.len(),
                        });
                    }
                    Tensor { c: *c, h: *h1, w: *h2, data: input.data.clone() }
                }
                Op::Conv(_) => {
                    let pw = self.prepared.get(&node.name).ok_or_else(|| {
                        DynamapError::Manifest(format!(
                            "no prepared weights for layer '{}'",
                            node.name
                        ))
                    })?;
                    if let Some(obs) = observe.as_mut() {
                        obs(&node.name, &values[&preds[0]].data);
                    }
                    // chaos hook: interference/throttling makes one
                    // layer run arbitrarily slow — deadline and tail
                    // accounting must absorb it, correctness must not
                    crate::fault::sleep_if(crate::fault::Site::SlowLayer);
                    let t0 = Instant::now();
                    let out = pw.conv2d(&values[&preds[0]]);
                    let t1 = Instant::now();
                    let algo = self.algo_map.get(&node.name).cloned().unwrap_or_default();
                    if let Some(rec) = &recorder {
                        rec.record_span(
                            trace,
                            crate::obs::Stage::Layer,
                            &node.name,
                            t0,
                            t1,
                            vec![
                                ("algo", algo.clone()),
                                ("precision", pw.precision().name().to_string()),
                                ("kernel", kernel.to_string()),
                            ],
                        );
                    }
                    per_layer.push((
                        node.name.clone(),
                        algo,
                        t1.duration_since(t0).as_secs_f64() * 1e6,
                    ));
                    out
                }
                Op::Pool(p) => pooling::reference(&values[&preds[0]], p),
                Op::Concat { c_out, h1, h2 } => {
                    let mut data = Vec::with_capacity(c_out * h1 * h2);
                    for &p in &preds {
                        data.extend_from_slice(&values[&p].data);
                    }
                    Tensor { c: *c_out, h: *h1, w: *h2, data }
                }
                Op::Add { c, h1, h2 } => {
                    let a = &values[&preds[0]];
                    let b = &values[&preds[1]];
                    let data = a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect();
                    Tensor { c: *c, h: *h1, w: *h2, data }
                }
                Op::Fc { c_in, c_out } => {
                    // an FC over the flattened activation is exactly a
                    // 1×1 conv on a (c_in, 1, 1) tensor, so it serves
                    // from the same prepared-weight form when the
                    // manifest carries weights for it (synthetic zoo
                    // manifests do; AOT manifests never list FC layers)
                    let pw = self.prepared.get(&node.name).ok_or_else(|| {
                        DynamapError::Runtime(format!(
                            "FC layer '{}' has no weights in the manifest",
                            node.name
                        ))
                    })?;
                    let x = &values[&preds[0]];
                    if x.data.len() != *c_in {
                        return Err(DynamapError::Shape {
                            context: node.name.clone(),
                            expected: *c_in,
                            got: x.data.len(),
                        });
                    }
                    let flat = Tensor { c: *c_in, h: 1, w: 1, data: x.data.clone() };
                    if let Some(obs) = observe.as_mut() {
                        obs(&node.name, &flat.data);
                    }
                    let t0 = Instant::now();
                    let out = pw.conv2d(&flat);
                    let t1 = Instant::now();
                    debug_assert_eq!(out.c, *c_out);
                    let algo = self.algo_map.get(&node.name).cloned().unwrap_or_default();
                    if let Some(rec) = &recorder {
                        rec.record_span(
                            trace,
                            crate::obs::Stage::Layer,
                            &node.name,
                            t0,
                            t1,
                            vec![
                                ("algo", algo.clone()),
                                ("precision", pw.precision().name().to_string()),
                                ("kernel", kernel.to_string()),
                            ],
                        );
                    }
                    per_layer.push((
                        node.name.clone(),
                        algo,
                        t1.duration_since(t0).as_secs_f64() * 1e6,
                    ));
                    out
                }
                Op::Output => {
                    final_out = Some(values[&preds[0]].clone());
                    continue;
                }
            };
            values.insert(id, out);
        }
        let out =
            final_out.ok_or_else(|| DynamapError::Graph("no output node reached".into()))?;
        if let Some(profiler) = &self.profiler {
            profiler.record(&per_layer);
        }
        let m = InferMetrics {
            total_us: t_total.elapsed().as_secs_f64() * 1e6,
            per_layer_us: per_layer,
        };
        Ok((TensorBuf::new(vec![out.c, out.h, out.w], out.data), m))
    }

    /// Run a batch of requests, fanning out across the scoped-thread
    /// pool ([`crate::util::parallel`]). Results and statistics come
    /// back in input order, bit-identical to a sequential [`NativeState::infer`]
    /// loop.
    pub fn infer_batch(
        &self,
        inputs: &[TensorBuf],
    ) -> Result<(Vec<TensorBuf>, BatchMetrics), DynamapError> {
        let results = parallel_map(inputs, |_, input| self.infer(input));
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut per_request = Vec::with_capacity(inputs.len());
        let mut stats = LatencyStats::new();
        for r in results {
            let (out, m) = r?;
            stats.push(m.total_us);
            outputs.push(out);
            per_request.push(m);
        }
        Ok((outputs, BatchMetrics { per_request, stats }))
    }
}

/// The serving session: plan + prepared weights + backend, ready for
/// requests.
pub struct Session {
    manifest: Manifest,
    cnn: Cnn,
    artifact: Option<PlanArtifact>,
    from_cache: bool,
    algo_map: BTreeMap<String, String>,
    backend: Backend,
    runtime: Option<PjrtRuntime>,
    weights: BTreeMap<String, TensorBuf>,
    native: Option<Arc<NativeState>>,
    aggregate: LatencyStats,
}

impl Session {
    /// Start building a session over an AOT artifact directory.
    ///
    /// The full quickstart flow (`examples/quickstart.rs` runs the
    /// offline half of this without artifacts):
    ///
    /// ```no_run
    /// use dynamap::api::{Backend, Compiler, Session};
    /// use dynamap::graph::zoo;
    /// use dynamap::runtime::TensorBuf;
    ///
    /// // offline: run the DSE once and persist the versioned plan
    /// let cnn = zoo::mini_inception();
    /// let artifact = Compiler::new().compile(&cnn)?;
    /// artifact.save("plans/mini-inception.json")?;
    ///
    /// // online: serve requests over an artifact directory. With a plan
    /// // cache, later sessions skip the DSE entirely; the native backend
    /// // needs only the manifest + weights (no PJRT executables).
    /// let mut session = Session::builder("artifacts")
    ///     .backend(Backend::Native)
    ///     .plan_cache("plans")
    ///     .build()?;
    /// let input = TensorBuf::zeros(vec![4, 16, 16]);
    /// let (outputs, metrics) = session.infer_batch(&[input])?;
    /// println!("{} outputs, {}", outputs.len(), metrics.stats.summary());
    /// # Ok::<(), dynamap::api::DynamapError>(())
    /// ```
    pub fn builder(artifacts_dir: impl Into<String>) -> SessionBuilder {
        SessionBuilder {
            artifacts_dir: artifacts_dir.into(),
            compiler: Compiler::new(),
            custom_map: None,
            plan: None,
            cache_dir: None,
            backend: Backend::Pjrt,
            profiler: None,
            act_scales: None,
        }
    }

    /// Build with all defaults (optimal mapping, fresh compile).
    pub fn open(artifacts_dir: &str) -> Result<Session, DynamapError> {
        Session::builder(artifacts_dir).build()
    }

    // -- introspection ---------------------------------------------------

    /// The parsed AOT artifact manifest this session serves from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The CNN graph resolved from the manifest's `model` field.
    pub fn cnn(&self) -> &Cnn {
        &self.cnn
    }

    /// Model name served by this session.
    pub fn model(&self) -> &str {
        &self.cnn.name
    }

    /// The conv execution backend.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// The resolved plan (absent when an explicit algorithm map was
    /// supplied).
    pub fn plan(&self) -> Option<&PlanArtifact> {
        self.artifact.as_ref()
    }

    /// `true` when the plan was served from a cache or supplied
    /// explicitly — i.e. no DSE ran during session construction.
    pub fn plan_from_cache(&self) -> bool {
        self.from_cache
    }

    /// Clamped `layer → algorithm` map actually being served.
    pub fn algo_map(&self) -> &BTreeMap<String, String> {
        &self.algo_map
    }

    /// Pre-lowered weights for one layer — built once at session
    /// construction on [`Backend::Native`] (the PJRT backend feeds raw
    /// tensors to its executables instead and keeps no prepared form).
    pub fn prepared(&self, layer: &str) -> Option<&PreparedWeights> {
        self.native.as_ref().and_then(|ns| ns.prepared(layer))
    }

    /// How many layers have pre-lowered weights.
    pub fn prepared_count(&self) -> usize {
        self.native.as_ref().map_or(0, |ns| ns.prepared_count())
    }

    /// The shareable request-invariant serving state (native backend
    /// only). The returned `Arc` is `Send + Sync` and independent of the
    /// session's lifetime: the serving engine in [`crate::serve`] hands
    /// clones to its batch-queue workers and drops the session itself.
    pub fn native_state(&self) -> Option<Arc<NativeState>> {
        self.native.clone()
    }

    /// Executables currently compiled in the PJRT cache (0 on the
    /// native backend).
    pub fn loaded_executables(&self) -> usize {
        self.runtime.as_ref().map_or(0, |rt| rt.loaded_count())
    }

    /// Aggregate latency statistics across every request this session
    /// has served.
    pub fn stats(&self) -> &LatencyStats {
        &self.aggregate
    }

    /// Expected input element count `(C · H1 · H2)`.
    pub fn input_len(&self) -> usize {
        let (c, h1, h2) = self.manifest.input;
        c * h1 * h2
    }

    fn artifact_path(&self, layer: &str) -> Result<PathBuf, DynamapError> {
        let algo = self.algo_map.get(layer).ok_or_else(|| {
            DynamapError::Manifest(format!("no algorithm chosen for layer '{layer}'"))
        })?;
        let la = self.manifest.layer(layer).ok_or_else(|| {
            DynamapError::Manifest(format!("manifest has no layer '{layer}'"))
        })?;
        let file = la.algos.get(algo).ok_or_else(|| {
            DynamapError::Manifest(format!("layer '{layer}': no artifact for '{algo}'"))
        })?;
        Ok(self.manifest.dir.join(file))
    }

    // -- serving ---------------------------------------------------------

    /// Run one inference. Input is `(C, H, W)` flattened f32.
    pub fn infer(
        &mut self,
        input: &TensorBuf,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        if let Some(ns) = &self.native {
            let (out, m) = ns.infer(input)?;
            self.aggregate.push(m.total_us);
            return Ok((out, m));
        }
        let t_total = Instant::now();
        let mut per_layer = Vec::new();
        let mut values: BTreeMap<usize, TensorBuf> = BTreeMap::new();
        let order = self.cnn.topo_order();
        let mut final_out = None;
        for id in order {
            let node = self.cnn.node(id).clone();
            let preds = self.cnn.predecessors(id);
            let out = match &node.op {
                Op::Input { c, h1, h2 } => {
                    if input.len() != c * h1 * h2 {
                        return Err(DynamapError::Shape {
                            context: "input".into(),
                            expected: c * h1 * h2,
                            got: input.len(),
                        });
                    }
                    TensorBuf::new(vec![*c, *h1, *h2], input.data.clone())
                }
                Op::Conv(spec) => {
                    let x = &values[&preds[0]];
                    let path = self.artifact_path(&node.name)?;
                    // disjoint field borrows: weights stay borrowed while
                    // the runtime executes — no per-request weight copy
                    let w = &self.weights[&node.name];
                    let rt = self.runtime.as_mut().expect("PJRT backend has a runtime");
                    let t0 = Instant::now();
                    let out = rt.execute(
                        &path,
                        &[x, w],
                        vec![spec.c_out, spec.o1(), spec.o2()],
                    )?;
                    per_layer.push((
                        node.name.clone(),
                        self.algo_map[&node.name].clone(),
                        t0.elapsed().as_secs_f64() * 1e6,
                    ));
                    out
                }
                Op::Pool(p) => {
                    let x = &values[&preds[0]];
                    let t = Tensor { c: p.c, h: p.h1, w: p.h2, data: x.data.clone() };
                    let out = pooling::reference(&t, p);
                    TensorBuf::new(vec![out.c, out.h, out.w], out.data)
                }
                Op::Concat { c_out, h1, h2 } => {
                    let mut data = Vec::with_capacity(c_out * h1 * h2);
                    for &p in &preds {
                        data.extend_from_slice(&values[&p].data);
                    }
                    TensorBuf::new(vec![*c_out, *h1, *h2], data)
                }
                Op::Add { c, h1, h2 } => {
                    let a = &values[&preds[0]];
                    let b = &values[&preds[1]];
                    let data = a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect();
                    TensorBuf::new(vec![*c, *h1, *h2], data)
                }
                Op::Fc { .. } => {
                    return Err(DynamapError::Runtime(
                        "FC layers are not part of the artifact set".into(),
                    ))
                }
                Op::Output => {
                    final_out = Some(values[&preds[0]].clone());
                    continue;
                }
            };
            values.insert(id, out);
        }
        let out = final_out
            .ok_or_else(|| DynamapError::Graph("no output node reached".into()))?;
        let m = InferMetrics {
            total_us: t_total.elapsed().as_secs_f64() * 1e6,
            per_layer_us: per_layer,
        };
        self.aggregate.push(m.total_us);
        Ok((out, m))
    }

    /// Run a batch of requests, collecting per-request and aggregate
    /// latency statistics.
    ///
    /// On the native backend, requests fan out across threads (results
    /// and statistics come back in input order, identical to the
    /// sequential loop — asserted by the golden-equality tests). The
    /// PJRT backend serves sequentially on the shared runtime, the
    /// paper's single-sample low-latency regime.
    pub fn infer_batch(
        &mut self,
        inputs: &[TensorBuf],
    ) -> Result<(Vec<TensorBuf>, BatchMetrics), DynamapError> {
        if let Some(ns) = self.native.clone() {
            let (outputs, metrics) = ns.infer_batch(inputs)?;
            for m in &metrics.per_request {
                self.aggregate.push(m.total_us);
            }
            return Ok((outputs, metrics));
        }
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut per_request = Vec::with_capacity(inputs.len());
        let mut stats = LatencyStats::new();
        for input in inputs {
            let (out, m) = self.infer(input)?;
            stats.push(m.total_us);
            outputs.push(out);
            per_request.push(m);
        }
        Ok((outputs, BatchMetrics { per_request, stats }))
    }

    /// Validate against the Python-side golden pair; returns the max
    /// absolute error.
    pub fn validate_golden(&mut self) -> Result<f32, DynamapError> {
        let (gi, go) = self.manifest.golden()?;
        let (c, h1, h2) = self.manifest.input;
        let input = TensorBuf::new(vec![c, h1, h2], gi);
        let (out, _) = self.infer(&input)?;
        if out.data.len() != go.len() {
            return Err(DynamapError::Shape {
                context: "golden output".into(),
                expected: go.len(),
                got: out.data.len(),
            });
        }
        let mut max_err = 0.0f32;
        for (a, b) in out.data.iter().zip(&go) {
            max_err = max_err.max((a - b).abs());
        }
        Ok(max_err)
    }

    /// Latency benchmark: `n` sequential inferences on the golden input
    /// (first call warms the executable cache).
    pub fn bench(&mut self, n: usize) -> Result<LatencyStats, DynamapError> {
        let (gi, _) = self.manifest.golden()?;
        let (c, h1, h2) = self.manifest.input;
        let input = TensorBuf::new(vec![c, h1, h2], gi);
        let mut stats = LatencyStats::new();
        self.infer(&input)?; // warm-up
        for _ in 0..n {
            let (_, m) = self.infer(&input)?;
            stats.push(m.total_us);
        }
        Ok(stats)
    }
}
