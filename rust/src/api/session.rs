//! [`Session`] — the online serving stage of the staged pipeline.
//!
//! A session owns everything the request path needs: the parsed AOT
//! manifest, the CNN resolved from the manifest's `model` field via the
//! zoo registry, a [`PlanArtifact`] (explicitly provided, loaded from a
//! [`PlanCache`], or compiled on first construction), the PJRT runtime
//! with every chosen executable pre-compiled, and pre-loaded weights.
//! Inference never re-runs the DSE: the plan is resolved once at build
//! time, mirroring the paper's split between the offline mapping flow
//! and the reused overlay.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use super::artifact::{PlanArtifact, PlanCache};
use super::compiler::Compiler;
use super::error::DynamapError;
use crate::algos::tensor::Tensor;
use crate::coordinator::metrics::LatencyStats;
use crate::cost::conv::Algo;
use crate::cost::graph_build::Policy;
use crate::graph::layer::Op;
use crate::graph::{zoo, Cnn};
use crate::overlay::pooling;
use crate::runtime::{Manifest, PjrtRuntime, TensorBuf};

/// Per-inference metrics.
#[derive(Debug, Clone)]
pub struct InferMetrics {
    pub total_us: f64,
    /// (layer name, algorithm, microseconds) per conv layer.
    pub per_layer_us: Vec<(String, String, f64)>,
}

/// Metrics for one [`Session::infer_batch`] call.
#[derive(Debug, Clone)]
pub struct BatchMetrics {
    /// Per-request metrics, in input order.
    pub per_request: Vec<InferMetrics>,
    /// Aggregate latency statistics over the batch.
    pub stats: LatencyStats,
}

/// Builder for [`Session`].
pub struct SessionBuilder {
    artifacts_dir: String,
    compiler: Compiler,
    custom_map: Option<BTreeMap<String, String>>,
    plan: Option<PlanArtifact>,
    cache_dir: Option<PathBuf>,
}

impl SessionBuilder {
    /// Use a pre-configured compiler for the (non-cached) compile path.
    pub fn compiler(mut self, compiler: Compiler) -> SessionBuilder {
        self.compiler = compiler;
        self
    }

    /// Map with a fixed baseline policy instead of the optimal PBQP
    /// solve (shorthand for configuring the compiler).
    pub fn policy(mut self, policy: Policy) -> SessionBuilder {
        self.compiler = self.compiler.policy(policy);
        self
    }

    /// Skip the DSE entirely and use an explicit per-layer
    /// `layer name → algorithm name` map.
    pub fn algo_map(mut self, map: BTreeMap<String, String>) -> SessionBuilder {
        self.custom_map = Some(map);
        self
    }

    /// Serve from an explicit, previously saved plan artifact.
    pub fn plan(mut self, artifact: PlanArtifact) -> SessionBuilder {
        self.plan = Some(artifact);
        self
    }

    /// Cache compiled plans under `dir`, keyed by
    /// `(model, device, compiler fingerprint)`; later sessions with the
    /// same key skip the DSE.
    pub fn plan_cache(mut self, dir: impl AsRef<Path>) -> SessionBuilder {
        self.cache_dir = Some(dir.as_ref().to_path_buf());
        self
    }

    /// Resolve the plan, pre-compile every chosen executable and
    /// pre-load weights.
    pub fn build(self) -> Result<Session, DynamapError> {
        let SessionBuilder { artifacts_dir, compiler, custom_map, plan, cache_dir } = self;
        if custom_map.is_some() && (plan.is_some() || cache_dir.is_some()) {
            return Err(DynamapError::Config(
                "SessionBuilder: .algo_map bypasses the DSE and cannot be combined with \
                 .plan or .plan_cache"
                    .into(),
            ));
        }
        let manifest = Manifest::load(&artifacts_dir)?;
        let cnn = zoo::by_name(&manifest.model)
            .ok_or_else(|| DynamapError::UnknownModel(manifest.model.clone()))?;

        // resolve the plan: explicit artifact > custom map > cache > compile
        let (artifact, from_cache) = match (plan, &custom_map) {
            (Some(a), _) => {
                if a.model != cnn.name {
                    return Err(DynamapError::Artifact(format!(
                        "plan artifact targets model '{}' but the manifest serves '{}'",
                        a.model, cnn.name
                    )));
                }
                (Some(a), true)
            }
            (None, Some(_)) => (None, false),
            (None, None) => match &cache_dir {
                Some(dir) => {
                    let (a, cached) =
                        PlanCache::new(dir.clone()).load_or_compile(&compiler, &cnn)?;
                    (Some(a), cached)
                }
                None => (Some(compiler.compile(&cnn)?), false),
            },
        };

        let algo_map: BTreeMap<String, String> = match (&artifact, custom_map) {
            (_, Some(m)) => m,
            (Some(a), None) => a
                .plan
                .mapping
                .layers
                .iter()
                .map(|l| {
                    let algo = match l.cost.algo {
                        Algo::Im2col => "im2col",
                        Algo::Kn2row => "kn2row",
                        Algo::Winograd { .. } | Algo::WinogradStrided { .. } => "winograd",
                    };
                    (l.name.clone(), algo.to_string())
                })
                .collect(),
            (None, None) => unreachable!("plan or custom map is always resolved"),
        };

        // clamp to AOT'd algorithms, pre-compile executables, load weights
        let mut runtime = PjrtRuntime::cpu()?;
        let mut clamped = BTreeMap::new();
        let mut weights = BTreeMap::new();
        for layer in &manifest.layers {
            let want = algo_map.get(&layer.name).map(|s| s.as_str()).unwrap_or("im2col");
            let algo = if layer.algos.contains_key(want) { want } else { "im2col" };
            let art = layer.algos.get(algo).ok_or_else(|| {
                DynamapError::Manifest(format!("{}: no artifact for {algo}", layer.name))
            })?;
            runtime.load(&manifest.dir.join(art))?;
            clamped.insert(layer.name.clone(), algo.to_string());
            let w = manifest.weights(layer)?;
            weights.insert(
                layer.name.clone(),
                TensorBuf::new(vec![layer.c_out, layer.c_in, layer.k1, layer.k2], w),
            );
        }
        // every conv layer of the resolved model must be backed by the
        // manifest, otherwise the serving loop would hit a missing
        // weights/executable entry mid-inference
        for id in cnn.conv_nodes() {
            let name = &cnn.node(id).name;
            if !clamped.contains_key(name) {
                return Err(DynamapError::Manifest(format!(
                    "manifest for model '{}' is missing conv layer '{}'",
                    cnn.name, name
                )));
            }
        }
        Ok(Session {
            manifest,
            cnn,
            artifact,
            from_cache,
            algo_map: clamped,
            runtime,
            weights,
            aggregate: LatencyStats::new(),
        })
    }
}

/// The serving session: plan + runtime + weights, ready for requests.
pub struct Session {
    manifest: Manifest,
    cnn: Cnn,
    artifact: Option<PlanArtifact>,
    from_cache: bool,
    algo_map: BTreeMap<String, String>,
    runtime: PjrtRuntime,
    weights: BTreeMap<String, TensorBuf>,
    aggregate: LatencyStats,
}

impl Session {
    /// Start building a session over an AOT artifact directory.
    pub fn builder(artifacts_dir: impl Into<String>) -> SessionBuilder {
        SessionBuilder {
            artifacts_dir: artifacts_dir.into(),
            compiler: Compiler::new(),
            custom_map: None,
            plan: None,
            cache_dir: None,
        }
    }

    /// Build with all defaults (optimal mapping, fresh compile).
    pub fn open(artifacts_dir: &str) -> Result<Session, DynamapError> {
        Session::builder(artifacts_dir).build()
    }

    // -- introspection ---------------------------------------------------

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn cnn(&self) -> &Cnn {
        &self.cnn
    }

    /// Model name served by this session.
    pub fn model(&self) -> &str {
        &self.cnn.name
    }

    /// The resolved plan (absent when an explicit algorithm map was
    /// supplied).
    pub fn plan(&self) -> Option<&PlanArtifact> {
        self.artifact.as_ref()
    }

    /// `true` when the plan was served from a cache or supplied
    /// explicitly — i.e. no DSE ran during session construction.
    pub fn plan_from_cache(&self) -> bool {
        self.from_cache
    }

    /// Clamped `layer → algorithm` map actually being served.
    pub fn algo_map(&self) -> &BTreeMap<String, String> {
        &self.algo_map
    }

    /// Executables currently compiled in the PJRT cache.
    pub fn loaded_executables(&self) -> usize {
        self.runtime.loaded_count()
    }

    /// Aggregate latency statistics across every request this session
    /// has served.
    pub fn stats(&self) -> &LatencyStats {
        &self.aggregate
    }

    /// Expected input element count `(C · H1 · H2)`.
    pub fn input_len(&self) -> usize {
        let (c, h1, h2) = self.manifest.input;
        c * h1 * h2
    }

    fn artifact_path(&self, layer: &str) -> Result<PathBuf, DynamapError> {
        let algo = self.algo_map.get(layer).ok_or_else(|| {
            DynamapError::Manifest(format!("no algorithm chosen for layer '{layer}'"))
        })?;
        let la = self.manifest.layer(layer).ok_or_else(|| {
            DynamapError::Manifest(format!("manifest has no layer '{layer}'"))
        })?;
        let file = la.algos.get(algo).ok_or_else(|| {
            DynamapError::Manifest(format!("layer '{layer}': no artifact for '{algo}'"))
        })?;
        Ok(self.manifest.dir.join(file))
    }

    // -- serving ---------------------------------------------------------

    /// Run one inference. Input is `(C, H, W)` flattened f32.
    pub fn infer(
        &mut self,
        input: &TensorBuf,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        let t_total = Instant::now();
        let mut per_layer = Vec::new();
        let mut values: BTreeMap<usize, TensorBuf> = BTreeMap::new();
        let order = self.cnn.topo_order();
        let mut final_out = None;
        for id in order {
            let node = self.cnn.node(id).clone();
            let preds = self.cnn.predecessors(id);
            let out = match &node.op {
                Op::Input { c, h1, h2 } => {
                    if input.len() != c * h1 * h2 {
                        return Err(DynamapError::Shape {
                            context: "input".into(),
                            expected: c * h1 * h2,
                            got: input.len(),
                        });
                    }
                    TensorBuf::new(vec![*c, *h1, *h2], input.data.clone())
                }
                Op::Conv(spec) => {
                    let x = &values[&preds[0]];
                    // disjoint field borrows: weights stay borrowed while
                    // the runtime executes — no per-request weight copy
                    let w = &self.weights[&node.name];
                    let path = self.artifact_path(&node.name)?;
                    let t0 = Instant::now();
                    let out = self.runtime.execute(
                        &path,
                        &[x, w],
                        vec![spec.c_out, spec.o1(), spec.o2()],
                    )?;
                    per_layer.push((
                        node.name.clone(),
                        self.algo_map[&node.name].clone(),
                        t0.elapsed().as_secs_f64() * 1e6,
                    ));
                    out
                }
                Op::Pool(p) => {
                    let x = &values[&preds[0]];
                    let t = Tensor { c: p.c, h: p.h1, w: p.h2, data: x.data.clone() };
                    let out = pooling::reference(&t, p);
                    TensorBuf::new(vec![out.c, out.h, out.w], out.data)
                }
                Op::Concat { c_out, h1, h2 } => {
                    let mut data = Vec::with_capacity(c_out * h1 * h2);
                    for &p in &preds {
                        data.extend_from_slice(&values[&p].data);
                    }
                    TensorBuf::new(vec![*c_out, *h1, *h2], data)
                }
                Op::Add { c, h1, h2 } => {
                    let a = &values[&preds[0]];
                    let b = &values[&preds[1]];
                    let data = a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect();
                    TensorBuf::new(vec![*c, *h1, *h2], data)
                }
                Op::Fc { .. } => {
                    return Err(DynamapError::Runtime(
                        "FC layers are not part of the artifact set".into(),
                    ))
                }
                Op::Output => {
                    final_out = Some(values[&preds[0]].clone());
                    continue;
                }
            };
            values.insert(id, out);
        }
        let out = final_out
            .ok_or_else(|| DynamapError::Graph("no output node reached".into()))?;
        let m = InferMetrics {
            total_us: t_total.elapsed().as_secs_f64() * 1e6,
            per_layer_us: per_layer,
        };
        self.aggregate.push(m.total_us);
        Ok((out, m))
    }

    /// Run a batch of requests sequentially on the shared overlay (the
    /// paper's single-sample low-latency regime), collecting per-request
    /// and aggregate latency statistics.
    pub fn infer_batch(
        &mut self,
        inputs: &[TensorBuf],
    ) -> Result<(Vec<TensorBuf>, BatchMetrics), DynamapError> {
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut per_request = Vec::with_capacity(inputs.len());
        let mut stats = LatencyStats::new();
        for input in inputs {
            let (out, m) = self.infer(input)?;
            stats.push(m.total_us);
            outputs.push(out);
            per_request.push(m);
        }
        Ok((outputs, BatchMetrics { per_request, stats }))
    }

    /// Validate against the Python-side golden pair; returns the max
    /// absolute error.
    pub fn validate_golden(&mut self) -> Result<f32, DynamapError> {
        let (gi, go) = self.manifest.golden()?;
        let (c, h1, h2) = self.manifest.input;
        let input = TensorBuf::new(vec![c, h1, h2], gi);
        let (out, _) = self.infer(&input)?;
        if out.data.len() != go.len() {
            return Err(DynamapError::Shape {
                context: "golden output".into(),
                expected: go.len(),
                got: out.data.len(),
            });
        }
        let mut max_err = 0.0f32;
        for (a, b) in out.data.iter().zip(&go) {
            max_err = max_err.max((a - b).abs());
        }
        Ok(max_err)
    }

    /// Latency benchmark: `n` sequential inferences on the golden input
    /// (first call warms the executable cache).
    pub fn bench(&mut self, n: usize) -> Result<LatencyStats, DynamapError> {
        let (gi, _) = self.manifest.golden()?;
        let (c, h1, h2) = self.manifest.input;
        let input = TensorBuf::new(vec![c, h1, h2], gi);
        let mut stats = LatencyStats::new();
        self.infer(&input)?; // warm-up
        for _ in 0..n {
            let (_, m) = self.infer(&input)?;
            stats.push(m.total_us);
        }
        Ok(stats)
    }
}
