//! The DYNAMAP front door: a staged `Compiler → PlanArtifact → Session`
//! pipeline with typed errors.
//!
//! DYNAMAP's value is the split between an *expensive offline* step —
//! the DSE flow of Fig. 7 (Algorithm 1 + PBQP mapping) — and a *cheap
//! online* step — per-layer execution on the reused overlay. This module
//! makes that split the shape of the API:
//!
//! 1. [`Compiler`] — a fluent builder over the DSE. Configure device,
//!    Winograd tile, policy and bounds; `compile(&cnn)` runs the search
//!    exactly once.
//! 2. [`PlanArtifact`] — the compiler's output: a versioned, fully
//!    round-trippable serialization of the plan (`to_json`/`from_json`,
//!    `save`/`load`), cacheable on disk via [`PlanCache`] keyed by
//!    `(model, device, config)`.
//! 3. [`Session`] — the serving layer: resolves the CNN from the AOT
//!    manifest's `model` field through the zoo registry, loads (or
//!    compiles) a plan, lowers every layer's weights once into the
//!    kernel layer's prepared form, pre-compiles every chosen PJRT
//!    executable, and serves [`Session::infer`] /
//!    [`Session::infer_batch`] with per-request and aggregate
//!    [`LatencyStats`]. [`Backend::Native`] serves from the in-process
//!    kernel layer (no HLO artifacts needed) and fans `infer_batch`
//!    out across threads; its request-invariant read state is the
//!    shareable [`NativeState`] the multi-model serving engine
//!    ([`crate::serve`]) builds on.
//!
//! Every fallible call returns the typed [`DynamapError`] instead of
//! `Result<_, String>`.
//!
//! ```no_run
//! use dynamap::api::{Compiler, PlanArtifact, Session};
//! use dynamap::graph::zoo;
//!
//! // offline: compile once, persist the plan artifact
//! let cnn = zoo::googlenet();
//! let artifact = Compiler::new().wino(2, 3).compile(&cnn).unwrap();
//! println!("latency = {:.3} ms", artifact.plan.total_latency_ms);
//! artifact.save("plans/googlenet.json").unwrap();
//!
//! // ... later, possibly in another process: load without re-running DSE
//! let artifact = PlanArtifact::load("plans/googlenet.json").unwrap();
//!
//! // online: serve requests against an AOT artifact directory
//! let mut session = Session::builder("artifacts")
//!     .plan_cache("plans")
//!     .build()
//!     .unwrap();
//! let input = dynamap::runtime::TensorBuf::zeros(vec![4, 16, 16]);
//! let (outputs, metrics) = session.infer_batch(&[input]).unwrap();
//! println!("{} outputs, {}", outputs.len(), metrics.stats.summary());
//! ```
//!
//! ## Migrating from the 0.1 API
//!
//! The 0.1 entry points (and their one-release deprecated shims) are
//! gone. The replacements preserve the call shape, with the typed
//! [`DynamapError`] instead of `Result<_, String>`:
//!
//! * `dse::Dse::{run, run_policy, run_fixed_shape}` →
//!   [`Compiler::compile`] (with [`Compiler::policy`] /
//!   [`Compiler::fixed_shape`]).
//! * `coordinator::InferenceEngine` / `EnginePolicy` →
//!   [`Session::builder`] (with [`SessionBuilder::policy`] /
//!   [`SessionBuilder::algo_map`]).

#![warn(missing_docs)]

pub mod artifact;
pub mod compiler;
pub mod error;
pub mod session;

pub use artifact::{PlanArtifact, PlanCache};
pub use compiler::Compiler;
pub use error::{DynamapError, Result};
pub use session::{Backend, BatchMetrics, InferMetrics, NativeState, Session, SessionBuilder};

pub use crate::coordinator::metrics::LatencyStats;
pub use crate::cost::graph_build::Policy;
pub use crate::cost::Device;
