//! Algorithm 1 — Architecture Parameter Identification.
//!
//! Iterates over `(P_SA1, P_SA2)` pairs within the DSP budget; for each
//! pair sums the best-dataflow execution time of *every* available
//! algorithm on *every* layer (`τ_emp`, lines 6–10) and keeps the
//! minimizing pair. For a fixed `P_SA1` the cost is monotonically
//! non-increasing in `P_SA2`, so only the boundary
//! `P_SA2 = ⌊cap / P_SA1⌋` needs evaluation — this reduces the paper's
//! 2-D loop to a 1-D sweep without changing the result (verified against
//! the exhaustive loop in tests on a small budget).

use crate::cost::conv::CostModel;
use crate::cost::gemm::Dataflow;
use crate::cost::Algo;
use crate::graph::layer::Op;
use crate::graph::Cnn;
use crate::util::parallel::parallel_map;
use std::collections::BTreeMap;

/// Output of Algorithm 1.
#[derive(Debug, Clone)]
pub struct Algo1Result {
    pub p1: usize,
    pub p2: usize,
    /// Empirical total node cost τ_min (seconds).
    pub tau_sec: f64,
    /// ψ: best dataflow per (conv layer node id, algorithm).
    pub dataflow: BTreeMap<(usize, String), Dataflow>,
}

/// Sum over all layers and algorithms of the best-dataflow latency
/// (Algorithm 1 lines 6–10).
pub fn tau_emp(cnn: &Cnn, cm: &CostModel, p1: usize, p2: usize) -> f64 {
    let mut tau = 0.0;
    for node in &cnn.nodes {
        if let Op::Conv(spec) = &node.op {
            for c in cm.layer_options(spec, p1, p2) {
                tau += c.seconds;
            }
        }
    }
    tau
}

/// Run Algorithm 1. `p1_range` bounds the sweep (defaults to `[4, cap]`
/// via [`identify_parameters`]).
pub fn identify_parameters_bounded(
    cnn: &Cnn,
    cm: &CostModel,
    dsp_cap: usize,
    p1_lo: usize,
    p1_hi: usize,
) -> Algo1Result {
    // candidate shapes are independent: evaluate τ_emp across threads,
    // then reduce sequentially in sweep order so ties resolve exactly
    // as the original loop (first/lowest P_SA1 wins)
    let candidates: Vec<(usize, usize)> = (p1_lo..=p1_hi.min(dsp_cap))
        .map(|p1| (p1, dsp_cap / p1))
        .take_while(|&(_, p2)| p2 > 0)
        .collect();
    let taus = parallel_map(&candidates, |_, &(p1, p2)| tau_emp(cnn, cm, p1, p2));
    let mut best: Option<(f64, usize, usize)> = None;
    for (&(p1, p2), tau) in candidates.iter().zip(taus) {
        let better = match best {
            None => true,
            Some((bt, _, _)) => tau < bt,
        };
        if better {
            best = Some((tau, p1, p2));
        }
    }
    let (tau_sec, p1, p2) = best.expect("empty P_SA sweep");
    // record ψ for the winning shape
    let mut dataflow = BTreeMap::new();
    for node in &cnn.nodes {
        if let Op::Conv(spec) = &node.op {
            for algo in Algo::available(spec, cm.wino_m, cm.wino_r, cm.strided_winograd) {
                let c = cm.best_conv_cost(spec, algo, p1, p2);
                dataflow.insert((node.id, algo.name()), c.dataflow);
            }
        }
    }
    Algo1Result { p1, p2, tau_sec, dataflow }
}

/// Run Algorithm 1 with the default sweep bounds `P_SA1 ∈ [4, cap]`.
pub fn identify_parameters(cnn: &Cnn, cm: &CostModel, dsp_cap: usize) -> Algo1Result {
    identify_parameters_bounded(cnn, cm, dsp_cap, 4, dsp_cap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::device::Device;
    use crate::graph::zoo;

    #[test]
    fn boundary_sweep_matches_exhaustive_on_small_budget() {
        let cnn = zoo::mini_inception();
        let cm = CostModel::new(Device::small_edge());
        let cap = 256;
        let fast = identify_parameters_bounded(&cnn, &cm, cap, 1, cap);
        // exhaustive 2-D loop
        let mut best = (f64::INFINITY, 0, 0);
        for p1 in 1..=cap {
            for p2 in 1..=cap {
                if p1 * p2 > cap {
                    continue;
                }
                let tau = tau_emp(&cnn, &cm, p1, p2);
                if tau < best.0 {
                    best = (tau, p1, p2);
                }
            }
        }
        assert!(
            (fast.tau_sec - best.0).abs() < 1e-15,
            "1-D sweep τ={} vs exhaustive τ={} at ({},{})",
            fast.tau_sec,
            best.0,
            best.1,
            best.2
        );
    }

    #[test]
    fn googlenet_shape_is_rectangular_near_cap() {
        let cnn = zoo::googlenet();
        let cm = CostModel::new(Device::alveo_u200());
        let r = identify_parameters_bounded(&cnn, &cm, 6084, 16, 512);
        // paper returns (92, 66); our cost model should land on an
        // elongated (non-square) shape using most of the budget
        assert!(r.p1 * r.p2 <= 6084);
        assert!(
            r.p1 * r.p2 >= 5000,
            "should use most of the DSP budget, got {}x{}",
            r.p1,
            r.p2
        );
        assert_ne!(r.p1, r.p2, "expected a rectangular shape like the paper's (92,66)");
    }

    #[test]
    fn tau_decreases_with_more_pes() {
        let cnn = zoo::mini_inception();
        let cm = CostModel::new(Device::alveo_u200());
        let small = tau_emp(&cnn, &cm, 8, 8);
        let large = tau_emp(&cnn, &cm, 32, 32);
        assert!(large < small);
    }

    #[test]
    fn psi_covers_all_layer_algo_pairs() {
        let cnn = zoo::mini_inception();
        let cm = CostModel::new(Device::alveo_u200());
        let r = identify_parameters_bounded(&cnn, &cm, 1024, 8, 128);
        let mut expected = 0;
        for node in &cnn.nodes {
            if let Op::Conv(spec) = &node.op {
                expected += Algo::available(spec, 2, 3, false).len();
            }
        }
        assert_eq!(r.dataflow.len(), expected);
    }
}
