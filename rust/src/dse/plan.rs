//! DSE configuration + the [`Plan`] produced by the pipeline (Fig. 7
//! steps ①–⑥) — everything the serving layer, the Verilog emitter and
//! the bench harness consume.
//!
//! The pipeline itself is driven by [`crate::api::Compiler`]; the 0.1
//! `Dse` driver shim has been removed (its call shapes map 1:1 onto
//! `Compiler::compile` / `Compiler::policy` / `Compiler::fixed_shape`).

use crate::cost::conv::CostModel;
use crate::cost::graph_build::{BuildOpts, MappingResult};
use crate::cost::transition::TransitionModel;
use crate::cost::{Device, DeviceCalibration, KernelThroughput};
use crate::util::json::Json;

/// Framework configuration: device + model hyper-parameters + search
/// bounds. This is the value a [`Compiler`] builds up fluently; it can
/// also be constructed directly and handed to
/// [`Compiler::from_config`].
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub device: Device,
    pub wino_m: usize,
    pub wino_r: usize,
    /// Enable the strided-Winograd future-work extension (§7).
    pub strided_winograd: bool,
    /// Force a single dataflow (NS-only baselines of Figs. 9/10).
    pub force_dataflow: Option<crate::cost::Dataflow>,
    pub opts: BuildOpts,
    /// `P_SA1` sweep bounds for Algorithm 1.
    pub p1_lo: usize,
    pub p1_hi: usize,
    /// Search int8 beside f32 per layer (see
    /// [`crate::quant`]): widens each conv vertex's PBQP domain to
    /// {algorithm × precision} with DSP-packed int8 pricing and
    /// requantization edge costs. Off by default — quantization changes
    /// numerics, so the precision axis is an explicit opt-in.
    pub precision_search: bool,
    /// Profile-fitted correction of the analytic cost model (identity
    /// by default; produced by `tune::calibrate`).
    pub calibration: DeviceCalibration,
    /// Measured host-microkernel throughput table (empty by default;
    /// produced by [`crate::kernels::KernelSelector::measure`]). When
    /// present, f32 layer latencies are priced from the host SIMD GEMM
    /// rate instead of the analytic overlay cycles.
    pub microkernels: KernelThroughput,
}

impl DseConfig {
    /// Paper evaluation setup: Alveo U200, 6084-DSP cap, F(2×2, 3×3).
    pub fn alveo_u200() -> DseConfig {
        DseConfig {
            device: Device::alveo_u200(),
            wino_m: 2,
            wino_r: 3,
            strided_winograd: false,
            force_dataflow: None,
            opts: BuildOpts::default(),
            p1_lo: 16,
            p1_hi: 512,
            precision_search: false,
            calibration: DeviceCalibration::identity(),
            microkernels: KernelThroughput::default(),
        }
    }

    pub fn with_device(device: Device) -> DseConfig {
        let cap = device.dsp_cap;
        DseConfig {
            device,
            wino_m: 2,
            wino_r: 3,
            strided_winograd: false,
            force_dataflow: None,
            opts: BuildOpts::default(),
            p1_lo: 2,
            p1_hi: cap,
            precision_search: false,
            calibration: DeviceCalibration::identity(),
            microkernels: KernelThroughput::default(),
        }
    }

    pub fn cost_model(&self) -> CostModel {
        let mut cm = CostModel::new(self.device.clone());
        cm.wino_m = self.wino_m;
        cm.wino_r = self.wino_r;
        cm.strided_winograd = self.strided_winograd;
        cm.force_dataflow = self.force_dataflow;
        cm.precision_search = self.precision_search;
        cm.calibration = self.calibration.clone();
        cm.microkernels = self.microkernels.clone();
        cm
    }

    pub fn transition_model(&self) -> TransitionModel {
        let mut tm = TransitionModel::new(self.device.clone());
        tm.wino_m = self.wino_m;
        tm.wino_r = self.wino_r;
        tm
    }
}

/// Full DSE output: architecture parameters + optimal algorithm mapping.
#[derive(Debug, Clone)]
pub struct Plan {
    pub cnn_name: String,
    pub p1: usize,
    pub p2: usize,
    pub tau_sec: f64,
    pub mapping: MappingResult,
    pub total_latency_ms: f64,
    /// End-to-end throughput in GOP/s (2·MACs / latency), the paper's
    /// Table-3 metric.
    pub throughput_gops: f64,
}

impl Plan {
    /// Serialize for the CLI / examples. For the full round-trippable
    /// form use [`crate::api::PlanArtifact`].
    pub fn to_json(&self) -> Json {
        let layers = self
            .mapping
            .layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("name", Json::str(l.name.clone())),
                    ("algo", Json::str(l.cost.algo.name())),
                    ("precision", Json::str(l.cost.precision.name())),
                    ("dataflow", Json::str(l.cost.dataflow.name())),
                    ("cycles", Json::num(l.cost.cycles as f64)),
                    ("utilization", Json::num(l.cost.utilization)),
                ])
            })
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("cnn", Json::str(self.cnn_name.clone())),
            ("p_sa1", Json::num(self.p1 as f64)),
            ("p_sa2", Json::num(self.p2 as f64)),
            ("latency_ms", Json::num(self.total_latency_ms)),
            ("throughput_gops", Json::num(self.throughput_gops)),
            ("compute_ms", Json::num(self.mapping.compute_sec * 1e3)),
            ("transition_ms", Json::num(self.mapping.transition_sec * 1e3)),
            ("layers", Json::Arr(layers)),
        ])
    }

    /// Histogram of chosen algorithms, for reports. Int8 choices count
    /// under a precision-suffixed key ("im2col-int8"), so a
    /// mixed-precision plan's histogram shows the precision split.
    pub fn algo_histogram(&self) -> Vec<(String, usize)> {
        let mut h: std::collections::BTreeMap<String, usize> = Default::default();
        for l in &self.mapping.layers {
            let key = crate::quant::mapped_name(&l.cost.algo.name(), l.cost.precision);
            *h.entry(key).or_insert(0) += 1;
        }
        h.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Compiler;
    use crate::cost::graph_build::Policy;
    use crate::graph::zoo;

    #[test]
    fn full_pipeline_on_mini() {
        let compiler = Compiler::from_config(DseConfig::with_device(Device::small_edge()));
        let plan = compiler.compile(&zoo::mini_inception()).unwrap().into_plan();
        assert!(plan.total_latency_ms > 0.0);
        assert!(plan.throughput_gops > 0.0);
        assert_eq!(plan.mapping.layers.len(), 7);
        // JSON round-trips through the parser
        let j = plan.to_json();
        assert!(crate::util::json::Json::parse(&j.pretty()).is_ok());
    }

    #[test]
    fn opt_beats_baselines_on_googlenet() {
        let compiler = Compiler::from_config(DseConfig::alveo_u200());
        let cnn = zoo::googlenet();
        let opt = compiler.compile(&cnn).unwrap().into_plan();
        for policy in [Policy::Im2colOnly, Policy::Kn2rowApplied, Policy::WinoApplied] {
            let bl = compiler.clone().policy(policy).compile(&cnn).unwrap().into_plan();
            assert!(
                opt.total_latency_ms <= bl.total_latency_ms + 1e-9,
                "OPT {} > {:?} {}",
                opt.total_latency_ms,
                policy,
                bl.total_latency_ms
            );
        }
    }

    #[test]
    fn calibration_flows_into_the_cost_model() {
        let mut cfg = DseConfig::with_device(Device::small_edge());
        cfg.calibration = DeviceCalibration::default().with("kn2row", 7.0, 0.0);
        let cm = cfg.cost_model();
        assert!((cm.calibration.apply("kn2row", 1.0) - 7.0).abs() < 1e-12);
        assert_eq!(cm.calibration.apply("im2col", 1.0), 1.0);
    }
}
