//! The DYNAMAP two-step DSE flow (paper Fig. 7).
//!
//! Step ① [`algo1`] — Architecture Parameter Identification: pick the
//! systolic-array shape `(P_SA1, P_SA2)` and the best dataflow for every
//! (layer, algorithm) pair by minimizing the empirical total node cost.
//! Steps ②–③ — cost-graph construction + optimal PBQP algorithm mapping.
//! Steps ④–⑥ — overlay customization and control-stream generation
//! (continued in [`crate::emit`]).
//!
//! The flow is driven through [`crate::api::Compiler`], which produces a
//! cacheable [`crate::api::PlanArtifact`]. The online `tune` subsystem
//! re-enters this flow at serving time: `tune::remap` re-runs the cost
//! graph + PBQP solve with a profile-calibrated cost model.

pub mod algo1;
pub mod plan;

pub use algo1::{identify_parameters, Algo1Result};
pub use plan::{DseConfig, Plan};
