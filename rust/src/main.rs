//! DYNAMAP command-line interface.
//!
//! Subcommands:
//! * `zoo` — list the built-in model zoo with stats.
//! * `dse --model <name> [--quant]` — run the full DSE flow, print the
//!   plan; `--quant` searches int8 beside f32 per layer.
//! * `compile --model <name> --out <dir|file.json> [--quant]` — run the
//!   DSE once and persist a versioned plan artifact for later sessions.
//! * `baselines --model <name>` — compare OPT vs bl3/bl4/bl5/greedy.
//! * `simulate --model <name>` — cycle-level overlay simulation.
//! * `infer [--plan-cache DIR]` — end-to-end functional inference
//!   through PJRT artifacts, optionally caching the DSE plan on disk.
//! * `serve --models <a,b,…> [--listen ADDR] [--max-inflight N]
//!   [--tune]` — host several models behind the multi-model engine
//!   (registry + dynamic batching). `--listen` serves the TCP wire
//!   protocol with admission control and graceful drain; otherwise a
//!   stdin REPL answers `infer <model> [n]`, `stats`, `models`,
//!   `profile <model> [file]`, `quit`. `--tune` runs the online
//!   profile → calibrate → remap → hot-swap loop.
//! * `loadgen --models <a,b,…> --clients N --requests M` — seeded
//!   closed-loop load through the serving engine; `--compare` reruns
//!   the identical workload unbatched and prints the speedup. With
//!   `--rate QPS` the load is open-loop seeded-Poisson instead (so
//!   overload is reachable), and `--connect ADDR` aims it at a running
//!   `serve --listen` server over TCP (`--shutdown` drains it after;
//!   `--deadline-ms D` attaches per-request deadlines, `--retries N`
//!   retries sheds under backoff, `--hedge` races a second attempt
//!   against slow requests).
//! * `trace --connect ADDR [--out FILE]` — drain a running server's
//!   span ring as Chrome trace-event JSON (Perfetto-loadable); spans
//!   buffer when the server runs with `--trace` or `DYNAMAP_TRACE=1`.
//! * `stats --connect ADDR` — scrape a running server's metrics +
//!   latency-histogram snapshot (read-only, poll-safe).
//! * `tune --model <name> --profile <file>` — one-shot cost-model
//!   calibration + re-map from a recorded profile; prints the residual
//!   report, the algorithm-map diff and the predicted speedup.
//! * `figures --out <dir>` — regenerate every paper table/figure.
//! * `emit --model <name> --out <dir>` — emit Verilog + control streams.

use dynamap::api::{Compiler, DynamapError};
use dynamap::dse::DseConfig;
use dynamap::graph::zoo;
use dynamap::util::cli::Args;
use dynamap::util::table::Table;

fn main() {
    let args = Args::parse_env(&[
        "json", "verbose", "no-fuse", "no-synth", "compare", "tune", "quant", "shutdown",
        "hedge", "measure", "trace",
    ]);
    // deterministic fault injection, opt-in via DYNAMAP_FAULTS (chaos
    // testing a live server without a rebuild); off = zero cost
    if let Some(plan) = dynamap::fault::FaultPlan::from_env() {
        eprintln!(
            "fault injection active (seed {}): DYNAMAP_FAULTS={}",
            plan.seed,
            std::env::var("DYNAMAP_FAULTS").unwrap_or_default()
        );
        dynamap::fault::install(plan);
    }
    // span recorder, opt-in via DYNAMAP_TRACE=1 (tracing a live server
    // without a rebuild, like DYNAMAP_FAULTS above); off = one relaxed
    // atomic load per would-be span
    dynamap::obs::install_from_env();
    let code = match args.subcommand.as_deref() {
        Some("zoo") => cmd_zoo(),
        Some("dse") => cmd_dse(&args),
        Some("compile") => cmd_compile(&args),
        Some("baselines") => cmd_baselines(&args),
        Some("simulate") => dynamap::coordinator::cli::simulate(&args),
        Some("infer") => dynamap::coordinator::cli::infer(&args),
        Some("serve") => dynamap::serve::cli::serve(&args),
        Some("loadgen") => dynamap::serve::cli::loadgen(&args),
        Some("trace") => dynamap::serve::cli::trace(&args),
        Some("stats") => dynamap::serve::cli::stats(&args),
        Some("tune") => dynamap::tune::cli::tune(&args),
        Some("figures") => dynamap::bench::figures::cli(&args),
        Some("emit") => dynamap::emit::cli(&args),
        _ => {
            eprintln!(
                "usage: dynamap <zoo|dse|compile|baselines|simulate|infer|serve|loadgen|\
                 trace|stats|tune|figures|emit> [--model NAME] [--models A,B] [--clients N] \
                 [--requests M] [--listen ADDR] [--connect ADDR] [--rate QPS] \
                 [--max-inflight N] [--deadline-ms D] [--retries N] [--hedge] \
                 [--dsp N] [--out DIR] [--plan-cache DIR] \
                 [--profile FILE] [--tune] [--quant] [--measure] [--trace] \
                 [--trace-out FILE] [--json]"
            );
            2
        }
    };
    std::process::exit(code);
}

/// Load a model by zoo name or JSON file path.
fn load_model(args: &Args) -> Result<dynamap::graph::Cnn, DynamapError> {
    let name = args.get_or("model", "googlenet");
    if let Some(m) = zoo::by_name(name) {
        return Ok(m);
    }
    dynamap::graph::config::load(name).map_err(DynamapError::Graph)
}

/// Build a Compiler from CLI overrides.
fn compiler_from(args: &Args) -> Compiler {
    let mut cfg = DseConfig::alveo_u200();
    if let Some(dsp) = args.get("dsp") {
        cfg.device.dsp_cap = dsp.parse().unwrap_or(cfg.device.dsp_cap);
    }
    cfg.device.ddr_gbps = args.get_f64("bw", cfg.device.ddr_gbps);
    cfg.device.freq_mhz = args.get_f64("freq", cfg.device.freq_mhz);
    if args.has("no-fuse") {
        cfg.opts.sram_fuse = false;
    }
    // --quant: search int8 beside f32 per layer (precision axis)
    cfg.precision_search = args.has("quant");
    Compiler::from_config(cfg)
}

fn cmd_zoo() -> i32 {
    for name in zoo::names() {
        let m = zoo::by_name(name).unwrap();
        println!("{}", m.summary());
    }
    0
}

fn cmd_dse(args: &Args) -> i32 {
    let cnn = match load_model(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let compiler = compiler_from(args);
    let t0 = std::time::Instant::now();
    let plan = match compiler.compile(&cnn) {
        Ok(a) => a.into_plan(),
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let dt = t0.elapsed();
    if args.has("json") {
        println!("{}", plan.to_json().pretty());
        return 0;
    }
    println!(
        "model={} P_SA=({}, {})  latency={:.3} ms  throughput={:.0} GOP/s  (DSE took {:.2?})",
        plan.cnn_name, plan.p1, plan.p2, plan.total_latency_ms, plan.throughput_gops, dt
    );
    println!(
        "  compute {:.3} ms + transitions {:.3} ms",
        plan.mapping.compute_sec * 1e3,
        plan.mapping.transition_sec * 1e3
    );
    println!("  algorithm histogram: {:?}", plan.algo_histogram());
    if args.has("verbose") {
        let mut t = Table::new(
            "per-layer mapping",
            &["layer", "algo", "precision", "dataflow", "cycles", "util"],
        );
        for l in &plan.mapping.layers {
            t.row(vec![
                l.name.clone(),
                l.cost.algo.name(),
                l.cost.precision.name().into(),
                l.cost.dataflow.name().into(),
                l.cost.cycles.to_string(),
                format!("{:.3}", l.cost.utilization),
            ]);
        }
        println!("{}", t.render());
    }
    0
}

/// Run the DSE once and persist the versioned plan artifact — the
/// offline half of the staged `Compiler → PlanArtifact → Session` flow.
fn cmd_compile(args: &Args) -> i32 {
    let cnn = match load_model(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let compiler = compiler_from(args);
    let artifact = match compiler.compile(&cnn) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let out = args.get_or("out", "plans");
    let path = if out.ends_with(".json") {
        std::path::PathBuf::from(out)
    } else {
        std::path::Path::new(out).join(compiler.cache_file_name(&cnn.name))
    };
    if let Err(e) = artifact.save(&path) {
        eprintln!("error: {e}");
        return 1;
    }
    println!(
        "wrote {} (model={}, P_SA = {}×{}, latency {:.3} ms)",
        path.display(),
        artifact.model,
        artifact.plan.p1,
        artifact.plan.p2,
        artifact.plan.total_latency_ms
    );
    0
}

fn cmd_baselines(args: &Args) -> i32 {
    use dynamap::cost::graph_build::Policy;
    let cnn = match load_model(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let compiler = compiler_from(args);
    let opt = match compiler.compile(&cnn) {
        Ok(a) => a.into_plan(),
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let mut t = Table::new(
        &format!("{} — OPT vs baselines", cnn.name),
        &["mapping", "latency ms", "vs OPT"],
    );
    t.row(vec!["OPT (DYNAMAP)".into(), format!("{:.3}", opt.total_latency_ms), "1.00×".into()]);
    for (label, policy) in [
        ("bl3 im2col-only", Policy::Im2colOnly),
        ("bl4 kn2row-applied", Policy::Kn2rowApplied),
        ("bl5 wino-applied", Policy::WinoApplied),
        ("greedy node-cost", Policy::Greedy),
    ] {
        let p = compiler.clone().policy(policy).compile(&cnn).unwrap().into_plan();
        t.row(vec![
            label.into(),
            format!("{:.3}", p.total_latency_ms),
            format!("{:.2}×", p.total_latency_ms / opt.total_latency_ms),
        ]);
    }
    println!("{}", t.render());
    0
}
