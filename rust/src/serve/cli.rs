//! `dynamap serve` and `dynamap loadgen` subcommands.
//!
//! `serve` exposes the multi-model engine over two transports behind
//! the same [`ModelRegistry`]: with `--listen <addr>` the TCP
//! front-end ([`crate::net::NetServer`]) speaks the length-prefixed
//! binary protocol (admission control via `--max-inflight`, graceful
//! drain on a remote `Shutdown` frame); without it, a line-oriented
//! stdin REPL (`infer <model> [n]`, `stats`, `models`,
//! `profile <model> [file]`, `quit`). With `--tune` the server also
//! runs the online adaptation loop ([`crate::tune`]): per-layer
//! profiling, cost-model calibration and zero-downtime plan hot-swaps,
//! with `stats` printing the observed-vs-predicted per-layer table.
//! `loadgen` drives the engine four ways: the seeded closed-loop
//! generator (default; `--compare` reruns the identical workload with
//! batching disabled and prints the speedup), open-loop seeded-Poisson
//! in process (`--rate <qps>`), open-loop over TCP against a
//! running server (`--connect <addr> --rate <qps>`, with `--shutdown`
//! draining the server afterwards), or seeded *mixed* multi-tenant
//! open loop (`--tenants "model=RATExREQS[@SLO_MS],..."`) with
//! per-tenant SLO-attainment reporting — in process the tenant specs
//! also derive the registry's SLO table, so the co-scheduler in
//! [`crate::serve::sched`] is exercised, not just measured.
//!
//! `serve --slo "model=MS[@PRIO],model=be,..."` attaches per-model
//! SLOs: the thread-budget partitioner splits the host's cores across
//! tenants by priority × demand, each tenant's plan is re-solved under
//! its partition (fingerprint-keyed, so re-solves hit the plan cache
//! on restart) and best-effort flushes defer while an interactive
//! tenant is behind.
//!
//! Two observability subcommands scrape a running server over the same
//! protocol: `trace --connect <addr>` drains its span ring as Chrome
//! trace-event JSON ([`crate::obs`]) and `stats --connect <addr>`
//! fetches the live metrics + latency-histogram snapshot.

use std::io::BufRead;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::{Compiler, DynamapError};
use crate::coordinator::metrics::LatencyStats;
use crate::graph::zoo;
use crate::net::{Client, HedgeConfig, NetServer, RetryPolicy};
use crate::runtime::TensorBuf;
use crate::tune::{observed_vs_predicted, TuneConfig, TuneController};
use crate::util::cli::Args;
use crate::util::parallel::{parallel_run, worker_count};
use crate::util::rng::Rng;

use super::loadgen::{self, InferTarget, LoadgenConfig, MixedConfig, OpenLoopConfig, TenantLoad};
use super::queue::BatchConfig;
use super::registry::{ModelRegistry, RegistryConfig};
use super::sched::{ModelSlo, SloTable};

/// Shared flags → [`RegistryConfig`] (`--root`, `--plan-cache`,
/// `--cap`, `--max-batch`, `--max-wait-ms`, `--max-inflight`,
/// `--seed`, `--no-synth`, `--quant`, `--measure`). `--max-inflight`
/// bounds each model's admitted-but-unreplied requests; excess is shed
/// with the retriable `Overloaded` error (0 = unbounded, the default).
/// `--quant` compiles every hosted model with precision
/// search on, so the DSE may serve layers int8 (quantized plans key
/// their own plan-cache entries and `tune` re-solves keep the flag).
/// `--measure` times the host's microkernel tiers once at startup
/// ([`Compiler::measure_microkernels`]) so plans are priced from this
/// machine's measured GEMM throughput (measured tables key their own
/// plan-cache entries too).
/// Profiling stays off here; only `serve` (the command that can run
/// the tune loop) opts in — `loadgen` must not silently add profiler
/// overhead to the hot path it exists to measure.
///
/// Unless `--cap` is given explicitly, capacity grows to fit every
/// listed model — serving a model list that LRU-thrashes by default
/// would make warm-up meaningless; capacity pressure is something to
/// opt into.
fn registry_config(args: &Args, models: usize, slos: SloTable) -> RegistryConfig {
    RegistryConfig {
        slos,
        artifacts_root: args.get_or("root", "serve-models").into(),
        plan_cache: Some(args.get_or("plan-cache", "plans").into()),
        capacity: match args.get("cap") {
            Some(_) => args.get_usize("cap", 4),
            None => models.max(4),
        },
        synthesize_missing: !args.has("no-synth"),
        seed: args.get_usize("seed", 0x5EED) as u64,
        batch: BatchConfig {
            max_batch: args.get_usize("max-batch", 8).max(1),
            max_wait: Duration::from_secs_f64(args.get_f64("max-wait-ms", 2.0).max(0.0) / 1e3),
        },
        max_inflight: args.get_usize("max-inflight", 0),
        compiler: {
            let compiler = Compiler::new().precision_search(args.has("quant"));
            if args.has("measure") {
                compiler.measure_microkernels()
            } else {
                compiler
            }
        },
        ..RegistryConfig::default()
    }
}

/// Parse `--slo "model=MS[@PRIO],model=be,..."` into a [`SloTable`].
/// `model=100` reads "100 ms p99 target at interactive priority",
/// `model=100@8` overrides the priority, and `model=be` (aliases
/// `bulk`, `best-effort`) marks the model a deferrable best-effort
/// tenant. An absent flag yields the empty table — multi-tenant
/// scheduling stays off and the registry behaves exactly as before.
fn slo_table(args: &Args) -> Result<SloTable, DynamapError> {
    let mut table = SloTable::new();
    let Some(spec) = args.get("slo") else { return Ok(table) };
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let Some((model, rhs)) = entry.split_once('=') else {
            return Err(DynamapError::Config(format!(
                "--slo entry '{entry}' must be model=<ms>[@prio] or model=be"
            )));
        };
        let slo = match rhs.trim() {
            "be" | "bulk" | "best-effort" => ModelSlo::bulk(),
            rhs => {
                let (ms, prio) = match rhs.split_once('@') {
                    Some((ms, p)) => (ms, Some(p)),
                    None => (rhs, None),
                };
                let ms: u64 = ms.trim().parse().map_err(|_| {
                    DynamapError::Config(format!(
                        "--slo entry '{entry}': '{ms}' is not a millisecond count"
                    ))
                })?;
                let slo = ModelSlo::interactive_ms(ms as f64);
                match prio {
                    Some(p) => {
                        let p: u32 = p.trim().parse().map_err(|_| {
                            DynamapError::Config(format!(
                                "--slo entry '{entry}': '{p}' is not a priority"
                            ))
                        })?;
                        slo.with_priority(p)
                    }
                    None => slo,
                }
            }
        };
        table.insert(model.trim().to_string(), slo);
    }
    Ok(table)
}

/// Parse `--tenants "model=RATExREQS[@SLO_MS],..."` into the mixed
/// open-loop workload: `mini=200x160@100` offers 200 qps × 160
/// requests under a 100 ms SLO; omitting `@SLO_MS` makes the tenant
/// bulk (measured on service rate alone). Every tenant inherits the
/// shared `--deadline-ms`, if given.
fn parse_tenants(
    spec: &str,
    deadline: Option<Duration>,
) -> Result<Vec<TenantLoad>, DynamapError> {
    let mut tenants = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let bad = || {
            DynamapError::Config(format!(
                "--tenants entry '{entry}' must be model=RATExREQS[@SLO_MS] \
                 (e.g. mini=200x160@100 or mini-vgg=4000x600)"
            ))
        };
        let (model, rhs) = entry.split_once('=').ok_or_else(bad)?;
        let (load, slo_ms) = match rhs.split_once('@') {
            Some((load, slo)) => (load, Some(slo.trim().parse::<u64>().map_err(|_| bad())?)),
            None => (rhs, None),
        };
        let (rate, requests) = load.split_once('x').ok_or_else(bad)?;
        tenants.push(TenantLoad {
            model: model.trim().to_string(),
            rate_qps: rate.trim().parse().map_err(|_| bad())?,
            requests: requests.trim().parse().map_err(|_| bad())?,
            slo: slo_ms.map(Duration::from_millis),
            deadline,
        });
    }
    if tenants.is_empty() {
        return Err(DynamapError::Config(
            "--tenants needs at least one model=RATExREQS[@SLO_MS] entry".into(),
        ));
    }
    Ok(tenants)
}

fn model_list(args: &Args, default: &str) -> Vec<String> {
    args.get_or("models", default)
        .split(',')
        .map(|m| m.trim().to_string())
        .filter(|m| !m.is_empty())
        .collect()
}

/// `dynamap serve --models mini,googlenet [--max-batch 8]
/// [--max-wait-ms 2] [--cap 4] [--root DIR] [--plan-cache DIR]
/// [--listen ADDR] [--max-inflight N] [--tune]` — host the listed
/// models behind batch queues. With `--listen` (e.g. `127.0.0.1:0`)
/// the TCP front-end serves the wire protocol until a client sends
/// `Shutdown`, then drains gracefully; without it, answer stdin
/// commands until EOF/`quit`. `--tune` (or `DYNAMAP_TUNE=1` in the
/// environment) profiles the serving path and runs the background
/// calibrate → remap → hot-swap loop (cadence knobs via
/// `DYNAMAP_TUNE_*` env vars). `--trace` (or `DYNAMAP_TRACE=1`)
/// installs the process-wide span recorder ([`crate::obs`]): every
/// request's admission/queue/flush/layer spans buffer in-process, and
/// `dynamap trace --connect <addr>` drains them as Chrome trace JSON.
pub fn serve(args: &Args) -> i32 {
    let models = model_list(args, "mini");
    if args.has("trace") && !crate::obs::is_active() {
        crate::obs::install(Arc::new(crate::obs::Recorder::with_default_capacity()));
        println!(
            "tracing enabled: spans buffer in-process \
             (drain with `dynamap trace --connect <addr> --out trace.json`)"
        );
    }
    // either opt-in enables the adaptation loop
    let tune_on = args.has("tune") || TuneConfig::from_env().is_some();
    let slos = match slo_table(args) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let mut config = registry_config(args, models.len(), slos);
    config.profile = tune_on;
    let registry = Arc::new(ModelRegistry::new(config));
    for model in &models {
        match registry.host(model) {
            Ok(host) => {
                let (c, h1, h2) = host.input_dims();
                println!(
                    "model ready: {} (input {}×{}×{}, {} prepared layers, plan {})",
                    host.model(),
                    c,
                    h1,
                    h2,
                    host.state().prepared_count(),
                    if host.plan_from_cache() { "from cache" } else { "freshly compiled" },
                );
            }
            Err(e) => {
                eprintln!("error hosting '{model}': {e}");
                return 1;
            }
        }
    }
    if !registry.config().slos.is_empty() {
        // partition once over the warm model set and re-solve each
        // tenant's plan under its budget *before* taking traffic, so
        // the first requests already run partition-priced plans
        let budgets = registry.repartition();
        let parts: Vec<String> =
            budgets.iter().map(|(model, threads)| format!("{model}={threads}")).collect();
        println!(
            "slo scheduling on: thread partition [{}] of {} worker threads",
            parts.join(", "),
            worker_count(usize::MAX),
        );
        match registry.resolve_partition_plans() {
            Ok(n) if n > 0 => {
                println!("partition plans resolved: {n} model(s) re-planned under their budgets");
            }
            Ok(_) => {}
            Err(e) => {
                eprintln!("error resolving partition plans: {e}");
                return 1;
            }
        }
    }
    let controller = if tune_on {
        // the DYNAMAP_TUNE_* cadence knobs apply with or without the
        // DYNAMAP_TUNE enable flag (--tune already opted in)
        let mut tune_config = TuneConfig::knobs_from_env();
        tune_config.verbose = true;
        println!(
            "online tuning enabled: calibrate + remap every {:?} once a model has \
             {} fresh profiled requests (hysteresis {:.2})",
            tune_config.interval, tune_config.min_new_requests, tune_config.hysteresis,
        );
        Some(TuneController::spawn(registry.clone(), tune_config))
    } else {
        None
    };
    if let Some(listen) = args.get("listen") {
        return serve_net(registry, controller, listen);
    }
    println!(
        "serving {} model(s) [max_batch={}, max_wait={:?}] — commands: \
         infer <model> [n] | stats | models | profile <model> [file] | quit",
        models.len(),
        registry.config().batch.max_batch,
        registry.config().batch.max_wait,
    );
    let stdin = std::io::stdin();
    let mut burst: u64 = 0;
    for line in stdin.lock().lines() {
        let Ok(line) = line else { break };
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("infer") => {
                let model = parts.next().unwrap_or("mini").to_string();
                let n = parts.next().and_then(|v| v.parse().ok()).unwrap_or(1).max(1);
                burst += 1;
                match infer_burst(&registry, &model, n, burst) {
                    Ok(msg) => println!("{msg}"),
                    Err(e) => eprintln!("error: {e}"),
                }
            }
            Some("stats") => {
                println!("{}", registry.metrics().report());
                print_tune_tables(&registry);
            }
            Some("models") => {
                println!("resident (LRU → MRU): {:?}", registry.resident());
                println!("zoo: {:?}", zoo::names());
            }
            Some("profile") => {
                let model = parts.next().unwrap_or("mini").to_string();
                match save_profile(&registry, &model, parts.next()) {
                    Ok(msg) => println!("{msg}"),
                    Err(e) => eprintln!("error: {e}"),
                }
            }
            Some("quit") | Some("exit") => break,
            None => {}
            Some(other) => {
                println!(
                    "unknown command '{other}' — infer <model> [n] | stats | models | \
                     profile <model> [file] | quit"
                );
            }
        }
    }
    println!("{}", registry.metrics().report());
    if let Some(controller) = controller {
        controller.shutdown();
        println!(
            "tune loop: {} pass(es), {} hot-swap(s)",
            controller.passes(),
            controller.swaps()
        );
    }
    registry.shutdown();
    0
}

/// The `--listen` arm of `serve`: bind the TCP front-end, block until
/// a client's `Shutdown` frame (or the accept loop is stopped), drain
/// the front-end (every accepted request gets its reply), then drain
/// the batch queues. The "listening on" line carries the actual bound
/// address so `--listen 127.0.0.1:0` callers (tests, CI) can discover
/// the ephemeral port, and the final stats table surfaces the per-model
/// shed counters.
fn serve_net(
    registry: Arc<ModelRegistry>,
    controller: Option<TuneController>,
    listen: &str,
) -> i32 {
    let mut server = match NetServer::bind(registry.clone(), listen) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error binding {listen}: {e}");
            return 1;
        }
    };
    println!(
        "listening on {} (max_inflight={}, send a Shutdown frame to drain)",
        server.local_addr(),
        registry.config().max_inflight,
    );
    server.wait_shutdown();
    println!("shutdown requested — draining connections");
    server.shutdown();
    println!("{}", registry.metrics().report());
    if let Some(controller) = controller {
        controller.shutdown();
        println!(
            "tune loop: {} pass(es), {} hot-swap(s)",
            controller.passes(),
            controller.swaps()
        );
    }
    registry.shutdown();
    println!("drained cleanly");
    0
}

/// `stats` tail: one observed-vs-predicted table per resident model
/// that carries a profile (i.e. when serving with `--tune`), so
/// calibration quality is inspectable without a bench run.
fn print_tune_tables(registry: &ModelRegistry) {
    for model in registry.resident() {
        // peek: a stats report must not touch LRU recency
        let Some(host) = registry.peek(&model) else { continue };
        let (Some(profile), Some((p1, p2))) = (host.profile(), host.plan_shape()) else {
            continue;
        };
        let state = host.state();
        let table = observed_vs_predicted(
            state.cnn(),
            &registry.config().compiler,
            p1,
            p2,
            state.algo_map(),
            &profile.snapshot(),
        );
        println!("{}", table.render());
        println!("  (epoch {}, {} profiled requests)", host.epoch(), profile.requests());
    }
}

/// `profile <model> [file]`: dump the model's recorded profile as JSON
/// (to stdout without a file argument) — the input `dynamap tune`
/// replays offline.
fn save_profile(
    registry: &ModelRegistry,
    model: &str,
    file: Option<&str>,
) -> Result<String, DynamapError> {
    // peek: dumping a profile must not host a cold model (its profile
    // would necessarily be empty) or touch LRU recency
    let Some(host) = registry.peek(model) else {
        return Err(DynamapError::Serve(format!(
            "model '{model}' is not resident — serve a request to it first"
        )));
    };
    let Some(profile) = host.profile() else {
        return Err(DynamapError::Serve(
            "profiling is off — start the server with --tune".into(),
        ));
    };
    match file {
        Some(path) => {
            profile.save(path)?;
            Ok(format!(
                "wrote {} ({} keys over {} requests)",
                path,
                profile.len(),
                profile.requests()
            ))
        }
        None => Ok(profile.to_json().pretty()),
    }
}

/// Submitter-thread cap for the REPL's `infer <model> [n]` bursts.
const BURST_THREADS: usize = 64;

/// Submit `n` concurrent seeded-random requests to one model and
/// summarize the burst. Concurrency is capped at [`BURST_THREADS`]
/// submitter threads that interleave the `n` requests, and inputs are
/// generated inside each thread — an oversized `infer mini 200000`
/// must not pre-allocate gigabytes or exhaust OS threads and take the
/// whole server down with it.
fn infer_burst(
    registry: &ModelRegistry,
    model: &str,
    n: usize,
    burst: u64,
) -> Result<String, DynamapError> {
    let host = registry.host(model)?;
    let (c, h1, h2) = host.input_dims();
    let threads = n.min(BURST_THREADS);
    let t0 = Instant::now();
    let per_thread = parallel_run(threads, |t| {
        let mut results = Vec::new();
        let mut i = t;
        while i < n {
            let mut rng = Rng::new(0xB005 ^ (burst << 20) ^ i as u64);
            let input = TensorBuf::new(
                vec![c, h1, h2],
                (0..c * h1 * h2).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            );
            results.push(registry.infer(model, &input));
            i += threads;
        }
        results
    });
    let wall = t0.elapsed();
    let mut compute = LatencyStats::new();
    let mut shape = Vec::new();
    for r in per_thread.into_iter().flatten() {
        let (out, m) = r?;
        compute.push(m.total_us);
        shape = out.shape;
    }
    Ok(format!(
        "{}: {n} request(s) in {wall:.2?} → output shape {shape:?}; compute {}",
        host.model(),
        compute.summary()
    ))
}

/// `dynamap loadgen` in three modes:
///
/// * `--models mini,googlenet --clients N --requests M [--seed S]
///   [--compare]` — closed-loop load through an in-process engine;
///   `--requests` counts per client, `--compare` reruns the identical
///   workload with batching disabled and prints the speedup.
/// * `--rate QPS [--requests N] [--workers W]` — open-loop
///   seeded-Poisson load through an in-process engine (overload is
///   reachable; the summary separates ok/shed/errors).
/// * `--connect ADDR --rate QPS [--shutdown]` — the same open loop
///   over TCP against a running `serve --listen` server, via the
///   pooled [`Client`]; `--shutdown` drains the server afterwards.
/// * `--tenants "model=RATExREQS[@SLO_MS],..."` — seeded mixed
///   multi-tenant open loop ([`loadgen::open_loop_mixed`]) with
///   per-tenant SLO attainment, in process or with `--connect`.
///
/// Open-loop reliability knobs: `--deadline-ms D` attaches a relative
/// deadline to every request (expired ones are shed server-side with
/// the typed `DeadlineExceeded`, reported as `dl_miss=`);
/// `--retries N` grants N extra attempts on `Overloaded` sheds
/// (honoring the server's `retry_after_ms` hint under capped
/// exponential backoff); `--hedge` enables a hedged second attempt
/// once a request outlives the client's latency EWMA. The latter two
/// apply only with `--connect` — they are client policy.
///
/// `--trace` stamps every open-loop request with a deterministic
/// [`crate::obs::TraceId`] derived from `--seed`. In process the span
/// recorder is installed for the run and the Chrome trace JSON is
/// written to `--trace-out FILE` (or summarized to stdout); over TCP
/// the ids ride the protocol-v3 trailer and the spans buffer in the
/// server — drain them with `dynamap trace --connect ADDR`.
pub fn loadgen(args: &Args) -> i32 {
    if args.get("tenants").is_some() {
        return loadgen_mixed(args);
    }
    if args.has("connect") || args.get("connect").is_some() || args.get("rate").is_some() {
        return loadgen_open(args);
    }
    let cfg = LoadgenConfig {
        models: model_list(args, "mini"),
        clients: args.get_usize("clients", 4).max(1),
        requests: args.get_usize("requests", 32).max(1),
        seed: args.get_usize("seed", 99) as u64,
    };
    let reg_cfg = registry_config(args, cfg.models.len(), SloTable::new());
    println!(
        "loadgen: {:?} × {} clients × {} req/client (seed {}, max_batch={}, max_wait={:?})",
        cfg.models,
        cfg.clients,
        cfg.requests,
        cfg.seed,
        reg_cfg.batch.max_batch,
        reg_cfg.batch.max_wait,
    );
    let registry = ModelRegistry::new(reg_cfg.clone());
    let report = match loadgen::run(&registry, &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("loadgen failed: {e}");
            return 1;
        }
    };
    println!("batched: {}", report.summary());
    println!("{}", registry.metrics().report());
    registry.shutdown();
    if args.has("compare") {
        let mut seq_cfg = reg_cfg;
        seq_cfg.batch.max_batch = 1;
        let seq_registry = ModelRegistry::new(seq_cfg);
        match loadgen::run(&seq_registry, &cfg) {
            Ok(seq) => {
                println!("one-at-a-time (max_batch=1): {}", seq.summary());
                if seq.throughput_rps > 0.0 {
                    println!(
                        "dynamic batching speedup: {:.2}x",
                        report.throughput_rps / seq.throughput_rps
                    );
                }
            }
            Err(e) => {
                eprintln!("comparison run failed: {e}");
                return 1;
            }
        }
        seq_registry.shutdown();
    }
    0
}

/// The open-loop arm of `loadgen` (`--rate` and/or `--connect`).
/// Offered load, request count and worker cap come from the CLI; the
/// target is a TCP [`Client`] when `--connect ADDR` is given, the
/// in-process registry otherwise. The printed summary's `shed=` field
/// is machine-parsed by the CI smoke job.
fn loadgen_open(args: &Args) -> i32 {
    let models = model_list(args, "mini");
    let cfg = OpenLoopConfig {
        model: models.first().cloned().unwrap_or_else(|| "mini".into()),
        rate_qps: args.get_f64("rate", 200.0),
        requests: args.get_usize("requests", 256).max(1),
        seed: args.get_usize("seed", 99) as u64,
        workers: args.get_usize("workers", 64).max(1),
        deadline: args
            .get("deadline-ms")
            .map(|_| Duration::from_millis(args.get_usize("deadline-ms", 250) as u64)),
        trace: args.has("trace"),
    };
    if models.len() > 1 {
        eprintln!(
            "note: open-loop mode drives one model; using '{}' (got {models:?})",
            cfg.model
        );
    }
    println!(
        "open loop: {} @ {:.0} qps offered, {} requests (seed {}, {} workers{})",
        cfg.model,
        cfg.rate_qps,
        cfg.requests,
        cfg.seed,
        cfg.workers,
        match cfg.deadline {
            Some(d) => format!(", deadline {d:?}"),
            None => String::new(),
        },
    );
    let run = |target: &dyn InferTarget| loadgen::open_loop(target, &cfg);
    let report = match args.get("connect") {
        Some(addr) => {
            let policy = RetryPolicy {
                overloaded_attempts: args.get_usize("retries", 0) as u32,
                hedge: args.has("hedge").then(HedgeConfig::default),
                seed: args.get_usize("seed", 99) as u64,
                ..RetryPolicy::default()
            };
            let client = match Client::connect_with(addr, policy) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("connect failed: {e}");
                    return 1;
                }
            };
            let report = run(&client);
            let stats = client.stats();
            if stats.retries > 0 || stats.hedges_won > 0 {
                println!(
                    "client: {} retries, {} hedges won, {} budget tokens left",
                    stats.retries, stats.hedges_won, stats.budget_remaining
                );
            }
            if cfg.trace {
                println!(
                    "trace ids sent on the wire — drain spans with \
                     `dynamap trace --connect {addr} --out trace.json`"
                );
            }
            if args.has("shutdown") {
                match client.shutdown_server() {
                    Ok(()) => println!("server drain requested"),
                    Err(e) => eprintln!("shutdown request failed: {e}"),
                }
            }
            report
        }
        None => {
            if args.has("connect") {
                eprintln!("--connect needs an address (e.g. --connect 127.0.0.1:4071)");
                return 1;
            }
            // RAII so a panicking run still uninstalls the recorder;
            // skipped when one is already live (e.g. DYNAMAP_TRACE=1)
            // so we don't tear down an ambient recorder on exit.
            let _guard = (cfg.trace && !crate::obs::is_active())
                .then(|| crate::obs::ObsGuard::install(crate::obs::DEFAULT_CAPACITY));
            let registry = ModelRegistry::new(registry_config(args, 1, SloTable::new()));
            let report = run(&registry);
            if report.is_ok() {
                println!("{}", registry.metrics().report());
            }
            registry.shutdown();
            if cfg.trace {
                if let Some(rec) = crate::obs::active() {
                    let spans = rec.drain();
                    let json = crate::obs::chrome_trace(&spans).to_string();
                    match args.get("trace-out") {
                        Some(path) => match std::fs::write(path, &json) {
                            Ok(()) => println!(
                                "wrote {path} ({} span events) — load in Perfetto or \
                                 chrome://tracing",
                                spans.len()
                            ),
                            Err(e) => eprintln!("error writing {path}: {e}"),
                        },
                        None => println!(
                            "captured {} span events ({} dropped) — rerun with \
                             --trace-out FILE to export Chrome trace JSON",
                            spans.len(),
                            rec.dropped()
                        ),
                    }
                }
            }
            report
        }
    };
    match report {
        Ok(r) => {
            println!("{}", r.summary());
            0
        }
        Err(e) => {
            eprintln!("open-loop loadgen failed: {e}");
            1
        }
    }
}

/// The mixed multi-tenant arm of `loadgen`
/// (`--tenants "model=RATExREQS[@SLO_MS],..."`): every tenant's
/// seeded-Poisson stream is merged into one arrival timeline and the
/// per-tenant summary ends with the aggregate
/// `slo attainment: high=NN.N% bulk=NN.N%` line the CI `slo-smoke`
/// job parses. In process, the tenant specs double as the registry's
/// SLO table (`@SLO_MS` → interactive at that target, no SLO → bulk)
/// and the partition plans are resolved before load is offered, so
/// the run measures the co-scheduler, not compile stalls. With
/// `--connect ADDR` the same workload rides the TCP client against a
/// server whose own `--slo` flags govern scheduling.
fn loadgen_mixed(args: &Args) -> i32 {
    let deadline = args
        .get("deadline-ms")
        .map(|_| Duration::from_millis(args.get_usize("deadline-ms", 250) as u64));
    let tenants = match parse_tenants(&args.get_or("tenants", ""), deadline) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let cfg = MixedConfig {
        tenants,
        seed: args.get_usize("seed", 99) as u64,
        workers: args.get_usize("workers", 64).max(1),
    };
    println!(
        "mixed open loop: {} tenant(s), seed {}, {} workers",
        cfg.tenants.len(),
        cfg.seed,
        cfg.workers
    );
    for t in &cfg.tenants {
        println!(
            "  {} @ {:.0} qps × {} requests{}",
            t.model,
            t.rate_qps,
            t.requests,
            match t.slo {
                Some(slo) => format!(" (slo {:.0}ms)", slo.as_secs_f64() * 1e3),
                None => " (bulk)".to_string(),
            },
        );
    }
    let report = match args.get("connect") {
        Some(addr) => {
            let client = match Client::connect(addr) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("connect failed: {e}");
                    return 1;
                }
            };
            let report = loadgen::open_loop_mixed(&client, &cfg);
            if args.has("shutdown") {
                match client.shutdown_server() {
                    Ok(()) => println!("server drain requested"),
                    Err(e) => eprintln!("shutdown request failed: {e}"),
                }
            }
            report
        }
        None => {
            // derive the registry's SLO table from the tenant specs so
            // the in-process run schedules the very priorities it
            // measures
            let mut slos = SloTable::new();
            for t in &cfg.tenants {
                let slo = match t.slo {
                    Some(slo) => ModelSlo::interactive_ms(slo.as_secs_f64() * 1e3),
                    None => ModelSlo::bulk(),
                };
                slos.insert(t.model.clone(), slo);
            }
            let registry = ModelRegistry::new(registry_config(args, cfg.tenants.len(), slos));
            for t in &cfg.tenants {
                if let Err(e) = registry.host(&t.model) {
                    eprintln!("error hosting '{}': {e}", t.model);
                    return 1;
                }
            }
            if let Err(e) = registry.resolve_partition_plans() {
                eprintln!("error resolving partition plans: {e}");
                return 1;
            }
            let report = loadgen::open_loop_mixed(&registry, &cfg);
            if report.is_ok() {
                println!("{}", registry.metrics().report());
            }
            registry.shutdown();
            report
        }
    };
    match report {
        Ok(r) => {
            println!("{}", r.summary());
            0
        }
        Err(e) => {
            eprintln!("mixed loadgen failed: {e}");
            1
        }
    }
}

/// `dynamap trace --connect ADDR [--out FILE]` — drain a running
/// server's span ring ([`crate::obs`]) as Chrome trace-event JSON.
/// The dump is destructive (the server's ring is emptied) so repeated
/// invocations see disjoint windows of activity. With `--out` the JSON
/// is written to a file Perfetto / `chrome://tracing` can load
/// directly; without it the JSON goes to stdout for piping.
pub fn trace(args: &Args) -> i32 {
    let Some(addr) = args.get("connect") else {
        eprintln!("trace needs --connect <addr> (a running `serve --listen` server)");
        return 1;
    };
    let client = match Client::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("connect failed: {e}");
            return 1;
        }
    };
    let json = match client.dump_trace() {
        Ok(j) => j,
        Err(e) => {
            eprintln!("trace dump failed: {e}");
            return 1;
        }
    };
    let events = crate::util::json::Json::parse(&json)
        .ok()
        .and_then(|doc| doc.get("traceEvents").as_arr().map(<[_]>::len))
        .unwrap_or(0);
    match args.get("out") {
        Some(path) => match std::fs::write(path, &json) {
            Ok(()) => {
                println!(
                    "wrote {path} ({events} span events) — load in Perfetto or chrome://tracing"
                );
                0
            }
            Err(e) => {
                eprintln!("error writing {path}: {e}");
                1
            }
        },
        None => {
            println!("{json}");
            0
        }
    }
}

/// `dynamap stats --connect ADDR` — fetch a running server's metrics
/// snapshot (per-model counters plus the mergeable latency histogram,
/// [`crate::serve::ServerMetrics::to_json`]) and pretty-print it. The
/// scrape is read-only: unlike `trace` it leaves server state intact,
/// so it is safe for dashboards to poll.
pub fn stats(args: &Args) -> i32 {
    let Some(addr) = args.get("connect") else {
        eprintln!("stats needs --connect <addr> (a running `serve --listen` server)");
        return 1;
    };
    let client = match Client::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("connect failed: {e}");
            return 1;
        }
    };
    match client.server_stats() {
        Ok(json) => {
            match crate::util::json::Json::parse(&json) {
                Ok(doc) => println!("{}", doc.pretty()),
                // still useful raw if the server speaks a newer schema
                Err(_) => println!("{json}"),
            }
            0
        }
        Err(e) => {
            eprintln!("stats fetch failed: {e}");
            1
        }
    }
}
