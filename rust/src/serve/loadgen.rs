//! Closed-loop synthetic load generator.
//!
//! Spawns `clients` dedicated threads (via
//! [`crate::util::parallel::parallel_run`]); each runs a closed loop of
//! `requests` inferences against a shared [`ModelRegistry`], picking a
//! model uniformly at random per request from a seeded
//! [`crate::util::rng::Rng`] stream, so every run of the same
//! configuration issues the identical request sequence. Models are
//! warmed (hosted + plan-compiled) before the clock starts, so when the
//! registry's capacity admits every model the report measures serving,
//! not lazy compilation. With capacity *below* the model count the
//! measured phase deliberately includes LRU re-hosting — that is what
//! capacity pressure does to a serving tier, and `dynamap loadgen`
//! only opts into it via an explicit `--cap`.
//!
//! This is the measurement harness behind `dynamap loadgen` and the
//! batched-vs-sequential comparison in `benches/serving.rs`.

use std::time::{Duration, Instant};

use crate::api::DynamapError;
use crate::runtime::TensorBuf;
use crate::util::parallel::parallel_run;
use crate::util::rng::Rng;

use super::metrics::ModelSnapshot;
use super::registry::ModelRegistry;

/// Workload shape for one [`run`] call.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Zoo model names (aliases fine); each request targets one of
    /// these, picked uniformly per request.
    pub models: Vec<String>,
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    /// Requests issued per client.
    pub requests: usize,
    /// Seed for the request streams (client `i` derives its own stream
    /// from `seed` and `i`, so runs are reproducible at any client
    /// count).
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            models: vec!["mini-inception".to_string()],
            clients: 4,
            requests: 32,
            seed: 99,
        }
    }
}

/// Outcome of one [`run`] call.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Total requests issued (`clients × requests`).
    pub requests: usize,
    /// Requests that returned an error.
    pub errors: usize,
    /// Wall-clock time of the measured (post-warm-up) phase.
    pub wall: Duration,
    /// `requests / wall` in requests per second.
    pub throughput_rps: f64,
    /// Per-model metrics snapshots taken at the end of the run.
    pub snapshots: Vec<ModelSnapshot>,
}

impl LoadReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} requests ({} errors) in {:.2?} → {:.1} req/s",
            self.requests, self.errors, self.wall, self.throughput_rps
        )
    }
}

/// Drive `registry` with the closed-loop workload described by `cfg`
/// and report throughput plus per-model telemetry.
pub fn run(registry: &ModelRegistry, cfg: &LoadgenConfig) -> Result<LoadReport, DynamapError> {
    if cfg.models.is_empty() {
        return Err(DynamapError::Serve("loadgen needs at least one model".into()));
    }
    // warm every model (host + compile) and capture its input shape so
    // the measured phase pays neither lazy compilation nor re-lookup
    let mut targets = Vec::with_capacity(cfg.models.len());
    for model in &cfg.models {
        let host = registry.host(model)?;
        targets.push((host.model().to_string(), host.input_dims()));
    }
    let t0 = Instant::now();
    let client_errors = parallel_run(cfg.clients, |client| {
        let stream = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(client as u64 + 1);
        let mut rng = Rng::new(cfg.seed ^ stream);
        let mut errors = 0usize;
        for _ in 0..cfg.requests {
            let (model, (c, h1, h2)) = &targets[rng.below(targets.len() as u64) as usize];
            let data: Vec<f32> = (0..c * h1 * h2).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            let input = TensorBuf::new(vec![*c, *h1, *h2], data);
            if registry.infer(model, &input).is_err() {
                errors += 1;
            }
        }
        errors
    });
    let wall = t0.elapsed();
    let total = cfg.clients * cfg.requests;
    Ok(LoadReport {
        requests: total,
        errors: client_errors.iter().sum(),
        wall,
        throughput_rps: if wall.as_secs_f64() > 0.0 {
            total as f64 / wall.as_secs_f64()
        } else {
            0.0
        },
        snapshots: registry.metrics().snapshots(),
    })
}
