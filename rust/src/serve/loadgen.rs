//! Synthetic load generators: closed-loop ([`run`]) and open-loop
//! ([`open_loop`]).
//!
//! **Closed loop** spawns `clients` dedicated threads (via
//! [`crate::util::parallel::parallel_run`]); each runs a closed loop of
//! `requests` inferences against a shared [`ModelRegistry`], picking a
//! model uniformly at random per request from a seeded
//! [`crate::util::rng::Rng`] stream, so every run of the same
//! configuration issues the identical request sequence. Models are
//! warmed (hosted + plan-compiled) before the clock starts, so when the
//! registry's capacity admits every model the report measures serving,
//! not lazy compilation. With capacity *below* the model count the
//! measured phase deliberately includes LRU re-hosting — that is what
//! capacity pressure does to a serving tier, and `dynamap loadgen`
//! only opts into it via an explicit `--cap`.
//!
//! This is the measurement harness behind `dynamap loadgen` and the
//! batched-vs-sequential comparison in `benches/serving.rs`.
//!
//! **Open loop** is how overload becomes measurable: closed-loop
//! clients self-throttle (a slow server slows its own offered load), so
//! they can never push a server past its knee. [`open_loop`] instead
//! fires requests at seeded-Poisson arrival instants derived from an
//! offered-load parameter in QPS, regardless of how fast replies come
//! back, and measures each success from its *scheduled* arrival time —
//! the coordinated-omission-safe convention, so queue buildup shows up
//! in the tail percentiles instead of being silently absorbed. Requests
//! shed by admission control ([`DynamapError::Overloaded`]) are
//! accounted separately, with reply latency measured from the actual
//! send. The target is anything implementing [`InferTarget`]: the
//! in-process [`ModelRegistry`] or the TCP [`crate::net::Client`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use crate::api::DynamapError;
use crate::coordinator::metrics::LatencyStats;
use crate::graph::layer::Op;
use crate::graph::zoo;
use crate::runtime::TensorBuf;
use crate::util::parallel::parallel_run;
use crate::util::rng::Rng;

use super::metrics::ModelSnapshot;
use super::registry::ModelRegistry;

/// Workload shape for one [`run`] call.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Zoo model names (aliases fine); each request targets one of
    /// these, picked uniformly per request.
    pub models: Vec<String>,
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    /// Requests issued per client.
    pub requests: usize,
    /// Seed for the request streams (client `i` derives its own stream
    /// from `seed` and `i`, so runs are reproducible at any client
    /// count).
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            models: vec!["mini-inception".to_string()],
            clients: 4,
            requests: 32,
            seed: 99,
        }
    }
}

/// Outcome of one [`run`] call.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Total requests issued (`clients × requests`).
    pub requests: usize,
    /// Requests that returned an error.
    pub errors: usize,
    /// Wall-clock time of the measured (post-warm-up) phase.
    pub wall: Duration,
    /// `requests / wall` in requests per second.
    pub throughput_rps: f64,
    /// Per-model metrics snapshots taken at the end of the run.
    pub snapshots: Vec<ModelSnapshot>,
}

impl LoadReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} requests ({} errors) in {:.2?} → {:.1} req/s",
            self.requests, self.errors, self.wall, self.throughput_rps
        )
    }
}

/// Drive `registry` with the closed-loop workload described by `cfg`
/// and report throughput plus per-model telemetry.
pub fn run(registry: &ModelRegistry, cfg: &LoadgenConfig) -> Result<LoadReport, DynamapError> {
    if cfg.models.is_empty() {
        return Err(DynamapError::Serve("loadgen needs at least one model".into()));
    }
    // warm every model (host + compile) and capture its input shape so
    // the measured phase pays neither lazy compilation nor re-lookup
    let mut targets = Vec::with_capacity(cfg.models.len());
    for model in &cfg.models {
        let host = registry.host(model)?;
        targets.push((host.model().to_string(), host.input_dims()));
    }
    let t0 = Instant::now();
    let client_errors = parallel_run(cfg.clients, |client| {
        let stream = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(client as u64 + 1);
        let mut rng = Rng::new(cfg.seed ^ stream);
        let mut errors = 0usize;
        for _ in 0..cfg.requests {
            let (model, (c, h1, h2)) = &targets[rng.below(targets.len() as u64) as usize];
            let data: Vec<f32> = (0..c * h1 * h2).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            let input = TensorBuf::new(vec![*c, *h1, *h2], data);
            if registry.infer(model, &input).is_err() {
                errors += 1;
            }
        }
        errors
    });
    let wall = t0.elapsed();
    let total = cfg.clients * cfg.requests;
    Ok(LoadReport {
        requests: total,
        errors: client_errors.iter().sum(),
        wall,
        throughput_rps: if wall.as_secs_f64() > 0.0 {
            total as f64 / wall.as_secs_f64()
        } else {
            0.0
        },
        snapshots: registry.metrics().snapshots(),
    })
}

/// Anything the open-loop generator can drive: one blocking inference
/// per call. Implemented by the in-process [`ModelRegistry`] and the
/// TCP [`crate::net::Client`], so the same generator measures the
/// engine with and without the network in front of it.
pub trait InferTarget: Sync {
    /// Serve one request for `model`, blocking for the reply.
    fn infer_once(&self, model: &str, input: &TensorBuf) -> Result<TensorBuf, DynamapError>;

    /// [`InferTarget::infer_once`] with an optional relative deadline:
    /// the target should shed the request with
    /// [`DynamapError::DeadlineExceeded`] once `deadline` has elapsed
    /// from acceptance. Targets without deadline support ignore it
    /// (the default), which keeps third-party stubs source-compatible.
    fn infer_deadline(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
    ) -> Result<TensorBuf, DynamapError> {
        let _ = deadline;
        self.infer_once(model, input)
    }

    /// [`InferTarget::infer_deadline`] carrying the request's
    /// span-correlation id ([`crate::obs::TraceId`]). Targets without
    /// tracing support drop the id (the default), which keeps
    /// third-party stubs source-compatible; the registry threads it
    /// into its spans and the TCP client puts it on the wire as the
    /// protocol-v3 trailer.
    fn infer_traced(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
        trace: Option<crate::obs::TraceId>,
    ) -> Result<TensorBuf, DynamapError> {
        let _ = trace;
        self.infer_deadline(model, input, deadline)
    }
}

impl InferTarget for ModelRegistry {
    fn infer_once(&self, model: &str, input: &TensorBuf) -> Result<TensorBuf, DynamapError> {
        self.infer(model, input).map(|(out, _)| out)
    }

    fn infer_deadline(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
    ) -> Result<TensorBuf, DynamapError> {
        let absolute = deadline.map(|d| Instant::now() + d);
        self.infer_with_deadline(model, input, absolute).map(|(out, _)| out)
    }

    fn infer_traced(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
        trace: Option<crate::obs::TraceId>,
    ) -> Result<TensorBuf, DynamapError> {
        let absolute = deadline.map(|d| Instant::now() + d);
        ModelRegistry::infer_traced(self, model, input, absolute, trace).map(|(out, _)| out)
    }
}

/// Input dimensions `(C, H1, H2)` of a zoo model, resolved from the
/// graph alone — no hosting, no artifacts. Lets a network client build
/// correctly shaped requests without a round trip.
pub fn model_input_dims(model: &str) -> Result<(usize, usize, usize), DynamapError> {
    let canonical = zoo::canonical_name(model)
        .ok_or_else(|| DynamapError::UnknownModel(model.to_string()))?;
    let cnn = zoo::by_name(canonical)
        .ok_or_else(|| DynamapError::UnknownModel(canonical.to_string()))?;
    for node in &cnn.nodes {
        if let Op::Input { c, h1, h2 } = &node.op {
            return Ok((*c, *h1, *h2));
        }
    }
    Err(DynamapError::Graph(format!("model '{canonical}' has no input node")))
}

/// The deterministic input for open-loop request `index`: any party
/// holding `(seed, index, dims)` regenerates the identical tensor, so
/// tests and benches can bitwise-compare a server reply against a
/// sequential [`crate::api::Session::infer`] of the same request.
pub fn open_loop_input(seed: u64, index: usize, dims: (usize, usize, usize)) -> TensorBuf {
    let (c, h1, h2) = dims;
    let stream = (index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut rng = Rng::new(seed ^ stream);
    TensorBuf::new(
        vec![c, h1, h2],
        (0..c * h1 * h2).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
    )
}

/// Workload shape for one [`open_loop`] call.
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// Zoo model every request targets (alias fine).
    pub model: String,
    /// Offered load: mean arrival rate of the Poisson process, QPS.
    pub rate_qps: f64,
    /// Total requests to offer.
    pub requests: usize,
    /// Seed for arrival instants and request payloads (fixed 99 across
    /// the benches, per the ROADMAP methodology).
    pub seed: u64,
    /// Worker threads available to carry in-flight requests. This is a
    /// transport concurrency cap, not a load parameter — arrivals the
    /// pool cannot pick up immediately wait (and that wait is charged
    /// to their latency), they are never dropped by the generator.
    pub workers: usize,
    /// Optional relative deadline attached to every request; the target
    /// sheds expired requests with [`DynamapError::DeadlineExceeded`],
    /// accounted separately from errors in the report.
    pub deadline: Option<Duration>,
    /// Stamp request `i` with the deterministic
    /// [`crate::obs::TraceId::derive`]`(seed, i)` so its spans (local
    /// or server-side via the protocol-v3 trailer) are correlated and
    /// reproducible. Off by default: an untraced run offers zero
    /// tracing work to the target.
    pub trace: bool,
}

impl Default for OpenLoopConfig {
    fn default() -> OpenLoopConfig {
        OpenLoopConfig {
            model: "mini-inception".to_string(),
            rate_qps: 200.0,
            requests: 256,
            seed: 99,
            workers: 64,
            deadline: None,
            trace: false,
        }
    }
}

/// Outcome of one [`open_loop`] call.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    /// Configured offered load, QPS.
    pub offered_qps: f64,
    /// Successful replies per second of wall clock.
    pub achieved_qps: f64,
    /// Requests offered (= `cfg.requests`).
    pub sent: usize,
    /// Successful replies.
    pub ok: usize,
    /// Requests shed with [`DynamapError::Overloaded`].
    pub shed: usize,
    /// Requests shed with [`DynamapError::DeadlineExceeded`] — they
    /// expired (pre-admission or in queue) before compute started.
    pub deadline_miss: usize,
    /// Requests failing with any other error.
    pub errors: usize,
    /// Wall clock from first scheduled arrival to last reply.
    pub wall: Duration,
    /// Success latency, µs, measured from each request's *scheduled*
    /// arrival instant (coordinated-omission-safe).
    pub latency: LatencyStats,
    /// Shed-reply latency, µs, measured from the actual send — how
    /// quickly the server says "back off" when it cannot serve.
    pub shed_latency: LatencyStats,
}

impl OpenLoopReport {
    /// One-line human summary (the `shed=` field is machine-parsed by
    /// the CI smoke job — keep it).
    pub fn summary(&self) -> String {
        let tail = self.latency.percentiles(&[50.0, 99.0, 99.9]);
        format!(
            "offered {:.0} qps → achieved {:.1} qps  ok={} shed={} dl_miss={} errors={} \
             p50={:.0}µs p99={:.0}µs p99.9={:.0}µs  shed reply max={:.0}µs",
            self.offered_qps,
            self.achieved_qps,
            self.ok,
            self.shed,
            self.deadline_miss,
            self.errors,
            tail[0],
            tail[1],
            tail[2],
            self.shed_latency.max(),
        )
    }
}

/// Offer `cfg.requests` requests to `target` at seeded-Poisson arrival
/// instants with mean rate `cfg.rate_qps`, and report what came back.
///
/// A dispatcher thread sleeps until each pre-generated arrival instant
/// and hands the request to a fixed pool of `cfg.workers` blocking
/// workers; arrivals that find every worker busy queue up, and their
/// wait is charged to their latency (measured from the scheduled
/// instant). Request `i`'s payload is [`open_loop_input`]`(seed, i)` —
/// deterministic, so replies can be verified offline.
pub fn open_loop<T: InferTarget + ?Sized>(
    target: &T,
    cfg: &OpenLoopConfig,
) -> Result<OpenLoopReport, DynamapError> {
    if cfg.rate_qps <= 0.0 || !cfg.rate_qps.is_finite() {
        return Err(DynamapError::Config(format!(
            "open-loop rate must be a positive QPS figure, got {}",
            cfg.rate_qps
        )));
    }
    if cfg.requests == 0 {
        return Err(DynamapError::Config("open loop needs at least one request".into()));
    }
    let dims = model_input_dims(&cfg.model)?;

    // pre-generate every Poisson arrival instant so the dispatch loop
    // does no RNG work between sleeps
    let mut rng = Rng::new(cfg.seed);
    let mut arrivals = Vec::with_capacity(cfg.requests);
    let mut t = 0.0f64;
    for _ in 0..cfg.requests {
        // inter-arrival gaps of a Poisson process are Exp(λ);
        // 1 - f64() is in (0, 1], so the log is always finite
        t += -(1.0 - rng.f64()).ln() / cfg.rate_qps;
        arrivals.push(Duration::from_secs_f64(t));
    }

    let workers = cfg.workers.clamp(1, cfg.requests);
    let (tx, rx) = mpsc::channel::<(usize, Duration)>();
    let rx = Mutex::new(rx);
    let ok_lat = Mutex::new(Vec::new());
    let shed_lat = Mutex::new(Vec::new());
    let deadline_miss = AtomicUsize::new(0);
    let errors = AtomicUsize::new(0);
    let start = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let job = rx.lock().unwrap_or_else(|p| p.into_inner()).recv();
                let Ok((i, scheduled)) = job else { break };
                let input = open_loop_input(cfg.seed, i, dims);
                let trace = if cfg.trace {
                    Some(crate::obs::TraceId::derive(cfg.seed, i as u64))
                } else {
                    None
                };
                let sent = Instant::now();
                match target.infer_traced(&cfg.model, &input, cfg.deadline, trace) {
                    Ok(_) => {
                        let e2e = start.elapsed().saturating_sub(scheduled);
                        let us = e2e.as_secs_f64() * 1e6;
                        ok_lat.lock().unwrap_or_else(|p| p.into_inner()).push(us);
                    }
                    Err(DynamapError::Overloaded { .. }) => {
                        let us = sent.elapsed().as_secs_f64() * 1e6;
                        shed_lat.lock().unwrap_or_else(|p| p.into_inner()).push(us);
                    }
                    Err(DynamapError::DeadlineExceeded { .. }) => {
                        deadline_miss.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
        // dispatch on this thread: sleep to each arrival instant, send
        for (i, at) in arrivals.iter().enumerate() {
            let now = start.elapsed();
            if *at > now {
                std::thread::sleep(*at - now);
            }
            // workers only exit once the channel is closed below, so a
            // send can only fail if a worker panicked — propagate then
            tx.send((i, *at)).expect("open-loop worker pool died");
        }
        drop(tx); // closes the channel; workers drain and exit
    });
    let wall = start.elapsed();

    let mut latency = LatencyStats::new();
    for us in ok_lat.into_inner().unwrap_or_else(|p| p.into_inner()) {
        latency.push(us);
    }
    let mut shed_latency = LatencyStats::new();
    for us in shed_lat.into_inner().unwrap_or_else(|p| p.into_inner()) {
        shed_latency.push(us);
    }
    let ok = latency.count();
    let shed = shed_latency.count();
    Ok(OpenLoopReport {
        offered_qps: cfg.rate_qps,
        achieved_qps: if wall.as_secs_f64() > 0.0 {
            ok as f64 / wall.as_secs_f64()
        } else {
            0.0
        },
        sent: cfg.requests,
        ok,
        shed,
        deadline_miss: deadline_miss.into_inner(),
        errors: errors.into_inner(),
        wall,
        latency,
        shed_latency,
    })
}

/// The derived seed for tenant `t`'s arrival schedule and request
/// payloads in a [`open_loop_mixed`] run: tenant `t` request `i`'s
/// payload is [`open_loop_input`]`(tenant_seed(seed, t), i, dims)`.
/// Public (and deliberately trivial) so tests and offline verifiers
/// regenerate any tenant's exact request stream from `(seed, t)` alone
/// and bitwise-compare server replies against `Session::infer`.
pub fn tenant_seed(seed: u64, tenant: usize) -> u64 {
    seed ^ (tenant as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03)
}

/// One tenant's traffic in a [`open_loop_mixed`] run.
#[derive(Debug, Clone)]
pub struct TenantLoad {
    /// Zoo model this tenant targets (alias fine).
    pub model: String,
    /// Offered load: mean Poisson arrival rate, QPS.
    pub rate_qps: f64,
    /// Requests this tenant offers.
    pub requests: usize,
    /// End-to-end latency SLO. `Some` marks the tenant high-priority
    /// for the report's attainment split: its attainment is the
    /// fraction of *offered* requests answered OK within the target
    /// (measured from the scheduled arrival — coordinated-omission
    /// safe). `None` marks it bulk: its "attainment" is the plain
    /// service rate `ok / sent`.
    pub slo: Option<Duration>,
    /// Optional relative deadline attached to every request (bulk
    /// tenants typically set one so overload sheds instead of queueing
    /// without bound).
    pub deadline: Option<Duration>,
}

/// Workload shape for one [`open_loop_mixed`] call.
#[derive(Debug, Clone)]
pub struct MixedConfig {
    /// The tenants; their Poisson streams are merged into one arrival
    /// timeline.
    pub tenants: Vec<TenantLoad>,
    /// Master seed; tenant `t` streams from [`tenant_seed`]`(seed, t)`.
    pub seed: u64,
    /// Worker threads carrying in-flight requests across all tenants.
    pub workers: usize,
}

impl Default for MixedConfig {
    fn default() -> MixedConfig {
        MixedConfig { tenants: Vec::new(), seed: 99, workers: 64 }
    }
}

/// One tenant's slice of a [`MixedReport`].
#[derive(Debug, Clone)]
pub struct TenantReport {
    /// The tenant's model.
    pub model: String,
    /// The SLO it was offered under (`None` = bulk).
    pub slo: Option<Duration>,
    /// OK replies whose scheduled-arrival-to-reply latency met the SLO
    /// (always 0 for bulk tenants).
    pub within_slo: usize,
    /// Full per-tenant accounting, same shape as a single-tenant
    /// [`open_loop`] run.
    pub report: OpenLoopReport,
}

/// Outcome of one [`open_loop_mixed`] call.
#[derive(Debug, Clone)]
pub struct MixedReport {
    /// Per-tenant reports, in [`MixedConfig::tenants`] order.
    pub tenants: Vec<TenantReport>,
    /// Wall clock from first scheduled arrival to last reply.
    pub wall: Duration,
}

impl MixedReport {
    /// `(high, bulk)` attainment percentages: high = SLO-tenant
    /// requests answered within their target over requests offered;
    /// bulk = no-SLO-tenant requests answered at all over requests
    /// offered. An absent class reports 100% (vacuously attained).
    pub fn attainment(&self) -> (f64, f64) {
        let (mut hi_ok, mut hi_sent, mut bulk_ok, mut bulk_sent) = (0usize, 0usize, 0usize, 0usize);
        for t in &self.tenants {
            if t.slo.is_some() {
                hi_ok += t.within_slo;
                hi_sent += t.report.sent;
            } else {
                bulk_ok += t.report.ok;
                bulk_sent += t.report.sent;
            }
        }
        let pct = |ok: usize, sent: usize| {
            if sent == 0 { 100.0 } else { 100.0 * ok as f64 / sent as f64 }
        };
        (pct(hi_ok, hi_sent), pct(bulk_ok, bulk_sent))
    }

    /// Multi-line human summary: one line per tenant plus the
    /// aggregate `slo attainment: high=NN.N% bulk=NN.N%` line (the
    /// latter is machine-parsed by the CI `slo-smoke` job and the
    /// serving bench gate — keep its shape).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for t in &self.tenants {
            let tier = match t.slo {
                Some(d) => format!("slo {:.0}ms (met {})", d.as_secs_f64() * 1e3, t.within_slo),
                None => "bulk".to_string(),
            };
            s.push_str(&format!("  {} [{tier}]: {}\n", t.model, t.report.summary()));
        }
        let (high, bulk) = self.attainment();
        s.push_str(&format!("slo attainment: high={high:.1}% bulk={bulk:.1}%"));
        s
    }
}

/// Offer every tenant's seeded-Poisson stream to `target`
/// concurrently, merged into one arrival timeline, and report per
/// tenant.
///
/// Each tenant's arrival instants and payloads derive from
/// [`tenant_seed`]`(cfg.seed, t)` alone — adding, removing or
/// reordering *other* tenants never changes what a given tenant sends,
/// and the merged dispatch order is a pure sort of the union (ties
/// broken by tenant index, then request index), so the same `(seed,
/// config)` replays bit-for-bit. Latency is measured from each
/// request's scheduled arrival, like [`open_loop`].
pub fn open_loop_mixed<T: InferTarget + ?Sized>(
    target: &T,
    cfg: &MixedConfig,
) -> Result<MixedReport, DynamapError> {
    if cfg.tenants.is_empty() {
        return Err(DynamapError::Config("mixed open loop needs at least one tenant".into()));
    }
    let mut dims = Vec::with_capacity(cfg.tenants.len());
    for tenant in &cfg.tenants {
        if tenant.rate_qps <= 0.0 || !tenant.rate_qps.is_finite() {
            return Err(DynamapError::Config(format!(
                "tenant '{}' rate must be a positive QPS figure, got {}",
                tenant.model, tenant.rate_qps
            )));
        }
        if tenant.requests == 0 {
            return Err(DynamapError::Config(format!(
                "tenant '{}' needs at least one request",
                tenant.model
            )));
        }
        dims.push(model_input_dims(&tenant.model)?);
    }

    // per-tenant Poisson schedules, merged into one timeline
    let mut schedule: Vec<(Duration, usize, usize)> = Vec::new();
    for (t, tenant) in cfg.tenants.iter().enumerate() {
        let mut rng = Rng::new(tenant_seed(cfg.seed, t));
        let mut at = 0.0f64;
        for i in 0..tenant.requests {
            at += -(1.0 - rng.f64()).ln() / tenant.rate_qps;
            schedule.push((Duration::from_secs_f64(at), t, i));
        }
    }
    schedule.sort(); // Duration is Ord; ties break by (tenant, index)

    /// Per-tenant accounting, all under one mutex per tenant — the
    /// worker touches it once per reply, never on the dispatch path.
    #[derive(Default)]
    struct Acc {
        ok: Vec<f64>,
        shed: Vec<f64>,
        within: usize,
        deadline_miss: usize,
        errors: usize,
    }
    let accs: Vec<Mutex<Acc>> = cfg.tenants.iter().map(|_| Mutex::new(Acc::default())).collect();

    let workers = cfg.workers.clamp(1, schedule.len());
    let (tx, rx) = mpsc::channel::<(usize, usize, Duration)>();
    let rx = Mutex::new(rx);
    let start = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let job = rx.lock().unwrap_or_else(|p| p.into_inner()).recv();
                let Ok((t, i, scheduled)) = job else { break };
                let tenant = &cfg.tenants[t];
                let input = open_loop_input(tenant_seed(cfg.seed, t), i, dims[t]);
                let sent = Instant::now();
                match target.infer_deadline(&tenant.model, &input, tenant.deadline) {
                    Ok(_) => {
                        let e2e = start.elapsed().saturating_sub(scheduled);
                        let mut acc = accs[t].lock().unwrap_or_else(|p| p.into_inner());
                        acc.ok.push(e2e.as_secs_f64() * 1e6);
                        if tenant.slo.is_some_and(|slo| e2e <= slo) {
                            acc.within += 1;
                        }
                    }
                    Err(DynamapError::Overloaded { .. }) => {
                        let us = sent.elapsed().as_secs_f64() * 1e6;
                        accs[t].lock().unwrap_or_else(|p| p.into_inner()).shed.push(us);
                    }
                    Err(DynamapError::DeadlineExceeded { .. }) => {
                        accs[t].lock().unwrap_or_else(|p| p.into_inner()).deadline_miss += 1;
                    }
                    Err(_) => {
                        accs[t].lock().unwrap_or_else(|p| p.into_inner()).errors += 1;
                    }
                }
            });
        }
        for (at, t, i) in &schedule {
            let now = start.elapsed();
            if *at > now {
                std::thread::sleep(*at - now);
            }
            tx.send((*t, *i, *at)).expect("mixed open-loop worker pool died");
        }
        drop(tx);
    });
    let wall = start.elapsed();

    let tenants = cfg
        .tenants
        .iter()
        .zip(accs)
        .map(|(tenant, acc)| {
            let acc = acc.into_inner().unwrap_or_else(|p| p.into_inner());
            let mut latency = LatencyStats::new();
            for us in &acc.ok {
                latency.push(*us);
            }
            let mut shed_latency = LatencyStats::new();
            for us in &acc.shed {
                shed_latency.push(*us);
            }
            let ok = latency.count();
            TenantReport {
                model: tenant.model.clone(),
                slo: tenant.slo,
                within_slo: acc.within,
                report: OpenLoopReport {
                    offered_qps: tenant.rate_qps,
                    achieved_qps: if wall.as_secs_f64() > 0.0 {
                        ok as f64 / wall.as_secs_f64()
                    } else {
                        0.0
                    },
                    sent: tenant.requests,
                    ok,
                    shed: shed_latency.count(),
                    deadline_miss: acc.deadline_miss,
                    errors: acc.errors,
                    wall,
                    latency,
                    shed_latency,
                },
            }
        })
        .collect();
    Ok(MixedReport { tenants, wall })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_loop_inputs_are_deterministic_and_distinct() {
        let dims = (4, 16, 16);
        let a = open_loop_input(99, 7, dims);
        let b = open_loop_input(99, 7, dims);
        assert_eq!(a, b, "same (seed, index) → same tensor");
        assert_eq!(a.shape, vec![4, 16, 16]);
        let c = open_loop_input(99, 8, dims);
        assert_ne!(a.data, c.data, "different index → different tensor");
        let d = open_loop_input(100, 7, dims);
        assert_ne!(a.data, d.data, "different seed → different tensor");
    }

    #[test]
    fn model_dims_resolve_through_aliases() {
        assert_eq!(model_input_dims("mini").unwrap(), (4, 16, 16));
        assert_eq!(model_input_dims("mini-inception").unwrap(), (4, 16, 16));
        assert_eq!(model_input_dims("mini-vgg").unwrap(), (3, 16, 16));
        assert!(matches!(
            model_input_dims("nope").unwrap_err(),
            DynamapError::UnknownModel(_)
        ));
    }

    #[test]
    fn poisson_arrivals_are_seeded_and_rate_scaled() {
        // regenerate the arrival schedule exactly as open_loop does
        let gaps = |seed: u64, rate: f64, n: usize| -> Vec<f64> {
            let mut rng = Rng::new(seed);
            (0..n).map(|_| -(1.0 - rng.f64()).ln() / rate).collect()
        };
        let a = gaps(99, 100.0, 512);
        let b = gaps(99, 100.0, 512);
        assert_eq!(a, b, "fixed seed → identical schedule");
        let mean = a.iter().sum::<f64>() / a.len() as f64;
        assert!(
            (mean - 0.01).abs() < 0.002,
            "mean inter-arrival {mean:.4}s ≈ 1/rate"
        );
        assert!(a.iter().all(|g| g.is_finite() && *g >= 0.0));
    }

    /// A stub target cycling through every reply class — checks the
    /// report's accounting paths without a real server.
    struct Flaky(AtomicUsize);
    impl InferTarget for Flaky {
        fn infer_once(
            &self,
            _model: &str,
            input: &TensorBuf,
        ) -> Result<TensorBuf, DynamapError> {
            let n = self.0.fetch_add(1, Ordering::Relaxed);
            match n % 4 {
                0 => Ok(input.clone()),
                1 => Err(DynamapError::Overloaded {
                    model: "mini-inception".into(),
                    retry_after_ms: 1,
                }),
                2 => Err(DynamapError::DeadlineExceeded {
                    model: "mini-inception".into(),
                    waited_ms: 5,
                }),
                _ => Err(DynamapError::Serve("boom".into())),
            }
        }
    }

    #[test]
    fn open_loop_accounts_ok_shed_and_errors() {
        let target = Flaky(AtomicUsize::new(0));
        let cfg = OpenLoopConfig {
            rate_qps: 20_000.0, // finish fast; accounting is rate-blind
            requests: 100,
            workers: 8,
            ..OpenLoopConfig::default()
        };
        let report = open_loop(&target, &cfg).unwrap();
        assert_eq!(report.sent, 100);
        assert_eq!(report.ok + report.shed + report.deadline_miss + report.errors, 100);
        assert_eq!(report.ok, 25);
        assert_eq!(report.shed, 25);
        assert_eq!(report.deadline_miss, 25);
        assert_eq!(report.errors, 25);
        assert_eq!(report.latency.count(), report.ok);
        assert!(report.summary().contains("shed=25"), "{}", report.summary());
        assert!(report.summary().contains("dl_miss=25"), "{}", report.summary());

        // invalid configs are typed, not panics
        assert!(open_loop(&target, &OpenLoopConfig { rate_qps: 0.0, ..cfg.clone() }).is_err());
        assert!(open_loop(&target, &OpenLoopConfig { requests: 0, ..cfg }).is_err());
    }

    #[test]
    fn tenant_seeds_are_stable_and_distinct() {
        assert_eq!(tenant_seed(99, 0), tenant_seed(99, 0));
        assert_ne!(tenant_seed(99, 0), tenant_seed(99, 1));
        assert_ne!(tenant_seed(99, 0), tenant_seed(100, 0));
        // the payload contract tests and verifiers rely on
        let a = open_loop_input(tenant_seed(99, 1), 5, (4, 16, 16));
        let b = open_loop_input(tenant_seed(99, 1), 5, (4, 16, 16));
        assert_eq!(a, b);
    }

    /// An always-OK echo target: replies instantly, so the mixed
    /// report's accounting (not the server) is what's under test.
    struct Echo;
    impl InferTarget for Echo {
        fn infer_once(
            &self,
            _model: &str,
            input: &TensorBuf,
        ) -> Result<TensorBuf, DynamapError> {
            Ok(input.clone())
        }
    }

    fn two_tenant_cfg() -> MixedConfig {
        MixedConfig {
            tenants: vec![
                TenantLoad {
                    model: "mini-inception".into(),
                    rate_qps: 20_000.0,
                    requests: 60,
                    slo: Some(Duration::from_millis(250)),
                    deadline: None,
                },
                TenantLoad {
                    model: "mini-vgg".into(),
                    rate_qps: 40_000.0,
                    requests: 90,
                    slo: None,
                    deadline: None,
                },
            ],
            seed: 99,
            workers: 8,
        }
    }

    #[test]
    fn mixed_open_loop_accounts_per_tenant_and_replays() {
        let cfg = two_tenant_cfg();
        let r = open_loop_mixed(&Echo, &cfg).unwrap();
        assert_eq!(r.tenants.len(), 2);
        let hi = &r.tenants[0];
        let bulk = &r.tenants[1];
        assert_eq!(hi.report.sent, 60);
        assert_eq!(hi.report.ok, 60, "echo target answers everything");
        assert_eq!(bulk.report.sent, 90);
        assert_eq!(bulk.report.ok, 90);
        // an instant echo under a 250 ms SLO attains everything
        assert_eq!(hi.within_slo, 60);
        assert_eq!(bulk.within_slo, 0, "bulk tenants have no SLO to meet");
        let (high, bulk_pct) = r.attainment();
        assert!((high - 100.0).abs() < 1e-9);
        assert!((bulk_pct - 100.0).abs() < 1e-9);
        assert!(
            r.summary().contains("slo attainment: high=100.0% bulk=100.0%"),
            "{}",
            r.summary()
        );
        assert!(r.summary().contains("mini-inception [slo 250ms"), "{}", r.summary());
        assert!(r.summary().contains("mini-vgg [bulk]"), "{}", r.summary());

        // same (seed, config) → identical accounting, replayed
        let r2 = open_loop_mixed(&Echo, &cfg).unwrap();
        for (a, b) in r.tenants.iter().zip(&r2.tenants) {
            assert_eq!(a.report.ok, b.report.ok);
            assert_eq!(a.report.sent, b.report.sent);
            assert_eq!(a.within_slo, b.within_slo);
        }
    }

    #[test]
    fn mixed_open_loop_rejects_bad_configs() {
        assert!(open_loop_mixed(&Echo, &MixedConfig::default()).is_err());
        let mut cfg = two_tenant_cfg();
        cfg.tenants[0].rate_qps = 0.0;
        assert!(open_loop_mixed(&Echo, &cfg).is_err());
        let mut cfg = two_tenant_cfg();
        cfg.tenants[1].requests = 0;
        assert!(open_loop_mixed(&Echo, &cfg).is_err());
        let mut cfg = two_tenant_cfg();
        cfg.tenants[0].model = "nope".into();
        assert!(open_loop_mixed(&Echo, &cfg).is_err());
    }

    #[test]
    fn mixed_schedules_merge_deterministically() {
        // regenerate both tenants' schedules exactly as open_loop_mixed
        // does and check the merged order is a pure function of inputs
        let cfg = two_tenant_cfg();
        let build = || {
            let mut schedule: Vec<(Duration, usize, usize)> = Vec::new();
            for (t, tenant) in cfg.tenants.iter().enumerate() {
                let mut rng = Rng::new(tenant_seed(cfg.seed, t));
                let mut at = 0.0f64;
                for i in 0..tenant.requests {
                    at += -(1.0 - rng.f64()).ln() / tenant.rate_qps;
                    schedule.push((Duration::from_secs_f64(at), t, i));
                }
            }
            schedule.sort();
            schedule
        };
        let a = build();
        assert_eq!(a, build());
        assert_eq!(a.len(), 150);
        // both tenants interleave rather than running back to back
        let first_50_tenants: std::collections::BTreeSet<usize> =
            a.iter().take(50).map(|(_, t, _)| *t).collect();
        assert_eq!(first_50_tenants.len(), 2);
    }
}
