//! Synthetic load generators: closed-loop ([`run`]) and open-loop
//! ([`open_loop`]).
//!
//! **Closed loop** spawns `clients` dedicated threads (via
//! [`crate::util::parallel::parallel_run`]); each runs a closed loop of
//! `requests` inferences against a shared [`ModelRegistry`], picking a
//! model uniformly at random per request from a seeded
//! [`crate::util::rng::Rng`] stream, so every run of the same
//! configuration issues the identical request sequence. Models are
//! warmed (hosted + plan-compiled) before the clock starts, so when the
//! registry's capacity admits every model the report measures serving,
//! not lazy compilation. With capacity *below* the model count the
//! measured phase deliberately includes LRU re-hosting — that is what
//! capacity pressure does to a serving tier, and `dynamap loadgen`
//! only opts into it via an explicit `--cap`.
//!
//! This is the measurement harness behind `dynamap loadgen` and the
//! batched-vs-sequential comparison in `benches/serving.rs`.
//!
//! **Open loop** is how overload becomes measurable: closed-loop
//! clients self-throttle (a slow server slows its own offered load), so
//! they can never push a server past its knee. [`open_loop`] instead
//! fires requests at seeded-Poisson arrival instants derived from an
//! offered-load parameter in QPS, regardless of how fast replies come
//! back, and measures each success from its *scheduled* arrival time —
//! the coordinated-omission-safe convention, so queue buildup shows up
//! in the tail percentiles instead of being silently absorbed. Requests
//! shed by admission control ([`DynamapError::Overloaded`]) are
//! accounted separately, with reply latency measured from the actual
//! send. The target is anything implementing [`InferTarget`]: the
//! in-process [`ModelRegistry`] or the TCP [`crate::net::Client`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use crate::api::DynamapError;
use crate::coordinator::metrics::LatencyStats;
use crate::graph::layer::Op;
use crate::graph::zoo;
use crate::runtime::TensorBuf;
use crate::util::parallel::parallel_run;
use crate::util::rng::Rng;

use super::metrics::ModelSnapshot;
use super::registry::ModelRegistry;

/// Workload shape for one [`run`] call.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Zoo model names (aliases fine); each request targets one of
    /// these, picked uniformly per request.
    pub models: Vec<String>,
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    /// Requests issued per client.
    pub requests: usize,
    /// Seed for the request streams (client `i` derives its own stream
    /// from `seed` and `i`, so runs are reproducible at any client
    /// count).
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            models: vec!["mini-inception".to_string()],
            clients: 4,
            requests: 32,
            seed: 99,
        }
    }
}

/// Outcome of one [`run`] call.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Total requests issued (`clients × requests`).
    pub requests: usize,
    /// Requests that returned an error.
    pub errors: usize,
    /// Wall-clock time of the measured (post-warm-up) phase.
    pub wall: Duration,
    /// `requests / wall` in requests per second.
    pub throughput_rps: f64,
    /// Per-model metrics snapshots taken at the end of the run.
    pub snapshots: Vec<ModelSnapshot>,
}

impl LoadReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} requests ({} errors) in {:.2?} → {:.1} req/s",
            self.requests, self.errors, self.wall, self.throughput_rps
        )
    }
}

/// Drive `registry` with the closed-loop workload described by `cfg`
/// and report throughput plus per-model telemetry.
pub fn run(registry: &ModelRegistry, cfg: &LoadgenConfig) -> Result<LoadReport, DynamapError> {
    if cfg.models.is_empty() {
        return Err(DynamapError::Serve("loadgen needs at least one model".into()));
    }
    // warm every model (host + compile) and capture its input shape so
    // the measured phase pays neither lazy compilation nor re-lookup
    let mut targets = Vec::with_capacity(cfg.models.len());
    for model in &cfg.models {
        let host = registry.host(model)?;
        targets.push((host.model().to_string(), host.input_dims()));
    }
    let t0 = Instant::now();
    let client_errors = parallel_run(cfg.clients, |client| {
        let stream = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(client as u64 + 1);
        let mut rng = Rng::new(cfg.seed ^ stream);
        let mut errors = 0usize;
        for _ in 0..cfg.requests {
            let (model, (c, h1, h2)) = &targets[rng.below(targets.len() as u64) as usize];
            let data: Vec<f32> = (0..c * h1 * h2).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            let input = TensorBuf::new(vec![*c, *h1, *h2], data);
            if registry.infer(model, &input).is_err() {
                errors += 1;
            }
        }
        errors
    });
    let wall = t0.elapsed();
    let total = cfg.clients * cfg.requests;
    Ok(LoadReport {
        requests: total,
        errors: client_errors.iter().sum(),
        wall,
        throughput_rps: if wall.as_secs_f64() > 0.0 {
            total as f64 / wall.as_secs_f64()
        } else {
            0.0
        },
        snapshots: registry.metrics().snapshots(),
    })
}

/// Anything the open-loop generator can drive: one blocking inference
/// per call. Implemented by the in-process [`ModelRegistry`] and the
/// TCP [`crate::net::Client`], so the same generator measures the
/// engine with and without the network in front of it.
pub trait InferTarget: Sync {
    /// Serve one request for `model`, blocking for the reply.
    fn infer_once(&self, model: &str, input: &TensorBuf) -> Result<TensorBuf, DynamapError>;

    /// [`InferTarget::infer_once`] with an optional relative deadline:
    /// the target should shed the request with
    /// [`DynamapError::DeadlineExceeded`] once `deadline` has elapsed
    /// from acceptance. Targets without deadline support ignore it
    /// (the default), which keeps third-party stubs source-compatible.
    fn infer_deadline(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
    ) -> Result<TensorBuf, DynamapError> {
        let _ = deadline;
        self.infer_once(model, input)
    }

    /// [`InferTarget::infer_deadline`] carrying the request's
    /// span-correlation id ([`crate::obs::TraceId`]). Targets without
    /// tracing support drop the id (the default), which keeps
    /// third-party stubs source-compatible; the registry threads it
    /// into its spans and the TCP client puts it on the wire as the
    /// protocol-v3 trailer.
    fn infer_traced(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
        trace: Option<crate::obs::TraceId>,
    ) -> Result<TensorBuf, DynamapError> {
        let _ = trace;
        self.infer_deadline(model, input, deadline)
    }
}

impl InferTarget for ModelRegistry {
    fn infer_once(&self, model: &str, input: &TensorBuf) -> Result<TensorBuf, DynamapError> {
        self.infer(model, input).map(|(out, _)| out)
    }

    fn infer_deadline(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
    ) -> Result<TensorBuf, DynamapError> {
        let absolute = deadline.map(|d| Instant::now() + d);
        self.infer_with_deadline(model, input, absolute).map(|(out, _)| out)
    }

    fn infer_traced(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
        trace: Option<crate::obs::TraceId>,
    ) -> Result<TensorBuf, DynamapError> {
        let absolute = deadline.map(|d| Instant::now() + d);
        ModelRegistry::infer_traced(self, model, input, absolute, trace).map(|(out, _)| out)
    }
}

/// Input dimensions `(C, H1, H2)` of a zoo model, resolved from the
/// graph alone — no hosting, no artifacts. Lets a network client build
/// correctly shaped requests without a round trip.
pub fn model_input_dims(model: &str) -> Result<(usize, usize, usize), DynamapError> {
    let canonical = zoo::canonical_name(model)
        .ok_or_else(|| DynamapError::UnknownModel(model.to_string()))?;
    let cnn = zoo::by_name(canonical)
        .ok_or_else(|| DynamapError::UnknownModel(canonical.to_string()))?;
    for node in &cnn.nodes {
        if let Op::Input { c, h1, h2 } = &node.op {
            return Ok((*c, *h1, *h2));
        }
    }
    Err(DynamapError::Graph(format!("model '{canonical}' has no input node")))
}

/// The deterministic input for open-loop request `index`: any party
/// holding `(seed, index, dims)` regenerates the identical tensor, so
/// tests and benches can bitwise-compare a server reply against a
/// sequential [`crate::api::Session::infer`] of the same request.
pub fn open_loop_input(seed: u64, index: usize, dims: (usize, usize, usize)) -> TensorBuf {
    let (c, h1, h2) = dims;
    let stream = (index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut rng = Rng::new(seed ^ stream);
    TensorBuf::new(
        vec![c, h1, h2],
        (0..c * h1 * h2).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
    )
}

/// Workload shape for one [`open_loop`] call.
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// Zoo model every request targets (alias fine).
    pub model: String,
    /// Offered load: mean arrival rate of the Poisson process, QPS.
    pub rate_qps: f64,
    /// Total requests to offer.
    pub requests: usize,
    /// Seed for arrival instants and request payloads (fixed 99 across
    /// the benches, per the ROADMAP methodology).
    pub seed: u64,
    /// Worker threads available to carry in-flight requests. This is a
    /// transport concurrency cap, not a load parameter — arrivals the
    /// pool cannot pick up immediately wait (and that wait is charged
    /// to their latency), they are never dropped by the generator.
    pub workers: usize,
    /// Optional relative deadline attached to every request; the target
    /// sheds expired requests with [`DynamapError::DeadlineExceeded`],
    /// accounted separately from errors in the report.
    pub deadline: Option<Duration>,
    /// Stamp request `i` with the deterministic
    /// [`crate::obs::TraceId::derive`]`(seed, i)` so its spans (local
    /// or server-side via the protocol-v3 trailer) are correlated and
    /// reproducible. Off by default: an untraced run offers zero
    /// tracing work to the target.
    pub trace: bool,
}

impl Default for OpenLoopConfig {
    fn default() -> OpenLoopConfig {
        OpenLoopConfig {
            model: "mini-inception".to_string(),
            rate_qps: 200.0,
            requests: 256,
            seed: 99,
            workers: 64,
            deadline: None,
            trace: false,
        }
    }
}

/// Outcome of one [`open_loop`] call.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    /// Configured offered load, QPS.
    pub offered_qps: f64,
    /// Successful replies per second of wall clock.
    pub achieved_qps: f64,
    /// Requests offered (= `cfg.requests`).
    pub sent: usize,
    /// Successful replies.
    pub ok: usize,
    /// Requests shed with [`DynamapError::Overloaded`].
    pub shed: usize,
    /// Requests shed with [`DynamapError::DeadlineExceeded`] — they
    /// expired (pre-admission or in queue) before compute started.
    pub deadline_miss: usize,
    /// Requests failing with any other error.
    pub errors: usize,
    /// Wall clock from first scheduled arrival to last reply.
    pub wall: Duration,
    /// Success latency, µs, measured from each request's *scheduled*
    /// arrival instant (coordinated-omission-safe).
    pub latency: LatencyStats,
    /// Shed-reply latency, µs, measured from the actual send — how
    /// quickly the server says "back off" when it cannot serve.
    pub shed_latency: LatencyStats,
}

impl OpenLoopReport {
    /// One-line human summary (the `shed=` field is machine-parsed by
    /// the CI smoke job — keep it).
    pub fn summary(&self) -> String {
        let tail = self.latency.percentiles(&[50.0, 99.0, 99.9]);
        format!(
            "offered {:.0} qps → achieved {:.1} qps  ok={} shed={} dl_miss={} errors={} \
             p50={:.0}µs p99={:.0}µs p99.9={:.0}µs  shed reply max={:.0}µs",
            self.offered_qps,
            self.achieved_qps,
            self.ok,
            self.shed,
            self.deadline_miss,
            self.errors,
            tail[0],
            tail[1],
            tail[2],
            self.shed_latency.max(),
        )
    }
}

/// Offer `cfg.requests` requests to `target` at seeded-Poisson arrival
/// instants with mean rate `cfg.rate_qps`, and report what came back.
///
/// A dispatcher thread sleeps until each pre-generated arrival instant
/// and hands the request to a fixed pool of `cfg.workers` blocking
/// workers; arrivals that find every worker busy queue up, and their
/// wait is charged to their latency (measured from the scheduled
/// instant). Request `i`'s payload is [`open_loop_input`]`(seed, i)` —
/// deterministic, so replies can be verified offline.
pub fn open_loop<T: InferTarget + ?Sized>(
    target: &T,
    cfg: &OpenLoopConfig,
) -> Result<OpenLoopReport, DynamapError> {
    if cfg.rate_qps <= 0.0 || !cfg.rate_qps.is_finite() {
        return Err(DynamapError::Config(format!(
            "open-loop rate must be a positive QPS figure, got {}",
            cfg.rate_qps
        )));
    }
    if cfg.requests == 0 {
        return Err(DynamapError::Config("open loop needs at least one request".into()));
    }
    let dims = model_input_dims(&cfg.model)?;

    // pre-generate every Poisson arrival instant so the dispatch loop
    // does no RNG work between sleeps
    let mut rng = Rng::new(cfg.seed);
    let mut arrivals = Vec::with_capacity(cfg.requests);
    let mut t = 0.0f64;
    for _ in 0..cfg.requests {
        // inter-arrival gaps of a Poisson process are Exp(λ);
        // 1 - f64() is in (0, 1], so the log is always finite
        t += -(1.0 - rng.f64()).ln() / cfg.rate_qps;
        arrivals.push(Duration::from_secs_f64(t));
    }

    let workers = cfg.workers.clamp(1, cfg.requests);
    let (tx, rx) = mpsc::channel::<(usize, Duration)>();
    let rx = Mutex::new(rx);
    let ok_lat = Mutex::new(Vec::new());
    let shed_lat = Mutex::new(Vec::new());
    let deadline_miss = AtomicUsize::new(0);
    let errors = AtomicUsize::new(0);
    let start = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let job = rx.lock().unwrap_or_else(|p| p.into_inner()).recv();
                let Ok((i, scheduled)) = job else { break };
                let input = open_loop_input(cfg.seed, i, dims);
                let trace = if cfg.trace {
                    Some(crate::obs::TraceId::derive(cfg.seed, i as u64))
                } else {
                    None
                };
                let sent = Instant::now();
                match target.infer_traced(&cfg.model, &input, cfg.deadline, trace) {
                    Ok(_) => {
                        let e2e = start.elapsed().saturating_sub(scheduled);
                        let us = e2e.as_secs_f64() * 1e6;
                        ok_lat.lock().unwrap_or_else(|p| p.into_inner()).push(us);
                    }
                    Err(DynamapError::Overloaded { .. }) => {
                        let us = sent.elapsed().as_secs_f64() * 1e6;
                        shed_lat.lock().unwrap_or_else(|p| p.into_inner()).push(us);
                    }
                    Err(DynamapError::DeadlineExceeded { .. }) => {
                        deadline_miss.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
        // dispatch on this thread: sleep to each arrival instant, send
        for (i, at) in arrivals.iter().enumerate() {
            let now = start.elapsed();
            if *at > now {
                std::thread::sleep(*at - now);
            }
            // workers only exit once the channel is closed below, so a
            // send can only fail if a worker panicked — propagate then
            tx.send((i, *at)).expect("open-loop worker pool died");
        }
        drop(tx); // closes the channel; workers drain and exit
    });
    let wall = start.elapsed();

    let mut latency = LatencyStats::new();
    for us in ok_lat.into_inner().unwrap_or_else(|p| p.into_inner()) {
        latency.push(us);
    }
    let mut shed_latency = LatencyStats::new();
    for us in shed_lat.into_inner().unwrap_or_else(|p| p.into_inner()) {
        shed_latency.push(us);
    }
    let ok = latency.count();
    let shed = shed_latency.count();
    Ok(OpenLoopReport {
        offered_qps: cfg.rate_qps,
        achieved_qps: if wall.as_secs_f64() > 0.0 {
            ok as f64 / wall.as_secs_f64()
        } else {
            0.0
        },
        sent: cfg.requests,
        ok,
        shed,
        deadline_miss: deadline_miss.into_inner(),
        errors: errors.into_inner(),
        wall,
        latency,
        shed_latency,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_loop_inputs_are_deterministic_and_distinct() {
        let dims = (4, 16, 16);
        let a = open_loop_input(99, 7, dims);
        let b = open_loop_input(99, 7, dims);
        assert_eq!(a, b, "same (seed, index) → same tensor");
        assert_eq!(a.shape, vec![4, 16, 16]);
        let c = open_loop_input(99, 8, dims);
        assert_ne!(a.data, c.data, "different index → different tensor");
        let d = open_loop_input(100, 7, dims);
        assert_ne!(a.data, d.data, "different seed → different tensor");
    }

    #[test]
    fn model_dims_resolve_through_aliases() {
        assert_eq!(model_input_dims("mini").unwrap(), (4, 16, 16));
        assert_eq!(model_input_dims("mini-inception").unwrap(), (4, 16, 16));
        assert_eq!(model_input_dims("mini-vgg").unwrap(), (3, 16, 16));
        assert!(matches!(
            model_input_dims("nope").unwrap_err(),
            DynamapError::UnknownModel(_)
        ));
    }

    #[test]
    fn poisson_arrivals_are_seeded_and_rate_scaled() {
        // regenerate the arrival schedule exactly as open_loop does
        let gaps = |seed: u64, rate: f64, n: usize| -> Vec<f64> {
            let mut rng = Rng::new(seed);
            (0..n).map(|_| -(1.0 - rng.f64()).ln() / rate).collect()
        };
        let a = gaps(99, 100.0, 512);
        let b = gaps(99, 100.0, 512);
        assert_eq!(a, b, "fixed seed → identical schedule");
        let mean = a.iter().sum::<f64>() / a.len() as f64;
        assert!(
            (mean - 0.01).abs() < 0.002,
            "mean inter-arrival {mean:.4}s ≈ 1/rate"
        );
        assert!(a.iter().all(|g| g.is_finite() && *g >= 0.0));
    }

    /// A stub target cycling through every reply class — checks the
    /// report's accounting paths without a real server.
    struct Flaky(AtomicUsize);
    impl InferTarget for Flaky {
        fn infer_once(
            &self,
            _model: &str,
            input: &TensorBuf,
        ) -> Result<TensorBuf, DynamapError> {
            let n = self.0.fetch_add(1, Ordering::Relaxed);
            match n % 4 {
                0 => Ok(input.clone()),
                1 => Err(DynamapError::Overloaded {
                    model: "mini-inception".into(),
                    retry_after_ms: 1,
                }),
                2 => Err(DynamapError::DeadlineExceeded {
                    model: "mini-inception".into(),
                    waited_ms: 5,
                }),
                _ => Err(DynamapError::Serve("boom".into())),
            }
        }
    }

    #[test]
    fn open_loop_accounts_ok_shed_and_errors() {
        let target = Flaky(AtomicUsize::new(0));
        let cfg = OpenLoopConfig {
            rate_qps: 20_000.0, // finish fast; accounting is rate-blind
            requests: 100,
            workers: 8,
            ..OpenLoopConfig::default()
        };
        let report = open_loop(&target, &cfg).unwrap();
        assert_eq!(report.sent, 100);
        assert_eq!(report.ok + report.shed + report.deadline_miss + report.errors, 100);
        assert_eq!(report.ok, 25);
        assert_eq!(report.shed, 25);
        assert_eq!(report.deadline_miss, 25);
        assert_eq!(report.errors, 25);
        assert_eq!(report.latency.count(), report.ok);
        assert!(report.summary().contains("shed=25"), "{}", report.summary());
        assert!(report.summary().contains("dl_miss=25"), "{}", report.summary());

        // invalid configs are typed, not panics
        assert!(open_loop(&target, &OpenLoopConfig { rate_qps: 0.0, ..cfg.clone() }).is_err());
        assert!(open_loop(&target, &OpenLoopConfig { requests: 0, ..cfg }).is_err());
    }
}
