//! [`BatchQueue`] — the dynamic batching scheduler.
//!
//! Turns many concurrent single-request callers into few large
//! [`NativeState::infer_batch`] calls. A dedicated scheduler thread owns
//! the receive side of an mpsc channel; callers block on a per-request
//! reply channel. The scheduler accumulates requests until either
//! `max_batch` are queued or the oldest request has waited `max_wait`,
//! then flushes the whole batch through the shared [`NativeState`],
//! whose `infer_batch` fans the compute out over the scoped-thread pool
//! in [`crate::util::parallel`]. No async runtime is involved — the
//! offline build has no tokio, and std channels + threads cover the
//! closed-loop serving model exactly.
//!
//! Ordering guarantee: a flush preserves submission order within the
//! batch and `infer_batch` returns results in input order, so every
//! caller gets the bitwise-identical output a sequential
//! [`crate::api::Session::infer`] would have produced (asserted by the
//! soak test in `rust/tests/serving.rs`).
//!
//! Hot-swap guarantee: the scheduler resolves the model's
//! [`StateCell`] once per flush — not at spawn time — so a
//! [`crate::serve::ModelRegistry::swap_state`] takes effect on the
//! next batch while in-flight batches finish on the state they
//! captured. No batch is ever served by a mix of plans.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::api::{DynamapError, InferMetrics};
use crate::runtime::TensorBuf;

use super::metrics::ModelMetrics;
use super::registry::StateCell;
use super::sched::QueuePolicy;

/// A request hit a queue whose scheduler has shut down (e.g. the model
/// was evicted from the registry between lookup and submit) — the
/// typed, retry-safe [`DynamapError::QueueClosed`].
fn closed_error(model: &str) -> DynamapError {
    DynamapError::QueueClosed { model: model.to_string() }
}

/// When a [`BatchQueue`] flushes its pending requests.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Flush as soon as this many requests are queued (≥ 1; `1`
    /// disables batching and serves strictly one request at a time —
    /// the baseline arm of `benches/serving.rs`).
    pub max_batch: usize,
    /// Flush when the oldest queued request has waited this long since
    /// it was enqueued (not since the scheduler picked it up), even if
    /// the batch is not full. Bounds the latency cost of batching.
    pub max_wait: Duration,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

struct Request {
    input: TensorBuf,
    enqueued: Instant,
    /// Absolute expiry: when `Some` and already past at dequeue time,
    /// the request is shed with [`DynamapError::DeadlineExceeded`]
    /// instead of entering the flushed batch.
    deadline: Option<Instant>,
    /// Span-correlation id ([`crate::obs`]): stamps the request's queue
    /// span and rides into the per-layer spans of its compute.
    trace: Option<crate::obs::TraceId>,
    reply: mpsc::Sender<Result<(TensorBuf, InferMetrics), DynamapError>>,
}

/// A per-model request queue with a dedicated scheduler thread.
///
/// Submit with [`BatchQueue::infer`] from any number of threads; shut
/// down explicitly with [`BatchQueue::shutdown`] (also runs on drop).
/// In-flight requests are always answered: on shutdown the scheduler
/// drains everything already submitted before exiting.
pub struct BatchQueue {
    model: String,
    input_len: usize,
    tx: Mutex<Option<mpsc::Sender<Request>>>,
    worker: Mutex<Option<thread::JoinHandle<()>>>,
    metrics: Arc<ModelMetrics>,
}

impl BatchQueue {
    /// Spawn the scheduler thread over `cell`'s model with the default
    /// (inert) [`QueuePolicy`]: no SLO, no pressure coordination, no
    /// flush thread cap — exactly the pre-sched behavior. The scheduler
    /// re-reads the cell at every flush, so hot-swapped states take
    /// effect without restarting the queue.
    pub fn new(
        cell: Arc<StateCell>,
        config: BatchConfig,
        metrics: Arc<ModelMetrics>,
    ) -> BatchQueue {
        BatchQueue::with_policy(cell, config, metrics, QueuePolicy::default())
    }

    /// [`BatchQueue::new`] as one tenant among many: `policy` carries
    /// the model's SLO, the registry-wide pressure gauge and its live
    /// thread-partition budget (see [`crate::serve::sched`]).
    pub fn with_policy(
        cell: Arc<StateCell>,
        config: BatchConfig,
        metrics: Arc<ModelMetrics>,
        policy: QueuePolicy,
    ) -> BatchQueue {
        let state = cell.get();
        let model = state.model().to_string();
        let input_len = state.input_len();
        drop(state);
        let config = BatchConfig { max_batch: config.max_batch.max(1), ..config };
        let (tx, rx) = mpsc::channel::<Request>();
        let worker_metrics = metrics.clone();
        let worker = thread::Builder::new()
            .name(format!("dynamap-batch-{model}"))
            .spawn(move || scheduler_loop(rx, cell, config, worker_metrics, policy))
            .expect("spawn batch scheduler thread");
        BatchQueue {
            model,
            input_len,
            tx: Mutex::new(Some(tx)),
            worker: Mutex::new(Some(worker)),
            metrics,
        }
    }

    /// Model served by this queue.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Telemetry handle shared with the scheduler.
    pub fn metrics(&self) -> &Arc<ModelMetrics> {
        &self.metrics
    }

    /// `true` until [`BatchQueue::shutdown`] has run.
    pub fn is_open(&self) -> bool {
        self.tx.lock().unwrap_or_else(|p| p.into_inner()).is_some()
    }

    /// `true` when the scheduler thread died while the queue was still
    /// open — e.g. it panicked — so every future submit would fail with
    /// [`DynamapError::QueueClosed`] forever. The registry uses this to
    /// distinguish "evicted while I looked" (retry against a fresh
    /// lookup) from "poisoned" (evict and re-host the model).
    pub fn is_wedged(&self) -> bool {
        let open = self.tx.lock().unwrap_or_else(|p| p.into_inner()).is_some();
        let dead = self
            .worker
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .as_ref()
            .map(|h| h.is_finished())
            .unwrap_or(false);
        open && dead
    }

    /// Submit one request and block until its batch is served.
    ///
    /// Returns the output plus the request's compute-side
    /// [`InferMetrics`]; queue-side latency lands in the shared
    /// [`ModelMetrics`]. A wrong-sized input is rejected here, before
    /// it can enter (and poison) a batch shared with other callers —
    /// typed as [`DynamapError::Shape`]. Fails with
    /// [`DynamapError::QueueClosed`] when the queue is shut down.
    pub fn infer(
        &self,
        input: TensorBuf,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        self.infer_with_deadline(input, None)
    }

    /// Shape-check `input` against the model's expected element count
    /// without submitting anything. Public so the registry can reject a
    /// malformed request *before* claiming an admission slot — a shaped
    /// reject must never consume in-flight budget.
    pub fn validate_input(&self, input: &TensorBuf) -> Result<(), DynamapError> {
        if input.len() != self.input_len {
            return Err(DynamapError::Shape {
                context: format!("request for model '{}'", self.model),
                expected: self.input_len,
                got: input.len(),
            });
        }
        Ok(())
    }

    /// [`BatchQueue::infer`] with an optional absolute deadline. A
    /// request whose deadline has passed by the time the scheduler
    /// dequeues it is shed with [`DynamapError::DeadlineExceeded`]
    /// *without* entering the flushed batch — late work never wastes
    /// device time on a reply nobody is waiting for.
    pub fn infer_with_deadline(
        &self,
        input: TensorBuf,
        deadline: Option<Instant>,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        self.infer_traced(input, deadline, None)
    }

    /// [`BatchQueue::infer_with_deadline`] carrying the request's
    /// span-correlation id ([`crate::obs::TraceId`]): when a recorder is
    /// installed, the request's enqueue → dequeue wait is recorded as a
    /// [`crate::obs::Stage::Queue`] span and the id rides into the
    /// per-layer spans of its compute.
    pub fn infer_traced(
        &self,
        input: TensorBuf,
        deadline: Option<Instant>,
        trace: Option<crate::obs::TraceId>,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        self.validate_input(&input)?;
        let sender = self.tx.lock().unwrap_or_else(|p| p.into_inner()).clone();
        let Some(sender) = sender else {
            return Err(closed_error(&self.model));
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        self.metrics.enqueued();
        let req = Request { input, enqueued: Instant::now(), deadline, trace, reply: reply_tx };
        if sender.send(req).is_err() {
            self.metrics.dequeued();
            return Err(closed_error(&self.model));
        }
        drop(sender);
        // the scheduler answers every drained request; a dropped reply
        // channel means it exited before reaching ours
        reply_rx.recv().unwrap_or_else(|_| Err(closed_error(&self.model)))
    }

    /// Stop accepting requests, drain everything already submitted and
    /// join the scheduler thread. Idempotent.
    pub fn shutdown(&self) {
        self.tx.lock().unwrap_or_else(|p| p.into_inner()).take();
        let worker = self.worker.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(handle) = worker {
            let _ = handle.join();
        }
    }
}

impl Drop for BatchQueue {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The scheduler: block for the first request, top the batch up until
/// full or past the deadline, flush against the cell's *current*
/// state, repeat. Exits when every sender is gone and the channel is
/// drained.
///
/// Multi-tenant behavior (inert under the default [`QueuePolicy`]):
/// an interactive tenant whose oldest queued request has waited ≥ ¼ of
/// its latency target raises pressure on the shared
/// [`crate::serve::sched::SchedCoordinator`] before flushing; a
/// best-effort tenant parks an assembled batch while pressure holds —
/// bounded to `8 × max_wait` so bulk traffic is deferred, never
/// starved — and keeps absorbing arrivals while parked, then flushes
/// the whole batch with its fan-out squeezed to one worker if pressure
/// still holds. Deferral never drops or reorders a request: the batch
/// that was assembled is the batch that flushes (plus any arrivals
/// absorbed while parked), each caller still gets exactly one reply.
fn scheduler_loop(
    rx: mpsc::Receiver<Request>,
    cell: Arc<StateCell>,
    config: BatchConfig,
    metrics: Arc<ModelMetrics>,
    policy: QueuePolicy,
) {
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all senders dropped, nothing buffered
        };
        // chaos hook: a scheduler that dies mid-service wedges the whole
        // queue — the registry's re-host path must recover it
        crate::fault::panic_if(crate::fault::Site::SchedulerPanic);
        let mut batch = vec![first];
        // the max_wait budget is measured from the oldest request's
        // enqueue, not from scheduler pickup: a request that already
        // aged in the channel while the previous batch was computing
        // must not wait another full max_wait for companions
        let flush_by = batch[0].enqueued + config.max_wait;
        let mut disconnected = false;
        while batch.len() < config.max_batch {
            // requests already buffered during the previous flush
            // batch for free, even past the deadline
            match rx.try_recv() {
                Ok(r) => {
                    batch.push(r);
                    continue;
                }
                Err(mpsc::TryRecvError::Empty) => {}
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
            let left = flush_by.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // SLO pressure: an interactive tenant about to flush a batch
        // whose oldest request burned ≥ ¼ of the latency target on
        // queue wait tells best-effort tenants to step aside for the
        // next half-target window
        if let (Some(coord), Some(target), false) =
            (&policy.coordinator, policy.slo.latency_target, policy.slo.best_effort)
        {
            if batch[0].enqueued.elapsed() * 4 >= target {
                coord.raise((target / 2).max(config.max_wait));
            }
        }
        // best-effort deferral: park the assembled batch while pressure
        // holds, still absorbing arrivals, for at most 8 × max_wait —
        // bulk work yields the CPU to the pressured tenant but is never
        // starved outright, and nothing is dropped
        if policy.slo.best_effort && !disconnected {
            if let Some(coord) = &policy.coordinator {
                let park_until = Instant::now() + (config.max_wait * 8).max(Duration::from_millis(2));
                let mut deferred = false;
                while coord.pressured() && Instant::now() < park_until {
                    deferred = true;
                    while batch.len() < config.max_batch {
                        match rx.try_recv() {
                            Ok(r) => batch.push(r),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                disconnected = true;
                                break;
                            }
                        }
                    }
                    if disconnected {
                        break;
                    }
                    thread::sleep(Duration::from_micros(200));
                }
                if deferred {
                    metrics.record_deferral();
                }
            }
        }
        // snapshot the serving state per flush: the whole batch runs on
        // one plan, and a concurrent hot swap lands on the next batch —
        // deferral happens *before* this snapshot, so a parked batch
        // can never mix plan epochs either
        let state = cell.get();
        flush(&state, &metrics, batch, policy.flush_threads());
        if disconnected {
            break;
        }
    }
}

/// Render a caught panic payload into something loggable.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Serve one accumulated batch and answer every caller.
///
/// Two reliability gates run here, per request:
///
/// * **Deadline re-check at dequeue.** A request whose deadline expired
///   while it sat in the channel is answered with
///   [`DynamapError::DeadlineExceeded`] and never enters the computed
///   batch — the whole point of a deadline is not computing results
///   nobody will read.
/// * **Panic isolation.** Each request's compute runs under
///   `catch_unwind`, so one poisoned input yields one typed
///   [`DynamapError::Serve`] reply while its batch siblings return
///   bitwise-correct results. Without this, a single panic would kill
///   the scheduler thread and wedge the queue for every future caller.
fn flush(
    state: &crate::api::session::NativeState,
    metrics: &ModelMetrics,
    batch: Vec<Request>,
    thread_cap: usize,
) {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // resolve the span recorder once per flush (one relaxed load when
    // tracing is off; see `crate::obs`)
    let recorder = crate::obs::active();
    let mut inputs = Vec::new();
    let mut waiters = Vec::new();
    for req in batch {
        metrics.dequeued();
        if let Some(rec) = &recorder {
            // queue span: the request's enqueue → dequeue wait,
            // recorded for served and deadline-shed requests alike
            rec.record_span(
                req.trace,
                crate::obs::Stage::Queue,
                state.model(),
                req.enqueued,
                Instant::now(),
                vec![],
            );
        }
        match req.deadline {
            Some(d) if Instant::now() >= d => {
                // aged out in queue: shed at dequeue, before the batch
                metrics.record_deadline_miss();
                let waited_ms = req.enqueued.elapsed().as_millis() as u64;
                let _ = req.reply.send(Err(DynamapError::DeadlineExceeded {
                    model: state.model().to_string(),
                    waited_ms,
                }));
            }
            _ => {
                inputs.push((req.input, req.trace));
                waiters.push((req.enqueued, req.reply));
            }
        }
    }
    if inputs.is_empty() {
        return; // everything expired — nothing to compute, no batch
    }
    metrics.record_batch(inputs.len());

    // per-request compute with per-request blast radius: panics are
    // caught inside the worker closure, so the parallel map never
    // re-raises and the scheduler thread survives. `thread_cap` is the
    // tenant's live partition budget (0 = uncapped)
    let t_flush = Instant::now();
    let results: Vec<Result<(TensorBuf, InferMetrics), DynamapError>> =
        crate::util::parallel::parallel_map_capped(&inputs, thread_cap, |_, (input, trace)| {
            catch_unwind(AssertUnwindSafe(|| state.infer_traced(input, *trace)))
                .unwrap_or_else(|payload| {
                    Err(DynamapError::Serve(format!(
                        "request compute panicked: {}",
                        panic_message(payload)
                    )))
                })
        });
    if let Some(rec) = &recorder {
        // flush span: the whole batch's compute, on the batch-level
        // track (no single owning request), tagged with its size
        rec.record_span(
            None,
            crate::obs::Stage::Flush,
            state.model(),
            t_flush,
            Instant::now(),
            vec![("batch", inputs.len().to_string())],
        );
    }

    // account the whole batch under one lock BEFORE answering: a caller
    // that has its reply must already be visible in the metrics (the
    // soak test asserts exactly that)
    let mut lat = Vec::with_capacity(waiters.len());
    let mut errors = 0usize;
    for ((enqueued, _), result) in waiters.iter().zip(&results) {
        match result {
            Ok(_) => lat.push(enqueued.elapsed().as_secs_f64() * 1e6),
            Err(DynamapError::Serve(m)) if m.starts_with("request compute panicked") => {
                errors += 1;
                metrics.record_panic_recovered();
            }
            Err(_) => errors += 1,
        }
    }
    metrics.record_requests(&lat);
    metrics.record_errors(errors);
    for ((_, reply), result) in waiters.into_iter().zip(results) {
        let _ = reply.send(result);
    }
}
