//! [`BatchQueue`] — the dynamic batching scheduler.
//!
//! Turns many concurrent single-request callers into few large
//! [`NativeState::infer_batch`] calls. A dedicated scheduler thread owns
//! the receive side of an mpsc channel; callers block on a per-request
//! reply channel. The scheduler accumulates requests until either
//! `max_batch` are queued or the oldest request has waited `max_wait`,
//! then flushes the whole batch through the shared [`NativeState`],
//! whose `infer_batch` fans the compute out over the scoped-thread pool
//! in [`crate::util::parallel`]. No async runtime is involved — the
//! offline build has no tokio, and std channels + threads cover the
//! closed-loop serving model exactly.
//!
//! Ordering guarantee: a flush preserves submission order within the
//! batch and `infer_batch` returns results in input order, so every
//! caller gets the bitwise-identical output a sequential
//! [`crate::api::Session::infer`] would have produced (asserted by the
//! soak test in `rust/tests/serving.rs`).
//!
//! Hot-swap guarantee: the scheduler resolves the model's
//! [`StateCell`] once per flush — not at spawn time — so a
//! [`crate::serve::ModelRegistry::swap_state`] takes effect on the
//! next batch while in-flight batches finish on the state they
//! captured. No batch is ever served by a mix of plans.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::api::{DynamapError, InferMetrics};
use crate::runtime::TensorBuf;

use super::metrics::ModelMetrics;
use super::registry::StateCell;

/// A request hit a queue whose scheduler has shut down (e.g. the model
/// was evicted from the registry between lookup and submit) — the
/// typed, retry-safe [`DynamapError::QueueClosed`].
fn closed_error(model: &str) -> DynamapError {
    DynamapError::QueueClosed { model: model.to_string() }
}

/// When a [`BatchQueue`] flushes its pending requests.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Flush as soon as this many requests are queued (≥ 1; `1`
    /// disables batching and serves strictly one request at a time —
    /// the baseline arm of `benches/serving.rs`).
    pub max_batch: usize,
    /// Flush when the oldest queued request has waited this long since
    /// it was enqueued (not since the scheduler picked it up), even if
    /// the batch is not full. Bounds the latency cost of batching.
    pub max_wait: Duration,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

struct Request {
    input: TensorBuf,
    enqueued: Instant,
    reply: mpsc::Sender<Result<(TensorBuf, InferMetrics), DynamapError>>,
}

/// A per-model request queue with a dedicated scheduler thread.
///
/// Submit with [`BatchQueue::infer`] from any number of threads; shut
/// down explicitly with [`BatchQueue::shutdown`] (also runs on drop).
/// In-flight requests are always answered: on shutdown the scheduler
/// drains everything already submitted before exiting.
pub struct BatchQueue {
    model: String,
    input_len: usize,
    tx: Mutex<Option<mpsc::Sender<Request>>>,
    worker: Mutex<Option<thread::JoinHandle<()>>>,
    metrics: Arc<ModelMetrics>,
}

impl BatchQueue {
    /// Spawn the scheduler thread over `cell`'s model. The scheduler
    /// re-reads the cell at every flush, so hot-swapped states take
    /// effect without restarting the queue.
    pub fn new(
        cell: Arc<StateCell>,
        config: BatchConfig,
        metrics: Arc<ModelMetrics>,
    ) -> BatchQueue {
        let state = cell.get();
        let model = state.model().to_string();
        let input_len = state.input_len();
        drop(state);
        let config = BatchConfig { max_batch: config.max_batch.max(1), ..config };
        let (tx, rx) = mpsc::channel::<Request>();
        let worker_metrics = metrics.clone();
        let worker = thread::Builder::new()
            .name(format!("dynamap-batch-{model}"))
            .spawn(move || scheduler_loop(rx, cell, config, worker_metrics))
            .expect("spawn batch scheduler thread");
        BatchQueue {
            model,
            input_len,
            tx: Mutex::new(Some(tx)),
            worker: Mutex::new(Some(worker)),
            metrics,
        }
    }

    /// Model served by this queue.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Telemetry handle shared with the scheduler.
    pub fn metrics(&self) -> &Arc<ModelMetrics> {
        &self.metrics
    }

    /// `true` until [`BatchQueue::shutdown`] has run.
    pub fn is_open(&self) -> bool {
        self.tx.lock().unwrap_or_else(|p| p.into_inner()).is_some()
    }

    /// Submit one request and block until its batch is served.
    ///
    /// Returns the output plus the request's compute-side
    /// [`InferMetrics`]; queue-side latency lands in the shared
    /// [`ModelMetrics`]. A wrong-sized input is rejected here, before
    /// it can enter (and poison) a batch shared with other callers —
    /// typed as [`DynamapError::Shape`]. Fails with
    /// [`DynamapError::QueueClosed`] when the queue is shut down.
    pub fn infer(
        &self,
        input: TensorBuf,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        if input.len() != self.input_len {
            return Err(DynamapError::Shape {
                context: format!("request for model '{}'", self.model),
                expected: self.input_len,
                got: input.len(),
            });
        }
        let sender = self.tx.lock().unwrap_or_else(|p| p.into_inner()).clone();
        let Some(sender) = sender else {
            return Err(closed_error(&self.model));
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        self.metrics.enqueued();
        let req = Request { input, enqueued: Instant::now(), reply: reply_tx };
        if sender.send(req).is_err() {
            self.metrics.dequeued();
            return Err(closed_error(&self.model));
        }
        drop(sender);
        // the scheduler answers every drained request; a dropped reply
        // channel means it exited before reaching ours
        reply_rx.recv().unwrap_or_else(|_| Err(closed_error(&self.model)))
    }

    /// Stop accepting requests, drain everything already submitted and
    /// join the scheduler thread. Idempotent.
    pub fn shutdown(&self) {
        self.tx.lock().unwrap_or_else(|p| p.into_inner()).take();
        let worker = self.worker.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(handle) = worker {
            let _ = handle.join();
        }
    }
}

impl Drop for BatchQueue {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The scheduler: block for the first request, top the batch up until
/// full or past the deadline, flush against the cell's *current*
/// state, repeat. Exits when every sender is gone and the channel is
/// drained.
fn scheduler_loop(
    rx: mpsc::Receiver<Request>,
    cell: Arc<StateCell>,
    config: BatchConfig,
    metrics: Arc<ModelMetrics>,
) {
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all senders dropped, nothing buffered
        };
        let mut batch = vec![first];
        // the max_wait budget is measured from the oldest request's
        // enqueue, not from scheduler pickup: a request that already
        // aged in the channel while the previous batch was computing
        // must not wait another full max_wait for companions
        let deadline = batch[0].enqueued + config.max_wait;
        let mut disconnected = false;
        while batch.len() < config.max_batch {
            // requests already buffered during the previous flush
            // batch for free, even past the deadline
            match rx.try_recv() {
                Ok(r) => {
                    batch.push(r);
                    continue;
                }
                Err(mpsc::TryRecvError::Empty) => {}
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // snapshot the serving state per flush: the whole batch runs on
        // one plan, and a concurrent hot swap lands on the next batch
        let state = cell.get();
        flush(&state, &metrics, batch);
        if disconnected {
            break;
        }
    }
}

/// Serve one accumulated batch and answer every caller.
fn flush(
    state: &crate::api::session::NativeState,
    metrics: &ModelMetrics,
    batch: Vec<Request>,
) {
    let mut inputs = Vec::with_capacity(batch.len());
    let mut waiters = Vec::with_capacity(batch.len());
    for req in batch {
        metrics.dequeued();
        inputs.push(req.input);
        waiters.push((req.enqueued, req.reply));
    }
    metrics.record_batch(inputs.len());
    match state.infer_batch(&inputs) {
        Ok((outputs, bm)) => {
            // account the whole batch under one lock BEFORE answering:
            // a caller that has its reply must already be visible in
            // the metrics (the soak test asserts exactly that)
            let lat: Vec<f64> = waiters
                .iter()
                .map(|(enqueued, _)| enqueued.elapsed().as_secs_f64() * 1e6)
                .collect();
            metrics.record_requests(&lat);
            let replies = waiters.into_iter().zip(outputs).zip(bm.per_request);
            for (((_, reply), output), m) in replies {
                let _ = reply.send(Ok((output, m)));
            }
        }
        Err(e) => {
            // DynamapError is not Clone: every caller gets the flush
            // failure re-wrapped as a serve error
            metrics.record_errors(waiters.len());
            let msg = format!("batch flush failed: {e}");
            for (_, reply) in waiters {
                let _ = reply.send(Err(DynamapError::Serve(msg.clone())));
            }
        }
    }
}
