//! SLO-aware co-scheduling across hosted models (ROADMAP open item 2).
//!
//! DYNAMAP solves each CNN's per-layer algorithm mapping in isolation,
//! but a serving host rarely runs one model: f-CNNx (PAPERS.md) showed
//! that multi-CNN deployments need *joint* resource partitioning, and
//! fpgaConvNet's partitioned toolflow re-solves each network under its
//! slice of the device. This module is the CPU-overlay analogue:
//!
//! 1. **SLO table** — [`ModelSlo`] gives every hosted model a latency
//!    target, an integer priority and an optional best-effort tier;
//!    [`crate::serve::RegistryConfig::slos`] carries the table.
//! 2. **Thread partitioner** — [`partition_threads`] splits the host's
//!    `available_parallelism` across tenants proportionally to
//!    `priority × measured demand` with a largest-remainder
//!    apportionment. Invariants (property-tested in-module and in
//!    `tests/sched.rs`): budgets sum to the available total (when it
//!    covers one thread per tenant), every tenant gets ≥ 1 thread,
//!    within one allocation a higher-priority tenant at equal demand
//!    never receives fewer threads than a lower-priority one, and the
//!    whole computation is pure — same inputs, same budgets, bit for
//!    bit.
//! 3. **Per-partition plan re-solve** — the registry re-runs the DSE
//!    for each tenant under [`crate::cost::DeviceCalibration::scaled`]
//!    `(total / budget)`, so the plan cache keys one artifact per
//!    (model, partition) via the existing compiler fingerprint.
//! 4. **Pressure coordination** — [`SchedCoordinator`] is a tiny
//!    lock-free gauge between batch schedulers: a high-priority queue
//!    whose oldest request has waited ≥ ¼ of its latency target raises
//!    pressure; best-effort queues respond by *deferring* their next
//!    flush (bounded, so bulk traffic is never starved outright) and
//!    shrinking its fan-out to one worker thread. Deferral never drops
//!    a request — a deferred batch keeps absorbing arrivals and always
//!    flushes; every submitted request still gets exactly one typed
//!    reply (`tests/sched.rs` proves the blast radius is zero).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-model service-level objective: what latency the tenant was
/// promised and how hard the scheduler should fight for it.
///
/// The default SLO (no latency target, mid priority, not best-effort)
/// reproduces pre-sched behavior exactly: no pressure is ever raised
/// and no flush is ever deferred, so single-tenant deployments are
/// untouched.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelSlo {
    /// End-to-end latency target for this tenant (`None` = no SLO).
    /// Attainment against it is tracked per model in
    /// [`crate::serve::ModelMetrics`] and exported over the wire
    /// `Stats` frame.
    pub latency_target: Option<Duration>,
    /// Relative weight in the thread partition (clamped to ≥ 1).
    /// Doubling a tenant's priority roughly doubles its share.
    pub priority: u32,
    /// Best-effort tier: this tenant's flushes defer (bounded) and
    /// shrink to one worker while any high-priority tenant is under
    /// queue-delay pressure.
    pub best_effort: bool,
}

impl Default for ModelSlo {
    fn default() -> ModelSlo {
        ModelSlo { latency_target: None, priority: 4, best_effort: false }
    }
}

impl ModelSlo {
    /// A high-priority interactive tenant with a latency target of
    /// `ms` milliseconds (priority 8).
    pub fn interactive_ms(ms: f64) -> ModelSlo {
        ModelSlo {
            latency_target: Some(Duration::from_secs_f64((ms.max(0.001)) / 1e3)),
            priority: 8,
            best_effort: false,
        }
    }

    /// A bulk best-effort tenant: lowest priority, no latency target,
    /// defers to pressured interactive tenants.
    pub fn bulk() -> ModelSlo {
        ModelSlo { latency_target: None, priority: 1, best_effort: true }
    }

    /// Builder-style: override the priority.
    pub fn with_priority(mut self, priority: u32) -> ModelSlo {
        self.priority = priority;
        self
    }

    /// Builder-style: override the latency target (milliseconds).
    pub fn with_target_ms(mut self, ms: f64) -> ModelSlo {
        self.latency_target = Some(Duration::from_secs_f64(ms.max(0.001) / 1e3));
        self
    }

    /// `true` for a tenant that both has a latency target and is not
    /// best-effort — the only kind that raises pressure.
    pub fn is_interactive(&self) -> bool {
        self.latency_target.is_some() && !self.best_effort
    }

    /// The latency target in microseconds (`0` when unset) — the form
    /// the metrics layer stores atomically.
    pub fn target_us(&self) -> u64 {
        self.latency_target.map(|d| d.as_micros() as u64).unwrap_or(0)
    }
}

/// Per-model SLO table carried by `RegistryConfig` — keys are model
/// names (zoo aliases are resolved at host time, like everywhere else
/// in the registry).
pub type SloTable = BTreeMap<String, ModelSlo>;

/// One tenant's input to [`partition_threads`].
#[derive(Debug, Clone, PartialEq)]
pub struct Tenant {
    /// Model name (partition map key; also the deterministic
    /// tie-breaker of last resort).
    pub model: String,
    /// SLO priority (clamped to ≥ 1).
    pub priority: u32,
    /// Measured demand — the registry feeds `qps + queue depth`,
    /// clamped to ≥ 1 so an idle tenant still weighs its priority.
    pub demand: f64,
}

/// Split `total` worker threads across `tenants` proportionally to
/// `priority × demand`, largest-remainder style.
///
/// Guarantees (see module doc; property-tested under seed 99):
/// * every tenant receives ≥ 1 thread, always;
/// * the budgets sum to `max(total, tenants.len())` — i.e. exactly
///   `total` whenever the host has at least one thread per tenant;
/// * within one allocation, a tenant with strictly greater weight
///   never receives fewer threads than a lighter one (ties broken by
///   weight, then name, so the result is a pure function of the
///   inputs);
/// * no clocks, no RNG, no floats whose value depends on iteration
///   order — the same inputs replay bit-for-bit on any host.
pub fn partition_threads(total: usize, tenants: &[Tenant]) -> BTreeMap<String, usize> {
    let mut budgets = BTreeMap::new();
    if tenants.is_empty() {
        return budgets;
    }
    let n = tenants.len();
    let weight =
        |t: &Tenant| (t.priority.max(1) as f64) * t.demand.max(1e-6);
    let w_sum: f64 = tenants.iter().map(weight).sum();
    // one reserved thread each keeps every queue live even when the
    // host is smaller than the tenant count (budgets then exceed
    // `total`, which the flush-time min with `worker_count` absorbs)
    let spare = total.saturating_sub(n);
    // integer shares of the spare pool plus the fractional remainder
    // each tenant is owed
    let mut shares: Vec<(usize, usize, f64)> = tenants
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let exact = spare as f64 * weight(t) / w_sum;
            let base = exact.floor() as usize;
            (i, base, exact - base as f64)
        })
        .collect();
    let assigned: usize = shares.iter().map(|(_, b, _)| *b).sum();
    let mut leftover = spare.saturating_sub(assigned);
    // hand the leftover threads to the largest remainders; break ties
    // by weight (heavier first), then by name (lexicographic), so the
    // allocation is deterministic and never prefers a lighter tenant
    shares.sort_by(|a, b| {
        b.2.partial_cmp(&a.2)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| {
                weight(&tenants[b.0])
                    .partial_cmp(&weight(&tenants[a.0]))
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .then_with(|| tenants[a.0].model.cmp(&tenants[b.0].model))
    });
    for (i, base, _) in shares {
        let bonus = if leftover > 0 {
            leftover -= 1;
            1
        } else {
            0
        };
        budgets.insert(tenants[i].model.clone(), 1 + base + bonus);
    }
    budgets
}

/// Lock-free pressure gauge shared by every [`crate::serve::BatchQueue`]
/// scheduler thread of one registry.
///
/// High-priority schedulers call [`SchedCoordinator::raise`] when their
/// oldest queued request has waited long enough to threaten the SLO;
/// best-effort schedulers poll [`SchedCoordinator::pressured`] before
/// flushing. State is a single microsecond deadline measured against a
/// shared epoch `Instant`, advanced with `fetch_max`, so concurrent
/// raises compose and the gauge decays on its own — there is no
/// "lower" call to forget.
#[derive(Debug)]
pub struct SchedCoordinator {
    epoch: Instant,
    pressure_until_us: AtomicU64,
    raises: AtomicU64,
}

impl Default for SchedCoordinator {
    fn default() -> SchedCoordinator {
        SchedCoordinator::new()
    }
}

impl SchedCoordinator {
    /// A fresh gauge with no pressure.
    pub fn new() -> SchedCoordinator {
        SchedCoordinator {
            epoch: Instant::now(),
            pressure_until_us: AtomicU64::new(0),
            raises: AtomicU64::new(0),
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Signal SLO pressure for the next `hold` — best-effort flushes
    /// defer until it expires (or their deferral bound trips).
    pub fn raise(&self, hold: Duration) {
        let until = self.now_us().saturating_add(hold.as_micros() as u64);
        self.pressure_until_us.fetch_max(until, Ordering::AcqRel);
        self.raises.fetch_add(1, Ordering::Relaxed);
    }

    /// `true` while a raised pressure window is still open.
    pub fn pressured(&self) -> bool {
        self.now_us() < self.pressure_until_us.load(Ordering::Acquire)
    }

    /// How many times pressure was raised (tests assert the preemption
    /// path actually ran).
    pub fn raises(&self) -> u64 {
        self.raises.load(Ordering::Relaxed)
    }
}

/// Everything a [`crate::serve::BatchQueue`] scheduler needs to behave
/// as one tenant among many: its SLO, the shared pressure gauge, and
/// its live thread budget (written by the registry's repartitioner,
/// read at every flush; `0` = uncapped).
#[derive(Debug, Clone)]
pub struct QueuePolicy {
    /// This tenant's SLO.
    pub slo: ModelSlo,
    /// Shared pressure gauge (`None` for single-tenant registries —
    /// the scheduler then never defers and never raises).
    pub coordinator: Option<Arc<SchedCoordinator>>,
    /// Live thread budget for this tenant's flush fan-out (`0` =
    /// uncapped). An `Arc` so the registry repartitions without
    /// touching the scheduler thread.
    pub threads: Arc<AtomicUsize>,
}

impl Default for QueuePolicy {
    fn default() -> QueuePolicy {
        QueuePolicy {
            slo: ModelSlo::default(),
            coordinator: None,
            threads: Arc::new(AtomicUsize::new(0)),
        }
    }
}

impl QueuePolicy {
    /// The flush fan-out cap this tenant should use right now: its
    /// partition budget, squeezed to a single worker while it is
    /// best-effort under pressure (`0` = uncapped).
    pub fn flush_threads(&self) -> usize {
        let budget = self.threads.load(Ordering::Relaxed);
        if self.slo.best_effort
            && self.coordinator.as_ref().is_some_and(|c| c.pressured())
        {
            return 1;
        }
        budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tenants(specs: &[(&str, u32, f64)]) -> Vec<Tenant> {
        specs
            .iter()
            .map(|(m, p, d)| Tenant { model: m.to_string(), priority: *p, demand: *d })
            .collect()
    }

    #[test]
    fn partition_sums_to_total_and_floors_at_one() {
        let t = tenants(&[("a", 8, 100.0), ("b", 1, 1.0), ("c", 4, 10.0)]);
        for total in 3..=64 {
            let b = partition_threads(total, &t);
            assert_eq!(b.values().sum::<usize>(), total, "total={total}");
            assert!(b.values().all(|&v| v >= 1), "total={total}");
        }
        // host smaller than tenant count: everyone still gets one
        let b = partition_threads(2, &t);
        assert_eq!(b.values().sum::<usize>(), 3);
        assert!(b.values().all(|&v| v == 1));
        assert!(partition_threads(8, &[]).is_empty());
    }

    #[test]
    fn partition_is_monotone_in_priority() {
        // equal demand: the higher-priority tenant never gets fewer
        // threads, across a seeded sweep of shapes
        let mut rng = Rng::new(99);
        for _ in 0..500 {
            let demand = 1.0 + rng.f64() * 100.0;
            let lo = 1 + (rng.next_u64() % 8) as u32;
            let hi = lo + 1 + (rng.next_u64() % 8) as u32;
            let total = 2 + (rng.next_u64() % 62) as usize;
            let t = tenants(&[("high", hi, demand), ("low", lo, demand)]);
            let b = partition_threads(total, &t);
            assert!(
                b["high"] >= b["low"],
                "total={total} hi={hi} lo={lo} demand={demand}: {b:?}"
            );
        }
    }

    #[test]
    fn partition_replays_bit_for_bit() {
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let n = 1 + (rng.next_u64() % 6) as usize;
            let t: Vec<Tenant> = (0..n)
                .map(|i| Tenant {
                    model: format!("m{i}"),
                    priority: 1 + (rng.next_u64() % 16) as u32,
                    demand: rng.f64() * 1000.0,
                })
                .collect();
            let total = n + (rng.next_u64() % 64) as usize;
            assert_eq!(partition_threads(total, &t), partition_threads(total, &t));
        }
    }

    #[test]
    fn partition_weighs_demand() {
        // equal priority, 9:1 demand split over 10 spare threads:
        // the hot tenant owns the lion's share
        let t = tenants(&[("hot", 4, 90.0), ("cold", 4, 10.0)]);
        let b = partition_threads(12, &t);
        assert_eq!(b.values().sum::<usize>(), 12);
        assert!(b["hot"] >= 9, "{b:?}");
        assert!(b["cold"] >= 1, "{b:?}");
    }

    #[test]
    fn coordinator_pressure_raises_and_decays() {
        let c = SchedCoordinator::new();
        assert!(!c.pressured());
        assert_eq!(c.raises(), 0);
        c.raise(Duration::from_millis(50));
        assert!(c.pressured());
        assert_eq!(c.raises(), 1);
        // a shorter concurrent raise never shrinks the window
        c.raise(Duration::from_micros(1));
        assert!(c.pressured());
        std::thread::sleep(Duration::from_millis(60));
        assert!(!c.pressured(), "pressure must decay on its own");
    }

    #[test]
    fn policy_squeezes_best_effort_under_pressure() {
        let coord = Arc::new(SchedCoordinator::new());
        let be = QueuePolicy {
            slo: ModelSlo::bulk(),
            coordinator: Some(coord.clone()),
            threads: Arc::new(AtomicUsize::new(6)),
        };
        let hi = QueuePolicy {
            slo: ModelSlo::interactive_ms(25.0),
            coordinator: Some(coord.clone()),
            threads: Arc::new(AtomicUsize::new(2)),
        };
        assert_eq!(be.flush_threads(), 6);
        assert_eq!(hi.flush_threads(), 2);
        coord.raise(Duration::from_secs(5));
        assert_eq!(be.flush_threads(), 1, "bulk squeezes to one worker");
        assert_eq!(hi.flush_threads(), 2, "interactive keeps its budget");
        // default policy is inert regardless of pressure
        assert_eq!(QueuePolicy::default().flush_threads(), 0);
    }

    #[test]
    fn slo_constructors() {
        let i = ModelSlo::interactive_ms(25.0);
        assert!(i.is_interactive());
        assert_eq!(i.target_us(), 25_000);
        assert_eq!(i.priority, 8);
        let b = ModelSlo::bulk();
        assert!(b.best_effort && !b.is_interactive());
        assert_eq!(b.target_us(), 0);
        let d = ModelSlo::default();
        assert!(!d.is_interactive() && !d.best_effort);
        let c = ModelSlo::bulk().with_priority(3).with_target_ms(10.0);
        assert_eq!(c.priority, 3);
        assert_eq!(c.target_us(), 10_000);
    }
}
