//! [`ServerMetrics`] — per-model serving telemetry.
//!
//! Tracks the quantities a multi-model server is judged on: per-model
//! QPS, queue depth (current and high-water), batch-size histograms,
//! shed-request accounting and p50/p95/p99/p99.9 end-to-end latency.
//! Counters on the submit path are atomics; the latency histogram and
//! batch histogram sit behind a mutex the flush path takes a constant
//! number of times per batch (never per request), so the accounting
//! stays off the per-request hot path.
//!
//! Latency percentiles come from a fixed log-bucketed
//! [`LogHistogram`] per model: O(1) record, O(buckets) snapshot (no
//! sort-over-sample-window on `report`), constant memory over the
//! server's whole lifetime, and a documented quantile error bound
//! ([`LogHistogram::MAX_RELATIVE_ERROR`] ≈ 4.4%). The histogram covers
//! *all* requests ever served, not a sliding window. The full bucket
//! set exports through [`ServerMetrics::to_json`] for the wire `Stats`
//! frame.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::LogHistogram;
use crate::util::json::Json;
use crate::util::table::Table;

/// Mutable telemetry for one hosted model.
///
/// Shared (`Arc`) between the model's [`crate::serve::BatchQueue`]
/// worker, the submit path and any reporting thread; every method takes
/// `&self`.
#[derive(Debug)]
pub struct ModelMetrics {
    model: String,
    started: Instant,
    depth: AtomicUsize,
    max_depth: AtomicUsize,
    swaps: AtomicUsize,
    shed: AtomicU64,
    deadline_miss: AtomicU64,
    retries: AtomicU64,
    hedges_won: AtomicU64,
    panics: AtomicU64,
    /// EWMA of the mean per-request end-to-end latency (µs), updated
    /// once per flushed batch. Feeds the `retry_after_ms` hint on
    /// [`crate::api::DynamapError::Overloaded`] without touching the
    /// latency mutex on the (shed) submit path.
    ewma_us: AtomicU64,
    /// This tenant's SLO latency target, µs (`0` = no SLO). Set once at
    /// host time from [`crate::serve::sched::ModelSlo`]; every flushed
    /// latency sample is compared against it so attainment is exact,
    /// not re-derived from bucketed percentiles.
    slo_target_us: AtomicU64,
    /// Served requests whose end-to-end latency exceeded the SLO
    /// target.
    slo_miss: AtomicU64,
    /// Best-effort flushes deferred because a high-priority tenant was
    /// under SLO pressure.
    deferrals: AtomicU64,
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    errors: u64,
    batches: u64,
    /// End-to-end latency per request (queue wait + batched compute),
    /// µs — a fixed log-bucketed histogram over the model's whole
    /// lifetime. Constant memory (~2 KiB) no matter the traffic, so
    /// metrics surviving LRU eviction never grow unbounded.
    latency: LogHistogram,
    /// Flushed batch size → number of batches of that size.
    batch_hist: BTreeMap<usize, u64>,
}

impl ModelMetrics {
    /// Fresh telemetry for `model`; QPS is measured from this instant.
    pub fn new(model: impl Into<String>) -> ModelMetrics {
        ModelMetrics {
            model: model.into(),
            started: Instant::now(),
            depth: AtomicUsize::new(0),
            max_depth: AtomicUsize::new(0),
            swaps: AtomicUsize::new(0),
            shed: AtomicU64::new(0),
            deadline_miss: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            hedges_won: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            ewma_us: AtomicU64::new(0),
            slo_target_us: AtomicU64::new(0),
            slo_miss: AtomicU64::new(0),
            deferrals: AtomicU64::new(0),
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Install this tenant's SLO latency target (µs, `0` disables).
    /// Subsequent served requests count toward attainment against it.
    pub fn set_slo_target_us(&self, us: u64) {
        self.slo_target_us.store(us, Ordering::Relaxed);
    }

    /// The installed SLO latency target, µs (`0` = no SLO).
    pub fn slo_target_us(&self) -> u64 {
        self.slo_target_us.load(Ordering::Relaxed)
    }

    /// Served requests that exceeded the SLO target so far.
    pub fn slo_miss(&self) -> u64 {
        self.slo_miss.load(Ordering::Relaxed)
    }

    /// A best-effort flush was deferred under high-priority pressure.
    pub fn record_deferral(&self) {
        self.deferrals.fetch_add(1, Ordering::Relaxed);
    }

    /// Best-effort flush deferrals so far.
    pub fn deferrals(&self) -> u64 {
        self.deferrals.load(Ordering::Relaxed)
    }

    /// Model this telemetry belongs to.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// A request entered the queue.
    pub fn enqueued(&self) {
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.max_depth.fetch_max(d, Ordering::Relaxed);
    }

    /// A request left the queue (picked into a batch, or submit failed).
    pub fn dequeued(&self) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
    }

    /// Requests currently waiting in the queue.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// The tune loop hot-swapped this model's plan.
    pub fn record_swap(&self) {
        self.swaps.fetch_add(1, Ordering::Relaxed);
    }

    /// Plan hot-swaps served by this model so far.
    pub fn swaps(&self) -> usize {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Admission control rejected a request (in-flight budget full).
    /// Shed requests never enter the queue, so they are counted here and
    /// nowhere else — `requests` stays "work the backend actually did".
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests shed by admission control so far.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// A request's deadline expired before compute ran — shed either at
    /// admission (arrived expired) or at batch dequeue (aged out in
    /// queue). Like `shed`, these never count toward `requests`.
    pub fn record_deadline_miss(&self) {
        self.deadline_miss.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests shed because their deadline expired.
    pub fn deadline_miss(&self) -> u64 {
        self.deadline_miss.load(Ordering::Relaxed)
    }

    /// `n` client-side retries were spent against this model (mirrored
    /// into the server table via [`crate::net::Client::bind_metrics`]).
    pub fn record_retries(&self, n: u64) {
        self.retries.fetch_add(n, Ordering::Relaxed);
    }

    /// Client-side retries recorded against this model.
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// A hedged second attempt beat the primary request.
    pub fn record_hedge_won(&self) {
        self.hedges_won.fetch_add(1, Ordering::Relaxed);
    }

    /// Hedged attempts that won the race against the primary.
    pub fn hedges_won(&self) -> u64 {
        self.hedges_won.load(Ordering::Relaxed)
    }

    /// A per-request compute panic was caught and converted into a
    /// typed error while the batch's siblings completed.
    pub fn record_panic_recovered(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Compute panics caught and isolated so far.
    pub fn panics_recovered(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    /// Backoff hint for [`crate::api::DynamapError::Overloaded`],
    /// milliseconds: one EWMA'd batch-mean latency rounded up, clamped
    /// to ≥ 1 ms. Falls back to 2 ms before the first batch completes
    /// (cold server, nothing measured yet).
    pub fn suggest_retry_ms(&self) -> u64 {
        let ewma = self.ewma_us.load(Ordering::Relaxed);
        if ewma == 0 {
            return 2;
        }
        (ewma as f64 / 1000.0).ceil().max(1.0) as u64
    }

    /// Point-in-time copy of the end-to-end latency histogram — the
    /// full bucket set behind the snapshot percentiles, exported on the
    /// wire `Stats` frame and mergeable across models.
    pub fn latency_histogram(&self) -> LogHistogram {
        self.lock().latency.clone()
    }

    /// A batch of `size` requests was flushed to the backend.
    pub fn record_batch(&self, size: usize) {
        let mut inner = self.lock();
        inner.batches += 1;
        *inner.batch_hist.entry(size).or_insert(0) += 1;
    }

    /// One request completed successfully after `e2e_us` microseconds
    /// end to end (queue wait included).
    pub fn record_request(&self, e2e_us: f64) {
        self.record_requests(&[e2e_us]);
    }

    /// A batch of requests completed; one end-to-end latency sample per
    /// request, recorded under a single lock acquisition (this is what
    /// the flush path calls, keeping the mutex off the per-request hot
    /// path). Each sample is one O(1) histogram bucket increment — no
    /// buffer to slide, and the request counter stays exact forever.
    pub fn record_requests(&self, e2e_us: &[f64]) {
        if e2e_us.is_empty() {
            return;
        }
        let mut inner = self.lock();
        inner.requests += e2e_us.len() as u64;
        for &us in e2e_us {
            inner.latency.record(us);
        }
        drop(inner);
        // exact SLO attainment: compare each served sample against the
        // target outside the lock (target and counter are atomics)
        let target = self.slo_target_us.load(Ordering::Relaxed);
        if target > 0 {
            let misses = e2e_us.iter().filter(|&&us| us > target as f64).count();
            if misses > 0 {
                self.slo_miss.fetch_add(misses as u64, Ordering::Relaxed);
            }
        }
        // blend the batch mean into the retry-hint EWMA (¾ old + ¼ new);
        // a lock-free store is fine — the hint is advisory, and a lost
        // race between two flushes loses one blend step, not the value
        let mean = e2e_us.iter().sum::<f64>() / e2e_us.len() as f64;
        let old = self.ewma_us.load(Ordering::Relaxed);
        let new = if old == 0 { mean } else { old as f64 * 0.75 + mean * 0.25 };
        self.ewma_us.store(new.max(1.0) as u64, Ordering::Relaxed);
    }

    /// `n` requests failed (backend error or shutdown mid-flight).
    pub fn record_errors(&self, n: usize) {
        self.lock().errors += n as u64;
    }

    /// Point-in-time copy of every counter, with percentiles resolved
    /// from the log-bucketed histogram — O(buckets) per snapshot, no
    /// sort and no sample-window copy, so a `stats` report can never
    /// stall the flush path behind allocation-heavy work.
    pub fn snapshot(&self) -> ModelSnapshot {
        let inner = self.lock();
        let elapsed = self.started.elapsed().as_secs_f64();
        let served = inner.requests;
        let tail = inner.latency.percentiles(&[50.0, 95.0, 99.0, 99.9]);
        ModelSnapshot {
            model: self.model.clone(),
            requests: served,
            errors: inner.errors,
            shed: self.shed(),
            deadline_miss: self.deadline_miss(),
            retries: self.retries(),
            hedges_won: self.hedges_won(),
            panics_recovered: self.panics_recovered(),
            batches: inner.batches,
            qps: if elapsed > 0.0 { served as f64 / elapsed } else { 0.0 },
            mean_batch: if inner.batches > 0 {
                let total: u64 =
                    inner.batch_hist.iter().map(|(size, n)| *size as u64 * n).sum();
                total as f64 / inner.batches as f64
            } else {
                0.0
            },
            mean_us: inner.latency.mean(),
            p50_us: tail[0],
            p95_us: tail[1],
            p99_us: tail[2],
            p999_us: tail[3],
            queue_depth: self.queue_depth(),
            max_queue_depth: self.max_depth.load(Ordering::Relaxed),
            swaps: self.swaps(),
            slo_target_us: self.slo_target_us(),
            slo_miss: self.slo_miss(),
            deferrals: self.deferrals(),
            batch_hist: inner.batch_hist.clone(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Immutable point-in-time view of one model's [`ModelMetrics`].
#[derive(Debug, Clone)]
pub struct ModelSnapshot {
    /// Model name.
    pub model: String,
    /// Successfully served requests.
    pub requests: u64,
    /// Failed requests.
    pub errors: u64,
    /// Requests shed by admission control (never entered the queue).
    pub shed: u64,
    /// Requests shed because their deadline expired before compute.
    pub deadline_miss: u64,
    /// Client-side retries mirrored into the server table.
    pub retries: u64,
    /// Hedged attempts that won the race against the primary.
    pub hedges_won: u64,
    /// Per-request compute panics caught and isolated.
    pub panics_recovered: u64,
    /// Batches flushed to the backend.
    pub batches: u64,
    /// Served requests per second since the metrics were created.
    pub qps: f64,
    /// Mean flushed batch size.
    pub mean_batch: f64,
    /// Exact mean end-to-end latency, µs, over the model's lifetime.
    pub mean_us: f64,
    /// Median end-to-end latency, µs, from the log-bucketed histogram
    /// (within [`LogHistogram::MAX_RELATIVE_ERROR`] of exact).
    pub p50_us: f64,
    /// 95th-percentile end-to-end latency, µs (histogram).
    pub p95_us: f64,
    /// 99th-percentile end-to-end latency, µs (histogram).
    pub p99_us: f64,
    /// 99.9th-percentile end-to-end latency, µs (histogram).
    pub p999_us: f64,
    /// Requests waiting in the queue at snapshot time.
    pub queue_depth: usize,
    /// High-water queue depth since the metrics were created.
    pub max_queue_depth: usize,
    /// Plan hot-swaps applied by the tune loop.
    pub swaps: usize,
    /// SLO latency target, µs (`0` = no SLO configured).
    pub slo_target_us: u64,
    /// Served requests that exceeded the SLO target.
    pub slo_miss: u64,
    /// Best-effort flushes deferred under high-priority pressure.
    pub deferrals: u64,
    /// Flushed batch size → number of batches of that size.
    pub batch_hist: BTreeMap<usize, u64>,
}

impl ModelSnapshot {
    /// Fraction of served requests that met the SLO target, percent —
    /// `None` when no SLO is configured or nothing was served yet.
    /// Shed/deadline-shed requests never ran, so they are accounted in
    /// their own columns, not here.
    pub fn slo_attainment_pct(&self) -> Option<f64> {
        if self.slo_target_us == 0 || self.requests == 0 {
            return None;
        }
        Some(100.0 * (1.0 - self.slo_miss as f64 / self.requests as f64))
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let slo = match self.slo_attainment_pct() {
            Some(pct) => format!(
                "  slo {}ms att {pct:.1}% ({} miss)",
                self.slo_target_us / 1000,
                self.slo_miss
            ),
            None if self.slo_target_us > 0 => {
                format!("  slo {}ms att -", self.slo_target_us / 1000)
            }
            None => String::new(),
        };
        format!(
            "{}: {} req ({} err, {} shed, {} dl-miss) {:.1} qps  e2e mean={:.0}µs \
             p50={:.0}µs p95={:.0}µs p99={:.0}µs p99.9={:.0}µs  {} batches (mean \
             {:.2}, hist {})  max depth {}  swaps {}  retries {}  hedges won {}  \
             panics {}  deferrals {}{slo}",
            self.model,
            self.requests,
            self.errors,
            self.shed,
            self.deadline_miss,
            self.qps,
            self.mean_us,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.p999_us,
            self.batches,
            self.mean_batch,
            self.hist_summary(),
            self.max_queue_depth,
            self.swaps,
            self.retries,
            self.hedges_won,
            self.panics_recovered,
            self.deferrals
        )
    }

    /// Compact `size×count` rendering of the batch-size histogram.
    pub fn hist_summary(&self) -> String {
        if self.batch_hist.is_empty() {
            return "-".into();
        }
        self.batch_hist
            .iter()
            .map(|(size, n)| format!("{size}×{n}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Serialize every snapshot field (batch histogram as
    /// `[size, count]` pairs). The wire `Stats` frame pairs this with
    /// the full latency histogram — see [`ServerMetrics::to_json`].
    pub fn to_json(&self) -> Json {
        let batch_hist = self
            .batch_hist
            .iter()
            .map(|(size, n)| Json::arr(vec![Json::Num(*size as f64), Json::Num(*n as f64)]))
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("requests", Json::Num(self.requests as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("deadline_miss", Json::Num(self.deadline_miss as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("hedges_won", Json::Num(self.hedges_won as f64)),
            ("panics_recovered", Json::Num(self.panics_recovered as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("qps", Json::Num(self.qps)),
            ("mean_batch", Json::Num(self.mean_batch)),
            ("mean_us", Json::Num(self.mean_us)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p95_us", Json::Num(self.p95_us)),
            ("p99_us", Json::Num(self.p99_us)),
            ("p999_us", Json::Num(self.p999_us)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("max_queue_depth", Json::Num(self.max_queue_depth as f64)),
            ("swaps", Json::Num(self.swaps as f64)),
            ("slo_target_us", Json::Num(self.slo_target_us as f64)),
            ("slo_miss", Json::Num(self.slo_miss as f64)),
            ("deferrals", Json::Num(self.deferrals as f64)),
            ("batch_hist", Json::Arr(batch_hist)),
        ])
    }
}

/// Registry-wide telemetry: one [`ModelMetrics`] per hosted model,
/// created on first touch and kept across LRU evictions so the report
/// covers the server's whole lifetime.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    models: Mutex<BTreeMap<String, Arc<ModelMetrics>>>,
}

impl ServerMetrics {
    /// Empty metrics set.
    pub fn new() -> ServerMetrics {
        ServerMetrics::default()
    }

    /// Telemetry handle for `model`, created on first use.
    pub fn model(&self, model: &str) -> Arc<ModelMetrics> {
        let mut models = self.models.lock().unwrap_or_else(|p| p.into_inner());
        models
            .entry(model.to_string())
            .or_insert_with(|| Arc::new(ModelMetrics::new(model)))
            .clone()
    }

    /// Snapshots of every model, sorted by model name.
    pub fn snapshots(&self) -> Vec<ModelSnapshot> {
        let models = self.models.lock().unwrap_or_else(|p| p.into_inner());
        models.values().map(|m| m.snapshot()).collect()
    }

    /// ASCII table over all models: QPS, tail latency, batching and
    /// queue-depth columns.
    pub fn report(&self) -> String {
        let mut t = Table::new(
            "serving metrics",
            &[
                "model", "req", "err", "shed", "dl miss", "qps", "mean µs", "p50 µs",
                "p95 µs", "p99 µs", "p99.9 µs", "batches", "mean b", "depth max",
                "swaps", "retries", "hedged", "panics", "slo ms", "slo p99 µs",
                "miss %", "defer", "batch hist",
            ],
        );
        for s in self.snapshots() {
            // per-tenant SLO columns: target, attained p99 (only shown
            // when a target exists, so SLO-free models stay visually
            // quiet) and exact miss rate
            let (slo_ms, slo_p99, miss_pct) = if s.slo_target_us > 0 {
                (
                    format!("{:.0}", s.slo_target_us as f64 / 1000.0),
                    format!("{:.0}", s.p99_us),
                    s.slo_attainment_pct()
                        .map(|a| format!("{:.1}", 100.0 - a))
                        .unwrap_or_else(|| "-".into()),
                )
            } else {
                ("-".into(), "-".into(), "-".into())
            };
            t.row(vec![
                s.model.clone(),
                s.requests.to_string(),
                s.errors.to_string(),
                s.shed.to_string(),
                s.deadline_miss.to_string(),
                format!("{:.1}", s.qps),
                format!("{:.0}", s.mean_us),
                format!("{:.0}", s.p50_us),
                format!("{:.0}", s.p95_us),
                format!("{:.0}", s.p99_us),
                format!("{:.0}", s.p999_us),
                s.batches.to_string(),
                format!("{:.2}", s.mean_batch),
                s.max_queue_depth.to_string(),
                s.swaps.to_string(),
                s.retries.to_string(),
                s.hedges_won.to_string(),
                s.panics_recovered.to_string(),
                slo_ms,
                slo_p99,
                miss_pct,
                s.deferrals.to_string(),
                s.hist_summary(),
            ]);
        }
        t.render()
    }

    /// The wire `Stats` frame body: every model's snapshot fields plus
    /// its full latency-histogram buckets, so a remote scraper
    /// (`dynamap stats --connect`, the benches) reads the same numbers
    /// the in-process report prints — and can re-derive any quantile
    /// via [`LogHistogram::from_json`].
    pub fn to_json(&self) -> Json {
        let models = self.models.lock().unwrap_or_else(|p| p.into_inner());
        let entries = models
            .values()
            .map(|m| {
                let mut entry = match m.snapshot().to_json() {
                    Json::Obj(o) => o,
                    _ => unreachable!("snapshot serializes as an object"),
                };
                entry.insert("latency_hist".to_string(), m.latency_histogram().to_json());
                Json::Obj(entry)
            })
            .collect::<Vec<_>>();
        Json::obj(vec![("models", Json::Arr(entries))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = ModelMetrics::new("mini");
        for _ in 0..3 {
            m.enqueued();
        }
        assert_eq!(m.queue_depth(), 3);
        for _ in 0..3 {
            m.dequeued();
        }
        m.record_batch(3);
        for us in [100.0, 200.0, 300.0] {
            m.record_request(us);
        }
        m.record_errors(1);
        m.record_swap();
        m.record_shed();
        m.record_shed();
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.errors, 1);
        assert_eq!(s.shed, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.max_queue_depth, 3);
        assert_eq!(s.swaps, 1);
        assert_eq!(s.mean_batch, 3.0);
        // p50 of [100, 200, 300] is 200 exactly up to the histogram's
        // documented bucket error
        assert!(
            (s.p50_us - 200.0).abs() / 200.0 <= LogHistogram::MAX_RELATIVE_ERROR,
            "p50 {} outside the documented error of 200",
            s.p50_us
        );
        assert!(s.p99_us >= s.p50_us);
        assert!(s.p999_us >= s.p99_us);
        assert!(s.qps > 0.0);
        assert_eq!(s.batch_hist.get(&3), Some(&1));
        assert!(s.summary().contains("mini"));
        assert!(s.summary().contains("2 shed"), "{}", s.summary());
    }

    #[test]
    fn reliability_counters_land_in_snapshot_and_report() {
        let m = ModelMetrics::new("rel");
        m.record_deadline_miss();
        m.record_deadline_miss();
        m.record_retries(5);
        m.record_hedge_won();
        m.record_panic_recovered();
        let s = m.snapshot();
        assert_eq!(s.deadline_miss, 2);
        assert_eq!(s.retries, 5);
        assert_eq!(s.hedges_won, 1);
        assert_eq!(s.panics_recovered, 1);
        assert!(s.summary().contains("2 dl-miss"), "{}", s.summary());
        assert!(s.summary().contains("retries 5"), "{}", s.summary());

        let sm = ServerMetrics::new();
        sm.model("rel").record_deadline_miss();
        let report = sm.report();
        assert!(report.contains("dl miss"), "{report}");
        assert!(report.contains("retries"), "{report}");
    }

    #[test]
    fn retry_hint_tracks_batch_latency() {
        let m = ModelMetrics::new("hint");
        // cold server: conservative fallback, never zero
        assert_eq!(m.suggest_retry_ms(), 2);
        m.record_requests(&[8000.0, 8000.0]); // 8 ms mean
        let hint = m.suggest_retry_ms();
        assert!((1..=9).contains(&hint), "hint {hint} ≈ one batch latency");
        // EWMA converges toward a sustained latency shift
        for _ in 0..32 {
            m.record_requests(&[40_000.0]);
        }
        let hint = m.suggest_retry_ms();
        assert!((20..=41).contains(&hint), "hint {hint} follows the 40 ms regime");
        // empty flush is a no-op, not a divide-by-zero
        m.record_requests(&[]);
        assert_eq!(m.snapshot().requests, 34);
    }

    #[test]
    fn histogram_accumulates_per_size() {
        let m = ModelMetrics::new("m");
        for size in [1, 4, 4, 8] {
            m.record_batch(size);
        }
        let s = m.snapshot();
        assert_eq!(s.batches, 4);
        assert_eq!(s.batch_hist.get(&4), Some(&2));
        assert_eq!(s.hist_summary(), "1×1 4×2 8×1");
        // mean batch = (1 + 4 + 4 + 8) / 4
        assert!((s.mean_batch - 4.25).abs() < 1e-12);
    }

    #[test]
    fn latency_accounting_stays_bounded_and_exact() {
        let m = ModelMetrics::new("w");
        let chunk: Vec<f64> = (0..4096).map(|i| i as f64).collect();
        for _ in 0..40 {
            m.record_requests(&chunk);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 40 * 4096, "exact request count");
        assert_eq!(
            m.latency_histogram().count(),
            40 * 4096,
            "the histogram covers every sample at constant memory — \
             nothing slides out of a window"
        );
        // the mean is tracked exactly alongside the buckets
        assert!((s.mean_us - 2047.5).abs() < 1e-9, "mean {}", s.mean_us);
        assert!(s.p99_us >= s.p50_us);
    }

    #[test]
    fn snapshot_percentiles_agree_with_exact_sort() {
        // seed-99 heavy-tailed latencies through the real recording
        // path: snapshot percentiles must stay within the histogram's
        // documented bucket error of a full sort of the same samples
        let mut rng = crate::util::rng::Rng::new(99);
        let m = ModelMetrics::new("agree");
        let mut samples = Vec::new();
        for _ in 0..64 {
            let batch: Vec<f64> =
                (0..1024).map(|_| 50.0 * 10f64.powf(rng.f64() * 2.5)).collect();
            samples.extend_from_slice(&batch);
            m.record_requests(&batch);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let s = m.snapshot();
        for (p, got) in [(50.0, s.p50_us), (99.0, s.p99_us), (99.9, s.p999_us)] {
            let rank = ((p / 100.0) * (samples.len() - 1) as f64).round() as usize;
            let exact = samples[rank];
            let rel = (got - exact).abs() / exact;
            assert!(
                rel <= LogHistogram::MAX_RELATIVE_ERROR,
                "p{p}: snapshot {got} vs exact {exact} — relative error {rel:.4}"
            );
        }
    }

    #[test]
    fn stats_json_carries_counters_and_histogram() {
        let sm = ServerMetrics::new();
        let m = sm.model("mini");
        m.record_batch(2);
        m.record_requests(&[100.0, 300.0]);
        m.record_errors(1);
        let doc = Json::parse(&sm.to_json().to_string()).expect("stats JSON parses");
        let entry = doc.get("models").at(0);
        assert_eq!(entry.get("model").as_str(), Some("mini"));
        assert_eq!(entry.get("requests").as_u64(), Some(2));
        assert_eq!(entry.get("errors").as_u64(), Some(1));
        assert_eq!(entry.get("batches").as_u64(), Some(1));
        assert_eq!(entry.get("batch_hist").at(0).at(0).as_u64(), Some(2));
        // the embedded histogram re-derives the same quantiles
        let hist = LogHistogram::from_json(entry.get("latency_hist"))
            .expect("latency_hist round-trips");
        assert_eq!(hist.count(), 2);
        assert_eq!(hist.quantile(50.0), m.latency_histogram().quantile(50.0));
        assert_eq!(entry.get("mean_us").as_f64(), Some(200.0));
    }

    #[test]
    fn slo_attainment_is_exact_and_exported() {
        let m = ModelMetrics::new("slo");
        // no target: attainment undefined, summary silent
        m.record_requests(&[100.0]);
        assert_eq!(m.snapshot().slo_attainment_pct(), None);
        assert!(!m.snapshot().summary().contains("slo"), "{}", m.snapshot().summary());

        m.set_slo_target_us(25_000);
        assert_eq!(m.slo_target_us(), 25_000);
        // 3 under target, 1 over: misses counted exactly, not bucketed
        m.record_requests(&[1_000.0, 24_999.0, 25_001.0, 2_000.0]);
        assert_eq!(m.slo_miss(), 1);
        let s = m.snapshot();
        assert_eq!(s.slo_target_us, 25_000);
        assert_eq!(s.slo_miss, 1);
        // 5 served total (1 pre-target), 1 miss → 80% attainment
        assert!((s.slo_attainment_pct().unwrap() - 80.0).abs() < 1e-9);
        assert!(s.summary().contains("slo 25ms att 80.0% (1 miss)"), "{}", s.summary());

        m.record_deferral();
        m.record_deferral();
        assert_eq!(m.deferrals(), 2);
        assert!(m.snapshot().summary().contains("deferrals 2"));
    }

    #[test]
    fn slo_columns_land_in_report_and_stats_json() {
        let sm = ServerMetrics::new();
        let hi = sm.model("hi");
        hi.set_slo_target_us(10_000);
        hi.record_requests(&[5_000.0, 15_000.0]);
        sm.model("bulk").record_requests(&[50_000.0]);
        let report = sm.report();
        assert!(report.contains("slo ms"), "{report}");
        assert!(report.contains("slo p99 µs"), "{report}");
        assert!(report.contains("miss %"), "{report}");
        assert!(report.contains("defer"), "{report}");
        assert!(report.contains("50.0"), "hi misses half: {report}");

        let doc = Json::parse(&sm.to_json().to_string()).expect("stats JSON parses");
        let entry = doc.get("models").at(1); // BTreeMap order: bulk, hi
        assert_eq!(entry.get("model").as_str(), Some("hi"));
        assert_eq!(entry.get("slo_target_us").as_u64(), Some(10_000));
        assert_eq!(entry.get("slo_miss").as_u64(), Some(1));
        assert_eq!(entry.get("deferrals").as_u64(), Some(0));
        let bulk = doc.get("models").at(0);
        assert_eq!(bulk.get("slo_target_us").as_u64(), Some(0));
    }

    #[test]
    fn server_metrics_shares_handles() {
        let sm = ServerMetrics::new();
        let a = sm.model("x");
        let b = sm.model("x");
        a.record_request(10.0);
        assert_eq!(b.snapshot().requests, 1, "same Arc behind the same name");
        sm.model("y").record_request(5.0);
        let snaps = sm.snapshots();
        assert_eq!(snaps.len(), 2);
        assert!(sm.report().contains('x') && sm.report().contains('y'));
    }
}
