//! [`ModelRegistry`] — named, lazily compiled, LRU-evicted model hosts.
//!
//! The registry is the front door of the serving engine: callers name a
//! zoo model ("mini", "googlenet", …) and get back a [`ModelHost`]
//! whose [`crate::serve::BatchQueue`] they can submit to. Hosting is
//! lazy — the first request for a model resolves its artifacts
//! (synthesizing a manifest + seeded random weights when permitted and
//! none exist), builds a native-backend [`Session`] (hitting the shared
//! on-disk [`crate::api::PlanCache`] so the DSE runs at most once per
//! `(model, device, config)` across all hosts and process restarts),
//! splits off its [`NativeState`] and spawns the model's batch
//! scheduler. Beyond `capacity` resident models the least-recently-used
//! host is evicted: its queue drains and shuts down, and the next
//! request for that model rebuilds it (from the plan cache — no DSE).
//!
//! Each host's state lives in a [`StateCell`], so the `tune` subsystem
//! can hot-swap a re-mapped plan into a live host
//! ([`ModelRegistry::swap_state`]) without dropping a request.
//!
//! Artifact layout: `<artifacts_root>/<canonical model name>/manifest.json`
//! plus per-layer weight files, exactly the contract
//! [`crate::runtime::Manifest`] defines for AOT artifacts.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::api::session::NativeState;
use crate::api::{Backend, Compiler, DynamapError, InferMetrics, Session};
use crate::graph::layer::Op;
use crate::graph::{zoo, Cnn};
use crate::runtime::TensorBuf;
use crate::tune::profiler::LayerProfile;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::metrics::{ModelMetrics, ServerMetrics};
use super::queue::{BatchConfig, BatchQueue};
use super::sched::{partition_threads, ModelSlo, QueuePolicy, SchedCoordinator, SloTable, Tenant};

/// Configuration for [`ModelRegistry`].
#[derive(Debug, Clone)]
pub struct RegistryConfig {
    /// Root directory; each model's artifacts live at
    /// `<artifacts_root>/<canonical name>/`.
    pub artifacts_root: PathBuf,
    /// Shared on-disk plan cache for every hosted model (`None`
    /// compiles a fresh plan per session build).
    pub plan_cache: Option<PathBuf>,
    /// Maximum resident models; `0` means unbounded. The
    /// least-recently-used host is evicted first.
    pub capacity: usize,
    /// When a zoo model has no artifacts on disk, synthesize a manifest
    /// with seeded random weights instead of failing (demo/benchmark
    /// substrate; real deployments point `artifacts_root` at AOT
    /// output).
    pub synthesize_missing: bool,
    /// Seed for synthesized weights.
    pub seed: u64,
    /// Compiler used for lazy plan compilation; also keys the shared
    /// plan cache.
    pub compiler: Compiler,
    /// Batch scheduler configuration applied to every model queue.
    pub batch: BatchConfig,
    /// Per-model admission budget: at most this many requests may be
    /// in flight (queued or being served) per host; excess submits are
    /// shed with the retriable [`DynamapError::Overloaded`] instead of
    /// growing the queue unboundedly. `0` means unbounded (the
    /// pre-admission-control behavior; fine for in-process callers,
    /// the network front-end should set a budget).
    pub max_inflight: usize,
    /// Attach a [`LayerProfile`] to every host so the serving path
    /// records per-layer latency — the evidence `tune::calibrate`
    /// fits. Off by default (`serve --tune` and the adaptive bench
    /// turn it on); attaching a profiler never changes outputs.
    pub profile: bool,
    /// Per-model SLOs keyed by model name (zoo aliases accepted).
    /// An empty table — the default — disables multi-tenant scheduling
    /// entirely: no pressure coordination, no thread partitioning, no
    /// flush deferral, bit-for-bit the single-tenant behavior. A
    /// non-empty table makes every hosted model a tenant: models
    /// missing from the table serve under [`ModelSlo::default`].
    pub slos: SloTable,
}

impl Default for RegistryConfig {
    fn default() -> RegistryConfig {
        RegistryConfig {
            artifacts_root: PathBuf::from("serve-models"),
            plan_cache: None,
            capacity: 4,
            synthesize_missing: true,
            seed: 0x5EED,
            compiler: Compiler::new(),
            batch: BatchConfig::default(),
            max_inflight: 0,
            profile: false,
            slos: SloTable::new(),
        }
    }
}

/// The hot-swappable serving state of one hosted model: an epoch
/// counter plus the current `Arc<NativeState>` behind a read-write
/// lock.
///
/// Readers ([`crate::serve::BatchQueue`]'s scheduler) take the read
/// lock once per *flush* — never per request — clone the `Arc` and
/// serve the whole batch from that snapshot, so a concurrent
/// [`StateCell::swap`] can never split a batch across plans: batches
/// in flight finish on the state they started with, later batches pick
/// up the new one. Because only the algorithm map (and its prepared
/// weight form) differs between swapped states, every request is
/// bitwise-identical to a sequential `Session::infer` under whichever
/// plan served it.
#[derive(Debug)]
pub struct StateCell {
    state: RwLock<Arc<NativeState>>,
    epoch: AtomicU64,
}

impl StateCell {
    /// A cell at epoch 0 holding `state`.
    pub fn new(state: Arc<NativeState>) -> StateCell {
        StateCell { state: RwLock::new(state), epoch: AtomicU64::new(0) }
    }

    /// Snapshot the current state (one read-lock + `Arc` clone).
    pub fn get(&self) -> Arc<NativeState> {
        self.state.read().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Atomically publish `state`, returning the previous one. Bumps
    /// the epoch after the new state is visible.
    pub fn swap(&self, state: Arc<NativeState>) -> Arc<NativeState> {
        let old = {
            let mut slot = self.state.write().unwrap_or_else(|p| p.into_inner());
            std::mem::replace(&mut *slot, state)
        };
        self.epoch.fetch_add(1, Ordering::Release);
        old
    }

    /// How many swaps this cell has seen.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }
}

/// One resident model: its hot-swappable serving state, batch queue
/// and telemetry.
pub struct ModelHost {
    model: String,
    cell: Arc<StateCell>,
    input: (usize, usize, usize),
    queue: BatchQueue,
    metrics: Arc<ModelMetrics>,
    plan_from_cache: bool,
    profile: Option<Arc<LayerProfile>>,
    plan_shape: Mutex<Option<(usize, usize)>>,
    /// Requests currently admitted (queued or being served).
    inflight: AtomicUsize,
    /// Admission budget ([`RegistryConfig::max_inflight`]; 0 = unbounded).
    max_inflight: usize,
    /// This tenant's SLO (the default when the registry has no table
    /// entry for the model).
    slo: ModelSlo,
    /// Live thread-partition budget, written by
    /// [`ModelRegistry::repartition`] and read by the batch scheduler
    /// at every flush (`0` = uncapped).
    threads: Arc<AtomicUsize>,
}

/// RAII guard for one slot of a host's bounded in-flight budget;
/// releases the slot when dropped — on reply *and* on every error path.
struct AdmissionPermit<'a> {
    inflight: &'a AtomicUsize,
}

impl Drop for AdmissionPermit<'_> {
    fn drop(&mut self) {
        self.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

impl ModelHost {
    /// Canonical model name.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Snapshot of the request-invariant serving state currently
    /// backing the queue (the *current* plan — a later
    /// [`ModelRegistry::swap_state`] does not retroactively change the
    /// returned `Arc`).
    pub fn state(&self) -> Arc<NativeState> {
        self.cell.get()
    }

    /// The hot-swappable state slot shared with the batch scheduler.
    pub fn state_cell(&self) -> &Arc<StateCell> {
        &self.cell
    }

    /// How many plan hot-swaps this host has served.
    pub fn epoch(&self) -> u64 {
        self.cell.epoch()
    }

    /// The per-layer latency profile recorded by this host's serving
    /// path (`None` unless [`RegistryConfig::profile`] is set).
    pub fn profile(&self) -> Option<&Arc<LayerProfile>> {
        self.profile.as_ref()
    }

    /// `P_SA1 × P_SA2` shape of the plan currently served (`None` for
    /// hosts built from — or hot-swapped to — an explicit algorithm
    /// map).
    pub fn plan_shape(&self) -> Option<(usize, usize)> {
        *self.plan_shape.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Telemetry for this model (shared with [`ServerMetrics`]).
    pub fn metrics(&self) -> &Arc<ModelMetrics> {
        &self.metrics
    }

    /// `true` when the host's plan came from the shared cache (no DSE
    /// ran while building it).
    pub fn plan_from_cache(&self) -> bool {
        self.plan_from_cache
    }

    /// Input dimensions `(C, H1, H2)` this model expects (invariant
    /// across hot swaps — a swap changes algorithms, never the model).
    pub fn input_dims(&self) -> (usize, usize, usize) {
        self.input
    }

    /// Requests currently in flight (admitted but not yet replied to).
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Admission budget this host enforces (0 = unbounded).
    pub fn max_inflight(&self) -> usize {
        self.max_inflight
    }

    /// This tenant's SLO.
    pub fn slo(&self) -> ModelSlo {
        self.slo
    }

    /// The tenant's current thread-partition budget (`0` = uncapped —
    /// the value before the first [`ModelRegistry::repartition`], and
    /// always for SLO-free registries).
    pub fn thread_budget(&self) -> usize {
        self.threads.load(Ordering::Relaxed)
    }

    /// Submit one request to the model's batch queue and block for the
    /// result. Fails with [`DynamapError::QueueClosed`] after the host
    /// has been evicted — [`ModelRegistry::infer`] handles that by
    /// re-resolving the host — and with the retriable
    /// [`DynamapError::Overloaded`] when the in-flight budget
    /// ([`RegistryConfig::max_inflight`]) is exhausted; shed requests
    /// never enter the queue.
    pub fn infer(&self, input: TensorBuf) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        self.infer_with_deadline(input, None)
    }

    /// [`ModelHost::infer`] with an optional absolute deadline.
    ///
    /// Ordering here is the permit-leak audit made explicit:
    ///
    /// 1. **Shape validation before admission** — a malformed request
    ///    must not consume a slot of the in-flight budget, even
    ///    transiently.
    /// 2. **Deadline check before admission** — a request that arrives
    ///    already expired is shed with
    ///    [`DynamapError::DeadlineExceeded`] without claiming a slot or
    ///    touching the queue.
    /// 3. Only then is the RAII [`AdmissionPermit`] claimed; it releases
    ///    on *every* exit from the queue submit — reply, typed error or
    ///    unwind — because release lives in `Drop`.
    pub fn infer_with_deadline(
        &self,
        input: TensorBuf,
        deadline: Option<std::time::Instant>,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        self.infer_traced(input, deadline, None)
    }

    /// [`ModelHost::infer_with_deadline`] carrying the request's
    /// span-correlation id ([`crate::obs::TraceId`]): when a recorder
    /// is installed, the validate → admit front door is recorded as a
    /// [`crate::obs::Stage::Admission`] span and the id rides through
    /// the queue into the per-layer spans of the request's compute.
    pub fn infer_traced(
        &self,
        input: TensorBuf,
        deadline: Option<std::time::Instant>,
        trace: Option<crate::obs::TraceId>,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        let recorder = crate::obs::active();
        let t_admit = std::time::Instant::now();
        self.queue.validate_input(&input)?;
        if let Some(d) = deadline {
            if std::time::Instant::now() >= d {
                self.metrics.record_deadline_miss();
                return Err(DynamapError::DeadlineExceeded {
                    model: self.model.clone(),
                    waited_ms: 0,
                });
            }
        }
        let _permit = self.try_admit()?;
        if let Some(rec) = &recorder {
            // admission span: shape + deadline validation and the
            // permit claim; shed requests never get here, so a trace
            // with an admission span was genuinely admitted
            rec.record_span(
                trace,
                crate::obs::Stage::Admission,
                &self.model,
                t_admit,
                std::time::Instant::now(),
                vec![],
            );
        }
        self.queue.infer_traced(input, deadline, trace)
    }

    /// `true` when this host's batch scheduler died while the queue was
    /// still open (a wedged queue: every submit would fail forever).
    pub fn is_wedged(&self) -> bool {
        self.queue.is_wedged()
    }

    /// Claim one in-flight slot or shed the request. The counter is
    /// bumped first and rolled back on rejection, so two racing submits
    /// can at worst *both* be shed (conservative), never both admitted
    /// over budget.
    fn try_admit(&self) -> Result<AdmissionPermit<'_>, DynamapError> {
        let prev = self.inflight.fetch_add(1, Ordering::SeqCst);
        if self.max_inflight > 0 && prev >= self.max_inflight {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            self.metrics.record_shed();
            return Err(DynamapError::Overloaded {
                model: self.model.clone(),
                retry_after_ms: self.metrics.suggest_retry_ms(),
            });
        }
        Ok(AdmissionPermit { inflight: &self.inflight })
    }

    fn shutdown(&self) {
        self.queue.shutdown();
    }
}

/// The multi-model registry: lazy hosting, shared plan cache, LRU
/// eviction, per-model batching.
pub struct ModelRegistry {
    config: RegistryConfig,
    metrics: Arc<ServerMetrics>,
    /// Resident hosts in LRU → MRU order.
    resident: Mutex<Vec<(String, Arc<ModelHost>)>>,
    /// Serializes session builds (and artifact synthesis) so two
    /// first-requests for the same model never race a half-written
    /// manifest or duplicate an expensive compile.
    build_lock: Mutex<()>,
    loads: AtomicUsize,
    /// Pressure gauge shared by every hosted tenant's batch scheduler
    /// (only wired into queues when [`RegistryConfig::slos`] is
    /// non-empty).
    coordinator: Arc<SchedCoordinator>,
}

impl ModelRegistry {
    /// An empty registry; models are hosted on first request.
    pub fn new(config: RegistryConfig) -> ModelRegistry {
        ModelRegistry {
            config,
            metrics: Arc::new(ServerMetrics::new()),
            resident: Mutex::new(Vec::new()),
            build_lock: Mutex::new(()),
            loads: AtomicUsize::new(0),
            coordinator: Arc::new(SchedCoordinator::new()),
        }
    }

    /// The registry-wide SLO pressure gauge (inert unless
    /// [`RegistryConfig::slos`] is non-empty).
    pub fn coordinator(&self) -> &Arc<SchedCoordinator> {
        &self.coordinator
    }

    /// The configuration the registry was built with.
    pub fn config(&self) -> &RegistryConfig {
        &self.config
    }

    /// Registry-wide telemetry (survives evictions).
    pub fn metrics(&self) -> &Arc<ServerMetrics> {
        &self.metrics
    }

    /// How many sessions this registry has built — a probe for LRU
    /// tests: an eviction followed by a re-request increments this, a
    /// resident hit does not.
    pub fn loads(&self) -> usize {
        self.loads.load(Ordering::Relaxed)
    }

    /// Canonical names of the currently resident models, least recently
    /// used first.
    pub fn resident(&self) -> Vec<String> {
        self.lock_resident().iter().map(|(n, _)| n.clone()).collect()
    }

    /// Look up a resident host *without* refreshing its LRU recency or
    /// hosting on a miss. This is the observation path for the tune
    /// loop and `stats` reporting: a background tick over every
    /// resident model must not promote idle models over ones real
    /// traffic is keeping warm.
    pub fn peek(&self, model: &str) -> Option<Arc<ModelHost>> {
        let canonical = zoo::canonical_name(model)?;
        let resident = self.lock_resident();
        resident.iter().find(|(n, _)| n == canonical).map(|(_, h)| h.clone())
    }

    /// Resolve (and if necessary host) `model`, refreshing its recency.
    /// Accepts any zoo alias ("mini" == "mini-inception"). The resident
    /// hit path is cheap (name canonicalization + one short lock); the
    /// model graph is only built on a hosting miss.
    pub fn host(&self, model: &str) -> Result<Arc<ModelHost>, DynamapError> {
        let canonical = zoo::canonical_name(model)
            .ok_or_else(|| DynamapError::UnknownModel(model.to_string()))?;
        if let Some(host) = self.lookup_refresh(canonical) {
            return Ok(host);
        }
        // build under the build lock; re-check residency first because
        // another thread may have hosted the model while we waited
        let build_guard = self.build_lock.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(host) = self.lookup_refresh(canonical) {
            return Ok(host);
        }
        let cnn = zoo::by_name(canonical)
            .ok_or_else(|| DynamapError::UnknownModel(canonical.to_string()))?;
        let host = Arc::new(self.build_host(&cnn, canonical)?);
        self.loads.fetch_add(1, Ordering::Relaxed);
        let evicted = {
            let mut resident = self.lock_resident();
            resident.push((canonical.to_string(), host.clone()));
            let mut evicted = Vec::new();
            if self.config.capacity > 0 {
                while resident.len() > self.config.capacity {
                    evicted.push(resident.remove(0).1);
                }
            }
            evicted
        };
        // the new host is published; release both locks before joining
        // evicted schedulers — draining another model's in-flight batch
        // must block neither resident lookups nor unrelated cold starts
        drop(build_guard);
        for old in evicted {
            old.shutdown();
        }
        // the tenant set changed: rebalance every resident budget
        if !self.config.slos.is_empty() {
            self.repartition();
        }
        Ok(host)
    }

    /// Serve one request through `model`'s batch queue, hosting the
    /// model first if needed. A host evicted between lookup and submit
    /// is transparently re-resolved. [`DynamapError::Overloaded`] is
    /// *not* retried here — admission control's whole point is to push
    /// backoff to the caller, so the shed propagates with its
    /// `retry_after_ms` hint intact.
    pub fn infer(
        &self,
        model: &str,
        input: &TensorBuf,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        self.infer_with_deadline(model, input, None)
    }

    /// [`ModelRegistry::infer`] with an optional absolute deadline.
    ///
    /// Besides deadline threading, this is where wedged-queue recovery
    /// lives: when a submit fails with [`DynamapError::QueueClosed`]
    /// but the host's scheduler thread is *dead* rather than evicted
    /// (it panicked — e.g. the chaos harness's `SchedulerPanic` site),
    /// the poisoned host is evicted and the retry re-hosts the model
    /// from the plan cache instead of propagating the poison forever.
    pub fn infer_with_deadline(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<std::time::Instant>,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        self.infer_traced(model, input, deadline, None)
    }

    /// [`ModelRegistry::infer_with_deadline`] carrying the request's
    /// span-correlation id ([`crate::obs::TraceId`]) down through the
    /// host's admission, queue and per-layer spans. The network server
    /// threads the protocol-v3 trailer's id through here.
    pub fn infer_traced(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<std::time::Instant>,
        trace: Option<crate::obs::TraceId>,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        for _ in 0..3 {
            let host = self.host(model)?;
            match host.infer_traced(input.clone(), deadline, trace) {
                Err(DynamapError::QueueClosed { .. }) => {
                    self.evict_if_wedged(&host);
                    continue;
                }
                result => return result,
            }
        }
        Err(DynamapError::Serve(format!(
            "model '{model}' kept being evicted mid-request"
        )))
    }

    /// Evict `host` iff it is still the resident entry for its model
    /// *and* its scheduler is wedged (dead thread behind an open
    /// queue). The `Arc::ptr_eq` guard makes the race with a concurrent
    /// re-host benign: a freshly built healthy host is never evicted on
    /// the strength of its poisoned predecessor's failure.
    fn evict_if_wedged(&self, host: &Arc<ModelHost>) {
        if !host.is_wedged() {
            return;
        }
        let removed = {
            let mut resident = self.lock_resident();
            match resident
                .iter()
                .position(|(n, h)| n == host.model() && Arc::ptr_eq(h, host))
            {
                Some(pos) => Some(resident.remove(pos).1),
                None => None,
            }
        };
        if let Some(h) = removed {
            h.shutdown();
        }
    }

    /// Sum of every resident host's in-flight count. Used by the
    /// permit-leak audit: after a drain (or any test), this must be 0 —
    /// a nonzero value means an error path returned without releasing
    /// its [`AdmissionPermit`].
    pub fn inflight_total(&self) -> usize {
        self.lock_resident().iter().map(|(_, h)| h.inflight()).sum()
    }

    /// Assert the permit-leak invariant: no admitted request is still
    /// holding a slot. Call after a drain or at the end of a test.
    pub fn assert_quiesced(&self) {
        let total = self.inflight_total();
        assert_eq!(
            total, 0,
            "admission-permit leak: {total} in-flight slots still held after drain"
        );
    }

    /// Atomically hot-swap `model`'s serving state (the `tune::remap`
    /// publish step). The new state must serve the same model and
    /// input shape — only the algorithm map (and its prepared-weight
    /// form) may differ; keeping the underlying weights identical is
    /// the caller's contract (`tune::remap` rebuilds from the host's
    /// own artifact directory). Batches already flushed keep the state
    /// they started with; every later batch reads the new one. Does
    /// not refresh LRU recency — a background remap of an idle model
    /// must not shield it from eviction. Returns the new swap epoch.
    /// `plan_shape` becomes the host's new [`ModelHost::plan_shape`]
    /// verbatim: `Some` for a compiled plan, `None` for an explicit
    /// algorithm map (whose state corresponds to no array shape).
    pub fn swap_state(
        &self,
        model: &str,
        state: Arc<NativeState>,
        plan_shape: Option<(usize, usize)>,
    ) -> Result<u64, DynamapError> {
        let canonical = zoo::canonical_name(model)
            .ok_or_else(|| DynamapError::UnknownModel(model.to_string()))?;
        let host = self.peek(canonical).ok_or_else(|| {
            DynamapError::Serve(format!(
                "cannot swap plan for '{canonical}': model is not resident"
            ))
        })?;
        if state.model() != canonical {
            return Err(DynamapError::Serve(format!(
                "plan swap for '{canonical}' carries state for model '{}'",
                state.model()
            )));
        }
        if state.input_dims() != host.input_dims() {
            return Err(DynamapError::Serve(format!(
                "plan swap for '{canonical}' changes the input shape \
                 ({:?} → {:?})",
                host.input_dims(),
                state.input_dims()
            )));
        }
        let old = host.cell.swap(state);
        drop(old); // in-flight batches keep their own Arc clones
        // overwrite unconditionally: keeping a stale shape would price
        // later tune-loop observations against a plan no longer served
        *host.plan_shape.lock().unwrap_or_else(|p| p.into_inner()) = plan_shape;
        host.metrics.record_swap();
        Ok(host.cell.epoch())
    }

    /// Evict `model` now (no-op when it is not resident). Returns
    /// whether a host was evicted. The next request re-hosts it.
    pub fn evict(&self, model: &str) -> bool {
        let Some(canonical) = zoo::canonical_name(model) else {
            return false;
        };
        let host = {
            let mut resident = self.lock_resident();
            match resident.iter().position(|(n, _)| n == canonical) {
                Some(pos) => Some(resident.remove(pos).1),
                None => None,
            }
        };
        match host {
            Some(h) => {
                h.shutdown();
                if !self.config.slos.is_empty() {
                    self.repartition();
                }
                true
            }
            None => false,
        }
    }

    /// Drain and shut down every resident host. The registry stays
    /// usable: later requests re-host lazily.
    pub fn shutdown(&self) {
        let hosts: Vec<_> = self.lock_resident().drain(..).collect();
        for (_, host) in hosts {
            host.shutdown();
        }
    }

    /// Recompute every resident tenant's thread budget from the SLO
    /// table and current measured demand (`qps + queue depth`, clamped
    /// to ≥ 1 so an idle tenant still weighs its priority), and publish
    /// the budgets into each host's live atomic — the batch schedulers
    /// pick them up at their next flush without any coordination.
    /// Returns the budgets by model name. Runs automatically whenever
    /// the tenant set changes (host / evict); callers with fresher
    /// demand signals (the serve CLI's stats tick, tests) may re-run it
    /// any time — the computation is pure given its inputs, so
    /// re-running with unchanged inputs is idempotent.
    pub fn repartition(&self) -> std::collections::BTreeMap<String, usize> {
        let total = crate::util::parallel::worker_count(usize::MAX);
        let hosts: Vec<(String, Arc<ModelHost>)> = self.lock_resident().clone();
        let tenants: Vec<Tenant> = hosts
            .iter()
            .map(|(name, host)| {
                let snap = host.metrics.snapshot();
                Tenant {
                    model: name.clone(),
                    priority: host.slo.priority,
                    demand: (snap.qps + snap.queue_depth as f64).max(1.0),
                }
            })
            .collect();
        let budgets = partition_threads(total, &tenants);
        for (name, host) in &hosts {
            if let Some(budget) = budgets.get(name) {
                host.threads.store(*budget, Ordering::Relaxed);
            }
        }
        budgets
    }

    /// Re-solve every resident tenant's plan *under its thread
    /// partition*: a tenant owning `b` of the host's `t` threads sees
    /// per-layer latencies stretched by `t / b`, so its DSE re-runs
    /// with [`crate::cost::DeviceCalibration::scaled`]`(t / b)` — which
    /// changes the compiler fingerprint, so the shared plan cache keys
    /// one entry per (model, partition) and a repeat resolve is
    /// DSE-free. The re-solved state is published through the ordinary
    /// [`ModelRegistry::swap_state`] hot-swap path (same model, same
    /// weights, same input shape; only the algorithm map may differ),
    /// so in-flight batches finish on their plan and replies stay
    /// bitwise-correct throughout. Tenants owning the full host (or
    /// not yet partitioned) are skipped — their hosting-time plan
    /// already assumed every thread. Returns how many tenants were
    /// re-solved.
    pub fn resolve_partition_plans(&self) -> Result<usize, DynamapError> {
        let total = crate::util::parallel::worker_count(usize::MAX);
        let hosts: Vec<(String, Arc<ModelHost>)> = self.lock_resident().clone();
        let mut swapped = 0;
        for (name, host) in hosts {
            let budget = host.thread_budget();
            if budget == 0 || budget >= total {
                continue;
            }
            let factor = total as f64 / budget as f64;
            let calibration =
                self.config.compiler.config().calibration.clone().scaled(factor);
            let compiler = self.config.compiler.clone().calibration(calibration);
            let dir = self.config.artifacts_root.join(&name);
            let mut builder = Session::builder(dir.to_string_lossy().into_owned())
                .backend(Backend::Native)
                .compiler(compiler);
            if let Some(cache) = &self.config.plan_cache {
                builder = builder.plan_cache(cache);
            }
            let session = builder.build()?;
            let plan_shape = session.plan().map(|a| (a.plan.p1, a.plan.p2));
            let state = session.native_state().ok_or_else(|| {
                DynamapError::Serve("native session produced no shareable state".into())
            })?;
            self.swap_state(&name, state, plan_shape)?;
            swapped += 1;
        }
        Ok(swapped)
    }

    /// The SLO for `canonical`, resolving zoo aliases in the table's
    /// keys ("mini" configures "mini-inception").
    fn slo_for(&self, canonical: &str) -> ModelSlo {
        for (name, slo) in &self.config.slos {
            if zoo::canonical_name(name) == Some(canonical) || name.as_str() == canonical {
                return *slo;
            }
        }
        ModelSlo::default()
    }

    fn lock_resident(&self) -> std::sync::MutexGuard<'_, Vec<(String, Arc<ModelHost>)>> {
        self.resident.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Resident hit: move to the MRU end and return the host.
    fn lookup_refresh(&self, canonical: &str) -> Option<Arc<ModelHost>> {
        let mut resident = self.lock_resident();
        let pos = resident.iter().position(|(n, _)| n == canonical)?;
        let entry = resident.remove(pos);
        let host = entry.1.clone();
        resident.push(entry);
        Some(host)
    }

    /// Resolve artifacts, build the session, split its native state and
    /// spawn the batch scheduler.
    fn build_host(&self, cnn: &Cnn, canonical: &str) -> Result<ModelHost, DynamapError> {
        let dir = self.config.artifacts_root.join(canonical);
        // chaos hook: a hosting attempt whose artifact I/O fails must
        // surface a typed error and leave the registry healthy — the
        // next request simply retries the build
        crate::fault::io_error_if(crate::fault::Site::ArtifactIo, &dir.to_string_lossy())
            .map_err(|e| DynamapError::io(&dir, e))?;
        if !dir.join("manifest.json").exists() {
            if self.config.synthesize_missing {
                synthesize_artifacts(cnn, &dir, self.config.seed)?;
            } else {
                return Err(DynamapError::Serve(format!(
                    "no artifacts for model '{canonical}' under {} \
                     (synthesize_missing is off)",
                    dir.display()
                )));
            }
        }
        let mut builder = Session::builder(dir.to_string_lossy().into_owned())
            .backend(Backend::Native)
            .compiler(self.config.compiler.clone());
        if let Some(cache) = &self.config.plan_cache {
            builder = builder.plan_cache(cache);
        }
        let profile = self
            .config
            .profile
            .then(|| Arc::new(LayerProfile::new(canonical)));
        if let Some(profile) = &profile {
            builder = builder.profiler(profile.clone());
        }
        let session = builder.build()?;
        let plan_from_cache = session.plan_from_cache();
        let plan_shape = session.plan().map(|a| (a.plan.p1, a.plan.p2));
        let state = session.native_state().ok_or_else(|| {
            DynamapError::Serve("native session produced no shareable state".into())
        })?;
        let input = state.input_dims();
        let metrics = self.metrics.model(canonical);
        let cell = Arc::new(StateCell::new(state));
        // tenant wiring: resolve the SLO, expose the target to the
        // metrics (attainment counting starts with the first request)
        // and hand the scheduler its policy — with the shared pressure
        // gauge only when the registry actually has tenants, so
        // SLO-free registries keep the exact single-tenant scheduler
        let slo = self.slo_for(canonical);
        metrics.set_slo_target_us(slo.target_us());
        let threads = Arc::new(AtomicUsize::new(0));
        let policy = QueuePolicy {
            slo,
            coordinator: (!self.config.slos.is_empty()).then(|| self.coordinator.clone()),
            threads: threads.clone(),
        };
        let queue = BatchQueue::with_policy(
            cell.clone(),
            self.config.batch.clone(),
            metrics.clone(),
            policy,
        );
        Ok(ModelHost {
            model: canonical.to_string(),
            cell,
            input,
            queue,
            metrics,
            plan_from_cache,
            profile,
            plan_shape: Mutex::new(plan_shape),
            inflight: AtomicUsize::new(0),
            max_inflight: self.config.max_inflight,
            slo,
            threads,
        })
    }
}

/// Write a synthetic artifact set for `cnn` into `dir`: a
/// [`crate::runtime::Manifest`]-conformant `manifest.json` with empty
/// `algos` maps (native serving needs no HLO) and one seeded random
/// weight file per conv/FC layer, He-scaled so activations stay bounded
/// through deep networks.
///
/// This is the registry's missing-artifact fallback and the substrate
/// for the serving tests and benches; it deliberately produces the same
/// bytes for the same `(cnn, seed)` so runs are reproducible.
pub fn synthesize_artifacts(cnn: &Cnn, dir: &Path, seed: u64) -> Result<(), DynamapError> {
    std::fs::create_dir_all(dir).map_err(|e| DynamapError::io(dir, e))?;
    let mut input = None;
    for node in &cnn.nodes {
        if let Op::Input { c, h1, h2 } = &node.op {
            input = Some((*c, *h1, *h2));
        }
    }
    let (c, h1, h2) = input
        .ok_or_else(|| DynamapError::Graph(format!("model '{}' has no input node", cnn.name)))?;
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for node in &cnn.nodes {
        // (c_in, c_out, h1, h2, k1, k2, s, p1, p2, o1, o2)
        let (dims, count) = match &node.op {
            Op::Conv(spec) => (
                (
                    spec.c_in, spec.c_out, spec.h1, spec.h2, spec.k1, spec.k2, spec.s,
                    spec.p1, spec.p2, spec.o1(), spec.o2(),
                ),
                spec.weight_count(),
            ),
            // an FC layer is a 1×1 conv over the flattened activation —
            // see `NativeState::infer` — so the manifest carries it in
            // the same layer schema
            Op::Fc { c_in, c_out } => {
                ((*c_in, *c_out, 1, 1, 1, 1, 1, 0, 0, 1, 1), c_in * c_out)
            }
            _ => continue,
        };
        let (ci, co, lh1, lh2, k1, k2, s, p1, p2, o1, o2) = dims;
        let scale = (2.0 / (ci * k1 * k2) as f32).sqrt();
        let safe: String = node
            .name
            .chars()
            .map(|ch| if ch.is_ascii_alphanumeric() || ch == '-' || ch == '.' { ch } else { '_' })
            .collect();
        let wfile = format!("w__{safe}.bin");
        let mut bytes = Vec::with_capacity(count * 4);
        for _ in 0..count {
            bytes.extend_from_slice(&rng.f32_range(-scale, scale).to_le_bytes());
        }
        let wpath = dir.join(&wfile);
        std::fs::write(&wpath, bytes).map_err(|e| DynamapError::io(&wpath, e))?;
        layers.push(Json::obj(vec![
            ("name", Json::str(node.name.clone())),
            ("c_in", Json::num(ci as f64)),
            ("c_out", Json::num(co as f64)),
            ("h1", Json::num(lh1 as f64)),
            ("h2", Json::num(lh2 as f64)),
            ("k1", Json::num(k1 as f64)),
            ("k2", Json::num(k2 as f64)),
            ("s", Json::num(s as f64)),
            ("p1", Json::num(p1 as f64)),
            ("p2", Json::num(p2 as f64)),
            ("o1", Json::num(o1 as f64)),
            ("o2", Json::num(o2 as f64)),
            ("algos", Json::obj(vec![])),
            ("weights", Json::str(wfile)),
            ("weight_count", Json::num(count as f64)),
        ]));
    }
    let manifest = Json::obj(vec![
        ("model", Json::str(cnn.name.clone())),
        (
            "input",
            Json::obj(vec![
                ("c", Json::num(c as f64)),
                ("h1", Json::num(h1 as f64)),
                ("h2", Json::num(h2 as f64)),
            ]),
        ),
        ("layers", Json::Arr(layers)),
        ("golden_input", Json::str("")),
        ("golden_output", Json::str("")),
    ]);
    let mpath = dir.join("manifest.json");
    std::fs::write(&mpath, manifest.pretty()).map_err(|e| DynamapError::io(&mpath, e))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_model_is_typed() {
        let reg = ModelRegistry::new(RegistryConfig::default());
        let e = reg.host("not-a-model").unwrap_err();
        assert!(matches!(e, DynamapError::UnknownModel(_)), "{e}");
        assert!(!reg.evict("not-a-model"));
        let e = reg
            .swap_state("not-a-model", dummy_state(), None)
            .unwrap_err();
        assert!(matches!(e, DynamapError::UnknownModel(_)), "{e}");
        // known model, but not resident: typed serve error, no panic
        let e = reg.swap_state("mini", dummy_state(), None).unwrap_err();
        assert!(matches!(e, DynamapError::Serve(_)), "{e}");
    }

    /// A NativeState for StateCell unit tests, built through the
    /// synthetic-artifact path (no DSE: explicit algorithm map). Each
    /// call gets its own directory so concurrently running tests never
    /// race a half-written manifest.
    fn dummy_state() -> Arc<NativeState> {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let cnn = zoo::mini_inception();
        let dir = std::env::temp_dir().join(format!(
            "dynamap_cell_state_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        synthesize_artifacts(&cnn, &dir, 3).unwrap();
        let map: std::collections::BTreeMap<String, String> = cnn
            .nodes
            .iter()
            .filter(|n| n.op.is_conv())
            .map(|n| (n.name.clone(), "im2col".to_string()))
            .collect();
        let session = Session::builder(dir.to_string_lossy().into_owned())
            .backend(Backend::Native)
            .algo_map(map)
            .build()
            .unwrap();
        session.native_state().unwrap()
    }

    #[test]
    fn state_cell_swap_publishes_and_counts_epochs() {
        let a = dummy_state();
        let b = dummy_state();
        let cell = StateCell::new(a.clone());
        assert_eq!(cell.epoch(), 0);
        assert!(Arc::ptr_eq(&cell.get(), &a));
        let old = cell.swap(b.clone());
        assert!(Arc::ptr_eq(&old, &a), "swap returns the displaced state");
        assert!(Arc::ptr_eq(&cell.get(), &b));
        assert_eq!(cell.epoch(), 1);
        // a snapshot taken before a swap keeps serving the old plan
        let snapshot = cell.get();
        cell.swap(a);
        assert!(Arc::ptr_eq(&snapshot, &b));
        assert_eq!(cell.epoch(), 2);
    }

    #[test]
    fn synthesized_manifest_round_trips() {
        let cnn = zoo::mini_inception();
        let dir = std::env::temp_dir()
            .join(format!("dynamap_synth_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        synthesize_artifacts(&cnn, &dir, 7).unwrap();
        let m = crate::runtime::Manifest::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.model, "mini-inception");
        assert_eq!(m.input, (4, 16, 16));
        assert_eq!(m.layers.len(), 7);
        for l in &m.layers {
            let w = m.weights(l).unwrap();
            assert_eq!(w.len(), l.weight_count);
            assert!(w.iter().all(|v| v.is_finite()));
        }
        // same seed, same bytes: synthesis is reproducible
        let dir2 = std::env::temp_dir()
            .join(format!("dynamap_synth2_{}", std::process::id()));
        std::fs::remove_dir_all(&dir2).ok();
        synthesize_artifacts(&cnn, &dir2, 7).unwrap();
        let a = std::fs::read(dir.join("manifest.json")).unwrap();
        let b = std::fs::read(dir2.join("manifest.json")).unwrap();
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }
}
