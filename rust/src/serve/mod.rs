//! Multi-model serving engine: registry + dynamic batching + metrics,
//! layered on top of [`crate::api::Session`].
//!
//! DYNAMAP's staged pipeline ends with a session that serves one model
//! to one caller at a time. This module opens the many-users,
//! many-models deployment the ROADMAP asks for (the multi-CNN scenario
//! of f-CNNx, arxiv 1805.10174, with the tail-latency accounting
//! surveyed in arxiv 2505.13461), without an async runtime — std
//! channels and threads only:
//!
//! * [`ModelRegistry`] hosts named sessions for any zoo model: lazy
//!   compilation on first request, one shared on-disk
//!   [`crate::api::PlanCache`] across all models, LRU eviction beyond a
//!   configurable capacity, and synthetic artifact generation
//!   ([`synthesize_artifacts`]) when a model has no AOT output yet.
//! * [`BatchQueue`] converts concurrent single-request callers into
//!   batched [`crate::api::NativeState::infer_batch`] calls: flush at
//!   `max_batch` requests or after `max_wait`, whichever comes first.
//!   The flush fans compute out over the scoped-thread pool in
//!   [`crate::util::parallel`], and batching is invisible to callers —
//!   outputs are bitwise-identical to sequential
//!   [`crate::api::Session::infer`].
//! * [`ServerMetrics`] tracks per-model QPS, queue depth, batch-size
//!   histograms, shed-request accounting and p50/p95/p99/p99.9
//!   end-to-end latency — percentiles from an O(1)-record log-bucketed
//!   [`crate::obs::LogHistogram`] per model, exported whole over the
//!   wire `Stats` frame.
//! * Admission control: [`RegistryConfig::max_inflight`] bounds each
//!   host's in-flight requests; excess submits are shed with the
//!   retriable [`crate::api::DynamapError::Overloaded`] (carrying a
//!   measured `retry_after_ms` hint) instead of queueing unboundedly —
//!   the backpressure story behind the TCP front-end in [`crate::net`].
//!   Requests may also carry a deadline
//!   ([`ModelRegistry::infer_with_deadline`]): expired requests are
//!   shed with the typed
//!   [`crate::api::DynamapError::DeadlineExceeded`] *before* they
//!   claim an admission permit or a batch slot, and re-checked at
//!   flush time so a request that expired waiting never burns compute.
//! * Panic isolation: a request that panics inside compute is caught
//!   at the batch boundary and answered with a typed `Serve` error
//!   while its batch siblings complete normally; a wedged queue (dead
//!   scheduler) is detected and the model re-hosted on the next
//!   request. Counters for all of it (`deadline_miss`, `retries`,
//!   `hedges_won`, `panics_recovered`) land in [`ServerMetrics`].
//! * [`loadgen`] is the seeded measurement harness behind
//!   `dynamap loadgen` and the benches: closed-loop ([`loadgen::run`])
//!   for throughput, open-loop seeded-Poisson ([`loadgen::open_loop`])
//!   for overload and coordinated-omission-safe tail latency.
//! * [`StateCell`] holds each host's serving state behind an
//!   epoch-counted `Arc` swap, so the online adaptation loop in
//!   [`crate::tune`] can hot-swap a re-mapped plan into a live model
//!   ([`ModelRegistry::swap_state`]) without dropping, duplicating or
//!   corrupting a single reply.
//! * [`sched`] makes co-hosted models *tenants*: per-model SLOs
//!   ([`RegistryConfig::slos`]), a deterministic thread-budget
//!   partitioner over `priority × demand`, per-partition plan
//!   re-solves through the fingerprint-keyed plan cache
//!   ([`ModelRegistry::resolve_partition_plans`]), and priority-aware
//!   flushes — best-effort batches defer (bounded, never dropped)
//!   while a high-priority tenant's queue delay threatens its SLO.
//!   Attainment (target / attained p99 / miss count) lands in
//!   [`ServerMetrics`] and the wire `Stats` frame;
//!   [`loadgen::open_loop_mixed`] drives seeded multi-tenant traffic
//!   against it.
//!
//! ```no_run
//! use dynamap::serve::{ModelRegistry, RegistryConfig};
//!
//! let registry = ModelRegistry::new(RegistryConfig::default());
//! let host = registry.host("mini")?; // lazily compiled + queued
//! let (c, h1, h2) = host.input_dims();
//! let input = dynamap::runtime::TensorBuf::zeros(vec![c, h1, h2]);
//! let (output, metrics) = registry.infer("mini", &input)?;
//! println!("{:?} in {:.0}µs", output.shape, metrics.total_us);
//! println!("{}", registry.metrics().report());
//! # Ok::<(), dynamap::api::DynamapError>(())
//! ```
#![warn(missing_docs)]
#![deny(clippy::correctness, clippy::suspicious)]

pub mod cli;
pub mod loadgen;
pub mod metrics;
pub mod queue;
pub mod registry;
pub mod sched;

pub use loadgen::{
    open_loop, open_loop_mixed, tenant_seed, InferTarget, LoadReport, LoadgenConfig,
    MixedConfig, MixedReport, OpenLoopConfig, OpenLoopReport, TenantLoad, TenantReport,
};
pub use metrics::{ModelMetrics, ModelSnapshot, ServerMetrics};
pub use queue::{BatchConfig, BatchQueue};
pub use registry::{
    synthesize_artifacts, ModelHost, ModelRegistry, RegistryConfig, StateCell,
};
pub use sched::{
    partition_threads, ModelSlo, QueuePolicy, SchedCoordinator, SloTable, Tenant,
};
