//! Fixed log-bucketed latency histograms.
//!
//! [`LogHistogram`] replaces the sort-over-sample-window percentile
//! path in `serve::metrics`: recording is O(1) (one bucket increment,
//! no allocation), snapshots are O(buckets), and two histograms merge
//! bucket-wise so per-model views can be aggregated server-wide. The
//! price is bounded, documented quantile error: buckets grow
//! geometrically at `2^(1/8)` per bucket (8 buckets per octave), so a
//! reported quantile is at most a factor `2^(1/16)` away from the true
//! sample — a relative error of at most
//! [`LogHistogram::MAX_RELATIVE_ERROR`] ≈ 4.4%.
//!
//! The value domain is microseconds (the unit every latency path in the
//! crate already uses): 256 buckets × 8 per octave cover `[1 µs, 2³² µs)`
//! ≈ 71 minutes; values below 1 µs clamp into the first bucket and
//! values past the top clamp into the last, so `record` is total.

use crate::util::json::Json;

/// Number of buckets per octave (factor-of-two span of the domain).
const PER_OCTAVE: u32 = 8;

/// Total bucket count: 32 octaves × 8 = `[2⁰, 2³²)` microseconds.
const BUCKETS: usize = 256;

/// A fixed-size log-bucketed histogram over microsecond samples.
///
/// O(1) [`record`](LogHistogram::record), O(buckets) quantiles,
/// bucket-wise [`merge`](LogHistogram::merge); exact `count`/`sum`/
/// `min`/`max` are tracked alongside the buckets so the mean and the
/// extremes carry no bucketing error at all.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> LogHistogram {
        LogHistogram::new()
    }
}

impl LogHistogram {
    /// Worst-case relative error of any reported quantile versus the
    /// exact sorted-sample quantile: half a bucket in log space,
    /// `2^(1/16) − 1` ≈ 0.0443.
    pub const MAX_RELATIVE_ERROR: f64 = 0.044_3;

    /// An empty histogram.
    pub fn new() -> LogHistogram {
        LogHistogram {
            counts: [0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bucket index for a microsecond value: `floor(8·log2(v))`,
    /// clamped into `[0, BUCKETS)`. Values ≤ 1 µs (and non-finite or
    /// negative garbage) land in bucket 0.
    fn bucket(v: f64) -> usize {
        if !v.is_finite() || v <= 1.0 {
            return 0;
        }
        let idx = (v.log2() * PER_OCTAVE as f64).floor();
        if idx < 0.0 {
            0
        } else {
            (idx as usize).min(BUCKETS - 1)
        }
    }

    /// Geometric midpoint of bucket `i` — the value reported for any
    /// sample that landed there: `2^((i + 0.5) / 8)` µs.
    fn representative(i: usize) -> f64 {
        ((i as f64 + 0.5) / PER_OCTAVE as f64).exp2()
    }

    /// Record one sample (microseconds). O(1), allocation-free.
    pub fn record(&mut self, us: f64) {
        self.counts[Self::bucket(us)] += 1;
        self.count += 1;
        if us.is_finite() {
            self.sum += us;
            self.min = self.min.min(us);
            self.max = self.max.max(us);
        }
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact mean of all recorded samples; `0.0` when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact smallest recorded sample; `0.0` when empty.
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact largest recorded sample; `0.0` when empty.
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Nearest-rank quantile under the same convention as
    /// `coordinator::metrics::LatencyStats`: `p` in `[0, 100]` maps to
    /// rank `round(p/100 · (n−1))` in the (implicitly sorted) sample
    /// set, resolved to the containing bucket's geometric midpoint and
    /// clamped into `[min, max]` so degenerate distributions (one
    /// bucket, one sample) report exactly. Empty histograms yield
    /// `0.0`; `p` outside `[0, 100]` clamps to min/max.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 100.0) / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Self::representative(i).clamp(self.min, self.max);
            }
        }
        self.max()
    }

    /// Several quantiles in one pass-per-quantile; mirrors
    /// `LatencyStats::percentiles`.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        ps.iter().map(|&p| self.quantile(p)).collect()
    }

    /// Fold `other` into `self` bucket-wise. Merging preserves every
    /// quantile's error bound because both sides share the same fixed
    /// bucket boundaries.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.count > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }

    /// Serialize for the wire `Stats` frame: exact summary fields plus
    /// the sparse non-zero buckets as `[index, count]` pairs.
    pub fn to_json(&self) -> Json {
        let buckets = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, c)| **c > 0)
            .map(|(i, c)| Json::arr(vec![Json::Num(i as f64), Json::Num(*c as f64)]))
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum)),
            ("min", Json::Num(self.min())),
            ("max", Json::Num(self.max())),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    /// Rebuild from [`LogHistogram::to_json`] output. Returns `None` on
    /// a malformed value (missing fields, out-of-range bucket index).
    pub fn from_json(v: &Json) -> Option<LogHistogram> {
        let mut h = LogHistogram::new();
        h.count = v.get("count").as_u64()?;
        h.sum = v.get("sum").as_f64()?;
        if h.count > 0 {
            h.min = v.get("min").as_f64()?;
            h.max = v.get("max").as_f64()?;
        }
        for pair in v.get("buckets").as_arr()? {
            let i = pair.at(0).as_usize()?;
            let c = pair.at(1).as_u64()?;
            if i >= BUCKETS {
                return None;
            }
            h.counts[i] = c;
        }
        Some(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn empty_is_total() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.quantile(50.0), 0.0);
    }

    #[test]
    fn single_sample_is_every_quantile() {
        let mut h = LogHistogram::new();
        h.record(42.0);
        for p in [0.0, 25.0, 50.0, 99.9, 100.0] {
            assert_eq!(h.quantile(p), 42.0, "p={p} clamps to the exact sample");
        }
        assert_eq!(h.mean(), 42.0);
    }

    #[test]
    fn clamps_below_and_above_domain() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(-5.0);
        h.record(1e40);
        assert_eq!(h.count(), 3);
        // quantiles stay inside [min, max] even for clamped samples
        assert!(h.quantile(0.0) >= -5.0 && h.quantile(100.0) <= 1e40);
    }

    #[test]
    fn quantiles_within_documented_error_of_exact_sort() {
        // seed-99 log-uniform samples spanning ~5 decades: the shape
        // that stresses geometric bucketing hardest
        let mut rng = Rng::new(99);
        let mut h = LogHistogram::new();
        let mut samples = Vec::new();
        for _ in 0..50_000 {
            let v = 10f64.powf(rng.f64() * 5.0); // 1 µs .. 100 ms
            samples.push(v);
            h.record(v);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            let rank = ((p / 100.0) * (samples.len() - 1) as f64).round() as usize;
            let exact = samples[rank];
            let approx = h.quantile(p);
            let rel = (approx - exact).abs() / exact;
            assert!(
                rel <= LogHistogram::MAX_RELATIVE_ERROR,
                "p{p}: histogram {approx} vs exact {exact} — relative error \
                 {rel:.4} exceeds the documented bound"
            );
        }
    }

    #[test]
    fn merge_equals_recording_into_one() {
        let mut rng = Rng::new(7);
        let (mut a, mut b, mut whole) =
            (LogHistogram::new(), LogHistogram::new(), LogHistogram::new());
        for i in 0..2_000 {
            let v = 1.0 + rng.f64() * 10_000.0;
            whole.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        for p in [50.0, 95.0, 99.0] {
            assert_eq!(a.quantile(p), whole.quantile(p), "p={p}");
        }
    }

    #[test]
    fn json_round_trip() {
        let mut rng = Rng::new(3);
        let mut h = LogHistogram::new();
        for _ in 0..500 {
            h.record(1.0 + rng.f64() * 1e6);
        }
        let back = LogHistogram::from_json(&h.to_json()).expect("round trip");
        assert_eq!(back.count(), h.count());
        assert_eq!(back.min(), h.min());
        assert_eq!(back.max(), h.max());
        for p in [50.0, 99.0, 99.9] {
            assert_eq!(back.quantile(p), h.quantile(p), "p={p}");
        }
        // malformed inputs are rejected, not misread
        assert!(LogHistogram::from_json(&Json::Null).is_none());
        assert!(
            LogHistogram::from_json(&Json::parse(r#"{"count":1,"sum":2.0,"min":2.0,"max":2.0,"buckets":[[999,1]]}"#).unwrap())
                .is_none(),
            "out-of-range bucket index must be rejected"
        );
    }

    #[test]
    fn quantiles_are_monotone() {
        let mut rng = Rng::new(11);
        let mut h = LogHistogram::new();
        for _ in 0..10_000 {
            h.record(1.0 + rng.f64() * 1e5);
        }
        let ps = h.percentiles(&[0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0]);
        for w in ps.windows(2) {
            assert!(w[0] <= w[1], "quantiles must be monotone: {ps:?}");
        }
        assert_eq!(ps[0], h.min());
        assert_eq!(*ps.last().unwrap(), h.max());
    }
}
