//! Structured request tracing + histogram metrics export.
//!
//! DYNAMAP's thesis is that per-layer strategy choice (algorithm ×
//! precision × kernel) drives end-to-end latency — but aggregate
//! percentiles cannot say where *one* slow request spent its time.
//! This module closes that gap with evidence-grade spans threaded
//! through the whole request path:
//!
//! - **admission** — shape/deadline validation + admission-permit claim
//!   in [`crate::serve::ModelHost`];
//! - **queue** — enqueue → dequeue wait inside
//!   [`crate::serve::BatchQueue`];
//! - **flush** — one span per batch flush (tagged with batch size);
//! - **layer** — one span per conv/FC layer executed by
//!   [`crate::api::session::NativeState`], tagged with the layer name
//!   and the *live* plan's (algo, precision, kernel) choice;
//! - **measure** — microkernel timing runs in
//!   [`crate::kernels::KernelSelector::measure`].
//!
//! Requests are correlated by a [`TraceId`] — seeded and deterministic
//! under `loadgen` ([`TraceId::derive`]) — carried on the wire as the
//! optional protocol-v3 trailer (`net::protocol`). Spans land in a
//! bounded ring buffer ([`Recorder`]) and export as Chrome trace-event
//! JSON ([`chrome_trace`]), loadable in Perfetto / `chrome://tracing`.
//!
//! Design constraints, shared with [`crate::fault`]:
//!
//! - **Default-off and near-zero-cost when off.** Every instrumentation
//!   point compiles down to one relaxed atomic load
//!   ([`is_active`]) when no recorder is installed; tags are only
//!   materialized after that check passes. The serving bench prints the
//!   measured disabled-path overhead and `DYNAMAP_BENCH_ASSERT=1`
//!   gates it below 1%.
//! - **Bounded.** The ring holds at most its capacity; overflow drops
//!   the *oldest* span and bumps a counter — recording never blocks and
//!   never allocates beyond the span being stored.
//! - **Deterministic.** `TraceId::derive(seed, i)` is a pure SplitMix64
//!   mix, so a seeded loadgen run produces the same trace ids every
//!   time.
//!
//! The histogram half lives in [`hist`]: fixed log-bucketed
//! [`LogHistogram`] with O(1) record and a documented ≤ 4.4% quantile
//! error, replacing the sort-over-sample-window percentile path in
//! `serve::metrics`.

#![warn(missing_docs)]
#![deny(clippy::correctness, clippy::suspicious)]

pub mod hist;

pub use hist::LogHistogram;

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::util::json::Json;

/// Default ring capacity: enough for ~10k requests of a 6-layer model
/// without eviction, small enough (~100 B/span) to stay cheap.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Per-request trace correlation id, propagated over the wire as the
/// protocol-v3 trailer and stamped on every span the request produces.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(u64);

impl TraceId {
    /// Wrap a raw wire value.
    pub fn from_raw(raw: u64) -> TraceId {
        TraceId(raw)
    }

    /// The raw wire value.
    pub fn raw(self) -> u64 {
        self.0
    }

    /// Deterministically derive the id for request `index` of a seeded
    /// run: one SplitMix64 finalization of `seed ^ (index+1)·φ64` (the
    /// same mixer `fault::Injector` and `util::rng` use), remapped away
    /// from 0 so a derived id is never the all-zeroes value.
    pub fn derive(seed: u64, index: u64) -> TraceId {
        let z = splitmix64(seed ^ index.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        TraceId(if z == 0 { 1 } else { z })
    }
}

impl std::fmt::Display for TraceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// SplitMix64 finalizer (same constants as `fault::splitmix64`).
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Span taxonomy — where in the request path a span was recorded. The
/// stage doubles as the Chrome trace-event category (`cat`), so
/// Perfetto can filter per stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Shape/deadline validation + admission-permit claim
    /// (`serve::registry::ModelHost`).
    Admission,
    /// Enqueue → dequeue wait in the batch queue (`serve::queue`).
    Queue,
    /// One batch flush: dequeue of the batch through the last reply
    /// (`serve::queue`).
    Flush,
    /// One conv/FC layer executed under the live plan
    /// (`api::session::NativeState`).
    Layer,
    /// One microkernel timing run (`kernels::KernelSelector::measure`).
    Measure,
}

impl Stage {
    /// Stable lowercase name, used as the Chrome trace `cat` field.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::Queue => "queue",
            Stage::Flush => "flush",
            Stage::Layer => "layer",
            Stage::Measure => "measure",
        }
    }
}

/// One completed span: a named interval at a [`Stage`], optionally
/// correlated to a request [`TraceId`], with free-form tags (the layer
/// spans carry `algo` / `precision` / `kernel` from the live plan).
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Correlated request, `None` for request-independent spans
    /// (microkernel measurement).
    pub trace: Option<TraceId>,
    /// Where in the request path the span was recorded.
    pub stage: Stage,
    /// Human-readable span name (layer name, model name, kernel name).
    pub name: String,
    /// Start, microseconds since the recorder's epoch.
    pub start_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
    /// Key/value tags; keys are static so tagging never allocates for
    /// the key side.
    pub tags: Vec<(&'static str, String)>,
}

/// Bounded lock-cheap span sink.
///
/// One mutex-protected ring of [`SpanRecord`]s: `record_span` is a
/// short push under the lock (poison-tolerant, like every lock in the
/// serving stack); overflow pops the oldest span and bumps
/// [`Recorder::dropped`] instead of blocking or growing. All span
/// timestamps are measured against the recorder's construction instant
/// so exported traces start near `ts = 0`.
#[derive(Debug)]
pub struct Recorder {
    epoch: Instant,
    capacity: usize,
    spans: Mutex<VecDeque<SpanRecord>>,
    dropped: AtomicU64,
}

impl Recorder {
    /// A recorder holding at most `capacity` spans (clamped to ≥ 1).
    pub fn new(capacity: usize) -> Recorder {
        Recorder {
            epoch: Instant::now(),
            capacity: capacity.max(1),
            spans: Mutex::new(VecDeque::new()),
            dropped: AtomicU64::new(0),
        }
    }

    /// A recorder with [`DEFAULT_CAPACITY`].
    pub fn with_default_capacity() -> Recorder {
        Recorder::new(DEFAULT_CAPACITY)
    }

    /// Microseconds from the recorder's epoch to `t` (0 for instants
    /// before the epoch).
    fn us_since_epoch(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Record a completed `[start, end]` interval. Never blocks beyond
    /// the short ring lock; on a full ring the oldest span is dropped.
    pub fn record_span(
        &self,
        trace: Option<TraceId>,
        stage: Stage,
        name: &str,
        start: Instant,
        end: Instant,
        tags: Vec<(&'static str, String)>,
    ) {
        let start_us = self.us_since_epoch(start);
        let end_us = self.us_since_epoch(end);
        let record = SpanRecord {
            trace,
            stage,
            name: name.to_string(),
            start_us,
            dur_us: end_us.saturating_sub(start_us),
            tags,
        };
        let mut spans = self.spans.lock().unwrap_or_else(|p| p.into_inner());
        if spans.len() >= self.capacity {
            spans.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        spans.push_back(record);
    }

    /// Copy out the current ring contents, oldest first.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        self.spans
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .iter()
            .cloned()
            .collect()
    }

    /// Move out the current ring contents, oldest first, leaving the
    /// ring empty (the `TraceDump` wire frame's collect-then-fetch
    /// semantics: each dump returns the spans recorded since the last).
    pub fn drain(&self) -> Vec<SpanRecord> {
        self.spans
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .drain(..)
            .collect()
    }

    /// Spans currently buffered.
    pub fn len(&self) -> usize {
        self.spans.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// `true` when no spans are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum spans the ring holds before dropping the oldest.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// How many spans overflow has discarded since construction.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// Render spans as a Chrome trace-event JSON document (the
/// `{"traceEvents": [...]}` format Perfetto and `chrome://tracing`
/// load). Each span becomes one complete event (`ph: "X"`, timestamps
/// already in microseconds); events of the same request share a `tid`
/// (a compact per-trace index — the full id is in `args.trace`), so
/// Perfetto lays each request out on its own track. Untraced spans
/// (microkernel measurement) share track 0.
pub fn chrome_trace(spans: &[SpanRecord]) -> Json {
    let mut tids: BTreeMap<u64, usize> = BTreeMap::new();
    for s in spans {
        if let Some(t) = s.trace {
            let next = tids.len() + 1;
            tids.entry(t.raw()).or_insert(next);
        }
    }
    let events = spans
        .iter()
        .map(|s| {
            let mut args = vec![];
            if let Some(t) = s.trace {
                args.push(("trace", Json::str(t.to_string())));
            }
            for (k, v) in &s.tags {
                args.push((*k, Json::str(v.clone())));
            }
            let tid = s.trace.map(|t| tids[&t.raw()]).unwrap_or(0);
            Json::obj(vec![
                ("name", Json::str(s.name.clone())),
                ("cat", Json::str(s.stage.name())),
                ("ph", Json::str("X")),
                ("ts", Json::Num(s.start_us as f64)),
                ("dur", Json::Num(s.dur_us as f64)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(tid as f64)),
                ("args", Json::obj(args)),
            ])
        })
        .collect::<Vec<_>>();
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

/// Fast path: is *any* recorder installed? One relaxed load.
static ENABLED: AtomicBool = AtomicBool::new(false);
static ACTIVE: RwLock<Option<Arc<Recorder>>> = RwLock::new(None);

/// Install `recorder` process-wide, replacing any previous one. Every
/// instrumentation point starts recording into it.
pub fn install(recorder: Arc<Recorder>) {
    *ACTIVE.write().expect("obs registry lock") = Some(recorder);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Remove the installed recorder; every instrumentation point returns
/// to the one-relaxed-load no-op.
pub fn clear() {
    ENABLED.store(false, Ordering::SeqCst);
    *ACTIVE.write().expect("obs registry lock") = None;
}

/// Whether a recorder is currently installed — the check every
/// instrumentation point performs *before* materializing tags or
/// timestamps, so the disabled path costs one relaxed atomic load.
pub fn is_active() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The installed recorder, if any. Instrumentation points call this
/// once and only build span tags when it returns `Some`.
pub fn active() -> Option<Arc<Recorder>> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    ACTIVE.read().expect("obs registry lock").clone()
}

/// Install a recorder from the environment: `DYNAMAP_TRACE=1` turns
/// tracing on (any other value leaves it off), `DYNAMAP_TRACE_CAP`
/// overrides the ring capacity. Returns whether a recorder was
/// installed. Wired in `main.rs` next to the `DYNAMAP_FAULTS` hook.
pub fn install_from_env() -> bool {
    match std::env::var("DYNAMAP_TRACE") {
        Ok(v) if v == "1" => {}
        _ => return false,
    }
    let cap = std::env::var("DYNAMAP_TRACE_CAP")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_CAPACITY);
    install(Arc::new(Recorder::new(cap)));
    true
}

/// RAII installer for tests: installs a fresh recorder on construction,
/// clears on drop — including the unwind path, so a failing trace test
/// cannot leak its recorder into the next one.
pub struct ObsGuard {
    recorder: Arc<Recorder>,
}

impl ObsGuard {
    /// Install a fresh recorder of `capacity` and hold it active.
    pub fn install(capacity: usize) -> ObsGuard {
        let recorder = Arc::new(Recorder::new(capacity));
        install(recorder.clone());
        ObsGuard { recorder }
    }

    /// The recorder this guard installed.
    pub fn recorder(&self) -> &Arc<Recorder> {
        &self.recorder
    }
}

impl Drop for ObsGuard {
    fn drop(&mut self) {
        clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn trace_ids_are_deterministic_and_nonzero() {
        for i in 0..10_000u64 {
            let a = TraceId::derive(99, i);
            let b = TraceId::derive(99, i);
            assert_eq!(a, b, "same (seed, index) must give the same id");
            assert_ne!(a.raw(), 0, "derived ids are never zero");
        }
        assert_ne!(TraceId::derive(99, 0), TraceId::derive(99, 1));
        assert_ne!(TraceId::derive(99, 0), TraceId::derive(100, 0));
        assert_eq!(TraceId::from_raw(7).raw(), 7);
    }

    #[test]
    fn ring_overflow_drops_oldest_without_blocking() {
        let rec = Recorder::new(4);
        let t0 = rec.epoch;
        for i in 0..10u64 {
            rec.record_span(
                Some(TraceId::from_raw(i + 1)),
                Stage::Layer,
                &format!("span{i}"),
                t0 + Duration::from_micros(i),
                t0 + Duration::from_micros(i + 1),
                vec![],
            );
        }
        assert_eq!(rec.len(), 4, "ring stays at capacity");
        assert_eq!(rec.dropped(), 6, "overflow drops are counted");
        let spans = rec.snapshot();
        // the oldest were evicted: only the last 4 remain, in order
        let names: Vec<&str> = spans.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["span6", "span7", "span8", "span9"]);
    }

    #[test]
    fn drain_empties_the_ring() {
        let rec = Recorder::new(16);
        let t0 = rec.epoch;
        rec.record_span(None, Stage::Measure, "k", t0, t0 + Duration::from_micros(5), vec![]);
        assert_eq!(rec.len(), 1);
        let spans = rec.drain();
        assert_eq!(spans.len(), 1);
        assert!(rec.is_empty(), "drain leaves the ring empty");
        assert_eq!(spans[0].dur_us, 5);
    }

    #[test]
    fn chrome_export_is_perfetto_shaped() {
        let rec = Recorder::new(16);
        let t0 = rec.epoch;
        rec.record_span(
            Some(TraceId::derive(99, 0)),
            Stage::Layer,
            "conv1",
            t0 + Duration::from_micros(10),
            t0 + Duration::from_micros(30),
            vec![
                ("algo", "im2col".to_string()),
                ("precision", "f32".to_string()),
                ("kernel", "avx2-4x16".to_string()),
            ],
        );
        rec.record_span(None, Stage::Measure, "scalar-4x8", t0, t0 + Duration::from_micros(2), vec![]);
        let json = chrome_trace(&rec.snapshot());
        // must survive a parse round trip (what the CI smoke validates)
        let back = Json::parse(&json.to_string()).expect("exported trace parses");
        let events = back.get("traceEvents").as_arr().expect("traceEvents array");
        assert_eq!(events.len(), 2);
        let layer = &events[0];
        assert_eq!(layer.get("name").as_str(), Some("conv1"));
        assert_eq!(layer.get("cat").as_str(), Some("layer"));
        assert_eq!(layer.get("ph").as_str(), Some("X"));
        assert_eq!(layer.get("ts").as_u64(), Some(10));
        assert_eq!(layer.get("dur").as_u64(), Some(20));
        assert_eq!(layer.get("args").get("algo").as_str(), Some("im2col"));
        assert_eq!(layer.get("args").get("precision").as_str(), Some("f32"));
        assert_eq!(layer.get("args").get("kernel").as_str(), Some("avx2-4x16"));
        assert_eq!(
            layer.get("args").get("trace").as_str(),
            Some(TraceId::derive(99, 0).to_string().as_str())
        );
        // untraced spans land on track 0, traced spans on 1..
        assert_eq!(events[1].get("tid").as_u64(), Some(0));
        assert_eq!(layer.get("tid").as_u64(), Some(1));
    }

    #[test]
    fn guard_installs_and_clears() {
        assert!(!is_active());
        {
            let g = ObsGuard::install(64);
            assert!(is_active());
            let t = Instant::now();
            g.recorder().record_span(None, Stage::Flush, "f", t, t, vec![]);
            assert_eq!(active().expect("installed").len(), 1);
        }
        assert!(!is_active());
        assert!(active().is_none());
    }
}
