//! PJRT client wrapper: HLO text → compile → execute
//! (the /opt/xla-example/load_hlo pattern, generalized with an
//! executable cache).

use crate::api::DynamapError;
use std::collections::BTreeMap;
use std::path::Path;

/// A shaped f32 host tensor moving in/out of the runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorBuf {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorBuf {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> TensorBuf {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        TensorBuf { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> TensorBuf {
        let n = shape.iter().product();
        TensorBuf { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// The PJRT CPU runtime with a compiled-executable cache keyed by
/// artifact path. One compiled executable per (layer, algorithm) —
/// "one compiled executable per model variant".
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<PjrtRuntime, DynamapError> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| DynamapError::Runtime(format!("PjRtClient::cpu: {e:?}")))?;
        Ok(PjrtRuntime { client, cache: BTreeMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached).
    pub fn load(&mut self, path: &Path) -> Result<(), DynamapError> {
        let key = path.to_string_lossy().to_string();
        if self.cache.contains_key(&key) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(&key)
            .map_err(|e| DynamapError::Runtime(format!("parse HLO {key}: {e:?}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| DynamapError::Runtime(format!("compile {key}: {e:?}")))?;
        self.cache.insert(key, exe);
        Ok(())
    }

    pub fn is_loaded(&self, path: &Path) -> bool {
        self.cache.contains_key(path.to_string_lossy().as_ref())
    }

    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }

    /// Execute a loaded artifact with the given inputs. The artifact was
    /// lowered with `return_tuple=True`, so the single output is a
    /// 1-tuple (unwrapped here). `out_shape` reshapes the flat result.
    pub fn execute(
        &mut self,
        path: &Path,
        inputs: &[&TensorBuf],
        out_shape: Vec<usize>,
    ) -> Result<TensorBuf, DynamapError> {
        self.load(path)?;
        let key = path.to_string_lossy().to_string();
        let exe = self.cache.get(&key).unwrap();
        let rt = |m: String| DynamapError::Runtime(m);
        let mut literals = Vec::with_capacity(inputs.len());
        for t in inputs {
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| rt(format!("reshape input: {e:?}")))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| rt(format!("execute {key}: {e:?}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| rt(format!("fetch result: {e:?}")))?;
        let out = lit.to_tuple1().map_err(|e| rt(format!("untuple: {e:?}")))?;
        let data = out.to_vec::<f32>().map_err(|e| rt(format!("to_vec: {e:?}")))?;
        Ok(TensorBuf::new(out_shape, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_buf_shape_checked() {
        let t = TensorBuf::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn tensor_buf_rejects_bad_shape() {
        TensorBuf::new(vec![2, 3], vec![0.0; 5]);
    }

    // PJRT integration tests live in rust/tests/pjrt_runtime.rs — they
    // need the artifacts directory and a working libxla_extension.
}
