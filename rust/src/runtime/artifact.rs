//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust engine (`artifacts/manifest.json`).

use crate::api::DynamapError;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One conv layer's artifacts.
#[derive(Debug, Clone)]
pub struct LayerArtifact {
    pub name: String,
    pub c_in: usize,
    pub c_out: usize,
    pub h1: usize,
    pub h2: usize,
    pub k1: usize,
    pub k2: usize,
    pub s: usize,
    pub p1: usize,
    pub p2: usize,
    pub o1: usize,
    pub o2: usize,
    /// algorithm name → HLO text file (relative to the artifact dir).
    pub algos: BTreeMap<String, String>,
    pub weights_file: String,
    pub weight_count: usize,
}

/// Parsed manifest + artifact directory root.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: String,
    pub input: (usize, usize, usize),
    pub layers: Vec<LayerArtifact>,
    pub golden_input: String,
    pub golden_output: String,
    pub golden_output_shape: Vec<usize>,
    pub fused: Option<String>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest, DynamapError> {
        let path = Path::new(dir).join("manifest.json");
        let text =
            std::fs::read_to_string(&path).map_err(|e| DynamapError::io(&path, e))?;
        let j = Json::parse(&text).map_err(|e| DynamapError::json_in(&path, e))?;
        let u = |v: &Json, k: &str| -> Result<usize, DynamapError> {
            v.get(k)
                .as_usize()
                .ok_or_else(|| DynamapError::Manifest(format!("bad field '{k}'")))
        };
        let mut layers = Vec::new();
        for lj in j
            .get("layers")
            .as_arr()
            .ok_or_else(|| DynamapError::Manifest("no layers".into()))?
        {
            let mut algos = BTreeMap::new();
            if let Some(obj) = lj.get("algos").as_obj() {
                for (k, v) in obj {
                    algos.insert(k.clone(), v.as_str().unwrap_or_default().to_string());
                }
            }
            layers.push(LayerArtifact {
                name: lj.get("name").as_str().unwrap_or_default().to_string(),
                c_in: u(lj, "c_in")?,
                c_out: u(lj, "c_out")?,
                h1: u(lj, "h1")?,
                h2: u(lj, "h2")?,
                k1: u(lj, "k1")?,
                k2: u(lj, "k2")?,
                s: u(lj, "s")?,
                p1: u(lj, "p1")?,
                p2: u(lj, "p2")?,
                o1: u(lj, "o1")?,
                o2: u(lj, "o2")?,
                algos,
                weights_file: lj.get("weights").as_str().unwrap_or_default().to_string(),
                weight_count: u(lj, "weight_count")?,
            });
        }
        let inp = j.get("input");
        Ok(Manifest {
            dir: PathBuf::from(dir),
            model: j.get("model").as_str().unwrap_or_default().to_string(),
            input: (u(&inp, "c")?, u(&inp, "h1")?, u(&inp, "h2")?),
            layers,
            golden_input: j.get("golden_input").as_str().unwrap_or_default().to_string(),
            golden_output: j.get("golden_output").as_str().unwrap_or_default().to_string(),
            golden_output_shape: j
                .get("golden_output_shape")
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                .unwrap_or_default(),
            fused: j.get("fused").as_str().map(|s| s.to_string()),
        })
    }

    pub fn layer(&self, name: &str) -> Option<&LayerArtifact> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Load a raw f32 little-endian binary file from the artifact dir.
    pub fn load_f32(&self, file: &str) -> Result<Vec<f32>, DynamapError> {
        let path = self.dir.join(file);
        let bytes = std::fs::read(&path).map_err(|e| DynamapError::io(&path, e))?;
        if bytes.len() % 4 != 0 {
            return Err(DynamapError::Manifest(format!(
                "{file}: not a multiple of 4 bytes"
            )));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn golden(&self) -> Result<(Vec<f32>, Vec<f32>), DynamapError> {
        Ok((self.load_f32(&self.golden_input)?, self.load_f32(&self.golden_output)?))
    }

    pub fn weights(&self, layer: &LayerArtifact) -> Result<Vec<f32>, DynamapError> {
        let w = self.load_f32(&layer.weights_file)?;
        if w.len() != layer.weight_count {
            return Err(DynamapError::Manifest(format!(
                "{}: expected {} weights, file has {}",
                layer.name,
                layer.weight_count,
                w.len()
            )));
        }
        Ok(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<String> {
        let d = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if Path::new(d).join("manifest.json").exists() {
            Some(d.to_string())
        } else {
            None
        }
    }

    #[test]
    fn loads_manifest_when_built() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model, "mini-inception");
        assert_eq!(m.layers.len(), 7);
        assert_eq!(m.input, (4, 16, 16));
        // every layer's weights load with the right count
        for l in &m.layers {
            let w = m.weights(l).unwrap();
            assert_eq!(w.len(), l.c_in * l.c_out * l.k1 * l.k2);
        }
        let (gi, go) = m.golden().unwrap();
        assert_eq!(gi.len(), 4 * 16 * 16);
        assert_eq!(go.len(), 16 * 8 * 8);
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Manifest::load("/nonexistent/path").is_err());
    }
}
