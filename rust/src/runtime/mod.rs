//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path —
//! Python is never involved at inference time.

pub mod artifact;
pub mod client;

pub use artifact::{LayerArtifact, Manifest};
pub use client::{PjrtRuntime, TensorBuf};
