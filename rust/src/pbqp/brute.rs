//! Exponential brute-force PBQP solver — the correctness oracle for
//! [`super::solve_sp`] in tests, and the fallback for small non-SP
//! graphs (the paper notes general PBQP is NP-complete; CNNs in practice
//! are series-parallel, Lemmas 4.3/4.4).

use super::problem::{Problem, Solution};

/// Enumerate all assignments. Panics if the search space exceeds
/// `2^31` states — callers must check [`search_space`] first for
/// untrusted inputs.
pub fn solve_brute(p: &Problem) -> Solution {
    let space = search_space(p);
    assert!(
        space < (1u128 << 31),
        "brute-force space {space} too large; use solve_sp"
    );
    let n = p.n();
    let mut assignment = vec![0usize; n];
    let mut best = Solution { assignment: assignment.clone(), cost: p.evaluate(&assignment) };
    loop {
        // odometer increment
        let mut i = 0;
        loop {
            if i == n {
                return best;
            }
            assignment[i] += 1;
            if assignment[i] < p.costs[i].len() {
                break;
            }
            assignment[i] = 0;
            i += 1;
        }
        let c = p.evaluate(&assignment);
        if c < best.cost {
            best = Solution { assignment: assignment.clone(), cost: c };
        }
    }
}

/// Total number of assignments (`Π |A_i|`).
pub fn search_space(p: &Problem) -> u128 {
    p.costs.iter().map(|c| c.len() as u128).product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pbqp::problem::Matrix;

    #[test]
    fn finds_global_minimum() {
        let mut p = Problem::default();
        let l = vec!["a".to_string(), "b".to_string(), "c".to_string()];
        let x = p.add_vertex("x", vec![3.0, 1.0, 2.0], l.clone());
        let y = p.add_vertex("y", vec![0.0, 5.0, 5.0], l.clone());
        p.add_edge(x, y, Matrix::from_fn(3, 3, |i, j| if i == 1 && j == 0 { 0.0 } else { 9.0 }));
        let sol = solve_brute(&p);
        assert_eq!(sol.assignment, vec![1, 0]);
        assert_eq!(sol.cost, 1.0);
    }

    #[test]
    fn search_space_counts() {
        let mut p = Problem::default();
        let mk = |n: usize| (0..n).map(|i| format!("o{i}")).collect::<Vec<_>>();
        p.add_vertex("a", vec![0.0; 3], mk(3));
        p.add_vertex("b", vec![0.0; 2], mk(2));
        p.add_vertex("c", vec![0.0; 5], mk(5));
        assert_eq!(search_space(&p), 30);
    }
}
