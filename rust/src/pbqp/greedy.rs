//! Greedy baseline: pick each vertex's cheapest node cost, ignoring
//! transition matrices. §6.1.2 of the paper argues this is sub-optimal
//! ("a scheme that greedily chooses the algorithm with the smallest
//! layer node cost c would not return the optimal mapping") — the
//! `ablation_greedy` bench quantifies the gap.

use super::problem::{Problem, Solution};

/// Greedy per-vertex argmin of `c_i`, evaluated under the full objective.
pub fn solve_greedy(p: &Problem) -> Solution {
    let assignment: Vec<usize> = p
        .costs
        .iter()
        .map(|c| {
            let mut bi = 0;
            for (i, &x) in c.iter().enumerate() {
                if x < c[bi] {
                    bi = i;
                }
            }
            bi
        })
        .collect();
    let cost = p.evaluate(&assignment);
    Solution { assignment, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pbqp::problem::Matrix;
    use crate::pbqp::solve_brute;

    #[test]
    fn greedy_can_be_suboptimal() {
        // node costs prefer (1, 1) but the transition matrix punishes it
        let mut p = Problem::default();
        let l = vec!["x".to_string(), "y".to_string()];
        let a = p.add_vertex("a", vec![2.0, 1.0], l.clone());
        let b = p.add_vertex("b", vec![2.0, 1.0], l.clone());
        p.add_edge(a, b, Matrix::from_fn(2, 2, |i, j| if i == 1 && j == 1 { 100.0 } else { 0.0 }));
        let g = solve_greedy(&p);
        let o = solve_brute(&p);
        assert_eq!(g.assignment, vec![1, 1]);
        assert!(g.cost > o.cost, "greedy {} should exceed optimal {}", g.cost, o.cost);
    }

    #[test]
    fn greedy_optimal_without_edges() {
        let mut p = Problem::default();
        let l = vec!["x".to_string(), "y".to_string()];
        p.add_vertex("a", vec![2.0, 1.0], l.clone());
        p.add_vertex("b", vec![0.5, 1.0], l.clone());
        let g = solve_greedy(&p);
        assert_eq!(g.assignment, vec![1, 0]);
        assert_eq!(g.cost, 1.5);
    }
}
