//! Polynomial-time PBQP on series-parallel graphs (Theorem 4.1/4.2).
//!
//! Repeatedly applies the two optimality-preserving reduction operations
//! of Definition 1 — (1) eliminate a degree-2 vertex (other than the
//! source `s` / sink `t`), folding its cost vector and incident matrices
//! into a new edge between its neighbours; (2) merge parallel edges by
//! matrix addition — until the graph is a `K_2` on `{s, t}`, solves the
//! two-vertex problem by enumeration, then back-substitutes the recorded
//! argmins to recover the optimal assignment of every eliminated vertex.
//!
//! Degree-1 vertices (possible in cost graphs whose sink-side layers
//! hang off a chain) are folded into their neighbour's cost vector — the
//! same operation the paper's base step (1) uses in its inductive
//! construction. Each elimination does `O(d³)` work (a `d×d` min over
//! the middle domain), so the total is `O(N·d³)` — the paper quotes
//! `O(N·d²)` counting the per-pair work as O(d) lookups; with `d ≤ 4`
//! algorithm choices both are instant (<2 s even for Inception-v4,
//! reproduced by the `dse_runtime` bench).

use super::problem::{Matrix, Problem, Solution};

#[derive(Debug)]
enum Step {
    /// Removed degree-2 vertex `k` between `i` and `j`; `argmin[di][dj]`.
    R1 { k: usize, i: usize, j: usize, argmin: Vec<Vec<usize>> },
    /// Removed degree-1 vertex `k` hanging off `i`; `argmin[di]`.
    R0 { k: usize, i: usize, argmin: Vec<usize> },
    /// Removed isolated vertex `k`; fixed best choice.
    RIso { k: usize, best: usize },
}

struct LiveEdge {
    u: usize,
    v: usize,
    m: Matrix,
    alive: bool,
}

/// Solve PBQP on a series-parallel graph with the given source and sink.
/// Returns `None` if the graph is not series-parallel reducible (callers
/// fall back to [`super::solve_brute`] for small instances).
///
/// Worklist implementation: adjacency lists are maintained incrementally
/// and a vertex is (re)examined only when its incident edges change, so
/// the whole reduction is `O((N+E)·d³)` — the `dse_runtime` bench
/// demonstrates the linear scaling of Theorem 4.1 on 10k-vertex chains.
pub fn solve_sp(p: &Problem, s: usize, t: usize) -> Option<Solution> {
    assert!(s < p.n() && t < p.n() && s != t, "bad source/sink");
    let n = p.n();
    let mut costs: Vec<Vec<f64>> = p.costs.clone();
    let mut alive = vec![true; n];
    let mut edges: Vec<LiveEdge> =
        p.edges.iter().map(|e| LiveEdge { u: e.u, v: e.v, m: e.m.clone(), alive: true }).collect();
    let mut steps: Vec<Step> = Vec::new();
    let mut alive_count = n;

    // adjacency: edge ids per vertex (lazily compacted)
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (eid, e) in edges.iter().enumerate() {
        adj[e.u].push(eid);
        adj[e.v].push(eid);
    }

    // matrix of edge `eid` oriented as (a → b)
    let oriented = |edges: &[LiveEdge], eid: usize, a: usize, b: usize| -> Matrix {
        let e = &edges[eid];
        if (e.u, e.v) == (a, b) {
            e.m.clone()
        } else {
            debug_assert_eq!((e.u, e.v), (b, a));
            e.m.transposed()
        }
    };

    use std::collections::VecDeque;
    let mut work: VecDeque<usize> = (0..n).collect();
    let mut queued = vec![true; n];

    while let Some(k) = work.pop_front() {
        queued[k] = false;
        if !alive[k] {
            continue;
        }
        // compact adjacency, drop dead edges
        adj[k].retain(|&eid| edges[eid].alive);
        adj[k].sort_unstable();
        adj[k].dedup();

        // --- operation 2: merge parallel edges at k locally -----------
        {
            let mut by_nb: std::collections::BTreeMap<usize, usize> = Default::default();
            let inc = adj[k].clone();
            for eid in inc {
                if !edges[eid].alive {
                    continue;
                }
                let nb = if edges[eid].u == k { edges[eid].v } else { edges[eid].u };
                if let Some(&first) = by_nb.get(&nb) {
                    // merge eid into first (orient both k → nb)
                    let m_add = oriented(&edges, eid, k, nb);
                    let m_first = oriented(&edges, first, k, nb);
                    edges[first].m = m_first.add(&m_add);
                    edges[first].u = k;
                    edges[first].v = nb;
                    edges[eid].alive = false;
                } else {
                    by_nb.insert(nb, eid);
                }
            }
            adj[k].retain(|&eid| edges[eid].alive);
        }

        if k == s || k == t {
            continue; // terminals are never reduced
        }

        // --- reduce k if degree ≤ 2 ------------------------------------
        let inc: Vec<usize> = adj[k].clone();
        match inc.len() {
            0 => {
                let best = argmin_f64(&costs[k]);
                steps.push(Step::RIso { k, best });
                alive[k] = false;
                alive_count -= 1;
            }
            1 => {
                let eid = inc[0];
                let i = if edges[eid].u == k { edges[eid].v } else { edges[eid].u };
                let m_ik = oriented(&edges, eid, i, k);
                let (di_n, dk_n) = (costs[i].len(), costs[k].len());
                let mut argmin = vec![0usize; di_n];
                for di in 0..di_n {
                    let mut best = f64::INFINITY;
                    let mut bk = 0;
                    for dk in 0..dk_n {
                        let v = m_ik.get(di, dk) + costs[k][dk];
                        if v < best {
                            best = v;
                            bk = dk;
                        }
                    }
                    costs[i][di] += best;
                    argmin[di] = bk;
                }
                steps.push(Step::R0 { k, i, argmin });
                edges[eid].alive = false;
                alive[k] = false;
                alive_count -= 1;
                if !queued[i] {
                    queued[i] = true;
                    work.push_back(i);
                }
            }
            2 => {
                let (e1, e2) = (inc[0], inc[1]);
                let i = if edges[e1].u == k { edges[e1].v } else { edges[e1].u };
                let j = if edges[e2].u == k { edges[e2].v } else { edges[e2].u };
                debug_assert_ne!(i, j, "parallels were merged above");
                let m_ik = oriented(&edges, e1, i, k);
                let m_kj = oriented(&edges, e2, k, j);
                let (di_n, dj_n, dk_n) = (costs[i].len(), costs[j].len(), costs[k].len());
                let mut new_m = Matrix::zeros(di_n, dj_n);
                let mut argmin = vec![vec![0usize; dj_n]; di_n];
                for di in 0..di_n {
                    for dj in 0..dj_n {
                        let mut best = f64::INFINITY;
                        let mut bk = 0;
                        for dk in 0..dk_n {
                            let v = m_ik.get(di, dk) + costs[k][dk] + m_kj.get(dk, dj);
                            if v < best {
                                best = v;
                                bk = dk;
                            }
                        }
                        new_m.set(di, dj, best);
                        argmin[di][dj] = bk;
                    }
                }
                steps.push(Step::R1 { k, i, j, argmin });
                edges[e1].alive = false;
                edges[e2].alive = false;
                let new_eid = edges.len();
                edges.push(LiveEdge { u: i, v: j, m: new_m, alive: true });
                adj[i].push(new_eid);
                adj[j].push(new_eid);
                alive[k] = false;
                alive_count -= 1;
                for v in [i, j] {
                    if !queued[v] {
                        queued[v] = true;
                        work.push_back(v);
                    }
                }
            }
            _ => {} // not reducible now; re-queued if neighbours change
        }
    }

    if alive_count > 2 {
        return None; // not series-parallel
    }
    // final parallel merge between s and t
    {
        adj[s].retain(|&eid| edges[eid].alive);
        let inc = adj[s].clone();
        let mut first: Option<usize> = None;
        for eid in inc {
            if !edges[eid].alive {
                continue;
            }
            match first {
                None => first = Some(eid),
                Some(f) => {
                    let m_add = oriented(&edges, eid, s, t);
                    let m_f = oriented(&edges, f, s, t);
                    edges[f].m = m_f.add(&m_add);
                    edges[f].u = s;
                    edges[f].v = t;
                    edges[eid].alive = false;
                }
            }
        }
    }

    // --- solve the terminal K2 (or two isolated vertices) --------------
    let mut assignment = vec![usize::MAX; n];
    let live: Vec<usize> = (0..n).filter(|&v| alive[v]).collect();
    debug_assert!(live.contains(&s) && live.contains(&t));
    let st_edges: Vec<usize> = edges
        .iter()
        .enumerate()
        .filter(|(_, e)| e.alive)
        .map(|(i, _)| i)
        .collect();
    if let Some(&eid) = st_edges.first() {
        debug_assert_eq!(st_edges.len(), 1, "parallel edges survived merging");
        let m_st = oriented(&edges, eid, s, t);
        let mut best = f64::INFINITY;
        let mut bst = (0, 0);
        for ds in 0..costs[s].len() {
            for dt in 0..costs[t].len() {
                let v = costs[s][ds] + m_st.get(ds, dt) + costs[t][dt];
                if v < best {
                    best = v;
                    bst = (ds, dt);
                }
            }
        }
        assignment[s] = bst.0;
        assignment[t] = bst.1;
    } else {
        assignment[s] = argmin_f64(&costs[s]);
        assignment[t] = argmin_f64(&costs[t]);
    }

    // --- back-substitute eliminated vertices ----------------------------
    for step in steps.iter().rev() {
        match step {
            Step::R1 { k, i, j, argmin } => {
                assignment[*k] = argmin[assignment[*i]][assignment[*j]];
            }
            Step::R0 { k, i, argmin } => {
                assignment[*k] = argmin[assignment[*i]];
            }
            Step::RIso { k, best } => {
                assignment[*k] = *best;
            }
        }
    }
    debug_assert!(assignment.iter().all(|&a| a != usize::MAX));
    let cost = p.evaluate(&assignment);
    Some(Solution { assignment, cost })
}

fn argmin_f64(v: &[f64]) -> usize {
    let mut bi = 0;
    for (i, &x) in v.iter().enumerate() {
        if x < v[bi] {
            bi = i;
        }
    }
    bi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pbqp::brute::solve_brute;
    use crate::pbqp::problem::Problem;
    use crate::util::rng::Rng;

    /// The Figure-6 example: 3 vertices in a chain, d=2, zero node costs.
    #[test]
    fn figure6_chain() {
        let mut p = Problem::default();
        let labels = |n: usize| (0..n).map(|i| format!("o{i}")).collect::<Vec<_>>();
        let a = p.add_vertex("a", vec![0.0, 0.0], labels(2));
        let k = p.add_vertex("k", vec![0.0, 0.0], labels(2));
        let b = p.add_vertex("b", vec![0.0, 0.0], labels(2));
        p.add_edge(a, k, Matrix::from_fn(2, 2, |i, j| [[1.0, 9.0], [8.0, 2.0]][i][j]));
        p.add_edge(k, b, Matrix::from_fn(2, 2, |i, j| [[3.0, 4.0], [1.0, 7.0]][i][j]));
        let sol = solve_sp(&p, a, b).unwrap();
        let brute = solve_brute(&p);
        assert_eq!(sol.cost, brute.cost);
        assert_eq!(sol.cost, p.evaluate(&sol.assignment));
        // chain min: min over (da,dk,db) of T1+T2 = 1+3=4? (0,0,0)=1+3=4;
        // (1,1,0)=2+1=3 → 3
        assert_eq!(sol.cost, 3.0);
    }

    #[test]
    fn parallel_edges_merge() {
        let mut p = Problem::default();
        let labels = vec!["x".to_string(), "y".to_string()];
        let s = p.add_vertex("s", vec![0.0, 1.0], labels.clone());
        let t = p.add_vertex("t", vec![0.0, 2.0], labels.clone());
        p.add_edge(s, t, Matrix::from_fn(2, 2, |i, j| (i + j) as f64));
        p.add_edge(t, s, Matrix::from_fn(2, 2, |i, j| (2 * i + j) as f64));
        let sol = solve_sp(&p, s, t).unwrap();
        let brute = solve_brute(&p);
        assert_eq!(sol.cost, brute.cost);
    }

    #[test]
    fn diamond_graph() {
        // s → a → t, s → b → t (inception-like branch)
        let mut p = Problem::default();
        let l3 = vec!["i".to_string(), "k".to_string(), "w".to_string()];
        let s = p.add_vertex("s", vec![0.0, 0.0, 0.0], l3.clone());
        let a = p.add_vertex("a", vec![5.0, 1.0, 9.0], l3.clone());
        let b = p.add_vertex("b", vec![2.0, 2.0, 0.5], l3.clone());
        let t = p.add_vertex("t", vec![0.0, 0.0, 0.0], l3.clone());
        let m = |seed: f64| Matrix::from_fn(3, 3, |i, j| seed * (1.0 + (i as f64 - j as f64).abs()));
        p.add_edge(s, a, m(1.0));
        p.add_edge(a, t, m(2.0));
        p.add_edge(s, b, m(0.5));
        p.add_edge(b, t, m(1.5));
        let sol = solve_sp(&p, s, t).unwrap();
        let brute = solve_brute(&p);
        assert!((sol.cost - brute.cost).abs() < 1e-12);
        assert!((p.evaluate(&sol.assignment) - sol.cost).abs() < 1e-12);
    }

    #[test]
    fn non_sp_returns_none() {
        // K4 is not series-parallel
        let mut p = Problem::default();
        let l = vec!["x".to_string(), "y".to_string()];
        let vs: Vec<usize> =
            (0..4).map(|i| p.add_vertex(&format!("v{i}"), vec![0.0, 1.0], l.clone())).collect();
        for i in 0..4 {
            for j in (i + 1)..4 {
                p.add_edge(vs[i], vs[j], Matrix::from_fn(2, 2, |a, b| (a + b) as f64));
            }
        }
        assert!(solve_sp(&p, vs[0], vs[3]).is_none());
    }

    #[test]
    fn degree1_chain_tail() {
        // s - t - k (k hangs off the sink side)
        let mut p = Problem::default();
        let l = vec!["x".to_string(), "y".to_string()];
        let s = p.add_vertex("s", vec![0.0, 3.0], l.clone());
        let t = p.add_vertex("t", vec![1.0, 0.0], l.clone());
        let k = p.add_vertex("k", vec![0.0, 0.0], l.clone());
        p.add_edge(s, t, Matrix::from_fn(2, 2, |i, j| ((i + 1) * (j + 1)) as f64));
        p.add_edge(t, k, Matrix::from_fn(2, 2, |i, j| if i == j { 0.0 } else { 4.0 }));
        let sol = solve_sp(&p, s, t).unwrap();
        let brute = solve_brute(&p);
        assert_eq!(sol.cost, brute.cost);
    }

    #[test]
    fn matches_brute_force_on_random_sp_graphs() {
        use crate::util::{proptest, rng::Rng};
        proptest::check("sp_solver_optimal", 64, |r: &mut Rng| {
            let p = random_sp_problem(r);
            let sol = solve_sp(&p, 0, 1).ok_or("sp graph judged non-SP")?;
            let brute = solve_brute(&p);
            if (sol.cost - brute.cost).abs() > 1e-9 {
                return Err(format!("sp {} != brute {}", sol.cost, brute.cost));
            }
            let eval = p.evaluate(&sol.assignment);
            if (eval - sol.cost).abs() > 1e-9 {
                return Err(format!("reported {} != evaluated {}", sol.cost, eval));
            }
            Ok(())
        });
    }

    /// Generate a random series-parallel PBQP problem by the paper's
    /// inductive construction: start from K2 {0, 1}, then repeatedly
    /// subdivide an edge (series) or duplicate an edge (parallel).
    pub(crate) fn random_sp_problem(r: &mut Rng) -> Problem {
        let mut p = Problem::default();
        let dom = |r: &mut Rng| r.range(1, 3);
        let mk_labels = |n: usize| (0..n).map(|i| format!("o{i}")).collect::<Vec<_>>();
        let mk_costs = |r: &mut Rng, n: usize| (0..n).map(|_| (r.below(20) as f64)).collect();
        let d0 = dom(r);
        let d1 = dom(r);
        let s = p.add_vertex("s", mk_costs(r, d0), mk_labels(d0));
        let t = p.add_vertex("t", mk_costs(r, d1), mk_labels(d1));
        let mk_m = |r: &mut Rng, a: usize, b: usize| {
            Matrix::from_fn(a, b, |_, _| r.below(20) as f64)
        };
        let m0 = mk_m(r, p.costs[s].len(), p.costs[t].len());
        p.add_edge(s, t, m0);
        let steps = r.range(1, 8);
        for _ in 0..steps {
            let eid = r.below(p.edges.len() as u64) as usize;
            if r.bool() {
                // series: subdivide edge (u,v) with new vertex k
                let (u, v) = (p.edges[eid].u, p.edges[eid].v);
                let dk = dom(r);
                let k = p.add_vertex(
                    &format!("v{}", p.n()),
                    mk_costs(r, dk),
                    mk_labels(dk),
                );
                let m1 = mk_m(r, p.costs[u].len(), dk);
                let m2 = mk_m(r, dk, p.costs[v].len());
                p.edges.remove(eid);
                p.add_edge(u, k, m1);
                p.add_edge(k, v, m2);
            } else {
                // parallel: duplicate edge with fresh costs
                let (u, v) = (p.edges[eid].u, p.edges[eid].v);
                let m = mk_m(r, p.costs[u].len(), p.costs[v].len());
                p.add_edge(u, v, m);
            }
        }
        p
    }
}
