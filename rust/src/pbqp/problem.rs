//! PBQP problem representation (Eq. 8).

/// A dense `rows × cols` cost matrix for one edge.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Transpose (re-orienting an edge matrix).
    pub fn transposed(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }

    /// Element-wise sum — reduction operation 2 (parallel edges).
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "matrix dim mismatch");
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }
}

/// One PBQP edge: an oriented pair `(u, v)` with a `|A_u| × |A_v|`
/// transition matrix.
#[derive(Debug, Clone)]
pub struct Edge {
    pub u: usize,
    pub v: usize,
    pub m: Matrix,
}

/// A PBQP instance: per-vertex cost vectors and pairwise matrices.
#[derive(Debug, Clone, Default)]
pub struct Problem {
    /// Vertex display names (layer names for cost graphs).
    pub names: Vec<String>,
    /// Per-vertex choice labels (algorithm names).
    pub choice_labels: Vec<Vec<String>>,
    /// Cost vectors `c_i`.
    pub costs: Vec<Vec<f64>>,
    pub edges: Vec<Edge>,
}

impl Problem {
    pub fn n(&self) -> usize {
        self.costs.len()
    }

    /// Max domain size `d` (for the O(N·d²) bound).
    pub fn max_domain(&self) -> usize {
        self.costs.iter().map(|c| c.len()).max().unwrap_or(0)
    }

    pub fn add_vertex(&mut self, name: &str, costs: Vec<f64>, labels: Vec<String>) -> usize {
        assert_eq!(costs.len(), labels.len());
        assert!(!costs.is_empty(), "vertex '{name}' has empty domain");
        let id = self.costs.len();
        self.names.push(name.to_string());
        self.costs.push(costs);
        self.choice_labels.push(labels);
        id
    }

    pub fn add_edge(&mut self, u: usize, v: usize, m: Matrix) {
        assert_eq!(m.rows, self.costs[u].len(), "edge ({u},{v}) row dim");
        assert_eq!(m.cols, self.costs[v].len(), "edge ({u},{v}) col dim");
        assert_ne!(u, v, "self loops are not representable in PBQP");
        self.edges.push(Edge { u, v, m });
    }

    /// Objective value of a full assignment (Eq. 8).
    pub fn evaluate(&self, assignment: &[usize]) -> f64 {
        assert_eq!(assignment.len(), self.n());
        let mut total = 0.0;
        for (i, &k) in assignment.iter().enumerate() {
            total += self.costs[i][k];
        }
        for e in &self.edges {
            total += e.m.get(assignment[e.u], assignment[e.v]);
        }
        total
    }

    /// Validate an assignment is within domains.
    pub fn check_assignment(&self, assignment: &[usize]) -> Result<(), String> {
        if assignment.len() != self.n() {
            return Err(format!("assignment len {} != {}", assignment.len(), self.n()));
        }
        for (i, &k) in assignment.iter().enumerate() {
            if k >= self.costs[i].len() {
                return Err(format!("vertex {} choice {} out of domain {}", i, k, self.costs[i].len()));
            }
        }
        Ok(())
    }
}

/// A solved assignment with its objective value.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    pub assignment: Vec<usize>,
    pub cost: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_ops() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.get(1, 2), 12.0);
        let t = m.transposed();
        assert_eq!(t.get(2, 1), 12.0);
        let s = m.add(&m);
        assert_eq!(s.get(1, 2), 24.0);
    }

    #[test]
    fn evaluate_sums_nodes_and_edges() {
        let mut p = Problem::default();
        let a = p.add_vertex("a", vec![1.0, 5.0], vec!["x".into(), "y".into()]);
        let b = p.add_vertex("b", vec![2.0, 0.0], vec!["x".into(), "y".into()]);
        p.add_edge(a, b, Matrix::from_fn(2, 2, |i, j| if i == j { 0.0 } else { 10.0 }));
        assert_eq!(p.evaluate(&[0, 0]), 3.0);
        assert_eq!(p.evaluate(&[0, 1]), 11.0);
        assert_eq!(p.evaluate(&[1, 1]), 5.0);
    }

    #[test]
    #[should_panic(expected = "row dim")]
    fn edge_dims_checked() {
        let mut p = Problem::default();
        let a = p.add_vertex("a", vec![0.0], vec!["x".into()]);
        let b = p.add_vertex("b", vec![0.0, 1.0], vec!["x".into(), "y".into()]);
        p.add_edge(a, b, Matrix::zeros(2, 2));
    }
}
