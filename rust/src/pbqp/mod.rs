//! Partitioned Boolean Quadratic Programming (paper §4).
//!
//! The algorithm-mapping problem (Eq. 8) — pick one algorithm per layer
//! minimizing node costs plus pairwise transition costs — is PBQP, which
//! is NP-complete in general but solvable in `O(N·d²)` time on
//! series-parallel graphs (Theorem 4.1) by the two reduction operations
//! of Definition 1. [`sp_solver`] implements that algorithm with full
//! back-substitution; [`brute`] is an exponential verifier used in tests
//! and for non-SP fallback on small graphs; [`greedy`] is the
//! node-cost-greedy baseline the paper argues against in §6.1.2.

pub mod problem;
pub mod sp_solver;
pub mod brute;
pub mod greedy;

pub use problem::{Edge, Matrix, Problem, Solution};
pub use sp_solver::solve_sp;
pub use brute::solve_brute;
pub use greedy::solve_greedy;
