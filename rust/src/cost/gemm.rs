//! Eq. 9 — GEMM execution cycles on a fixed `P_SA1 × P_SA2` systolic
//! array under the three dataflows.
//!
//! For input matrices `X (a × b)` and `W (b × c)`:
//!
//! ```text
//! NS:  ⌈a/P_SA1⌉ · ⌈c/P_SA2⌉ · b + I_SA
//! WS:  ⌈b/P_SA1⌉ · ⌈c/P_SA2⌉ · a + I_SA
//! IS:  ⌈b/P_SA1⌉ · ⌈a/P_SA2⌉ · c + I_SA
//! ```
//!
//! `I_SA ∝ max(P_SA1, P_SA2)` is the pipeline initialization overhead.
//! With the stall-free PE design of §3.2 the overhead is overlapped with
//! the next pass and is paid once per GEMM; a naive PE pays it on every
//! pass (exposed via [`gemm_cycles_naive`] for the ablation bench).

/// Systolic-array dataflow (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// Non-stationary: both operands stream; output stays per-PE.
    NS,
    /// Weight-stationary: a `P_SA1 × P_SA2` weight block is pinned.
    WS,
    /// Input-stationary: mirror of WS with the input pinned.
    IS,
}

impl Dataflow {
    /// All three dataflows, in the tie-breaking order `NS < WS < IS`.
    pub const ALL: [Dataflow; 3] = [Dataflow::NS, Dataflow::WS, Dataflow::IS];

    /// Stable display name ("NS" / "WS" / "IS").
    pub fn name(&self) -> &'static str {
        match self {
            Dataflow::NS => "NS",
            Dataflow::WS => "WS",
            Dataflow::IS => "IS",
        }
    }
}

fn ceil_div(x: usize, d: usize) -> usize {
    x.div_ceil(d)
}

/// Number of passes over tile pairs for a given dataflow (used by the
/// naive-initialization model and the cycle simulator).
pub fn gemm_passes(p1: usize, p2: usize, df: Dataflow, a: usize, b: usize, c: usize) -> usize {
    match df {
        Dataflow::NS => ceil_div(a, p1) * ceil_div(c, p2),
        Dataflow::WS => ceil_div(b, p1) * ceil_div(c, p2),
        Dataflow::IS => ceil_div(b, p1) * ceil_div(a, p2),
    }
}

/// Eq. 9 with the stall-free PE: one `I_SA = max(P1, P2)` per GEMM.
pub fn gemm_cycles(p1: usize, p2: usize, df: Dataflow, a: usize, b: usize, c: usize) -> u64 {
    assert!(p1 > 0 && p2 > 0 && a > 0 && b > 0 && c > 0, "gemm_cycles: zero dim");
    let i_sa = p1.max(p2) as u64;
    let work = match df {
        Dataflow::NS => (ceil_div(a, p1) * ceil_div(c, p2)) as u64 * b as u64,
        Dataflow::WS => (ceil_div(b, p1) * ceil_div(c, p2)) as u64 * a as u64,
        Dataflow::IS => (ceil_div(b, p1) * ceil_div(a, p2)) as u64 * c as u64,
    };
    work + i_sa
}

/// Naive PE (no stall-free optimization): `I_SA` on every pass. Used by
/// the `ablation_stall_free` bench.
pub fn gemm_cycles_naive(p1: usize, p2: usize, df: Dataflow, a: usize, b: usize, c: usize) -> u64 {
    let i_sa = p1.max(p2) as u64;
    let passes = gemm_passes(p1, p2, df, a, b, c) as u64;
    let per_pass = match df {
        Dataflow::NS => b as u64,
        Dataflow::WS => a as u64,
        Dataflow::IS => c as u64,
    };
    passes * (per_pass + i_sa)
}

/// Useful multiply-accumulates in the GEMM (no zero padding): `a·b·c`.
pub fn gemm_macs(a: usize, b: usize, c: usize) -> u64 {
    a as u64 * b as u64 * c as u64
}

/// The dataflow minimizing Eq. 9 for this GEMM shape, with its cycles.
/// Ties resolve in `NS < WS < IS` declaration order (deterministic).
pub fn best_dataflow(p1: usize, p2: usize, a: usize, b: usize, c: usize) -> (Dataflow, u64) {
    Dataflow::ALL
        .iter()
        .map(|&df| (df, gemm_cycles(p1, p2, df, a, b, c)))
        .min_by_key(|&(_, cy)| cy)
        .unwrap()
}

/// Effective PE utilization of a single GEMM (Eq. 14 restricted to one
/// GEMM call): useful MACs / (cycles · P1 · P2).
pub fn gemm_utilization(p1: usize, p2: usize, df: Dataflow, a: usize, b: usize, c: usize) -> f64 {
    let t = gemm_cycles(p1, p2, df, a, b, c) as f64;
    gemm_macs(a, b, c) as f64 / (t * (p1 * p2) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq9_exact_values() {
        // a=62, b=124, c=64 on 31×31 — the paper's §3.2 example.
        let (p1, p2) = (31, 31);
        let ns = gemm_cycles(p1, p2, Dataflow::NS, 62, 124, 64);
        // ⌈62/31⌉·⌈64/31⌉·124 + 31 = 2·3·124+31 = 775
        assert_eq!(ns, 775);
        let ws = gemm_cycles(p1, p2, Dataflow::WS, 62, 124, 64);
        // ⌈124/31⌉·⌈64/31⌉·62 + 31 = 4·3·62+31 = 775
        assert_eq!(ws, 775);
        let is = gemm_cycles(p1, p2, Dataflow::IS, 62, 124, 64);
        // ⌈124/31⌉·⌈62/31⌉·64 + 31 = 4·2·64+31 = 543
        assert_eq!(is, 543);
        assert_eq!(best_dataflow(p1, p2, 62, 124, 64), (Dataflow::IS, 543));
    }

    #[test]
    fn paper_utilization_example() {
        // §3.2: parallelizing along (a, c) on 31×31 for (62,124)×(124,64)
        // gives ~68% utilization because the last c-tile has 2 columns.
        let u_ns = gemm_utilization(31, 31, Dataflow::NS, 62, 124, 64);
        assert!(
            (0.60..0.72).contains(&u_ns),
            "NS utilization {u_ns} should be ≈0.66-0.68"
        );
        // IS avoids the padding: utilization should be clearly higher.
        let u_is = gemm_utilization(31, 31, Dataflow::IS, 62, 124, 64);
        assert!(u_is > u_ns, "IS {u_is} should beat NS {u_ns}");
    }

    #[test]
    fn naive_never_faster() {
        for &(a, b, c) in &[(10, 10, 10), (100, 3, 700), (64, 576, 128), (1, 1, 1)] {
            for df in Dataflow::ALL {
                let fast = gemm_cycles(16, 8, df, a, b, c);
                let naive = gemm_cycles_naive(16, 8, df, a, b, c);
                assert!(naive >= fast, "naive {naive} < stall-free {fast}");
            }
        }
    }

    #[test]
    fn utilization_bounded() {
        use crate::util::{proptest, rng::Rng};
        proptest::check("gemm_util_le_1", 256, |r: &mut Rng| {
            let p1 = r.range(1, 128);
            let p2 = r.range(1, 128);
            let a = r.range(1, 2048);
            let b = r.range(1, 2048);
            let c = r.range(1, 2048);
            for df in Dataflow::ALL {
                let u = gemm_utilization(p1, p2, df, a, b, c);
                if !(0.0 < u && u <= 1.0 + 1e-12) {
                    return Err(format!(
                        "utilization {u} out of (0,1] for p=({p1},{p2}) df={df:?} ({a},{b},{c})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn exact_fit_has_high_utilization() {
        // a,c multiples of p1,p2: only I_SA keeps μ below 1
        let u = gemm_utilization(32, 32, Dataflow::NS, 64, 512, 64);
        assert!(u > 0.9, "exact-fit NS utilization {u}");
    }
}
