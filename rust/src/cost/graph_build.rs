//! §5.1 — Cost graph construction.
//!
//! Builds a PBQP instance from a CNN graph: one compute vertex `V_c` per
//! layer whose domain is the layer's available algorithm-dataflow pairs
//! (non-conv layers get a singleton "passthrough" domain), plus a store
//! vertex `V_s` for every fan-out layer capturing the single format its
//! output is stored in (the paper: "Layer i connected to multiple
//! downstream layers can store the output in only one format").
//!
//! Node costs are Eq. 10–12 latencies (overlapped with the layer's
//! weight streaming when `overlap_weight_load` is set); edge matrices
//! are the Table-2 store+load transition latencies, plus a
//! requantization pass ([`TransitionModel::requant_sec`]) whenever the
//! two endpoints run at different precisions — that term is what
//! couples neighbouring precision choices into the PBQP solve instead
//! of leaving precision a per-layer greedy pick.

use std::collections::BTreeMap;

use super::conv::{Algo, ConvCost, CostModel};
use super::transition::{input_format, output_format, EdgeDims, Format, TransitionModel};
use crate::graph::layer::{Op, PoolKind};
use crate::graph::{Cnn, NodeId};
use crate::pbqp::{solve_brute, solve_sp, Matrix, Problem, Solution};
use crate::pbqp::brute::search_space;
use crate::quant::Precision;
use crate::util::parallel::parallel_map;

/// One entry of a PBQP vertex domain.
#[derive(Debug, Clone)]
pub enum Choice {
    /// Conv layer executed with this (algorithm, precision, dataflow)
    /// tuple.
    Conv {
        /// CNN node this choice belongs to.
        node: NodeId,
        /// Evaluated cost of the tuple.
        cost: ConvCost,
    },
    /// Non-conv layer (pool/concat/add/fc/input/output).
    Passthrough {
        /// CNN node this choice belongs to.
        node: NodeId,
        /// Fixed node latency.
        seconds: f64,
    },
    /// `V_s` store vertex: store output in the input format (and
    /// precision domain) of one algorithm choice of downstream `child`.
    StoreAs {
        /// Fan-out CNN node whose output is stored.
        node: NodeId,
        /// The downstream consumer the stored copy is formatted for.
        child: NodeId,
        /// Stored layout family.
        fmt: Format,
        /// Precision domain the stored copy lives in.
        precision: Precision,
        /// Consumer dims the layout is instantiated at.
        dims: EdgeDims,
        /// Stored element volume (drives mismatch restores).
        volume: u64,
    },
}

impl Choice {
    /// Storage format family this choice's output occupies in DRAM.
    pub fn out_format(&self) -> Format {
        match self {
            Choice::Conv { cost, .. } => output_format(cost.algo),
            Choice::Passthrough { .. } => Format::Tensor3D,
            Choice::StoreAs { fmt, .. } => *fmt,
        }
    }

    /// Input format this choice's vertex consumes.
    pub fn in_format(&self) -> Format {
        match self {
            Choice::Conv { cost, .. } => input_format(cost.algo),
            Choice::Passthrough { .. } => Format::Tensor3D,
            Choice::StoreAs { fmt, .. } => *fmt,
        }
    }

    /// Precision domain of this choice's data: the conv tuple's
    /// precision, the stored copy's precision, f32 for passthrough
    /// layers (pool/concat/add run on the full-precision datapath).
    pub fn precision(&self) -> Precision {
        match self {
            Choice::Conv { cost, .. } => cost.precision,
            Choice::Passthrough { .. } => Precision::F32,
            Choice::StoreAs { precision, .. } => *precision,
        }
    }

    /// Human-readable label for reports and the PBQP problem dump.
    pub fn label(&self) -> String {
        match self {
            Choice::Conv { cost, .. } => match cost.precision {
                Precision::F32 => format!("{}/{}", cost.algo.name(), cost.dataflow.name()),
                Precision::Int8 => {
                    format!("{}/{}/int8", cost.algo.name(), cost.dataflow.name())
                }
            },
            Choice::Passthrough { .. } => "pass".into(),
            Choice::StoreAs { child, fmt, precision, .. } => match precision {
                Precision::F32 => format!("store[{}]:{}", child, fmt.name()),
                Precision::Int8 => format!("store[{}]:{}/int8", child, fmt.name()),
            },
        }
    }
}

/// The constructed cost graph: PBQP problem + bookkeeping to map the
/// solution back onto CNN layers.
pub struct CostGraph {
    /// The PBQP instance (vertex cost vectors + edge matrices).
    pub problem: Problem,
    /// Domain metadata parallel to `problem.costs`.
    pub choices: Vec<Vec<Choice>>,
    /// `V_c` vertex of each CNN node.
    pub vc: BTreeMap<NodeId, usize>,
    /// `V_s` vertex of fan-out CNN nodes.
    pub vs: BTreeMap<NodeId, usize>,
    /// PBQP vertex of the CNN input node (SP-solve source).
    pub source: usize,
    /// PBQP vertex of the CNN output node (SP-solve sink).
    pub sink: usize,
}

/// The chosen mapping for one conv layer.
#[derive(Debug, Clone)]
pub struct LayerAssignment {
    /// CNN node id of the layer.
    pub node: NodeId,
    /// Layer name.
    pub name: String,
    /// The chosen (algorithm, precision, dataflow) cost tuple.
    pub cost: ConvCost,
}

/// A solved algorithm mapping with its latency breakdown.
#[derive(Debug, Clone)]
pub struct MappingResult {
    /// Chosen domain index per PBQP vertex.
    pub assignment: Vec<usize>,
    /// Total objective (seconds): compute + transitions.
    pub total_sec: f64,
    /// Σ node costs of the chosen assignment.
    pub compute_sec: f64,
    /// Σ edge (store+load) costs.
    pub transition_sec: f64,
    /// Per-conv-layer chosen (algorithm, precision, dataflow).
    pub layers: Vec<LayerAssignment>,
}

/// Fixed single-algorithm policies — the paper's baselines `bl_3..bl_5`
/// (§6.1.2) plus the greedy node-cost policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// bl3: im2col on every layer.
    Im2colOnly,
    /// bl4: kn2row wherever available (i.e. everywhere), im2col else.
    Kn2rowApplied,
    /// bl5: Winograd where applicable, im2col everywhere else.
    WinoApplied,
    /// greedy: per-layer argmin of node cost (ignores transitions).
    Greedy,
}

/// Cost-graph construction options.
#[derive(Debug, Clone, Copy)]
pub struct BuildOpts {
    /// Overlap weight streaming with compute (double-buffered weights):
    /// node cost = max(compute, weight transfer) instead of the sum.
    pub overlap_weight_load: bool,
    /// DSE step 5: keep consecutive-layer hand-offs on chip when both
    /// buffers fit in SRAM, skipping the DRAM round-trip.
    pub sram_fuse: bool,
}

impl Default for BuildOpts {
    fn default() -> BuildOpts {
        BuildOpts { overlap_weight_load: true, sram_fuse: true }
    }
}

impl CostGraph {
    /// Build the cost graph for a CNN on a fixed `P_SA1 × P_SA2` array.
    pub fn build(
        cnn: &Cnn,
        cm: &CostModel,
        tm: &TransitionModel,
        p1: usize,
        p2: usize,
        opts: BuildOpts,
    ) -> CostGraph {
        let overlap_weight_load = opts.overlap_weight_load;
        let mut problem = Problem::default();
        let mut choices: Vec<Vec<Choice>> = Vec::new();
        let mut vc = BTreeMap::new();
        let mut vs = BTreeMap::new();

        // --- V_c vertices ------------------------------------------------
        // per-layer cost tables are independent (Eq. 10–12 evaluated per
        // node over its algorithm × dataflow domain), so the expensive
        // half of construction fans out across layers; vertex insertion
        // below stays sequential to keep PBQP vertex ids deterministic
        let domains = parallel_map(&cnn.nodes, |_, node| {
            let (dom, costs): (Vec<Choice>, Vec<f64>) = match &node.op {
                Op::Conv(spec) => {
                    let opts = cm.layer_options(spec, p1, p2);
                    let weight_sec = |algo: Algo| -> f64 {
                        let elems = match algo {
                            Algo::Im2col | Algo::Kn2row => spec.weight_count() as f64,
                            Algo::Winograd { m, r } | Algo::WinogradStrided { m, r } => {
                                let pts = ((m + r - 1) * (m + r - 1)) as f64;
                                let rounds = ((spec.k1 * spec.k2).div_ceil(r * r)) as f64;
                                pts * rounds * (spec.c_in * spec.c_out) as f64
                            }
                        };
                        tm.device.xfer_sec(elems)
                    };
                    let mut dom = Vec::new();
                    let mut cv = Vec::new();
                    for c in opts {
                        let sec = if overlap_weight_load {
                            c.seconds.max(weight_sec(c.algo))
                        } else {
                            c.seconds + weight_sec(c.algo)
                        };
                        dom.push(Choice::Conv { node: node.id, cost: c });
                        cv.push(sec);
                    }
                    (dom, cv)
                }
                Op::Pool(p) => {
                    // §3.4 — HPU/VPU pipeline, P_pool parallel units: one
                    // intermediate result per cycle per unit; the HPU
                    // touches every input pixel once and the VPU overlaps.
                    // AvgPool runs on the same PU array with the adder
                    // tree in place of the max comparator (the paper's
                    // conv-lowering alternative is a *depthwise* conv —
                    // executing it as a dense GEMM on the CU would inflate
                    // work by C×, so the PU path is the faithful model).
                    let _ = PoolKind::Max;
                    let sec = (p.c * p.h1 * p.h2) as f64 / tm.device.pool_units as f64
                        * cm.device.cycle_time();
                    (vec![Choice::Passthrough { node: node.id, seconds: sec }], vec![sec])
                }
                Op::Fc { c_in, c_out } => {
                    let (df, cy) = super::gemm::best_dataflow(p1, p2, 1, *c_in, *c_out);
                    let _ = df;
                    // the serving layer executes FC as an im2col 1×1
                    // conv, so it calibrates with that family
                    let sec =
                        cm.calibration.apply("im2col", cy as f64 * cm.device.cycle_time());
                    let w = tm.device.xfer_sec((*c_in * *c_out) as f64);
                    let sec = if overlap_weight_load { sec.max(w) } else { sec + w };
                    (vec![Choice::Passthrough { node: node.id, seconds: sec }], vec![sec])
                }
                Op::Add { c, h1, h2 } => {
                    let sec = (*c * *h1 * *h2) as f64 / tm.device.pool_units as f64
                        * cm.device.cycle_time();
                    (vec![Choice::Passthrough { node: node.id, seconds: sec }], vec![sec])
                }
                Op::Input { .. } | Op::Concat { .. } | Op::Output => {
                    (vec![Choice::Passthrough { node: node.id, seconds: 0.0 }], vec![0.0])
                }
            };
            (dom, costs)
        });
        for (node, (dom, costs)) in cnn.nodes.iter().zip(domains) {
            let labels = dom.iter().map(|c| c.label()).collect();
            let v = problem.add_vertex(&node.name, costs, labels);
            choices.push(dom);
            vc.insert(node.id, v);
        }

        // input tensor dims a consumer expects on its inbound edge
        let consumer_dims = |node: NodeId| -> EdgeDims {
            match &cnn.node(node).op {
                Op::Conv(spec) => EdgeDims::for_conv(spec),
                Op::Pool(p) => EdgeDims::for_tensor(p.c, p.h1, p.h2),
                Op::Concat { c_out, h1, h2 } => EdgeDims::for_tensor(*c_out, *h1, *h2),
                Op::Add { c, h1, h2 } => EdgeDims::for_tensor(*c, *h1, *h2),
                Op::Fc { c_in, .. } => EdgeDims::for_tensor(*c_in, 1, 1),
                Op::Input { c, h1, h2 } => EdgeDims::for_tensor(*c, *h1, *h2),
                Op::Output => EdgeDims::for_tensor(1, 1, 1),
            }
        };

        // --- V_s vertices + edges ---------------------------------------
        for node in &cnn.nodes {
            let succs = cnn.successors(node.id);
            if succs.len() <= 1 {
                continue;
            }
            // domain: Σ_{b'} |A_{b'}| store choices (paper §5.1); the
            // stored copy inherits each child choice's precision domain
            // so precision coupling survives the fan-out indirection
            let mut dom = Vec::new();
            for &child in &succs {
                let d = consumer_dims(child);
                for ch in &choices[vc[&child]] {
                    let fmt = ch.in_format();
                    dom.push(Choice::StoreAs {
                        node: node.id,
                        child,
                        fmt,
                        precision: ch.precision(),
                        dims: d,
                        volume: d.volume(fmt, tm.wino_m, tm.wino_r),
                    });
                }
            }
            // deduplicate identical (child, fmt, precision) entries to
            // keep the domain small
            dom.dedup_by(|a, b| match (a, b) {
                (
                    Choice::StoreAs { child: c1, fmt: f1, precision: p1a, .. },
                    Choice::StoreAs { child: c2, fmt: f2, precision: p2a, .. },
                ) => c1 == c2 && f1 == f2 && p1a == p2a,
                _ => false,
            });
            let labels = dom.iter().map(|c| c.label()).collect();
            let costs = vec![0.0; dom.len()]; // V_s carries no node cost
            let v = problem.add_vertex(&format!("{}#store", node.name), costs, labels);
            choices.push(dom);
            vs.insert(node.id, v);
        }

        // --- edges --------------------------------------------------------
        // precision term shared by every edge kind: endpoints in
        // different precision domains pay one requantization pass over
        // the consumed layout
        let requant = |from: &Choice, to: &Choice, fmt: Format, d: &EdgeDims| -> f64 {
            if from.precision() != to.precision() {
                tm.requant_sec(fmt, d)
            } else {
                0.0
            }
        };
        for &(src, dst) in &cnn.edges {
            let d = consumer_dims(dst);
            if cnn.out_degree(src) <= 1 {
                // direct edge (V_c_src, V_c_dst):
                // T(m, n) = Store(out(m) → in(n), d) + Load(in(n), d)
                let (u, v) = (vc[&src], vc[&dst]);
                let m = Matrix::from_fn(
                    choices[u].len(),
                    choices[v].len(),
                    |i, j| {
                        let from = choices[u][i].out_format();
                        let to = choices[v][j].in_format();
                        let base = if opts.sram_fuse && tm.fits_on_chip(to, &d) {
                            tm.edge_sec_onchip(to, &d, p1)
                        } else {
                            tm.store_sec(from, to, &d) + tm.load_sec(to, &d)
                        };
                        base + requant(&choices[u][i], &choices[v][j], to, &d)
                    },
                );
                problem.add_edge(u, v, m);
            } else {
                // fan-out: edge (V_s_src, V_c_dst)
                let (u, v) = (vs[&src], vc[&dst]);
                let m = Matrix::from_fn(
                    choices[u].len(),
                    choices[v].len(),
                    |i, j| {
                        let needed = choices[v][j].in_format();
                        let base = match &choices[u][i] {
                            Choice::StoreAs { child, fmt, volume, .. } => {
                                if *child == dst && *fmt == needed {
                                    tm.load_sec(needed, &d)
                                } else {
                                    tm.mismatch_load_sec(*fmt, *volume, needed, &d)
                                }
                            }
                            _ => unreachable!("V_s domain holds StoreAs only"),
                        };
                        base + requant(&choices[u][i], &choices[v][j], needed, &d)
                    },
                );
                problem.add_edge(u, v, m);
            }
        }
        // fan-out: edges (V_c_src, V_s_src)
        for (&node, &sv) in &vs {
            let u = vc[&node];
            let m = Matrix::from_fn(choices[u].len(), choices[sv].len(), |i, j| {
                match &choices[sv][j] {
                    Choice::StoreAs { fmt, dims, .. } => {
                        tm.store_sec(choices[u][i].out_format(), *fmt, dims)
                            + requant(&choices[u][i], &choices[sv][j], *fmt, dims)
                    }
                    _ => unreachable!(),
                }
            });
            problem.add_edge(u, sv, m);
        }

        let source = vc[&cnn.input()];
        let sink = vc[&cnn.output()];
        CostGraph { problem, choices, vc, vs, source, sink }
    }

    /// Solve optimally: series-parallel PBQP (Thm 4.1) with brute-force
    /// fallback for small non-SP graphs.
    pub fn solve(&self, cnn: &Cnn) -> MappingResult {
        let sol = match solve_sp(&self.problem, self.source, self.sink) {
            Some(s) => s,
            None => {
                assert!(
                    search_space(&self.problem) < (1 << 24),
                    "graph is not series-parallel and too large for brute force"
                );
                solve_brute(&self.problem)
            }
        };
        self.mapping_from(cnn, sol)
    }

    /// Evaluate a fixed baseline policy (bl3/bl4/bl5/greedy). `V_s`
    /// store formats are chosen locally-optimally given the fixed layer
    /// algorithms (one pass of coordinate descent — exact because each
    /// `V_s` only neighbours fixed vertices).
    pub fn solve_policy(&self, cnn: &Cnn, policy: Policy) -> MappingResult {
        let n = self.problem.n();
        let mut assignment = vec![0usize; n];
        // conv + passthrough vertices
        for (v, dom) in self.choices.iter().enumerate() {
            let pick = match policy {
                Policy::Greedy => {
                    let c = &self.problem.costs[v];
                    (0..c.len()).min_by(|&a, &b| c[a].partial_cmp(&c[b]).unwrap()).unwrap()
                }
                _ => {
                    let mut pick = 0;
                    for (i, ch) in dom.iter().enumerate() {
                        if let Choice::Conv { cost, .. } = ch {
                            // the fixed bl3–bl5 baselines are f32
                            // policies; int8 domain entries (precision
                            // search) are never theirs to pick
                            if cost.precision != Precision::F32 {
                                continue;
                            }
                            let hit = match policy {
                                Policy::Im2colOnly => cost.algo == Algo::Im2col,
                                Policy::Kn2rowApplied => cost.algo == Algo::Kn2row,
                                Policy::WinoApplied => {
                                    matches!(cost.algo, Algo::Winograd { .. })
                                }
                                Policy::Greedy => unreachable!(),
                            };
                            if hit {
                                pick = i;
                                break;
                            }
                            // fallback for WinoApplied on non-wino layers
                            if cost.algo == Algo::Im2col {
                                pick = i;
                            }
                        }
                    }
                    pick
                }
            };
            assignment[v] = pick;
        }
        // V_s vertices: exact local optimum given fixed neighbours
        for (_, &sv) in &self.vs {
            let mut best = (f64::INFINITY, 0usize);
            for k in 0..self.choices[sv].len() {
                let mut c = self.problem.costs[sv][k];
                for e in &self.problem.edges {
                    if e.u == sv {
                        c += e.m.get(k, assignment[e.v]);
                    } else if e.v == sv {
                        c += e.m.get(assignment[e.u], k);
                    }
                }
                if c < best.0 {
                    best = (c, k);
                }
            }
            assignment[sv] = best.1;
        }
        let cost = self.problem.evaluate(&assignment);
        self.mapping_from(cnn, Solution { assignment, cost })
    }

    /// Turn a PBQP solution into a per-layer mapping with breakdown.
    pub fn mapping_from(&self, cnn: &Cnn, sol: Solution) -> MappingResult {
        let mut compute = 0.0;
        for (v, &k) in sol.assignment.iter().enumerate() {
            compute += self.problem.costs[v][k];
        }
        let mut layers = Vec::new();
        for node in &cnn.nodes {
            if !node.op.is_conv() {
                continue;
            }
            let v = self.vc[&node.id];
            if let Choice::Conv { cost, .. } = &self.choices[v][sol.assignment[v]] {
                layers.push(LayerAssignment {
                    node: node.id,
                    name: node.name.clone(),
                    cost: *cost,
                });
            }
        }
        MappingResult {
            total_sec: sol.cost,
            compute_sec: compute,
            transition_sec: sol.cost - compute,
            assignment: sol.assignment,
            layers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::device::Device;
    use crate::graph::zoo;

    fn models() -> (CostModel, TransitionModel) {
        let d = Device::alveo_u200();
        (CostModel::new(d.clone()), TransitionModel::new(d))
    }

    #[test]
    fn builds_for_mini() {
        let cnn = zoo::mini_inception();
        let (cm, tm) = models();
        let g = CostGraph::build(&cnn, &cm, &tm, 32, 32, BuildOpts::default());
        // every CNN node has a V_c; the fan-out stem has a V_s
        assert_eq!(g.vc.len(), cnn.nodes.len());
        assert!(!g.vs.is_empty(), "mini-inception has a fan-out stem");
        // conv domains have 2-3 entries
        for id in cnn.conv_nodes() {
            let d = g.choices[g.vc[&id]].len();
            assert!((2..=3).contains(&d), "conv domain size {d}");
        }
    }

    #[test]
    fn optimal_beats_or_ties_all_policies() {
        let cnn = zoo::mini_inception();
        let (cm, tm) = models();
        let g = CostGraph::build(&cnn, &cm, &tm, 32, 32, BuildOpts::default());
        let opt = g.solve(&cnn);
        for policy in
            [Policy::Im2colOnly, Policy::Kn2rowApplied, Policy::WinoApplied, Policy::Greedy]
        {
            let base = g.solve_policy(&cnn, policy);
            assert!(
                opt.total_sec <= base.total_sec + 1e-12,
                "OPT {} should ≤ {:?} {}",
                opt.total_sec,
                policy,
                base.total_sec
            );
        }
    }

    #[test]
    fn sp_solver_handles_googlenet_cost_graph() {
        let cnn = zoo::googlenet();
        let (cm, tm) = models();
        let g = CostGraph::build(&cnn, &cm, &tm, 92, 66, BuildOpts::default());
        let opt = g.solve(&cnn);
        assert!(opt.total_sec > 0.0);
        assert_eq!(opt.layers.len(), 57);
        // breakdown sums to total
        assert!(
            (opt.compute_sec + opt.transition_sec - opt.total_sec).abs() < 1e-9,
            "breakdown mismatch"
        );
    }

    #[test]
    fn precision_search_widens_domains_and_stays_optimal() {
        let cnn = zoo::mini_inception();
        let (mut cm, tm) = models();
        cm.precision_search = true;
        let g = CostGraph::build(&cnn, &cm, &tm, 16, 16, BuildOpts::default());
        // conv domains gain one int8 entry per quantizable algorithm
        for id in cnn.conv_nodes() {
            let d = g.choices[g.vc[&id]].len();
            assert!((4..=5).contains(&d), "conv domain size {d}");
        }
        // the widened problem still solves exactly: SP result == brute
        let opt = g.solve(&cnn);
        let brute = solve_brute(&g.problem);
        assert!(
            (opt.total_sec - brute.cost).abs() < 1e-12,
            "sp {} vs brute {}",
            opt.total_sec,
            brute.cost
        );
        // a strictly larger choice space can never cost more
        let g_f32 = CostGraph::build(
            &cnn,
            &CostModel { precision_search: false, ..cm.clone() },
            &tm,
            16,
            16,
            BuildOpts::default(),
        );
        let opt_f32 = g_f32.solve(&cnn);
        assert!(opt.total_sec <= opt_f32.total_sec + 1e-12);
        // f32 baseline policies keep picking f32 entries
        for policy in [Policy::Im2colOnly, Policy::Kn2rowApplied, Policy::WinoApplied] {
            let bl = g.solve_policy(&cnn, policy);
            assert!(bl
                .layers
                .iter()
                .all(|l| l.cost.precision == crate::quant::Precision::F32));
            assert!(opt.total_sec <= bl.total_sec + 1e-12);
        }
    }

    #[test]
    fn matches_brute_force_on_mini() {
        // mini-inception's cost graph is small enough to brute force —
        // the real-cost analogue of the random-matrix property test.
        let cnn = zoo::mini_inception();
        let (cm, tm) = models();
        let g = CostGraph::build(&cnn, &cm, &tm, 16, 16, BuildOpts::default());
        let opt = g.solve(&cnn);
        let brute = solve_brute(&g.problem);
        assert!(
            (opt.total_sec - brute.cost).abs() < 1e-12,
            "sp {} vs brute {}",
            opt.total_sec,
            brute.cost
        );
    }
}
