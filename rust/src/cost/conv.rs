//! Eq. 10–12 — per-layer convolution latency under each algorithm, and
//! Eq. 14 — effective PE utilization.

use super::device::{Device, DeviceCalibration, KernelThroughput};
use super::gemm::{self, Dataflow};
use crate::graph::layer::ConvSpec;
use crate::quant::Precision;

/// A GEMM-based convolution algorithm (paper §2.1). `Winograd { m, r }`
/// is the F(m×m, r×r) minimal-filtering variant; the paper evaluates
/// F(2×2, 3×3). `WinogradStrided` is the paper's future-work extension
/// (§7): stride-2 square kernels handled by input channel-splitting into
/// 4 stride-1 sub-convolutions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Toeplitz lowering: one large GEMM (Eq. 10).
    Im2col,
    /// Per-tap unit GEMMs + pad-and-accumulate (Eq. 11).
    Kn2row,
    /// Minimal-filtering `F(m×m, r×r)` in transform space (Eq. 12).
    Winograd {
        /// Output tile size per axis.
        m: usize,
        /// Kernel tile size per axis.
        r: usize,
    },
    /// §7 future-work extension: stride-2 square kernels via channel
    /// splitting into 4 stride-1 sub-convolutions.
    WinogradStrided {
        /// Output tile size per axis.
        m: usize,
        /// Kernel tile size per axis.
        r: usize,
    },
}

impl Algo {
    /// Full display name, including Winograd tile parameters.
    pub fn name(&self) -> String {
        match self {
            Algo::Im2col => "im2col".into(),
            Algo::Kn2row => "kn2row".into(),
            Algo::Winograd { m, r } => format!("winograd-f{m}x{r}"),
            Algo::WinogradStrided { m, r } => format!("winograd-strided-f{m}x{r}"),
        }
    }

    /// The family name shared by every variant of one algorithm — the
    /// key space of [`DeviceCalibration`] and the label the serving
    /// layer's algorithm maps use ("im2col", "kn2row", "winograd";
    /// the strided extension belongs to the Winograd family).
    pub fn family(&self) -> &'static str {
        match self {
            Algo::Im2col => "im2col",
            Algo::Kn2row => "kn2row",
            Algo::Winograd { .. } | Algo::WinogradStrided { .. } => "winograd",
        }
    }

    /// Algorithm families available for a layer (the `|A_i|` entries of
    /// the paper's cost vector). im2col and kn2row apply everywhere;
    /// Winograd needs a square kernel ≥ r and unit stride; the strided
    /// extension (if enabled) covers stride-2 square kernels.
    pub fn available(spec: &ConvSpec, wino_m: usize, wino_r: usize, strided_ext: bool) -> Vec<Algo> {
        let mut v = vec![Algo::Im2col, Algo::Kn2row];
        if spec.winograd_applicable(wino_r) {
            v.push(Algo::Winograd { m: wino_m, r: wino_r });
        } else if strided_ext && spec.s == 2 && spec.k1 == spec.k2 && spec.k1 >= wino_r {
            v.push(Algo::WinogradStrided { m: wino_m, r: wino_r });
        }
        v
    }

    /// Precisions this algorithm can execute with: im2col and kn2row
    /// quantize to int8; Winograd (and the strided extension) stays
    /// f32 because its transform-space arithmetic amplifies
    /// quantization error — the kernel layer enforces the same clamp.
    pub fn precisions(&self) -> &'static [Precision] {
        match self {
            Algo::Im2col | Algo::Kn2row => &Precision::ALL,
            Algo::Winograd { .. } | Algo::WinogradStrided { .. } => &[Precision::F32],
        }
    }
}

/// Fully-evaluated cost of one (layer, algorithm, precision, dataflow)
/// tuple.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConvCost {
    /// Algorithm the cost was evaluated for.
    pub algo: Algo,
    /// Arithmetic precision (int8 runs on the DSP-packed array).
    pub precision: Precision,
    /// Best (or forced) systolic dataflow.
    pub dataflow: Dataflow,
    /// Total systolic-array busy cycles (compute only).
    pub cycles: u64,
    /// Latency in seconds at the device clock.
    pub seconds: f64,
    /// MACs the algorithm actually performs (Winograd performs fewer
    /// "pixel" MACs but in transform space).
    pub macs: u64,
    /// Effective PE utilization μ (Eq. 14); for int8 the denominator
    /// counts the packed MAC capacity (`P1 · P2 · int8_macs_per_dsp`).
    pub utilization: f64,
    /// GEMM dims fed to the array, for reporting: (a, b, c, calls).
    pub gemm: (usize, usize, usize, usize),
}

/// The analytic cost model: device + Winograd hyper-parameters + the
/// stall-free-PE switch (naive mode exists for the ablation bench).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Target device meta data.
    pub device: Device,
    /// Winograd output tile size `m` of `F(m×m, r×r)`.
    pub wino_m: usize,
    /// Winograd kernel tile size `r` of `F(m×m, r×r)`.
    pub wino_r: usize,
    /// Use the §3.2 stall-free PE (one `I_SA` per GEMM instead of one
    /// per pass).
    pub stall_free: bool,
    /// Enable the strided-Winograd future-work extension.
    pub strided_winograd: bool,
    /// Restrict every layer to one dataflow (the Figs. 9/10 `bl1`/`bl2`
    /// NS-only baselines disable the §3.2 dataflow optimization).
    pub force_dataflow: Option<Dataflow>,
    /// Search int8 beside f32 per layer: [`CostModel::layer_options`]
    /// widens each conv domain from {algorithm} to
    /// {algorithm × precision}. Off by default — quantization changes
    /// numerics, so the precision axis is an explicit opt-in
    /// ([`crate::api::Compiler::precision_search`]).
    pub precision_search: bool,
    /// Profile-fitted per-algorithm correction applied to every
    /// latency this model reports (identity by default). Fitted by
    /// `tune::calibrate` from observed per-layer latencies so the DSE
    /// re-solves against what the hardware actually achieves.
    pub calibration: DeviceCalibration,
    /// Measured host-microkernel throughput
    /// ([`crate::kernels::KernelSelector::measure`]). When non-empty,
    /// f32 latencies are priced from the host GEMM rate (per-shape tile
    /// occupancy + per-call overhead) instead of the analytic overlay
    /// cycles, so the DSE maps for what the native serving path
    /// actually runs. Empty by default — nothing changes until a
    /// measured table is folded in.
    pub microkernels: KernelThroughput,
}

impl CostModel {
    /// A cost model over `device` with the paper's defaults
    /// (`F(2×2, 3×3)`, stall-free PEs, f32-only mapping).
    pub fn new(device: Device) -> CostModel {
        CostModel {
            device,
            wino_m: 2,
            wino_r: 3,
            stall_free: true,
            strided_winograd: false,
            force_dataflow: None,
            precision_search: false,
            calibration: DeviceCalibration::identity(),
            microkernels: KernelThroughput::default(),
        }
    }

    fn gemm_cycles(&self, p1: usize, p2: usize, df: Dataflow, a: usize, b: usize, c: usize) -> u64 {
        if self.stall_free {
            gemm::gemm_cycles(p1, p2, df, a, b, c)
        } else {
            gemm::gemm_cycles_naive(p1, p2, df, a, b, c)
        }
    }

    /// GEMM dimensions `(a, b, c, calls)` a layer presents to the
    /// systolic array under `algo`.
    ///
    /// * im2col (Eq. 10): one `(O1O2) × (K1K2·C_in) × C_out` GEMM.
    /// * kn2row (Eq. 11): `K1K2` calls of `(O1O2) × C_in × C_out`.
    /// * Winograd (Eq. 12): `(m+r−1)²·⌈K1K2/r²⌉` calls of
    ///   `(⌈H1/m⌉·⌈H2/m⌉) × C_in × C_out` in transform space.
    pub fn gemm_dims(&self, spec: &ConvSpec, algo: Algo) -> (usize, usize, usize, usize) {
        let o = spec.o1() * spec.o2();
        match algo {
            Algo::Im2col => (o, spec.k1 * spec.k2 * spec.c_in, spec.c_out, 1),
            Algo::Kn2row => (o, spec.c_in, spec.c_out, spec.k1 * spec.k2),
            Algo::Winograd { m, r } => {
                let tiles = spec.h1.div_ceil(m) * spec.h2.div_ceil(m);
                let points = (m + r - 1) * (m + r - 1);
                let rounds = (spec.k1 * spec.k2).div_ceil(r * r);
                (tiles, spec.c_in, spec.c_out, points * rounds)
            }
            Algo::WinogradStrided { m, r } => {
                // stride-2 decomposition: 4 stride-1 sub-convolutions on
                // half-resolution maps with ⌈K/2⌉-sized sub-kernels.
                let h1 = spec.h1.div_ceil(2);
                let h2 = spec.h2.div_ceil(2);
                let k = spec.k1.div_ceil(2).max(r);
                let tiles = h1.div_ceil(m) * h2.div_ceil(m);
                let points = (m + r - 1) * (m + r - 1);
                let rounds = (k * k).div_ceil(r * r);
                (tiles, spec.c_in, spec.c_out, 4 * points * rounds)
            }
        }
    }

    /// Linear-transform overhead per Winograd GEMM call (the `LT` term of
    /// Eq. 12). The transform modules are shift-add pipelines processing
    /// `P_SA1` tiles per cycle in parallel with array feeding, so the
    /// exposed overhead is the pipeline fill of one tile batch:
    /// `⌈tiles/P_SA1⌉ + (m+r−1)` cycles.
    fn lt_cycles(&self, p1: usize, tiles: usize, m: usize, r: usize) -> u64 {
        (tiles.div_ceil(p1) + (m + r - 1)) as u64
    }

    /// DSP-packing factor a precision runs the array at.
    fn packing(&self, precision: Precision) -> usize {
        match precision {
            Precision::F32 => 1,
            Precision::Int8 => self.device.int8_macs_per_dsp.max(1),
        }
    }

    /// Evaluate one (layer, algorithm, precision, dataflow):
    /// Eq. 10–12 + Eq. 14, with int8 priced as a
    /// `P_SA1 × (P_SA2 · int8_macs_per_dsp)` array on the same DSP
    /// budget (DSP packing).
    pub fn conv_cost_at(
        &self,
        spec: &ConvSpec,
        algo: Algo,
        precision: Precision,
        df: Dataflow,
        p1: usize,
        p2: usize,
    ) -> ConvCost {
        let packing = self.packing(precision);
        let (a, b, c, calls) = self.gemm_dims(spec, algo);
        let per_call = self.gemm_cycles(p1, p2 * packing, df, a, b, c);
        let lt = match algo {
            Algo::Winograd { m, r } | Algo::WinogradStrided { m, r } => {
                self.lt_cycles(p1, a, m, r)
            }
            _ => 0,
        };
        let cycles = (per_call + lt) * calls as u64;
        let macs = gemm::gemm_macs(a, b, c) * calls as u64;
        let pes = (p1 * p2 * packing) as f64;
        // `cycles` stays the raw analytic count (it also feeds Eq. 14);
        // the calibration corrects the wall-clock estimate only, so a
        // family-uniform affine fit never reorders dataflows within a
        // family but does reorder algorithms against each other. The
        // calibration key carries the precision ("im2col" vs
        // "im2col-int8"): a host's int8 observed/analytic ratio differs
        // systematically from its f32 one, so the two regimes must
        // never pool into one fit. f32 keys are the bare family name,
        // keeping every pre-quantization calibration bit-identical.
        //
        // A measured microkernel table replaces the *f32* wall-clock
        // estimate with the host GEMM rate (the native serving path
        // runs these exact GEMM shapes on the SIMD tier); int8 layers
        // keep the analytic overlay price — the qgemm path is not part
        // of the measured tier. The calibration still applies on top,
        // in both regimes.
        let key = crate::quant::mapped_name(algo.family(), precision);
        let analytic = cycles as f64 * self.device.cycle_time();
        let host = match precision {
            Precision::F32 => self
                .microkernels
                .gemm_sec(a, b, c)
                .map(|per_call| per_call * calls as f64),
            Precision::Int8 => None,
        };
        let seconds = self.calibration.apply(&key, host.unwrap_or(analytic));
        ConvCost {
            algo,
            precision,
            dataflow: df,
            cycles,
            seconds,
            macs,
            utilization: macs as f64 / (cycles as f64 * pes),
            gemm: (a, b, c, calls),
        }
    }

    /// [`CostModel::conv_cost_at`] at f32 — the pre-quantization call
    /// shape, kept for the overlay simulator and figure code.
    pub fn conv_cost(
        &self,
        spec: &ConvSpec,
        algo: Algo,
        df: Dataflow,
        p1: usize,
        p2: usize,
    ) -> ConvCost {
        self.conv_cost_at(spec, algo, Precision::F32, df, p1, p2)
    }

    /// Best dataflow for a (layer, algorithm, precision) tuple on a
    /// fixed array — the inner loop of Algorithm 1 (lines 7–9).
    /// Honours `force_dataflow` for the NS-only baselines.
    pub fn best_conv_cost_at(
        &self,
        spec: &ConvSpec,
        algo: Algo,
        precision: Precision,
        p1: usize,
        p2: usize,
    ) -> ConvCost {
        if let Some(df) = self.force_dataflow {
            return self.conv_cost_at(spec, algo, precision, df, p1, p2);
        }
        Dataflow::ALL
            .iter()
            .map(|&df| self.conv_cost_at(spec, algo, precision, df, p1, p2))
            .min_by(|x, y| x.cycles.cmp(&y.cycles))
            .unwrap()
    }

    /// [`CostModel::best_conv_cost_at`] at f32.
    pub fn best_conv_cost(&self, spec: &ConvSpec, algo: Algo, p1: usize, p2: usize) -> ConvCost {
        self.best_conv_cost_at(spec, algo, Precision::F32, p1, p2)
    }

    /// All available (algorithm, precision) choices with their best
    /// dataflow for a layer — the PBQP vertex domain. Without
    /// `precision_search` only the f32 entries are produced (the
    /// pre-quantization domain, bit-identical costs). With it, each
    /// quantizable algorithm contributes an int8 entry after its f32
    /// one, so exact ties keep full precision.
    pub fn layer_options(&self, spec: &ConvSpec, p1: usize, p2: usize) -> Vec<ConvCost> {
        let mut out = Vec::new();
        for algo in Algo::available(spec, self.wino_m, self.wino_r, self.strided_winograd) {
            for &precision in algo.precisions() {
                if precision != Precision::F32 && !self.precision_search {
                    continue;
                }
                out.push(self.best_conv_cost_at(spec, algo, precision, p1, p2));
            }
        }
        out
    }

    /// Compute-and-memory load summary used by Fig. 1: returns
    /// `(mult_ops, memory_elems)` for a layer under an algorithm —
    /// multiplications performed and activation elements moved
    /// (input-format volume + output volume).
    pub fn loads(&self, spec: &ConvSpec, algo: Algo) -> (u64, u64) {
        let (a, b, c, calls) = self.gemm_dims(spec, algo);
        let mults = gemm::gemm_macs(a, b, c) * calls as u64;
        let mem = match algo {
            Algo::Im2col => {
                // Toeplitz input duplication + output
                (spec.o1() * spec.o2() * spec.k1 * spec.k2 * spec.c_in
                    + spec.output_count()) as u64
            }
            Algo::Kn2row => {
                // 3D tensor in + intermediate patch accumulation + out
                (spec.input_count() + 2 * spec.output_count()) as u64
            }
            Algo::Winograd { m, r } | Algo::WinogradStrided { m, r } => {
                let tiles = spec.h1.div_ceil(m) * spec.h2.div_ceil(m);
                let points = (m + r - 1) * (m + r - 1);
                (tiles * points * spec.c_in + tiles * points * spec.c_out) as u64
            }
        };
        (mults, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::new(Device::alveo_u200())
    }

    fn layer_3x3() -> ConvSpec {
        // 28×28, 3×3 same, 64→128 (GoogLeNet-like)
        ConvSpec::new(64, 128, 28, 28, 3, 3, 1, 1, 1)
    }

    #[test]
    fn im2col_gemm_dims() {
        let m = model();
        let (a, b, c, calls) = m.gemm_dims(&layer_3x3(), Algo::Im2col);
        assert_eq!((a, b, c, calls), (28 * 28, 9 * 64, 128, 1));
    }

    #[test]
    fn kn2row_is_k2_unit_gemms() {
        let m = model();
        let (a, b, c, calls) = m.gemm_dims(&layer_3x3(), Algo::Kn2row);
        assert_eq!((a, b, c, calls), (28 * 28, 64, 128, 9));
    }

    #[test]
    fn winograd_reduces_mults() {
        let m = model();
        let spec = layer_3x3();
        let (mults_wino, _) = m.loads(&spec, Algo::Winograd { m: 2, r: 3 });
        let (mults_im2col, _) = m.loads(&spec, Algo::Im2col);
        // F(2,3): 16 mults per 4-output tile vs 36 direct → 2.25×
        let ratio = mults_im2col as f64 / mults_wino as f64;
        assert!((1.8..2.6).contains(&ratio), "winograd mult reduction ratio {ratio}");
    }

    #[test]
    fn winograd_f4_reduction_matches_paper() {
        // paper §2.1.3: F(4×4, 3×3) needs 36 mults/tile vs 144 spatial —
        // exactly 4×. Check the asymptotic ratio on a large layer where
        // the ceil() effects vanish.
        let mut m = model();
        m.wino_m = 4;
        let spec = ConvSpec::new(64, 64, 256, 256, 3, 3, 1, 1, 1);
        let (w, _) = m.loads(&spec, Algo::Winograd { m: 4, r: 3 });
        let (d, _) = m.loads(&spec, Algo::Im2col);
        let ratio = d as f64 / w as f64;
        assert!((3.5..4.1).contains(&ratio), "F(4,3) reduction {ratio} ≈ 4");
    }

    #[test]
    fn kn2row_never_more_mults_than_im2col() {
        let m = model();
        for spec in [
            layer_3x3(),
            ConvSpec::new(32, 64, 17, 17, 1, 7, 1, 0, 3),
            ConvSpec::new(16, 32, 56, 56, 5, 5, 1, 2, 2),
        ] {
            let (ki, _) = m.loads(&spec, Algo::Kn2row);
            let (ii, _) = m.loads(&spec, Algo::Im2col);
            // same multiplication count for stride 1 (O1O2 == H1H2)
            assert_eq!(ki, ii);
        }
    }

    #[test]
    fn best_dataflow_beats_or_ties_ns() {
        let m = model();
        let spec = ConvSpec::new(48, 64, 35, 35, 7, 1, 1, 3, 0);
        for algo in Algo::available(&spec, 2, 3, false) {
            let best = m.best_conv_cost(&spec, algo, 92, 66);
            let ns = m.conv_cost(&spec, algo, Dataflow::NS, 92, 66);
            assert!(best.cycles <= ns.cycles);
            assert!(best.utilization >= ns.utilization - 1e-12);
        }
    }

    #[test]
    fn utilization_in_unit_interval() {
        let m = model();
        let spec = layer_3x3();
        for algo in Algo::available(&spec, 2, 3, false) {
            for df in Dataflow::ALL {
                let c = m.conv_cost(&spec, algo, df, 92, 66);
                assert!(c.utilization > 0.0 && c.utilization <= 1.0, "{:?}", c);
            }
        }
    }

    #[test]
    fn availability_rules() {
        // 1×7 kernel: no winograd
        let spec = ConvSpec::new(8, 8, 17, 17, 1, 7, 1, 0, 3);
        assert_eq!(Algo::available(&spec, 2, 3, false).len(), 2);
        // 3×3 stride 1: all three
        assert_eq!(Algo::available(&layer_3x3(), 2, 3, false).len(), 3);
        // 3×3 stride 2: strided extension only when enabled
        let s2 = ConvSpec::new(8, 8, 16, 16, 3, 3, 2, 1, 1);
        assert_eq!(Algo::available(&s2, 2, 3, false).len(), 2);
        assert_eq!(Algo::available(&s2, 2, 3, true).len(), 3);
    }

    #[test]
    fn calibration_rescales_one_family_only() {
        let mut m = model();
        let spec = layer_3x3();
        let base_kn = m.best_conv_cost(&spec, Algo::Kn2row, 64, 64);
        let base_im = m.best_conv_cost(&spec, Algo::Im2col, 64, 64);
        m.calibration = DeviceCalibration::default().with("kn2row", 10.0, 0.0);
        let cal_kn = m.best_conv_cost(&spec, Algo::Kn2row, 64, 64);
        let cal_im = m.best_conv_cost(&spec, Algo::Im2col, 64, 64);
        assert!((cal_kn.seconds / base_kn.seconds - 10.0).abs() < 1e-9);
        assert_eq!(cal_im.seconds, base_im.seconds, "other families untouched");
        assert_eq!(cal_kn.cycles, base_kn.cycles, "raw cycle count is preserved");
        assert_eq!(cal_kn.dataflow, base_kn.dataflow, "uniform fit keeps the dataflow");
    }

    #[test]
    fn microkernel_table_reprices_f32_only() {
        let mut m = model();
        let spec = layer_3x3();
        let base_f32 = m.best_conv_cost(&spec, Algo::Im2col, 64, 64);
        let base_i8 = m.best_conv_cost_at(&spec, Algo::Im2col, Precision::Int8, 64, 64);
        m.microkernels = KernelThroughput::default().with("avx2-4x16", 8.0);
        let host_f32 = m.best_conv_cost(&spec, Algo::Im2col, 64, 64);
        let host_i8 = m.best_conv_cost_at(&spec, Algo::Im2col, Precision::Int8, 64, 64);
        // f32 now priced by the host table: per-call gemm_sec × calls
        let (a, b, c, calls) = m.gemm_dims(&spec, Algo::Im2col);
        let expect = m.microkernels.gemm_sec(a, b, c).unwrap() * calls as f64;
        assert!((host_f32.seconds - expect).abs() < 1e-15, "{} vs {expect}", host_f32.seconds);
        assert_ne!(host_f32.seconds, base_f32.seconds);
        // raw cycles (and so Eq. 14 utilization) are untouched, and the
        // int8 overlay price is out of the measured tier's scope
        assert_eq!(host_f32.cycles, base_f32.cycles);
        assert_eq!(host_f32.utilization, base_f32.utilization);
        assert_eq!(host_i8.seconds, base_i8.seconds);
    }

    #[test]
    fn call_overhead_penalizes_many_call_algorithms() {
        let mut m = model();
        let spec = layer_3x3();
        // overhead-dominated table: 1 ms per GEMM call dwarfs compute
        m.microkernels =
            KernelThroughput::default().with("avx2-4x16", 50.0).with_call_overhead(1e-3);
        let im = m.best_conv_cost(&spec, Algo::Im2col, 64, 64);
        let kn = m.best_conv_cost(&spec, Algo::Kn2row, 64, 64);
        let wino = m.best_conv_cost(&spec, Algo::Winograd { m: 2, r: 3 }, 64, 64);
        // 1 call vs 9 taps vs 16 transform-space point GEMMs
        assert!(im.seconds < kn.seconds);
        assert!(kn.seconds < wino.seconds);
    }

    #[test]
    fn int8_packing_never_slower_and_utilization_bounded() {
        let m = model();
        let spec = layer_3x3();
        for algo in [Algo::Im2col, Algo::Kn2row] {
            let f = m.best_conv_cost_at(&spec, algo, Precision::F32, 92, 66);
            let q = m.best_conv_cost_at(&spec, algo, Precision::Int8, 92, 66);
            assert!(q.cycles <= f.cycles, "{algo:?}: int8 {} > f32 {}", q.cycles, f.cycles);
            assert!(q.utilization > 0.0 && q.utilization <= 1.0 + 1e-12, "{q:?}");
            assert_eq!(q.precision, Precision::Int8);
            assert_eq!(f.precision, Precision::F32);
        }
        // a wide layer sees close to the full 2x packing win
        let wide = ConvSpec::new(64, 256, 28, 28, 3, 3, 1, 1, 1);
        let f = m.best_conv_cost_at(&wide, Algo::Im2col, Precision::F32, 64, 64);
        let q = m.best_conv_cost_at(&wide, Algo::Im2col, Precision::Int8, 64, 64);
        let ratio = f.cycles as f64 / q.cycles as f64;
        assert!((1.6..=2.1).contains(&ratio), "packing ratio {ratio}");
    }

    #[test]
    fn precision_search_widens_the_domain_f32_first() {
        let mut m = model();
        let spec = layer_3x3();
        let base = m.layer_options(&spec, 32, 32);
        assert_eq!(base.len(), 3, "f32-only domain: one entry per algorithm");
        assert!(base.iter().all(|c| c.precision == Precision::F32));
        m.precision_search = true;
        let wide = m.layer_options(&spec, 32, 32);
        // im2col and kn2row gain an int8 entry; winograd stays f32-only
        assert_eq!(wide.len(), 5);
        assert!(wide
            .iter()
            .all(|c| !matches!(c.algo, Algo::Winograd { .. }) || c.precision == Precision::F32));
        for pair in wide.chunks(2).take(2) {
            assert_eq!(pair[0].algo, pair[1].algo);
            assert_eq!(pair[0].precision, Precision::F32, "f32 precedes int8 per algo");
            assert_eq!(pair[1].precision, Precision::Int8);
        }
    }

    #[test]
    fn seconds_scale_with_frequency() {
        let mut m = model();
        let c1 = m.best_conv_cost(&layer_3x3(), Algo::Im2col, 64, 64);
        m.device.freq_mhz *= 2.0;
        let c2 = m.best_conv_cost(&layer_3x3(), Algo::Im2col, 64, 64);
        assert_eq!(c1.cycles, c2.cycles);
        assert!((c1.seconds / c2.seconds - 2.0).abs() < 1e-9);
    }
}
