//! Eq. 10–12 — per-layer convolution latency under each algorithm, and
//! Eq. 14 — effective PE utilization.

use super::device::{Device, DeviceCalibration};
use super::gemm::{self, Dataflow};
use crate::graph::layer::ConvSpec;

/// A GEMM-based convolution algorithm (paper §2.1). `Winograd { m, r }`
/// is the F(m×m, r×r) minimal-filtering variant; the paper evaluates
/// F(2×2, 3×3). `WinogradStrided` is the paper's future-work extension
/// (§7): stride-2 square kernels handled by input channel-splitting into
/// 4 stride-1 sub-convolutions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    Im2col,
    Kn2row,
    Winograd { m: usize, r: usize },
    WinogradStrided { m: usize, r: usize },
}

impl Algo {
    pub fn name(&self) -> String {
        match self {
            Algo::Im2col => "im2col".into(),
            Algo::Kn2row => "kn2row".into(),
            Algo::Winograd { m, r } => format!("winograd-f{m}x{r}"),
            Algo::WinogradStrided { m, r } => format!("winograd-strided-f{m}x{r}"),
        }
    }

    /// The family name shared by every variant of one algorithm — the
    /// key space of [`DeviceCalibration`] and the label the serving
    /// layer's algorithm maps use ("im2col", "kn2row", "winograd";
    /// the strided extension belongs to the Winograd family).
    pub fn family(&self) -> &'static str {
        match self {
            Algo::Im2col => "im2col",
            Algo::Kn2row => "kn2row",
            Algo::Winograd { .. } | Algo::WinogradStrided { .. } => "winograd",
        }
    }

    /// Algorithm families available for a layer (the `|A_i|` entries of
    /// the paper's cost vector). im2col and kn2row apply everywhere;
    /// Winograd needs a square kernel ≥ r and unit stride; the strided
    /// extension (if enabled) covers stride-2 square kernels.
    pub fn available(spec: &ConvSpec, wino_m: usize, wino_r: usize, strided_ext: bool) -> Vec<Algo> {
        let mut v = vec![Algo::Im2col, Algo::Kn2row];
        if spec.winograd_applicable(wino_r) {
            v.push(Algo::Winograd { m: wino_m, r: wino_r });
        } else if strided_ext && spec.s == 2 && spec.k1 == spec.k2 && spec.k1 >= wino_r {
            v.push(Algo::WinogradStrided { m: wino_m, r: wino_r });
        }
        v
    }
}

/// Fully-evaluated cost of one (layer, algorithm, dataflow) triple.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConvCost {
    pub algo: Algo,
    pub dataflow: Dataflow,
    /// Total systolic-array busy cycles (compute only).
    pub cycles: u64,
    /// Latency in seconds at the device clock.
    pub seconds: f64,
    /// MACs the algorithm actually performs (Winograd performs fewer
    /// "pixel" MACs but in transform space).
    pub macs: u64,
    /// Effective PE utilization μ (Eq. 14).
    pub utilization: f64,
    /// GEMM dims fed to the array, for reporting: (a, b, c, calls).
    pub gemm: (usize, usize, usize, usize),
}

/// The analytic cost model: device + Winograd hyper-parameters + the
/// stall-free-PE switch (naive mode exists for the ablation bench).
#[derive(Debug, Clone)]
pub struct CostModel {
    pub device: Device,
    pub wino_m: usize,
    pub wino_r: usize,
    pub stall_free: bool,
    /// Enable the strided-Winograd future-work extension.
    pub strided_winograd: bool,
    /// Restrict every layer to one dataflow (the Figs. 9/10 `bl1`/`bl2`
    /// NS-only baselines disable the §3.2 dataflow optimization).
    pub force_dataflow: Option<Dataflow>,
    /// Profile-fitted per-algorithm correction applied to every
    /// latency this model reports (identity by default). Fitted by
    /// `tune::calibrate` from observed per-layer latencies so the DSE
    /// re-solves against what the hardware actually achieves.
    pub calibration: DeviceCalibration,
}

impl CostModel {
    pub fn new(device: Device) -> CostModel {
        CostModel {
            device,
            wino_m: 2,
            wino_r: 3,
            stall_free: true,
            strided_winograd: false,
            force_dataflow: None,
            calibration: DeviceCalibration::identity(),
        }
    }

    fn gemm_cycles(&self, p1: usize, p2: usize, df: Dataflow, a: usize, b: usize, c: usize) -> u64 {
        if self.stall_free {
            gemm::gemm_cycles(p1, p2, df, a, b, c)
        } else {
            gemm::gemm_cycles_naive(p1, p2, df, a, b, c)
        }
    }

    /// GEMM dimensions `(a, b, c, calls)` a layer presents to the
    /// systolic array under `algo`.
    ///
    /// * im2col (Eq. 10): one `(O1O2) × (K1K2·C_in) × C_out` GEMM.
    /// * kn2row (Eq. 11): `K1K2` calls of `(O1O2) × C_in × C_out`.
    /// * Winograd (Eq. 12): `(m+r−1)²·⌈K1K2/r²⌉` calls of
    ///   `(⌈H1/m⌉·⌈H2/m⌉) × C_in × C_out` in transform space.
    pub fn gemm_dims(&self, spec: &ConvSpec, algo: Algo) -> (usize, usize, usize, usize) {
        let o = spec.o1() * spec.o2();
        match algo {
            Algo::Im2col => (o, spec.k1 * spec.k2 * spec.c_in, spec.c_out, 1),
            Algo::Kn2row => (o, spec.c_in, spec.c_out, spec.k1 * spec.k2),
            Algo::Winograd { m, r } => {
                let tiles = spec.h1.div_ceil(m) * spec.h2.div_ceil(m);
                let points = (m + r - 1) * (m + r - 1);
                let rounds = (spec.k1 * spec.k2).div_ceil(r * r);
                (tiles, spec.c_in, spec.c_out, points * rounds)
            }
            Algo::WinogradStrided { m, r } => {
                // stride-2 decomposition: 4 stride-1 sub-convolutions on
                // half-resolution maps with ⌈K/2⌉-sized sub-kernels.
                let h1 = spec.h1.div_ceil(2);
                let h2 = spec.h2.div_ceil(2);
                let k = spec.k1.div_ceil(2).max(r);
                let tiles = h1.div_ceil(m) * h2.div_ceil(m);
                let points = (m + r - 1) * (m + r - 1);
                let rounds = (k * k).div_ceil(r * r);
                (tiles, spec.c_in, spec.c_out, 4 * points * rounds)
            }
        }
    }

    /// Linear-transform overhead per Winograd GEMM call (the `LT` term of
    /// Eq. 12). The transform modules are shift-add pipelines processing
    /// `P_SA1` tiles per cycle in parallel with array feeding, so the
    /// exposed overhead is the pipeline fill of one tile batch:
    /// `⌈tiles/P_SA1⌉ + (m+r−1)` cycles.
    fn lt_cycles(&self, p1: usize, tiles: usize, m: usize, r: usize) -> u64 {
        (tiles.div_ceil(p1) + (m + r - 1)) as u64
    }

    /// Evaluate one (layer, algorithm, dataflow): Eq. 10–12 + Eq. 14.
    pub fn conv_cost(
        &self,
        spec: &ConvSpec,
        algo: Algo,
        df: Dataflow,
        p1: usize,
        p2: usize,
    ) -> ConvCost {
        let (a, b, c, calls) = self.gemm_dims(spec, algo);
        let per_call = self.gemm_cycles(p1, p2, df, a, b, c);
        let lt = match algo {
            Algo::Winograd { m, r } | Algo::WinogradStrided { m, r } => {
                self.lt_cycles(p1, a, m, r)
            }
            _ => 0,
        };
        let cycles = (per_call + lt) * calls as u64;
        let macs = gemm::gemm_macs(a, b, c) * calls as u64;
        let pes = (p1 * p2) as f64;
        // `cycles` stays the raw analytic count (it also feeds Eq. 14);
        // the calibration corrects the wall-clock estimate only, so a
        // family-uniform affine fit never reorders dataflows within a
        // family but does reorder algorithms against each other
        let seconds = self
            .calibration
            .apply(algo.family(), cycles as f64 * self.device.cycle_time());
        ConvCost {
            algo,
            dataflow: df,
            cycles,
            seconds,
            macs,
            utilization: macs as f64 / (cycles as f64 * pes),
            gemm: (a, b, c, calls),
        }
    }

    /// Best dataflow for a (layer, algorithm) pair on a fixed array —
    /// the inner loop of Algorithm 1 (lines 7–9). Honours
    /// `force_dataflow` for the NS-only baselines.
    pub fn best_conv_cost(&self, spec: &ConvSpec, algo: Algo, p1: usize, p2: usize) -> ConvCost {
        if let Some(df) = self.force_dataflow {
            return self.conv_cost(spec, algo, df, p1, p2);
        }
        Dataflow::ALL
            .iter()
            .map(|&df| self.conv_cost(spec, algo, df, p1, p2))
            .min_by(|x, y| x.cycles.cmp(&y.cycles))
            .unwrap()
    }

    /// All available algorithms with their best dataflow for a layer.
    pub fn layer_options(&self, spec: &ConvSpec, p1: usize, p2: usize) -> Vec<ConvCost> {
        Algo::available(spec, self.wino_m, self.wino_r, self.strided_winograd)
            .into_iter()
            .map(|algo| self.best_conv_cost(spec, algo, p1, p2))
            .collect()
    }

    /// Compute-and-memory load summary used by Fig. 1: returns
    /// `(mult_ops, memory_elems)` for a layer under an algorithm —
    /// multiplications performed and activation elements moved
    /// (input-format volume + output volume).
    pub fn loads(&self, spec: &ConvSpec, algo: Algo) -> (u64, u64) {
        let (a, b, c, calls) = self.gemm_dims(spec, algo);
        let mults = gemm::gemm_macs(a, b, c) * calls as u64;
        let mem = match algo {
            Algo::Im2col => {
                // Toeplitz input duplication + output
                (spec.o1() * spec.o2() * spec.k1 * spec.k2 * spec.c_in
                    + spec.output_count()) as u64
            }
            Algo::Kn2row => {
                // 3D tensor in + intermediate patch accumulation + out
                (spec.input_count() + 2 * spec.output_count()) as u64
            }
            Algo::Winograd { m, r } | Algo::WinogradStrided { m, r } => {
                let tiles = spec.h1.div_ceil(m) * spec.h2.div_ceil(m);
                let points = (m + r - 1) * (m + r - 1);
                (tiles * points * spec.c_in + tiles * points * spec.c_out) as u64
            }
        };
        (mults, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::new(Device::alveo_u200())
    }

    fn layer_3x3() -> ConvSpec {
        // 28×28, 3×3 same, 64→128 (GoogLeNet-like)
        ConvSpec::new(64, 128, 28, 28, 3, 3, 1, 1, 1)
    }

    #[test]
    fn im2col_gemm_dims() {
        let m = model();
        let (a, b, c, calls) = m.gemm_dims(&layer_3x3(), Algo::Im2col);
        assert_eq!((a, b, c, calls), (28 * 28, 9 * 64, 128, 1));
    }

    #[test]
    fn kn2row_is_k2_unit_gemms() {
        let m = model();
        let (a, b, c, calls) = m.gemm_dims(&layer_3x3(), Algo::Kn2row);
        assert_eq!((a, b, c, calls), (28 * 28, 64, 128, 9));
    }

    #[test]
    fn winograd_reduces_mults() {
        let m = model();
        let spec = layer_3x3();
        let (mults_wino, _) = m.loads(&spec, Algo::Winograd { m: 2, r: 3 });
        let (mults_im2col, _) = m.loads(&spec, Algo::Im2col);
        // F(2,3): 16 mults per 4-output tile vs 36 direct → 2.25×
        let ratio = mults_im2col as f64 / mults_wino as f64;
        assert!((1.8..2.6).contains(&ratio), "winograd mult reduction ratio {ratio}");
    }

    #[test]
    fn winograd_f4_reduction_matches_paper() {
        // paper §2.1.3: F(4×4, 3×3) needs 36 mults/tile vs 144 spatial —
        // exactly 4×. Check the asymptotic ratio on a large layer where
        // the ceil() effects vanish.
        let mut m = model();
        m.wino_m = 4;
        let spec = ConvSpec::new(64, 64, 256, 256, 3, 3, 1, 1, 1);
        let (w, _) = m.loads(&spec, Algo::Winograd { m: 4, r: 3 });
        let (d, _) = m.loads(&spec, Algo::Im2col);
        let ratio = d as f64 / w as f64;
        assert!((3.5..4.1).contains(&ratio), "F(4,3) reduction {ratio} ≈ 4");
    }

    #[test]
    fn kn2row_never_more_mults_than_im2col() {
        let m = model();
        for spec in [
            layer_3x3(),
            ConvSpec::new(32, 64, 17, 17, 1, 7, 1, 0, 3),
            ConvSpec::new(16, 32, 56, 56, 5, 5, 1, 2, 2),
        ] {
            let (ki, _) = m.loads(&spec, Algo::Kn2row);
            let (ii, _) = m.loads(&spec, Algo::Im2col);
            // same multiplication count for stride 1 (O1O2 == H1H2)
            assert_eq!(ki, ii);
        }
    }

    #[test]
    fn best_dataflow_beats_or_ties_ns() {
        let m = model();
        let spec = ConvSpec::new(48, 64, 35, 35, 7, 1, 1, 3, 0);
        for algo in Algo::available(&spec, 2, 3, false) {
            let best = m.best_conv_cost(&spec, algo, 92, 66);
            let ns = m.conv_cost(&spec, algo, Dataflow::NS, 92, 66);
            assert!(best.cycles <= ns.cycles);
            assert!(best.utilization >= ns.utilization - 1e-12);
        }
    }

    #[test]
    fn utilization_in_unit_interval() {
        let m = model();
        let spec = layer_3x3();
        for algo in Algo::available(&spec, 2, 3, false) {
            for df in Dataflow::ALL {
                let c = m.conv_cost(&spec, algo, df, 92, 66);
                assert!(c.utilization > 0.0 && c.utilization <= 1.0, "{:?}", c);
            }
        }
    }

    #[test]
    fn availability_rules() {
        // 1×7 kernel: no winograd
        let spec = ConvSpec::new(8, 8, 17, 17, 1, 7, 1, 0, 3);
        assert_eq!(Algo::available(&spec, 2, 3, false).len(), 2);
        // 3×3 stride 1: all three
        assert_eq!(Algo::available(&layer_3x3(), 2, 3, false).len(), 3);
        // 3×3 stride 2: strided extension only when enabled
        let s2 = ConvSpec::new(8, 8, 16, 16, 3, 3, 2, 1, 1);
        assert_eq!(Algo::available(&s2, 2, 3, false).len(), 2);
        assert_eq!(Algo::available(&s2, 2, 3, true).len(), 3);
    }

    #[test]
    fn calibration_rescales_one_family_only() {
        let mut m = model();
        let spec = layer_3x3();
        let base_kn = m.best_conv_cost(&spec, Algo::Kn2row, 64, 64);
        let base_im = m.best_conv_cost(&spec, Algo::Im2col, 64, 64);
        m.calibration = DeviceCalibration::default().with("kn2row", 10.0, 0.0);
        let cal_kn = m.best_conv_cost(&spec, Algo::Kn2row, 64, 64);
        let cal_im = m.best_conv_cost(&spec, Algo::Im2col, 64, 64);
        assert!((cal_kn.seconds / base_kn.seconds - 10.0).abs() < 1e-9);
        assert_eq!(cal_im.seconds, base_im.seconds, "other families untouched");
        assert_eq!(cal_kn.cycles, base_kn.cycles, "raw cycle count is preserved");
        assert_eq!(cal_kn.dataflow, base_kn.dataflow, "uniform fit keeps the dataflow");
    }

    #[test]
    fn seconds_scale_with_frequency() {
        let mut m = model();
        let c1 = m.best_conv_cost(&layer_3x3(), Algo::Im2col, 64, 64);
        m.device.freq_mhz *= 2.0;
        let c2 = m.best_conv_cost(&layer_3x3(), Algo::Im2col, 64, 64);
        assert_eq!(c1.cycles, c2.cycles);
        assert!((c1.seconds / c2.seconds - 2.0).abs() < 1e-9);
    }
}
