//! Table 2 + Eq. 13 — inter-layer data-layout transition latencies.
//!
//! Every algorithm consumes and produces data in a specific layout
//! (§3.3): im2col consumes a Toeplitz matrix, kn2row consumes the plain
//! spatial 3D tensor, Winograd consumes/produces the scattered
//! transform-space layout; im2col and kn2row both *produce* the 3D
//! tensor. The DLT modules convert between layouts while streaming
//! to/from DRAM, so each edge of the cost graph pays
//! `Store(AF_i → AF_{i+1}) + Load(AF_{i+1} → AF_{i+1})` (paper §5.1.2).

use super::conv::Algo;
use super::device::Device;
use crate::graph::layer::ConvSpec;

/// A tensor storage layout family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Format {
    /// Spatial 3D tensor `(H1·H2, C)` — kn2row input, im2col/kn2row output.
    Tensor3D,
    /// im2col's duplicated sliding-window matrix `(O1·O2, K1K2·C)`.
    Toeplitz,
    /// Winograd's scattered transform-space layout
    /// (`(m+r−1)²` matrices of `(H1H2/m², C)`).
    WinoScattered,
}

impl Format {
    /// Stable display name of the layout family.
    pub fn name(&self) -> &'static str {
        match self {
            Format::Tensor3D => "3d-tensor",
            Format::Toeplitz => "toeplitz",
            Format::WinoScattered => "wino-scattered",
        }
    }
}

/// Input layout an algorithm consumes.
pub fn input_format(algo: Algo) -> Format {
    match algo {
        Algo::Im2col => Format::Toeplitz,
        Algo::Kn2row => Format::Tensor3D,
        Algo::Winograd { .. } | Algo::WinogradStrided { .. } => Format::WinoScattered,
    }
}

/// Output layout family an algorithm produces (§3.3: im2col and kn2row
/// both emit the spatial 3D tensor; Winograd emits the scattered layout).
pub fn output_format(algo: Algo) -> Format {
    match algo {
        Algo::Im2col | Algo::Kn2row => Format::Tensor3D,
        Algo::Winograd { .. } | Algo::WinogradStrided { .. } => Format::WinoScattered,
    }
}

/// Dimensions Table 2 needs about the *consumer* layer `i+1` plus the
/// producer's channel count `C_out(i)` (= `C_in(i+1)` on direct edges).
#[derive(Debug, Clone, Copy)]
pub struct EdgeDims {
    /// Consumer input height.
    pub h1: usize,
    /// Consumer input width.
    pub h2: usize,
    /// Consumer output height.
    pub o1: usize,
    /// Consumer output width.
    pub o2: usize,
    /// Consumer kernel height.
    pub k1: usize,
    /// Consumer kernel width.
    pub k2: usize,
    /// Channel count crossing the edge (`C_out(i)` = `C_in(i+1)`).
    pub c: usize,
}

impl EdgeDims {
    /// Dims for an edge feeding conv layer `next`.
    pub fn for_conv(next: &ConvSpec) -> EdgeDims {
        EdgeDims {
            h1: next.h1,
            h2: next.h2,
            o1: next.o1(),
            o2: next.o2(),
            k1: next.k1,
            k2: next.k2,
            c: next.c_in,
        }
    }

    /// Dims for an edge feeding a non-conv consumer of a `(c, h1, h2)`
    /// tensor (pool/concat/add/fc): only the 3D-tensor volume matters.
    pub fn for_tensor(c: usize, h1: usize, h2: usize) -> EdgeDims {
        EdgeDims { h1, h2, o1: h1, o2: h2, k1: 1, k2: 1, c }
    }

    /// Element volume of a layout instantiated at these dims.
    pub fn volume(&self, f: Format, m: usize, r: usize) -> u64 {
        match f {
            Format::Tensor3D => (self.h1 * self.h2 * self.c) as u64,
            Format::Toeplitz => (self.o1 * self.o2 * self.k1 * self.k2 * self.c) as u64,
            Format::WinoScattered => {
                let tiles = self.h1.div_ceil(m) * self.h2.div_ceil(m);
                (tiles * (m + r - 1) * (m + r - 1) * self.c) as u64
            }
        }
    }
}

/// Transition-cost model: Table 2 with the Eq. 13 burst-wastage factor.
#[derive(Debug, Clone)]
pub struct TransitionModel {
    /// Target device (bandwidth, burst length, clock).
    pub device: Device,
    /// Winograd output tile size `m` (scattered-layout volumes).
    pub wino_m: usize,
    /// Winograd kernel tile size `r` (scattered-layout volumes).
    pub wino_r: usize,
    /// Use the literal Eq. 13 as printed in the paper. The printed
    /// formula `f = C/(C + m²/(H1H2))·BW` is ≈ BW for any realistic
    /// C (dimensionally inert); the *text* describes burst-length
    /// wastage — "depending on whether each transaction of C_out(i)
    /// addresses saturates the entire DDR burst length". The default
    /// (false) implements the described behaviour:
    /// `f = BW · C/BL` when `C < BL`.
    pub literal_eq13: bool,
    /// 2-LTU pipeline initialization for the Winograd→Toeplitz 2-step
    /// path (`ovhd` in Table 2 row 5), in seconds.
    pub ltu_ovhd_sec: f64,
}

impl TransitionModel {
    /// A transition model over `device` with `F(2×2, 3×3)` layouts and
    /// the burst-wastage reading of Eq. 13.
    pub fn new(device: Device) -> TransitionModel {
        // ovhd: two pipelined LTU passes' fill time — a few hundred
        // cycles; modeled as 512 cycles at the device clock.
        let ovhd = 512.0 / (device.freq_mhz * 1e6);
        TransitionModel { device, wino_m: 2, wino_r: 3, literal_eq13: false, ltu_ovhd_sec: ovhd }
    }

    /// Eq. 13 — effective bandwidth (elements/s) for the scattered
    /// Winograd-input store pattern whose transactions move `c` elements
    /// per generated address.
    pub fn f_bw(&self, c: usize, h1: usize, h2: usize) -> f64 {
        let bw = self.device.bw_elems_per_sec();
        if self.literal_eq13 {
            if c >= self.device.burst_len {
                bw
            } else {
                let m2 = (self.wino_m * self.wino_m) as f64;
                (c as f64 / (c as f64 + m2 / (h1 * h2) as f64)) * bw
            }
        } else if c >= self.device.burst_len {
            bw
        } else {
            bw * c as f64 / self.device.burst_len as f64
        }
    }

    /// Table 2 — store-side latency (seconds): layer `i` computed with
    /// an algorithm whose *output family* is `from`, stored into the
    /// layout `to` required by layer `i+1` with dims `d`.
    pub fn store_sec(&self, from: Format, to: Format, d: &EdgeDims) -> f64 {
        let (m, r) = (self.wino_m, self.wino_r);
        let bw = self.device.bw_elems_per_sec();
        match (from, to) {
            // row 1: 3D tensor → Toeplitz (duplicating sliding windows)
            (Format::Tensor3D, Format::Toeplitz) => {
                d.volume(Format::Toeplitz, m, r) as f64 / bw
            }
            // row 2: {3D tensor, winograd} → 3D tensor
            (Format::Tensor3D, Format::Tensor3D) | (Format::WinoScattered, Format::Tensor3D) => {
                d.volume(Format::Tensor3D, m, r) as f64 / bw
            }
            // row 3: 3D tensor → Winograd input (re-order + duplicate,
            // scattered DDR addresses → Eq. 13 burst wastage)
            (Format::Tensor3D, Format::WinoScattered) => {
                d.volume(Format::WinoScattered, m, r) as f64 / self.f_bw(d.c, d.h1, d.h2)
            }
            // row 4: Winograd output → Winograd input (both scattered —
            // streaming access at full bandwidth)
            (Format::WinoScattered, Format::WinoScattered) => {
                d.volume(Format::WinoScattered, m, r) as f64 / bw
            }
            // row 5: Winograd → Toeplitz: two pipelined LTU steps
            // (restore 3D tensor, then Toeplitz) + pipeline ovhd
            (Format::WinoScattered, Format::Toeplitz) => {
                d.volume(Format::Toeplitz, m, r) as f64 / bw + self.ltu_ovhd_sec
            }
            // Toeplitz is never an *output* family of any algorithm; the
            // arm is unreachable from graph construction but kept total.
            (Format::Toeplitz, to) => {
                d.volume(to, m, r) as f64 / bw + self.ltu_ovhd_sec
            }
        }
    }

    /// Load-side latency (seconds): layer `i+1` loads its input, already
    /// stored in its own format (`Load(n, n, dim(j))` in §5.1.2) — a
    /// format-matched stream of the layout's volume.
    pub fn load_sec(&self, fmt: Format, d: &EdgeDims) -> f64 {
        let (m, r) = (self.wino_m, self.wino_r);
        let bw = self.device.bw_elems_per_sec();
        match fmt {
            Format::Tensor3D => d.volume(Format::Tensor3D, m, r) as f64 / bw,
            Format::Toeplitz => d.volume(Format::Toeplitz, m, r) as f64 / bw,
            // scattered on-chip placement: burst wastage applies on load
            // too when C is small (mirror of the store side)
            Format::WinoScattered => {
                d.volume(Format::WinoScattered, m, r) as f64 / self.f_bw(d.c, d.h1, d.h2)
            }
        }
    }

    /// Full edge transition (paper §5.1.2):
    /// `T_ij(algo_i, algo_j) = Store + Load` on consumer dims `d`.
    pub fn edge_sec(&self, algo_i: Algo, algo_j: Algo, d: &EdgeDims) -> f64 {
        let store = self.store_sec(output_format(algo_i), input_format(algo_j), d);
        let load = self.load_sec(input_format(algo_j), d);
        store + load
    }

    /// On-chip transition (DSE step 5, §5): when producer output and
    /// consumer input both fit in SRAM the DRAM round-trip is skipped;
    /// the store-side LTU rewrites straight into the Input Buffer
    /// across `max(P1, P2)` banks with 8-byte ports — aggregate BRAM
    /// bandwidth on the U200 far exceeds the DDR channels, which is the
    /// entire point of step 5 ("redundant off-chip data traffic will be
    /// avoided").
    pub fn edge_sec_onchip(&self, to: Format, d: &EdgeDims, p1: usize) -> f64 {
        let vol = d.volume(to, self.wino_m, self.wino_r) as f64;
        let elems_per_cycle = (p1 * 8) as f64;
        (vol / elems_per_cycle) * self.device.cycle_time()
    }

    /// Would an on-chip hand-off of `to`-formatted data at dims `d`
    /// (plus the producer's 3D-tensor output copy) fit in SRAM?
    pub fn fits_on_chip(&self, to: Format, d: &EdgeDims) -> bool {
        let vol_in = d.volume(to, self.wino_m, self.wino_r);
        let vol_out = d.volume(Format::Tensor3D, self.wino_m, self.wino_r);
        // INT8: 1 byte/element; both buffers must coexist (double buffer)
        (vol_in + vol_out) as u64 <= self.device.sram_bytes as u64
    }

    /// Quantize/dequantize cost paid on a cost-graph edge whose
    /// endpoints run at different precisions: one streaming pass of the
    /// consumer-layout volume through the requantization unit at DDR
    /// bandwidth. Same price in both directions (f32→int8 quantize and
    /// int8→f32 dequantize are both one multiply per element on a
    /// streamed tensor), and deliberately cheap relative to compute —
    /// the point of the edge term is to couple neighbouring precision
    /// choices (a lone int8 layer pays two requant passes; a chain pays
    /// two at its borders), not to forbid mixing.
    pub fn requant_sec(&self, fmt: Format, d: &EdgeDims) -> f64 {
        d.volume(fmt, self.wino_m, self.wino_r) as f64 / self.device.bw_elems_per_sec()
    }

    /// Mismatched load at a fan-out point (`V_s` vertices): the tensor
    /// was stored in `stored` (instantiated at the dims of the child it
    /// was stored *for*), but child `j` with dims `d` needs `needed`.
    /// The load-side DLT re-reads the stored volume and converts; if the
    /// stored layout is not the plain 3D tensor an extra restore pass
    /// over the stored volume is required first.
    pub fn mismatch_load_sec(
        &self,
        stored: Format,
        stored_volume: u64,
        needed: Format,
        d: &EdgeDims,
    ) -> f64 {
        let bw = self.device.bw_elems_per_sec();
        let restore = match stored {
            Format::Tensor3D => 0.0,
            // duplicated layouts stored for a *different* consumer must
            // be round-tripped through the 3D tensor by the 2-LTU path
            Format::Toeplitz | Format::WinoScattered => {
                stored_volume as f64 / bw + self.ltu_ovhd_sec
            }
        };
        restore + self.load_sec(needed, d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tm() -> TransitionModel {
        TransitionModel::new(Device::alveo_u200())
    }

    fn dims() -> EdgeDims {
        // next layer: 28×28 input, 3×3 kernel, 64 channels in
        EdgeDims { h1: 28, h2: 28, o1: 28, o2: 28, k1: 3, k2: 3, c: 64 }
    }

    #[test]
    fn toeplitz_store_is_k2_heavier() {
        let t = tm();
        let d = dims();
        let toe = t.store_sec(Format::Tensor3D, Format::Toeplitz, &d);
        let t3d = t.store_sec(Format::Tensor3D, Format::Tensor3D, &d);
        // 9× data duplication for a stride-1 3×3 kernel
        assert!((toe / t3d - 9.0).abs() < 0.05, "ratio {}", toe / t3d);
    }

    #[test]
    fn wino_to_wino_streams_at_full_bw() {
        let t = tm();
        let d = dims();
        let ww = t.store_sec(Format::WinoScattered, Format::WinoScattered, &d);
        let tw = t.store_sec(Format::Tensor3D, Format::WinoScattered, &d);
        // same volume; the 3D→wino path pays burst wastage (c=64 == BL ⇒
        // equal here), so test with a narrow c where wastage bites:
        assert!(ww <= tw + 1e-15);
        let dn = EdgeDims { c: 16, ..d };
        let ww_n = t.store_sec(Format::WinoScattered, Format::WinoScattered, &dn);
        let tw_n = t.store_sec(Format::Tensor3D, Format::WinoScattered, &dn);
        assert!(tw_n > ww_n * 2.0, "narrow-c wastage: {} vs {}", tw_n, ww_n);
    }

    #[test]
    fn eq13_literal_vs_burst_interpretation() {
        let mut t = tm();
        let (c, h1, h2) = (8, 28, 28);
        let burst = t.f_bw(c, h1, h2);
        t.literal_eq13 = true;
        let literal = t.f_bw(c, h1, h2);
        // literal formula barely discounts; burst interpretation does
        assert!(literal > 0.9 * t.device.bw_elems_per_sec());
        assert!(burst < 0.2 * t.device.bw_elems_per_sec());
    }

    #[test]
    fn edge_cost_symmetry_classes() {
        let t = tm();
        let d = dims();
        // im2col→kn2row and kn2row→kn2row share row 2 store + same load
        let a = t.edge_sec(Algo::Im2col, Algo::Kn2row, &d);
        let b = t.edge_sec(Algo::Kn2row, Algo::Kn2row, &d);
        assert!((a - b).abs() < 1e-15);
        // winograd→im2col costs at least as much as kn2row→im2col (ovhd)
        let w = t.edge_sec(Algo::Winograd { m: 2, r: 3 }, Algo::Im2col, &d);
        let k = t.edge_sec(Algo::Kn2row, Algo::Im2col, &d);
        assert!(w >= k);
    }

    #[test]
    fn mismatch_load_penalizes_duplicated_layouts() {
        let t = tm();
        let d = dims();
        let clean = t.mismatch_load_sec(Format::Tensor3D, 0, Format::Tensor3D, &d);
        let dirty =
            t.mismatch_load_sec(Format::Toeplitz, 9 * 28 * 28 * 64, Format::Tensor3D, &d);
        assert!(dirty > clean * 2.0);
    }

    #[test]
    fn volumes() {
        let d = dims();
        assert_eq!(d.volume(Format::Tensor3D, 2, 3), 28 * 28 * 64);
        assert_eq!(d.volume(Format::Toeplitz, 2, 3), 28 * 28 * 9 * 64);
        // wino m=2,r=3: 14×14 tiles × 16 points × 64
        assert_eq!(d.volume(Format::WinoScattered, 2, 3), 14 * 14 * 16 * 64);
    }
}
