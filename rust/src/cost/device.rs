//! Target device meta data — the third input of the DYNAMAP flow
//! (paper §1: "FPGA device meta data (DSP resources, on-chip memory size
//! and external bandwidth)").

/// FPGA device description. All bandwidth numbers are for the INT8
/// datapath the paper evaluates (1 byte / element).
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    pub name: String,
    /// PE budget for the systolic array. The paper caps DSP consumption
    /// at 6084 for fairness; with INT8 one PE maps to one DSP.
    pub dsp_cap: usize,
    /// Accelerator clock in MHz (paper achieves 286 MHz on the U200).
    pub freq_mhz: f64,
    /// Peak usable external (DDR) bandwidth in GB/s.
    pub ddr_gbps: f64,
    /// DDR burst length in elements (BL in Eq. 13).
    pub burst_len: usize,
    /// On-chip SRAM capacity in bytes (BRAM+URAM usable for buffers);
    /// used by DSE step 5 to fuse consecutive layers on chip.
    pub sram_bytes: usize,
    /// Parallel pooling units (§3.4 "array of PUs").
    pub pool_units: usize,
}

impl Device {
    /// Xilinx Alveo U200 as configured in the paper's evaluation:
    /// 6084-DSP systolic-array budget, 286 MHz, 4× DDR4-2400 channels
    /// (77 GB/s peak; we default to a usable 64 GB/s), 64-byte bursts.
    /// `sram_bytes` is the *fusion slack*: the paper's designs consume
    /// 93–97% of BRAM for the working Input/Kernel/Output buffers
    /// (Table 3), leaving ~2 MiB for DSE step 5's consecutive-layer
    /// on-chip hand-offs.
    pub fn alveo_u200() -> Device {
        Device {
            name: "alveo-u200".into(),
            dsp_cap: 6084,
            freq_mhz: 286.0,
            ddr_gbps: 64.0,
            burst_len: 64,
            sram_bytes: 2 << 20,
            pool_units: 64,
        }
    }

    /// A small edge-class device, used in tests and the custom-CNN
    /// example to show DSE adapting to a different resource budget.
    pub fn small_edge() -> Device {
        Device {
            name: "small-edge".into(),
            dsp_cap: 1024,
            freq_mhz: 200.0,
            ddr_gbps: 12.8,
            burst_len: 32,
            sram_bytes: 2 << 20,
            pool_units: 16,
        }
    }

    /// Seconds per clock cycle.
    pub fn cycle_time(&self) -> f64 {
        1.0 / (self.freq_mhz * 1e6)
    }

    /// DDR bandwidth in elements (bytes) per second.
    pub fn bw_elems_per_sec(&self) -> f64 {
        self.ddr_gbps * 1e9
    }

    /// Transfer latency in seconds for `elems` INT8 elements at full
    /// bandwidth.
    pub fn xfer_sec(&self, elems: f64) -> f64 {
        elems / self.bw_elems_per_sec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u200_preset() {
        let d = Device::alveo_u200();
        assert_eq!(d.dsp_cap, 6084);
        assert!((d.cycle_time() - 1.0 / 286e6).abs() < 1e-18);
    }

    #[test]
    fn xfer_scaling() {
        let d = Device::alveo_u200();
        // 64 GB/s → 64e9 elements/s → 64e9 elems in 1 s
        assert!((d.xfer_sec(64e9) - 1.0).abs() < 1e-9);
        assert!(d.xfer_sec(1.0) > 0.0);
    }
}
