//! Target device meta data — the third input of the DYNAMAP flow
//! (paper §1: "FPGA device meta data (DSP resources, on-chip memory size
//! and external bandwidth)") — plus [`DeviceCalibration`], the
//! profile-fitted correction the `tune` subsystem layers on top of the
//! analytic numbers.

use std::collections::BTreeMap;

/// FPGA device description. All bandwidth numbers are for the INT8
/// datapath the paper evaluates (1 byte / element).
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    /// Device name (keys plan-cache entries and reports).
    pub name: String,
    /// PE budget for the systolic array. The paper caps DSP consumption
    /// at 6084 for fairness; at full precision one PE maps to one DSP.
    pub dsp_cap: usize,
    /// Accelerator clock in MHz (paper achieves 286 MHz on the U200).
    pub freq_mhz: f64,
    /// Peak usable external (DDR) bandwidth in GB/s.
    pub ddr_gbps: f64,
    /// DDR burst length in elements (BL in Eq. 13).
    pub burst_len: usize,
    /// On-chip SRAM capacity in bytes (BRAM+URAM usable for buffers);
    /// used by DSE step 5 to fuse consecutive layers on chip.
    pub sram_bytes: usize,
    /// Parallel pooling units (§3.4 "array of PUs").
    pub pool_units: usize,
    /// DSP packing factor for int8 layers: MACs one DSP slice performs
    /// per cycle on the quantized datapath (2 on DSP48-class slices —
    /// two int8 multiplies share the wide operand port). The cost model
    /// prices a [`crate::quant::Precision::Int8`] choice as a
    /// `P_SA1 × (P_SA2 · int8_macs_per_dsp)` array on the same DSP
    /// budget; f32 choices always run at 1 MAC/DSP.
    pub int8_macs_per_dsp: usize,
}

impl Device {
    /// Xilinx Alveo U200 as configured in the paper's evaluation:
    /// 6084-DSP systolic-array budget, 286 MHz, 4× DDR4-2400 channels
    /// (77 GB/s peak; we default to a usable 64 GB/s), 64-byte bursts.
    /// `sram_bytes` is the *fusion slack*: the paper's designs consume
    /// 93–97% of BRAM for the working Input/Kernel/Output buffers
    /// (Table 3), leaving ~2 MiB for DSE step 5's consecutive-layer
    /// on-chip hand-offs.
    pub fn alveo_u200() -> Device {
        Device {
            name: "alveo-u200".into(),
            dsp_cap: 6084,
            freq_mhz: 286.0,
            ddr_gbps: 64.0,
            burst_len: 64,
            sram_bytes: 2 << 20,
            pool_units: 64,
            int8_macs_per_dsp: 2,
        }
    }

    /// A small edge-class device, used in tests and the custom-CNN
    /// example to show DSE adapting to a different resource budget.
    pub fn small_edge() -> Device {
        Device {
            name: "small-edge".into(),
            dsp_cap: 1024,
            freq_mhz: 200.0,
            ddr_gbps: 12.8,
            burst_len: 32,
            sram_bytes: 2 << 20,
            pool_units: 16,
            int8_macs_per_dsp: 2,
        }
    }

    /// Seconds per clock cycle.
    pub fn cycle_time(&self) -> f64 {
        1.0 / (self.freq_mhz * 1e6)
    }

    /// DDR bandwidth in elements (bytes) per second.
    pub fn bw_elems_per_sec(&self) -> f64 {
        self.ddr_gbps * 1e9
    }

    /// Transfer latency in seconds for `elems` INT8 elements at full
    /// bandwidth.
    pub fn xfer_sec(&self, elems: f64) -> f64 {
        elems / self.bw_elems_per_sec()
    }
}

/// Affine correction for one algorithm family, fitted from observed
/// latencies: `calibrated_sec = scale · analytic_sec + offset_sec`
/// (clamped at zero).
///
/// `scale` is the inverse of the achievable fraction of modeled GEMM
/// throughput for that family (`scale = 2` means the family runs at
/// half the analytic rate); `offset_sec` absorbs per-invocation
/// overheads the cycle model does not see (dispatch, transform setup).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlgoFit {
    /// Multiplicative term applied to the analytic latency.
    pub scale: f64,
    /// Additive per-layer overhead, seconds.
    pub offset_sec: f64,
}

impl AlgoFit {
    /// The do-nothing fit (`scale = 1`, no offset).
    pub fn identity() -> AlgoFit {
        AlgoFit { scale: 1.0, offset_sec: 0.0 }
    }

    /// Apply the fit to an analytic latency, never going negative.
    pub fn apply(&self, sec: f64) -> f64 {
        (self.scale * sec + self.offset_sec).max(0.0)
    }
}

impl Default for AlgoFit {
    fn default() -> AlgoFit {
        AlgoFit::identity()
    }
}

/// Profile-fitted correction of a [`Device`]'s analytic cost model:
/// one [`AlgoFit`] per algorithm family and precision (keyed by
/// [`crate::quant::mapped_name`] — "im2col", "kn2row", "winograd" for
/// f32, "im2col-int8"/"kn2row-int8" for quantized layers, which fit
/// separately because a host's int8 observed/analytic ratio differs
/// systematically from its f32 one), plus a fallback fit for keys
/// without observations.
///
/// The default value is the identity (every family served verbatim by
/// the analytic model), so an uncalibrated pipeline behaves exactly as
/// before. `tune::calibrate` produces non-trivial instances from
/// measured per-layer latencies; the fallback is set to the global
/// time-scale so an unprofiled family is never accidentally priced at
/// the raw analytic cost next to heavily re-scaled profiled ones.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DeviceCalibration {
    /// Per-family fit, keyed by algorithm family name.
    pub per_algo: BTreeMap<String, AlgoFit>,
    /// Fit applied to families absent from `per_algo`.
    pub fallback: AlgoFit,
}

impl DeviceCalibration {
    /// The identity calibration (same as `Default`).
    pub fn identity() -> DeviceCalibration {
        DeviceCalibration::default()
    }

    /// `true` when applying this calibration changes nothing.
    pub fn is_identity(&self) -> bool {
        self.fallback == AlgoFit::identity()
            && self.per_algo.values().all(|f| *f == AlgoFit::identity())
    }

    /// Builder-style: set the fit for one family (tests and the
    /// deliberately mis-calibrated bench device use this).
    pub fn with(mut self, family: &str, scale: f64, offset_sec: f64) -> DeviceCalibration {
        self.per_algo.insert(family.to_string(), AlgoFit { scale, offset_sec });
        self
    }

    /// The fit for `family` (the fallback when unprofiled).
    pub fn fit(&self, family: &str) -> &AlgoFit {
        self.per_algo.get(family).unwrap_or(&self.fallback)
    }

    /// Apply the family's fit to an analytic latency.
    pub fn apply(&self, family: &str, sec: f64) -> f64 {
        self.fit(family).apply(sec)
    }

    /// A copy of this calibration with every fit's `scale` *and*
    /// `offset_sec` multiplied by `factor`.
    ///
    /// This is the thread-partition hook for multi-tenant serving
    /// ([`crate::serve::sched`]): a model granted `b` of the host's `t`
    /// worker threads sees roughly `t / b` times the per-layer latency,
    /// so re-running the DSE under `scaled(t / b)` re-solves its plan
    /// for the slice it actually owns. Scaling the identity produces a
    /// non-identity calibration, so [`DeviceCalibration::describe`] —
    /// and therefore `Compiler::fingerprint` — keys a distinct plan
    /// cache entry per partition with no extra plumbing. `factor = 1`
    /// returns the calibration unchanged (identity stays identity).
    pub fn scaled(self, factor: f64) -> DeviceCalibration {
        if factor == 1.0 {
            return self;
        }
        let stretch = |f: &AlgoFit| AlgoFit {
            scale: f.scale * factor,
            offset_sec: f.offset_sec * factor,
        };
        DeviceCalibration {
            per_algo: self.per_algo.iter().map(|(k, f)| (k.clone(), stretch(f))).collect(),
            fallback: stretch(&self.fallback),
        }
    }

    /// Stable textual form for compiler fingerprints: two calibrations
    /// with equal descriptions produce identical plans.
    pub fn describe(&self) -> String {
        if self.is_identity() {
            return "id".to_string();
        }
        let mut s = format!("fb{:e},{:e}", self.fallback.scale, self.fallback.offset_sec);
        for (family, f) in &self.per_algo {
            s.push_str(&format!(";{family}{:e},{:e}", f.scale, f.offset_sec));
        }
        s
    }
}

/// Measured host-microkernel throughput — the second
/// [`DeviceCalibration`]-style correction, produced by
/// [`crate::kernels::KernelSelector::measure`] and folded into
/// [`crate::cost::CostModel`] so the DSE prices f32 GEMMs at what the
/// serving host actually runs instead of the analytic overlay rate.
///
/// Keys are kernel names (`avx2-4x16`, `scalar-1x8`, …: kind, then
/// `mr×nr` register tile); values are measured GFLOP/s at full tile
/// occupancy. [`KernelThroughput::gemm_sec`] re-applies shape-dependent
/// tail losses analytically, so one fixed-shape measurement prices
/// every layer shape. The default (empty) table disables host pricing
/// — an unmeasured pipeline behaves exactly as before.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct KernelThroughput {
    /// Measured full-tile throughput per kernel name, GFLOP/s.
    pub gflops: BTreeMap<String, f64>,
    /// Fixed per-GEMM-call overhead in seconds (dispatch, panel-pack
    /// setup, output allocation) — the axis the three conv algorithms
    /// differ on hardest (1 im2col call vs `K1K2` kn2row calls vs
    /// `(m+r−1)²·rounds` Winograd calls).
    pub call_overhead_sec: f64,
}

impl KernelThroughput {
    /// `true` when no kernel was measured: host pricing is disabled and
    /// the analytic model serves every latency verbatim.
    pub fn is_empty(&self) -> bool {
        self.gflops.is_empty()
    }

    /// Builder-style: record one kernel's measured throughput (tests
    /// and deliberately skewed cost-fold fixtures use this).
    pub fn with(mut self, kernel: &str, gflops: f64) -> KernelThroughput {
        self.gflops.insert(kernel.to_string(), gflops);
        self
    }

    /// Builder-style: set the per-call overhead.
    pub fn with_call_overhead(mut self, sec: f64) -> KernelThroughput {
        self.call_overhead_sec = sec;
        self
    }

    /// Predicted seconds for one `a × b × c` f32 GEMM call on the
    /// fastest measured kernel, or `None` when the table is empty.
    ///
    /// Each kernel's effective rate is its measured full-tile GFLOP/s
    /// scaled by row (`mr`) and column (`nr`) tail occupancy for this
    /// shape — tail lanes compute zero-packed dead work — plus the
    /// per-call overhead. Deterministic in the table alone, so plans
    /// priced by equal tables are identical (fingerprint-safe).
    pub fn gemm_sec(&self, a: usize, b: usize, c: usize) -> Option<f64> {
        let flops = 2.0 * (a as f64) * (b as f64) * (c as f64);
        self.gflops
            .iter()
            .filter(|(_, &gf)| gf > 0.0)
            .map(|(name, &gf)| {
                let (mr, nr) = parse_tile(name);
                let occ = |dim: usize, t: usize| {
                    if dim == 0 {
                        1.0
                    } else {
                        dim as f64 / (dim.div_ceil(t) * t) as f64
                    }
                };
                flops / (gf * 1e9 * occ(a, mr) * occ(c, nr)) + self.call_overhead_sec
            })
            .min_by(|x, y| x.partial_cmp(y).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// Stable textual form for compiler fingerprints (mirrors
    /// [`DeviceCalibration::describe`]): `id` when empty, otherwise the
    /// overhead plus every `name=gflops` entry in key order.
    pub fn describe(&self) -> String {
        if self.is_empty() {
            return "id".to_string();
        }
        let mut s = format!("ov{:e}", self.call_overhead_sec);
        for (name, g) in &self.gflops {
            s.push_str(&format!(";{name}={g:e}"));
        }
        s
    }
}

/// Parse the `MRxNR` register-tile suffix of a kernel name
/// (`avx2-4x16` → `(4, 16)`); unparseable names fall back to a 1×1
/// tile (no occupancy penalty).
fn parse_tile(name: &str) -> (usize, usize) {
    name.rsplit('-')
        .next()
        .and_then(|t| t.split_once('x'))
        .and_then(|(m, n)| Some((m.parse().ok()?, n.parse().ok()?)))
        .filter(|&(m, n)| m > 0 && n > 0)
        .unwrap_or((1, 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u200_preset() {
        let d = Device::alveo_u200();
        assert_eq!(d.dsp_cap, 6084);
        assert!((d.cycle_time() - 1.0 / 286e6).abs() < 1e-18);
    }

    #[test]
    fn xfer_scaling() {
        let d = Device::alveo_u200();
        // 64 GB/s → 64e9 elements/s → 64e9 elems in 1 s
        assert!((d.xfer_sec(64e9) - 1.0).abs() < 1e-9);
        assert!(d.xfer_sec(1.0) > 0.0);
    }

    #[test]
    fn calibration_identity_and_apply() {
        let id = DeviceCalibration::identity();
        assert!(id.is_identity());
        assert_eq!(id.apply("im2col", 2.5), 2.5);
        assert_eq!(id.describe(), "id");

        let cal = DeviceCalibration::default().with("kn2row", 3.0, 0.5);
        assert!(!cal.is_identity());
        assert!((cal.apply("kn2row", 2.0) - 6.5).abs() < 1e-12);
        // unprofiled family falls back (identity fallback here)
        assert_eq!(cal.apply("winograd", 2.0), 2.0);
        assert_ne!(cal.describe(), "id");
        assert_eq!(cal.describe(), cal.clone().describe(), "description is stable");
    }

    #[test]
    fn calibration_scaled_stretches_and_keys_fingerprints() {
        // scaling the identity must leave the identity regime: that is
        // what keys a distinct plan-cache entry per thread partition
        let half = DeviceCalibration::identity().scaled(2.0);
        assert!(!half.is_identity());
        assert_eq!(half.apply("im2col", 1.0), 2.0);
        assert_ne!(half.describe(), "id");
        assert_ne!(half.describe(), DeviceCalibration::identity().scaled(4.0).describe());

        // factor 1 is a no-op (identity stays identity, fitted stays put)
        assert!(DeviceCalibration::identity().scaled(1.0).is_identity());
        let fitted = DeviceCalibration::default().with("kn2row", 3.0, 0.5);
        assert_eq!(fitted.clone().scaled(1.0), fitted);

        // per-family fits and the fallback both stretch linearly
        let s = fitted.scaled(2.0);
        assert!((s.apply("kn2row", 2.0) - 13.0).abs() < 1e-12);
        assert!((s.apply("winograd", 2.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn calibration_never_goes_negative() {
        let f = AlgoFit { scale: 1.0, offset_sec: -5.0 };
        assert_eq!(f.apply(1.0), 0.0);
    }

    #[test]
    fn kernel_throughput_empty_is_inert() {
        let t = KernelThroughput::default();
        assert!(t.is_empty());
        assert_eq!(t.gemm_sec(128, 96, 128), None);
        assert_eq!(t.describe(), "id");
    }

    #[test]
    fn gemm_sec_applies_tile_occupancy() {
        // 10 GFLOP/s full-tile; a=4, c=16 is a perfect 4x16 fit
        let t = KernelThroughput::default().with("avx2-4x16", 10.0);
        let perfect = t.gemm_sec(4, 100, 16).unwrap();
        let flops = 2.0 * 4.0 * 100.0 * 16.0;
        assert!((perfect - flops / 10e9).abs() < 1e-15);
        // c=17 pads to 32 lanes: the same flops run at 17/32 occupancy
        let ragged = t.gemm_sec(4, 100, 17).unwrap();
        let ragged_flops = 2.0 * 4.0 * 100.0 * 17.0;
        assert!((ragged - ragged_flops / (10e9 * 17.0 / 32.0)).abs() < 1e-15);
        assert!(ragged > perfect);
    }

    #[test]
    fn gemm_sec_picks_fastest_kernel_and_adds_overhead() {
        let t = KernelThroughput::default()
            .with("scalar-1x8", 1.0)
            .with("avx2-4x16", 8.0)
            .with_call_overhead(1e-6);
        // a perfect-fit shape for both tiles: the 8 GFLOP/s entry wins
        let sec = t.gemm_sec(16, 32, 16).unwrap();
        let flops = 2.0 * 16.0 * 32.0 * 16.0;
        assert!((sec - (flops / 8e9 + 1e-6)).abs() < 1e-12);
        // degenerate zero-flop call still pays the per-call overhead
        assert!((t.gemm_sec(0, 32, 16).unwrap() - 1e-6).abs() < 1e-15);
    }

    #[test]
    fn kernel_throughput_describe_is_stable_and_distinct() {
        let a = KernelThroughput::default().with("avx2-4x16", 8.0);
        let b = KernelThroughput::default().with("avx2-4x16", 9.0);
        assert_eq!(a.describe(), a.clone().describe());
        assert_ne!(a.describe(), b.describe());
        assert_ne!(a.describe(), "id");
    }

    #[test]
    fn tile_suffix_parsing() {
        assert_eq!(parse_tile("avx2-4x16"), (4, 16));
        assert_eq!(parse_tile("scalar-1x8"), (1, 8));
        assert_eq!(parse_tile("weird"), (1, 1));
        assert_eq!(parse_tile("neon-0x8"), (1, 1));
    }
}
