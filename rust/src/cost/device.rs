//! Target device meta data — the third input of the DYNAMAP flow
//! (paper §1: "FPGA device meta data (DSP resources, on-chip memory size
//! and external bandwidth)") — plus [`DeviceCalibration`], the
//! profile-fitted correction the `tune` subsystem layers on top of the
//! analytic numbers.

use std::collections::BTreeMap;

/// FPGA device description. All bandwidth numbers are for the INT8
/// datapath the paper evaluates (1 byte / element).
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    /// Device name (keys plan-cache entries and reports).
    pub name: String,
    /// PE budget for the systolic array. The paper caps DSP consumption
    /// at 6084 for fairness; at full precision one PE maps to one DSP.
    pub dsp_cap: usize,
    /// Accelerator clock in MHz (paper achieves 286 MHz on the U200).
    pub freq_mhz: f64,
    /// Peak usable external (DDR) bandwidth in GB/s.
    pub ddr_gbps: f64,
    /// DDR burst length in elements (BL in Eq. 13).
    pub burst_len: usize,
    /// On-chip SRAM capacity in bytes (BRAM+URAM usable for buffers);
    /// used by DSE step 5 to fuse consecutive layers on chip.
    pub sram_bytes: usize,
    /// Parallel pooling units (§3.4 "array of PUs").
    pub pool_units: usize,
    /// DSP packing factor for int8 layers: MACs one DSP slice performs
    /// per cycle on the quantized datapath (2 on DSP48-class slices —
    /// two int8 multiplies share the wide operand port). The cost model
    /// prices a [`crate::quant::Precision::Int8`] choice as a
    /// `P_SA1 × (P_SA2 · int8_macs_per_dsp)` array on the same DSP
    /// budget; f32 choices always run at 1 MAC/DSP.
    pub int8_macs_per_dsp: usize,
}

impl Device {
    /// Xilinx Alveo U200 as configured in the paper's evaluation:
    /// 6084-DSP systolic-array budget, 286 MHz, 4× DDR4-2400 channels
    /// (77 GB/s peak; we default to a usable 64 GB/s), 64-byte bursts.
    /// `sram_bytes` is the *fusion slack*: the paper's designs consume
    /// 93–97% of BRAM for the working Input/Kernel/Output buffers
    /// (Table 3), leaving ~2 MiB for DSE step 5's consecutive-layer
    /// on-chip hand-offs.
    pub fn alveo_u200() -> Device {
        Device {
            name: "alveo-u200".into(),
            dsp_cap: 6084,
            freq_mhz: 286.0,
            ddr_gbps: 64.0,
            burst_len: 64,
            sram_bytes: 2 << 20,
            pool_units: 64,
            int8_macs_per_dsp: 2,
        }
    }

    /// A small edge-class device, used in tests and the custom-CNN
    /// example to show DSE adapting to a different resource budget.
    pub fn small_edge() -> Device {
        Device {
            name: "small-edge".into(),
            dsp_cap: 1024,
            freq_mhz: 200.0,
            ddr_gbps: 12.8,
            burst_len: 32,
            sram_bytes: 2 << 20,
            pool_units: 16,
            int8_macs_per_dsp: 2,
        }
    }

    /// Seconds per clock cycle.
    pub fn cycle_time(&self) -> f64 {
        1.0 / (self.freq_mhz * 1e6)
    }

    /// DDR bandwidth in elements (bytes) per second.
    pub fn bw_elems_per_sec(&self) -> f64 {
        self.ddr_gbps * 1e9
    }

    /// Transfer latency in seconds for `elems` INT8 elements at full
    /// bandwidth.
    pub fn xfer_sec(&self, elems: f64) -> f64 {
        elems / self.bw_elems_per_sec()
    }
}

/// Affine correction for one algorithm family, fitted from observed
/// latencies: `calibrated_sec = scale · analytic_sec + offset_sec`
/// (clamped at zero).
///
/// `scale` is the inverse of the achievable fraction of modeled GEMM
/// throughput for that family (`scale = 2` means the family runs at
/// half the analytic rate); `offset_sec` absorbs per-invocation
/// overheads the cycle model does not see (dispatch, transform setup).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlgoFit {
    /// Multiplicative term applied to the analytic latency.
    pub scale: f64,
    /// Additive per-layer overhead, seconds.
    pub offset_sec: f64,
}

impl AlgoFit {
    /// The do-nothing fit (`scale = 1`, no offset).
    pub fn identity() -> AlgoFit {
        AlgoFit { scale: 1.0, offset_sec: 0.0 }
    }

    /// Apply the fit to an analytic latency, never going negative.
    pub fn apply(&self, sec: f64) -> f64 {
        (self.scale * sec + self.offset_sec).max(0.0)
    }
}

impl Default for AlgoFit {
    fn default() -> AlgoFit {
        AlgoFit::identity()
    }
}

/// Profile-fitted correction of a [`Device`]'s analytic cost model:
/// one [`AlgoFit`] per algorithm family and precision (keyed by
/// [`crate::quant::mapped_name`] — "im2col", "kn2row", "winograd" for
/// f32, "im2col-int8"/"kn2row-int8" for quantized layers, which fit
/// separately because a host's int8 observed/analytic ratio differs
/// systematically from its f32 one), plus a fallback fit for keys
/// without observations.
///
/// The default value is the identity (every family served verbatim by
/// the analytic model), so an uncalibrated pipeline behaves exactly as
/// before. `tune::calibrate` produces non-trivial instances from
/// measured per-layer latencies; the fallback is set to the global
/// time-scale so an unprofiled family is never accidentally priced at
/// the raw analytic cost next to heavily re-scaled profiled ones.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DeviceCalibration {
    /// Per-family fit, keyed by algorithm family name.
    pub per_algo: BTreeMap<String, AlgoFit>,
    /// Fit applied to families absent from `per_algo`.
    pub fallback: AlgoFit,
}

impl DeviceCalibration {
    /// The identity calibration (same as `Default`).
    pub fn identity() -> DeviceCalibration {
        DeviceCalibration::default()
    }

    /// `true` when applying this calibration changes nothing.
    pub fn is_identity(&self) -> bool {
        self.fallback == AlgoFit::identity()
            && self.per_algo.values().all(|f| *f == AlgoFit::identity())
    }

    /// Builder-style: set the fit for one family (tests and the
    /// deliberately mis-calibrated bench device use this).
    pub fn with(mut self, family: &str, scale: f64, offset_sec: f64) -> DeviceCalibration {
        self.per_algo.insert(family.to_string(), AlgoFit { scale, offset_sec });
        self
    }

    /// The fit for `family` (the fallback when unprofiled).
    pub fn fit(&self, family: &str) -> &AlgoFit {
        self.per_algo.get(family).unwrap_or(&self.fallback)
    }

    /// Apply the family's fit to an analytic latency.
    pub fn apply(&self, family: &str, sec: f64) -> f64 {
        self.fit(family).apply(sec)
    }

    /// Stable textual form for compiler fingerprints: two calibrations
    /// with equal descriptions produce identical plans.
    pub fn describe(&self) -> String {
        if self.is_identity() {
            return "id".to_string();
        }
        let mut s = format!("fb{:e},{:e}", self.fallback.scale, self.fallback.offset_sec);
        for (family, f) in &self.per_algo {
            s.push_str(&format!(";{family}{:e},{:e}", f.scale, f.offset_sec));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u200_preset() {
        let d = Device::alveo_u200();
        assert_eq!(d.dsp_cap, 6084);
        assert!((d.cycle_time() - 1.0 / 286e6).abs() < 1e-18);
    }

    #[test]
    fn xfer_scaling() {
        let d = Device::alveo_u200();
        // 64 GB/s → 64e9 elements/s → 64e9 elems in 1 s
        assert!((d.xfer_sec(64e9) - 1.0).abs() < 1e-9);
        assert!(d.xfer_sec(1.0) > 0.0);
    }

    #[test]
    fn calibration_identity_and_apply() {
        let id = DeviceCalibration::identity();
        assert!(id.is_identity());
        assert_eq!(id.apply("im2col", 2.5), 2.5);
        assert_eq!(id.describe(), "id");

        let cal = DeviceCalibration::default().with("kn2row", 3.0, 0.5);
        assert!(!cal.is_identity());
        assert!((cal.apply("kn2row", 2.0) - 6.5).abs() < 1e-12);
        // unprofiled family falls back (identity fallback here)
        assert_eq!(cal.apply("winograd", 2.0), 2.0);
        assert_ne!(cal.describe(), "id");
        assert_eq!(cal.describe(), cal.clone().describe(), "description is stable");
    }

    #[test]
    fn calibration_never_goes_negative() {
        let f = AlgoFit { scale: 1.0, offset_sec: -5.0 };
        assert_eq!(f.apply(1.0), 0.0);
    }
}
