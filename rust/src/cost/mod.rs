//! The DYNAMAP analytical cost model (paper §5.1).
//!
//! * [`device`] — FPGA device meta data (DSP budget, frequency, DDR
//!   bandwidth/burst length, on-chip SRAM) with an Alveo U200 preset.
//! * [`gemm`] — Eq. 9: GEMM execution cycles on a `P_SA1 × P_SA2`
//!   systolic array under the NS / WS / IS dataflows, with and without
//!   the stall-free PE optimization (§3.2).
//! * [`conv`] — Eq. 10–12: per-layer convolution latency for im2col,
//!   kn2row and Winograd(m, r), plus effective-PE-utilization (Eq. 14).
//! * [`transition`] — Table 2 + Eq. 13: inter-layer data-layout
//!   store/load transition latencies, including DDR burst wastage.
//! * [`graph_build`] — §5.1 cost-graph construction: one PBQP vertex per
//!   layer (`V_c`), plus a store vertex (`V_s`) per fan-out layer, with
//!   cost vectors and transition matrices.
//!
//! Precision is a second mapping dimension throughout: int8 choices are
//! priced with DSP packing ([`Device::int8_macs_per_dsp`]), edges whose
//! endpoints disagree pay a requantization pass, and Winograd choices
//! are f32-only (see [`crate::quant`]).

#![warn(missing_docs)]

pub mod device;
pub mod gemm;
pub mod conv;
pub mod transition;
pub mod graph_build;

pub use conv::{Algo, ConvCost, CostModel};
pub use device::{AlgoFit, Device, DeviceCalibration, KernelThroughput};
pub use gemm::{gemm_cycles, gemm_macs, Dataflow};
pub use transition::Format;
