//! Cycle-level simulator of the DYNAMAP hardware overlay (paper §3).
//!
//! The FPGA itself is unavailable; this module is the substitution
//! substrate (DESIGN.md §Hardware-Adaptation): it implements the
//! overlay's microarchitectural mechanisms — the `P_SA1 × P_SA2`
//! systolic Computing Unit with NS/WS/IS dataflows and stall-free PEs
//! ([`systolic`]), the dual-parallelism blocked SRAM banking of Eq. 7
//! ([`buffers`]), the DLT layout-transformation FSM of Table 1/Fig. 5
//! ([`dlt`]), kn2row's pipelined Pad-and-Accumulate ([`pad_accum`]),
//! the Winograd shift-add linear transforms ([`wino_xform`]), the
//! HPU/VPU pooling pipeline ([`pooling`]) and the DDR burst model
//! ([`ddr`]) — at pass/transaction granularity, producing both the
//! functional result (validated against [`crate::algos`]) and the cycle
//! counts (validated against the Eq. 9–12 analytical model).

pub mod buffers;
pub mod systolic;
pub mod dlt;
pub mod pad_accum;
pub mod wino_xform;
pub mod pooling;
pub mod ddr;
pub mod layer_sim;

pub use layer_sim::{prepare_layer, simulate_layer, simulate_layer_prepared, LayerSim};
pub use systolic::{SimStats, SystolicSim};
