//! Pass-level simulation of the `P_SA1 × P_SA2` systolic Computing Unit
//! (§3.1/3.2).
//!
//! The simulator walks the exact tile/pass schedule of each dataflow,
//! computing the GEMM functionally per pass (validated against plain
//! matmul) while accounting cycles with the stall-free PE semantics:
//! the `I_SA = max(P1, P2)` pipeline-initialization overhead is
//! overlapped with the next pass (paid once per GEMM), and the widened
//! drain wires remove result-congestion stalls when `b < P_SA`. The
//! naive mode charges `I_SA` on every pass — the ablation baseline.
//! Per-PE busy counts give the measured effective utilization μ
//! (Eq. 14), which must agree with the analytical model — asserted in
//! tests and used to cross-check Figs. 9/10.

use super::buffers::BlockedLayout;
use crate::algos::tensor::Mat;
use crate::cost::gemm::{self, Dataflow};

/// Outcome of a simulated GEMM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimStats {
    pub cycles: u64,
    pub passes: u64,
    pub useful_macs: u64,
    /// Measured effective PE utilization (Eq. 14).
    pub utilization: f64,
    /// Bank-conflict stalls observed (0 with the Eq. 7 layout).
    pub conflict_stalls: u64,
}

/// The simulated Computing Unit.
#[derive(Debug, Clone)]
pub struct SystolicSim {
    pub p1: usize,
    pub p2: usize,
    pub dataflow: Dataflow,
    pub stall_free: bool,
    layout: BlockedLayout,
}

impl SystolicSim {
    pub fn new(p1: usize, p2: usize, dataflow: Dataflow, stall_free: bool) -> SystolicSim {
        SystolicSim { p1, p2, dataflow, stall_free, layout: BlockedLayout::new(p1.max(p2)) }
    }

    /// Execute `X (a×b) · W (b×c)` on the array. Returns the product and
    /// the cycle statistics.
    pub fn gemm(&self, x: &Mat, w: &Mat) -> (Mat, SimStats) {
        assert_eq!(x.cols, w.rows, "gemm dims");
        let (a, b, c) = (x.rows, x.cols, w.cols);
        let (p1, p2) = (self.p1, self.p2);
        let i_sa = p1.max(p2) as u64;
        let mut out = Mat::zeros(a, c);
        let mut cycles: u64 = 0;
        let mut passes: u64 = 0;
        let mut busy_macs: u64 = 0;

        // verify the Eq. 7 layout keeps both access directions clean for
        // this array shape (cheap sanity executed once per GEMM)
        debug_assert_eq!(
            BlockedLayout::conflicts(&self.layout.row_banks(0, p1.min(p2))),
            0
        );

        // hot path: pre-transpose W so every PE dot product walks two
        // contiguous rows (perf pass iteration 3 — see EXPERIMENTS §Perf)
        let wt = w.transposed();
        match self.dataflow {
            Dataflow::NS => {
                // tiles: a-dim rows of P1 output rows × c-dim cols of P2
                for ti in 0..a.div_ceil(p1) {
                    for tj in 0..c.div_ceil(p2) {
                        let rows = p1.min(a - ti * p1);
                        let cols = p2.min(c - tj * p2);
                        // each PE (r, s) accumulates out[ti·p1+r, tj·p2+s]
                        // over the full b dimension: pass length = b
                        for r in 0..rows {
                            let ri = ti * p1 + r;
                            let x_row = &x.data[ri * b..(ri + 1) * b];
                            for s in 0..cols {
                                let cj = tj * p2 + s;
                                let w_col = &wt.data[cj * b..(cj + 1) * b];
                                let acc: f32 =
                                    x_row.iter().zip(w_col).map(|(p, q)| p * q).sum();
                                out.set(ri, cj, acc);
                            }
                        }
                        cycles += b as u64;
                        passes += 1;
                        busy_macs += (rows * cols) as u64 * b as u64;
                        if !self.stall_free {
                            cycles += i_sa;
                        }
                    }
                }
            }
            Dataflow::WS => {
                // stationary P1×P2 weight blocks over (b, c); inputs
                // stream a elements per pass
                for tb in 0..b.div_ceil(p1) {
                    for tc in 0..c.div_ceil(p2) {
                        let kb = p1.min(b - tb * p1);
                        let kc = p2.min(c - tc * p2);
                        for ri in 0..a {
                            let x_win = &x.data[ri * b + tb * p1..ri * b + tb * p1 + kb];
                            for s in 0..kc {
                                let cj = tc * p2 + s;
                                let w_win = &wt.data[cj * b + tb * p1..cj * b + tb * p1 + kb];
                                let dot: f32 =
                                    x_win.iter().zip(w_win).map(|(p, q)| p * q).sum();
                                out.set(ri, cj, out.get(ri, cj) + dot);
                            }
                        }
                        cycles += a as u64;
                        passes += 1;
                        busy_macs += (kb * kc) as u64 * a as u64;
                        if !self.stall_free {
                            cycles += i_sa;
                        }
                    }
                }
            }
            Dataflow::IS => {
                // mirror of WS: stationary P1×P2 input blocks over (b, a);
                // weights stream c elements per pass
                for tb in 0..b.div_ceil(p1) {
                    for ta in 0..a.div_ceil(p2) {
                        let kb = p1.min(b - tb * p1);
                        let ka = p2.min(a - ta * p2);
                        for cj in 0..c {
                            let w_win = &wt.data[cj * b + tb * p1..cj * b + tb * p1 + kb];
                            for s in 0..ka {
                                let ri = ta * p2 + s;
                                let x_win = &x.data[ri * b + tb * p1..ri * b + tb * p1 + kb];
                                let dot: f32 =
                                    x_win.iter().zip(w_win).map(|(p, q)| p * q).sum();
                                out.set(ri, cj, out.get(ri, cj) + dot);
                            }
                        }
                        cycles += c as u64;
                        passes += 1;
                        busy_macs += (kb * ka) as u64 * c as u64;
                        if !self.stall_free {
                            cycles += i_sa;
                        }
                    }
                }
            }
        }
        if self.stall_free {
            cycles += i_sa; // paid once, overlapped thereafter (§3.2)
        }
        let stats = SimStats {
            cycles,
            passes,
            useful_macs: busy_macs,
            utilization: busy_macs as f64 / (cycles as f64 * (p1 * p2) as f64),
            conflict_stalls: 0,
        };
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn random_mat(r: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| r.i8_small() as f32)
    }

    #[test]
    fn functional_equivalence_all_dataflows() {
        check("systolic_functional", 48, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 40), r.range(1, 40), r.range(1, 40));
            let x = random_mat(r, a, b);
            let w = random_mat(r, b, c);
            let reference = x.matmul(&w);
            for df in Dataflow::ALL {
                let sim = SystolicSim::new(r.range(1, 12), r.range(1, 12), df, true);
                let (out, _) = sim.gemm(&x, &w);
                assert_allclose(&out.data, &reference.data, 1e-3, 1e-5)
                    .map_err(|e| format!("{df:?}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn cycles_match_eq9() {
        check("systolic_cycles_eq9", 64, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 60), r.range(1, 60), r.range(1, 60));
            let (p1, p2) = (r.range(1, 16), r.range(1, 16));
            let x = random_mat(r, a, b);
            let w = random_mat(r, b, c);
            for df in Dataflow::ALL {
                let sim = SystolicSim::new(p1, p2, df, true);
                let (_, st) = sim.gemm(&x, &w);
                let model = gemm::gemm_cycles(p1, p2, df, a, b, c);
                if st.cycles != model {
                    return Err(format!(
                        "{df:?} sim {} != Eq.9 {} for ({a},{b},{c}) on ({p1},{p2})",
                        st.cycles, model
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn naive_cycles_match_model() {
        let mut r = Rng::new(3);
        let x = random_mat(&mut r, 10, 20);
        let w = random_mat(&mut r, 20, 30);
        for df in Dataflow::ALL {
            let sim = SystolicSim::new(4, 4, df, false);
            let (_, st) = sim.gemm(&x, &w);
            assert_eq!(st.cycles, gemm::gemm_cycles_naive(4, 4, df, 10, 20, 30), "{df:?}");
        }
    }

    #[test]
    fn utilization_matches_analytic() {
        let mut r = Rng::new(4);
        let x = random_mat(&mut r, 62, 124);
        let w = random_mat(&mut r, 124, 64);
        let sim = SystolicSim::new(31, 31, Dataflow::NS, true);
        let (_, st) = sim.gemm(&x, &w);
        let analytic = gemm::gemm_utilization(31, 31, Dataflow::NS, 62, 124, 64);
        assert!((st.utilization - analytic).abs() < 1e-12);
        // the paper's §3.2 example: ~68% NS utilization
        assert!((0.60..0.72).contains(&st.utilization));
    }

    #[test]
    fn stall_free_beats_naive() {
        let mut r = Rng::new(5);
        let x = random_mat(&mut r, 33, 7); // b < P_SA: many passes, short b
        let w = random_mat(&mut r, 7, 33);
        let fast = SystolicSim::new(8, 8, Dataflow::NS, true).gemm(&x, &w).1;
        let slow = SystolicSim::new(8, 8, Dataflow::NS, false).gemm(&x, &w).1;
        assert!(slow.cycles > fast.cycles);
        // same functional result
        let (o1, _) = SystolicSim::new(8, 8, Dataflow::NS, true).gemm(&x, &w);
        let (o2, _) = SystolicSim::new(8, 8, Dataflow::NS, false).gemm(&x, &w);
        assert_eq!(o1.data, o2.data);
    }
}
