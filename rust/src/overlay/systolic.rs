//! The `P_SA1 × P_SA2` systolic Computing Unit (§3.1/3.2): functional
//! compute decoupled from cycle accounting.
//!
//! The old simulator walked the exact tile/pass schedule of each
//! dataflow, producing the GEMM result from per-PE scalar loops whose
//! only purpose was to tally cycles. The two concerns are now split:
//! the output tensor comes from the fast kernel layer
//! ([`crate::kernels::gemm`], transpose-free over packed `Wᵀ` panels),
//! and the [`SimStats`] come *closed-form* from the Eq. 9 model in
//! [`crate::cost::gemm`] — the pass counts, busy-MAC totals and the
//! stall-free `I_SA = max(P1, P2)` once-per-GEMM initialization are all
//! analytic in `(a, b, c, P1, P2, dataflow)`.
//!
//! The analytic stats are cross-checked against the old loop-derived
//! schedule walk ([`SystolicSim::loop_stats`], kept as the accounting
//! oracle): a `debug_assert` on every GEMM plus explicit property tests
//! assert exact equality, so the fast path cannot silently drift from
//! the pass-level semantics (naive mode charges `I_SA` per pass — the
//! ablation baseline — and is covered by the same cross-check).
//!
//! Functional note: the output is now dataflow-independent — every
//! element is one ascending-`k` dot, bit-identical to [`Mat::matmul`]
//! and to the old NS walk. The old WS/IS walks summed per-`b`-tile
//! partial dots instead, so under those dataflows results may differ
//! from the pre-change simulator in the last ulp (all consumers
//! compare within tolerance; the golden-serving PJRT path is
//! untouched).

use super::buffers::BlockedLayout;
use crate::algos::tensor::Mat;
use crate::cost::gemm::{self, Dataflow};
use crate::kernels::{self, PackedWt};

/// Outcome of a simulated GEMM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimStats {
    pub cycles: u64,
    pub passes: u64,
    pub useful_macs: u64,
    /// Measured effective PE utilization (Eq. 14).
    pub utilization: f64,
    /// Bank-conflict stalls observed (0 with the Eq. 7 layout).
    pub conflict_stalls: u64,
}

/// The simulated Computing Unit.
#[derive(Debug, Clone)]
pub struct SystolicSim {
    pub p1: usize,
    pub p2: usize,
    pub dataflow: Dataflow,
    pub stall_free: bool,
    layout: BlockedLayout,
}

impl SystolicSim {
    pub fn new(p1: usize, p2: usize, dataflow: Dataflow, stall_free: bool) -> SystolicSim {
        SystolicSim { p1, p2, dataflow, stall_free, layout: BlockedLayout::new(p1.max(p2)) }
    }

    /// Execute `X (a×b) · W (b×c)` on the array. Returns the product and
    /// the cycle statistics. Packs `W` per call; inside a loop prefer
    /// [`SystolicSim::gemm_packed`] on a prepared operand.
    pub fn gemm(&self, x: &Mat, w: &Mat) -> (Mat, SimStats) {
        assert_eq!(x.cols, w.rows, "gemm dims");
        self.gemm_packed(x, &PackedWt::pack(w))
    }

    /// Execute `X (a×b) · W (b×c)` with `Wᵀ` pre-packed (the hot path:
    /// no transpose, no weight-side allocation).
    pub fn gemm_packed(&self, x: &Mat, wt: &PackedWt) -> (Mat, SimStats) {
        assert_eq!(x.cols, wt.b, "gemm dims");
        // verify the Eq. 7 layout keeps both access directions clean for
        // this array shape (cheap sanity executed once per GEMM)
        debug_assert_eq!(
            BlockedLayout::conflicts(&self.layout.row_banks(0, self.p1.min(self.p2))),
            0
        );
        let out = kernels::gemm(x, wt);
        let stats = self.stats(x.rows, x.cols, wt.c);
        // the closed-form accounting must reproduce the pass-level
        // schedule walk exactly — the decoupling's safety net
        debug_assert_eq!(stats, self.loop_stats(x.rows, x.cols, wt.c));
        (out, stats)
    }

    /// Closed-form [`SimStats`] for an `a×b×c` GEMM on this array
    /// (Eq. 9 cycles, Eq. 14 utilization). Every pass covers the full
    /// reduction for its tile, so the busy-MAC total telescopes to
    /// `a·b·c` under all three dataflows.
    pub fn stats(&self, a: usize, b: usize, c: usize) -> SimStats {
        let (p1, p2) = (self.p1, self.p2);
        let cycles = if self.stall_free {
            gemm::gemm_cycles(p1, p2, self.dataflow, a, b, c)
        } else {
            gemm::gemm_cycles_naive(p1, p2, self.dataflow, a, b, c)
        };
        let busy_macs = gemm::gemm_macs(a, b, c);
        SimStats {
            cycles,
            passes: gemm::gemm_passes(p1, p2, self.dataflow, a, b, c) as u64,
            useful_macs: busy_macs,
            utilization: busy_macs as f64 / (cycles as f64 * (p1 * p2) as f64),
            conflict_stalls: 0,
        }
    }

    /// The old loop-derived accounting: walk the exact tile/pass
    /// schedule of the configured dataflow, tallying cycles, passes and
    /// busy MACs (no numerics). Kept as the oracle the analytic
    /// [`SystolicSim::stats`] is asserted against in debug builds and
    /// property tests.
    pub fn loop_stats(&self, a: usize, b: usize, c: usize) -> SimStats {
        let (p1, p2) = (self.p1, self.p2);
        let i_sa = p1.max(p2) as u64;
        let mut cycles: u64 = 0;
        let mut passes: u64 = 0;
        let mut busy_macs: u64 = 0;
        // (tile extents along the two partitioned dims, streamed length)
        let (d1, d2, stream) = match self.dataflow {
            // P1 output rows × P2 output cols; pass length b
            Dataflow::NS => (a, c, b),
            // stationary P1×P2 weight block over (b, c); a streams
            Dataflow::WS => (b, c, a),
            // stationary P1×P2 input block over (b, a); c streams
            Dataflow::IS => (b, a, c),
        };
        for t1 in 0..d1.div_ceil(p1) {
            for t2 in 0..d2.div_ceil(p2) {
                let k1 = p1.min(d1 - t1 * p1);
                let k2 = p2.min(d2 - t2 * p2);
                cycles += stream as u64;
                passes += 1;
                busy_macs += (k1 * k2) as u64 * stream as u64;
                if !self.stall_free {
                    cycles += i_sa;
                }
            }
        }
        if self.stall_free {
            cycles += i_sa; // paid once, overlapped thereafter (§3.2)
        }
        SimStats {
            cycles,
            passes,
            useful_macs: busy_macs,
            utilization: busy_macs as f64 / (cycles as f64 * (p1 * p2) as f64),
            conflict_stalls: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn random_mat(r: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| r.i8_small() as f32)
    }

    #[test]
    fn functional_equivalence_all_dataflows() {
        check("systolic_functional", 48, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 40), r.range(1, 40), r.range(1, 40));
            let x = random_mat(r, a, b);
            let w = random_mat(r, b, c);
            let reference = x.matmul(&w);
            for df in Dataflow::ALL {
                let sim = SystolicSim::new(r.range(1, 12), r.range(1, 12), df, true);
                let (out, _) = sim.gemm(&x, &w);
                assert_allclose(&out.data, &reference.data, 1e-3, 1e-5)
                    .map_err(|e| format!("{df:?}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn packed_gemm_matches_unpacked() {
        let mut r = Rng::new(17);
        let x = random_mat(&mut r, 23, 14);
        let w = random_mat(&mut r, 14, 9);
        let wt = PackedWt::pack(&w);
        for df in Dataflow::ALL {
            let sim = SystolicSim::new(5, 3, df, true);
            let (o1, s1) = sim.gemm(&x, &w);
            let (o2, s2) = sim.gemm_packed(&x, &wt);
            assert_eq!(o1.data, o2.data, "{df:?}");
            assert_eq!(s1, s2, "{df:?}");
        }
    }

    #[test]
    fn analytic_stats_match_loop_derived_exactly() {
        // the tentpole cross-check: closed-form SimStats ≡ the old
        // schedule-walking accounting, both PE modes, ragged shapes
        check("systolic_stats_vs_loop", 128, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 70), r.range(1, 70), r.range(1, 70));
            let (p1, p2) = (r.range(1, 17), r.range(1, 17));
            for df in Dataflow::ALL {
                for stall_free in [true, false] {
                    let sim = SystolicSim::new(p1, p2, df, stall_free);
                    let analytic = sim.stats(a, b, c);
                    let walked = sim.loop_stats(a, b, c);
                    if analytic != walked {
                        return Err(format!(
                            "{df:?} stall_free={stall_free} ({a},{b},{c}) on \
                             ({p1},{p2}): analytic {analytic:?} != loop {walked:?}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cycles_match_eq9() {
        check("systolic_cycles_eq9", 64, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 60), r.range(1, 60), r.range(1, 60));
            let (p1, p2) = (r.range(1, 16), r.range(1, 16));
            let x = random_mat(r, a, b);
            let w = random_mat(r, b, c);
            for df in Dataflow::ALL {
                let sim = SystolicSim::new(p1, p2, df, true);
                let (_, st) = sim.gemm(&x, &w);
                let model = gemm::gemm_cycles(p1, p2, df, a, b, c);
                if st.cycles != model {
                    return Err(format!(
                        "{df:?} sim {} != Eq.9 {} for ({a},{b},{c}) on ({p1},{p2})",
                        st.cycles, model
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn naive_cycles_match_model() {
        let mut r = Rng::new(3);
        let x = random_mat(&mut r, 10, 20);
        let w = random_mat(&mut r, 20, 30);
        for df in Dataflow::ALL {
            let sim = SystolicSim::new(4, 4, df, false);
            let (_, st) = sim.gemm(&x, &w);
            assert_eq!(st.cycles, gemm::gemm_cycles_naive(4, 4, df, 10, 20, 30), "{df:?}");
        }
    }

    #[test]
    fn utilization_matches_analytic() {
        let mut r = Rng::new(4);
        let x = random_mat(&mut r, 62, 124);
        let w = random_mat(&mut r, 124, 64);
        let sim = SystolicSim::new(31, 31, Dataflow::NS, true);
        let (_, st) = sim.gemm(&x, &w);
        let analytic = gemm::gemm_utilization(31, 31, Dataflow::NS, 62, 124, 64);
        assert!((st.utilization - analytic).abs() < 1e-12);
        // the paper's §3.2 example: ~68% NS utilization
        assert!((0.60..0.72).contains(&st.utilization));
    }

    #[test]
    fn stall_free_beats_naive() {
        let mut r = Rng::new(5);
        let x = random_mat(&mut r, 33, 7); // b < P_SA: many passes, short b
        let w = random_mat(&mut r, 7, 33);
        let fast = SystolicSim::new(8, 8, Dataflow::NS, true).gemm(&x, &w).1;
        let slow = SystolicSim::new(8, 8, Dataflow::NS, false).gemm(&x, &w).1;
        assert!(slow.cycles > fast.cycles);
        // same functional result
        let (o1, _) = SystolicSim::new(8, 8, Dataflow::NS, true).gemm(&x, &w);
        let (o2, _) = SystolicSim::new(8, 8, Dataflow::NS, false).gemm(&x, &w);
        assert_eq!(o1.data, o2.data);
    }
}
