//! Pooling module (§3.4): Pooling Units with a Horizontal PU feeding a
//! Vertical PU in a pipelined fashion, one result per clock each, an
//! array of PUs parallel across feature maps.

use crate::algos::tensor::Tensor;
use crate::graph::layer::{PoolKind, PoolSpec};

/// Simulation result of a pooling layer.
#[derive(Debug, Clone)]
pub struct PoolSim {
    pub out: Tensor,
    pub cycles: u64,
}

/// Run the HPU→VPU pipeline for one pooling layer on `units` parallel
/// PUs. Functionally exact; cycles follow the pipeline model: the HPU
/// streams every input pixel of its assigned channels once (1/cycle),
/// the VPU overlaps after a `K` row fill.
pub fn simulate(input: &Tensor, spec: &PoolSpec, units: usize) -> PoolSim {
    assert_eq!(input.c, spec.c);
    assert_eq!((input.h, input.w), (spec.h1, spec.h2));
    let (o1, o2) = (spec.o1(), spec.o2());
    let mut out = Tensor::zeros(spec.c, o1, o2);

    for c in 0..spec.c {
        // HPU: horizontal window reduce per input row (stride s along x)
        // intermediate: h1 × o2
        let mut inter = vec![0.0f32; spec.h1 * o2];
        for y in 0..spec.h1 {
            for ox in 0..o2 {
                let mut m = init(spec.kind);
                for kx in 0..spec.k {
                    let ix = (ox * spec.s + kx) as isize - spec.p as isize;
                    let v = input.get_padded(c, y as isize, ix);
                    m = reduce(spec.kind, m, v, ix < 0 || ix >= spec.h2 as isize);
                }
                inter[y * o2 + ox] = finish(spec.kind, m, spec.k);
            }
        }
        // VPU: vertical reduce over K intermediate rows
        for oy in 0..o1 {
            for ox in 0..o2 {
                let mut m = init(spec.kind);
                for ky in 0..spec.k {
                    let iy = (oy * spec.s + ky) as isize - spec.p as isize;
                    let v = if iy < 0 || iy >= spec.h1 as isize {
                        0.0
                    } else {
                        inter[iy as usize * o2 + ox]
                    };
                    m = reduce(spec.kind, m, v, iy < 0 || iy >= spec.h1 as isize);
                }
                out.set(c, oy, ox, finish_v(spec.kind, m, spec.k));
            }
        }
    }

    // cycles: channels are distributed over `units` PUs; each PU streams
    // its channel's pixels through the HPU once; VPU overlaps except the
    // initial K-row fill.
    let chans_per_unit = spec.c.div_ceil(units) as u64;
    let hpu = (spec.h1 * spec.h2) as u64;
    let fill = (spec.k * spec.h2) as u64;
    let cycles = chans_per_unit * (hpu + fill);
    PoolSim { out, cycles }
}

fn init(kind: PoolKind) -> f32 {
    match kind {
        PoolKind::Max => f32::NEG_INFINITY,
        PoolKind::Avg => 0.0,
    }
}

fn reduce(kind: PoolKind, acc: f32, v: f32, oob: bool) -> f32 {
    match kind {
        // max pooling ignores padding (−∞ identity keeps in-bounds max);
        // out-of-bounds contributes nothing
        PoolKind::Max => {
            if oob {
                acc
            } else {
                acc.max(v)
            }
        }
        PoolKind::Avg => acc + v, // zero-padded average (count includes pad)
    }
}

fn finish(kind: PoolKind, acc: f32, _k: usize) -> f32 {
    match kind {
        PoolKind::Max => acc,
        PoolKind::Avg => acc, // horizontal stage keeps the raw sum
    }
}

fn finish_v(kind: PoolKind, acc: f32, k: usize) -> f32 {
    match kind {
        PoolKind::Max => acc,
        PoolKind::Avg => acc / (k * k) as f32,
    }
}

/// Naive reference pooling for validation.
pub fn reference(input: &Tensor, spec: &PoolSpec) -> Tensor {
    let (o1, o2) = (spec.o1(), spec.o2());
    let mut out = Tensor::zeros(spec.c, o1, o2);
    for c in 0..spec.c {
        for oy in 0..o1 {
            for ox in 0..o2 {
                let mut m = init(spec.kind);
                for ky in 0..spec.k {
                    for kx in 0..spec.k {
                        let iy = (oy * spec.s + ky) as isize - spec.p as isize;
                        let ix = (ox * spec.s + kx) as isize - spec.p as isize;
                        let oob =
                            iy < 0 || ix < 0 || iy >= spec.h1 as isize || ix >= spec.h2 as isize;
                        let v = input.get_padded(c, iy, ix);
                        m = reduce(spec.kind, m, v, oob);
                    }
                }
                out.set(c, oy, ox, finish_v(spec.kind, m, spec.k));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_allclose, check};
    use crate::util::rng::Rng;

    #[test]
    fn hpu_vpu_matches_reference() {
        check("pool_pipeline", 48, |r: &mut Rng| {
            let k = r.range(2, 3);
            let s = r.range(1, 2);
            let h = r.range(k + 1, 12);
            let kind = if r.bool() { PoolKind::Max } else { PoolKind::Avg };
            let p = if r.bool() && kind == PoolKind::Max { r.range(0, 1) } else { 0 };
            let spec = PoolSpec { kind, c: r.range(1, 4), h1: h, h2: h, k, s, p };
            let input = Tensor::random(spec.c, h, h, r);
            let sim = simulate(&input, &spec, 4);
            let reference = reference(&input, &spec);
            assert_allclose(&sim.out.data, &reference.data, 1e-5, 1e-5)
                .map_err(|e| format!("{spec:?}: {e}"))
        });
    }

    #[test]
    fn known_maxpool() {
        let spec = PoolSpec { kind: PoolKind::Max, c: 1, h1: 4, h2: 4, k: 2, s: 2, p: 0 };
        let input = Tensor::from_fn(1, 4, 4, |_, y, x| (y * 4 + x) as f32);
        let sim = simulate(&input, &spec, 1);
        assert_eq!(sim.out.data, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn cycles_scale_with_units() {
        let spec = PoolSpec { kind: PoolKind::Max, c: 16, h1: 8, h2: 8, k: 2, s: 2, p: 0 };
        let input = Tensor::zeros(16, 8, 8);
        let one = simulate(&input, &spec, 1).cycles;
        let four = simulate(&input, &spec, 4).cycles;
        assert_eq!(one, 4 * four);
    }
}
