//! Dual-parallelism blocked data layout (§3.2, Eq. 7, Fig. 4).
//!
//! A matrix is partitioned into `P_SA1 × P_SA2` blocks along both
//! systolic-array dimensions and block `(i, j)` is stored in
//! `Bank_x = (i + j) mod N_B`, `Block_y = i` — the circular shift
//! guarantees that reading a full block-row (NS dataflow streaming) or
//! a full block-column (WS/IS stationary pre-load) touches `N_B`
//! distinct banks, so both access patterns are single-cycle parallel
//! and conflict-free without `P_SA1 × P_SA2` individual banks.

/// The Eq. 7 mapping: block coordinates → (bank, slot).
#[derive(Debug, Clone, Copy)]
pub struct BlockedLayout {
    /// Number of SRAM banks (= max(P_SA1, P_SA2) in the overlay).
    pub n_banks: usize,
}

impl BlockedLayout {
    pub fn new(n_banks: usize) -> BlockedLayout {
        assert!(n_banks > 0);
        BlockedLayout { n_banks }
    }

    /// Eq. 7: `(Bank_x, Block_y)` of block `(i, j)`.
    #[inline]
    pub fn place(&self, i: usize, j: usize) -> (usize, usize) {
        ((i + j) % self.n_banks, i)
    }

    /// Banks touched when reading block-row `i` across `w` block-columns.
    pub fn row_banks(&self, i: usize, w: usize) -> Vec<usize> {
        (0..w).map(|j| self.place(i, j).0).collect()
    }

    /// Banks touched when reading block-column `j` across `h` block-rows.
    pub fn col_banks(&self, j: usize, h: usize) -> Vec<usize> {
        (0..h).map(|i| self.place(i, j).0).collect()
    }

    /// Count of conflicting (same-bank) pairs in one parallel access —
    /// 0 means single-cycle conflict-free.
    pub fn conflicts(banks: &[usize]) -> usize {
        let mut sorted = banks.to_vec();
        sorted.sort_unstable();
        sorted.windows(2).filter(|w| w[0] == w[1]).count()
    }
}

/// A banked scratchpad storing f32 words, modelling the Input/Kernel/
/// Output buffers. Tracks per-cycle access sets to detect conflicts.
#[derive(Debug, Clone)]
pub struct BankedSram {
    pub layout: BlockedLayout,
    pub banks: Vec<Vec<f32>>,
    /// Total accesses and conflict-stall cycles observed.
    pub accesses: u64,
    pub conflict_stalls: u64,
}

impl BankedSram {
    pub fn new(n_banks: usize, bank_words: usize) -> BankedSram {
        BankedSram {
            layout: BlockedLayout::new(n_banks),
            banks: vec![vec![0.0; bank_words]; n_banks],
            accesses: 0,
            conflict_stalls: 0,
        }
    }

    /// Perform one parallel access to `(bank, addr)` pairs; extra cycles
    /// are charged when multiple requests hit one bank.
    pub fn parallel_read(&mut self, reqs: &[(usize, usize)]) -> Vec<f32> {
        self.accesses += reqs.len() as u64;
        let banks: Vec<usize> = reqs.iter().map(|&(b, _)| b).collect();
        self.conflict_stalls += BlockedLayout::conflicts(&banks) as u64;
        reqs.iter().map(|&(b, a)| self.banks[b][a]).collect()
    }

    pub fn write(&mut self, bank: usize, addr: usize, v: f32) {
        self.banks[bank][addr] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, rng::Rng};

    #[test]
    fn rows_and_cols_conflict_free() {
        proptest::check("eq7_conflict_free", 128, |r: &mut Rng| {
            let n = r.range(1, 64);
            let l = BlockedLayout::new(n);
            // any row / column access across up to n blocks is conflict-free
            let w = r.range(1, n);
            let i = r.range(0, 2 * n);
            let j = r.range(0, 2 * n);
            let rb = l.row_banks(i, w);
            let cb = l.col_banks(j, w);
            if BlockedLayout::conflicts(&rb) != 0 {
                return Err(format!("row conflict: n={n} i={i} banks={rb:?}"));
            }
            if BlockedLayout::conflicts(&cb) != 0 {
                return Err(format!("col conflict: n={n} j={j} banks={cb:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn naive_layout_conflicts_on_columns() {
        // contrast: storing block (i,j) in bank j (no circular shift)
        // makes column reads hit a single bank — total serialization.
        let n = 8;
        let naive: Vec<usize> = (0..n).map(|_i| 3 % n).collect();
        assert_eq!(BlockedLayout::conflicts(&naive), n - 1);
    }

    #[test]
    fn sram_counts_conflicts() {
        let mut s = BankedSram::new(4, 16);
        s.write(0, 0, 1.0);
        s.write(1, 0, 2.0);
        let v = s.parallel_read(&[(0, 0), (1, 0)]);
        assert_eq!(v, vec![1.0, 2.0]);
        assert_eq!(s.conflict_stalls, 0);
        s.parallel_read(&[(2, 0), (2, 1)]);
        assert_eq!(s.conflict_stalls, 1);
    }

    #[test]
    fn place_is_stable() {
        let l = BlockedLayout::new(4);
        assert_eq!(l.place(0, 0), (0, 0));
        assert_eq!(l.place(1, 3), (0, 1));
        assert_eq!(l.place(2, 3), (1, 2));
    }
}
