//! Winograd Linear Transform module (§3.1): shift-add implementation of
//! the F(2×2, 3×3) transforms.
//!
//! The F(2,3) matrices contain only `0, ±1, ±½`, so the hardware
//! implements them with adders and 1-bit shifts (§3.1: "can be easily
//! implemented using shift and add operations"). This module mirrors
//! that: fixed-point `i32` arithmetic with a fractional guard bit,
//! counting add/shift operations, validated against the floating-point
//! transforms of [`crate::algos::winograd`].


/// Operation counters for one transform invocation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct XformOps {
    pub adds: u64,
    pub shifts: u64,
}

/// Input transform `V = Bᵀ d B` in shift-add form. `d` is 4×4.
/// All entries of Bᵀ are 0/±1 → pure adds.
pub fn transform_input_shiftadd(d: &[i32; 16], ops: &mut XformOps) -> [i32; 16] {
    // rows: Bᵀ · d   (t[r][c] = combination of d[.][c])
    let mut t = [0i32; 16];
    for c in 0..4 {
        let (d0, d1, d2, d3) = (d[c], d[4 + c], d[8 + c], d[12 + c]);
        t[c] = d0 - d2;
        t[4 + c] = d1 + d2;
        t[8 + c] = d2 - d1;
        t[12 + c] = d1 - d3;
        ops.adds += 4;
    }
    // cols: (Bᵀ d) · B  — same combination along rows
    let mut v = [0i32; 16];
    for r in 0..4 {
        let (t0, t1, t2, t3) = (t[r * 4], t[r * 4 + 1], t[r * 4 + 2], t[r * 4 + 3]);
        v[r * 4] = t0 - t2;
        v[r * 4 + 1] = t1 + t2;
        v[r * 4 + 2] = t2 - t1;
        v[r * 4 + 3] = t1 - t3;
        ops.adds += 4;
    }
    v
}

/// Kernel transform `U = G g Gᵀ` in shift-add form with one fractional
/// guard bit: inputs are `g·2`, i.e. the caller passes kernel values
/// pre-scaled by 2 so the ½ factors become 1-bit right shifts without
/// precision loss; the result carries scale 4 (2 per side).
pub fn transform_kernel_shiftadd(g2: &[i32; 9], ops: &mut XformOps) -> [i32; 16] {
    // Stage 1: t = 2·(G·g). With pre-doubled inputs (g2 = 2g, all even)
    // the ½ rows become exact 1-bit right shifts:
    // row0 = g2₀ ; row1 = (g2₀+g2₁+g2₂)≫1 ; row2 = (g2₀−g2₁+g2₂)≫1 ;
    // row3 = g2₂.
    let mut t = [0i32; 12]; // 4×3
    for c in 0..3 {
        let (g0, g1, g2v) = (g2[c], g2[3 + c], g2[6 + c]);
        t[c] = g0;
        t[3 + c] = (g0 + g1 + g2v) >> 1;
        t[6 + c] = (g0 - g1 + g2v) >> 1;
        t[9 + c] = g2v;
        ops.adds += 4;
        ops.shifts += 2;
    }
    let mut u = [0i32; 16];
    for r in 0..4 {
        let (t0, t1, t2) = (t[r * 3], t[r * 3 + 1], t[r * 3 + 2]);
        u[r * 4] = t0 << 1;
        u[r * 4 + 1] = t0 + t1 + t2;
        u[r * 4 + 2] = t0 - t1 + t2;
        u[r * 4 + 3] = t2 << 1;
        ops.adds += 4;
        ops.shifts += 2;
    }
    u
}

/// Inverse transform `Y = Aᵀ M A` in shift-add form (Aᵀ is 0/±1).
pub fn inverse_transform_shiftadd(m: &[i32; 16], ops: &mut XformOps) -> [i32; 4] {
    // Aᵀ·M → 2×4
    let mut t = [0i32; 8];
    for c in 0..4 {
        let (m0, m1, m2, m3) = (m[c], m[4 + c], m[8 + c], m[12 + c]);
        t[c] = m0 + m1 + m2;
        t[4 + c] = m1 - m2 - m3;
        ops.adds += 4;
    }
    let mut y = [0i32; 4];
    for r in 0..2 {
        let (t0, t1, t2, t3) = (t[r * 4], t[r * 4 + 1], t[r * 4 + 2], t[r * 4 + 3]);
        y[r * 2] = t0 + t1 + t2;
        y[r * 2 + 1] = t1 - t2 - t3;
        ops.adds += 4;
    }
    y
}

/// Cycle model of the Linear Transform module: a pipelined tree does
/// one 4×4 tile per cycle per unit, `units` in parallel, plus the
/// pipeline fill depth.
pub fn lt_cycles(tiles: usize, units: usize) -> u64 {
    (tiles.div_ceil(units) as u64) + 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::tensor::Mat;
    use crate::algos::winograd;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn mat_from(v: &[i32]) -> Mat {
        Mat { rows: 4, cols: 4, data: v.iter().map(|&x| x as f32).collect() }
    }

    #[test]
    fn input_transform_matches_float() {
        check("wino_xform_input", 64, |r: &mut Rng| {
            let mut d = [0i32; 16];
            for v in &mut d {
                *v = r.i8_small() as i32;
            }
            let mut ops = XformOps::default();
            let fast = transform_input_shiftadd(&d, &mut ops);
            let float = winograd::transform_input(&mat_from(&d));
            for i in 0..16 {
                if (fast[i] as f32 - float.data[i]).abs() > 1e-3 {
                    return Err(format!("V[{i}]: {} vs {}", fast[i], float.data[i]));
                }
            }
            if ops.adds != 32 {
                return Err(format!("expected 32 adds, counted {}", ops.adds));
            }
            Ok(())
        });
    }

    #[test]
    fn kernel_transform_matches_float_times_4() {
        check("wino_xform_kernel", 64, |r: &mut Rng| {
            let mut g = [0i32; 9];
            for v in &mut g {
                *v = r.i8_small() as i32;
            }
            // pre-scale by 2 (the guard bit)
            let g2: [i32; 9] = std::array::from_fn(|i| g[i] * 2);
            let mut ops = XformOps::default();
            let fast = transform_kernel_shiftadd(&g2, &mut ops);
            let k3 = Mat { rows: 3, cols: 3, data: g.iter().map(|&x| x as f32).collect() };
            let float = winograd::transform_kernel(&k3);
            for i in 0..16 {
                // fast carries scale 4 (2 per transform side)
                if (fast[i] as f32 - 4.0 * float.data[i]).abs() > 1e-3 {
                    return Err(format!("U[{i}]: {} vs 4·{}", fast[i], float.data[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn inverse_transform_matches_float() {
        check("wino_xform_inverse", 64, |r: &mut Rng| {
            let mut m = [0i32; 16];
            for v in &mut m {
                *v = r.i8_small() as i32 * 16;
            }
            let mut ops = XformOps::default();
            let fast = inverse_transform_shiftadd(&m, &mut ops);
            let float =
                winograd::inverse_transform(&mat_from(&m));
            for i in 0..4 {
                if (fast[i] as f32 - float.data[i]).abs() > 1e-3 {
                    return Err(format!("Y[{i}]: {} vs {}", fast[i], float.data[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn end_to_end_tile_shiftadd() {
        // full tile pipeline: transform kernel+input, hadamard in i32,
        // inverse — compare against the float path with scale 4
        let mut r = Rng::new(77);
        let mut g = [0i32; 9];
        let mut d = [0i32; 16];
        for v in &mut g {
            *v = r.i8_small() as i32;
        }
        for v in &mut d {
            *v = r.i8_small() as i32;
        }
        let mut ops = XformOps::default();
        let g2: [i32; 9] = std::array::from_fn(|i| g[i] * 2);
        let u = transform_kernel_shiftadd(&g2, &mut ops);
        let v = transform_input_shiftadd(&d, &mut ops);
        let m: [i32; 16] = std::array::from_fn(|i| u[i] * v[i]);
        let y = inverse_transform_shiftadd(&m, &mut ops);

        let k3 = Mat { rows: 3, cols: 3, data: g.iter().map(|&x| x as f32).collect() };
        let uf = winograd::transform_kernel(&k3);
        let vf = winograd::transform_input(&mat_from(&d));
        let mf = Mat::from_fn(4, 4, |i, j| uf.get(i, j) * vf.get(i, j));
        let yf = winograd::inverse_transform(&mf);
        for i in 0..4 {
            assert!(
                (y[i] as f32 - 4.0 * yf.data[i]).abs() < 1e-2,
                "tile Y[{i}]: {} vs 4·{}",
                y[i],
                yf.data[i]
            );
        }
    }

    #[test]
    fn lt_cycle_model() {
        assert_eq!(lt_cycles(64, 16), 8);
        assert_eq!(lt_cycles(65, 16), 9);
        assert_eq!(lt_cycles(1, 16), 5);
    }
}
