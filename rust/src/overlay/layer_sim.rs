//! Whole-layer simulation: run one CONV layer through the overlay under
//! a chosen (algorithm, dataflow) pair — DLT gather, linear transforms,
//! the systolic Computing Unit, Pad-and-Accumulate — producing both the
//! functional output (validated against `algos::direct`) and measured
//! cycles/utilization (cross-checked against the Eq. 10–12 model).

use super::dlt::Ltu;
use super::pad_accum::PadAccum;
use super::systolic::SystolicSim;
use super::wino_xform;
use crate::algos::tensor::{Mat, Tensor, Weights};
use crate::algos::{im2col, kn2row, winograd};
use crate::cost::conv::{Algo, CostModel};
use crate::cost::gemm::Dataflow;
use crate::graph::layer::ConvSpec;

/// Measured result of simulating one layer.
#[derive(Debug, Clone)]
pub struct LayerSim {
    pub out: Tensor,
    /// Computing Unit busy cycles (sum over all GEMM calls).
    pub cu_cycles: u64,
    /// Exposed (non-overlapped) auxiliary-module cycles: Pad-and-
    /// Accumulate tail, Linear Transform fill.
    pub aux_cycles: u64,
    /// Measured effective PE utilization over the CU busy time (Eq. 14).
    pub utilization: f64,
    pub gemm_calls: u64,
}

/// Simulate one conv layer end to end on the overlay.
pub fn simulate_layer(
    input: &Tensor,
    weights: &Weights,
    spec: &ConvSpec,
    algo: Algo,
    df: Dataflow,
    p1: usize,
    p2: usize,
) -> LayerSim {
    let sim = SystolicSim::new(p1, p2, df, true);
    match algo {
        Algo::Im2col => {
            // DLT gathers the Toeplitz matrix; one GEMM
            let ltu = Ltu::tensor3d_to_toeplitz(spec);
            let rows = spec.k1 * spec.k2 * spec.c_in;
            let cols = spec.o1() * spec.o2();
            let mut toep = vec![0.0f32; rows * cols];
            ltu.gather(&input.data, &mut toep);
            let x = Mat { rows, cols, data: toep };
            let w = im2col::weight_matrix(weights);
            // CU computes W (C_out × K²C) × X (K²C × O²): a=C_out rows?
            // Eq. 10 uses (a,b,c) = (O1O2, K1K2C_in, C_out); feed as
            // Xᵀ·Wᵀ to match: a=O1O2. Use x_t (O² × K²C) · w_t (K²C × C_out)
            let x_t = x.transposed();
            let w_t = w.transposed();
            let (z, st) = sim.gemm(&x_t, &w_t);
            // z: (O1O2 × C_out) → CHW tensor
            let (o1, o2) = (spec.o1(), spec.o2());
            let out = Tensor::from_fn(spec.c_out, o1, o2, |c, y, x_| z.get(y * o2 + x_, c));
            LayerSim {
                out,
                cu_cycles: st.cycles,
                aux_cycles: 0,
                utilization: st.utilization,
                gemm_calls: 1,
            }
        }
        Algo::Kn2row => {
            // K1K2 unit-conv GEMMs pipelined with Pad-and-Accumulate
            let mut pa = PadAccum::new(spec, p1.max(p2));
            let mut cu_cycles = 0u64;
            let mut macs = 0u64;
            let mut per_call = 0u64;
            for ky in 0..spec.k1 {
                for kx in 0..spec.k2 {
                    let xm = kn2row::input_matrix(input).transposed(); // (H1H2 × C_in)
                    let wm = kn2row::unit_weight_matrix(weights, ky, kx).transposed(); // (C_in × C_out)
                    let (patch_t, st) = sim.gemm(&xm, &wm); // (H1H2 × C_out)
                    cu_cycles += st.cycles;
                    macs += st.useful_macs;
                    per_call = st.cycles;
                    let patch = patch_t.transposed();
                    pa.accumulate(&patch, ky, kx);
                }
            }
            let aux = pa.exposed_cycles(per_call);
            let out = pa.take();
            LayerSim {
                out,
                cu_cycles,
                aux_cycles: aux,
                utilization: macs as f64 / (cu_cycles as f64 * (p1 * p2) as f64),
                gemm_calls: (spec.k1 * spec.k2) as u64,
            }
        }
        Algo::Winograd { m, r } => {
            assert_eq!((m, r), (2, 3), "overlay implements F(2×2, 3×3)");
            simulate_winograd(input, weights, spec, &sim, p1, p2)
        }
        Algo::WinogradStrided { .. } => {
            // functional fallback through the polyphase decomposition;
            // CU cycles modeled as 4 stride-1 sub-layers
            let out = winograd::conv2d_strided(input, weights, spec);
            LayerSim { out, cu_cycles: 0, aux_cycles: 0, utilization: 0.0, gemm_calls: 4 }
        }
    }
}

/// Winograd path: DLT scatters tiles, LT modules transform, the CU runs
/// the 16 per-point GEMMs (per 3×3 sub-kernel round), inverse transform
/// + restore.
fn simulate_winograd(
    input: &Tensor,
    weights: &Weights,
    spec: &ConvSpec,
    sim: &SystolicSim,
    p1: usize,
    p2: usize,
) -> LayerSim {
    let (m, r) = (2usize, 3usize);
    let a = m + r - 1; // 4
    let (o1, o2) = (spec.o1(), spec.o2());
    let t1 = o1.div_ceil(m);
    let t2 = o2.div_ceil(m);
    let tiles = t1 * t2;
    let groups = spec.k1.div_ceil(r);
    let mut out = Tensor::zeros(spec.c_out, o1, o2);
    let mut cu_cycles = 0u64;
    let mut macs = 0u64;
    let mut calls = 0u64;

    for gy in 0..groups {
        for gx in 0..groups {
            // V tiles for every (channel, tile): gathered + transformed
            // (the DLT + LT pipeline)
            let mut v = vec![Mat::zeros(tiles, spec.c_in); a * a];
            for ci in 0..spec.c_in {
                for ty in 0..t1 {
                    for tx in 0..t2 {
                        let iy0 = (ty * m + gy * r) as isize - spec.p1 as isize;
                        let ix0 = (tx * m + gx * r) as isize - spec.p2 as isize;
                        let d = Mat::from_fn(a, a, |y, x| {
                            input.get_padded(ci, iy0 + y as isize, ix0 + x as isize)
                        });
                        let vt = winograd::transform_input(&d);
                        for py in 0..a {
                            for px in 0..a {
                                v[py * a + px].set(ty * t2 + tx, ci, vt.get(py, px));
                            }
                        }
                    }
                }
            }
            // U for this sub-kernel round
            let mut u = vec![Mat::zeros(spec.c_in, spec.c_out); a * a];
            for co in 0..spec.c_out {
                for ci in 0..spec.c_in {
                    let k3 = Mat::from_fn(3, 3, |y, x| {
                        let ky = gy * r + y;
                        let kx = gx * r + x;
                        if ky < spec.k1 && kx < spec.k2 {
                            weights.get(co, ci, ky, kx)
                        } else {
                            0.0
                        }
                    });
                    let ut = winograd::transform_kernel(&k3);
                    for py in 0..a {
                        for px in 0..a {
                            u[py * a + px].set(ci, co, ut.get(py, px));
                        }
                    }
                }
            }
            // 16 independent GEMMs (tiles × C_in) · (C_in × C_out)
            let mut m_pts = Vec::with_capacity(a * a);
            for p in 0..a * a {
                let (z, st) = sim.gemm(&v[p], &u[p]);
                cu_cycles += st.cycles;
                macs += st.useful_macs;
                calls += 1;
                m_pts.push(z);
            }
            // inverse transform + accumulate into the output
            for co in 0..spec.c_out {
                for ty in 0..t1 {
                    for tx in 0..t2 {
                        let mm = Mat::from_fn(a, a, |py, px| {
                            m_pts[py * a + px].get(ty * t2 + tx, co)
                        });
                        let y = winograd::inverse_transform(&mm);
                        for dy in 0..m {
                            for dx in 0..m {
                                let (oy, ox) = (ty * m + dy, tx * m + dx);
                                if oy < o1 && ox < o2 {
                                    let cur = out.get(co, oy, ox);
                                    out.set(co, oy, ox, cur + y.get(dy, dx));
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    // exposed LT pipeline fill per round (transforms otherwise overlap
    // with CU streaming)
    let aux = wino_xform::lt_cycles(tiles, p1) * (groups * groups) as u64;
    LayerSim {
        out,
        cu_cycles,
        aux_cycles: aux,
        utilization: macs as f64 / (cu_cycles as f64 * (p1 * p2) as f64),
        gemm_calls: calls,
    }
}

/// Cross-check helper: analytical cycles for the same configuration.
pub fn model_cycles(cm: &CostModel, spec: &ConvSpec, algo: Algo, df: Dataflow, p1: usize, p2: usize) -> u64 {
    cm.conv_cost(spec, algo, df, p1, p2).cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::direct;
    use crate::cost::Device;
    use crate::util::proptest::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn run_case(
        r: &mut Rng,
        algo: Algo,
        spec: &ConvSpec,
    ) -> Result<(), String> {
        let input = Tensor::random(spec.c_in, spec.h1, spec.h2, r);
        let w = Weights::random(spec.c_out, spec.c_in, spec.k1, spec.k2, r);
        let df = *r.choose(&Dataflow::ALL);
        let (p1, p2) = (r.range(2, 8), r.range(2, 8));
        let simr = simulate_layer(&input, &w, spec, algo, df, p1, p2);
        let reference = direct::conv2d(&input, &w, spec);
        assert_allclose(&simr.out.data, &reference.data, 1e-2, 1e-3)
            .map_err(|e| format!("{algo:?}/{df:?} p=({p1},{p2}) {spec:?}: {e}"))?;
        // utilization sane
        if !(simr.utilization > 0.0 && simr.utilization <= 1.0) {
            return Err(format!("bad utilization {}", simr.utilization));
        }
        Ok(())
    }

    #[test]
    fn im2col_layer_functional() {
        check("layer_sim_im2col", 24, |r: &mut Rng| {
            let spec = im2col::random_spec(r);
            run_case(r, Algo::Im2col, &spec)
        });
    }

    #[test]
    fn kn2row_layer_functional() {
        check("layer_sim_kn2row", 24, |r: &mut Rng| {
            let spec = im2col::random_spec(r);
            run_case(r, Algo::Kn2row, &spec)
        });
    }

    #[test]
    fn winograd_layer_functional() {
        check("layer_sim_wino", 12, |r: &mut Rng| {
            let k = *r.choose(&[3usize, 5]);
            let h = r.range(k + 1, 10);
            let spec = ConvSpec::new(r.range(1, 3), r.range(1, 3), h, h, k, k, 1, k / 2, k / 2);
            run_case(r, Algo::Winograd { m: 2, r: 3 }, &spec)
        });
    }

    #[test]
    fn cu_cycles_match_analytic_model() {
        // the simulator's pass schedule must reproduce Eq. 10/11 GEMM
        // cycles exactly (LT/pad-accum exposed cycles are separate).
        let cm = CostModel::new(Device::alveo_u200());
        let spec = ConvSpec::new(4, 6, 10, 10, 3, 3, 1, 1, 1);
        let mut r = Rng::new(41);
        let input = Tensor::random(4, 10, 10, &mut r);
        let w = Weights::random(6, 4, 3, 3, &mut r);
        for algo in [Algo::Im2col, Algo::Kn2row] {
            for df in Dataflow::ALL {
                let s = simulate_layer(&input, &w, &spec, algo, df, 8, 4);
                // analytic models I_SA once per GEMM call
                let gemm_model: u64 = match algo {
                    Algo::Im2col => {
                        crate::cost::gemm::gemm_cycles(8, 4, df, 100, 36, 6)
                    }
                    Algo::Kn2row => {
                        9 * crate::cost::gemm::gemm_cycles(8, 4, df, 100, 4, 6)
                    }
                    _ => unreachable!(),
                };
                assert_eq!(s.cu_cycles, gemm_model, "{algo:?}/{df:?}");
                let _ = &cm;
            }
        }
    }

    #[test]
    fn winograd_uses_fewer_cu_cycles_on_big_channels() {
        // where Winograd should win: 3×3, deep channels, big maps
        let spec = ConvSpec::new(16, 16, 16, 16, 3, 3, 1, 1, 1);
        let mut r = Rng::new(42);
        let input = Tensor::random(16, 16, 16, &mut r);
        let w = Weights::random(16, 16, 3, 3, &mut r);
        let im = simulate_layer(&input, &w, &spec, Algo::Im2col, Dataflow::NS, 8, 8);
        let wi = simulate_layer(
            &input,
            &w,
            &spec,
            Algo::Winograd { m: 2, r: 3 },
            Dataflow::NS,
            8,
            8,
        );
        assert!(
            wi.cu_cycles < im.cu_cycles,
            "winograd {} should beat im2col {}",
            wi.cu_cycles,
            im.cu_cycles
        );
    }
}
