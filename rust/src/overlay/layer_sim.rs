//! Whole-layer simulation: run one CONV layer through the overlay under
//! a chosen (algorithm, dataflow) pair, producing the functional output
//! (validated against `algos::direct`) and the cycle accounting
//! (cross-checked against the Eq. 10–12 model).
//!
//! The two halves are decoupled: the output tensor comes from the
//! kernel layer ([`crate::kernels::PreparedWeights::conv2d`] — weight
//! transforms pre-lowered once, transpose-free GEMMs), while the
//! Computing-Unit cycles, pass counts and utilization are closed-form
//! per GEMM shape ([`SystolicSim::stats`], itself debug-asserted
//! against the old pass-schedule walk). Serving callers prepare weights
//! once via [`prepare_layer`] and amortize the lowering over every
//! request; [`simulate_layer`] keeps the old one-shot signature.

use super::pad_accum::PadAccum;
use super::systolic::SystolicSim;
use super::wino_xform;
use crate::algos::tensor::{Tensor, Weights};
use crate::cost::conv::{Algo, CostModel};
use crate::cost::gemm::Dataflow;
use crate::graph::layer::ConvSpec;
use crate::kernels::PreparedWeights;

/// Measured result of simulating one layer.
#[derive(Debug, Clone)]
pub struct LayerSim {
    pub out: Tensor,
    /// Computing Unit busy cycles (sum over all GEMM calls).
    pub cu_cycles: u64,
    /// Exposed (non-overlapped) auxiliary-module cycles: Pad-and-
    /// Accumulate tail, Linear Transform fill.
    pub aux_cycles: u64,
    /// Measured effective PE utilization over the CU busy time (Eq. 14).
    pub utilization: f64,
    pub gemm_calls: u64,
}

/// Pre-lower one layer's weights for `algo` — the offline half of the
/// split. The returned [`PreparedWeights`] is request-invariant; build
/// it once per (layer, algorithm) and reuse across the serving loop.
pub fn prepare_layer(weights: &Weights, spec: &ConvSpec, algo: Algo) -> PreparedWeights {
    PreparedWeights::new(weights, spec, algo)
}

/// Simulate one conv layer end to end on the overlay (one-shot: lowers
/// the weights, then delegates to [`simulate_layer_prepared`]).
pub fn simulate_layer(
    input: &Tensor,
    weights: &Weights,
    spec: &ConvSpec,
    algo: Algo,
    df: Dataflow,
    p1: usize,
    p2: usize,
) -> LayerSim {
    simulate_layer_prepared(input, &prepare_layer(weights, spec, algo), df, p1, p2)
}

/// Simulate one conv layer with pre-lowered weights: functional output
/// from the kernel layer, cycles from the analytic pass model.
pub fn simulate_layer_prepared(
    input: &Tensor,
    pw: &PreparedWeights,
    df: Dataflow,
    p1: usize,
    p2: usize,
) -> LayerSim {
    let sim = SystolicSim::new(p1, p2, df, true);
    let spec = &pw.spec;
    let pes = (p1 * p2) as f64;
    match pw.algo {
        Algo::Im2col => {
            // one GEMM over the Toeplitz matrix:
            // (O1O2 × K1K2C_in) · (K1K2C_in × C_out)
            let st = sim.stats(spec.o1() * spec.o2(), spec.k1 * spec.k2 * spec.c_in, spec.c_out);
            LayerSim {
                out: pw.conv2d(input),
                cu_cycles: st.cycles,
                aux_cycles: 0,
                utilization: st.utilization,
                gemm_calls: 1,
            }
        }
        Algo::Kn2row => {
            // K1K2 unit-conv GEMMs (H1H2 × C_in) · (C_in × C_out),
            // pipelined with Pad-and-Accumulate
            let calls = (spec.k1 * spec.k2) as u64;
            let st = sim.stats(spec.h1 * spec.h2, spec.c_in, spec.c_out);
            let cu_cycles = calls * st.cycles;
            let macs = calls * st.useful_macs;
            LayerSim {
                out: pw.conv2d(input),
                cu_cycles,
                aux_cycles: PadAccum::exposed_cycles_for(spec, p1.max(p2), st.cycles),
                utilization: macs as f64 / (cu_cycles as f64 * pes),
                gemm_calls: calls,
            }
        }
        Algo::Winograd { m, r } => {
            // per sub-kernel round, (m+r−1)² point GEMMs of
            // (tiles × C_in) · (C_in × C_out); LT pipeline fill exposed
            // once per round
            assert_eq!((m, r), (2, 3), "overlay implements F(2×2, 3×3)");
            let t1 = spec.o1().div_ceil(m);
            let t2 = spec.o2().div_ceil(m);
            let tiles = t1 * t2;
            let groups = spec.k1.div_ceil(r);
            let points = ((m + r - 1) * (m + r - 1)) as u64;
            let calls = points * (groups * groups) as u64;
            let st = sim.stats(tiles, spec.c_in, spec.c_out);
            let cu_cycles = calls * st.cycles;
            let macs = calls * st.useful_macs;
            LayerSim {
                out: pw.conv2d(input),
                cu_cycles,
                aux_cycles: wino_xform::lt_cycles(tiles, p1) * (groups * groups) as u64,
                utilization: macs as f64 / (cu_cycles as f64 * pes),
                gemm_calls: calls,
            }
        }
        Algo::WinogradStrided { .. } => {
            // functional fallback through the polyphase decomposition;
            // CU cycles modeled as 4 stride-1 sub-layers
            LayerSim {
                out: pw.conv2d(input),
                cu_cycles: 0,
                aux_cycles: 0,
                utilization: 0.0,
                gemm_calls: 4,
            }
        }
    }
}

/// Cross-check helper: analytical cycles for the same configuration.
pub fn model_cycles(cm: &CostModel, spec: &ConvSpec, algo: Algo, df: Dataflow, p1: usize, p2: usize) -> u64 {
    cm.conv_cost(spec, algo, df, p1, p2).cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{direct, im2col};
    use crate::cost::Device;
    use crate::util::proptest::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn run_case(
        r: &mut Rng,
        algo: Algo,
        spec: &ConvSpec,
    ) -> Result<(), String> {
        let input = Tensor::random(spec.c_in, spec.h1, spec.h2, r);
        let w = Weights::random(spec.c_out, spec.c_in, spec.k1, spec.k2, r);
        let df = *r.choose(&Dataflow::ALL);
        let (p1, p2) = (r.range(2, 8), r.range(2, 8));
        let simr = simulate_layer(&input, &w, spec, algo, df, p1, p2);
        let reference = direct::conv2d(&input, &w, spec);
        assert_allclose(&simr.out.data, &reference.data, 1e-2, 1e-3)
            .map_err(|e| format!("{algo:?}/{df:?} p=({p1},{p2}) {spec:?}: {e}"))?;
        // utilization sane
        if !(simr.utilization > 0.0 && simr.utilization <= 1.0) {
            return Err(format!("bad utilization {}", simr.utilization));
        }
        Ok(())
    }

    #[test]
    fn im2col_layer_functional() {
        check("layer_sim_im2col", 24, |r: &mut Rng| {
            let spec = im2col::random_spec(r);
            run_case(r, Algo::Im2col, &spec)
        });
    }

    #[test]
    fn kn2row_layer_functional() {
        check("layer_sim_kn2row", 24, |r: &mut Rng| {
            let spec = im2col::random_spec(r);
            run_case(r, Algo::Kn2row, &spec)
        });
    }

    #[test]
    fn winograd_layer_functional() {
        check("layer_sim_wino", 12, |r: &mut Rng| {
            let k = *r.choose(&[3usize, 5]);
            let h = r.range(k + 1, 10);
            let spec = ConvSpec::new(r.range(1, 3), r.range(1, 3), h, h, k, k, 1, k / 2, k / 2);
            run_case(r, Algo::Winograd { m: 2, r: 3 }, &spec)
        });
    }

    #[test]
    fn prepared_path_is_request_invariant() {
        // preparing once and simulating many inputs must match the
        // one-shot path bit-for-bit, stats included
        let spec = ConvSpec::new(3, 5, 9, 9, 3, 3, 1, 1, 1);
        let mut r = Rng::new(40);
        let w = Weights::random(5, 3, 3, 3, &mut r);
        for algo in [Algo::Im2col, Algo::Kn2row, Algo::Winograd { m: 2, r: 3 }] {
            let pw = prepare_layer(&w, &spec, algo);
            for _ in 0..3 {
                let input = Tensor::random(3, 9, 9, &mut r);
                let a = simulate_layer(&input, &w, &spec, algo, Dataflow::NS, 8, 4);
                let b = simulate_layer_prepared(&input, &pw, Dataflow::NS, 8, 4);
                assert_eq!(a.out.data, b.out.data, "{algo:?}");
                assert_eq!(
                    (a.cu_cycles, a.aux_cycles, a.gemm_calls),
                    (b.cu_cycles, b.aux_cycles, b.gemm_calls),
                    "{algo:?}"
                );
            }
        }
    }

    #[test]
    fn cu_cycles_match_analytic_model() {
        // the simulator's pass schedule must reproduce Eq. 10/11 GEMM
        // cycles exactly (LT/pad-accum exposed cycles are separate).
        let cm = CostModel::new(Device::alveo_u200());
        let spec = ConvSpec::new(4, 6, 10, 10, 3, 3, 1, 1, 1);
        let mut r = Rng::new(41);
        let input = Tensor::random(4, 10, 10, &mut r);
        let w = Weights::random(6, 4, 3, 3, &mut r);
        for algo in [Algo::Im2col, Algo::Kn2row] {
            for df in Dataflow::ALL {
                let s = simulate_layer(&input, &w, &spec, algo, df, 8, 4);
                // analytic models I_SA once per GEMM call
                let gemm_model: u64 = match algo {
                    Algo::Im2col => {
                        crate::cost::gemm::gemm_cycles(8, 4, df, 100, 36, 6)
                    }
                    Algo::Kn2row => {
                        9 * crate::cost::gemm::gemm_cycles(8, 4, df, 100, 4, 6)
                    }
                    _ => unreachable!(),
                };
                assert_eq!(s.cu_cycles, gemm_model, "{algo:?}/{df:?}");
                let _ = &cm;
            }
        }
    }

    #[test]
    fn winograd_uses_fewer_cu_cycles_on_big_channels() {
        // where Winograd should win: 3×3, deep channels, big maps
        let spec = ConvSpec::new(16, 16, 16, 16, 3, 3, 1, 1, 1);
        let mut r = Rng::new(42);
        let input = Tensor::random(16, 16, 16, &mut r);
        let w = Weights::random(16, 16, 3, 3, &mut r);
        let im = simulate_layer(&input, &w, &spec, Algo::Im2col, Dataflow::NS, 8, 8);
        let wi = simulate_layer(
            &input,
            &w,
            &spec,
            Algo::Winograd { m: 2, r: 3 },
            Dataflow::NS,
            8,
            8,
        );
        assert!(
            wi.cu_cycles < im.cu_cycles,
            "winograd {} should beat im2col {}",
            wi.cu_cycles,
            im.cu_cycles
        );
    }
}
