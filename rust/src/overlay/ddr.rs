//! DDR interface model: burst aggregation of a generated address
//! stream (§3.3: "generated (DDR address, data) tuples are buffered
//! until DDR transfer burst length (BL) is saturated").

/// Burst accountant: feed it the DRAM addresses a DLT/LTU pass
/// generates in order; it groups consecutive addresses into bursts of
/// up to `bl` elements and counts transactions.
#[derive(Debug, Clone)]
pub struct BurstCounter {
    pub bl: usize,
    transactions: u64,
    run_start: Option<u64>,
    run_len: usize,
    last: Option<u64>,
}

impl BurstCounter {
    pub fn new(bl: usize) -> BurstCounter {
        assert!(bl > 0);
        BurstCounter { bl, transactions: 0, run_start: None, run_len: 0, last: None }
    }

    /// Feed one generated DDR address.
    pub fn push(&mut self, addr: u64) {
        match self.last {
            Some(last) if addr == last + 1 && self.run_len < self.bl => {
                self.run_len += 1;
            }
            _ => {
                if self.run_start.is_some() {
                    self.transactions += 1;
                }
                self.run_start = Some(addr);
                self.run_len = 1;
            }
        }
        self.last = Some(addr);
    }

    /// Close the stream, returning total burst transactions.
    pub fn finish(mut self) -> u64 {
        if self.run_start.is_some() {
            self.transactions += 1;
        }
        self.transactions
    }

    /// Effective bandwidth utilization of the stream: elements moved /
    /// (transactions × BL).
    pub fn efficiency(elements: u64, transactions: u64, bl: usize) -> f64 {
        if transactions == 0 {
            return 1.0;
        }
        elements as f64 / (transactions as f64 * bl as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_saturates_bursts() {
        let mut b = BurstCounter::new(64);
        for a in 0..640u64 {
            b.push(a);
        }
        let tx = b.finish();
        assert_eq!(tx, 10);
        assert_eq!(BurstCounter::efficiency(640, tx, 64), 1.0);
    }

    #[test]
    fn strided_stream_wastes_bandwidth() {
        // stride-16 addresses: every element opens a new burst
        let mut b = BurstCounter::new(64);
        for i in 0..100u64 {
            b.push(i * 16);
        }
        let tx = b.finish();
        assert_eq!(tx, 100);
        assert!(BurstCounter::efficiency(100, tx, 64) < 0.02);
    }

    #[test]
    fn scattered_with_c_runs() {
        // the Eq. 13 pattern: runs of C consecutive addresses spaced far
        // apart — efficiency ≈ C/BL when C < BL
        let c = 16u64;
        let bl = 64;
        let mut b = BurstCounter::new(bl);
        for chunk in 0..50u64 {
            for i in 0..c {
                b.push(chunk * 10_000 + i);
            }
        }
        let tx = b.finish();
        assert_eq!(tx, 50);
        let eff = BurstCounter::efficiency(50 * c, tx, bl);
        assert!((eff - c as f64 / bl as f64).abs() < 1e-12);
    }

    #[test]
    fn empty_stream() {
        let b = BurstCounter::new(8);
        assert_eq!(b.finish(), 0);
    }
}
