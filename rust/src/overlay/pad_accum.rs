//! Pad-and-Accumulate module (§3.1, kn2row phase 2).
//!
//! Bank indices and address offsets are precomputed from the layer meta
//! data; the accumulation buffer adds each shifted unit-conv patch while
//! the Computing Unit works on the next patch — the pipelining that
//! hides most of the phase-2 overhead (modelled by the `exposed_cycles`
//! accounting here and assumed by Eq. 11's bare `×K1K2` factor).

use crate::algos::kn2row;
use crate::algos::tensor::{Mat, Tensor};
use crate::graph::layer::ConvSpec;

/// Precomputed accumulation descriptor for one kernel tap.
#[derive(Debug, Clone, Copy)]
pub struct TapPlan {
    pub ky: usize,
    pub kx: usize,
    /// Number of patch elements that actually land in the output
    /// (the rest fall on the zero-pad fringe).
    pub live_elems: usize,
}

/// The module: accumulation buffer + per-tap plans.
#[derive(Debug, Clone)]
pub struct PadAccum {
    pub spec: ConvSpec,
    pub plans: Vec<TapPlan>,
    pub acc: Tensor,
    /// Accumulator write ports (elements added per cycle).
    pub ports: usize,
}

/// Per-tap accumulation plans for a layer. The in-bounds test separates
/// per axis, so the live-pixel count is a product of two 1-D counts —
/// O(O1 + O2) per tap instead of an O(O1·O2) double loop.
pub fn tap_plans(spec: &ConvSpec) -> Vec<TapPlan> {
    let (o1, o2) = (spec.o1(), spec.o2());
    let live_1d = |o: usize, k: usize, p: usize, h: usize| -> usize {
        (0..o)
            .filter(|&v| {
                let i = (v * spec.s + k) as isize - p as isize;
                i >= 0 && i < h as isize
            })
            .count()
    };
    let mut plans = Vec::with_capacity(spec.k1 * spec.k2);
    for ky in 0..spec.k1 {
        let rows = live_1d(o1, ky, spec.p1, spec.h1);
        for kx in 0..spec.k2 {
            let live = rows * live_1d(o2, kx, spec.p2, spec.h2);
            plans.push(TapPlan { ky, kx, live_elems: live * spec.c_out });
        }
    }
    plans
}

impl PadAccum {
    pub fn new(spec: &ConvSpec, ports: usize) -> PadAccum {
        PadAccum {
            plans: tap_plans(spec),
            acc: Tensor::zeros(spec.c_out, spec.o1(), spec.o2()),
            spec: spec.clone(),
            ports,
        }
    }

    /// [`PadAccum::exposed_cycles`] without instantiating the module —
    /// for cycle-accounting callers that never accumulate (the
    /// accumulation buffer allocation is skipped entirely).
    pub fn exposed_cycles_for(spec: &ConvSpec, ports: usize, gemm_cycles: u64) -> u64 {
        exposed(&tap_plans(spec), ports, gemm_cycles)
    }

    /// Accumulate one unit-conv patch (functional) and return the cycle
    /// count of this tap's accumulation pass.
    pub fn accumulate(&mut self, patch: &Mat, ky: usize, kx: usize) -> u64 {
        kn2row::pad_accumulate(&mut self.acc, patch, &self.spec, ky, kx);
        self.tap_cycles(ky, kx)
    }

    /// Cycles of one tap's accumulation pass.
    pub fn tap_cycles(&self, ky: usize, kx: usize) -> u64 {
        let plan = &self.plans[ky * self.spec.k2 + kx];
        (plan.live_elems as u64).div_ceil(self.ports as u64)
    }

    /// Exposed (non-overlapped) cycles when each accumulation pass is
    /// pipelined behind a unit-conv GEMM taking `gemm_cycles`: only the
    /// excess of the final pass shows (§3.1: "CU starts working on the
    /// next patch while the accumulation buffer still processes the
    /// last").
    pub fn exposed_cycles(&self, gemm_cycles: u64) -> u64 {
        exposed(&self.plans, self.ports, gemm_cycles)
    }

    pub fn take(self) -> Tensor {
        self.acc
    }
}

fn exposed(plans: &[TapPlan], ports: usize, gemm_cycles: u64) -> u64 {
    let per_tap: Vec<u64> =
        plans.iter().map(|p| (p.live_elems as u64).div_ceil(ports as u64)).collect();
    let hidden: u64 =
        per_tap.iter().rev().skip(1).map(|&c| c.saturating_sub(gemm_cycles)).sum();
    hidden + per_tap.last().copied().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::tensor::Weights;
    use crate::algos::{direct, kn2row};
    use crate::util::rng::Rng;

    #[test]
    fn functional_equivalence() {
        let spec = ConvSpec::new(3, 4, 7, 7, 3, 3, 1, 1, 1);
        let mut rng = Rng::new(31);
        let input = Tensor::random_i8(3, 7, 7, &mut rng);
        let w = Weights::random_i8(4, 3, 3, 3, &mut rng);
        let mut pa = PadAccum::new(&spec, 16);
        for ky in 0..3 {
            for kx in 0..3 {
                let patch = kn2row::unit_conv(&input, &w, ky, kx);
                pa.accumulate(&patch, ky, kx);
            }
        }
        let out = pa.take();
        let reference = direct::conv2d(&input, &w, &spec);
        assert_eq!(out.data, reference.data);
    }

    #[test]
    fn live_elems_smaller_on_fringe_taps() {
        // corner taps lose a row+column to padding
        let spec = ConvSpec::new(1, 1, 8, 8, 3, 3, 1, 1, 1);
        let pa = PadAccum::new(&spec, 1);
        let center = pa.plans[4].live_elems; // (1,1)
        let corner = pa.plans[0].live_elems; // (0,0)
        assert_eq!(center, 64);
        assert_eq!(corner, 49);
    }

    #[test]
    fn pipelining_hides_accumulation() {
        let spec = ConvSpec::new(8, 8, 12, 12, 3, 3, 1, 1, 1);
        let pa = PadAccum::new(&spec, 8);
        // when GEMM is long, only the last tap's pass is exposed
        let long_gemm = 1_000_000;
        let exposed = pa.exposed_cycles(long_gemm);
        let last = (pa.plans.last().unwrap().live_elems as u64).div_ceil(8);
        assert_eq!(exposed, last);
        // when GEMM is tiny, nearly everything is exposed
        let all: u64 =
            pa.plans.iter().map(|p| (p.live_elems as u64).div_ceil(8)).sum();
        assert!(pa.exposed_cycles(0) == all);
        // the allocation-free path agrees with the instantiated module
        for g in [0, 3, long_gemm] {
            assert_eq!(PadAccum::exposed_cycles_for(&spec, 8, g), pa.exposed_cycles(g));
        }
    }
}
