//! Data Layout Transformation module (§3.3, Table 1, Fig. 5).
//!
//! A Layout Transformation Unit (LTU) is a nested-counter address
//! generator: each FSM level walks a counter and advances the on-chip
//! SRAM address `B` and the DRAM address `D` by per-level strides —
//! exactly the `(I, step_b, step_d, I1, inc_b2, inc_d2, …)` scheme of
//! Table 1, generalized to any nesting depth (Table 1 shows the
//! depth-1-feature-map rows; the channel loop is one more level).
//!
//! Padding is handled the way the hardware does: the FSM tracks the 2-D
//! `(y, x)` coordinate alongside the linear SRAM address and a bounds
//! mux substitutes zero outside `[0, H1) × [0, H2)` — a purely linear
//! address check would wrap across rows/channels.
//!
//! [`Ltu::tensor3d_to_toeplitz`], [`Ltu::tensor3d_to_wino`] and
//! [`Ltu::wino_to_tensor3d`] instantiate the three Table-1 rows; tests
//! verify each against the reference layout builders in
//! [`crate::algos`], and the generated DRAM streams against the burst
//! behaviour Table 2 assumes (sequential for Toeplitz stores, `C`-run
//! scattered for Winograd-input stores — the Eq. 13 wastage).

use crate::graph::layer::ConvSpec;

/// One FSM nesting level: `count` iterations advancing the SRAM address
/// by `b_stride`, the DRAM address by `d_stride`, and the 2-D bounds
/// coordinate by `(dy, dx)` per step.
#[derive(Debug, Clone, Copy)]
pub struct Level {
    pub count: usize,
    pub b_stride: i64,
    pub d_stride: i64,
    pub dy: i64,
    pub dx: i64,
}

/// A configured LTU: base addresses, bounds geometry and nesting levels
/// (outermost first). `h1 == 0` disables the bounds mux (source layout
/// has no spatial halo, e.g. the scattered Winograd buffers).
#[derive(Debug, Clone)]
pub struct Ltu {
    pub b0: i64,
    pub d0: i64,
    pub y0: i64,
    pub x0: i64,
    pub h1: usize,
    pub h2: usize,
    pub levels: Vec<Level>,
}

impl Ltu {
    /// Total tuples generated.
    pub fn len(&self) -> usize {
        self.levels.iter().map(|l| l.count).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Run the FSM, invoking `f(b, d, valid)` for every generated pair.
    ///
    /// Addresses are maintained *incrementally* by the odometer (add the
    /// stride on increment, subtract `count·stride` on carry) — exactly
    /// how the hardware counters work, and ~4× faster than recomputing
    /// the affine sum per tuple (perf pass iteration 4).
    pub fn walk(&self, mut f: impl FnMut(i64, i64, bool)) {
        let n = self.levels.len();
        let mut idx = vec![0usize; n];
        let (mut b, mut d) = (self.b0, self.d0);
        let (mut y, mut x) = (self.y0, self.x0);
        let bounded = self.h1 != 0;
        loop {
            let valid =
                !bounded || (y >= 0 && x >= 0 && y < self.h1 as i64 && x < self.h2 as i64);
            f(b, d, valid);
            let mut i = n;
            loop {
                if i == 0 {
                    return;
                }
                i -= 1;
                idx[i] += 1;
                let l = &self.levels[i];
                if idx[i] < l.count {
                    b += l.b_stride;
                    d += l.d_stride;
                    y += l.dy;
                    x += l.dx;
                    break;
                }
                // carry: rewind this level
                let c = (l.count - 1) as i64;
                idx[i] = 0;
                b -= c * l.b_stride;
                d -= c * l.d_stride;
                y -= c * l.dy;
                x -= c * l.dx;
                if i == 0 {
                    return;
                }
            }
        }
    }

    /// Apply as a gather: `dst[d] = src[b]`, zero when the bounds mux
    /// fires (padding halo).
    pub fn gather(&self, src: &[f32], dst: &mut [f32]) {
        self.walk(|b, d, valid| {
            let v = if valid && b >= 0 && (b as usize) < src.len() {
                src[b as usize]
            } else {
                0.0
            };
            dst[d as usize] = v;
        });
    }

    /// Collect the generated DRAM address stream (for burst analysis).
    pub fn d_stream(&self) -> Vec<u64> {
        let mut v = Vec::with_capacity(self.len());
        self.walk(|_, d, _| v.push(d as u64));
        v
    }

    // --- Table 1 instantiations -------------------------------------

    /// Row 1 — 3D Tensor → Toeplitz for layer `spec` (all channels).
    /// Iteration (ci, ky, kx, oy, ox); DRAM layout is the row-major
    /// `(K1K2·C_in) × (O1·O2)` Toeplitz matrix of `algos::im2col`.
    /// The generated D stream is fully sequential — Table 2 row 1's
    /// "can be streamed out".
    pub fn tensor3d_to_toeplitz(spec: &ConvSpec) -> Ltu {
        let (o1, o2) = (spec.o1() as i64, spec.o2() as i64);
        let (h2, s) = (spec.h2 as i64, spec.s as i64);
        Ltu {
            b0: -(spec.p1 as i64) * h2 - spec.p2 as i64,
            d0: 0,
            y0: -(spec.p1 as i64),
            x0: -(spec.p2 as i64),
            h1: spec.h1,
            h2: spec.h2,
            levels: vec![
                Level {
                    count: spec.c_in,
                    b_stride: (spec.h1 * spec.h2) as i64,
                    d_stride: (spec.k1 * spec.k2) as i64 * o1 * o2,
                    dy: 0,
                    dx: 0,
                },
                Level {
                    count: spec.k1,
                    b_stride: h2,
                    d_stride: spec.k2 as i64 * o1 * o2,
                    dy: 1,
                    dx: 0,
                },
                Level { count: spec.k2, b_stride: 1, d_stride: o1 * o2, dy: 0, dx: 1 },
                Level { count: spec.o1(), b_stride: s * h2, d_stride: o2, dy: s, dx: 0 },
                Level { count: spec.o2(), b_stride: s, d_stride: 1, dy: 0, dx: s },
            ],
        }
    }

    /// Row 2 — 3D Tensor → Winograd input layout: gather each
    /// `(m+r−1)²` tile (adjacent tiles overlap by `r−1`) into the
    /// scattered per-point matrices. DRAM layout is channel-INNERMOST
    /// (`[point][tile][channel]`) — §5.1.2: "in practice we access
    /// C_out(i) altogether for each address increment", which is what
    /// makes runs of `C < BL` waste bursts (Eq. 13). Iteration order is
    /// the source-stream order (wy, wx, ty, tx, ci).
    pub fn tensor3d_to_wino(c: usize, h1: usize, h2: usize, m: usize, r: usize, pad: usize) -> Ltu {
        let t1 = h1.div_ceil(m);
        let t2 = h2.div_ceil(m);
        let tiles = (t1 * t2) as i64;
        let a = m + r - 1;
        let ci = c as i64;
        Ltu {
            b0: -(pad as i64) * h2 as i64 - pad as i64,
            d0: 0,
            y0: -(pad as i64),
            x0: -(pad as i64),
            h1,
            h2,
            // walk order (ty, tx, wy, wx, ci): the store-side LTU
            // consumes the output buffer tile by tile, duplicating the
            // r−1 halo, and each (tile, point) slot lands `tiles·C`
            // apart in DRAM with only the C channel elements contiguous.
            levels: vec![
                Level { count: t1, b_stride: (m * h2) as i64, d_stride: t2 as i64 * ci, dy: m as i64, dx: 0 },
                Level { count: t2, b_stride: m as i64, d_stride: ci, dy: 0, dx: m as i64 },
                Level { count: a, b_stride: h2 as i64, d_stride: (a as i64) * tiles * ci, dy: 1, dx: 0 },
                Level { count: a, b_stride: 1, d_stride: tiles * ci, dy: 0, dx: 1 },
                Level { count: c, b_stride: (h1 * h2) as i64, d_stride: 1, dy: 0, dx: 0 },
            ],
        }
    }

    /// Row 3 — Winograd output layout → 3D Tensor: each output tile's
    /// `m²` elements live `T1·T2` apart in the scattered source; restore
    /// the spatial `(C, O1, O2)` tensor (store-side LTU #1 of the
    /// double-buffered §3.3.2 scheme). Source has no halo → bounds mux
    /// disabled.
    pub fn wino_to_tensor3d(c: usize, o1: usize, o2: usize, m: usize) -> Ltu {
        let t1 = o1.div_ceil(m);
        let t2 = o2.div_ceil(m);
        let tiles = (t1 * t2) as i64;
        Ltu {
            b0: 0,
            d0: 0,
            y0: 0,
            x0: 0,
            h1: 0,
            h2: 0,
            levels: vec![
                Level {
                    count: c,
                    b_stride: (m * m) as i64 * tiles,
                    d_stride: (o1 * o2) as i64,
                    dy: 0,
                    dx: 0,
                },
                Level { count: m, b_stride: m as i64 * tiles, d_stride: o2 as i64, dy: 0, dx: 0 },
                Level { count: m, b_stride: tiles, d_stride: 1, dy: 0, dx: 0 },
                Level { count: t1, b_stride: t2 as i64, d_stride: (m * o2) as i64, dy: 0, dx: 0 },
                Level { count: t2, b_stride: 1, d_stride: m as i64, dy: 0, dx: 0 },
            ],
        }
    }

    /// Identity (kn2row → kn2row): one-to-one consecutive matching.
    pub fn identity(n: usize) -> Ltu {
        Ltu {
            b0: 0,
            d0: 0,
            y0: 0,
            x0: 0,
            h1: 0,
            h2: 0,
            levels: vec![Level { count: n, b_stride: 1, d_stride: 1, dy: 0, dx: 0 }],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::im2col;
    use crate::algos::tensor::Tensor;
    use crate::overlay::ddr::BurstCounter;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn toeplitz_matches_reference() {
        check("ltu_toeplitz", 32, |r: &mut Rng| {
            let spec = im2col::random_spec(r);
            let t = Tensor::random_i8(spec.c_in, spec.h1, spec.h2, r);
            let reference = im2col::toeplitz(&t, &spec);
            let ltu = Ltu::tensor3d_to_toeplitz(&spec);
            let mut out = vec![0.0f32; reference.data.len()];
            ltu.gather(&t.data, &mut out);
            if out != reference.data {
                return Err(format!("LTU toeplitz mismatch for {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn toeplitz_store_stream_is_sequential() {
        // Table 2 row 1: "can be streamed out, as consecutive DRAM
        // addresses are accessed"
        let spec = ConvSpec::new(4, 8, 10, 10, 3, 3, 1, 1, 1);
        let ltu = Ltu::tensor3d_to_toeplitz(&spec);
        let stream = ltu.d_stream();
        let mut bc = BurstCounter::new(64);
        for a in &stream {
            bc.push(*a);
        }
        let tx = bc.finish();
        let eff = BurstCounter::efficiency(stream.len() as u64, tx, 64);
        assert!(eff > 0.95, "toeplitz store burst efficiency {eff}");
    }

    #[test]
    fn wino_gather_collects_overlapping_tiles() {
        // m=2, r=3: tiles are 4×4 with overlap 2 — verify against a
        // direct gather (channel-innermost DRAM layout)
        let (c, h, m, r, p) = (2usize, 8usize, 2usize, 3usize, 1usize);
        let mut rng = Rng::new(9);
        let t = Tensor::random_i8(c, h, h, &mut rng);
        let ltu = Ltu::tensor3d_to_wino(c, h, h, m, r, p);
        let t1 = h.div_ceil(m);
        let tiles = t1 * t1;
        let a = m + r - 1;
        let mut out = vec![0.0f32; c * a * a * tiles];
        ltu.gather(&t.data, &mut out);
        for &(ci, wy, wx, ty, tx) in
            &[(0usize, 0usize, 0usize, 0usize, 0usize), (1, 3, 2, 1, 3), (0, 1, 1, 2, 2)]
        {
            let d = (((wy * a + wx) * tiles) + ty * t1 + tx) * c + ci;
            let iy = (ty * m + wy) as isize - p as isize;
            let ix = (tx * m + wx) as isize - p as isize;
            let expect = t.get_padded(ci, iy, ix);
            assert_eq!(out[d], expect, "ci={ci} w=({wy},{wx}) t=({ty},{tx})");
        }
    }

    #[test]
    fn wino_output_restore_roundtrip() {
        let (c, o, m) = (3usize, 8usize, 2usize);
        let t1 = o.div_ceil(m);
        let tiles = t1 * t1;
        let mut rng = Rng::new(10);
        let spatial = Tensor::random_i8(c, o, o, &mut rng);
        let mut scattered = vec![0.0f32; c * m * m * tiles];
        for ci in 0..c {
            for py in 0..m {
                for px in 0..m {
                    for ty in 0..t1 {
                        for tx in 0..t1 {
                            let b = ((ci * m + py) * m + px) * tiles + ty * t1 + tx;
                            scattered[b] = spatial.get(ci, ty * m + py, tx * m + px);
                        }
                    }
                }
            }
        }
        let ltu = Ltu::wino_to_tensor3d(c, o, o, m);
        let mut restored = vec![0.0f32; c * o * o];
        ltu.gather(&scattered, &mut restored);
        assert_eq!(restored, spatial.data);
    }

    #[test]
    fn wino_store_stream_has_c_runs() {
        // Eq. 13: C-element runs spaced tile-count apart. With C=4 ≪
        // BL=64, burst efficiency collapses to ≈ C/BL.
        let c = 4;
        let ltu = Ltu::tensor3d_to_wino(c, 8, 8, 2, 3, 1);
        let stream = ltu.d_stream();
        let mut bc = BurstCounter::new(64);
        for a in &stream {
            bc.push(*a);
        }
        let tx = bc.finish();
        let eff = BurstCounter::efficiency(stream.len() as u64, tx, 64);
        assert!(eff < 0.2, "wino scatter should waste bursts, eff={eff}");
    }

    #[test]
    fn identity_is_one_to_one() {
        let ltu = Ltu::identity(10);
        let src: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let mut dst = vec![0.0; 10];
        ltu.gather(&src, &mut dst);
        assert_eq!(src, dst);
    }
}
