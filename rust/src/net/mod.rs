//! Production TCP front-end for the serving engine — `dynamap serve
//! --listen` and `loadgen --connect`.
//!
//! Three pieces, std-TCP only (no async runtime — the whole crate runs
//! on scoped threads and blocking I/O):
//!
//! * [`protocol`] — the versioned, length-prefixed binary wire format:
//!   `Infer`/`Ping`/`Shutdown` requests, typed error frames
//!   ([`WireError`] mirrors the serving subset of
//!   [`crate::api::DynamapError`]), hard payload caps, and decode paths
//!   that turn every malformed byte sequence into a typed
//!   `Protocol` error instead of a panic.
//! * [`server`] — [`NetServer`]: accept thread + one blocking worker
//!   per connection, all submitting into the shared
//!   [`crate::serve::ModelRegistry`] so network callers batch together
//!   with in-process ones. Admission control
//!   ([`crate::serve::RegistryConfig::max_inflight`]) sheds excess load
//!   with retriable `Overloaded` frames; [`NetServer::shutdown`]
//!   drains gracefully — every accepted request gets its reply, late
//!   connects are refused by the closed listener.
//! * [`client`] — [`Client`]: blocking, connection-pooled, with a
//!   unified [`RetryPolicy`] (capped exponential backoff with seeded
//!   jitter, a per-client retry budget, opt-in [`HedgeConfig`] hedged
//!   requests) and optional per-request deadlines carried on the wire.
//!   Implements [`crate::serve::loadgen::InferTarget`], so the
//!   open-loop generator drives a remote server exactly as it drives
//!   an in-process registry.
//!
//! Failure isolation and the deterministic fault-injection sites wired
//! through this stack are documented in [`crate::fault`] and exercised
//! end-to-end by the chaos harness in `rust/tests/chaos.rs`.
//!
//! ```no_run
//! use std::sync::Arc;
//! use dynamap::net::{Client, NetServer};
//! use dynamap::serve::{ModelRegistry, RegistryConfig};
//!
//! let registry = Arc::new(ModelRegistry::new(RegistryConfig::default()));
//! let mut server = NetServer::bind(registry, "127.0.0.1:0")?;
//! let client = Client::connect(server.local_addr().to_string())?;
//! let input = dynamap::runtime::TensorBuf::zeros(vec![4, 16, 16]);
//! let (output, server_us) = client.infer("mini", &input)?;
//! println!("{:?} in {server_us:.0}µs", output.shape);
//! client.shutdown_server()?;
//! server.shutdown(); // drain: every accepted request gets its reply
//! # Ok::<(), dynamap::api::DynamapError>(())
//! ```
#![warn(missing_docs)]
#![deny(clippy::correctness, clippy::suspicious)]

pub mod client;
pub mod protocol;
pub mod server;

pub use client::{backoff_delay, Client, ClientStats, HedgeConfig, RetryPolicy};
pub use protocol::{Frame, WireError};
pub use server::NetServer;
