//! [`Client`] — blocking TCP client for the DYNAMAP wire protocol,
//! with connection pooling, a unified retry/backoff policy and opt-in
//! request hedging.
//!
//! The protocol is strictly request-reply, so a connection is "free"
//! whenever no call is using it: [`Client`] keeps a small pool of idle
//! connections, checks one out per call and returns it afterwards.
//! Typed server errors (`Overloaded`, `UnknownModel`, …) leave the
//! stream on a frame boundary, so the connection goes back to the pool.
//!
//! Failure handling is governed by one [`RetryPolicy`] instead of the
//! old asymmetry (transport errors got a silent fresh-dial retry while
//! `Overloaded` was surfaced raw even when `retry_after_ms` was tiny):
//!
//! * **Transport failures** ([`DynamapError::Net`]) — the bytes never
//!   arrived; inference requests are stateless and idempotent, so the
//!   client re-dials fresh and retries up to
//!   [`RetryPolicy::transport_attempts`] total attempts.
//! * **`Overloaded` sheds** — retried up to
//!   [`RetryPolicy::overloaded_attempts`] *extra* attempts (default 0:
//!   surfacing the shed raw preserves the open-loop measurement
//!   semantics the loadgen and benches depend on), sleeping at least
//!   the server's `retry_after_ms` hint.
//! * Both paths share capped exponential backoff with seeded jitter
//!   ([`backoff_delay`]) and draw from one per-client
//!   [`RetryPolicy::retry_budget`], so a shed storm costs a bounded
//!   number of extra requests no matter how many callers share the
//!   client.
//! * **Protocol errors never retry**: the stream is out of sync, and
//!   re-sending bytes at a confused peer helps nobody.
//!
//! Hedging ([`RetryPolicy::hedge`]): when the primary attempt has
//! outlived a latency-EWMA-derived delay, a second identical request is
//! launched on a fresh connection and the first reply wins. The loser
//! is cancelled by dropping its reply channel — its connection is
//! closed, never pooled, so a late duplicate reply can never be
//! misdelivered to a future request. Hedging is safe precisely because
//! inference is read-only: a duplicated request duplicates compute,
//! never a side effect.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::DynamapError;
use crate::runtime::TensorBuf;
use crate::serve::loadgen::InferTarget;
use crate::serve::metrics::ModelMetrics;
use crate::util::rng::Rng;

use super::protocol::{read_frame, write_frame, Frame};

/// Idle connections kept per client (beyond this, checked-in
/// connections are simply closed).
const MAX_POOL: usize = 16;

/// Client-side failure policy: how many attempts each error class
/// gets, how backoff between attempts is shaped, and whether to hedge.
///
/// The default reproduces the original client behavior exactly — one
/// fresh-dial transport retry, `Overloaded` surfaced raw, no hedging —
/// so existing callers (the loadgen's shed accounting, the overload
/// benches) measure what they always measured. Opt into more with
/// [`Client::connect_with`].
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts for a request whose failures are all transport
    /// errors (≥ 1; the first attempt is included in the count).
    pub transport_attempts: u32,
    /// Extra attempts granted when the server sheds with `Overloaded`
    /// (0 = surface the shed raw, the default).
    pub overloaded_attempts: u32,
    /// Backoff before retry attempt 0 (doubles every attempt).
    pub base_backoff: Duration,
    /// Backoff ceiling (pre-jitter; the server's `retry_after_ms` hint
    /// may exceed it and then wins).
    pub max_backoff: Duration,
    /// Total retries this client may spend over its lifetime, across
    /// all threads sharing it. Bounds the amplification a retry storm
    /// can produce: once spent, every failure surfaces raw.
    pub retry_budget: u64,
    /// Seed for backoff jitter (deterministic given the draw order).
    pub seed: u64,
    /// `Some` enables hedged requests for `infer` calls.
    pub hedge: Option<HedgeConfig>,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            transport_attempts: 2,
            overloaded_attempts: 0,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(100),
            retry_budget: 64,
            seed: 99,
            hedge: None,
        }
    }
}

/// When to launch a hedged second attempt: after the primary has been
/// outstanding `ewma_mult ×` the client's EWMA of recent successful
/// request latency, clamped to `[min_delay, max_delay]` (and
/// `max_delay` before any latency has been observed).
#[derive(Debug, Clone)]
pub struct HedgeConfig {
    /// Multiple of the latency EWMA to wait before hedging.
    pub ewma_mult: f64,
    /// Never hedge sooner than this.
    pub min_delay: Duration,
    /// Never wait longer than this (also the cold-start delay).
    pub max_delay: Duration,
}

impl Default for HedgeConfig {
    fn default() -> HedgeConfig {
        HedgeConfig {
            ewma_mult: 3.0,
            min_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(50),
        }
    }
}

/// The backoff schedule, as a pure function so it is property-testable:
/// capped exponential in the attempt index, floored by the server's
/// `retry_after_ms` hint, scaled by seeded jitter in `[1, 1.25)`.
///
/// Invariants (asserted by the in-module property test):
/// * deterministic — same policy + same `Rng` state ⇒ same delay;
/// * the pre-jitter value grows monotonically with `attempt` until it
///   saturates at [`RetryPolicy::max_backoff`];
/// * the delay is always ≥ the server hint (backing off *less* than the
///   server asked just converts one shed into two);
/// * the delay is bounded by `max(max_backoff, hint) × 1.25`, so the
///   total sleep across a budget of retries is bounded too.
pub fn backoff_delay(
    policy: &RetryPolicy,
    attempt: u32,
    hint_ms: Option<u64>,
    rng: &mut Rng,
) -> Duration {
    let base_us = policy.base_backoff.as_secs_f64() * 1e6;
    let cap_us = policy.max_backoff.as_secs_f64() * 1e6;
    let exp_us = (base_us * 2f64.powi(attempt.min(16) as i32)).min(cap_us);
    let hint_us = hint_ms.unwrap_or(0) as f64 * 1000.0;
    let pre_us = exp_us.max(hint_us);
    let jitter = 1.0 + 0.25 * rng.f64();
    Duration::from_secs_f64((pre_us * jitter / 1e6).max(0.0))
}

/// Point-in-time counters for one [`Client`]'s failure handling.
#[derive(Debug, Clone, Copy)]
pub struct ClientStats {
    /// Retries spent so far (transport + overloaded).
    pub retries: u64,
    /// Hedged attempts that won the race against the primary.
    pub hedges_won: u64,
    /// Retry-budget tokens still available.
    pub budget_remaining: u64,
    /// EWMA of recent successful request latency, µs (0 = none yet).
    pub ewma_us: u64,
}

/// A blocking client for one server address; cheap to share across
/// threads (`&self` methods, pool behind a mutex held only during
/// checkout/checkin — never across a network round trip).
pub struct Client {
    addr: String,
    pool: Mutex<Vec<TcpStream>>,
    policy: RetryPolicy,
    rng: Mutex<Rng>,
    retries: AtomicU64,
    hedges_won: AtomicU64,
    budget_left: AtomicU64,
    /// EWMA of successful `infer` latency, µs — drives the hedge delay.
    ewma_us: AtomicU64,
    /// Optional server-side [`ModelMetrics`] to mirror retry/hedge
    /// counters into (so they land in the `stats` table).
    mirror: Mutex<Option<Arc<ModelMetrics>>>,
}

impl Client {
    /// Connect to `addr` (e.g. `"127.0.0.1:4071"`) with the default
    /// (original-behavior) [`RetryPolicy`], validating the server is
    /// reachable by dialing one pooled connection.
    pub fn connect(addr: impl Into<String>) -> Result<Client, DynamapError> {
        Client::connect_with(addr, RetryPolicy::default())
    }

    /// [`Client::connect`] with an explicit retry/backoff/hedge policy.
    pub fn connect_with(
        addr: impl Into<String>,
        policy: RetryPolicy,
    ) -> Result<Client, DynamapError> {
        let budget = policy.retry_budget;
        let seed = policy.seed;
        let client = Client {
            addr: addr.into(),
            pool: Mutex::new(Vec::new()),
            policy,
            rng: Mutex::new(Rng::new(seed)),
            retries: AtomicU64::new(0),
            hedges_won: AtomicU64::new(0),
            budget_left: AtomicU64::new(budget),
            ewma_us: AtomicU64::new(0),
            mirror: Mutex::new(None),
        };
        let conn = client.dial()?;
        client.checkin(conn);
        Ok(client)
    }

    /// The server address this client dials.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The policy this client was built with.
    pub fn policy(&self) -> &RetryPolicy {
        &self.policy
    }

    /// Current retry/hedge/budget counters.
    pub fn stats(&self) -> ClientStats {
        ClientStats {
            retries: self.retries.load(Ordering::Relaxed),
            hedges_won: self.hedges_won.load(Ordering::Relaxed),
            budget_remaining: self.budget_left.load(Ordering::Relaxed),
            ewma_us: self.ewma_us.load(Ordering::Relaxed),
        }
    }

    /// Mirror this client's retry and hedge-win counters into `metrics`
    /// (a model's [`ModelMetrics`]), so client-side reliability spend
    /// shows up in the server's `stats` table next to the work it
    /// caused.
    pub fn bind_metrics(&self, metrics: Arc<ModelMetrics>) {
        *self.mirror.lock().unwrap_or_else(|p| p.into_inner()) = Some(metrics);
    }

    fn dial(&self) -> Result<TcpStream, DynamapError> {
        let stream = TcpStream::connect(&self.addr)
            .map_err(|e| DynamapError::Net(format!("connect {} failed: {e}", self.addr)))?;
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    fn checkout(&self) -> Result<TcpStream, DynamapError> {
        let pooled = self.pool.lock().unwrap_or_else(|p| p.into_inner()).pop();
        match pooled {
            Some(conn) => Ok(conn),
            None => self.dial(),
        }
    }

    fn checkin(&self, conn: TcpStream) {
        let mut pool = self.pool.lock().unwrap_or_else(|p| p.into_inner());
        if pool.len() < MAX_POOL {
            pool.push(conn);
        }
    }

    /// Spend one retry-budget token; `false` when the budget is dry.
    fn try_spend_budget(&self) -> bool {
        self.budget_left
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |b| b.checked_sub(1))
            .is_ok()
    }

    fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = &*self.mirror.lock().unwrap_or_else(|p| p.into_inner()) {
            m.record_retries(1);
        }
    }

    fn note_hedge_won(&self) {
        self.hedges_won.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = &*self.mirror.lock().unwrap_or_else(|p| p.into_inner()) {
            m.record_hedge_won();
        }
    }

    fn next_backoff(&self, attempt: u32, hint_ms: Option<u64>) -> Duration {
        let mut rng = self.rng.lock().unwrap_or_else(|p| p.into_inner());
        backoff_delay(&self.policy, attempt, hint_ms, &mut rng)
    }

    fn observe_latency(&self, elapsed: Duration) {
        let us = (elapsed.as_secs_f64() * 1e6).max(1.0);
        let old = self.ewma_us.load(Ordering::Relaxed);
        let new = if old == 0 { us } else { old as f64 * 0.75 + us * 0.25 };
        self.ewma_us.store(new as u64, Ordering::Relaxed);
    }

    /// One request-reply round trip on a checked-out connection, with
    /// a single retry on transport failure (fresh connection). Used by
    /// the control-plane calls (`ping`, `shutdown`); `infer` goes
    /// through the policy-driven path instead. Returns the reply frame
    /// with the connection already returned to the pool — except after
    /// `Shutdown`, whose connection is spent.
    fn request(&self, frame: &Frame) -> Result<Frame, DynamapError> {
        let mut last_err = None;
        for attempt in 0..2 {
            // first attempt may use a pooled (possibly stale)
            // connection; the retry always dials fresh
            let mut conn = if attempt == 0 { self.checkout()? } else { self.dial()? };
            match roundtrip(&mut conn, frame) {
                Ok(reply) => {
                    if !matches!(frame, Frame::Shutdown) {
                        self.checkin(conn);
                    }
                    return Ok(reply);
                }
                Err(e @ DynamapError::Net(_)) => {
                    last_err = Some(e); // dropped conn; retry once
                }
                Err(e) => return Err(e), // protocol error: never retry
            }
        }
        Err(last_err.expect("retry loop ran"))
    }

    /// Serve one inference for `model`; returns the output tensor
    /// (bitwise-equal to a local `Session::infer` of the same request)
    /// and the server-side end-to-end latency in µs. Failure handling
    /// follows the client's [`RetryPolicy`]; under the default policy
    /// `Overloaded` comes back raw with its `retry_after_ms` hint.
    pub fn infer(
        &self,
        model: &str,
        input: &TensorBuf,
    ) -> Result<(TensorBuf, f64), DynamapError> {
        self.infer_with_deadline(model, input, None)
    }

    /// [`Client::infer`] carrying a relative deadline on the wire: the
    /// server sheds the request with the typed
    /// [`DynamapError::DeadlineExceeded`] once `deadline` has elapsed
    /// from the moment it decodes the frame (a relative field dodges
    /// clock skew between client and server). Each retry attempt sends
    /// the deadline afresh — the budget is per attempt, by design: a
    /// retry is a *new* request with a new arrival time.
    pub fn infer_with_deadline(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
    ) -> Result<(TensorBuf, f64), DynamapError> {
        self.infer_traced(model, input, deadline, None)
    }

    /// [`Client::infer_with_deadline`] carrying the request's
    /// span-correlation id ([`crate::obs::TraceId`]) on the wire as the
    /// protocol-v3 trailer, so the server's admission/queue/flush/layer
    /// spans for this request are tagged with an id the *client* chose
    /// (deterministic under a seeded loadgen). Retries and hedges
    /// re-send the same id — they are the same logical request, and a
    /// hedge's duplicate spans under one id are exactly what a trace
    /// viewer should show.
    pub fn infer_traced(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
        trace: Option<crate::obs::TraceId>,
    ) -> Result<(TensorBuf, f64), DynamapError> {
        let frame = Frame::Infer {
            model: model.to_string(),
            input: input.clone(),
            deadline_ms: deadline.map(|d| d.as_millis() as u64),
            trace,
        };
        let mut transport_left = self.policy.transport_attempts.saturating_sub(1);
        let mut overloaded_left = self.policy.overloaded_attempts;
        let mut attempt: u32 = 0;
        loop {
            let t0 = Instant::now();
            match self.attempt_infer(&frame, attempt > 0) {
                Ok((output, server_us)) => {
                    self.observe_latency(t0.elapsed());
                    return Ok((output, server_us));
                }
                Err(e @ DynamapError::Net(_)) => {
                    if transport_left == 0 || !self.try_spend_budget() {
                        return Err(e);
                    }
                    transport_left -= 1;
                    self.note_retry();
                    std::thread::sleep(self.next_backoff(attempt, None));
                }
                Err(DynamapError::Overloaded { model, retry_after_ms }) => {
                    if overloaded_left == 0 || !self.try_spend_budget() {
                        return Err(DynamapError::Overloaded { model, retry_after_ms });
                    }
                    overloaded_left -= 1;
                    self.note_retry();
                    // the backoff floor is the server's own hint: it
                    // knows its batch latency better than we do
                    std::thread::sleep(self.next_backoff(attempt, Some(retry_after_ms)));
                }
                Err(e) => return Err(e), // typed, non-retriable
            }
            attempt += 1;
        }
    }

    /// One infer attempt: a plain round trip, or a hedged race when the
    /// policy enables it.
    fn attempt_infer(
        &self,
        frame: &Frame,
        fresh: bool,
    ) -> Result<(TensorBuf, f64), DynamapError> {
        let reply = if self.policy.hedge.is_some() {
            self.roundtrip_hedged(frame, fresh)?
        } else {
            let mut conn = if fresh { self.dial()? } else { self.checkout()? };
            let reply = roundtrip(&mut conn, frame)?;
            self.checkin(conn);
            reply
        };
        match reply {
            Frame::InferOk { output, server_us } => Ok((output, server_us)),
            Frame::Error(e) => Err(e.into()),
            other => Err(unexpected("InferOk", &other)),
        }
    }

    /// The hedge delay for the current latency regime.
    fn hedge_delay(&self, cfg: &HedgeConfig) -> Duration {
        let ewma = self.ewma_us.load(Ordering::Relaxed);
        if ewma == 0 {
            return cfg.max_delay; // cold: hedge late, not eagerly
        }
        Duration::from_secs_f64(ewma as f64 * cfg.ewma_mult / 1e6)
            .clamp(cfg.min_delay, cfg.max_delay)
    }

    /// Race a primary attempt against an optional hedged second attempt
    /// launched once the primary has outlived the hedge delay. First
    /// reply wins; the loser's reply channel is dropped, so its late
    /// send fails and its connection is closed rather than pooled — a
    /// stale duplicate reply can never be misread by a later request.
    fn roundtrip_hedged(
        &self,
        frame: &Frame,
        fresh: bool,
    ) -> Result<Frame, DynamapError> {
        let cfg = self.policy.hedge.clone().expect("hedge config present");
        type Msg = (Result<Frame, DynamapError>, Option<TcpStream>, bool);
        let (tx, rx) = mpsc::channel::<Msg>();
        let done = Arc::new(AtomicBool::new(false));

        // primary: moves its (possibly pooled) connection into a
        // detached thread so this caller can time it out without
        // abandoning the read mid-frame
        let mut conn = if fresh { self.dial()? } else { self.checkout()? };
        let p_tx = tx.clone();
        let p_frame = frame.clone();
        std::thread::spawn(move || {
            let result = roundtrip(&mut conn, &p_frame);
            let keep = result.is_ok();
            let _ = p_tx.send((result, keep.then_some(conn), false));
        });

        let mut first = match rx.recv_timeout(self.hedge_delay(&cfg)) {
            Ok(msg) => Some(msg),
            Err(_) => None,
        };
        let mut hedge_launched = false;
        if first.is_none() {
            // primary is slow: fire the hedge on a fresh dial
            hedge_launched = true;
            let h_tx = tx.clone();
            let h_frame = frame.clone();
            let h_done = done.clone();
            let addr = self.addr.clone();
            std::thread::spawn(move || {
                if h_done.load(Ordering::SeqCst) {
                    return; // already decided: skip the dial entirely
                }
                let result = (|| {
                    let conn = TcpStream::connect(&addr)
                        .map_err(|e| DynamapError::Net(format!("hedge connect failed: {e}")))?;
                    let _ = conn.set_nodelay(true);
                    let mut conn = conn;
                    let reply = roundtrip(&mut conn, &h_frame)?;
                    Ok::<_, DynamapError>((reply, conn))
                })();
                let _ = match result {
                    Ok((reply, conn)) => h_tx.send((Ok(reply), Some(conn), true)),
                    Err(e) => h_tx.send((Err(e), None, true)),
                };
            });
        }
        drop(tx);

        let mut last_err: Option<DynamapError> = None;
        loop {
            let msg = match first.take() {
                Some(m) => m,
                None => match rx.recv() {
                    Ok(m) => m,
                    // every attempt has reported in and none won
                    Err(_) => {
                        return Err(last_err.unwrap_or_else(|| {
                            DynamapError::Net("hedged request got no reply".into())
                        }))
                    }
                },
            };
            let (result, conn, is_hedge) = msg;
            match result {
                Ok(reply) => {
                    done.store(true, Ordering::SeqCst);
                    if let Some(conn) = conn {
                        self.checkin(conn);
                    }
                    if is_hedge {
                        self.note_hedge_won();
                    }
                    return Ok(reply);
                }
                Err(e) => {
                    last_err = Some(e);
                    if !hedge_launched {
                        // primary failed before the hedge delay expired:
                        // there is no second attempt to wait for
                        return Err(last_err.expect("just set"));
                    }
                    // otherwise loop: the other attempt may still win
                }
            }
        }
    }

    /// Liveness probe; returns the round-trip time.
    pub fn ping(&self) -> Result<Duration, DynamapError> {
        let t0 = Instant::now();
        match self.request(&Frame::Ping)? {
            Frame::Pong => Ok(t0.elapsed()),
            Frame::Error(e) => Err(e.into()),
            other => Err(unexpected("Pong", &other)),
        }
    }

    /// Ask the server to drain and shut down; returns once the server
    /// has acked (drain begins immediately after).
    pub fn shutdown_server(&self) -> Result<(), DynamapError> {
        match self.request(&Frame::Shutdown)? {
            Frame::ShutdownAck => Ok(()),
            Frame::Error(e) => Err(e.into()),
            other => Err(unexpected("ShutdownAck", &other)),
        }
    }

    /// Fetch the server's full metrics document — every model's
    /// counters plus its latency-histogram snapshot
    /// ([`crate::serve::ServerMetrics::to_json`]) — as a JSON string.
    /// Behind `dynamap stats --connect`.
    pub fn server_stats(&self) -> Result<String, DynamapError> {
        match self.request(&Frame::Stats)? {
            Frame::StatsOk { json } => Ok(json),
            Frame::Error(e) => Err(e.into()),
            other => Err(unexpected("StatsOk", &other)),
        }
    }

    /// Drain the server's span recorder and fetch the result as a
    /// Chrome trace-event JSON document ([`crate::obs::chrome_trace`]).
    /// Collect-then-fetch: each call returns the spans recorded since
    /// the previous one. A server with tracing off returns a valid
    /// empty document. Behind `dynamap trace --connect`.
    pub fn dump_trace(&self) -> Result<String, DynamapError> {
        match self.request(&Frame::TraceDump)? {
            Frame::TraceDumpOk { json } => Ok(json),
            Frame::Error(e) => Err(e.into()),
            other => Err(unexpected("TraceDumpOk", &other)),
        }
    }
}

impl InferTarget for Client {
    fn infer_once(&self, model: &str, input: &TensorBuf) -> Result<TensorBuf, DynamapError> {
        self.infer(model, input).map(|(out, _)| out)
    }

    fn infer_deadline(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
    ) -> Result<TensorBuf, DynamapError> {
        self.infer_with_deadline(model, input, deadline).map(|(out, _)| out)
    }

    fn infer_traced(
        &self,
        model: &str,
        input: &TensorBuf,
        deadline: Option<Duration>,
        trace: Option<crate::obs::TraceId>,
    ) -> Result<TensorBuf, DynamapError> {
        Client::infer_traced(self, model, input, deadline, trace).map(|(out, _)| out)
    }
}

fn unexpected(wanted: &str, got: &Frame) -> DynamapError {
    let kind = match got {
        Frame::Infer { .. } => "Infer",
        Frame::Ping => "Ping",
        Frame::Shutdown => "Shutdown",
        Frame::Stats => "Stats",
        Frame::TraceDump => "TraceDump",
        Frame::InferOk { .. } => "InferOk",
        Frame::Pong => "Pong",
        Frame::ShutdownAck => "ShutdownAck",
        Frame::StatsOk { .. } => "StatsOk",
        Frame::TraceDumpOk { .. } => "TraceDumpOk",
        Frame::Error(_) => "Error",
    };
    DynamapError::Protocol(format!("expected a {wanted} reply, got {kind}"))
}

/// Write `frame`, read one reply. A clean server close mid-call is a
/// transport failure (the pooled connection went stale), not protocol.
fn roundtrip(conn: &mut TcpStream, frame: &Frame) -> Result<Frame, DynamapError> {
    write_frame(conn, frame)?;
    match read_frame(conn)? {
        Some(reply) => Ok(reply),
        None => Err(DynamapError::Net("server closed the connection".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    /// The satellite property test for the backoff schedule: seeded
    /// `Rng` ⇒ deterministic, pre-jitter values monotonically capped,
    /// always ≥ the server hint, total sleep bounded by the budget.
    #[test]
    fn backoff_schedule_properties() {
        check("backoff schedule", 128, |rng| {
            let policy = RetryPolicy {
                base_backoff: Duration::from_micros(rng.range(100, 5_000) as u64),
                max_backoff: Duration::from_millis(rng.range(10, 500) as u64),
                ..RetryPolicy::default()
            };
            let seed = rng.next_u64();
            let hint = if rng.bool() { Some(rng.below(300)) } else { None };

            // deterministic: same seed, same draw order ⇒ same schedule
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let seq_a: Vec<Duration> =
                (0..12).map(|i| backoff_delay(&policy, i, hint, &mut a)).collect();
            let seq_b: Vec<Duration> =
                (0..12).map(|i| backoff_delay(&policy, i, hint, &mut b)).collect();
            if seq_a != seq_b {
                return Err("same seed produced different schedules".into());
            }

            let base_us = policy.base_backoff.as_secs_f64() * 1e6;
            let cap_us = policy.max_backoff.as_secs_f64() * 1e6;
            let hint_us = hint.unwrap_or(0) as f64 * 1000.0;
            let mut total_us = 0.0;
            let mut prev_floor = 0.0;
            for (i, d) in seq_a.iter().enumerate() {
                let us = d.as_secs_f64() * 1e6;
                let floor =
                    (base_us * 2f64.powi(i.min(16) as i32)).min(cap_us).max(hint_us);
                // ≥ hint and ≥ the capped exponential it was derived from
                if us < floor - 1.0 {
                    return Err(format!("attempt {i}: delay {us}µs below floor {floor}µs"));
                }
                // ≤ the cap (or hint) with full jitter
                let ceil = cap_us.max(hint_us) * 1.25 + 1.0;
                if us > ceil {
                    return Err(format!("attempt {i}: delay {us}µs above ceiling {ceil}µs"));
                }
                // pre-jitter floor is monotone non-decreasing
                if floor < prev_floor {
                    return Err(format!("floor shrank at attempt {i}"));
                }
                prev_floor = floor;
                total_us += us;
            }
            // total sleep across a whole budget of retries is bounded
            let bound = 12.0 * cap_us.max(hint_us) * 1.25 + 12.0;
            if total_us > bound {
                return Err(format!("total {total_us}µs exceeds bound {bound}µs"));
            }
            Ok(())
        });
    }

    #[test]
    fn backoff_honors_hint_over_exponential() {
        let policy = RetryPolicy::default(); // base 1 ms, cap 100 ms
        let mut rng = Rng::new(7);
        // a hint far above the exponential floor must win
        let d = backoff_delay(&policy, 0, Some(80), &mut rng);
        assert!(d >= Duration::from_millis(80), "{d:?} ignores the 80 ms hint");
        // and a hint above the cap still wins (the server knows best)
        let d = backoff_delay(&policy, 9, Some(500), &mut rng);
        assert!(d >= Duration::from_millis(500), "{d:?} capped below the hint");
    }

    #[test]
    fn default_policy_matches_original_client_behavior() {
        let p = RetryPolicy::default();
        assert_eq!(p.transport_attempts, 2, "one fresh-dial transport retry");
        assert_eq!(p.overloaded_attempts, 0, "Overloaded surfaces raw by default");
        assert!(p.hedge.is_none(), "hedging is opt-in");
    }
}
