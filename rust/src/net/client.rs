//! [`Client`] — blocking TCP client for the DYNAMAP wire protocol,
//! with connection pooling and one transparent reconnect.
//!
//! The protocol is strictly request-reply, so a connection is "free"
//! whenever no call is using it: [`Client`] keeps a small pool of idle
//! connections, checks one out per call and returns it afterwards.
//! Typed server errors (`Overloaded`, `UnknownModel`, …) leave the
//! stream on a frame boundary, so the connection goes back to the pool;
//! transport failures ([`DynamapError::Net`]) discard the connection
//! and — because inference requests are stateless and idempotent —
//! retry exactly once on a freshly dialed one, which absorbs the
//! common case of a pooled connection going stale between calls.
//! Protocol errors never retry: the stream is out of sync, and
//! re-sending bytes at a confused peer helps nobody.

use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::api::DynamapError;
use crate::runtime::TensorBuf;
use crate::serve::loadgen::InferTarget;

use super::protocol::{read_frame, write_frame, Frame};

/// Idle connections kept per client (beyond this, checked-in
/// connections are simply closed).
const MAX_POOL: usize = 16;

/// A blocking client for one server address; cheap to share across
/// threads (`&self` methods, pool behind a mutex held only during
/// checkout/checkin — never across a network round trip).
pub struct Client {
    addr: String,
    pool: Mutex<Vec<TcpStream>>,
}

impl Client {
    /// Connect to `addr` (e.g. `"127.0.0.1:4071"`), validating the
    /// server is reachable by dialing one pooled connection.
    pub fn connect(addr: impl Into<String>) -> Result<Client, DynamapError> {
        let client = Client { addr: addr.into(), pool: Mutex::new(Vec::new()) };
        let conn = client.dial()?;
        client.checkin(conn);
        Ok(client)
    }

    /// The server address this client dials.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn dial(&self) -> Result<TcpStream, DynamapError> {
        let stream = TcpStream::connect(&self.addr)
            .map_err(|e| DynamapError::Net(format!("connect {} failed: {e}", self.addr)))?;
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    fn checkout(&self) -> Result<TcpStream, DynamapError> {
        let pooled = self.pool.lock().unwrap_or_else(|p| p.into_inner()).pop();
        match pooled {
            Some(conn) => Ok(conn),
            None => self.dial(),
        }
    }

    fn checkin(&self, conn: TcpStream) {
        let mut pool = self.pool.lock().unwrap_or_else(|p| p.into_inner());
        if pool.len() < MAX_POOL {
            pool.push(conn);
        }
    }

    /// One request-reply round trip on a checked-out connection, with
    /// a single retry on transport failure (fresh connection). Returns
    /// the reply frame with the connection already returned to the
    /// pool — except after `Shutdown`, whose connection is spent.
    fn request(&self, frame: &Frame) -> Result<Frame, DynamapError> {
        let mut last_err = None;
        for attempt in 0..2 {
            // first attempt may use a pooled (possibly stale)
            // connection; the retry always dials fresh
            let mut conn = if attempt == 0 { self.checkout()? } else { self.dial()? };
            match roundtrip(&mut conn, frame) {
                Ok(reply) => {
                    if !matches!(frame, Frame::Shutdown) {
                        self.checkin(conn);
                    }
                    return Ok(reply);
                }
                Err(e @ DynamapError::Net(_)) => {
                    last_err = Some(e); // dropped conn; retry once
                }
                Err(e) => return Err(e), // protocol error: never retry
            }
        }
        Err(last_err.expect("retry loop ran"))
    }

    /// Serve one inference for `model`; returns the output tensor
    /// (bitwise-equal to a local `Session::infer` of the same request)
    /// and the server-side end-to-end latency in µs. Server-side
    /// failures come back as their typed [`DynamapError`] — including
    /// the retriable `Overloaded` with its `retry_after_ms` hint, which
    /// this client deliberately does *not* sleep on: backoff policy
    /// belongs to the caller.
    pub fn infer(
        &self,
        model: &str,
        input: &TensorBuf,
    ) -> Result<(TensorBuf, f64), DynamapError> {
        let frame = Frame::Infer { model: model.to_string(), input: input.clone() };
        match self.request(&frame)? {
            Frame::InferOk { output, server_us } => Ok((output, server_us)),
            Frame::Error(e) => Err(e.into()),
            other => Err(unexpected("InferOk", &other)),
        }
    }

    /// Liveness probe; returns the round-trip time.
    pub fn ping(&self) -> Result<Duration, DynamapError> {
        let t0 = Instant::now();
        match self.request(&Frame::Ping)? {
            Frame::Pong => Ok(t0.elapsed()),
            Frame::Error(e) => Err(e.into()),
            other => Err(unexpected("Pong", &other)),
        }
    }

    /// Ask the server to drain and shut down; returns once the server
    /// has acked (drain begins immediately after).
    pub fn shutdown_server(&self) -> Result<(), DynamapError> {
        match self.request(&Frame::Shutdown)? {
            Frame::ShutdownAck => Ok(()),
            Frame::Error(e) => Err(e.into()),
            other => Err(unexpected("ShutdownAck", &other)),
        }
    }
}

impl InferTarget for Client {
    fn infer_once(&self, model: &str, input: &TensorBuf) -> Result<TensorBuf, DynamapError> {
        self.infer(model, input).map(|(out, _)| out)
    }
}

fn unexpected(wanted: &str, got: &Frame) -> DynamapError {
    let kind = match got {
        Frame::Infer { .. } => "Infer",
        Frame::Ping => "Ping",
        Frame::Shutdown => "Shutdown",
        Frame::InferOk { .. } => "InferOk",
        Frame::Pong => "Pong",
        Frame::ShutdownAck => "ShutdownAck",
        Frame::Error(_) => "Error",
    };
    DynamapError::Protocol(format!("expected a {wanted} reply, got {kind}"))
}

/// Write `frame`, read one reply. A clean server close mid-call is a
/// transport failure (the pooled connection went stale), not protocol.
fn roundtrip(conn: &mut TcpStream, frame: &Frame) -> Result<Frame, DynamapError> {
    write_frame(conn, frame)?;
    match read_frame(conn)? {
        Some(reply) => Ok(reply),
        None => Err(DynamapError::Net("server closed the connection".into())),
    }
}
