//! [`NetServer`] — the blocking TCP front-end over a
//! [`ModelRegistry`].
//!
//! Threading model (std only, no async runtime, matching the rest of
//! the crate): one accept thread polls a non-blocking listener; each
//! accepted connection gets a dedicated worker thread running a
//! blocking read-serve-reply loop. Inference itself is **not** done per
//! connection — workers submit into the registry's per-model
//! [`crate::serve::BatchQueue`], so concurrent connections batch
//! together exactly like in-process callers and replies stay
//! bitwise-equal to [`crate::api::Session::infer`].
//!
//! Overload safety: the registry's admission budget
//! ([`crate::serve::RegistryConfig::max_inflight`]) bounds queued work,
//! so a worker either serves a request or immediately writes a typed
//! `Overloaded` error frame — the server never queues unboundedly and
//! never stalls a shed client behind a full queue.
//!
//! Graceful drain ([`NetServer::shutdown`]): (1) stop accepting and
//! join the accept thread, dropping the listener so late connects are
//! refused by the OS; (2) half-close every connection's *read* side —
//! blocked workers wake with a clean EOF while their write sides stay
//! open; (3) join every worker — each one finishes the request it
//! already read, writes the reply, and exits on the EOF. Every accepted
//! request therefore gets exactly one reply; only then may the caller
//! drain the registry's queues ([`crate::serve::ModelRegistry::shutdown`]).
//! A remote [`Frame::Shutdown`] triggers the same sequence via
//! [`NetServer::wait_shutdown`] returning on the owner thread — the
//! worker that received the frame only acks and raises the stop flag,
//! it never joins its siblings (or itself).
//!
//! Failure isolation: every connection worker runs under
//! `catch_unwind`, and each Infer request gets its own unwind barrier
//! around the registry submit — one poisoned request costs one typed
//! `Server` error (or at worst one connection), never the process. The
//! [`crate::fault`] hooks on this path (stall, drop, corrupt-reply) let
//! the chaos harness provoke each failure deterministically.

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::DynamapError;
use crate::fault;
use crate::serve::ModelRegistry;

use super::protocol::{encode_frame, read_frame, write_frame, Frame, WireError};

/// Accept-loop poll interval while the listener has nothing to accept.
const ACCEPT_POLL: Duration = Duration::from_millis(2);

/// State shared between the accept thread, connection workers and the
/// owning [`NetServer`] handle.
struct Shared {
    registry: Arc<ModelRegistry>,
    /// Raised once; accept loop exits and workers refuse further reads.
    stop: AtomicBool,
    /// Signalled when a shutdown is requested (remote frame or local
    /// [`NetServer::request_stop`]); [`NetServer::wait_shutdown`] blocks on it.
    stop_signal: (Mutex<bool>, Condvar),
    /// Read-half handles of every live connection, keyed by connection
    /// id — drain half-closes these to wake blocked workers.
    conns: Mutex<HashMap<u64, TcpStream>>,
    /// Live connection worker handles (reaped opportunistically by the
    /// accept loop, joined exhaustively by drain).
    workers: Mutex<Vec<JoinHandle<()>>>,
    next_conn: AtomicU64,
}

impl Shared {
    fn lock_conns(&self) -> std::sync::MutexGuard<'_, HashMap<u64, TcpStream>> {
        self.conns.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn lock_workers(&self) -> std::sync::MutexGuard<'_, Vec<JoinHandle<()>>> {
        self.workers.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let (lock, cvar) = &self.stop_signal;
        *lock.lock().unwrap_or_else(|p| p.into_inner()) = true;
        cvar.notify_all();
    }
}

/// A running TCP front-end: accept thread + one worker per connection,
/// all serving one shared [`ModelRegistry`].
pub struct NetServer {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    local_addr: SocketAddr,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting connections against `registry`. Returns as soon
    /// as the listener is live; [`NetServer::local_addr`] reports the
    /// actual bound address.
    pub fn bind(
        registry: Arc<ModelRegistry>,
        addr: impl ToSocketAddrs,
    ) -> Result<NetServer, DynamapError> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| DynamapError::Net(format!("bind failed: {e}")))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| DynamapError::Net(format!("local_addr failed: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| DynamapError::Net(format!("set_nonblocking failed: {e}")))?;
        let shared = Arc::new(Shared {
            registry,
            stop: AtomicBool::new(false),
            stop_signal: (Mutex::new(false), Condvar::new()),
            conns: Mutex::new(HashMap::new()),
            workers: Mutex::new(Vec::new()),
            next_conn: AtomicU64::new(0),
        });
        let accept_shared = shared.clone();
        let accept = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(NetServer { shared, accept: Some(accept), local_addr })
    }

    /// The address the listener actually bound (resolves `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Raise the stop flag without draining — unblocks
    /// [`NetServer::wait_shutdown`]; call [`NetServer::shutdown`] to drain.
    pub fn request_stop(&self) {
        self.shared.request_stop();
    }

    /// Block until a shutdown is requested, by a remote
    /// [`Frame::Shutdown`] or a local [`NetServer::request_stop`].
    pub fn wait_shutdown(&self) {
        let (lock, cvar) = &self.shared.stop_signal;
        let mut stopped = lock.lock().unwrap_or_else(|p| p.into_inner());
        while !*stopped {
            stopped = cvar.wait(stopped).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Graceful drain (idempotent): stop accepting, wake every blocked
    /// connection read with a clean EOF, and join all workers — every
    /// request a worker already read gets its reply before this
    /// returns. Does **not** shut the registry down; the caller owns
    /// that ordering (drain the front-end first, then the queues).
    pub fn shutdown(&mut self) {
        self.shared.request_stop();
        if let Some(accept) = self.accept.take() {
            let _ = accept.join(); // drops the listener: late connects refused
        } else {
            return; // already drained
        }
        // half-close read sides: blocked `read_frame`s return EOF, but
        // in-flight replies still go out on the intact write sides
        for (_, conn) in self.shared.lock_conns().iter() {
            let _ = conn.shutdown(Shutdown::Read);
        }
        // the accept thread is joined, so no new workers can appear:
        // one sweep is exhaustive
        let workers: Vec<_> = self.shared.lock_workers().drain(..).collect();
        for w in workers {
            let _ = w.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // the worker runs a blocking loop; nodelay because the
                // protocol is strictly request-reply
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                let id = shared.next_conn.fetch_add(1, Ordering::Relaxed);
                if let Ok(read_half) = stream.try_clone() {
                    shared.lock_conns().insert(id, read_half);
                }
                let worker_shared = shared.clone();
                let handle = std::thread::spawn(move || {
                    // panic isolation: a worker that unwinds (a bug, or
                    // the chaos harness) takes down one connection, not
                    // the server — and its map entry is still cleaned
                    // up so drain never waits on a ghost
                    let cleanup_shared = worker_shared.clone();
                    let result = catch_unwind(AssertUnwindSafe(move || {
                        connection_loop(stream, id, worker_shared)
                    }));
                    if let Some(conn) = cleanup_shared.lock_conns().remove(&id) {
                        let _ = conn.shutdown(Shutdown::Both);
                    }
                    if result.is_err() {
                        eprintln!(
                            "dynamap: connection worker {id} panicked; \
                             connection dropped, server unaffected"
                        );
                    }
                });
                let mut workers = shared.lock_workers();
                workers.push(handle);
                // reap finished workers so a long-lived server does not
                // accumulate one parked handle per historical connection
                workers.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    // listener drops here → the OS refuses late connects
}

/// Serve one connection: read a frame, act, reply, repeat. Every error
/// path replies typed when the socket permits and never panics.
fn connection_loop(mut stream: TcpStream, id: u64, shared: Arc<Shared>) {
    loop {
        match read_frame(&mut stream) {
            Ok(Some(Frame::Ping)) => {
                if write_frame(&mut stream, &Frame::Pong).is_err() {
                    break;
                }
            }
            Ok(Some(Frame::Shutdown)) => {
                // ack, then only *raise the flag*: the actual drain
                // joins workers, and this thread must not join itself
                let _ = write_frame(&mut stream, &Frame::ShutdownAck);
                shared.request_stop();
                break;
            }
            Ok(Some(Frame::Infer { model, input, deadline_ms, trace })) => {
                // chaos hook: a stalled peer path delays service — the
                // deadline clock below keeps ticking through it
                fault::sleep_if(fault::Site::ConnStall);
                // the deadline starts when the server *decodes* the
                // frame: a relative wire field dodges clock skew
                let deadline =
                    deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
                // second unwind barrier, per request: even if a panic
                // escapes the batch queue's own isolation (e.g. on the
                // submit path itself), this connection answers typed
                // and lives on
                let reply = match catch_unwind(AssertUnwindSafe(|| {
                    shared.registry.infer_traced(&model, &input, deadline, trace)
                })) {
                    Ok(Ok((output, metrics))) => {
                        Frame::InferOk { output, server_us: metrics.total_us }
                    }
                    Ok(Err(e)) => Frame::Error(WireError::from(e)),
                    Err(_) => Frame::Error(WireError::Server(
                        "connection worker panicked serving the request".into(),
                    )),
                };
                // chaos hook: drop the connection after serving but
                // before replying — the client must see a transport
                // error and treat the request as safely retriable
                if fault::should_fire(fault::Site::ConnDrop) {
                    break;
                }
                // chaos hook: corrupt the reply frame's kind byte (never
                // the payload — silent data corruption is a different
                // failure class than a decodable-but-wrong frame)
                if fault::should_fire(fault::Site::CorruptReply) {
                    let mut bytes = encode_frame(&reply);
                    bytes[3] ^= 0x40;
                    if stream.write_all(&bytes).and_then(|_| stream.flush()).is_err() {
                        break;
                    }
                    continue;
                }
                if write_frame(&mut stream, &reply).is_err() {
                    break;
                }
            }
            Ok(Some(Frame::Stats)) => {
                // full metrics + per-model latency-histogram snapshot,
                // as one JSON document (`ServerMetrics::to_json`)
                let json = shared.registry.metrics().to_json().to_string();
                if write_frame(&mut stream, &Frame::StatsOk { json }).is_err() {
                    break;
                }
            }
            Ok(Some(Frame::TraceDump)) => {
                // collect-then-fetch: drain whatever the process-wide
                // recorder buffered since the last dump. No recorder
                // installed → a valid empty trace document, not an
                // error — `dynamap trace` against an untraced server
                // degrades gracefully
                let json = match crate::obs::active() {
                    Some(rec) => crate::obs::chrome_trace(&rec.drain()).to_string(),
                    None => crate::obs::chrome_trace(&[]).to_string(),
                };
                if write_frame(&mut stream, &Frame::TraceDumpOk { json }).is_err() {
                    break;
                }
            }
            Ok(Some(_)) => {
                // a response-kind frame (InferOk/Pong/…) from a client
                // is a protocol violation: reply typed, then drop the
                // connection
                let msg = "unexpected response-kind frame from client".to_string();
                let _ = write_frame(&mut stream, &Frame::Error(WireError::Protocol(msg)));
                break;
            }
            Ok(None) => break, // clean close (or drain's half-close EOF)
            Err(DynamapError::Protocol(msg)) => {
                // malformed bytes: the stream is out of sync, so reply
                // (best effort) and close — resyncing is impossible
                let _ = write_frame(
                    &mut stream,
                    &Frame::Error(WireError::Protocol(msg)),
                );
                break;
            }
            Err(_) => break, // transport failure: nothing to say it on
        }
    }
    // map-entry removal lives in the spawn wrapper (it must run even
    // when this loop unwinds); closing our own handle here just makes
    // the normal-exit close prompt
    let _ = stream.shutdown(Shutdown::Both);
}
